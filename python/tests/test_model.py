"""L2 model tests: the scan-based Jacobi-PCG vs the loop oracle, and
actual convergence on a grounded Laplacian."""

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.kernels.ref import jacobi_pcg_ref, spmv_ell_ref
from compile.kernels.spmv_ell import BLOCK_ROWS
from compile.model import jacobi_pcg


def grid_laplacian_ell(side, n_pad, k=8, ground=0.05):
    """2D grid Laplacian + ground regularization, padded to (n_pad, k)."""
    n = side * side
    assert n <= n_pad
    vals = np.zeros((n_pad, k), np.float32)
    cols = np.tile(np.arange(n_pad)[:, None], (1, k)).astype(np.int32)
    for y in range(side):
        for x in range(side):
            i = y * side + x
            nbrs = []
            if x > 0:
                nbrs.append(i - 1)
            if x < side - 1:
                nbrs.append(i + 1)
            if y > 0:
                nbrs.append(i - side)
            if y < side - 1:
                nbrs.append(i + side)
            vals[i, 0] = len(nbrs) + ground
            cols[i, 0] = i
            for s, jn in enumerate(nbrs, start=1):
                vals[i, s] = -1.0
                cols[i, s] = jn
    return jnp.asarray(vals), jnp.asarray(cols), n


def test_scan_matches_loop_reference():
    vals, cols, n = grid_laplacian_ell(16, BLOCK_ROWS, k=8)
    rng = np.random.default_rng(0)
    b = np.zeros(BLOCK_ROWS, np.float32)
    b[:n] = rng.standard_normal(n).astype(np.float32)
    b = jnp.asarray(b)
    diag = vals[:, 0]
    inv_diag = jnp.where(diag > 0, 1.0 / jnp.maximum(diag, 1e-30), 1.0)
    x_scan, norms_scan = jacobi_pcg(vals, cols, inv_diag, b, iters=20)
    x_ref, norms_ref = jacobi_pcg_ref(vals, cols, inv_diag, b, iters=20)
    assert_allclose(np.asarray(x_scan), np.asarray(x_ref), rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(norms_scan), np.asarray(norms_ref), rtol=2e-3, atol=1e-4)


def test_pcg_converges_on_spd_grid():
    vals, cols, n = grid_laplacian_ell(16, BLOCK_ROWS, k=8, ground=0.2)
    rng = np.random.default_rng(1)
    x_true = np.zeros(BLOCK_ROWS, np.float32)
    x_true[:n] = rng.standard_normal(n).astype(np.float32)
    b = spmv_ell_ref(vals, cols, jnp.asarray(x_true))
    diag = vals[:, 0]
    inv_diag = jnp.where(diag > 0, 1.0 / jnp.maximum(diag, 1e-30), 1.0)
    x, norms = jacobi_pcg(vals, cols, inv_diag, b, iters=100)
    norms = np.asarray(norms)
    assert norms[-1] < 1e-3 * max(norms[0], 1e-30), f"no convergence: {norms[-1]}"
    assert_allclose(np.asarray(x)[:n], x_true[:n], rtol=2e-2, atol=2e-2)


def test_residuals_mostly_decrease():
    vals, cols, n = grid_laplacian_ell(12, BLOCK_ROWS, k=8, ground=0.1)
    # b must be zero on padded rows (the operator is zero there).
    b = np.zeros(BLOCK_ROWS, np.float32)
    b[:n] = np.random.default_rng(3).standard_normal(n).astype(np.float32)
    b = jnp.asarray(b)
    diag = vals[:, 0]
    inv_diag = jnp.where(diag > 0, 1.0 / jnp.maximum(diag, 1e-30), 1.0)
    _, norms = jacobi_pcg(vals, cols, inv_diag, b, iters=50)
    norms = np.asarray(norms)
    assert norms[-1] < norms[0]
