"""AOT lowering smoke tests: every artifact lowers to parsable-looking
HLO text with the expected entry signature (fast checks — no PJRT
compile here; the rust integration test does the full round-trip)."""

import jax

from compile import aot


def test_all_artifacts_lower():
    for name, fn, specs, _desc in aot.artifact_definitions():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, f"{name}: missing entry computation"
        # Tuple return (return_tuple=True) — the rust loader unwraps it.
        assert "tuple(" in text or "tuple " in text.lower(), f"{name}: no tuple root"


def test_artifact_names_match_rust_constants():
    names = [d[0] for d in aot.artifact_definitions()]
    # Keep in sync with rust/src/runtime/sampler.rs BUCKET_WIDTHS/BATCH.
    for k in (16, 64, 256):
        assert f"sample_b64_k{k}" in names
    assert "pcg_n4096_k8" in names
    assert "spmv_n4096_k8" in names


def test_sample_artifact_is_executable_locally():
    """Sanity: the lowered sampling computation still runs under jit."""
    import jax.numpy as jnp
    import numpy as np

    from compile.model import sample_entry

    w = np.zeros((64, 16), np.float32)
    w[:, -2] = 1.0
    w[:, -1] = 2.0
    u = np.full((64, 16), 0.25, np.float32)
    j, wn = jax.jit(sample_entry)(jnp.asarray(w), jnp.asarray(u))
    assert j.shape == (64, 16)
    assert np.all(np.asarray(j)[:, -2] == 15)  # only valid sample pairs with the last
