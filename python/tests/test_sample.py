"""Pallas clique-sampling kernel vs the oracle + statistical checks."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.kernels.ref import sample_clique_ref
from compile.kernels.sample_clique import BLOCK_B, sample_clique


def make_batch(rng, b, k):
    """Random front-padded ascending weight rows + uniforms."""
    w = np.zeros((b, k), np.float32)
    u = rng.random((b, k)).astype(np.float32)
    for row in range(b):
        m = rng.integers(0, k + 1)
        if m > 0:
            ws = np.sort(rng.random(m).astype(np.float32) * 10 + 0.01)
            w[row, k - m :] = ws
    return jnp.asarray(w), jnp.asarray(u)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    k=st.sampled_from([4, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_reference(k, seed):
    rng = np.random.default_rng(seed)
    b = 4 * BLOCK_B
    w, u = make_batch(rng, b, k)
    jk, wk = sample_clique(w, u)
    jr, wr = sample_clique_ref(w, u)
    np.testing.assert_array_equal(np.asarray(jk), np.asarray(jr))
    assert_allclose(np.asarray(wk), np.asarray(wr), rtol=1e-6, atol=1e-7)


def test_partner_is_strictly_later():
    rng = np.random.default_rng(0)
    w, u = make_batch(rng, BLOCK_B, 16)
    j, wn = sample_clique(w, u)
    j = np.asarray(j)
    wn = np.asarray(wn)
    wnp = np.asarray(w)
    for row in range(BLOCK_B):
        for i in range(16):
            if j[row, i] >= 0:
                assert j[row, i] > i
                assert wnp[row, j[row, i]] > 0, "partner must be a live neighbor"
                assert wn[row, i] > 0


def test_invalid_rows_and_padding():
    k = 8
    w = np.zeros((BLOCK_B, k), np.float32)
    # Row 0: empty. Row 1: single neighbor (no samples possible).
    w[1, -1] = 3.0
    u = np.full((BLOCK_B, k), 0.5, np.float32)
    j, wn = sample_clique(jnp.asarray(w), jnp.asarray(u))
    assert np.all(np.asarray(j)[0] == -1)
    assert np.all(np.asarray(j)[1] == -1)
    assert np.all(np.asarray(wn)[:2] == 0.0)


def test_expectation_preserves_clique():
    """E[w(i,j)] == w_i w_j / total over the uniform draws."""
    k = 8
    weights = np.array([0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 3.0], np.float32)
    total = 6.0
    rng = np.random.default_rng(42)
    trials = 4000
    acc = {}
    for t in range(trials // BLOCK_B):
        w = np.tile(weights, (BLOCK_B, 1))
        u = rng.random((BLOCK_B, k)).astype(np.float32)
        j, wn = sample_clique(jnp.asarray(w), jnp.asarray(u))
        j = np.asarray(j)
        wn = np.asarray(wn)
        for row in range(BLOCK_B):
            for i in range(k):
                if j[row, i] >= 0:
                    key = (i, int(j[row, i]))
                    acc[key] = acc.get(key, 0.0) + float(wn[row, i])
    n_total = (trials // BLOCK_B) * BLOCK_B
    for (i, j_), s in acc.items():
        want = weights[i] * weights[j_] / total
        got = s / n_total
        assert abs(got - want) < 0.15 * max(want, 0.2), f"pair {(i, j_)}: {got} vs {want}"


def test_weight_mass_deterministic():
    """Σ_i w_new_i is u-independent: w_i·rest_i/total summed."""
    k = 16
    rng = np.random.default_rng(7)
    w, _ = make_batch(rng, BLOCK_B, k)
    u1 = jnp.asarray(rng.random((BLOCK_B, k)).astype(np.float32))
    u2 = jnp.asarray(rng.random((BLOCK_B, k)).astype(np.float32))
    _, w1 = sample_clique(w, u1)
    _, w2 = sample_clique(w, u2)
    assert_allclose(
        np.asarray(w1).sum(axis=1), np.asarray(w2).sum(axis=1), rtol=1e-5
    )
