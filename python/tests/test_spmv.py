"""Pallas ELL SpMV vs the pure-jnp oracle (hypothesis shape sweep)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.kernels.ref import spmv_ell_ref
from compile.kernels.spmv_ell import BLOCK_ROWS, spmv_ell


def make_ell(rng, n, k):
    """Random padded-ELL operator with in-bounds columns."""
    vals = rng.standard_normal((n, k)).astype(np.float32)
    cols = rng.integers(0, n, size=(n, k)).astype(np.int32)
    # Randomly blank some slots (padding pattern).
    mask = rng.random((n, k)) < 0.3
    vals[mask] = 0.0
    return jnp.asarray(vals), jnp.asarray(cols)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    blocks=st.integers(min_value=1, max_value=4),
    k=st.sampled_from([1, 4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_reference(blocks, k, seed):
    rng = np.random.default_rng(seed)
    n = blocks * BLOCK_ROWS
    vals, cols = make_ell(rng, n, k)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = spmv_ell(vals, cols, x)
    want = spmv_ell_ref(vals, cols, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_zero_matrix():
    n, k = BLOCK_ROWS, 8
    vals = jnp.zeros((n, k), jnp.float32)
    cols = jnp.zeros((n, k), jnp.int32)
    x = jnp.ones(n, jnp.float32)
    assert np.all(np.asarray(spmv_ell(vals, cols, x)) == 0.0)


def test_identity_like():
    n, k = BLOCK_ROWS, 4
    vals = np.zeros((n, k), np.float32)
    cols = np.zeros((n, k), np.int32)
    vals[:, 0] = 2.0
    cols[:, 0] = np.arange(n)
    x = np.linspace(-1, 1, n).astype(np.float32)
    got = spmv_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    assert_allclose(np.asarray(got), 2.0 * x, rtol=1e-6)


def test_laplacian_row_sums():
    """A 1D path-graph Laplacian in ELL: L @ 1 == 0."""
    n, k = BLOCK_ROWS, 4
    vals = np.zeros((n, k), np.float32)
    cols = np.tile(np.arange(n)[:, None], (1, k)).astype(np.int32)
    for i in range(n):
        entries = [(i, 2.0 if 0 < i < n - 1 else 1.0)]
        if i > 0:
            entries.append((i - 1, -1.0))
        if i < n - 1:
            entries.append((i + 1, -1.0))
        for slot, (c, v) in enumerate(entries):
            cols[i, slot] = c
            vals[i, slot] = v
    y = spmv_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.ones(n, jnp.float32))
    assert_allclose(np.asarray(y), np.zeros(n), atol=1e-6)
