"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references the pytest suite checks the
kernels against (`assert_allclose`), and they document the exact
semantics the rust runtime (`rust/src/runtime/sampler.rs`) relies on.
"""

from __future__ import annotations

import jax.numpy as jnp


def spmv_ell_ref(vals: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Padded-ELL SpMV: ``y[r] = sum_k vals[r, k] * x[cols[r, k]]``.

    Padding slots carry ``vals == 0`` with an in-bounds self-referencing
    column, so they contribute nothing.
    """
    return jnp.sum(vals * x[cols], axis=1)


def sample_clique_ref(w: jnp.ndarray, u: jnp.ndarray):
    """Batched AC clique sampling (Algorithm 2 inner loop), vectorized.

    Args:
      w: ``(B, K)`` f32 — merged neighbor weights per pivot, sorted
        ascending, **front-padded** with zeros (padding first keeps the
        ascending order valid).
      u: ``(B, K)`` f32 — uniform draws in ``[0, 1)`` per sample slot
        (host-generated from the per-pivot RNG stream).

    Returns:
      ``(j_idx, w_new)`` both ``(B, K)``:
      * ``j_idx`` i32 — absolute index of the sampled partner for the
        neighbor at each position ``i`` (−1 where no sample is drawn:
        padding slots and the last live neighbor);
      * ``w_new`` f32 — the fill edge's weight
        ``w_i · (Σ_{t>i} w_t) / ℓ_kk`` (0 where invalid).

    Semantics per row: ``P = cumsum(w)``; ``total = P[-1]``;
    ``rest_i = total − P[i]``; partner
    ``j = #{t : P[t] ≤ P[i] + u_i·rest_i}`` (inverse-CDF over the
    suffix); valid iff ``w_i > 0`` and ``rest_i > 0``.
    """
    K = w.shape[1]
    P = jnp.cumsum(w, axis=1)  # inclusive prefix sums
    total = P[:, -1:]
    below = P
    rest = total - below
    valid = (w > 0.0) & (rest > 1e-30)
    target = below + u * rest
    # j = count of prefix entries <= target  (first index with P > target)
    j = jnp.sum(P[:, None, :] <= target[:, :, None], axis=2)
    # Guard: partner strictly after i, inside the row.
    i_idx = jnp.arange(K)[None, :]
    j = jnp.clip(j, i_idx + 1, K - 1)
    w_new = jnp.where(valid, w * rest / jnp.maximum(total, 1e-30), 0.0)
    j_idx = jnp.where(valid, j, -1).astype(jnp.int32)
    return j_idx, w_new.astype(jnp.float32)


def jacobi_pcg_ref(vals, cols, inv_diag, b, iters: int):
    """Reference Jacobi-preconditioned CG on an ELL operator.

    Plain python loop (no scan) — the oracle for ``model.jacobi_pcg``.
    Returns ``(x, res_norms)`` with ``res_norms`` of length ``iters``.
    """
    x = jnp.zeros_like(b)
    r = b
    z = inv_diag * r
    p = z
    rz = jnp.dot(r, z)
    norms = []
    for _ in range(iters):
        ap = spmv_ell_ref(vals, cols, p)
        pap = jnp.dot(p, ap)
        alpha = jnp.where(pap > 0, rz / jnp.maximum(pap, 1e-30), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        z = inv_diag * r
        rz_new = jnp.dot(r, z)
        beta = jnp.where(rz > 0, rz_new / jnp.maximum(rz, 1e-30), 0.0)
        p = z + beta * p
        rz = rz_new
        norms.append(jnp.linalg.norm(r))
    return x, jnp.stack(norms)
