"""Pallas ELL SpMV kernel — the solve-phase hot spot (Layer 1).

TPU adaptation (DESIGN.md §Hardware-Adaptation): rather than a
CUDA-style one-warp-per-row gather, rows are tiled into VMEM blocks via
``BlockSpec`` — each grid step loads a ``(BLOCK_ROWS, K)`` tile of
values/columns plus the full ``x`` vector (N·4 bytes; at N=4096 that is
16 KiB, far under VMEM), does a vectorized gather + row reduction on
the VPU, and writes a ``(BLOCK_ROWS,)`` slice of ``y``. The MXU is not
used — SpMV is bandwidth-bound (the paper's §3.1.1 point: AC/ParAC's
operations don't block; same for its solve phase).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel lowers to plain HLO (numerics are
identical; real-TPU performance is estimated structurally in DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _spmv_kernel(vals_ref, cols_ref, x_ref, y_ref):
    """One row-tile: gather x at the tile's column ids, reduce rows."""
    vals = vals_ref[...]  # (BLOCK_ROWS, K)
    cols = cols_ref[...]  # (BLOCK_ROWS, K)
    x = x_ref[...]  # (N,)
    gathered = jnp.take(x, cols, axis=0)  # VPU gather
    y_ref[...] = jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=())
def spmv_ell(vals: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``y = A x`` for a padded-ELL matrix ``(N, K)``; N % BLOCK_ROWS == 0."""
    n, k = vals.shape
    assert n % BLOCK_ROWS == 0, f"N={n} must be a multiple of {BLOCK_ROWS}"
    grid = (n // BLOCK_ROWS,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),  # x resident in VMEM
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(vals, cols, x)
