"""Pallas batched clique-sampling kernel — the paper's per-vertex
stage-2 hot spot (Algorithm 2 / Algorithm 4 lines 17–22) as a Layer-1
kernel.

The GPU paper runs one thread block per pivot: sort by weight, suffix
sums, then each lane draws its partner with a parallel binary search.
TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of a block per
pivot we **batch** `B` pivots into a `(B, K)` tile held in VMEM — the
sort is pre-applied host-side (the rust coordinator keeps neighbors
merged and weight-sorted anyway), the suffix CDF becomes a row cumsum,
and the per-lane binary search becomes a vectorized rank computation
`sum(P <= target)` over the tile: an all-compare that trades the
device's `log K` search for one VPU-friendly dense comparison — the
natural choice when K is small and fixed.

Inputs are front-padded (zeros first keeps ascending order); the
uniform draws come from the host so the samples reproduce the native
engines' RNG streams exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of the (B, K) batch processed per grid step; K ≤ 256 keeps the
# (BLOCK_B, K, K) comparison cube small (8·256·256·4 B = 2 MiB < VMEM).
BLOCK_B = 8


def _sample_kernel(w_ref, u_ref, j_ref, wn_ref):
    """One batch tile: cumsum CDF + rank-search + weight assignment."""
    w = w_ref[...]  # (BLOCK_B, K)
    u = u_ref[...]
    K = w.shape[1]
    P = jnp.cumsum(w, axis=1)
    total = P[:, -1:]
    rest = total - P
    valid = (w > 0.0) & (rest > 1e-30)
    target = P + u * rest
    j = jnp.sum((P[:, None, :] <= target[:, :, None]).astype(jnp.int32), axis=2)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)
    j = jnp.clip(j, i_idx + 1, K - 1)
    j_ref[...] = jnp.where(valid, j, -1).astype(jnp.int32)
    wn_ref[...] = jnp.where(valid, w * rest / jnp.maximum(total, 1e-30), 0.0).astype(
        jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def sample_clique(w: jnp.ndarray, u: jnp.ndarray):
    """Batched sampling over `(B, K)`; B % BLOCK_B == 0.

    Returns `(j_idx i32, w_new f32)`, see `ref.sample_clique_ref`.
    """
    b, k = w.shape
    assert b % BLOCK_B == 0, f"B={b} must be a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    return pl.pallas_call(
        _sample_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=True,
    )(w, u)
