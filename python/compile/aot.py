"""AOT lowering: JAX → HLO **text** artifacts for the rust runtime.

Interchange format is HLO text, not ``lowered.compile()`` /
``.serialize()``: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids that the crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; the rust binary then never touches
python. Emits::

    artifacts/pcg_n4096_k8.hlo.txt        # L2 Jacobi-PCG model
    artifacts/spmv_n4096_k8.hlo.txt       # bare L1 SpMV
    artifacts/sample_b64_k{16,64,256}.hlo.txt  # L1 clique sampling
    artifacts/manifest.json               # shapes/dtypes per artifact

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Static shapes — must match rust/src/runtime/sampler.rs and the
# hlo_pcg example.
PCG_N = 4096
PCG_K = 8
SAMPLE_B = 64
SAMPLE_KS = (16, 64, 256)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_definitions():
    """(name, fn, arg_specs, description) for every artifact."""
    f32, i32 = jnp.float32, jnp.int32
    defs = [
        (
            f"pcg_n{PCG_N}_k{PCG_K}",
            model.pcg_entry,
            [
                _spec((PCG_N, PCG_K), f32),
                _spec((PCG_N, PCG_K), i32),
                _spec((PCG_N,), f32),
                _spec((PCG_N,), f32),
            ],
            "Jacobi-PCG, 100 fixed iterations over padded-ELL",
        ),
        (
            f"spmv_n{PCG_N}_k{PCG_K}",
            model.spmv_entry,
            [
                _spec((PCG_N, PCG_K), f32),
                _spec((PCG_N, PCG_K), i32),
                _spec((PCG_N,), f32),
            ],
            "bare Pallas ELL SpMV",
        ),
    ]
    for k in SAMPLE_KS:
        defs.append(
            (
                f"sample_b{SAMPLE_B}_k{k}",
                model.sample_entry,
                [_spec((SAMPLE_B, k), f32), _spec((SAMPLE_B, k), f32)],
                f"batched clique sampling, bucket width {k}",
            )
        )
    return defs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn, specs, desc in artifact_definitions():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "description": desc,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
