"""Layer 2 — the JAX compute graph built on the Pallas kernels.

The paper's solve phase is PCG with the randomized factor; the
fixed-shape AOT model compiled here is the **Jacobi-PCG inner loop**
over a padded-ELL operator (`lax.scan`, fixed iteration count — PJRT
executables need static shapes). The rust coordinator uses it as the
L2 demonstration path (`examples/hlo_pcg.rs`): same numerics as the
native rust PCG with a Jacobi preconditioner.

Build-time only: nothing here is imported at serve/solve time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.spmv_ell import spmv_ell


@functools.partial(jax.jit, static_argnames=("iters",))
def jacobi_pcg(vals, cols, inv_diag, b, iters: int = 100):
    """Run `iters` fixed PCG steps; returns `(x, res_norm_history)`.

    All shapes static: `vals/cols (N, K)`, `inv_diag/b (N,)`.
    Singular or exhausted directions degrade to zero steps (`alpha = 0`)
    instead of NaNs so the scan is total.
    """

    def step(state, _):
        x, r, p, rz = state
        ap = spmv_ell(vals, cols, p)
        pap = jnp.dot(p, ap)
        ok = pap > 0
        alpha = jnp.where(ok, rz / jnp.maximum(pap, 1e-30), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        z = inv_diag * r
        rz_new = jnp.dot(r, z)
        beta = jnp.where(rz > 0, rz_new / jnp.maximum(rz, 1e-30), 0.0)
        p = z + beta * p
        return (x, r, p, rz_new), jnp.linalg.norm(r)

    x0 = jnp.zeros_like(b)
    z0 = inv_diag * b
    init = (x0, b, z0, jnp.dot(b, z0))
    (x, _, _, _), norms = jax.lax.scan(step, init, None, length=iters)
    return x, norms


def pcg_entry(vals, cols, inv_diag, b):
    """AOT entry point (tuple output, fixed 100 iterations)."""
    x, norms = jacobi_pcg(vals, cols, inv_diag, b, iters=100)
    return (x, norms)


def sample_entry(w, u):
    """AOT entry point for the batched sampling kernel (tuple output)."""
    from .kernels.sample_clique import sample_clique

    j, wn = sample_clique(w, u)
    return (j, wn)


def spmv_entry(vals, cols, x):
    """AOT entry point for a bare SpMV (tuple output)."""
    return (spmv_ell(vals, cols, x),)
