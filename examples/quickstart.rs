//! Quickstart: build a Laplacian, factor it with ParAC, solve with PCG.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use parac::factor::{factorize, Engine, ParacOptions};
use parac::graph::generators::{self, Coeff};
use parac::ordering::Ordering;
use parac::precond::LdlPrecond;
use parac::solve::pcg::{self, PcgOptions};
use parac::util::{fmt_count, fmt_duration, timed};

fn main() {
    // 1. A Laplacian: 3D Poisson on a 24³ grid (13.8k vertices).
    let lap = generators::grid3d(24, 24, 24, Coeff::Uniform, 42);
    println!(
        "matrix: {}  n={}  nnz={}",
        lap.name,
        fmt_count(lap.n()),
        fmt_count(lap.matrix.nnz())
    );

    // 2. Factor with the parallel CPU engine and nnz-sort ordering.
    let opts = ParacOptions {
        ordering: Ordering::NnzSort,
        engine: Engine::Cpu { threads: 0 }, // auto
        seed: 7,
        ..Default::default()
    };
    let (factor, dt) = timed(|| factorize(&lap, &opts).expect("factorization"));
    println!(
        "factor: {} in {}  (nnz(G)={}, fill ratio {:.2})",
        opts.engine.name(),
        fmt_duration(dt),
        fmt_count(factor.nnz()),
        factor.fill_ratio(lap.matrix.nnz()),
    );

    // 3. Solve L x = b with ParAC-preconditioned CG.
    let b = pcg::random_rhs(&lap, 1);
    let pre = LdlPrecond::new(factor);
    let (out, ds) = timed(|| pcg::solve(&lap.matrix, &b, &pre, &PcgOptions::default()));
    println!(
        "solve: {} iterations in {}  (relative residual {:.2e}, converged={})",
        out.iters,
        fmt_duration(ds),
        out.rel_residual,
        out.converged,
    );
    assert!(out.converged, "quickstart must converge");
    println!("OK");
}
