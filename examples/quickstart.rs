//! Quickstart: build a Laplacian, open a `Solver` session, solve
//! several right-hand sides against one factor and one workspace.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use parac::error::ParacError;
use parac::factor::Engine;
use parac::graph::generators::{self, Coeff};
use parac::ordering::Ordering;
use parac::solve::pcg;
use parac::solver::Solver;
use parac::util::{fmt_count, fmt_duration, timed};

fn main() -> Result<(), ParacError> {
    // 1. A Laplacian: 3D Poisson on a 24³ grid (13.8k vertices).
    let lap = generators::grid3d(24, 24, 24, Coeff::Uniform, 42);
    println!(
        "matrix: {}  n={}  nnz={}",
        lap.name,
        fmt_count(lap.n()),
        fmt_count(lap.matrix.nnz())
    );

    // 2. Configure + factor once: the builder carries ordering, engine,
    //    seed, solve-phase parallelism, and PCG tolerances; `build`
    //    runs the parallel CPU engine on the persistent worker pool.
    let (solver, dt) = timed(|| {
        Solver::builder()
            .ordering(Ordering::NnzSort)
            .engine(Engine::Cpu { threads: 0 }) // factor workers, auto
            .threads(0) // solve workers (SpMV + level solves), whole pool
            .seed(7)
            .build(&lap)
    });
    let mut solver = solver?;
    let stats = solver.factor_stats().expect("ParAC factor present");
    println!(
        "factor: cpu in {}  (nnz(M)={}, {})",
        fmt_duration(dt),
        fmt_count(solver.preconditioner().nnz()),
        stats.summary(),
    );

    // 3. Solve a batch of right-hand sides with the same session — one
    //    factor, one worker pool, one PCG workspace across the whole
    //    batch; no per-solve setup, zero allocations per iteration, and
    //    results bit-identical to looping `solve_into`.
    let bs: Vec<Vec<f64>> = (1..=3u64).map(|seed| pcg::random_rhs(&lap, seed)).collect();
    let refs: Vec<&[f64]> = bs.iter().map(|b| b.as_slice()).collect();
    let mut xs = vec![Vec::new(); bs.len()];
    let t = parac::util::Timer::start();
    let stats = solver.solve_batch(&refs, &mut xs)?;
    let ds = t.secs();
    for (i, out) in stats.iter().enumerate() {
        println!(
            "solve rhs#{}: {} iterations  (relative residual {:.2e}, converged={})",
            i + 1,
            out.iters,
            out.rel_residual,
            out.converged,
        );
        assert!(out.converged, "quickstart must converge");
    }
    println!(
        "batch of {} solved in {} ({} per rhs)",
        stats.len(),
        fmt_duration(ds),
        fmt_duration(ds / stats.len() as f64),
    );
    println!("OK");
    Ok(())
}
