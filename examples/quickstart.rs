//! Quickstart: build a Laplacian, open a `Solver` session, solve
//! several right-hand sides against one factor and one workspace.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use parac::error::ParacError;
use parac::factor::Engine;
use parac::graph::generators::{self, Coeff};
use parac::ordering::Ordering;
use parac::solve::pcg;
use parac::solver::Solver;
use parac::util::{fmt_count, fmt_duration, timed};

fn main() -> Result<(), ParacError> {
    // 1. A Laplacian: 3D Poisson on a 24³ grid (13.8k vertices).
    let lap = generators::grid3d(24, 24, 24, Coeff::Uniform, 42);
    println!(
        "matrix: {}  n={}  nnz={}",
        lap.name,
        fmt_count(lap.n()),
        fmt_count(lap.matrix.nnz())
    );

    // 2. Configure + factor once: the builder carries ordering, engine,
    //    seed, and PCG tolerances; `build` runs the parallel CPU engine.
    let (solver, dt) = timed(|| {
        Solver::builder()
            .ordering(Ordering::NnzSort)
            .engine(Engine::Cpu { threads: 0 }) // auto
            .seed(7)
            .build(&lap)
    });
    let mut solver = solver?;
    let stats = solver.factor_stats().expect("ParAC factor present");
    println!(
        "factor: cpu in {}  (nnz(M)={}, {})",
        fmt_duration(dt),
        fmt_count(solver.preconditioner().nnz()),
        stats.summary(),
    );

    // 3. Solve several right-hand sides with the same session — the
    //    factor and the PCG workspace are reused; no per-solve setup,
    //    zero allocations per iteration.
    let mut x = vec![0.0; lap.n()];
    for seed in 1..=3u64 {
        let b = pcg::random_rhs(&lap, seed);
        let (out, ds) = {
            let t = parac::util::Timer::start();
            let out = solver.solve_into(&b, &mut x)?;
            (out, t.secs())
        };
        println!(
            "solve rhs#{seed}: {} iterations in {}  (relative residual {:.2e}, converged={})",
            out.iters,
            fmt_duration(ds),
            out.rel_residual,
            out.converged,
        );
        assert!(out.converged, "quickstart must converge");
    }
    println!("OK");
    Ok(())
}
