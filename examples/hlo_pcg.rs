//! Layer-composition proof: run the AOT-compiled JAX/Pallas Jacobi-PCG
//! model (L2 calling the L1 SpMV kernel, lowered to HLO text by
//! `python/compile/aot.py`) from rust via PJRT, and cross-check it
//! against the native rust PCG on the same operator.
//!
//! Requires `make artifacts` to have run and the crate to be built with
//! the `xla` feature; skips gracefully (exit 0) otherwise.
//!
//! ```bash
//! cargo run --release --example hlo_pcg
//! ```

use parac::graph::generators::{self, Coeff};
use parac::runtime::Artifacts;
use parac::solver::{PrecondKind, Solver};
use parac::sparse::Ell;

const N_PAD: usize = 4096;
const WIDTH: usize = 8;

fn main() -> anyhow::Result<()> {
    // Grounded 2D Poisson (SPD) that fits the compiled (4096, 8) shape.
    let side = 60;
    let lap = generators::grid2d(side, side, Coeff::Uniform, 5);
    let mut coo = parac::sparse::Coo::new(lap.n(), lap.n());
    for r in 0..lap.n() {
        for (&c, &v) in lap.matrix.row_indices(r).iter().zip(lap.matrix.row_data(r)) {
            coo.push(r as u32, c, v);
        }
        coo.push(r as u32, r as u32, 0.1); // ground → SPD
    }
    let a = coo.to_csr();
    let ell = Ell::from_csr(&a, N_PAD, WIDTH).map_err(|e| anyhow::anyhow!(e))?;

    let b: Vec<f64> = (0..a.nrows).map(|i| ((i as f64) * 0.17).sin()).collect();
    let bpad = ell.pad_vec(&b);
    let inv_diag: Vec<f32> = (0..N_PAD)
        .map(|i| {
            if i < a.nrows {
                1.0 / a.get(i, i) as f32
            } else {
                1.0
            }
        })
        .collect();

    // --- PJRT path: the AOT model. ---
    let mut arts = match Artifacts::open_default() {
        Ok(a) => a,
        Err(e) => {
            println!("skipping hlo_pcg: {e}");
            return Ok(());
        }
    };
    println!("PJRT platform: {}", arts.platform());
    let exe = match arts.load(&format!("pcg_n{N_PAD}_k{WIDTH}")) {
        Ok(e) => e,
        Err(e) => {
            println!("skipping hlo_pcg: {e} (generate artifacts with python/compile/aot.py first)");
            return Ok(());
        }
    };
    let t = std::time::Instant::now();
    let outputs = run_pcg_hlo(exe, &ell, &inv_diag, &bpad)?;
    let dt_hlo = t.elapsed().as_secs_f64();
    let x_hlo = &outputs.0;
    let norms = &outputs.1;
    println!(
        "HLO PCG: 100 fixed iterations in {:.3}s, ‖r‖ {:.3e} → {:.3e}",
        dt_hlo,
        norms.first().copied().unwrap_or(0.0),
        norms.last().copied().unwrap_or(0.0)
    );

    // --- Native path: a Jacobi Solver session on the same SPD system
    // (build_sdd: raw Csr, projection off). ---
    let t = std::time::Instant::now();
    let mut session = Solver::builder()
        .preconditioner(PrecondKind::Jacobi)
        .tol(1e-10)
        .max_iter(100)
        .build_sdd(&a)?;
    let native = session.solve(&b)?;
    let dt_native = t.elapsed().as_secs_f64();
    println!(
        "native PCG: {} iterations in {:.3}s, rel residual {:.3e}",
        native.iters, dt_native, native.rel_residual
    );

    // --- Cross-check: solutions agree to f32-ish accuracy. ---
    let mut max_diff = 0.0f64;
    let mut max_ref = 0.0f64;
    for i in 0..a.nrows {
        max_diff = max_diff.max((x_hlo[i] as f64 - native.x[i]).abs());
        max_ref = max_ref.max(native.x[i].abs());
    }
    let rel = max_diff / max_ref.max(1e-30);
    println!("max |x_hlo − x_native| / ‖x‖∞ = {rel:.3e}");
    anyhow::ensure!(rel < 5e-3, "HLO and native PCG disagree: {rel}");
    // And the HLO residual actually dropped by orders of magnitude.
    let drop = norms.first().copied().unwrap_or(1.0) / norms.last().copied().unwrap_or(1.0).max(1e-30);
    anyhow::ensure!(drop > 1e3, "HLO PCG failed to converge (drop {drop:.1})");
    println!("hlo_pcg OK — all three layers compose");
    Ok(())
}

/// Execute the compiled PCG artifact: inputs (vals f32, cols i32,
/// inv_diag f32, b f32), outputs (x, residual-norm history).
fn run_pcg_hlo(
    exe: &parac::runtime::LoadedExec,
    ell: &Ell,
    inv_diag: &[f32],
    b: &[f32],
) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
    let out = exe.run_mixed(
        &[
            parac::runtime::pjrt::Input::F32(&ell.vals, &[N_PAD, WIDTH]),
            parac::runtime::pjrt::Input::I32(&ell.cols, &[N_PAD, WIDTH]),
            parac::runtime::pjrt::Input::F32(inv_diag, &[N_PAD]),
            parac::runtime::pjrt::Input::F32(b, &[N_PAD]),
        ],
    )?;
    Ok((out[0].clone(), out[1].clone()))
}
