//! Graph sparsification via ParAC — the paper's §1 closing use-case:
//! "ParAC, combined with sketching, provides a fast framework for graph
//! sparsification". This example approximates effective resistances
//! with the ParAC preconditioner + a Johnson–Lindenstrauss sketch and
//! resamples the graph by resistance (Spielman–Srivastava), then checks
//! the sparsifier's quality spectrally.
//!
//! ```bash
//! cargo run --release --example graph_sparsify [-- --side 40 --eps 0.5]
//! ```

use parac::cli::args::Args;
use parac::graph::generators::{self, Coeff};
use parac::graph::Laplacian;
use parac::rng::Rng;
use parac::solve::pcg;
use parac::solver::Solver;
use parac::sparse::ops::dot;
use parac::util::{fmt_count, timed};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let side = args.get_parse("side", 40usize);
    let eps = args.get_parse("eps", 0.5f64);
    let sketches = args.get_parse("sketches", 12usize);

    let lap = generators::grid2d(side, side, Coeff::Uniform, 3);
    let edges = lap.edges();
    println!(
        "input: {}  n={} edges={}",
        lap.name,
        fmt_count(lap.n()),
        fmt_count(edges.len())
    );

    // 1. One ParAC solver session — factor once, then every sketch row
    //    reuses the same factor and PCG workspace (allocation-free
    //    iterations).
    let (mut solver, dt) = timed(|| {
        Solver::builder().tol(1e-6).max_iter(1000).build(&lap).expect("solver setup")
    });
    println!(
        "ParAC session: {:.3}s setup (nnz(M)={})",
        dt,
        fmt_count(solver.preconditioner().nnz())
    );

    // 2. JL sketch: R_eff(u,v) ≈ ‖Z(e_u − e_v)‖² with Z = Q W B L⁺, where
    //    B is the signed incidence, W the weights, Q random ±1/√k rows.
    //    Each sketch row costs one PCG solve of L x = (QWB)ᵀ row.
    let n = lap.n();
    let mut rng = Rng::new(99);
    let mut z_rows: Vec<Vec<f64>> = Vec::with_capacity(sketches);
    let (_, t_sketch) = timed(|| {
        let mut x = vec![0.0; n];
        for _ in 0..sketches {
            // y = (Q W^1/2 B)ᵀ q for a random ±1 edge-vector q.
            let mut y = vec![0.0; n];
            for &(u, v, w) in &edges {
                let s = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                let c = s * w.sqrt() / (sketches as f64).sqrt();
                y[u as usize] += c;
                y[v as usize] -= c;
            }
            solver.solve_into(&y, &mut x).expect("sketch solve");
            z_rows.push(x.clone());
        }
    });
    println!("sketch: {sketches} solves in {t_sketch:.2}s");

    // 3. Resistance estimates → importance sampling of edges.
    let mut r_eff: Vec<f64> = edges
        .iter()
        .map(|&(u, v, _)| {
            z_rows
                .iter()
                .map(|z| {
                    let d = z[u as usize] - z[v as usize];
                    d * d
                })
                .sum::<f64>()
        })
        .collect();
    // Clamp into the valid range (estimates are noisy).
    for r in r_eff.iter_mut() {
        *r = r.clamp(1e-12, 1.0 / eps);
    }
    let q = ((lap.n() as f64).ln() * 9.0 / (eps * eps)) as usize;
    let probs: Vec<f64> = edges
        .iter()
        .zip(&r_eff)
        .map(|(&(_, _, w), &r)| (w * r).min(1.0))
        .collect();
    let ptotal: f64 = probs.iter().sum();
    let mut kept: Vec<(u32, u32, f64)> = Vec::new();
    let mut acc: Vec<f64> = Vec::new();
    // q independent draws ∝ w·R, accumulate w/(q·p) per hit.
    let mut hits: std::collections::HashMap<usize, f64> = Default::default();
    for _ in 0..q {
        let mut t = rng.next_f64() * ptotal;
        let mut idx = 0;
        for (i, &p) in probs.iter().enumerate() {
            if t < p {
                idx = i;
                break;
            }
            t -= p;
        }
        let p_i = probs[idx] / ptotal;
        *hits.entry(idx).or_insert(0.0) += edges[idx].2 / (q as f64 * p_i);
    }
    for (idx, w) in hits {
        kept.push((edges[idx].0, edges[idx].1, w));
        acc.push(w);
    }
    let sparse = Laplacian::from_edges(n, &kept, "sparsifier");
    println!(
        "sparsifier: {} edges ({:.1}% of input)",
        fmt_count(kept.len()),
        100.0 * kept.len() as f64 / edges.len() as f64
    );

    // 4. Spectral quality check: xᵀHx / xᵀLx for random mean-zero x
    //    should concentrate near 1.
    let mut worst: f64 = 1.0;
    for s in 0..20 {
        let x = pcg::random_rhs(&lap, 1000 + s);
        let lx = dot(&x, &lap.matrix.mul_vec(&x));
        let hx = dot(&x, &sparse.matrix.mul_vec(&x));
        let ratio = hx / lx;
        worst = worst.max(ratio.max(1.0 / ratio.max(1e-12)));
    }
    println!("worst quadratic-form ratio over 20 probes: {worst:.2}");
    assert!(
        worst < 1.0 + 4.0 * eps,
        "sparsifier quality {worst} out of range for eps={eps}"
    );
    println!("sparsify OK");
}
