//! End-to-end driver (DESIGN.md §End-to-end): a full Table-2/3-style
//! experiment on a real 3D Poisson workload, exercising every layer of
//! the system — graph generation, both parallel factorization engines,
//! all baseline preconditioners, level-scheduled triangular solves, and
//! the PCG solver — and printing paper-style rows. The run recorded in
//! EXPERIMENTS.md comes from this binary.
//!
//! ```bash
//! cargo run --release --example poisson_e2e [-- --n 40 --tol 1e-8]
//! ```

use parac::cli::args::Args;
use parac::coordinator::pipeline::{self, Method};
use parac::coordinator::report::{sci, secs, Table};
use parac::graph::generators::{self, Coeff};
use parac::solve::pcg::{self, PcgOptions};
use parac::util::fmt_count;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_parse("n", 40usize);
    let tol = args.get_parse("tol", 1e-8f64);
    let threads = args.get_parse("threads", 0usize);

    let lap = generators::grid3d(n, n, n, Coeff::Uniform, 42);
    println!(
        "## End-to-end: 3D Poisson {n}³  (n={}, nnz={}, tol={tol:.0e})\n",
        fmt_count(lap.n()),
        fmt_count(lap.matrix.nnz())
    );
    let b = pcg::random_rhs(&lap, 7);
    let o = PcgOptions { tol, max_iter: 5000, ..Default::default() };

    let methods: Vec<(&str, Method)> = vec![
        ("ParAC cpu/AMD", pipeline::parac_cpu_method(threads, 1)),
        ("ParAC gpusim/nnz", pipeline::parac_gpu_method(threads, 1)),
        ("ichol(0)", Method::Ichol0),
        ("ichol-t", Method::IcholT { droptol: Some(1e-3), fill_target: None }),
        ("AMG", Method::Amg),
        ("SSOR", Method::Ssor { omega: 1.5 }),
        ("Jacobi", Method::Jacobi),
    ];

    let mut table = Table::new(&[
        "method", "setup (s)", "solve (s)", "total (s)", "iters", "rel residual", "nnz(M)",
    ]);
    let mut all_ok = true;
    let mut rows = Vec::new();
    for (label, m) in &methods {
        let r = match pipeline::run_with_rhs(&lap, m, &o, &b) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error running {label}: {e}");
                std::process::exit(1);
            }
        };
        all_ok &= r.converged || *label == "Jacobi"; // Jacobi may exhaust iters
        rows.push(r.clone());
        table.row(vec![
            label.to_string(),
            secs(r.setup_secs),
            secs(r.solve_secs),
            secs(r.setup_secs + r.solve_secs),
            r.iters.to_string(),
            sci(r.rel_residual),
            fmt_count(r.nnz),
        ]);
        if let Some(st) = &r.factor_stats {
            println!("  [{label}] {}", st.summary());
        }
    }
    println!();
    print!("{}", table.render());

    // Machine-readable perf trajectory for future PRs to diff against.
    let json_path = std::path::Path::new("BENCH_pipeline.json");
    match pipeline::write_bench_json(json_path, &format!("poisson_e2e n={n}"), &rows) {
        Ok(()) => println!("\nwrote {}", json_path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", json_path.display()),
    }

    assert!(all_ok, "a preconditioned method failed to converge");
    println!("\nE2E OK");
}
