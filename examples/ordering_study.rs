//! Ordering ablation (Fig. 4 companion): how the elimination ordering
//! shapes the *parallelism* of the randomized factor — classical vs
//! actual e-tree height, triangular-solve critical path, fill, and the
//! sampling-sort quality ablation the paper mentions in §2.2.
//!
//! ```bash
//! cargo run --release --example ordering_study [-- --matrix GAP-road --scale small]
//! ```

use parac::cli::args::Args;
use parac::coordinator::report::Table;
use parac::etree;
use parac::factor::{factorize, Engine, ParacOptions};
use parac::graph::suite::{self, Scale};
use parac::ordering::Ordering;
use parac::solve::pcg;
use parac::solver::Solver;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let name = args.get("matrix", "uniform_3d_poisson");
    let scale = Scale::parse(args.get("scale", "small")).unwrap_or(Scale::Small);
    let entry = suite::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown matrix {name}");
        std::process::exit(2);
    });
    let lap = (entry.build)(scale);
    println!("## Ordering study on {} (n={})\n", entry.name, lap.n());

    // --- Part 1: parallelism metrics per ordering (Fig. 4 shape). ---
    let mut t = Table::new(&[
        "ordering", "classical e-tree", "actual e-tree", "critical path", "fill ratio",
        "parallelism (n/cp)",
    ]);
    for ord in [Ordering::Amd, Ordering::NnzSort, Ordering::Random, Ordering::Rcm] {
        let opts = ParacOptions { ordering: ord, engine: Engine::Seq, seed: 5, ..Default::default() };
        let f = factorize(&lap, &opts).unwrap();
        let permuted = lap.matrix.permute_sym(f.perm.as_ref().unwrap());
        let rep = etree::report(&permuted, &f.g);
        t.row(vec![
            ord.name().into(),
            rep.classical_height.to_string(),
            rep.actual_height.to_string(),
            rep.critical_path.to_string(),
            format!("{:.2}", rep.fill_ratio),
            format!("{:.0}", lap.n() as f64 / rep.critical_path as f64),
        ]);
    }
    print!("{}", t.render());

    // --- Part 2: the §2.2 sampling-sort quality ablation. ---
    println!("\n## Weight-sort ablation (paper §2.2: sorting improves quality)\n");
    let mut t2 = Table::new(&["sort by weight", "PCG iters", "rel residual"]);
    let b = pcg::random_rhs(&lap, 17);
    for sort in [true, false] {
        let mut solver = Solver::builder()
            .sort_by_weight(sort)
            .seed(5)
            .max_iter(2000)
            .tol(1e-8)
            .build(&lap)
            .expect("solver setup");
        let out = solver.solve(&b).expect("dimensions match");
        t2.row(vec![sort.to_string(), out.iters.to_string(), format!("{:.2e}", out.rel_residual)]);
    }
    print!("{}", t2.render());
    println!("\nordering study OK");
}
