//! Batch-solve bench: one `Solver` session, many right-hand sides —
//! measures the amortization the persistent worker pool and
//! `Solver::solve_batch` buy (RHS count × thread count × wall time,
//! the ROADMAP's "heavy traffic" economics: setup is paid once, every
//! additional RHS rides the warm factor, pool, and workspace).
//!
//! Emits `BENCH_batch_solve.json` through the hand-rolled JSON writer
//! so successive PRs can diff the trajectory mechanically; CI runs
//! this binary at `PARAC_SCALE=tiny` as a smoke step so thread-pool
//! regressions (a deadlocked dispatch, a slow wakeup path) fail
//! visibly rather than silently.

mod bench_common;

use parac::coordinator::pipeline::{self, BenchRow};
use parac::coordinator::report::Table;
use parac::graph::suite;
use parac::solve::pcg;
use parac::solver::Solver;

fn main() {
    let scale = bench_common::bench_scale();
    let max_threads = bench_common::bench_threads();
    let mut thread_counts = vec![1usize];
    if max_threads > 1 {
        thread_counts.push(max_threads);
    }
    println!("## Batch solve: RHS count × thread count  [scale {scale:?}]\n");
    let mut table = Table::new(&[
        "problem", "rhs", "threads", "setup (s)", "batch (s)", "per-rhs (ms)", "iters",
    ]);
    let mut rows: Vec<BenchRow> = Vec::new();
    for name in ["uniform_3d_poisson", "GAP-road"] {
        let e = suite::by_name(name).unwrap();
        let lap = (e.build)(scale);
        for &threads in &thread_counts {
            let mut solver = match Solver::builder().seed(1).threads(threads).build(&lap) {
                Ok(s) => s,
                Err(err) => {
                    eprintln!("error: {err}");
                    std::process::exit(1);
                }
            };
            let setup = solver.setup_secs();
            for nrhs in [1usize, 4, 16] {
                let bs: Vec<Vec<f64>> =
                    (0..nrhs).map(|i| pcg::random_rhs(&lap, 100 + i as u64)).collect();
                let refs: Vec<&[f64]> = bs.iter().map(|b| b.as_slice()).collect();
                let mut xs = vec![Vec::new(); nrhs];
                // Warm-up batch (pool creation, workspace sizing), then
                // the timed batch on warm state.
                solver.solve_batch(&refs, &mut xs).unwrap();
                let t0 = std::time::Instant::now();
                let stats = solver.solve_batch(&refs, &mut xs).unwrap();
                let wall = t0.elapsed().as_secs_f64();
                assert!(
                    stats.iter().all(|s| s.converged),
                    "{name}: batch must converge at every configuration"
                );
                let iters: usize = stats.iter().map(|s| s.iters).sum();
                table.row(vec![
                    e.name.into(),
                    nrhs.to_string(),
                    threads.to_string(),
                    format!("{setup:.3}"),
                    format!("{wall:.3}"),
                    format!("{:.2}", wall / nrhs as f64 * 1e3),
                    iters.to_string(),
                ]);
                rows.push(BenchRow {
                    name: format!("{} n={} rhs={nrhs} threads={threads}", e.name, lap.n()),
                    fields: vec![
                        ("rhs", nrhs as f64),
                        ("threads", threads as f64),
                        ("setup_secs", setup),
                        ("wall_secs", wall),
                        ("per_rhs_secs", wall / nrhs as f64),
                        ("iters", iters as f64),
                    ],
                });
            }
        }
    }
    print!("{}", table.render());
    let json_path = std::path::Path::new("BENCH_batch_solve.json");
    match pipeline::write_bench_rows_json(json_path, "batch_solve", &rows) {
        Ok(()) => println!("\nwrote {}", json_path.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", json_path.display()),
    }
    println!(
        "(one session per thread count: setup is paid once, every RHS \
         after the first rides the warm factor + pool + workspace)"
    );
}
