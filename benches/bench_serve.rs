//! Serving bench: N client threads against one shared cached factor —
//! measures the serve stack end to end (factor cache admission, wave
//! coalescing, `&self` batch solves on the shared session) under
//! open-loop load, reporting throughput and p50/p99 latency per
//! (graph × client-count) cell.
//!
//! Emits `BENCH_serve.json` through the hand-rolled JSON writer so
//! successive PRs can diff the serving trajectory mechanically; CI runs
//! this binary at `PARAC_SCALE=tiny` as a smoke step so a regression in
//! the concurrent solve path (a deadlocked gate, a workspace-pool leak,
//! a non-`Sync` session) fails visibly rather than silently.

mod bench_common;

use parac::coordinator::pipeline::{self, BenchRow};
use parac::coordinator::report::Table;
use parac::coordinator::serve_driver::{run_open_loop, LoadSpec};
use parac::graph::suite;
use parac::serve::{FactorCache, ServeOptions, SolveService};
use parac::solver::Solver;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let scale = bench_common::bench_scale();
    let threads = bench_common::bench_threads();
    let client_counts = [1usize, 8];
    println!("## Serve: open-loop clients × shared factor  [scale {scale:?}]\n");
    let mut table = Table::new(&[
        "problem", "clients", "solves", "solves/s", "p50 (ms)", "p99 (ms)", "waves", "coalesced",
        "retries",
    ]);
    let mut rows: Vec<BenchRow> = Vec::new();
    for name in ["uniform_3d_poisson", "rand_expander"] {
        let e = suite::by_name(name).unwrap();
        let lap = Arc::new((e.build)(scale));
        for &clients in &client_counts {
            // Fresh service per cell: one untimed build warms the
            // cache, then the measured window is pure serving.
            let svc = SolveService::new(
                FactorCache::new(Solver::builder().seed(1).threads(threads), 4),
                ServeOptions { max_wave: 8, max_wait: Duration::from_micros(200), ..Default::default() },
            );
            let spec = LoadSpec {
                clients,
                requests_per_client: 32,
                interval: Duration::from_micros(500),
                seed: 7,
                ..Default::default()
            };
            let rep = match run_open_loop(&svc, &lap, &spec) {
                Ok(rep) => rep,
                Err(err) => {
                    eprintln!("error: {name} clients={clients}: {err}");
                    std::process::exit(1);
                }
            };
            table.row(vec![
                e.name.into(),
                clients.to_string(),
                rep.solves.to_string(),
                format!("{:.1}", rep.throughput),
                format!("{:.3}", rep.p50_ms),
                format!("{:.3}", rep.p99_ms),
                rep.service.waves.to_string(),
                rep.service.coalesced.to_string(),
                rep.client_retries.to_string(),
            ]);
            rows.push(BenchRow {
                name: format!("{} n={} clients={clients}", e.name, lap.n()),
                fields: rep.fields(),
            });
        }
    }
    print!("{}", table.render());
    let json_path = std::path::Path::new("BENCH_serve.json");
    match pipeline::write_bench_rows_json(json_path, "serve", &rows) {
        Ok(()) => println!("\nwrote {}", json_path.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", json_path.display()),
    }
    println!(
        "(open loop: arrivals are scheduled, not throttled by completions, \
         so queueing delay lands in the latency percentiles)"
    );
}
