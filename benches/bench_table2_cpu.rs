//! Table 2 bench — CPU convergence comparison: ParAC (AMD) vs
//! fill-matched threshold ichol vs AMG (HyPre proxy), full suite.

mod bench_common;

fn main() {
    let scale = bench_common::bench_scale();
    let threads = bench_common::bench_threads();
    if let Err(e) = parac::coordinator::repro::table2(scale, threads) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
