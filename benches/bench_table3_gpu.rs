//! Table 3 bench — GPU-model comparison: ParAC (gpusim, nnz-sort,
//! level-scheduled SPSV) vs AMG (AmgX proxy) vs IC(0)+CG (cuSPARSE
//! proxy), full suite, times in ms.

mod bench_common;

fn main() {
    let scale = bench_common::bench_scale();
    let blocks = bench_common::bench_threads();
    if let Err(e) = parac::coordinator::repro::table3(scale, blocks) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
