//! Hash-ablation bench (§5.3.4 / §7.1): random-permutation vs identity
//! hash codes in the gpusim fill workspace — the paper's "the default
//! permutation may cause slow down; a random permutation works great".

mod bench_common;

fn main() {
    let scale = bench_common::bench_scale();
    let blocks = bench_common::bench_threads();
    if let Err(e) = parac::coordinator::repro::hash_ablation(scale, blocks) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
