//! Dynamic-graph update streams: per-path update latency against a
//! from-scratch rebuild baseline, plus how each scenario's rounds
//! classified (weight-only / cone-localized / rebuild).
//!
//! Emits `BENCH_dynamic.json` through the hand-rolled JSON writer so
//! successive PRs can diff the dynamic trajectory mechanically; CI runs
//! this binary at `PARAC_SCALE=tiny` as a smoke step so a broken
//! classification path, a mis-spliced cone factor (every round asserts
//! convergence), or a broken JSON emit fails visibly.

mod bench_common;

use parac::coordinator::pipeline::{self, BenchRow};
use parac::coordinator::report::Table;
use parac::dynamic::scenario::{self, ScenarioOptions};
use parac::dynamic::DynamicOptions;
use parac::graph::suite::{self, Scale};
use parac::solver::Solver;
use std::path::Path;

fn main() {
    let scale = bench_common::bench_scale();
    let threads = bench_common::bench_threads();
    let rounds = match scale {
        Scale::Tiny => 4,
        _ => 8,
    };
    println!("## Dynamic: delta-classified update streams  [scale {scale:?}]\n");
    let sopts = ScenarioOptions {
        rounds,
        seed: 0xD11A,
        measure_full_rebuild: true,
        dynamic: DynamicOptions::default(),
    };
    let mut table = Table::new(&[
        "problem",
        "scenario",
        "weight-only",
        "localized",
        "rebuild",
        "wo (ms)",
        "loc (ms)",
        "rb (ms)",
        "full rb (ms)",
        "iters",
    ]);
    let mut rows: Vec<BenchRow> = Vec::new();
    let ms = |s: f64| {
        if s > 0.0 {
            format!("{:.3}", s * 1e3)
        } else {
            "-".into()
        }
    };
    // One grid, one road-like, and the high-diameter adversary — the
    // three shapes with the most different cone geometry.
    for name in ["uniform_3d_poisson", "GAP-road", "clique_ladder"] {
        let e = match suite::by_name(name) {
            Some(e) => e,
            None => {
                eprintln!("error: unknown suite entry {name}");
                std::process::exit(1);
            }
        };
        let lap = (e.build)(scale);
        let builder = Solver::builder().seed(7).threads(threads).tol(1e-7).max_iter(2000);
        for sc in scenario::SCENARIOS {
            let rep = match scenario::run(sc, &lap, builder.clone(), &sopts) {
                Ok(rep) => rep,
                Err(err) => {
                    eprintln!("error: {name}/{sc}: {err}");
                    std::process::exit(1);
                }
            };
            // Every round must have converged — a mis-spliced cone
            // factor shows up here, not as a silently slow stream.
            assert!(rep.all_converged, "{name}/{sc}: a round failed to converge");
            table.row(vec![
                e.name.into(),
                rep.name.into(),
                rep.counts.weight_only.to_string(),
                rep.counts.localized.to_string(),
                rep.counts.rebuild.to_string(),
                ms(rep.weight_only_secs),
                ms(rep.localized_secs),
                ms(rep.rebuild_secs),
                ms(rep.full_rebuild_secs),
                format!("{:.1}", rep.mean_iters),
            ]);
            rows.push(BenchRow {
                name: format!("{} {} n={}", e.name, rep.name, lap.n()),
                fields: rep.fields(),
            });
        }
    }
    print!("{}", table.render());
    if let Err(e) = pipeline::write_bench_rows_json(Path::new("BENCH_dynamic.json"), "dynamic", &rows)
    {
        eprintln!("error writing BENCH_dynamic.json: {e}");
        std::process::exit(1);
    }
    println!("\nwrote BENCH_dynamic.json");
}
