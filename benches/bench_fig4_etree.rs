//! Figure 4 bench — classical vs actual e-tree heights, triangular
//! solve critical path, gpusim factor time, and fill ratio per
//! ordering, full suite.

mod bench_common;

fn main() {
    let scale = bench_common::bench_scale();
    let blocks = bench_common::bench_threads();
    if let Err(e) = parac::coordinator::repro::fig4(scale, blocks) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
