//! L1 kernel micro-bench: native rust clique sampling vs the
//! AOT-compiled Pallas kernel executed through PJRT, across bucket
//! widths — quantifies the offload break-even the coordinator's
//! batching policy is built around. Skips the PJRT half gracefully if
//! `make artifacts` hasn't run.

mod bench_common;

use parac::coordinator::report::Table;
use parac::rng::Rng;
use parac::runtime::sampler::{native_reference, HloSampler, SampleTask, BATCH, BUCKET_WIDTHS};
use parac::runtime::Artifacts;

fn make_tasks(k: usize, count: usize, seed: u64) -> Vec<SampleTask> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            let m = 2 + rng.below(k - 1);
            let mut nbrs: Vec<(u32, f64)> =
                (0..m).map(|j| (j as u32 * 3 + 1, rng.range_f64(0.1, 10.0))).collect();
            parac::factor::sample::sort_by_weight(&mut nbrs);
            SampleTask { pivot: i as u32, nbrs }
        })
        .collect()
}

fn main() {
    let seed = 42;
    let reps = 5;
    let mut table = Table::new(&[
        "bucket K", "tasks", "native (µs)", "pjrt (µs)", "pjrt/native", "edges",
    ]);
    let mut arts = Artifacts::open_default().ok();
    for &k in &BUCKET_WIDTHS {
        let tasks = make_tasks(k, BATCH * 4, seed);
        // Native path.
        let (edges_native, t_native) = bench_common::median_time(reps, || {
            tasks.iter().map(|t| native_reference(seed, t).len()).sum::<usize>()
        });
        // PJRT path.
        let (pjrt_us, edges_pjrt) = match arts.as_mut() {
            Some(a) => {
                let mut sampler = HloSampler::new(a, seed);
                match bench_common::median_time(reps, || sampler.run_bucket(k, &tasks)) {
                    (Ok(edges), t) => (format!("{:.0}", t * 1e6), edges.len()),
                    (Err(e), _) => (format!("err: {e}"), 0),
                }
            }
            None => ("n/a (no artifacts)".to_string(), 0),
        };
        let ratio = if edges_pjrt > 0 {
            let pj: f64 = pjrt_us.parse().unwrap_or(f64::NAN);
            format!("{:.1}x", pj / (t_native * 1e6))
        } else {
            "-".into()
        };
        table.row(vec![
            k.to_string(),
            tasks.len().to_string(),
            format!("{:.0}", t_native * 1e6),
            pjrt_us,
            ratio,
            format!("{edges_native}/{edges_pjrt}"),
        ]);
    }
    println!("## L1 sampling kernel: native vs PJRT-offloaded (batch={BATCH})\n");
    print!("{}", table.render());
    println!("\n(native is the engines' default; the PJRT path demonstrates the L1 kernel on the factor path and its launch-overhead break-even)");
}
