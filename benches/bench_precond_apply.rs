//! Preconditioner-apply bench: the packed sweep executor (one pool
//! dispatch per triangular sweep over a contiguous level-major factor)
//! vs the PR3 per-level executor (one dispatch per wide level, factor
//! in elimination order, gathered through `order[]` indirection) —
//! graph × threads × executor wall time, the paper's §6.2 SPSV solve
//! stage.
//!
//! Emits `BENCH_precond_apply.json` through the hand-rolled JSON
//! writer so successive PRs can diff the trajectory mechanically; CI
//! smoke-runs this binary at `PARAC_SCALE=tiny`, which also guards the
//! bit-identity of the two executors (asserted below) and the packed
//! executor's O(1)-dispatch invariant.
//!
//! The packed executor is additionally timed on its **f32 storage
//! plane** (`PackedSweeps<f32>`): same schedules, half the packed value
//! bytes — the exact-halving is asserted, and both the per-apply times
//! and the bytes-moved columns land in the table and the JSON so the
//! bandwidth story is diffable per precision.

mod bench_common;

use parac::coordinator::pipeline::{self, BenchRow};
use parac::coordinator::report::Table;
use parac::factor::{factorize, Engine, LdlFactor, ParacOptions};
use parac::graph::suite;
use parac::solve::packed::PackedSweeps;
use parac::solve::pcg;
use parac::solve::trisolve::LevelSchedule;

/// The PR3 apply, verbatim: scatter into permuted space, per-level
/// forward sweep, `D⁻¹` pass, per-level backward sweep, gather out —
/// every wide level its own pool dispatch.
fn pr3_apply(
    f: &LdlFactor,
    sched: &LevelSchedule,
    r: &[f64],
    z: &mut [f64],
    scratch: &mut [f64],
    threads: usize,
) {
    let y: &mut [f64] = match &f.perm {
        Some(p) => {
            for (i, &ri) in r.iter().enumerate() {
                scratch[p[i] as usize] = ri;
            }
            &mut scratch[..]
        }
        None => {
            z.copy_from_slice(r);
            &mut *z
        }
    };
    sched.forward(y, threads);
    for (yk, &d) in y.iter_mut().zip(&f.diag) {
        *yk = if d > 0.0 { *yk / d } else { 0.0 };
    }
    sched.backward(&f.g, y, threads);
    if let Some(p) = &f.perm {
        for (i, zi) in z.iter_mut().enumerate() {
            *zi = scratch[p[i] as usize];
        }
    }
}

fn main() {
    let scale = bench_common::bench_scale();
    let max_threads = bench_common::bench_threads();
    let mut thread_counts = vec![1usize];
    if max_threads > 1 {
        thread_counts.push(max_threads);
    }
    let reps = 7;
    println!("## Preconditioner apply: packed (1 dispatch/sweep) vs PR3 (1 dispatch/level)  [scale {scale:?}]\n");
    let mut table = Table::new(&[
        "problem", "threads", "critical path", "pr3 (ms)", "packed (ms)", "packed f32 (ms)",
        "speedup", "dispatches/apply", "val KB f64", "val KB f32",
    ]);
    let mut rows: Vec<BenchRow> = Vec::new();
    for name in ["uniform_3d_poisson", "GAP-road"] {
        let e = suite::by_name(name).unwrap();
        let lap = (e.build)(scale);
        let opts = ParacOptions { engine: Engine::Cpu { threads: 0 }, seed: 1, ..Default::default() };
        let f = match factorize(&lap, &opts) {
            Ok(f) => f,
            Err(err) => {
                eprintln!("error: {err}");
                std::process::exit(1);
            }
        };
        let b = pcg::random_rhs(&lap, 3);
        // The analysis phase is thread-independent — one level schedule
        // and one packed copy serve every thread count below (only the
        // apply takes a `threads` argument).
        let sched = LevelSchedule::analyze(&f);
        let packed = PackedSweeps::<f64>::analyze(&f);
        let packed32 = PackedSweeps::<f32>::analyze(&f);
        // The f32 plane's claim is exactly-half the packed value
        // traffic — same entry counts, 4 bytes instead of 8.
        assert_eq!(
            packed32.value_bytes() * 2,
            packed.value_bytes(),
            "{name}: f32 plane must store exactly half the value bytes"
        );
        let n = lap.n();
        let mut z_pr3 = vec![0.0; n];
        let mut z_packed = vec![0.0; n];
        let mut z_packed32 = vec![0.0; n];
        let mut scratch = vec![0.0; n];
        let (mut y_fwd, mut y_bwd) = (vec![0.0; n], vec![0.0; n]);
        for &threads in &thread_counts {
            // Warm both paths (pool creation), then pin bit-identity —
            // a silent numeric divergence between the executors must
            // fail the CI smoke run, not just a property test.
            pr3_apply(&f, &sched, &b, &mut z_pr3, &mut scratch, threads);
            packed.apply_into(&b, &mut z_packed, threads, &mut y_fwd, &mut y_bwd);
            assert_eq!(z_pr3, z_packed, "{name}: executors must be bit-identical");

            let (_, t_pr3) = bench_common::median_time(reps, || {
                pr3_apply(&f, &sched, &b, &mut z_pr3, &mut scratch, threads)
            });
            let c0 = packed.counters();
            let (_, t_packed) = bench_common::median_time(reps, || {
                packed.apply_into(&b, &mut z_packed, threads, &mut y_fwd, &mut y_bwd)
            });
            let dispatches = packed.counters().since(c0).dispatches as f64 / reps as f64;
            let (_, t_packed32) = bench_common::median_time(reps, || {
                packed32.apply_into(&b, &mut z_packed32, threads, &mut y_fwd, &mut y_bwd)
            });
            let cp = packed.critical_path;
            table.row(vec![
                e.name.into(),
                threads.to_string(),
                cp.to_string(),
                format!("{:.3}", t_pr3 * 1e3),
                format!("{:.3}", t_packed * 1e3),
                format!("{:.3}", t_packed32 * 1e3),
                format!("{:.2}x", t_pr3 / t_packed.max(1e-12)),
                format!("{dispatches:.0}"),
                format!("{:.1}", packed.value_bytes() as f64 / 1e3),
                format!("{:.1}", packed32.value_bytes() as f64 / 1e3),
            ]);
            rows.push(BenchRow {
                name: format!("{} n={} threads={threads}", e.name, n),
                fields: vec![
                    ("threads", threads as f64),
                    ("critical_path", cp as f64),
                    ("pr3_secs", t_pr3),
                    ("packed_secs", t_packed),
                    ("packed_f32_secs", t_packed32),
                    ("speedup", t_pr3 / t_packed.max(1e-12)),
                    ("dispatches_per_apply", dispatches),
                    ("val_bytes_f64", packed.value_bytes() as f64),
                    ("val_bytes_f32", packed32.value_bytes() as f64),
                ],
            });
        }
    }
    print!("{}", table.render());
    let json_path = std::path::Path::new("BENCH_precond_apply.json");
    match pipeline::write_bench_rows_json(json_path, "precond_apply", &rows) {
        Ok(()) => println!("\nwrote {}", json_path.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", json_path.display()),
    }
    println!(
        "(packed: one pool dispatch per sweep, contiguous level-major factor; \
         pr3: one dispatch per wide level, elimination-order factor — on a \
         1-core testbed the dispatch-count column carries the architectural \
         signal; see EXPERIMENTS.md)"
    );
}
