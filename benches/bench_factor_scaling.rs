//! Figure 3 bench — CPU factor-time scaling across threads × orderings
//! over the full matrix suite — plus the symbolic/numeric split: how
//! much of a build is one-time analysis (ordering, e-tree, packed
//! layout, workspace sizing) vs the per-reweighting numeric sweep, and
//! the resulting rebuild-vs-refactorize speedup of
//! `SymbolicFactor::refactorize_into` on a frozen pattern.
//!
//! Emits `BENCH_factor_scaling.json` through the hand-rolled JSON
//! writer so successive PRs can diff the trajectory mechanically; CI
//! smoke-runs this binary at `PARAC_SCALE=tiny` and uploads the
//! artifact.
//!
//! NOTE (testbed): this environment exposes **one** CPU core, so
//! wall-clock speedup across threads is structurally flat; the
//! dependency-level parallelism that drives the paper's Fig. 3 speedups
//! is quantified by the fig4 bench's critical-path column (n /
//! critical-path = available parallelism). See EXPERIMENTS.md. The
//! rebuild/refactorize ratio below is thread-independent: it compares
//! two runs at the *same* thread count.

mod bench_common;

use parac::coordinator::pipeline::{self, BenchRow};
use parac::coordinator::report::Table;
use parac::factor::{Engine, ParacOptions, SymbolicFactor};
use parac::graph::suite::SUITE;
use parac::graph::Laplacian;

fn main() {
    let scale = bench_common::bench_scale();
    let threads = bench_common::bench_threads();
    if let Err(e) = parac::coordinator::repro::fig3(scale, threads) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }

    // ---- Symbolic/numeric split + numeric-only refactorization. ----
    println!(
        "\n## Symbolic/numeric split: full rebuild vs numeric-only \
         refactorize  [scale {scale:?}, {threads} threads]\n"
    );
    let mut table = Table::new(&[
        "problem",
        "n",
        "nnz(L)",
        "analyze(ms)",
        "numeric(ms)",
        "rebuild(ms)",
        "refactor(ms)",
        "speedup",
    ]);
    let mut rows: Vec<BenchRow> = Vec::new();
    for e in SUITE {
        let lap = (e.build)(scale);
        // Same pattern, perturbed weights — the refactorize workload.
        let reweighted: Vec<(u32, u32, f64)> = lap
            .edges()
            .into_iter()
            .enumerate()
            .map(|(i, (a, b, w))| (a, b, w * (1.0 + (i % 5) as f64 * 0.25)))
            .collect();
        let lap2 = Laplacian::from_edges(lap.n(), &reweighted, e.name);
        let opts =
            ParacOptions { engine: Engine::Cpu { threads }, seed: 1, ..Default::default() };

        let ((mut sym, mut f), rebuild_secs) = bench_common::median_time(3, || {
            let mut sym = SymbolicFactor::analyze(&lap, &opts).expect("analyze");
            let f = sym.factorize(&lap).expect("factorize");
            (sym, f)
        });
        let analyze_secs = f.stats.symbolic_secs;
        let numeric_secs = f.stats.numeric_secs;
        let nnz = f.nnz();

        let (_, refactor_secs) = bench_common::median_time(3, || {
            sym.refactorize_into(&lap2, &mut f).expect("refactorize")
        });
        assert!(f.stats.symbolic_reused, "refactorize must skip the symbolic phase");
        let speedup = rebuild_secs / refactor_secs.max(1e-12);

        table.row(vec![
            e.name.into(),
            lap.n().to_string(),
            nnz.to_string(),
            format!("{:.3}", analyze_secs * 1e3),
            format!("{:.3}", numeric_secs * 1e3),
            format!("{:.3}", rebuild_secs * 1e3),
            format!("{:.3}", refactor_secs * 1e3),
            format!("{speedup:.2}x"),
        ]);
        rows.push(BenchRow {
            name: format!("{} n={} threads={threads}", e.name, lap.n()),
            fields: vec![
                ("n", lap.n() as f64),
                ("factor_nnz", nnz as f64),
                ("threads", threads as f64),
                ("analyze_secs", analyze_secs),
                ("numeric_secs", numeric_secs),
                ("rebuild_secs", rebuild_secs),
                ("refactorize_secs", refactor_secs),
                ("speedup", speedup),
            ],
        });
    }
    print!("{}", table.render());
    let json_path = std::path::Path::new("BENCH_factor_scaling.json");
    match pipeline::write_bench_rows_json(json_path, "factor_scaling", &rows) {
        Ok(()) => println!("\nwrote {}", json_path.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", json_path.display()),
    }
    println!(
        "(analyze = ordering + e-tree + packed layout + workspace sizing, paid \
         once per pattern; numeric = the randomized elimination sweep, paid per \
         reweighting; refactorize reruns only the numeric phase on the frozen \
         pattern)"
    );
}
