//! Figure 3 bench — CPU factor-time scaling across threads × orderings
//! over the full matrix suite.
//!
//! NOTE (testbed): this environment exposes **one** CPU core, so
//! wall-clock speedup is structurally flat; the dependency-level
//! parallelism that drives the paper's Fig. 3 speedups is quantified by
//! the fig4 bench's critical-path column (n / critical-path = available
//! parallelism). See EXPERIMENTS.md.

mod bench_common;

fn main() {
    let scale = bench_common::bench_scale();
    let threads = bench_common::bench_threads();
    if let Err(e) = parac::coordinator::repro::fig3(scale, threads) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
