//! Triangular-solve bench — sequential vs level-scheduled solves per
//! ordering (the paper §6.2: GPU solve performance is governed by the
//! DAG critical path, which is why AMD loses on GPU).

mod bench_common;

use parac::coordinator::report::Table;
use parac::factor::{factorize, Engine, ParacOptions};
use parac::graph::suite;
use parac::ordering::Ordering;
use parac::precond::{LdlPrecond, Preconditioner};
use parac::solve::pcg;

fn main() {
    let scale = bench_common::bench_scale();
    let threads = bench_common::bench_threads();
    let reps = 5;
    println!("## Triangular solve: sequential vs level-scheduled  [scale {scale:?}]\n");
    let mut table = Table::new(&[
        "problem", "ordering", "critical path", "levels avg width", "seq (ms)", "level (ms)",
    ]);
    for name in ["uniform_3d_poisson", "GAP-road", "com-LiveJournal"] {
        let e = suite::by_name(name).unwrap();
        let lap = (e.build)(scale);
        let b = pcg::random_rhs(&lap, 3);
        for ord in [Ordering::Amd, Ordering::NnzSort, Ordering::Random] {
            let opts = ParacOptions {
                ordering: ord,
                engine: Engine::Cpu { threads: 0 },
                seed: 1,
                ..Default::default()
            };
            let f = factorize(&lap, &opts).unwrap();
            let (levels, cp) = parac::etree::trisolve_levels(&f.g);
            let avg_width = lap.n() as f64 / cp as f64;
            let seq = LdlPrecond::new(f.clone());
            let lvl = LdlPrecond::with_level_schedule(f, threads);
            // Time the allocation-free hot-loop path PCG actually runs.
            let mut z = vec![0.0; lap.n()];
            let (_, t_seq) = bench_common::median_time(reps, || seq.apply_into(&b, &mut z));
            let (_, t_lvl) = bench_common::median_time(reps, || lvl.apply_into(&b, &mut z));
            let _ = levels;
            table.row(vec![
                e.name.into(),
                ord.name().into(),
                cp.to_string(),
                format!("{avg_width:.0}"),
                format!("{:.2}", t_seq * 1e3),
                format!("{:.2}", t_lvl * 1e3),
            ]);
        }
    }
    print!("{}", table.render());
    println!("\n(the `level` column runs the packed sweep executor: one persistent-pool dispatch per sweep over a contiguous level-major factor — `benches/bench_precond_apply.rs` compares it against the per-level-dispatch executor directly; on a 1-core testbed the `critical path` / `avg width` columns carry the architectural signal — see EXPERIMENTS.md)");
}
