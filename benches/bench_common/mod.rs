//! Shared bench-harness helpers (no criterion offline — each bench is a
//! `harness = false` binary printing paper-style tables).

// Each bench binary compiles its own copy of this module and uses a
// subset of the helpers.
#![allow(dead_code)]

use parac::graph::suite::Scale;

/// Scale selected by `PARAC_SCALE` (tiny|small|medium), default small.
pub fn bench_scale() -> Scale {
    std::env::var("PARAC_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Small)
}

/// Threads/blocks from `PARAC_BENCH_THREADS`, default 4 (the engines
/// are measured oversubscribed on this 1-core testbed; see
/// EXPERIMENTS.md).
pub fn bench_threads() -> usize {
    std::env::var("PARAC_BENCH_THREADS").ok().and_then(|s| s.parse().ok()).unwrap_or(4)
}

/// Median-of-`reps` timing helper.
pub fn median_time<T>(reps: usize, mut f: impl FnMut() -> T) -> (T, f64) {
    let mut times = Vec::with_capacity(reps);
    let mut out = None;
    for _ in 0..reps {
        let t = std::time::Instant::now();
        out = Some(f());
        times.push(t.elapsed().as_secs_f64());
    }
    (out.unwrap(), parac::util::median(&times))
}
