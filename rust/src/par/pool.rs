//! The persistent worker pool.
//!
//! One fixed set of OS threads is created once and then fed *jobs*: a
//! job is a `Fn(part, parts)` closure that every participant runs with
//! its own part index, splitting the work by index ranges. Dispatch is
//! the CPU analogue of the paper's persistent GPU kernel (§5.1): the
//! workers never exit, they spin briefly on an epoch counter and park
//! on a condvar when idle, and publishing a job is a pointer write + an
//! epoch bump + a wakeup — **no heap allocation on the steady-state
//! dispatch path** (the futex-based `std` mutex/condvar do not allocate
//! after construction, and the job closure is borrowed from the
//! dispatcher's stack, never boxed).
//!
//! The dispatching thread participates as part `0`, so a pool of size
//! `N` spawns `N − 1` threads and `threads == 1` degenerates to a plain
//! inline call with zero synchronization.

use std::cell::Cell;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Spins on the epoch counter before a worker parks on the condvar
/// (persistent-kernel-style polling keeps per-level dispatch latency in
/// the nanosecond range while levels are streaming in back-to-back).
const IDLE_SPINS: u32 = 4096;

/// Spins the dispatcher waits for job completion before parking.
const DONE_SPINS: u32 = 65_536;

thread_local! {
    /// Set inside pool workers so nested dispatch degrades to an inline
    /// call instead of deadlocking on the pool's own capacity.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A type-erased borrowed job: `call(data, part, parts)` invokes the
/// dispatcher's closure. Valid only while the dispatcher is blocked in
/// [`WorkerPool::run`], which is exactly when workers read it.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize, usize),
    parts: usize,
}

impl Job {
    const IDLE: Job = Job { data: std::ptr::null(), call: noop_call, parts: 0 };
}

/// `Job::IDLE` placeholder target; never invoked.
unsafe fn noop_call(_: *const (), _: usize, _: usize) {}

/// Monomorphized trampoline from the erased pointer back to `F`.
///
/// # Safety
/// `data` must point to a live `F` shared with `&F` semantics.
unsafe fn call_shim<F: Fn(usize, usize) + Sync>(data: *const (), part: usize, parts: usize) {
    (*(data as *const F))(part, parts)
}

/// State shared between the dispatcher and the workers.
struct Inner {
    /// The current job; written by the dispatcher only while every
    /// worker is idle (`remaining == 0` and the dispatch lock held).
    job: std::cell::UnsafeCell<Job>,
    /// Bumped (under `sleep`) each time a new job is published.
    epoch: AtomicUsize,
    /// Paired with `cv` for idle workers.
    sleep: Mutex<()>,
    cv: Condvar,
    /// Workers that have not yet finished the current epoch.
    remaining: AtomicUsize,
    /// Paired with `done_cv` for the waiting dispatcher.
    done: Mutex<()>,
    done_cv: Condvar,
    /// A job closure panicked on a worker.
    panicked: AtomicBool,
    /// Pool is being dropped.
    shutdown: AtomicBool,
}

// SAFETY: `job` is only mutated by the dispatcher between epochs (all
// workers idle, dispatch lock held) and only read by workers during an
// epoch; the epoch bump under `sleep` publishes the write. The raw
// pointers inside `Job` are only dereferenced while the dispatcher —
// which owns the pointee — is blocked in `run`, so moving/sharing
// `Inner` across threads is sound.
unsafe impl Send for Inner {}
unsafe impl Sync for Inner {}

/// A persistent fork-join worker pool (see the module docs).
pub struct WorkerPool {
    inner: Arc<Inner>,
    /// Serializes concurrent dispatchers (the pool is one shared
    /// resource; jobs from different sessions queue up FIFO-ish).
    dispatch: Mutex<()>,
    /// Jobs actually published to the workers (inline degradations —
    /// `parts == 1` and nested calls — are not counted). Diagnostic
    /// counter behind the O(1)-dispatch claim of the packed sweep
    /// executor; see [`WorkerPool::dispatch_count`].
    dispatches: AtomicU64,
    /// Total participants including the dispatching caller.
    size: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Create a pool with `threads` total participants (the calling
    /// thread counts as one, so this spawns `threads − 1` workers;
    /// `threads` is clamped to at least 1).
    pub fn new(threads: usize) -> WorkerPool {
        let size = threads.max(1);
        let inner = Arc::new(Inner {
            job: std::cell::UnsafeCell::new(Job::IDLE),
            epoch: AtomicUsize::new(0),
            sleep: Mutex::new(()),
            cv: Condvar::new(),
            remaining: AtomicUsize::new(0),
            done: Mutex::new(()),
            done_cv: Condvar::new(),
            panicked: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        });
        let handles = (1..size)
            .map(|part| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("parac-pool-{part}"))
                    .spawn(move || worker_loop(&inner, part))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { inner, dispatch: Mutex::new(()), dispatches: AtomicU64::new(0), size, handles }
    }

    /// Total participants (spawned workers + the dispatching caller).
    pub fn size(&self) -> usize {
        self.size
    }

    /// How many jobs have been published to the workers over the
    /// pool's lifetime (inline degradations are free and not counted).
    /// This is the observable behind the packed sweep executor's
    /// O(1)-dispatches-per-sweep claim: snapshot before/after a solve
    /// and diff. Monotone, relaxed — a diagnostic, not a fence.
    pub fn dispatch_count(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Run `f(part, parts)` for every `part in 0..parts`, split across
    /// the pool, and block until all parts finished. `parts` is clamped
    /// to the pool size; the caller executes part 0. Panics from `f`
    /// are re-raised here after every part has stopped.
    ///
    /// `f` must not dispatch onto the pool itself — nested calls
    /// degrade to an inline `f(0, 1)`.
    pub fn run<F: Fn(usize, usize) + Sync>(&self, parts: usize, f: F) {
        let parts = parts.clamp(1, self.size);
        if parts == 1 || IN_POOL_WORKER.with(|w| w.get()) {
            f(0, 1);
            return;
        }
        let _d = lock(&self.dispatch);
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        let inner = &*self.inner;
        // SAFETY: every worker is idle between epochs (remaining == 0
        // observed by the previous run's completion wait) and the
        // dispatch lock excludes other writers.
        unsafe {
            *inner.job.get() = Job { data: &f as *const F as *const (), call: call_shim::<F>, parts };
        }
        // Every spawned worker acknowledges every epoch, including the
        // ones with `part >= parts` that skip the call: the barrier is
        // what makes it safe to overwrite the job slot on the next
        // dispatch (a participants-only ack would let a slow idle
        // worker tear-read the next job). Cost: one wakeup + one
        // decrement per idle worker per dispatch.
        inner.remaining.store(self.size - 1, Ordering::Release);
        {
            let _g = lock(&inner.sleep);
            inner.epoch.fetch_add(1, Ordering::Release);
        }
        inner.cv.notify_all();

        // The caller is part 0. A panic here must still wait for the
        // workers — they borrow `f` from this stack frame. The flag is
        // set for the duration of the shard so a nested dispatch from
        // part 0 degrades inline like it does on the spawned workers
        // (re-locking the non-reentrant dispatch mutex would deadlock);
        // it cannot already be set here, or the entry check above would
        // have taken the inline path.
        IN_POOL_WORKER.with(|w| w.set(true));
        let caller = std::panic::catch_unwind(AssertUnwindSafe(|| {
            // Fault site `worker-panic` (chaos testing): an injected
            // panic in part 0 rides the pool's real panic machinery —
            // wait for the workers, clear the flag, re-raise — exactly
            // like a genuine job panic. One relaxed atomic load when no
            // fault plan is installed.
            if crate::faults::should_fire(crate::faults::Site::WorkerPanic) {
                panic!("injected worker-pool job panic");
            }
            f(0, parts)
        }));
        IN_POOL_WORKER.with(|w| w.set(false));

        let mut spins = 0u32;
        while inner.remaining.load(Ordering::Acquire) != 0 {
            spins += 1;
            if spins < DONE_SPINS {
                std::hint::spin_loop();
            } else {
                let mut g = lock(&inner.done);
                while inner.remaining.load(Ordering::Acquire) != 0 {
                    g = wait(&inner.done_cv, g);
                }
                break;
            }
        }

        // Clear the workers' panic flag before re-raising the caller's
        // own panic: a caught dispatch failure must not poison the next
        // (healthy) job.
        let worker_panicked = inner.panicked.swap(false, Ordering::AcqRel);
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("a worker-pool job panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        {
            let _g = lock(&self.inner.sleep);
        }
        self.inner.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Lock a mutex, ignoring poisoning (pool state is all atomics; the
/// guards protect nothing but the condvar protocol).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Condvar wait, ignoring poisoning (see [`lock`]).
fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|p| p.into_inner())
}

/// Block until the epoch moves past `seen` (or shutdown): bounded spin,
/// then park on the condvar. Returns the epoch observed.
fn wait_for_work(inner: &Inner, seen: usize) -> usize {
    let mut spins = 0u32;
    loop {
        let e = inner.epoch.load(Ordering::Acquire);
        if e != seen || inner.shutdown.load(Ordering::Acquire) {
            return e;
        }
        spins += 1;
        if spins < IDLE_SPINS {
            std::hint::spin_loop();
        } else {
            let mut g = lock(&inner.sleep);
            loop {
                let e = inner.epoch.load(Ordering::Acquire);
                if e != seen || inner.shutdown.load(Ordering::Acquire) {
                    return e;
                }
                g = wait(&inner.cv, g);
            }
        }
    }
}

/// The persistent worker body: wait for an epoch, run this worker's
/// part, acknowledge, repeat until shutdown.
fn worker_loop(inner: &Inner, part: usize) {
    IN_POOL_WORKER.with(|w| w.set(true));
    let mut seen = 0usize;
    loop {
        let e = wait_for_work(inner, seen);
        if inner.shutdown.load(Ordering::Acquire) {
            return;
        }
        seen = e;
        // SAFETY: published by the epoch bump; the dispatcher keeps the
        // closure alive until `remaining` drops to zero.
        let job = unsafe { *inner.job.get() };
        if part < job.parts {
            let ok = std::panic::catch_unwind(AssertUnwindSafe(|| unsafe {
                (job.call)(job.data, part, job.parts)
            }))
            .is_ok();
            if !ok {
                inner.panicked.store(true, Ordering::Release);
            }
        }
        if inner.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = lock(&inner.done);
            inner.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn all_parts_run_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        for _ in 0..100 {
            pool.run(4, |part, parts| {
                assert_eq!(parts, 4);
                hits[part].fetch_add(1, Ordering::Relaxed);
            });
        }
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 100);
        }
    }

    #[test]
    fn dispatch_count_tracks_published_jobs_only() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.dispatch_count(), 0);
        pool.run(2, |_, _| {});
        pool.run(2, |_, _| {});
        assert_eq!(pool.dispatch_count(), 2, "real dispatches are counted");
        // Inline degradations are free and uncounted: single-part...
        pool.run(1, |_, _| {});
        // ...and nested calls from inside a job.
        pool.run(2, |_, _| {
            pool.run(2, |_, _| {});
        });
        assert_eq!(pool.dispatch_count(), 3, "inline/nested calls must not count");
    }

    #[test]
    fn parts_clamped_to_pool_size() {
        let pool = WorkerPool::new(2);
        let seen = AtomicU64::new(0);
        pool.run(64, |part, parts| {
            assert!(parts <= 2);
            assert!(part < parts);
            seen.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(seen.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn single_part_runs_inline() {
        let pool = WorkerPool::new(1);
        let hits = AtomicU64::new(0);
        pool.run(8, |part, parts| {
            assert_eq!((part, parts), (0, 1));
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chunked_sum_matches_sequential() {
        let pool = WorkerPool::new(3);
        let xs: Vec<u64> = (0..10_000).collect();
        let partial: Vec<AtomicU64> = (0..3).map(|_| AtomicU64::new(0)).collect();
        pool.run(3, |part, parts| {
            let (lo, hi) = super::super::chunk_range(xs.len(), part, parts);
            let s: u64 = xs[lo..hi].iter().sum();
            partial[part].store(s, Ordering::Relaxed);
        });
        let total: u64 = partial.iter().map(|a| a.load(Ordering::Relaxed)).sum();
        assert_eq!(total, xs.iter().sum::<u64>());
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, |part, _| {
                if part == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must surface to the dispatcher");
        // The pool must still dispatch after a failed job.
        let ok = AtomicU64::new(0);
        pool.run(2, |_, _| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn nested_dispatch_degrades_inline() {
        // From spawned workers AND from the dispatching caller (part
        // 0), a nested `run` must degrade to an inline call instead of
        // re-locking the non-reentrant dispatch mutex.
        let pool = WorkerPool::new(2);
        let hits = AtomicU64::new(0);
        pool.run(2, |_, _| {
            pool.run(2, |part, parts| {
                assert_eq!((part, parts), (0, 1));
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn caller_panic_does_not_poison_next_dispatch() {
        // Every part panics (caller included). The caller's panic is
        // re-raised, but the workers' panic flag must be cleared so the
        // next healthy job doesn't report a stale failure.
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, |part, _| panic!("part {part} fails"));
        }));
        assert!(r.is_err());
        let ok = AtomicU64::new(0);
        pool.run(2, |_, _| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn sequential_reuse_many_epochs() {
        // Hammer the epoch protocol: results must be deterministic.
        let pool = WorkerPool::new(4);
        let mut data = vec![0u64; 1 << 12];
        for round in 0..200u64 {
            let ptr = crate::par::SendPtr::new(data.as_mut_ptr());
            let n = data.len();
            pool.run(4, |part, parts| {
                let (lo, hi) = super::super::chunk_range(n, part, parts);
                for i in lo..hi {
                    // SAFETY: [lo, hi) ranges are disjoint across parts.
                    unsafe { ptr.write(i, ptr.read(i) + round) };
                }
            });
        }
        let want: u64 = (0..200).sum();
        assert!(data.iter().all(|&v| v == want));
    }
}
