//! A lightweight in-job barrier for pool workers.
//!
//! The packed sweep executor ([`crate::solve::packed`]) runs a whole
//! level-scheduled triangular sweep as **one** pool dispatch: the
//! participants stay resident for every level and synchronize at level
//! boundaries with a [`SweepBarrier`] instead of returning to the
//! dispatcher — the CPU analogue of the paper's persistent GPU kernel
//! (§5.1), where thread blocks grid-sync between dependency levels
//! rather than paying a kernel launch per level.
//!
//! The barrier is the classic sense-reversing centralized design on two
//! atomics: arrivals count up on `arrived`; the last arriver resets the
//! count and bumps `generation`, releasing everyone spinning on it.
//! Waiters spin briefly and then `yield_now` (level boundaries are
//! microseconds apart when the sweep is healthy, but the crate's
//! testbeds are routinely oversubscribed, so unbounded spinning would
//! invert the priority of the worker everyone is waiting for). A wait
//! costs no heap allocation and no syscalls on the fast path, which is
//! what keeps the packed executor inside the crate's zero-allocation
//! solve contract (`rust/tests/alloc_free.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Spins before a waiter starts yielding the CPU between polls.
const BARRIER_SPINS: u32 = 512;

/// A reusable fork-join barrier for the participants of a single pool
/// job (see the module docs). All participants must call
/// [`SweepBarrier::wait`] with the same `parts` value, the same number
/// of times — exactly the discipline a deterministic level schedule
/// provides, since every participant walks the same level list.
#[derive(Default)]
pub struct SweepBarrier {
    /// Participants that have arrived at the current episode.
    arrived: AtomicUsize,
    /// Episode counter; bumped by the last arriver of each episode.
    generation: AtomicUsize,
}

impl SweepBarrier {
    /// A fresh barrier (no participants in flight).
    pub const fn new() -> SweepBarrier {
        SweepBarrier { arrived: AtomicUsize::new(0), generation: AtomicUsize::new(0) }
    }

    /// Block until all `parts` participants of the current episode have
    /// arrived. Memory ordering: every write sequenced before a
    /// participant's `wait` happens-before everything sequenced after
    /// any participant's return from the same episode (the arrival
    /// counter's release/acquire RMW chain feeds the last arriver, and
    /// the generation bump publishes it to every waiter).
    #[inline]
    pub fn wait(&self, parts: usize) {
        if parts <= 1 {
            return;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.arrived.fetch_add(1, Ordering::AcqRel) + 1 == parts {
            // Last arriver: reset for the next episode, then release.
            // The reset is sequenced before the generation bump, so no
            // participant of the *next* episode (who must first observe
            // the bump) can race it.
            self.arrived.store(0, Ordering::Relaxed);
            self.generation.fetch_add(1, Ordering::Release);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                spins = spins.saturating_add(1);
                if spins < BARRIER_SPINS {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::WorkerPool;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn single_part_is_a_no_op() {
        let b = SweepBarrier::new();
        b.wait(1); // must not block
        b.wait(0);
        assert_eq!(b.generation.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn phases_are_totally_ordered_across_participants() {
        // Each of 4 participants bumps its phase counter between
        // barrier episodes; after every episode all counters must agree
        // — a torn episode would let one participant run ahead.
        let pool = WorkerPool::new(4);
        let barrier = SweepBarrier::new();
        let phases: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        pool.run(4, |part, parts| {
            for _round in 0..200 {
                phases[part].fetch_add(1, Ordering::Relaxed);
                barrier.wait(parts);
                let mine = phases[part].load(Ordering::Relaxed);
                for other in &phases {
                    assert_eq!(other.load(Ordering::Relaxed), mine);
                }
                barrier.wait(parts);
            }
        });
        assert!(phases.iter().all(|p| p.load(Ordering::Relaxed) == 200));
    }

    #[test]
    fn publishes_plain_writes_between_episodes() {
        // Part 0 writes a slot before the barrier; every other part
        // must read the value after it — the release/acquire chain the
        // packed sweeps rely on between a narrow (worker-0-only) level
        // and the parallel level that consumes it.
        let pool = WorkerPool::new(3);
        let barrier = SweepBarrier::new();
        let mut slot = 0u64;
        let ptr = crate::par::SendPtr::new(&mut slot as *mut u64);
        pool.run(3, |part, parts| {
            for round in 1..=100u64 {
                if part == 0 {
                    // SAFETY: only part 0 writes; readers are fenced by
                    // the barrier below.
                    unsafe { ptr.write(0, round) };
                }
                barrier.wait(parts);
                // SAFETY: the write above happens-before this read.
                assert_eq!(unsafe { ptr.read(0) }, round);
                barrier.wait(parts);
            }
        });
    }
}
