//! Crate-wide persistent parallelism — the CPU analogue of the paper's
//! persistent GPU kernel (§5.1).
//!
//! The paper's GPU design launches one kernel whose blocks stay
//! resident while work is fed to them through queues. The CPU
//! reproduction used to do the opposite: every parallel section —
//! every level of every triangular-solve sweep of every PCG iteration
//! — spawned and joined fresh OS threads, thousands of times per
//! solve. This module replaces all of that with one
//! [`WorkerPool`]: fixed worker threads created once, jobs dispatched
//! as chunked index ranges with a completion barrier, and **zero heap
//! allocation on the steady-state dispatch path** (asserted by the
//! tracking-allocator test in `rust/tests/alloc_free.rs`).
//!
//! Users:
//! * [`crate::solve::packed`] — the packed sweep executor runs each
//!   whole triangular sweep as **one** pool job; the participants stay
//!   resident across every level and synchronize at level boundaries
//!   with a [`SweepBarrier`] instead of returning to the dispatcher.
//! * [`crate::solve::trisolve`] — the reference level-scheduled sweeps
//!   dispatch each level's vertex slice as its own pool job (the
//!   pre-packed executor, kept for comparison benches and tests).
//! * [`crate::sparse::Csr::spmv_par`] — SpMV split by row ranges.
//! * [`crate::factor::cpu`] / [`crate::factor::gpusim`] — the engine
//!   worker/block loops run as one pool job per factorization.
//!
//! [`global`] returns the process-wide pool. Its size is fixed at
//! first use: `PARAC_THREADS` if set (respected exactly, so a
//! constrained container can bound the thread count), otherwise the
//! larger of the available parallelism and [`MIN_GLOBAL_POOL`] (a
//! floor so the concurrent engines still run genuinely multi-threaded
//! — and their schedule-independence guarantees stay exercised — on
//! small CI machines). Requests beyond the pool size are clamped:
//! engine `threads`/`blocks` counts above it run with the pool's
//! actual width (and report it — `FactorStats` carries the effective
//! count).

mod barrier;
mod pool;

pub use barrier::SweepBarrier;
pub use pool::WorkerPool;

use std::sync::OnceLock;

/// Minimum size of the [`global`] pool (see the module docs).
pub const MIN_GLOBAL_POOL: usize = 4;

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide worker pool, created on first use and kept for the
/// lifetime of the process. Idle workers park on a condvar, so an
/// unused pool costs nothing but its stacks. Sizing: an explicit
/// `PARAC_THREADS` is respected exactly; the auto-detected size gets
/// the [`MIN_GLOBAL_POOL`] floor (see the module docs).
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| {
        let size = match std::env::var("PARAC_THREADS").ok().and_then(|s| s.parse().ok()) {
            Some(n) if n >= 1 => n,
            _ => crate::util::default_threads().max(MIN_GLOBAL_POOL),
        };
        WorkerPool::new(size)
    })
}

/// Contiguous index range of part `part` out of `parts` over `len`
/// items: ceil-divided chunks, so every index is covered exactly once
/// and parts differ in size by at most one chunk tail.
#[inline]
pub fn chunk_range(len: usize, part: usize, parts: usize) -> (usize, usize) {
    let chunk = len.div_ceil(parts.max(1));
    let lo = (part * chunk).min(len);
    let hi = (lo + chunk).min(len);
    (lo, hi)
}

/// A `Send + Sync` raw-pointer wrapper so pool parts can write disjoint
/// entries of one buffer (level-scheduled solves, row-split SpMV). All
/// safety obligations sit on the reader/writer: callers guarantee that
/// no two parts touch the same index and that the buffer outlives the
/// dispatch.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T: Copy> SendPtr<T> {
    /// Wrap a buffer's base pointer.
    pub fn new(ptr: *mut T) -> SendPtr<T> {
        SendPtr(ptr)
    }

    /// Read entry `i`.
    ///
    /// # Safety
    /// `i` is in bounds and no other part writes it concurrently.
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T {
        *self.0.add(i)
    }

    /// Write entry `i`.
    ///
    /// # Safety
    /// `i` is in bounds and this part has exclusive access to it.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        *self.0.add(i) = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for len in [0usize, 1, 7, 256, 1000] {
            for parts in [1usize, 2, 3, 8] {
                let mut covered = 0usize;
                let mut prev_hi = 0usize;
                for part in 0..parts {
                    let (lo, hi) = chunk_range(len, part, parts);
                    assert!(lo <= hi && hi <= len, "len={len} parts={parts} part={part}");
                    assert!(lo >= prev_hi, "parts must not overlap");
                    prev_hi = hi;
                    covered += hi - lo;
                }
                assert_eq!(covered, len, "len={len} parts={parts}");
            }
        }
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let p = global();
        assert!(p.size() >= 1);
        assert!(std::ptr::eq(p, global()));
    }
}
