//! GPU execution-model substrate.
//!
//! The paper's GPU engine (§5.3) runs a *persistent kernel*: every block
//! stays resident, polls the job queue, and eliminates one vertex at a
//! time using block-level primitives (CUB scans, custom odd-even /
//! bitonic sorts, parallel binary-search sampling) and a linear-probing
//! hash workspace with free/busy/occupied slot states.
//!
//! No GPU is available in this environment, so this module reproduces
//! the *execution model* faithfully on CPU (see DESIGN.md
//! §Hardware-Adaptation): [`primitives`] implements the block-level
//! collectives as explicit lane-step loops — the exact data movement a
//! warp would perform — and [`hashmap`] implements the slot-state
//! workspace with the same CAS protocol a CUDA implementation uses.
//! `factor::gpusim` drives them with one OS thread per simulated block.

pub mod hashmap;
pub mod primitives;
