//! The right-looking fill workspace `W` (§5.3, Algorithm 4).
//!
//! A linear-probing, array-based hash map whose entries carry one of
//! three states — **free**, **busy**, **occupied** — exactly as the
//! paper describes: busy means a block is mid-write and others
//! spin-wait. Fills for vertex `a` are inserted starting at
//! `hash(a) + fill_in_count(a)` (the paper's probe-shortening
//! heuristic); gathering scans from `hash(a)` until the expected count
//! is found, freeing slots for reuse.
//!
//! `hash` is a **random permutation** of the vertex ids stretched over
//! the table (§5.3.4: maximizing the minimum distance between any pair
//! of hash codes; "setting σ to a random permutation works great in
//! practice") — the identity mapping is kept for the ablation bench.

use crate::factor::chunk::SharedBuf;
use crate::rng::Rng;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};

const FREE: u32 = 0;
const BUSY: u32 = 1;
const OCCUPIED: u32 = 2;

/// Hash-code generation strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashKind {
    /// Random permutation σ stretched over the table (paper default).
    RandomPerm,
    /// Identity mapping (paper: "the default permutation may cause slow
    /// down" — kept for the ablation).
    Identity,
}

/// The concurrent slot-state workspace.
pub struct Workspace {
    state: Box<[AtomicU32]>,
    owner: SharedBuf<u32>,
    row: SharedBuf<u32>,
    val: SharedBuf<f64>,
    /// Per-vertex fill count (exact number of pending fills owned by v).
    fill_count: Box<[AtomicU32]>,
    /// hash(v): start slot per vertex.
    base: Vec<usize>,
    cap: usize,
    /// Total probe steps across all inserts and gathers (perf counter,
    /// reported as [`crate::factor::FactorStats::probe_steps`]).
    pub probe_steps: AtomicU64,
    /// Worst probe distance observed (perf counter, reported as
    /// [`crate::factor::FactorStats::max_probe`]).
    pub max_probe: AtomicU64,
    /// Currently occupied slots (relaxed; see [`Workspace::peak_occupancy`]).
    live: AtomicUsize,
    /// High-water mark of `live` — the fill-workspace occupancy
    /// reported as [`crate::factor::FactorStats::arena_used`].
    peak: AtomicUsize,
}

impl Workspace {
    /// Build a workspace of `cap` slots for `n` vertices.
    pub fn new(cap: usize, n: usize, kind: HashKind, seed: u64) -> Workspace {
        let cap = cap.max(n.max(16));
        let mut state = Vec::with_capacity(cap);
        state.resize_with(cap, || AtomicU32::new(FREE));
        let mut fill_count = Vec::with_capacity(n);
        fill_count.resize_with(n, || AtomicU32::new(0));
        let sigma: Vec<u32> = match kind {
            HashKind::RandomPerm => Rng::new(seed ^ 0x4A54_A5A5).permutation(n),
            HashKind::Identity => (0..n as u32).collect(),
        };
        let base = sigma
            .iter()
            .map(|&s| ((s as u128 * cap as u128) / n.max(1) as u128) as usize)
            .collect();
        Workspace {
            state: state.into_boxed_slice(),
            owner: SharedBuf::new(cap),
            row: SharedBuf::new(cap),
            val: SharedBuf::new(cap),
            fill_count: fill_count.into_boxed_slice(),
            base,
            cap,
            probe_steps: AtomicU64::new(0),
            max_probe: AtomicU64::new(0),
            live: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    /// Insert a fill `(row, val)` owned by vertex `v` (right-looking
    /// Schur update, Algorithm 4 line 22). Returns `Err(())` if the
    /// table is full.
    pub fn insert(&self, v: u32, row: u32, val: f64) -> Result<(), ()> {
        let hint = self.fill_count[v as usize].load(Ordering::Relaxed) as usize;
        let start = self.base[v as usize] + hint;
        let mut probes = 0u64;
        for step in 0..self.cap {
            let slot = (start + step) % self.cap;
            probes += 1;
            let st = &self.state[slot];
            if st.load(Ordering::Relaxed) == FREE
                && st
                    .compare_exchange(FREE, BUSY, Ordering::Acquire, Ordering::Relaxed)
                    .is_ok()
            {
                // SAFETY: BUSY state gives this thread exclusive access.
                unsafe {
                    self.owner.write(slot, v);
                    self.row.write(slot, row);
                    self.val.write(slot, val);
                }
                st.store(OCCUPIED, Ordering::Release);
                self.fill_count[v as usize].fetch_add(1, Ordering::AcqRel);
                self.probe_steps.fetch_add(probes, Ordering::Relaxed);
                self.max_probe.fetch_max(probes, Ordering::Relaxed);
                let now = self.live.fetch_add(1, Ordering::Relaxed) + 1;
                self.peak.fetch_max(now, Ordering::Relaxed);
                return Ok(());
            }
        }
        Err(())
    }

    /// Gather and free all fills owned by `v` (stage 1 of Algorithm 4).
    /// All inserts for `v` must happen-before (dependency protocol).
    /// Appends `(row, val)` pairs to `out`.
    pub fn gather(&self, v: u32, out: &mut Vec<(u32, f64)>) {
        let expected = self.fill_count[v as usize].load(Ordering::Acquire);
        if expected == 0 {
            return;
        }
        let start = self.base[v as usize];
        let mut found = 0u32;
        let mut probes = 0u64;
        let mut step = 0usize;
        while found < expected {
            debug_assert!(
                step < 2 * self.cap,
                "workspace scan overran: vertex {v}, expected {expected}, found {found}"
            );
            let slot = (start + step) % self.cap;
            probes += 1;
            let st = &self.state[slot];
            match st.load(Ordering::Acquire) {
                OCCUPIED => {
                    // SAFETY: OCCUPIED published with Release.
                    let o = unsafe { self.owner.read(slot) };
                    if o == v {
                        let r = unsafe { self.row.read(slot) };
                        let w = unsafe { self.val.read(slot) };
                        out.push((r, w));
                        st.store(FREE, Ordering::Release);
                        found += 1;
                    }
                    step += 1;
                }
                BUSY => {
                    // Another block is mid-insert here — it might be for
                    // a different owner; spin until resolved (yield so
                    // the writer can finish on oversubscribed CPUs).
                    std::thread::yield_now();
                }
                _ => {
                    step += 1;
                }
            }
        }
        self.fill_count[v as usize].store(0, Ordering::Relaxed);
        self.probe_steps.fetch_add(probes, Ordering::Relaxed);
        self.max_probe.fetch_max(probes, Ordering::Relaxed);
        self.live.fetch_sub(found as usize, Ordering::Relaxed);
    }

    /// Reuse the workspace for another factorization: clear every slot
    /// state and counter. The hash bases are seed-derived only, so they
    /// survive reuse. Caller must guarantee no concurrent access.
    pub fn reset(&self) {
        for st in self.state.iter() {
            st.store(FREE, Ordering::Relaxed);
        }
        for fc in self.fill_count.iter() {
            fc.store(0, Ordering::Relaxed);
        }
        self.probe_steps.store(0, Ordering::Relaxed);
        self.max_probe.store(0, Ordering::Relaxed);
        self.live.store(0, Ordering::Relaxed);
        self.peak.store(0, Ordering::Relaxed);
    }

    /// Current number of pending fills for `v`.
    pub fn pending(&self, v: u32) -> u32 {
        self.fill_count[v as usize].load(Ordering::Relaxed)
    }

    /// Table capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// High-water mark of occupied slots — the fill-workspace
    /// occupancy ([`crate::factor::FactorStats::arena_used`] for the
    /// gpusim engine, the slot-table analogue of the CPU engine's
    /// never-freed fill-arena bump watermark). Relaxed counters: under
    /// concurrent inserts the reported peak can lag the true
    /// instantaneous maximum by a few slots; it is a capacity-planning
    /// stat, not a synchronization primitive.
    pub fn peak_occupancy(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_then_gather_roundtrip() {
        let w = Workspace::new(64, 8, HashKind::RandomPerm, 1);
        w.insert(3, 10, 1.5).unwrap();
        w.insert(3, 11, 2.5).unwrap();
        w.insert(5, 12, 3.5).unwrap();
        let mut out = Vec::new();
        w.gather(3, &mut out);
        out.sort_by_key(|x| x.0);
        assert_eq!(out, vec![(10, 1.5), (11, 2.5)]);
        assert_eq!(w.pending(3), 0);
        assert_eq!(w.pending(5), 1);
    }

    #[test]
    fn slots_are_reusable_after_gather() {
        let w = Workspace::new(16, 4, HashKind::Identity, 0);
        for round in 0..20 {
            for i in 0..10 {
                w.insert(1, i, round as f64).unwrap();
            }
            let mut out = Vec::new();
            w.gather(1, &mut out);
            assert_eq!(out.len(), 10, "round {round}");
        }
        // 10 concurrent residents max, however many rounds ran.
        assert_eq!(w.peak_occupancy(), 10);
    }

    #[test]
    fn full_table_reports_error() {
        let w = Workspace::new(16, 4, HashKind::Identity, 0);
        for i in 0..16 {
            w.insert(0, i, 1.0).unwrap();
        }
        assert!(w.insert(0, 99, 1.0).is_err());
    }

    #[test]
    fn concurrent_inserts_distinct_owners() {
        let n = 8u32;
        let per = 500;
        let w = Workspace::new(16 * 1024, n as usize, HashKind::RandomPerm, 7);
        std::thread::scope(|s| {
            for v in 0..n {
                let w = &w;
                s.spawn(move || {
                    for i in 0..per {
                        w.insert(v, i, v as f64 + i as f64).unwrap();
                    }
                });
            }
        });
        for v in 0..n {
            let mut out = Vec::new();
            w.gather(v, &mut out);
            assert_eq!(out.len(), per as usize, "owner {v}");
            assert!(out.iter().all(|&(r, val)| val == v as f64 + r as f64));
        }
    }

    #[test]
    fn concurrent_insert_while_gathering_other_owner() {
        let w = Workspace::new(4096, 2, HashKind::RandomPerm, 3);
        for i in 0..200 {
            w.insert(0, i, 1.0).unwrap();
        }
        std::thread::scope(|s| {
            let w0 = &w;
            s.spawn(move || {
                for i in 0..200 {
                    w0.insert(1, i, 2.0).unwrap();
                }
            });
            let mut out = Vec::new();
            w.gather(0, &mut out);
            assert_eq!(out.len(), 200);
        });
        let mut out = Vec::new();
        w.gather(1, &mut out);
        assert_eq!(out.len(), 200);
    }

    #[test]
    fn random_perm_spreads_bases() {
        let w = Workspace::new(1000, 100, HashKind::RandomPerm, 9);
        // All bases distinct (permutation property).
        let mut bases = w.base.clone();
        bases.sort_unstable();
        bases.dedup();
        assert_eq!(bases.len(), 100);
    }
}
