//! Block-level collective primitives, modeled as lane-step loops.
//!
//! Each function mirrors a CUDA block collective the paper's kernel
//! uses; the lane loop (`for lane in 0..width`) stands in for the
//! warp's simultaneous execution, and the *step structure* (compare
//! distances, scan offsets) is identical to the device versions:
//!
//! * [`odd_even_sort_by`] / [`bitonic_sort_by`] — the paper's custom
//!   block sorts, "which can handle an arbitrary number of elements"
//!   (§5.3.2: CUB's block sort needs a compile-time size).
//! * [`exclusive_prefix_sum`] — Blelloch up/down-sweep scan.
//! * [`suffix_sums_f64`] — the sampling CDF (Algorithm 4 line 18).
//! * [`merge_sorted_by_flags`] — the paper's "mark 1 if different from
//!   left neighbor, prefix-sum for new indices" duplicate merge.

/// Odd–even transposition sort (stable network for small `n`).
/// `key` maps an element to its comparison key.
pub fn odd_even_sort_by<T: Copy, K: PartialOrd>(xs: &mut [T], key: impl Fn(&T) -> K) {
    let n = xs.len();
    for step in 0..n {
        let start = step % 2;
        // "Lanes" compare-exchange disjoint pairs simultaneously.
        let mut lane = start;
        while lane + 1 < n {
            if key(&xs[lane + 1]) < key(&xs[lane]) {
                xs.swap(lane, lane + 1);
            }
            lane += 2;
        }
    }
}

/// Bitonic sort for arbitrary `n`: the power-of-two network run over a
/// buffer padded with copies of the maximum element (the padding sorts
/// to the tail and is bit-identical to real maxima, so truncation is
/// exact) — the same strategy a device kernel uses with sentinel keys
/// in shared memory.
pub fn bitonic_sort_by<T: Copy, K: PartialOrd>(xs: &mut [T], key: impl Fn(&T) -> K) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    let m = n.next_power_of_two();
    // Pad with the max element.
    let mut buf: Vec<T> = Vec::with_capacity(m);
    buf.extend_from_slice(xs);
    if m > n {
        let mut max_i = 0;
        for i in 1..n {
            if key(&xs[i]) > key(&xs[max_i]) {
                max_i = i;
            }
        }
        buf.resize(m, xs[max_i]);
    }
    let mut k = 2;
    while k <= m {
        let mut j = k / 2;
        while j > 0 {
            for lane in 0..m {
                let partner = lane ^ j;
                if partner > lane {
                    let ascending = lane & k == 0;
                    let a = key(&buf[lane]);
                    let b = key(&buf[partner]);
                    if (b < a) == ascending {
                        buf.swap(lane, partner);
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }
    xs.copy_from_slice(&buf[..n]);
}

/// Exclusive prefix sum (Blelloch two-phase scan shape). Returns the
/// total.
pub fn exclusive_prefix_sum(xs: &mut [u32]) -> u32 {
    let n = xs.len();
    if n == 0 {
        return 0;
    }
    let m = n.next_power_of_two();
    let mut buf = vec![0u32; m];
    buf[..n].copy_from_slice(xs);
    // Up-sweep.
    let mut d = 1;
    while d < m {
        let mut lane = 2 * d - 1;
        while lane < m {
            buf[lane] += buf[lane - d];
            lane += 2 * d;
        }
        d *= 2;
    }
    let total = buf[m - 1];
    buf[m - 1] = 0;
    // Down-sweep.
    d = m / 2;
    while d >= 1 {
        let mut lane = 2 * d - 1;
        while lane < m {
            let t = buf[lane - d];
            buf[lane - d] = buf[lane];
            buf[lane] += t;
            lane += 2 * d;
        }
        if d == 1 {
            break;
        }
        d /= 2;
    }
    xs.copy_from_slice(&buf[..n]);
    total
}

/// Inclusive suffix sums of `f64` weights: `out[i] = Σ_{t ≥ i} w_t`
/// (Algorithm 4's parallel suffix sum; serial reference shape here
/// because float scans must stay deterministic anyway).
pub fn suffix_sums_f64(ws: &[f64], out: &mut Vec<f64>) {
    out.clear();
    out.resize(ws.len(), 0.0);
    let mut acc = 0.0;
    for i in (0..ws.len()).rev() {
        acc += ws[i];
        out[i] = acc;
    }
}

/// The paper's GPU duplicate-merge (§5.3.2): input sorted by key; flag
/// each element that differs from its left neighbor; exclusive prefix
/// sum of flags gives output indices; accumulate values and
/// multiplicities. Returns merged `(key, value)` pairs + multiplicity.
pub fn merge_sorted_by_flags(
    sorted: &[(u32, f64)],
    merged: &mut Vec<(u32, f64)>,
    mult: &mut Vec<u32>,
) {
    merged.clear();
    mult.clear();
    let n = sorted.len();
    if n == 0 {
        return;
    }
    // Flags: 1 where a new run starts.
    let flags: Vec<u32> = (0..n)
        .map(|i| if i == 0 || sorted[i].0 != sorted[i - 1].0 { 1 } else { 0 })
        .collect();
    // Output slot = inclusive_scan(flags) − 1 = exclusive + own flag − 1.
    let mut scan = flags.clone();
    let total = exclusive_prefix_sum(&mut scan);
    merged.resize(total as usize, (0, 0.0));
    mult.resize(total as usize, 0);
    for i in 0..n {
        let slot = (scan[i] + flags[i] - 1) as usize;
        let (k, v) = sorted[i];
        merged[slot].0 = k;
        merged[slot].1 += v;
        mult[slot] += 1;
    }
}

/// Parallel weighted draw (Algorithm 4 line 20): binary search over the
/// inclusive-prefix CDF — each lane would search independently on the
/// device; the search itself is identical.
pub fn block_search_cdf(cum: &[f64], u: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = cum.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if cum[mid] <= u {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::forall_rngs;

    #[test]
    fn odd_even_sorts() {
        forall_rngs(32, |rng| {
            let n = rng.below(64);
            let mut xs: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32 % 100).collect();
            let mut want = xs.clone();
            want.sort_unstable();
            odd_even_sort_by(&mut xs, |&x| x);
            if xs != want {
                return Err(format!("odd-even failed on {n} elems"));
            }
            Ok(())
        });
    }

    #[test]
    fn bitonic_sorts_arbitrary_sizes() {
        forall_rngs(48, |rng| {
            let n = rng.below(130); // crosses powers of two
            let mut xs: Vec<(u32, f64)> =
                (0..n).map(|i| ((rng.next_u64() % 1000) as u32, i as f64)).collect();
            let mut want = xs.clone();
            want.sort_by_key(|x| x.0);
            bitonic_sort_by(&mut xs, |x| x.0);
            let got: Vec<u32> = xs.iter().map(|x| x.0).collect();
            let exp: Vec<u32> = want.iter().map(|x| x.0).collect();
            if got != exp {
                return Err(format!("bitonic failed on {n} elems"));
            }
            Ok(())
        });
    }

    #[test]
    fn prefix_sum_matches_serial() {
        forall_rngs(32, |rng| {
            let n = rng.below(70);
            let xs: Vec<u32> = (0..n).map(|_| (rng.next_u64() % 10) as u32).collect();
            let mut got = xs.clone();
            let total = exclusive_prefix_sum(&mut got);
            let mut acc = 0u32;
            for i in 0..n {
                if got[i] != acc {
                    return Err(format!("prefix[{i}] = {} want {acc}", got[i]));
                }
                acc += xs[i];
            }
            if total != acc {
                return Err(format!("total {total} want {acc}"));
            }
            Ok(())
        });
    }

    #[test]
    fn suffix_sums() {
        let mut out = Vec::new();
        suffix_sums_f64(&[1.0, 2.0, 3.0], &mut out);
        assert_eq!(out, vec![6.0, 5.0, 3.0]);
        suffix_sums_f64(&[], &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn flag_merge_equals_reference_merge() {
        forall_rngs(32, |rng| {
            let n = rng.below(50);
            let mut raw: Vec<(u32, f64)> = (0..n)
                .map(|_| ((rng.next_u64() % 8) as u32, rng.range_f64(0.1, 2.0)))
                .collect();
            // Reference path.
            let mut m_ref = Vec::new();
            let mut c_ref = Vec::new();
            let mut raw2 = raw.clone();
            crate::factor::sample::merge_neighbors(&mut raw2, &mut m_ref, &mut c_ref);
            // GPU path: sort by (key, val) then flag-merge.
            // total_cmp: NaN-safe (partial_cmp().unwrap() would panic
            // the block-sort primitive on degenerate weights).
            raw.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
            let mut m_gpu = Vec::new();
            let mut c_gpu = Vec::new();
            merge_sorted_by_flags(&raw, &mut m_gpu, &mut c_gpu);
            if m_ref.len() != m_gpu.len() || c_ref != c_gpu {
                return Err("structure mismatch".into());
            }
            for (a, b) in m_ref.iter().zip(&m_gpu) {
                if a.0 != b.0 || (a.1 - b.1).abs() > 1e-12 {
                    return Err(format!("{a:?} vs {b:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cdf_search() {
        let cum = [1.0, 3.0, 6.0];
        assert_eq!(block_search_cdf(&cum, 0.5), 0);
        assert_eq!(block_search_cdf(&cum, 1.0), 1);
        assert_eq!(block_search_cdf(&cum, 2.9), 1);
        assert_eq!(block_search_cdf(&cum, 5.9), 2);
    }
}
