//! Hand-rolled CLI argument parsing (no clap offline).

pub mod args;
