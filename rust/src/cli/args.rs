//! Minimal `--key value` / `--flag` argument parser.

use std::collections::HashMap;

/// Parsed command line: positionals + options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options and bare `--flag`s (value
    /// `"true"`).
    pub options: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.options.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// String option with default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.options.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Parsed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.options.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Boolean flag.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["repro", "table2", "--scale", "small", "--threads=8", "--verbose"]);
        assert_eq!(a.positional, vec!["repro", "table2"]);
        assert_eq!(a.get("scale", "medium"), "small");
        assert_eq!(a.get_parse::<usize>("threads", 1), 8);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--x", "--y", "3"]);
        assert!(a.flag("x"));
        assert_eq!(a.get_parse::<i32>("y", 0), 3);
    }
}
