//! Random ordering (uniformly random permutation).
//!
//! The paper (§6, §7.1) notes a random elimination ordering behaves like
//! assigning the vertices random priorities, connecting ParAC's available
//! parallelism to Luby-style parallel maximal-independent-set rounds. It
//! is one of the two orderings that win on the GPU engine.

use crate::graph::Laplacian;
use crate::rng::Rng;

/// Uniformly random permutation `perm[old] = new`.
pub fn random_order(lap: &Laplacian, seed: u64) -> Vec<u32> {
    Rng::new(seed ^ 0xBADC_AB1E).permutation(lap.n())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::ordering::perm;

    #[test]
    fn is_valid_permutation_and_seed_dependent() {
        let l = generators::grid2d(10, 10, generators::Coeff::Uniform, 0);
        let a = random_order(&l, 1);
        let b = random_order(&l, 2);
        perm::validate(&a).unwrap();
        perm::validate(&b).unwrap();
        assert_ne!(a, b);
        assert_eq!(a, random_order(&l, 1));
    }
}
