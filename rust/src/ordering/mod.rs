//! Elimination orderings.
//!
//! The paper evaluates three orderings (§6): **AMD** (best on the CPU
//! engine — locality), **nnz-sort** (degree-sort with random tie-break;
//! best on the GPU engine — short critical paths), and **random**. RCM
//! is included as an extra locality baseline, and `Natural` as control.
//!
//! A permutation here is a map `perm[old] = new`; applying it relabels
//! vertex `old` as `new` before factorization (`L' = P L Pᵀ`). The
//! [`Ordering`] selector computes one via [`amd`], [`nnz_sort`],
//! [`random`], or [`rcm`]; [`perm`] holds the inverse/compose/apply
//! utilities the factor and solvers share.

pub mod amd;
pub mod nnz_sort;
pub mod perm;
pub mod random;
pub mod rcm;

use crate::graph::Laplacian;
use crate::rng::Rng;

/// Ordering strategy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ordering {
    /// Identity (input order).
    Natural,
    /// Uniformly random permutation.
    Random,
    /// Sort by initial degree ascending, random tie-break (the paper's
    /// GPU default).
    NnzSort,
    /// Approximate minimum degree (the paper's CPU default).
    Amd,
    /// Reverse Cuthill–McKee (bandwidth/locality baseline).
    Rcm,
}

impl Ordering {
    /// Compute `perm[old] = new` for this strategy.
    pub fn compute(&self, lap: &Laplacian, seed: u64) -> Vec<u32> {
        match self {
            Ordering::Natural => (0..lap.n() as u32).collect(),
            Ordering::Random => Rng::new(seed ^ 0x5EED_0DE5).permutation(lap.n()),
            Ordering::NnzSort => nnz_sort::nnz_sort(lap, seed),
            Ordering::Amd => amd::amd(&lap.matrix),
            Ordering::Rcm => rcm::rcm(&lap.matrix),
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Ordering> {
        match s.to_ascii_lowercase().as_str() {
            "natural" => Some(Ordering::Natural),
            "random" => Some(Ordering::Random),
            "nnz" | "nnz-sort" | "nnz_sort" => Some(Ordering::NnzSort),
            "amd" => Some(Ordering::Amd),
            "rcm" => Some(Ordering::Rcm),
            _ => None,
        }
    }

    /// Display name matching the paper's figures.
    pub fn name(&self) -> &'static str {
        match self {
            Ordering::Natural => "natural",
            Ordering::Random => "random",
            Ordering::NnzSort => "nnz-sort",
            Ordering::Amd => "AMD",
            Ordering::Rcm => "RCM",
        }
    }

    /// The three orderings the paper benchmarks.
    pub fn paper_set() -> [Ordering; 3] {
        [Ordering::Amd, Ordering::NnzSort, Ordering::Random]
    }
}
