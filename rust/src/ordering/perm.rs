//! Permutation utilities.
//!
//! Convention: `perm[old] = new` (a relabeling map). `inverse(perm)[new]
//! = old` gives the elimination sequence: the vertex eliminated at step
//! `k` is `inverse(perm)[k]`.

/// Invert a permutation.
pub fn inverse(perm: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; perm.len()];
    for (old, &new) in perm.iter().enumerate() {
        inv[new as usize] = old as u32;
    }
    inv
}

/// Compose: `(a ∘ b)[i] = a[b[i]]` — apply `b` first, then `a`.
pub fn compose(a: &[u32], b: &[u32]) -> Vec<u32> {
    assert_eq!(a.len(), b.len());
    b.iter().map(|&i| a[i as usize]).collect()
}

/// Check that `perm` is a bijection on `0..n`.
pub fn validate(perm: &[u32]) -> Result<(), String> {
    let n = perm.len();
    let mut seen = vec![false; n];
    for (i, &p) in perm.iter().enumerate() {
        let p = p as usize;
        if p >= n {
            return Err(format!("perm[{i}] = {p} out of range"));
        }
        if seen[p] {
            return Err(format!("perm[{i}] = {p} duplicated"));
        }
        seen[p] = true;
    }
    Ok(())
}

/// Apply to a vector: `out[perm[i]] = x[i]`.
pub fn apply_vec(perm: &[u32], x: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; x.len()];
    for (i, &p) in perm.iter().enumerate() {
        out[p as usize] = x[i];
    }
    out
}

/// Undo on a vector: `out[i] = x[perm[i]]`.
pub fn unapply_vec(perm: &[u32], x: &[f64]) -> Vec<f64> {
    perm.iter().map(|&p| x[p as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::forall_rngs;

    #[test]
    fn inverse_composes_to_identity() {
        forall_rngs(32, |rng| {
            let n = 1 + rng.below(200);
            let p = rng.permutation(n);
            let inv = inverse(&p);
            let id = compose(&p, &inv);
            for (i, &v) in id.iter().enumerate() {
                if v as usize != i {
                    return Err(format!("compose(p, inv)[{i}] = {v}"));
                }
            }
            validate(&p).map_err(|e| e.to_string())?;
            Ok(())
        });
    }

    #[test]
    fn vec_apply_roundtrip() {
        forall_rngs(16, |rng| {
            let n = 1 + rng.below(100);
            let p = rng.permutation(n);
            let x: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
            let y = apply_vec(&p, &x);
            let back = unapply_vec(&p, &y);
            crate::testing::prop::assert_close(&x, &back, 0.0, "roundtrip")
        });
    }

    #[test]
    fn validate_rejects_bad() {
        assert!(validate(&[0, 0]).is_err());
        assert!(validate(&[0, 5]).is_err());
        assert!(validate(&[1, 0]).is_ok());
    }
}
