//! Approximate minimum degree ordering (Amestoy–Davis–Duff style).
//!
//! A quotient-graph minimum-degree ordering with element absorption and
//! AMD's approximate external degree bound
//! `d_i ≈ |A_i| + |Lp \ i| + Σ_{e ∈ E_i} |Le \ Lp|`.
//! This is the ordering the paper reports as fastest for the CPU engine
//! (locality) and slowest for the GPU engine (long critical paths).
//!
//! The implementation favours clarity over the last constant factor (no
//! supervariable hashing / mass elimination); complexity is fine for the
//! suite sizes used here (≤ a few hundred thousand vertices, bounded
//! degree).

use crate::sparse::Csr;

/// Degree-bucket priority structure: doubly-linked lists per degree.
struct DegreeLists {
    head: Vec<i64>, // head[d] = first node with degree d, -1 if none
    next: Vec<i64>,
    prev: Vec<i64>,
    deg: Vec<usize>,
    min_deg: usize,
}

impl DegreeLists {
    fn new(n: usize, init_deg: &[usize]) -> Self {
        let max_d = n + 1;
        let mut dl = DegreeLists {
            head: vec![-1; max_d + 1],
            next: vec![-1; n],
            prev: vec![-1; n],
            deg: vec![0; n],
            min_deg: max_d,
        };
        for v in 0..n {
            dl.insert(v, init_deg[v]);
        }
        dl
    }

    fn insert(&mut self, v: usize, d: usize) {
        self.deg[v] = d;
        let h = self.head[d];
        self.next[v] = h;
        self.prev[v] = -1;
        if h >= 0 {
            self.prev[h as usize] = v as i64;
        }
        self.head[d] = v as i64;
        if d < self.min_deg {
            self.min_deg = d;
        }
    }

    fn remove(&mut self, v: usize) {
        let (p, nx) = (self.prev[v], self.next[v]);
        if p >= 0 {
            self.next[p as usize] = nx;
        } else {
            self.head[self.deg[v]] = nx;
        }
        if nx >= 0 {
            self.prev[nx as usize] = p;
        }
    }

    fn update(&mut self, v: usize, d: usize) {
        self.remove(v);
        self.insert(v, d);
    }

    fn pop_min(&mut self) -> Option<usize> {
        while self.min_deg < self.head.len() {
            let h = self.head[self.min_deg];
            if h >= 0 {
                let v = h as usize;
                self.remove(v);
                return Some(v);
            }
            self.min_deg += 1;
        }
        None
    }
}

/// Compute the AMD permutation `perm[old] = new` for a symmetric matrix.
pub fn amd(a: &Csr) -> Vec<u32> {
    let n = a.nrows;
    if n == 0 {
        return Vec::new();
    }
    // Node state. A node is a live variable, an element (eliminated
    // pivot), or dead (absorbed element).
    const VAR: u8 = 0;
    const ELEMENT: u8 = 1;
    const DEAD: u8 = 2;
    let mut kind = vec![VAR; n];
    // Variable lists: adjacent variables / adjacent elements.
    let mut adj_var: Vec<Vec<u32>> = (0..n)
        .map(|r| {
            a.row_indices(r)
                .iter()
                .copied()
                .filter(|&c| c as usize != r)
                .collect()
        })
        .collect();
    let mut adj_el: Vec<Vec<u32>> = vec![Vec::new(); n];
    // Element member lists (only meaningful for kind == ELEMENT).
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); n];

    let init_deg: Vec<usize> = (0..n).map(|v| adj_var[v].len()).collect();
    let mut lists = DegreeLists::new(n, &init_deg);

    // Work arrays.
    let mut mark = vec![0u64; n]; // generation marker
    let mut gen = 0u64;
    let mut w: Vec<i64> = vec![-1; n]; // |Le \ Lp| scratch per element

    let mut perm = vec![0u32; n];
    let mut lp: Vec<u32> = Vec::new();

    let mut k = 0usize;
    while k < n {
        let p = lists.pop_min().expect("ran out of variables");
        perm[p] = k as u32;

        // ---- Form Lp = (A_p ∪ ⋃_{e∈E_p} Le) \ {p}, deduplicated. ----
        gen += 1;
        mark[p] = gen;
        lp.clear();
        for &v in &adj_var[p] {
            let v = v as usize;
            if kind[v] == VAR && mark[v] != gen {
                mark[v] = gen;
                lp.push(v as u32);
            }
        }
        for &e in &adj_el[p] {
            let e = e as usize;
            if kind[e] != ELEMENT {
                continue;
            }
            for &v in &members[e] {
                let v = v as usize;
                if kind[v] == VAR && mark[v] != gen {
                    mark[v] = gen;
                    lp.push(v as u32);
                }
            }
        }

        // ---- Compute |Le \ Lp| for all elements adjacent to Lp. ----
        // w[e] starts at |Le| (live members) and is decremented once per
        // member that is in Lp.
        let mut touched_elems: Vec<u32> = Vec::new();
        for &iu in &lp {
            let i = iu as usize;
            for &e in &adj_el[i] {
                let e = e as usize;
                if kind[e] != ELEMENT {
                    continue;
                }
                if w[e] < 0 {
                    // Count live members — and compact the list in place
                    // so dead (eliminated/absorbed) members are scanned
                    // at most once across the whole run.
                    members[e].retain(|&v| kind[v as usize] == VAR);
                    w[e] = members[e].len() as i64;
                    touched_elems.push(e as u32);
                }
                w[e] -= 1;
            }
        }

        // ---- Update each i ∈ Lp. ----
        let lp_len = lp.len();
        for &iu in &lp {
            let i = iu as usize;
            // A_i := A_i \ Lp \ {p}  (now connected through element p).
            adj_var[i].retain(|&v| {
                let v = v as usize;
                kind[v] == VAR && mark[v] != gen && v != p
            });
            // E_i := (E_i \ absorbed) ∪ {p}; absorb elements with
            // Le ⊆ Lp (w[e] == 0).
            let mut approx = 0i64;
            adj_el[i].retain(|&e| {
                let e = e as usize;
                kind[e] == ELEMENT && w[e] > 0
            });
            for &e in &adj_el[i] {
                approx += w[e as usize];
            }
            adj_el[i].push(p as u32);
            // Approximate external degree.
            let d = (adj_var[i].len() as i64 + (lp_len as i64 - 1) + approx)
                .min(n as i64 - 1 - k as i64 - 1)
                .max(0) as usize;
            lists.update(i, d);
        }

        // ---- Absorb covered elements, finalize p as an element. ----
        for &e in &touched_elems {
            let e = e as usize;
            if w[e] == 0 {
                kind[e] = DEAD;
                members[e].clear();
                members[e].shrink_to_fit();
            }
            w[e] = -1;
        }
        for &e in &adj_el[p] {
            let e = e as usize;
            if kind[e] == ELEMENT {
                // p's own elements are covered by Lp by construction.
                kind[e] = DEAD;
                members[e].clear();
                members[e].shrink_to_fit();
            }
        }
        kind[p] = ELEMENT;

        // ---- Mass elimination: i ∈ Lp with A_i = ∅ and E_i = {p} is
        // indistinguishable from the pivot — its neighborhood is exactly
        // Lp, so eliminating it immediately is fill-free and skips a
        // full quotient-graph round (the classic MMD speedup).
        let mut next_label = k + 1;
        for &iu in &lp {
            let i = iu as usize;
            if next_label >= n {
                break;
            }
            if adj_var[i].is_empty() && adj_el[i].len() == 1 {
                debug_assert_eq!(adj_el[i][0] as usize, p);
                lists.remove(i);
                kind[i] = DEAD;
                perm[i] = next_label as u32;
                next_label += 1;
                adj_var[i] = Vec::new();
                adj_el[i] = Vec::new();
            }
        }
        k = next_label;
        members[p] = std::mem::take(&mut lp);
        adj_var[p] = Vec::new();
        adj_el[p] = Vec::new();
        lp = Vec::new();
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::ordering::perm;

    /// Exact symbolic fill count of Cholesky under an ordering — O(n²)
    /// reference (tiny graphs only).
    fn exact_fill(a: &Csr, p: &[u32]) -> usize {
        let n = a.nrows;
        let inv = perm::inverse(p);
        // adjacency sets in new labels
        let mut adj: Vec<std::collections::BTreeSet<u32>> = vec![Default::default(); n];
        for r in 0..n {
            for &c in a.row_indices(r) {
                if c as usize != r {
                    adj[p[r] as usize].insert(p[c as usize]);
                }
            }
        }
        let _ = inv;
        let mut fill = 0usize;
        for k in 0..n as u32 {
            let nbrs: Vec<u32> = adj[k as usize].iter().copied().filter(|&v| v > k).collect();
            for (x, &i) in nbrs.iter().enumerate() {
                for &j in &nbrs[x + 1..] {
                    if adj[i as usize].insert(j) {
                        adj[j as usize].insert(i);
                        fill += 1;
                    }
                }
            }
        }
        fill
    }

    #[test]
    fn valid_permutation() {
        let l = generators::grid2d(13, 11, generators::Coeff::Uniform, 0);
        let p = amd(&l.matrix);
        perm::validate(&p).unwrap();
    }

    #[test]
    fn path_graph_needs_no_fill() {
        let l = generators::path(40);
        let p = amd(&l.matrix);
        perm::validate(&p).unwrap();
        assert_eq!(exact_fill(&l.matrix, &p), 0, "AMD on a path must be fill-free");
    }

    #[test]
    fn star_hub_eliminated_near_last() {
        // Once all but one leaf is gone the hub's degree drops to 1 and
        // ties with the final leaf, so any of the last two labels is a
        // valid minimum-degree outcome. Fill must still be zero.
        let l = generators::star(30);
        let p = amd(&l.matrix);
        assert!(p[0] >= 28, "hub label {} should be among the last two", p[0]);
        assert_eq!(exact_fill(&l.matrix, &p), 0);
    }

    #[test]
    fn beats_natural_on_grid_fill() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let p_amd = amd(&l.matrix);
        let p_nat: Vec<u32> = (0..l.n() as u32).collect();
        let f_amd = exact_fill(&l.matrix, &p_amd);
        let f_nat = exact_fill(&l.matrix, &p_nat);
        assert!(
            f_amd < f_nat,
            "AMD fill {f_amd} should beat natural fill {f_nat}"
        );
    }

    #[test]
    fn handles_disconnected() {
        let l = crate::graph::Laplacian::from_edges(8, &[(0, 1, 1.0), (4, 5, 1.0)], "2c");
        let p = amd(&l.matrix);
        perm::validate(&p).unwrap();
    }

    #[test]
    fn deterministic() {
        let l = generators::random_connected(200, 150, 5);
        assert_eq!(amd(&l.matrix), amd(&l.matrix));
    }
}
