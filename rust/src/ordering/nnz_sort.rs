//! nnz-sort ordering: vertices sorted by initial degree ascending with
//! randomized tie-breaking (paper §6: "Nnz-sort is computed by sorting
//! the vertices based on the number of neighbors they start with, and we
//! use randomization for tie-break"). The paper's best ordering on GPU.

use crate::graph::Laplacian;
use crate::rng::Rng;

/// Compute the nnz-sort permutation `perm[old] = new`.
pub fn nnz_sort(lap: &Laplacian, seed: u64) -> Vec<u32> {
    let n = lap.n();
    let mut rng = Rng::new(seed ^ 0x4E4E_5A50);
    // (degree, random tie-break, vertex)
    let mut keys: Vec<(u32, u32, u32)> = (0..n)
        .map(|v| {
            let deg = lap
                .matrix
                .row_indices(v)
                .iter()
                .zip(lap.matrix.row_data(v))
                .filter(|(&c, &w)| c as usize != v && w != 0.0)
                .count() as u32;
            (deg, rng.next_u64() as u32, v as u32)
        })
        .collect();
    keys.sort_unstable();
    let mut perm = vec![0u32; n];
    for (new, &(_, _, old)) in keys.iter().enumerate() {
        perm[old as usize] = new as u32;
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::ordering::perm;

    #[test]
    fn low_degree_first() {
        // Star graph: hub has degree n-1, must be eliminated last.
        let l = generators::star(50);
        let p = nnz_sort(&l, 3);
        perm::validate(&p).unwrap();
        assert_eq!(p[0], 49, "hub (vertex 0) must get the last label");
    }

    #[test]
    fn degrees_nondecreasing_along_order() {
        let l = generators::pref_attach(300, 3, 5);
        let p = nnz_sort(&l, 7);
        perm::validate(&p).unwrap();
        let inv = perm::inverse(&p);
        let deg = |v: u32| l.matrix.row_indices(v as usize).len() - 1;
        for w in inv.windows(2) {
            assert!(deg(w[0]) <= deg(w[1]));
        }
    }

    #[test]
    fn tie_break_is_random_but_seeded() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let a = nnz_sort(&l, 1);
        let b = nnz_sort(&l, 2);
        assert_ne!(a, b, "different seeds should break ties differently");
        assert_eq!(a, nnz_sort(&l, 1));
    }
}
