//! Reverse Cuthill–McKee ordering — a bandwidth-minimizing, locality-
//! friendly baseline (not in the paper's benchmarked trio, but useful for
//! the ordering ablation: it is even more sequential than AMD).

use crate::sparse::Csr;
use std::collections::VecDeque;

/// Compute the RCM permutation `perm[old] = new` for a symmetric matrix.
pub fn rcm(a: &Csr) -> Vec<u32> {
    let n = a.nrows;
    let deg = |v: usize| a.row_indices(v).iter().filter(|&&c| c as usize != v).count();
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut queue: VecDeque<u32> = VecDeque::new();
    // Process every component, starting each from a pseudo-peripheral
    // (minimum degree) unvisited vertex.
    let mut by_degree: Vec<u32> = (0..n as u32).collect();
    by_degree.sort_unstable_by_key(|&v| deg(v as usize));
    for &start in &by_degree {
        if visited[start as usize] {
            continue;
        }
        visited[start as usize] = true;
        queue.push_back(start);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            let mut nbrs: Vec<u32> = a
                .row_indices(u as usize)
                .iter()
                .copied()
                .filter(|&c| c as usize != u as usize && !visited[c as usize])
                .collect();
            nbrs.sort_unstable_by_key(|&v| deg(v as usize));
            for v in nbrs {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    queue.push_back(v);
                }
            }
        }
    }
    // Reverse (the "R" in RCM) and convert sequence → perm.
    let mut perm = vec![0u32; n];
    for (k, &v) in order.iter().rev().enumerate() {
        perm[v as usize] = k as u32;
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::ordering::perm;

    fn bandwidth(a: &Csr, p: &[u32]) -> usize {
        let mut bw = 0usize;
        for r in 0..a.nrows {
            for &c in a.row_indices(r) {
                let d = (p[r] as i64 - p[c as usize] as i64).unsigned_abs() as usize;
                bw = bw.max(d);
            }
        }
        bw
    }

    #[test]
    fn valid_permutation_on_grid() {
        let l = generators::grid2d(15, 15, generators::Coeff::Uniform, 0);
        let p = rcm(&l.matrix);
        perm::validate(&p).unwrap();
    }

    #[test]
    fn reduces_bandwidth_vs_random() {
        let l = generators::random_connected(300, 300, 3);
        let p_rcm = rcm(&l.matrix);
        let p_rand = crate::rng::Rng::new(1).permutation(300);
        assert!(bandwidth(&l.matrix, &p_rcm) < bandwidth(&l.matrix, &p_rand));
    }

    #[test]
    fn handles_disconnected_graphs() {
        let l = crate::graph::Laplacian::from_edges(6, &[(0, 1, 1.0), (3, 4, 1.0)], "2comp");
        let p = rcm(&l.matrix);
        perm::validate(&p).unwrap();
    }
}
