//! Deterministic, seeded fault injection for robustness testing.
//!
//! Production serving has failure modes that ordinary tests never
//! exercise: an arena that overflows past the doubling retry, a worker
//! thread that panics mid-wave, a factor whose packed values went
//! non-finite, a solve that simply takes too long. This module gives
//! every one of those paths a *deterministic* trigger so the recovery
//! machinery (degrade-and-retry, panic quarantine, deadlines — see
//! [`crate::serve`]) is a tested contract instead of a hope.
//!
//! ## Design constraints
//!
//! The plane must be invisible when disabled. Every probe compiles to a
//! **single relaxed atomic load** ([`active`]) on the disabled path —
//! no lock, no allocation, no branch on shared mutable state — so the
//! crate's alloc-free and bit-identity contracts are untouched by the
//! mere existence of the instrumentation. Only when a plan is installed
//! does a probe take the `#[cold]` slow path that consults the
//! schedule.
//!
//! ## The `PARAC_FAULTS` grammar
//!
//! A fault *plan* is a comma-separated list of `key=value` items:
//!
//! * `seed=<u64>` — seeds the per-site phase offsets (default 0).
//! * `latency-us=<u64>` — duration injected by each fired
//!   `solve-latency` fault (default 1000µs).
//! * `<site>=<N>` — arm the named site to fire every `N`-th probe
//!   (`N ≥ 1`), at a seed-derived phase. Site names:
//!   `arena-overflow`, `gpusim-workspace-overflow`,
//!   `nan-packed-values`, `worker-panic`, `solve-latency`.
//!
//! The strings `off` and `` (empty) mean "no plan". Example:
//!
//! ```text
//! PARAC_FAULTS=seed=7,worker-panic=50,arena-overflow=100,latency-us=2000,solve-latency=25
//! ```
//!
//! Plans are installed process-wide ([`install_spec`]) — either from
//! the environment at the first `SolverBuilder::build` ([`init_from_env`])
//! or explicitly via `SolverBuilder::faults`. Because the plane is
//! global, tests that install plans must not run concurrently with
//! other tests that assume a quiet plane (the chaos suite runs under
//! `--test-threads=1` for exactly this reason).
//!
//! ## Determinism
//!
//! A site armed with period `N` under seed `s` fires on probe counts
//! `c` where `c % N == phase(s, site)` — a pure function of the plan
//! and the number of probes so far. Single-threaded runs replay
//! exactly; multi-threaded runs keep the *number* of fired faults per
//! site deterministic for a fixed probe count even though which thread
//! observes each firing may vary.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// Named fault sites — each one maps to a single probe point in the
/// production code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// The CPU engine's bump arena reports exhaustion even though
    /// capacity remains (probed in `factor::SymbolicFactor`): exercises
    /// the escaped-`ArenaFull` degrade path.
    ArenaOverflow,
    /// The gpusim engine's slot workspace reports exhaustion
    /// (same probe point, distinct typed error): exercises the escaped
    /// `WorkspaceFull` degrade path.
    WorkspaceOverflow,
    /// Poison one packed factor value with NaN after a successful
    /// numeric phase: exercises the non-finite-factor detection and
    /// quarantine/rebuild path.
    NanPackedValues,
    /// Panic inside a worker-pool job (probed in `par::WorkerPool::run`
    /// part 0): exercises panic quarantine at the serve leader boundary.
    WorkerPanic,
    /// Sleep at PCG solve entry: exercises deadline shedding.
    SolveLatency,
}

/// Number of sites (array sizing).
const NSITES: usize = 5;

impl Site {
    /// The site's name in the `PARAC_FAULTS` grammar.
    pub fn name(self) -> &'static str {
        match self {
            Site::ArenaOverflow => "arena-overflow",
            Site::WorkspaceOverflow => "gpusim-workspace-overflow",
            Site::NanPackedValues => "nan-packed-values",
            Site::WorkerPanic => "worker-panic",
            Site::SolveLatency => "solve-latency",
        }
    }

    /// All sites, in index order.
    pub const ALL: [Site; NSITES] = [
        Site::ArenaOverflow,
        Site::WorkspaceOverflow,
        Site::NanPackedValues,
        Site::WorkerPanic,
        Site::SolveLatency,
    ];

    #[inline]
    fn index(self) -> usize {
        match self {
            Site::ArenaOverflow => 0,
            Site::WorkspaceOverflow => 1,
            Site::NanPackedValues => 2,
            Site::WorkerPanic => 3,
            Site::SolveLatency => 4,
        }
    }
}

/// A parsed fault schedule: which sites are armed, how often each
/// fires, and with what phase offset.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for the per-site phase offsets.
    pub seed: u64,
    /// Duration injected per fired [`Site::SolveLatency`] fault.
    pub latency: Duration,
    /// Per-site firing period; 0 = site disarmed.
    pub period: [u64; NSITES],
    /// Per-site phase: the site fires when `probe_count % period == phase`.
    pub phase: [u64; NSITES],
    /// The spec string this plan was parsed from (idempotence check).
    pub spec: String,
}

/// splitmix64 — the standard 64-bit finalizer; good avalanche, tiny.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// Parse a `PARAC_FAULTS` spec. Returns `Ok(None)` for `off` /
    /// empty (no plan), `Ok(Some(plan))` for a valid spec, and a
    /// human-readable error otherwise.
    pub fn parse(spec: &str) -> Result<Option<FaultPlan>, String> {
        let trimmed = spec.trim();
        if trimmed.is_empty() || trimmed == "off" {
            return Ok(None);
        }
        let mut seed = 0u64;
        let mut latency_us = 1000u64;
        let mut period = [0u64; NSITES];
        let mut armed = false;
        for item in trimmed.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (key, value) = item
                .split_once('=')
                .ok_or_else(|| format!("fault item '{item}' is not key=value"))?;
            let num: u64 = value
                .trim()
                .parse()
                .map_err(|_| format!("fault item '{item}': '{value}' is not a u64"))?;
            match key.trim() {
                "seed" => seed = num,
                "latency-us" => latency_us = num,
                other => {
                    let site = Site::ALL
                        .iter()
                        .find(|s| s.name() == other)
                        .ok_or_else(|| format!("unknown fault site '{other}'"))?;
                    if num == 0 {
                        return Err(format!("site '{other}': period must be >= 1"));
                    }
                    period[site.index()] = num;
                    armed = true;
                }
            }
        }
        if !armed {
            return Err("fault spec arms no site (use 'off' to disable)".into());
        }
        let mut phase = [0u64; NSITES];
        for i in 0..NSITES {
            if period[i] > 0 {
                phase[i] = splitmix64(seed ^ (i as u64 + 1)) % period[i];
            }
        }
        Ok(Some(FaultPlan {
            seed,
            latency: Duration::from_micros(latency_us),
            period,
            phase,
            spec: trimmed.to_string(),
        }))
    }

    /// Whether a site fires at a given (zero-based) probe count — the
    /// pure schedule function, exposed for tests.
    pub fn fires_at(&self, site: Site, probe_count: u64) -> bool {
        let i = site.index();
        self.period[i] > 0 && probe_count % self.period[i] == self.phase[i]
    }
}

/// Fast-path gate: false ⇒ every probe is a single relaxed load.
static ACTIVE: AtomicBool = AtomicBool::new(false);
/// The installed plan (slow path only).
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

/// Per-site probe counters (how many times the site was consulted).
static PROBED: [AtomicU64; NSITES] = [const { AtomicU64::new(0) }; NSITES];
/// Per-site fired counters (how many probes actually injected a fault).
static FIRED: [AtomicU64; NSITES] = [const { AtomicU64::new(0) }; NSITES];

/// Whether any fault plan is installed. This is the whole cost of a
/// disabled probe: one relaxed atomic load.
#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Probe a site: returns `true` if the schedule says this probe should
/// inject its fault. Disabled plane ⇒ one relaxed load, `false`.
#[inline]
pub fn should_fire(site: Site) -> bool {
    if !active() {
        return false;
    }
    fire_slow(site)
}

#[cold]
fn fire_slow(site: Site) -> bool {
    let plan = PLAN.lock().unwrap_or_else(|p| p.into_inner());
    let plan = match plan.as_ref() {
        Some(p) => p,
        None => return false,
    };
    let i = site.index();
    if plan.period[i] == 0 {
        return false;
    }
    let count = PROBED[i].fetch_add(1, Ordering::Relaxed);
    let fire = count % plan.period[i] == plan.phase[i];
    if fire {
        FIRED[i].fetch_add(1, Ordering::Relaxed);
    }
    fire
}

/// Combined probe for [`Site::SolveLatency`]: `Some(duration)` when the
/// fault fires. Disabled plane ⇒ one relaxed load, `None`.
#[inline]
pub fn latency_fault() -> Option<Duration> {
    if !active() {
        return None;
    }
    latency_slow()
}

#[cold]
fn latency_slow() -> Option<Duration> {
    let d = {
        let plan = PLAN.lock().unwrap_or_else(|p| p.into_inner());
        plan.as_ref()?.latency
    };
    if fire_slow(Site::SolveLatency) {
        Some(d)
    } else {
        None
    }
}

/// How many times a site has fired since the last [`install`].
pub fn fired(site: Site) -> u64 {
    FIRED[site.index()].load(Ordering::Relaxed)
}

/// How many times a site has been probed since the last [`install`].
pub fn probed(site: Site) -> u64 {
    PROBED[site.index()].load(Ordering::Relaxed)
}

/// Install a plan (or clear with `None`), resetting all counters.
pub fn install(plan: Option<FaultPlan>) {
    let mut guard = PLAN.lock().unwrap_or_else(|p| p.into_inner());
    for i in 0..NSITES {
        PROBED[i].store(0, Ordering::Relaxed);
        FIRED[i].store(0, Ordering::Relaxed);
    }
    ACTIVE.store(plan.is_some(), Ordering::Relaxed);
    *guard = plan;
}

/// Parse and install a spec. Idempotent: re-installing the spec string
/// that is already active leaves the plan *and its counters* untouched,
/// so repeated `SolverBuilder::build` calls carrying the same `faults`
/// knob (e.g. the serve cache's cloned builders) don't reset the
/// schedule mid-soak.
pub fn install_spec(spec: &str) -> Result<(), String> {
    {
        let guard = PLAN.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(active_plan) = guard.as_ref() {
            if active_plan.spec == spec.trim() {
                return Ok(());
            }
        }
    }
    let plan = FaultPlan::parse(spec)?;
    install(plan);
    Ok(())
}

/// Read `PARAC_FAULTS` once per process and install it. Subsequent
/// calls return the cached outcome without touching the environment,
/// so an explicit [`install_spec`] is never clobbered by a later
/// builder consulting the env.
pub fn init_from_env() -> Result<(), String> {
    static ENV: OnceLock<Result<(), String>> = OnceLock::new();
    ENV.get_or_init(|| {
        match std::env::var("PARAC_FAULTS") {
            Ok(spec) => match FaultPlan::parse(&spec) {
                Ok(Some(plan)) => {
                    install(Some(plan));
                    Ok(())
                }
                Ok(None) => Ok(()),
                Err(e) => Err(e),
            },
            Err(_) => Ok(()),
        }
    })
    .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these tests exercise only the *pure* pieces (parsing and
    // the schedule function). Installing a global plan here would race
    // the rest of the parallel test suite; install-based coverage lives
    // in `rust/tests/chaos.rs`, which runs single-threaded.

    #[test]
    fn off_and_empty_mean_no_plan() {
        assert_eq!(FaultPlan::parse("off").unwrap(), None);
        assert_eq!(FaultPlan::parse("").unwrap(), None);
        assert_eq!(FaultPlan::parse("  off  ").unwrap(), None);
    }

    #[test]
    fn parses_full_spec() {
        let p = FaultPlan::parse("seed=7,worker-panic=50,latency-us=2000,solve-latency=25")
            .unwrap()
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.latency, Duration::from_micros(2000));
        assert_eq!(p.period[Site::WorkerPanic.index()], 50);
        assert_eq!(p.period[Site::SolveLatency.index()], 25);
        assert_eq!(p.period[Site::ArenaOverflow.index()], 0);
        // Phase is always within the period.
        assert!(p.phase[Site::WorkerPanic.index()] < 50);
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("worker-panic").is_err()); // no '='
        assert!(FaultPlan::parse("worker-panic=x").is_err()); // not a u64
        assert!(FaultPlan::parse("no-such-site=3").is_err()); // unknown site
        assert!(FaultPlan::parse("worker-panic=0").is_err()); // period 0
        assert!(FaultPlan::parse("seed=3").is_err()); // arms nothing
    }

    #[test]
    fn schedule_is_deterministic_and_periodic() {
        let p = FaultPlan::parse("seed=42,arena-overflow=10").unwrap().unwrap();
        let fires: Vec<u64> = (0..100).filter(|&c| p.fires_at(Site::ArenaOverflow, c)).collect();
        assert_eq!(fires.len(), 10, "period 10 over 100 probes fires 10 times");
        for w in fires.windows(2) {
            assert_eq!(w[1] - w[0], 10);
        }
        // Same spec ⇒ same schedule; different seed ⇒ (generally) a
        // different phase. Disarmed sites never fire.
        let q = FaultPlan::parse("seed=42,arena-overflow=10").unwrap().unwrap();
        assert_eq!(p, q);
        assert!((0..100).all(|c| !p.fires_at(Site::WorkerPanic, c)));
    }

    #[test]
    fn site_names_roundtrip() {
        for s in Site::ALL {
            let spec = format!("{}=3", s.name());
            let p = FaultPlan::parse(&spec).unwrap().unwrap();
            assert_eq!(p.period[s.index()], 3, "{}", s.name());
        }
    }
}
