//! Factorization statistics — the instrumentation behind the paper's
//! stage breakdown (§5.1) and the §Perf iteration log in EXPERIMENTS.md.

use crate::sparse::Precision;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};

/// Snapshot of one factorization run.
#[derive(Clone, Debug, Default)]
pub struct FactorStats {
    /// Fill edges sampled (Schur-complement spanning-tree edges).
    pub fills: u64,
    /// Entries written to the output factor.
    pub out_entries: u64,
    /// Peak occupancy of the **fill workspace**, in nodes/slots — the
    /// number the `arena_factor` sizing knob has to cover. Engines
    /// report the same semantic from their respective structures: the
    /// cpu engine's bump-allocated fill arena never frees, so its
    /// watermark *is* the peak; the gpusim engine reports the
    /// high-water mark of occupied slots in the hash workspace `W`
    /// (slots are freed on gather, so peak < total fills there). The
    /// seq engine has no shared fill workspace and reports 0.
    pub arena_used: usize,
    /// gpusim only: worst linear-probe distance observed in the
    /// workspace hash map.
    pub max_probe: u64,
    /// gpusim only: total probe steps (insert + gather).
    pub probe_steps: u64,
    /// Time (ns) in stage 1 — gather + merge fill-ins.
    pub stage_gather_ns: u64,
    /// Time (ns) in stage 2 — weight sort + sampling.
    pub stage_sample_ns: u64,
    /// Time (ns) in stage 3 — Schur update + dependency/queue work.
    pub stage_update_ns: u64,
    /// Worker threads (or simulated blocks) used.
    pub workers: usize,
    /// Wall-clock seconds of the engine run (excludes ordering +
    /// permutation).
    pub wall_secs: f64,
    /// Wall-clock seconds of the symbolic phase (ordering, permutation,
    /// workspace sizing). Zero when the run reused a frozen symbolic
    /// factorization (`Solver::refactorize`).
    pub symbolic_secs: f64,
    /// Wall-clock seconds of the numeric phase (the randomized
    /// elimination sweep itself, including value refresh).
    pub numeric_secs: f64,
    /// `true` when this run skipped the symbolic phase entirely and
    /// reused a frozen pattern (ordering, etree, workspaces).
    pub symbolic_reused: bool,
    /// The value-storage plane the preconditioner built on this factor
    /// packs in (`F64` unless a `SolverBuilder::precision` /
    /// `PARAC_PRECISION` override selected f32). The factorization
    /// itself always runs in f64; this records what the apply streams.
    pub precision: Precision,
}

impl FactorStats {
    /// Pretty one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "fills={} out={} workers={} wall={:.1}ms stages(g/s/u)={:.0}/{:.0}/{:.0}ms probes(max={})",
            self.fills,
            self.out_entries,
            self.workers,
            self.wall_secs * 1e3,
            self.stage_gather_ns as f64 / 1e6,
            self.stage_sample_ns as f64 / 1e6,
            self.stage_update_ns as f64 / 1e6,
            self.max_probe,
        )
    }
}

/// Thread-shared accumulator the engines update with relaxed atomics.
#[derive(Default)]
pub struct StatsCollector {
    /// See [`FactorStats::fills`].
    pub fills: AtomicU64,
    /// See [`FactorStats::out_entries`].
    pub out_entries: AtomicU64,
    /// See [`FactorStats::arena_used`].
    pub arena_used: AtomicUsize,
    /// See [`FactorStats::max_probe`].
    pub max_probe: AtomicU64,
    /// See [`FactorStats::probe_steps`].
    pub probe_steps: AtomicU64,
    /// See [`FactorStats::stage_gather_ns`].
    pub stage_gather_ns: AtomicU64,
    /// See [`FactorStats::stage_sample_ns`].
    pub stage_sample_ns: AtomicU64,
    /// See [`FactorStats::stage_update_ns`].
    pub stage_update_ns: AtomicU64,
}

impl StatsCollector {
    /// Raise `max_probe` to at least `p`.
    pub fn probe_max(&self, p: u64) {
        self.max_probe.fetch_max(p, Relaxed);
    }

    /// Finalize into a snapshot. The symbolic/numeric split is filled
    /// in by the caller (the engines only see the numeric phase).
    pub fn snapshot(&self, workers: usize, wall_secs: f64) -> FactorStats {
        FactorStats {
            fills: self.fills.load(Relaxed),
            out_entries: self.out_entries.load(Relaxed),
            arena_used: self.arena_used.load(Relaxed),
            max_probe: self.max_probe.load(Relaxed),
            probe_steps: self.probe_steps.load(Relaxed),
            stage_gather_ns: self.stage_gather_ns.load(Relaxed),
            stage_sample_ns: self.stage_sample_ns.load(Relaxed),
            stage_update_ns: self.stage_update_ns.load(Relaxed),
            workers,
            wall_secs,
            symbolic_secs: 0.0,
            numeric_secs: wall_secs,
            symbolic_reused: false,
            precision: Precision::default(),
        }
    }

    /// Zero every counter so the collector can be reused for another run.
    pub fn reset(&self) {
        self.fills.store(0, Relaxed);
        self.out_entries.store(0, Relaxed);
        self.arena_used.store(0, Relaxed);
        self.max_probe.store(0, Relaxed);
        self.probe_steps.store(0, Relaxed);
        self.stage_gather_ns.store(0, Relaxed);
        self.stage_sample_ns.store(0, Relaxed);
        self.stage_update_ns.store(0, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_snapshot() {
        let c = StatsCollector::default();
        c.fills.fetch_add(10, Relaxed);
        c.probe_max(5);
        c.probe_max(3);
        let s = c.snapshot(4, 0.5);
        assert_eq!(s.fills, 10);
        assert_eq!(s.max_probe, 5);
        assert_eq!(s.workers, 4);
        assert!(s.summary().contains("fills=10"));
    }
}
