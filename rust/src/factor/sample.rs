//! SampleClique — Algorithm 2, the heart of the randomized factorization.
//!
//! Given the merged neighbors of the pivot `k` (pairs `(vertex, w)` with
//! `w = -ℓ_kv > 0`), the classical Schur complement would create the full
//! clique `w_i·w_j / ℓ_kk` over all pairs. AC instead samples a *spanning
//! structure*: process neighbors in ascending-weight order; at position
//! `i`, draw one partner `j > i` with probability `w_j / Σ_{t>i} w_t` and
//! assign the edge weight `w_i · Σ_{t>i} w_t / ℓ_kk`. Every clique pair's
//! expectation is preserved: `E[w(i,j)] = w_i·w_j / ℓ_kk`.
//!
//! Sampling uses inverse-CDF binary search over the prefix-sum array —
//! the same primitive the paper's GPU kernel evaluates with a parallel
//! block search, and the computation the Pallas kernel
//! (`python/compile/kernels/sample_clique.py`) reproduces batched.
//!
//! Determinism: ties in the weight sort are broken by vertex id and the
//! RNG stream is derived from `(seed, pivot)` — so every engine (seq /
//! cpu / gpusim / PJRT-offloaded) produces the same samples.

use crate::rng::Rng;

/// Derive the sampling RNG for a pivot vertex. All engines must use this
/// so factors are engine-independent.
#[inline]
pub fn pivot_rng(seed: u64, pivot: u32) -> Rng {
    Rng::stream(seed, 0x5A3F_0000_0000_0000 | pivot as u64)
}

/// Sort merged neighbors `(vertex, w)` ascending by `(w, vertex)` —
/// the paper's quality-improving elimination order within a pivot.
#[inline]
pub fn sort_by_weight(nbrs: &mut [(u32, f64)]) {
    nbrs.sort_unstable_by(|a, b| {
        a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
    });
}

/// Run Algorithm 2 over merged neighbors (weights positive). `nbrs` must
/// already be in the desired processing order (sorted by weight unless
/// running the no-sort ablation). `cum` is scratch for prefix sums
/// (resized as needed). Emits `(vertex_i, vertex_j, new_weight)` for each
/// sampled fill edge — `m − 1` edges for `m` neighbors.
pub fn sample_clique(
    nbrs: &[(u32, f64)],
    cum: &mut Vec<f64>,
    rng: &mut Rng,
    mut emit: impl FnMut(u32, u32, f64),
) {
    let m = nbrs.len();
    if m < 2 {
        return;
    }
    // Inclusive prefix sums: cum[t] = w_0 + … + w_t.
    cum.clear();
    cum.reserve(m);
    let mut acc = 0.0;
    for &(_, w) in nbrs {
        debug_assert!(w > 0.0, "neighbor weights must be positive");
        acc += w;
        cum.push(acc);
    }
    let total = acc; // = ℓ_kk
    for i in 0..m - 1 {
        let below = cum[i]; // Σ_{t ≤ i} w_t
        let rest = total - below; // Σ_{t > i} w_t
        if rest <= 0.0 {
            break; // numerically exhausted tail
        }
        // Inverse-CDF draw over the suffix (i, m): u ∈ [below, total).
        let u = below + rng.next_f64() * rest;
        let j = partition_point(cum, u).min(m - 1).max(i + 1);
        let w_new = nbrs[i].1 * rest / total;
        emit(nbrs[i].0, nbrs[j].0, w_new);
    }
}

/// First index `t` with `cum[t] > u` (binary search — the paper's
/// weight-based parallel search).
#[inline]
fn partition_point(cum: &[f64], u: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = cum.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if cum[mid] <= u {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Merge raw gathered entries `(vertex, w)` in place: sort by
/// `(vertex, w)` (value in the key keeps float summation order — and
/// therefore the factor — schedule-independent), then fold duplicates,
/// summing weights and counting multiplicity. Returns `(merged, mult)`
/// lengths via the output vectors.
pub fn merge_neighbors(
    raw: &mut Vec<(u32, f64)>,
    merged: &mut Vec<(u32, f64)>,
    mult: &mut Vec<u32>,
) {
    raw.sort_unstable_by(|a, b| {
        a.0.cmp(&b.0).then(a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
    });
    merged.clear();
    mult.clear();
    let mut i = 0;
    while i < raw.len() {
        let v = raw[i].0;
        let mut w = raw[i].1;
        let mut c = 1u32;
        let mut j = i + 1;
        while j < raw.len() && raw[j].0 == v {
            w += raw[j].1;
            c += 1;
            j += 1;
        }
        merged.push((v, w));
        mult.push(c);
        i = j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::forall_rngs;

    #[test]
    fn emits_m_minus_1_edges() {
        let nbrs: Vec<(u32, f64)> = (0..10).map(|i| (i as u32, 1.0 + i as f64)).collect();
        let mut cum = Vec::new();
        let mut rng = Rng::new(1);
        let mut count = 0;
        sample_clique(&nbrs, &mut cum, &mut rng, |_, _, _| count += 1);
        assert_eq!(count, 9);
    }

    #[test]
    fn partner_always_later_in_order() {
        forall_rngs(64, |rng| {
            let m = 2 + rng.below(30);
            let mut nbrs: Vec<(u32, f64)> =
                (0..m).map(|i| (i as u32, rng.range_f64(0.1, 10.0))).collect();
            sort_by_weight(&mut nbrs);
            let pos: std::collections::HashMap<u32, usize> =
                nbrs.iter().enumerate().map(|(p, &(v, _))| (v, p)).collect();
            let mut cum = Vec::new();
            let mut bad = None;
            sample_clique(&nbrs, &mut cum, rng, |i, j, w| {
                if pos[&j] <= pos[&i] || w <= 0.0 {
                    bad = Some(format!("edge ({i},{j},{w})"));
                }
            });
            bad.map_or(Ok(()), Err)
        });
    }

    #[test]
    fn expectation_matches_clique() {
        // Pair (i,j) expectation must equal w_i w_j / total. Use 3
        // neighbors and many trials.
        let nbrs = vec![(0u32, 1.0), (1u32, 2.0), (2u32, 3.0)];
        let total = 6.0;
        let trials = 200_000;
        let mut sums = std::collections::HashMap::new();
        for t in 0..trials {
            let mut rng = Rng::new(1000 + t);
            let mut cum = Vec::new();
            sample_clique(&nbrs, &mut cum, &mut rng, |i, j, w| {
                *sums.entry((i.min(j), i.max(j))).or_insert(0.0) += w;
            });
        }
        for (&(i, j), &s) in &sums {
            let want = nbrs[i as usize].1 * nbrs[j as usize].1 / total;
            let got = s / trials as f64;
            assert!(
                (got - want).abs() < 0.02 * want.max(0.1),
                "pair ({i},{j}): got {got}, want {want}"
            );
        }
        // Total expectation over all pairs = Σ_{i<j} w_i w_j / total.
        let want_total: f64 = (1.0 * 2.0 + 1.0 * 3.0 + 2.0 * 3.0) / total;
        let got_total: f64 = sums.values().sum::<f64>() / trials as f64;
        assert!((got_total - want_total).abs() < 0.02 * want_total);
    }

    #[test]
    fn sampled_weights_conserve_tail_mass() {
        // Each step i emits exactly w_i · rest / total; sum over i is a
        // fixed deterministic quantity independent of the random draws.
        forall_rngs(32, |rng| {
            let m = 2 + rng.below(20);
            let mut nbrs: Vec<(u32, f64)> =
                (0..m).map(|i| (i as u32, rng.range_f64(0.1, 5.0))).collect();
            sort_by_weight(&mut nbrs);
            let total: f64 = nbrs.iter().map(|x| x.1).sum();
            let mut cum = Vec::new();
            let mut got = 0.0;
            sample_clique(&nbrs, &mut cum, rng, |_, _, w| got += w);
            let mut below = 0.0;
            let mut want = 0.0;
            for t in 0..m - 1 {
                below += nbrs[t].1;
                want += nbrs[t].1 * (total - below) / total;
            }
            if (got - want).abs() > 1e-9 * want.max(1.0) {
                return Err(format!("mass {got} vs {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn merge_sums_and_counts() {
        let mut raw = vec![(3u32, 1.0), (1u32, 2.0), (3u32, 0.5), (1u32, 1.0), (2u32, 4.0)];
        let mut merged = Vec::new();
        let mut mult = Vec::new();
        merge_neighbors(&mut raw, &mut merged, &mut mult);
        assert_eq!(merged, vec![(1, 3.0), (2, 4.0), (3, 1.5)]);
        assert_eq!(mult, vec![2, 1, 2]);
    }

    #[test]
    fn deterministic_per_pivot_rng() {
        let nbrs = vec![(5u32, 1.0), (9u32, 2.0), (11u32, 0.5), (2u32, 4.0)];
        let run = || {
            let mut r = pivot_rng(42, 17);
            let mut cum = Vec::new();
            let mut out = Vec::new();
            sample_clique(&nbrs, &mut cum, &mut r, |i, j, w| out.push((i, j, w)));
            out
        };
        assert_eq!(run(), run());
        let mut r2 = pivot_rng(42, 18);
        let mut cum = Vec::new();
        let mut out2 = Vec::new();
        sample_clique(&nbrs, &mut cum, &mut r2, |i, j, w| out2.push((i, j, w)));
        assert_ne!(run(), out2);
    }

    #[test]
    fn degenerate_inputs() {
        let mut cum = Vec::new();
        let mut rng = Rng::new(0);
        let mut n = 0;
        sample_clique(&[], &mut cum, &mut rng, |_, _, _| n += 1);
        sample_clique(&[(0, 1.0)], &mut cum, &mut rng, |_, _, _| n += 1);
        assert_eq!(n, 0);
    }
}
