//! Parallel left-looking CPU engine — Algorithm 3.
//!
//! Workers claim positions in the dynamic job queue and eliminate ready
//! vertices. Per elimination:
//!
//! 1. **Gather** (left-looking): read the vertex's original higher
//!    neighbors from the input CSR and traverse its lock-free fill list,
//!    then merge duplicates (sorted by `(row, val)` so float summation —
//!    and therefore the factor — is schedule-independent).
//! 2. **Sample**: sort merged neighbors by weight, run SampleClique with
//!    the per-vertex RNG stream.
//! 3. **Update**: push each sampled edge onto the smaller endpoint's
//!    fill list (atomic-exchange push into the shared bump arena),
//!    increment `dp[larger]`, then cut this vertex's edges
//!    (`dp[v] -= multiplicity`) and enqueue anything that hit zero.
//!
//! Memory: one shared fill arena and one shared output arena, both
//! bump-allocated (§5.2.1) — no malloc, no locks on the hot path.
//!
//! Workers run on the persistent [`crate::par`] pool (one pool job per
//! factorization, each part executing the worker loop) instead of
//! spawning scoped OS threads per call.

use super::chunk::{Bump, FillArena, SharedBuf, NIL};
use super::depend::DepCounts;
use super::queue::JobQueue;
use super::sample;
use super::stats::{FactorStats, StatsCollector};
use super::symbolic::{EngineScratch, FactorBufs};
use super::FactorError;
use crate::sparse::{Csc, Csr};
use crate::util::{default_threads, Timer};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Reusable working state of the CPU engine: the shared arenas, queue,
/// dependency counters, and per-worker elimination scratch. Everything
/// is interior-mutable, so a factorization borrows the workspace
/// immutably and `reset` rewinds it for the next run without touching
/// the allocator.
pub struct CpuWorkspace {
    fills: FillArena,
    heads: Box<[AtomicUsize]>,
    out_rows: SharedBuf<u32>,
    out_vals: SharedBuf<f64>,
    out_bump: Bump,
    col_meta: SharedBuf<(usize, u32)>,
    diag: SharedBuf<f64>,
    dp: DepCounts,
    queue: JobQueue,
    stats: StatsCollector,
    /// Per-part elimination scratch (part index ← the pool dispatch);
    /// uncontended mutexes, locked once per worker run.
    scratch: Box<[Mutex<EngineScratch>]>,
    threads: usize,
    cap_fill: usize,
}

impl CpuWorkspace {
    /// Workspace sized for `a` with `threads` workers (0 = auto) and the
    /// given fill-arena capacity multiplier.
    pub fn new(a: &Csr, threads: usize, arena_factor: f64) -> CpuWorkspace {
        let n = a.nrows;
        let pool = crate::par::global();
        let threads = if threads == 0 { default_threads() } else { threads }
            .max(1)
            .min(n.max(1))
            .min(pool.size());
        let cap_fill = ((arena_factor * (a.nnz() + n) as f64) as usize).max(64);
        // Output: every merged column entry; bounded by original lower
        // triangle + every fill node.
        let cap_out = a.nnz() / 2 + cap_fill + n;
        let (dp, _ready) = DepCounts::init(a);
        let mut heads = Vec::with_capacity(n);
        heads.resize_with(n, || AtomicUsize::new(NIL));
        let mut scratch = Vec::with_capacity(threads);
        scratch.resize_with(threads, || Mutex::new(EngineScratch::new()));
        CpuWorkspace {
            fills: FillArena::new(cap_fill),
            heads: heads.into_boxed_slice(),
            out_rows: SharedBuf::new(cap_out),
            out_vals: SharedBuf::new(cap_out),
            out_bump: Bump::new(cap_out),
            col_meta: SharedBuf::new(n),
            diag: SharedBuf::new(n),
            dp,
            queue: JobQueue::new(n),
            stats: StatsCollector::default(),
            scratch: scratch.into_boxed_slice(),
            threads,
            cap_fill,
        }
    }

    /// Worker count the workspace was resolved to.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Rewind every shared structure and re-derive the dependency
    /// counters + initial ready set from `a` — allocation-free.
    fn reset(&self, a: &Csr) {
        self.queue.reset();
        self.dp.reinit(a, |v| self.queue.push(v));
        for h in self.heads.iter() {
            h.store(NIL, Ordering::Relaxed);
        }
        self.fills.reset();
        self.out_bump.reset();
        self.stats.reset();
    }
}

/// Shared engine state (borrowed by every worker).
struct Shared<'a> {
    a: &'a Csr,
    ws: &'a CpuWorkspace,
    seed: u64,
    sort_by_weight: bool,
    timing: bool,
}

/// Factor a (permuted) Laplacian CSR with `threads` workers (0 = auto).
pub fn factorize_csr(
    a: &Csr,
    seed: u64,
    sort_by_weight: bool,
    threads: usize,
    arena_factor: f64,
    stage_timing: bool,
) -> Result<(Csc, Vec<f64>, FactorStats), FactorError> {
    let ws = CpuWorkspace::new(a, threads, arena_factor);
    let mut out = FactorBufs::new();
    let stats = factorize_into(a, seed, sort_by_weight, stage_timing, &ws, &mut out)?;
    let (g, diag) = out.take_factor(a.nrows);
    Ok((g, diag, stats))
}

/// [`factorize_csr`] through a reusable workspace into caller-owned
/// output buffers — the numeric phase of the symbolic/numeric split.
/// Allocation-free when the workspace and `out` capacities already fit.
pub fn factorize_into(
    a: &Csr,
    seed: u64,
    sort_by_weight: bool,
    stage_timing: bool,
    ws: &CpuWorkspace,
    out: &mut FactorBufs,
) -> Result<FactorStats, FactorError> {
    let timer = Timer::start();
    let n = a.nrows;
    ws.reset(a);
    let shared = Shared { a, ws, seed, sort_by_weight, timing: stage_timing };

    crate::par::global().run(ws.threads, |part, _parts| worker(&shared, part));

    if ws.queue.is_poisoned() {
        return Err(FactorError::ArenaFull { capacity: ws.cap_fill });
    }
    assemble_into(&shared, n, out);
    Ok(ws.stats.snapshot(ws.threads, timer.secs()))
}

/// Worker loop: claim → spin-wait → eliminate.
fn worker(sh: &Shared<'_>, part: usize) {
    let mut scratch =
        sh.ws.scratch[part].lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let EngineScratch { raw, merged, mult, bysort, cum } = &mut *scratch;
    let mut gather_ns = 0u64;
    let mut sample_ns = 0u64;
    let mut update_ns = 0u64;
    let mut fills_count = 0u64;

    while let Some(pos) = sh.ws.queue.claim() {
        let Ok(k) = sh.ws.queue.wait(pos) else { break };
        let k = k as usize;
        let t0 = sh.timing.then(Instant::now);

        // ---- Stage 1: gather + merge. ----
        raw.clear();
        for (&c, &v) in sh.a.row_indices(k).iter().zip(sh.a.row_data(k)) {
            if (c as usize) > k && v < 0.0 {
                raw.push((c, -v));
            }
        }
        let mut node = sh.ws.heads[k].load(Ordering::Acquire);
        while node != NIL {
            // SAFETY: node was fully written before being published to
            // this list, and all pushes happen-before this elimination
            // (dependency counters + queue release/acquire).
            unsafe {
                raw.push((sh.ws.fills.rows.read(node), sh.ws.fills.vals.read(node)));
            }
            node = sh.ws.fills.next[node].load(Ordering::Relaxed);
        }
        if raw.is_empty() {
            unsafe {
                sh.ws.diag.write(k, 0.0);
                sh.ws.col_meta.write(k, (0, 0));
            }
            if let Some(t0) = t0 {
                gather_ns += t0.elapsed().as_nanos() as u64;
            }
            continue;
        }
        sample::merge_neighbors(raw, merged, mult);
        let lkk: f64 = merged.iter().map(|x| x.1).sum();
        // Output column (merged is row-sorted).
        let Some(start) = sh.ws.out_bump.alloc(merged.len()) else {
            sh.ws.queue.poison();
            break;
        };
        for (t, &(r, w)) in merged.iter().enumerate() {
            // SAFETY: [start, start+len) was just reserved by this thread.
            unsafe {
                sh.ws.out_rows.write(start + t, r);
                sh.ws.out_vals.write(start + t, -w / lkk);
            }
        }
        unsafe {
            sh.ws.diag.write(k, lkk);
            sh.ws.col_meta.write(k, (start, merged.len() as u32));
        }
        let t1 = sh.timing.then(Instant::now);
        if let (Some(a), Some(b)) = (t0, t1) {
            gather_ns += (b - a).as_nanos() as u64;
        }

        // ---- Stage 2: weight sort + sampling. ----
        bysort.clear();
        bysort.extend_from_slice(merged);
        if sh.sort_by_weight {
            sample::sort_by_weight(bysort);
        }
        let mut rng = sample::pivot_rng(sh.seed, k as u32);
        let nsamples = bysort.len().saturating_sub(1);
        let base = if nsamples > 0 {
            match sh.ws.fills.bump.alloc(nsamples) {
                Some(b) => b,
                None => {
                    sh.ws.queue.poison();
                    break;
                }
            }
        } else {
            0
        };
        let mut emitted = 0usize;
        sample::sample_clique(bysort, cum, &mut rng, |i, j, w| {
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            let idx = base + emitted;
            emitted += 1;
            // SAFETY: idx is inside this thread's reservation.
            unsafe {
                sh.ws.fills.rows.write(idx, hi);
                sh.ws.fills.vals.write(idx, w);
            }
            // Publish: new smaller-neighbor dependency first, then the
            // node itself.
            sh.ws.dp.inc(hi);
            sh.ws.fills.push(&sh.ws.heads[lo as usize], idx);
        });
        fills_count += emitted as u64;
        let t2 = sh.timing.then(Instant::now);
        if let (Some(a), Some(b)) = (t1, t2) {
            sample_ns += (b - a).as_nanos() as u64;
        }

        // ---- Stage 3: cut this vertex's edges, schedule ready ones. ----
        for (&(v, _), &m) in merged.iter().zip(mult.iter()) {
            if sh.ws.dp.dec(v, m) {
                sh.ws.queue.push(v);
            }
        }
        if let Some(t2) = t2 {
            update_ns += t2.elapsed().as_nanos() as u64;
        }
    }

    let st = &sh.ws.stats;
    st.fills.fetch_add(fills_count, Ordering::Relaxed);
    st.stage_gather_ns.fetch_add(gather_ns, Ordering::Relaxed);
    st.stage_sample_ns.fetch_add(sample_ns, Ordering::Relaxed);
    st.stage_update_ns.fetch_add(update_ns, Ordering::Relaxed);
}

/// Collect the per-column slices into the caller's factor buffers
/// (single-threaded, O(nnz); allocation-free within `out` capacity).
fn assemble_into(sh: &Shared<'_>, n: usize, out: &mut FactorBufs) {
    out.clear();
    out.colptr.push(0usize);
    let mut total = 0usize;
    for k in 0..n {
        // SAFETY: all workers joined; engine writes happen-before.
        let (_, len) = unsafe { sh.ws.col_meta.read(k) };
        total += len as usize;
        out.colptr.push(total);
    }
    for k in 0..n {
        let (start, len) = unsafe { sh.ws.col_meta.read(k) };
        for t in 0..len as usize {
            unsafe {
                out.rowidx.push(sh.ws.out_rows.read(start + t));
                out.data.push(sh.ws.out_vals.read(start + t));
            }
        }
        out.diag.push(unsafe { sh.ws.diag.read(k) });
    }
    sh.ws.stats.out_entries.fetch_add(total as u64, Ordering::Relaxed);
    // `arena_used` is the *fill* arena occupancy; the bump pointer
    // never rewinds within a run, so its watermark is the peak node
    // count — the same semantic the gpusim engine reports from its
    // hash workspace.
    sh.ws.stats.arena_used.store(sh.ws.fills.bump.used(), Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use crate::factor::{factorize, Engine, ParacOptions};
    use crate::graph::generators;
    use crate::ordering::Ordering as Ord;
    use crate::testing::prop::forall_seeds;

    fn opts(engine: Engine, ordering: Ord, seed: u64) -> ParacOptions {
        ParacOptions { engine, ordering, seed, ..Default::default() }
    }

    #[test]
    fn matches_sequential_engine_exactly() {
        // The headline determinism property: cpu(T threads) ≡ seq for
        // any thread count, ordering and seed.
        forall_seeds(4, |seed| {
            let l = generators::random_connected(300, 450, seed);
            for threads in [1, 2, 4] {
                let fs = factorize(&l, &opts(Engine::Seq, Ord::Natural, seed)).unwrap();
                let fc =
                    factorize(&l, &opts(Engine::Cpu { threads }, Ord::Natural, seed)).unwrap();
                if fs.g != fc.g {
                    return Err(format!("G mismatch at {threads} threads"));
                }
                if fs.diag != fc.diag {
                    return Err(format!("D mismatch at {threads} threads"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matches_seq_on_suite_orderings() {
        let l = generators::grid3d(8, 8, 8, generators::Coeff::Uniform, 0);
        for ord in [Ord::Amd, Ord::NnzSort, Ord::Random] {
            let fs = factorize(&l, &opts(Engine::Seq, ord, 11)).unwrap();
            let fc = factorize(&l, &opts(Engine::Cpu { threads: 4 }, ord, 11)).unwrap();
            assert_eq!(fs.g, fc.g, "ordering {ord:?}");
            assert_eq!(fs.diag, fc.diag);
        }
    }

    #[test]
    fn factor_is_valid_on_larger_graph() {
        let l = generators::grid2d(50, 50, generators::Coeff::Uniform, 1);
        let f = factorize(&l, &opts(Engine::Cpu { threads: 4 }, Ord::NnzSort, 5)).unwrap();
        f.validate().unwrap();
        assert_eq!(f.n(), 2500);
        assert!(f.stats.fills > 0);
    }

    #[test]
    fn heavy_tail_graph_parallel() {
        let l = generators::pref_attach(1200, 6, 2);
        let f = factorize(&l, &opts(Engine::Cpu { threads: 4 }, Ord::NnzSort, 3)).unwrap();
        f.validate().unwrap();
        let fs = factorize(&l, &opts(Engine::Seq, Ord::NnzSort, 3)).unwrap();
        assert_eq!(f.g, fs.g);
    }

    #[test]
    fn arena_retry_recovers_from_small_estimate() {
        let l = generators::complete(60); // dense: fills blow past a tiny arena
        let mut o = opts(Engine::Cpu { threads: 4 }, Ord::Natural, 7);
        o.arena_factor = 0.05;
        let f = factorize(&l, &o).unwrap();
        f.validate().unwrap();
    }

    #[test]
    fn disconnected_graph_parallel() {
        let l = crate::graph::Laplacian::from_edges(
            10,
            &[(0, 1, 1.0), (2, 3, 1.0), (4, 5, 1.0), (6, 7, 2.0)],
            "forest",
        );
        let f = factorize(&l, &opts(Engine::Cpu { threads: 4 }, Ord::Natural, 1)).unwrap();
        f.validate().unwrap();
        assert_eq!(f.diag.iter().filter(|&&d| d == 0.0).count(), 6);
    }
}
