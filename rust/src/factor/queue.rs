//! The dynamic job queue (Algorithm 3 line 7 / Algorithm 4 line 7).
//!
//! A fixed array of `n` slots filled monotonically by `push` as vertices
//! become ready. Workers claim positions with a fetch-add cursor and
//! **spin-wait** on their slot until it is filled — exactly the paper's
//! `k ← q[id], spin wait on q[id] if necessary`. Progress is guaranteed
//! because every vertex is eventually enqueued exactly once (dependency
//! counters reach zero along any valid elimination order), so every
//! claimed position `< n` is eventually written.
//!
//! `poison` unblocks all spinners when an engine must abort (arena
//! overflow) — the retry loop in [`super::factorize`] then restarts with
//! a bigger arena.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};

const EMPTY: u32 = u32::MAX;

/// Fixed-size single-use job queue.
pub struct JobQueue {
    slots: Box<[AtomicU32]>,
    tail: AtomicUsize,
    cursor: AtomicUsize,
    poisoned: AtomicBool,
}

impl JobQueue {
    /// Queue for `n` jobs.
    pub fn new(n: usize) -> Self {
        let mut slots = Vec::with_capacity(n);
        slots.resize_with(n, || AtomicU32::new(EMPTY));
        JobQueue {
            slots: slots.into_boxed_slice(),
            tail: AtomicUsize::new(0),
            cursor: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
        }
    }

    /// Enqueue a ready vertex. Each vertex must be pushed at most once.
    #[inline]
    pub fn push(&self, v: u32) {
        let slot = self.tail.fetch_add(1, Ordering::Relaxed);
        debug_assert!(slot < self.slots.len(), "queue overflow: vertex pushed twice?");
        self.slots[slot].store(v, Ordering::Release);
    }

    /// Claim the next position to process; `None` once all positions are
    /// claimed (worker should exit).
    #[inline]
    pub fn claim(&self) -> Option<usize> {
        let pos = self.cursor.fetch_add(1, Ordering::Relaxed);
        if pos < self.slots.len() {
            Some(pos)
        } else {
            None
        }
    }

    /// Spin-wait until position `pos` is filled; `Err(())` if poisoned.
    ///
    /// Backoff ladder: pure spin → `yield_now` → short sleeps. The
    /// paper's GPU blocks spin for free; on an oversubscribed CPU
    /// (threads > cores) unbounded spinning starves the one thread
    /// doing useful work, so waiters progressively get out of the way.
    #[inline]
    pub fn wait(&self, pos: usize) -> Result<u32, ()> {
        let slot = &self.slots[pos];
        let mut spins = 0u32;
        loop {
            let v = slot.load(Ordering::Acquire);
            if v != EMPTY {
                return Ok(v);
            }
            if self.poisoned.load(Ordering::Relaxed) {
                return Err(());
            }
            spins = spins.saturating_add(1);
            if spins < 64 {
                std::hint::spin_loop();
            } else if spins < 256 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
        }
    }

    /// Abort: unblock every spinning worker.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::Relaxed);
    }

    /// Was the queue poisoned?
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Relaxed)
    }

    /// Number of jobs pushed so far.
    pub fn pushed(&self) -> usize {
        self.tail.load(Ordering::Relaxed).min(self.slots.len())
    }

    /// Rewind to a fresh, empty queue for another run. Caller must
    /// guarantee no worker is still claiming or waiting.
    pub fn reset(&self) {
        for slot in self.slots.iter() {
            slot.store(EMPTY, Ordering::Relaxed);
        }
        self.tail.store(0, Ordering::Relaxed);
        self.cursor.store(0, Ordering::Relaxed);
        self.poisoned.store(false, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_single_thread() {
        let q = JobQueue::new(4);
        q.push(7);
        q.push(3);
        let p0 = q.claim().unwrap();
        let p1 = q.claim().unwrap();
        assert_eq!(q.wait(p0), Ok(7));
        assert_eq!(q.wait(p1), Ok(3));
    }

    #[test]
    fn claim_exhausts() {
        let q = JobQueue::new(2);
        assert!(q.claim().is_some());
        assert!(q.claim().is_some());
        assert!(q.claim().is_none());
    }

    #[test]
    fn poison_unblocks_waiters() {
        let q = JobQueue::new(2);
        let pos = q.claim().unwrap();
        std::thread::scope(|s| {
            let h = s.spawn(|| q.wait(pos));
            std::thread::sleep(std::time::Duration::from_millis(5));
            q.poison();
            assert_eq!(h.join().unwrap(), Err(()));
        });
    }

    #[test]
    fn concurrent_producers_consumers() {
        let n = 10_000;
        let q = JobQueue::new(n);
        let seen = (0..n).map(|_| AtomicU32::new(0)).collect::<Vec<_>>();
        std::thread::scope(|s| {
            // 4 producers push disjoint ranges.
            for t in 0..4 {
                let q = &q;
                s.spawn(move || {
                    for v in (t..n).step_by(4) {
                        q.push(v as u32);
                    }
                });
            }
            // 4 consumers claim+wait.
            for _ in 0..4 {
                let q = &q;
                let seen = &seen;
                s.spawn(move || {
                    while let Some(pos) = q.claim() {
                        let v = q.wait(pos).unwrap();
                        seen[v as usize].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        for (i, c) in seen.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "vertex {i}");
        }
    }
}
