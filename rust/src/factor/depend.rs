//! Dynamic dependency tracking (§4.2) — the enabling idea of ParAC.
//!
//! `dp[i]` counts the multigraph edges from **live smaller-labeled**
//! neighbors of `i`. A vertex is ready exactly when `dp[i] == 0`. During
//! elimination of `k`:
//! * every sampled fill `(i, j)` adds a live edge: `inc(max(i,j))`;
//! * finishing `k` cuts its incident edges: `dec(v, multiplicity)` for
//!   each merged neighbor `v`.
//!
//! Increments must precede the eliminator's own decrements (engines do
//! this within each elimination) so `dp` can never transiently hit zero
//! while a fill that makes `i` depend on a new smaller neighbor is still
//! in flight — the invariant behind deadlock- and race-freedom.

use crate::sparse::Csr;
use std::sync::atomic::{AtomicU32, Ordering};

/// Shared dependency counters.
pub struct DepCounts {
    dp: Box<[AtomicU32]>,
}

impl DepCounts {
    /// Initialize from a (permuted) symmetric matrix: `dp[i] = |{j < i :
    /// ℓ_ij ≠ 0}|`. Returns the counters and the initially-ready set in
    /// ascending order.
    pub fn init(a: &Csr) -> (DepCounts, Vec<u32>) {
        let n = a.nrows;
        let mut ready = Vec::new();
        let mut dp = Vec::with_capacity(n);
        for i in 0..n {
            let count = a
                .row_indices(i)
                .iter()
                .zip(a.row_data(i))
                .filter(|(&c, &v)| (c as usize) < i && v < 0.0)
                .count() as u32;
            if count == 0 {
                ready.push(i as u32);
            }
            dp.push(AtomicU32::new(count));
        }
        (DepCounts { dp: dp.into_boxed_slice() }, ready)
    }

    /// Re-derive the counters from `a` in place (allocation-free
    /// [`DepCounts::init`] for refactorization on a frozen pattern).
    /// Calls `on_ready` for each initially-ready vertex in ascending
    /// order. `a` must have the same dimension the counters were built
    /// with.
    pub fn reinit(&self, a: &Csr, mut on_ready: impl FnMut(u32)) {
        debug_assert_eq!(a.nrows, self.dp.len());
        for i in 0..a.nrows {
            let count = a
                .row_indices(i)
                .iter()
                .zip(a.row_data(i))
                .filter(|(&c, &v)| (c as usize) < i && v < 0.0)
                .count() as u32;
            if count == 0 {
                on_ready(i as u32);
            }
            self.dp[i].store(count, Ordering::Relaxed);
        }
    }

    /// A new fill edge makes `v` depend on one more smaller neighbor.
    #[inline]
    pub fn inc(&self, v: u32) {
        self.dp[v as usize].fetch_add(1, Ordering::AcqRel);
    }

    /// Cut `by` edges into `v`; returns `true` if `v` just became ready.
    #[inline]
    pub fn dec(&self, v: u32, by: u32) -> bool {
        let prev = self.dp[v as usize].fetch_sub(by, Ordering::AcqRel);
        debug_assert!(prev >= by, "dependency count underflow at {v}: {prev} - {by}");
        prev == by
    }

    /// Current count (diagnostics).
    pub fn get(&self, v: u32) -> u32 {
        self.dp[v as usize].load(Ordering::Acquire)
    }

    /// Number of vertices tracked.
    pub fn len(&self) -> usize {
        self.dp.len()
    }

    /// Whether empty.
    pub fn is_empty(&self) -> bool {
        self.dp.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn init_counts_smaller_neighbors() {
        let l = generators::path(5);
        let (dp, ready) = DepCounts::init(&l.matrix);
        assert_eq!(ready, vec![0]);
        assert_eq!(dp.get(0), 0);
        for v in 1..5 {
            assert_eq!(dp.get(v as u32), 1);
        }
    }

    #[test]
    fn star_hub_first_all_ready_after() {
        // Star with hub = 0: every leaf has exactly one smaller neighbor.
        let l = generators::star(6);
        let (dp, ready) = DepCounts::init(&l.matrix);
        assert_eq!(ready, vec![0]);
        for v in 1..6u32 {
            assert!(!dp.dec(v, 1) == false, "leaf {v} becomes ready");
        }
    }

    #[test]
    fn inc_then_dec_balances() {
        let l = generators::path(3);
        let (dp, _) = DepCounts::init(&l.matrix);
        dp.inc(2);
        assert_eq!(dp.get(2), 2);
        assert!(!dp.dec(2, 1));
        assert!(dp.dec(2, 1));
    }

    #[test]
    fn concurrent_inc_dec_consistent() {
        let l = generators::complete(4);
        let (dp, _) = DepCounts::init(&l.matrix);
        // vertex 3 starts with 3 smaller neighbors.
        let rounds = 10_000u32;
        std::thread::scope(|s| {
            for _ in 0..4 {
                let dp = &dp;
                s.spawn(move || {
                    for _ in 0..rounds {
                        dp.inc(3);
                    }
                });
            }
        });
        assert_eq!(dp.get(3), 3 + 4 * rounds);
    }
}
