//! Right-looking GPU-model engine — Algorithm 4 on the simulated
//! persistent-kernel substrate (`crate::gpusim`).
//!
//! One persistent [`crate::par`] pool worker plays one persistent
//! *block* (the pool itself is the CPU stand-in for the paper's
//! resident kernel — workers outlive every factorization instead of
//! being spawned per call): it polls the shared job
//! queue (cyclic claim), eliminates its vertex with block-level
//! primitives (bitonic sort, flag/prefix-sum duplicate merge, CDF
//! search), and pushes right-looking Schur updates into the
//! linear-probing slot-state workspace `W` at
//! `hash(target) + fill_in_count(target)`.
//!
//! Differences from the CPU engine (paper §5.3): fills live in the
//! probing hash map, not per-vertex linked lists ("pointer jumping is
//! unfriendly towards multithreading"), so updates are written *to the
//! target's* storage immediately — right-looking. Dependency tracking,
//! job queue, and sampling are shared, and the produced factor is
//! bit-identical to the other engines.

use super::chunk::{Bump, SharedBuf};
use super::depend::DepCounts;
use super::queue::JobQueue;
use super::sample;
use super::stats::{FactorStats, StatsCollector};
use super::symbolic::{EngineScratch, FactorBufs};
use super::FactorError;
use crate::gpusim::hashmap::{HashKind, Workspace};
use crate::gpusim::primitives;
use crate::sparse::{Csc, Csr};
use crate::util::{default_threads, Timer};
use std::sync::atomic::Ordering;
use std::sync::Mutex;
use std::time::Instant;

/// Reusable working state of the gpusim engine: the slot-state hash
/// workspace `W`, output arenas, queue, dependency counters, and
/// per-block elimination scratch. Interior-mutable like
/// [`super::cpu::CpuWorkspace`]; `reset` rewinds it allocation-free.
pub struct GpuWorkspace {
    w: Workspace,
    out_rows: SharedBuf<u32>,
    out_vals: SharedBuf<f64>,
    out_bump: Bump,
    col_meta: SharedBuf<(usize, u32)>,
    diag: SharedBuf<f64>,
    dp: DepCounts,
    queue: JobQueue,
    stats: StatsCollector,
    scratch: Box<[Mutex<EngineScratch>]>,
    blocks: usize,
    cap_w: usize,
}

impl GpuWorkspace {
    /// Workspace sized for `a` with `blocks` simulated blocks (0 = auto),
    /// the given capacity multiplier, and hash strategy (the hash bases
    /// depend on `seed` only, so the workspace survives reweightings).
    pub fn new(a: &Csr, blocks: usize, arena_factor: f64, hash: HashKind, seed: u64) -> Self {
        let n = a.nrows;
        let pool = crate::par::global();
        let blocks = if blocks == 0 { default_threads() } else { blocks }
            .max(1)
            .min(n.max(1))
            .min(pool.size());
        let cap_w = ((arena_factor * (a.nnz() + n) as f64) as usize).max(64);
        let cap_out = a.nnz() / 2 + cap_w + n;
        let (dp, _ready) = DepCounts::init(a);
        let mut scratch = Vec::with_capacity(blocks);
        scratch.resize_with(blocks, || Mutex::new(EngineScratch::new()));
        GpuWorkspace {
            w: Workspace::new(cap_w, n, hash, seed),
            out_rows: SharedBuf::new(cap_out),
            out_vals: SharedBuf::new(cap_out),
            out_bump: Bump::new(cap_out),
            col_meta: SharedBuf::new(n),
            diag: SharedBuf::new(n),
            dp,
            queue: JobQueue::new(n),
            stats: StatsCollector::default(),
            scratch: scratch.into_boxed_slice(),
            blocks,
            cap_w,
        }
    }

    /// Block count the workspace was resolved to.
    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// Rewind every shared structure and re-derive the dependency
    /// counters + initial ready set from `a` — allocation-free.
    fn reset(&self, a: &Csr) {
        self.queue.reset();
        self.dp.reinit(a, |v| self.queue.push(v));
        self.w.reset();
        self.out_bump.reset();
        self.stats.reset();
    }
}

/// Shared engine state.
struct Shared<'a> {
    a: &'a Csr,
    ws: &'a GpuWorkspace,
    seed: u64,
    sort_by_weight: bool,
    timing: bool,
}

/// Factor a (permuted) Laplacian CSR with `blocks` simulated persistent
/// blocks (0 = auto). Uses random-permutation hashing.
pub fn factorize_csr(
    a: &Csr,
    seed: u64,
    sort_by_weight: bool,
    blocks: usize,
    arena_factor: f64,
    stage_timing: bool,
) -> Result<(Csc, Vec<f64>, FactorStats), FactorError> {
    factorize_csr_hash(
        a,
        seed,
        sort_by_weight,
        blocks,
        arena_factor,
        HashKind::RandomPerm,
        stage_timing,
    )
}

/// [`factorize_csr`] with an explicit hash strategy (ablation hook).
pub fn factorize_csr_hash(
    a: &Csr,
    seed: u64,
    sort_by_weight: bool,
    blocks: usize,
    arena_factor: f64,
    hash: HashKind,
    stage_timing: bool,
) -> Result<(Csc, Vec<f64>, FactorStats), FactorError> {
    let ws = GpuWorkspace::new(a, blocks, arena_factor, hash, seed);
    let mut out = FactorBufs::new();
    let stats = factorize_into(a, seed, sort_by_weight, stage_timing, &ws, &mut out)?;
    let (g, diag) = out.take_factor(a.nrows);
    Ok((g, diag, stats))
}

/// [`factorize_csr`] through a reusable workspace into caller-owned
/// output buffers — the numeric phase of the symbolic/numeric split.
/// Allocation-free when the workspace and `out` capacities already fit.
pub fn factorize_into(
    a: &Csr,
    seed: u64,
    sort_by_weight: bool,
    stage_timing: bool,
    ws: &GpuWorkspace,
    out: &mut FactorBufs,
) -> Result<FactorStats, FactorError> {
    let timer = Timer::start();
    let n = a.nrows;
    ws.reset(a);
    let shared = Shared { a, ws, seed, sort_by_weight, timing: stage_timing };

    crate::par::global().run(ws.blocks, |part, _parts| block_loop(&shared, part));

    if ws.queue.is_poisoned() {
        return Err(FactorError::WorkspaceFull { capacity: ws.cap_w });
    }
    assemble_into(&shared, n, out);
    let mut stats = ws.stats.snapshot(ws.blocks, timer.secs());
    stats.max_probe = ws.w.max_probe.load(Ordering::Relaxed);
    stats.probe_steps = ws.w.probe_steps.load(Ordering::Relaxed);
    Ok(stats)
}

/// Persistent-block loop.
fn block_loop(sh: &Shared<'_>, part: usize) {
    let mut scratch =
        sh.ws.scratch[part].lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let EngineScratch { raw, merged, mult, bysort, cum } = &mut *scratch;
    let mut gather_ns = 0u64;
    let mut sample_ns = 0u64;
    let mut update_ns = 0u64;
    let mut fills_count = 0u64;

    while let Some(pos) = sh.ws.queue.claim() {
        let Ok(k) = sh.ws.queue.wait(pos) else { break };
        let k = k as usize;
        let t0 = sh.timing.then(Instant::now);

        // ---- Stage 1: gather from CSR + workspace, block-merge. ----
        raw.clear();
        for (&c, &v) in sh.a.row_indices(k).iter().zip(sh.a.row_data(k)) {
            if (c as usize) > k && v < 0.0 {
                raw.push((c, -v));
            }
        }
        sh.ws.w.gather(k as u32, raw);
        if raw.is_empty() {
            unsafe {
                sh.ws.diag.write(k, 0.0);
                sh.ws.col_meta.write(k, (0, 0));
            }
            if let Some(t0) = t0 {
                gather_ns += t0.elapsed().as_nanos() as u64;
            }
            continue;
        }
        // Block-level merge: bitonic sort by (row, val) then the
        // flag/prefix-sum compaction (paper §5.3.2). (row, val) keying
        // keeps float sums schedule-independent.
        primitives::bitonic_sort_by(raw, |&(r, v)| (r, v));
        primitives::merge_sorted_by_flags(raw, merged, mult);
        let lkk: f64 = merged.iter().map(|x| x.1).sum();
        let Some(start) = sh.ws.out_bump.alloc(merged.len()) else {
            sh.ws.queue.poison();
            break;
        };
        for (t, &(r, w)) in merged.iter().enumerate() {
            // SAFETY: reserved region.
            unsafe {
                sh.ws.out_rows.write(start + t, r);
                sh.ws.out_vals.write(start + t, -w / lkk);
            }
        }
        unsafe {
            sh.ws.diag.write(k, lkk);
            sh.ws.col_meta.write(k, (start, merged.len() as u32));
        }
        let t1 = sh.timing.then(Instant::now);
        if let (Some(a), Some(b)) = (t0, t1) {
            gather_ns += (b - a).as_nanos() as u64;
        }

        // ---- Stage 2: weight sort (bitonic) + parallel-style sampling. ----
        bysort.clear();
        bysort.extend_from_slice(merged);
        if sh.sort_by_weight {
            primitives::bitonic_sort_by(bysort, |&(r, w)| (w, r));
        }
        let mut rng = sample::pivot_rng(sh.seed, k as u32);
        let mut overflow = false;
        sample::sample_clique(bysort, cum, &mut rng, |i, j, w| {
            if overflow {
                return;
            }
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            // Right-looking: write straight into the target's workspace
            // region (Algorithm 4 line 22), then the dependency.
            sh.ws.dp.inc(hi);
            if sh.ws.w.insert(lo, hi, w).is_err() {
                overflow = true;
                return;
            }
            fills_count += 1;
        });
        if overflow {
            sh.ws.queue.poison();
            break;
        }
        let t2 = sh.timing.then(Instant::now);
        if let (Some(a), Some(b)) = (t1, t2) {
            sample_ns += (b - a).as_nanos() as u64;
        }

        // ---- Stage 3: cut edges, schedule ready vertices. ----
        for (&(v, _), &m) in merged.iter().zip(mult.iter()) {
            if sh.ws.dp.dec(v, m) {
                sh.ws.queue.push(v);
            }
        }
        if let Some(t2) = t2 {
            update_ns += t2.elapsed().as_nanos() as u64;
        }
    }

    let st = &sh.ws.stats;
    st.fills.fetch_add(fills_count, Ordering::Relaxed);
    st.stage_gather_ns.fetch_add(gather_ns, Ordering::Relaxed);
    st.stage_sample_ns.fetch_add(sample_ns, Ordering::Relaxed);
    st.stage_update_ns.fetch_add(update_ns, Ordering::Relaxed);
}

/// Collect per-column slices into the caller's factor buffers (same as
/// the CPU engine; allocation-free within `out` capacity).
fn assemble_into(sh: &Shared<'_>, n: usize, out: &mut FactorBufs) {
    out.clear();
    out.colptr.push(0usize);
    let mut total = 0usize;
    for k in 0..n {
        let (_, len) = unsafe { sh.ws.col_meta.read(k) };
        total += len as usize;
        out.colptr.push(total);
    }
    for k in 0..n {
        let (start, len) = unsafe { sh.ws.col_meta.read(k) };
        for t in 0..len as usize {
            unsafe {
                out.rowidx.push(sh.ws.out_rows.read(start + t));
                out.data.push(sh.ws.out_vals.read(start + t));
            }
        }
        out.diag.push(unsafe { sh.ws.diag.read(k) });
    }
    sh.ws.stats.out_entries.fetch_add(total as u64, Ordering::Relaxed);
    // `arena_used` is the *fill* workspace occupancy (peak occupied
    // slots of `W`), matching the CPU engine's fill-arena watermark —
    // not the output arena, whose size `out_entries` already reports.
    sh.ws.stats.arena_used.store(sh.ws.w.peak_occupancy(), Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use crate::factor::{factorize, Engine, ParacOptions};
    use crate::graph::generators;
    use crate::ordering::Ordering as Ord;
    use crate::testing::prop::forall_seeds;

    fn opts(engine: Engine, ordering: Ord, seed: u64) -> ParacOptions {
        ParacOptions { engine, ordering, seed, ..Default::default() }
    }

    #[test]
    fn matches_sequential_engine_exactly() {
        forall_seeds(4, |seed| {
            let l = generators::random_connected(250, 380, seed);
            for blocks in [1, 2, 4] {
                let fs = factorize(&l, &opts(Engine::Seq, Ord::Natural, seed)).unwrap();
                let fg =
                    factorize(&l, &opts(Engine::GpuSim { blocks }, Ord::Natural, seed)).unwrap();
                if fs.g != fg.g || fs.diag != fg.diag {
                    return Err(format!("mismatch at {blocks} blocks"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matches_cpu_engine_on_orderings() {
        let l = generators::grid3d(7, 7, 7, generators::Coeff::HighContrast(3.0), 0);
        for ord in [Ord::Amd, Ord::NnzSort, Ord::Random] {
            let fc = factorize(&l, &opts(Engine::Cpu { threads: 4 }, ord, 13)).unwrap();
            let fg = factorize(&l, &opts(Engine::GpuSim { blocks: 4 }, ord, 13)).unwrap();
            assert_eq!(fc.g, fg.g, "ordering {ord:?}");
            assert_eq!(fc.diag, fg.diag);
        }
    }

    #[test]
    fn identity_hash_also_correct() {
        use crate::factor::gpusim::factorize_csr_hash;
        use crate::gpusim::hashmap::HashKind;
        let l = generators::grid2d(20, 20, generators::Coeff::Uniform, 0);
        let (g1, d1, _) = factorize_csr_hash(&l.matrix, 5, true, 4, 6.0, HashKind::Identity, false)
            .unwrap();
        let (g2, d2, _) =
            factorize_csr_hash(&l.matrix, 5, true, 4, 6.0, HashKind::RandomPerm, false).unwrap();
        assert_eq!(g1, g2, "hashing must not change the factor");
        assert_eq!(d1, d2);
    }

    #[test]
    fn workspace_retry_on_overflow() {
        let l = generators::complete(50);
        let mut o = opts(Engine::GpuSim { blocks: 4 }, Ord::Natural, 3);
        o.arena_factor = 0.05;
        let f = factorize(&l, &o).unwrap();
        f.validate().unwrap();
    }

    #[test]
    fn road_graph_gpusim() {
        let l = generators::road_like(30, 30, 0.15, 4);
        let f = factorize(&l, &opts(Engine::GpuSim { blocks: 4 }, Ord::NnzSort, 9)).unwrap();
        f.validate().unwrap();
        assert!(f.stats.max_probe >= 1);
    }
}
