//! Sequential randomized Cholesky — Algorithms 1–2 verbatim.
//!
//! The reference implementation every parallel engine is tested against
//! (factors are bit-identical by construction — see [`super::sample`]).
//! Uses a simple list-of-lists working structure: live edges `(a,b)`,
//! `a < b`, are stored in `a`'s list; eliminating `k` consumes `list[k]`
//! plus `k`'s original higher neighbors and pushes sampled fills into the
//! list of each new edge's smaller endpoint.

use super::sample;
use super::stats::FactorStats;
use super::FactorError;
use crate::sparse::{Csc, Csr};
use crate::util::Timer;

/// Factor a (permuted) Laplacian CSR matrix sequentially.
/// Returns `(G strictly-lower CSC, D, stats)`.
pub fn factorize_csr(
    a: &Csr,
    seed: u64,
    sort_by_weight: bool,
) -> Result<(Csc, Vec<f64>, FactorStats), FactorError> {
    let timer = Timer::start();
    let n = a.nrows;
    // Fill lists: fills[v] = sampled edges (u, w) with v < u.
    let mut fills: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
    let mut diag = vec![0.0f64; n];
    let mut colptr = Vec::with_capacity(n + 1);
    let mut rowidx: Vec<u32> = Vec::new();
    let mut data: Vec<f64> = Vec::new();
    colptr.push(0usize);

    let mut raw: Vec<(u32, f64)> = Vec::new();
    let mut merged: Vec<(u32, f64)> = Vec::new();
    let mut mult: Vec<u32> = Vec::new();
    let mut bysort: Vec<(u32, f64)> = Vec::new();
    let mut cum: Vec<f64> = Vec::new();
    let mut n_fills = 0u64;

    for k in 0..n {
        // ---- Stage 1: gather + merge the live column of k. ----
        raw.clear();
        for (&c, &v) in a.row_indices(k).iter().zip(a.row_data(k)) {
            if (c as usize) > k && v < 0.0 {
                raw.push((c, -v));
            }
        }
        raw.append(&mut fills[k]);
        fills[k].shrink_to_fit();
        if raw.is_empty() {
            diag[k] = 0.0;
            colptr.push(rowidx.len());
            continue;
        }
        sample::merge_neighbors(&mut raw, &mut merged, &mut mult);
        let lkk: f64 = merged.iter().map(|x| x.1).sum();
        diag[k] = lkk;
        // G(:,k) = L(:,k)/ℓ_kk — off-diagonals are −w/ℓ_kk, rows sorted.
        for &(r, w) in &merged {
            rowidx.push(r);
            data.push(-w / lkk);
        }
        colptr.push(rowidx.len());

        // ---- Stage 2: order by weight, sample the spanning structure. ----
        bysort.clear();
        bysort.extend_from_slice(&merged);
        if sort_by_weight {
            sample::sort_by_weight(&mut bysort);
        }
        let mut rng = sample::pivot_rng(seed, k as u32);
        // ---- Stage 3: push fills to the smaller endpoint's list. ----
        sample::sample_clique(&bysort, &mut cum, &mut rng, |i, j, w| {
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            fills[lo as usize].push((hi, w));
            n_fills += 1;
        });
    }

    let g = Csc { nrows: n, ncols: n, colptr, rowidx, data };
    let stats = FactorStats {
        fills: n_fills,
        out_entries: g.nnz() as u64,
        workers: 1,
        wall_secs: timer.secs(),
        ..FactorStats::default()
    };
    Ok((g, diag, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, Engine, ParacOptions};
    use crate::graph::generators;
    use crate::ordering::Ordering;
    use crate::testing::prop::forall_seeds;

    fn opts_seq() -> ParacOptions {
        ParacOptions { engine: Engine::Seq, ordering: Ordering::Natural, ..Default::default() }
    }

    #[test]
    fn path_graph_factors_exactly() {
        // A path has no clique bigger than an edge: AC is *exact* on
        // trees — G D Gᵀ must equal L precisely.
        let l = generators::path(20);
        let f = factorize(&l, &opts_seq()).unwrap();
        f.validate().unwrap();
        let got = f.product_dense();
        let want = l.matrix.to_dense();
        for i in 0..20 {
            for j in 0..20 {
                assert!((got[i][j] - want[i][j]).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn tree_factorization_is_exact_leaf_first() {
        // AC is exact whenever every pivot has ≤ 2 live neighbors — on a
        // tree, any leaf-pruning order (which minimum degree produces)
        // guarantees exactly one live neighbor per elimination.
        forall_seeds(10, |seed| {
            let l = generators::random_tree(40, seed);
            let mut o = opts_seq();
            o.ordering = Ordering::Amd;
            let f = factorize(&l, &o).unwrap();
            f.validate().map_err(|e| e.to_string())?;
            let got = f.product_dense();
            let want = l.matrix.to_dense();
            for i in 0..40 {
                for j in 0..40 {
                    if (got[i][j] - want[i][j]).abs() > 1e-9 * want[i][i].max(1.0) {
                        return Err(format!("mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn expectation_over_seeds_approaches_l() {
        // E[G D Gᵀ] = L (Kyng–Sachdeva). Average many seeds on a small
        // graph with real cliques and check convergence.
        let l = generators::complete(8);
        let n = l.n();
        let trials = 3000;
        let mut acc = vec![vec![0.0; n]; n];
        for t in 0..trials {
            let mut o = opts_seq();
            o.seed = 5000 + t;
            let f = factorize(&l, &o).unwrap();
            let p = f.product_dense();
            for i in 0..n {
                for j in 0..n {
                    acc[i][j] += p[i][j] / trials as f64;
                }
            }
        }
        let want = l.matrix.to_dense();
        for i in 0..n {
            for j in 0..n {
                assert!(
                    (acc[i][j] - want[i][j]).abs() < 0.25,
                    "E[GDGᵀ]({i},{j}) = {} vs {}",
                    acc[i][j],
                    want[i][j]
                );
            }
        }
    }

    #[test]
    fn diag_positive_and_last_zero_for_connected() {
        let l = generators::random_connected(60, 60, 3);
        let f = factorize(&l, &opts_seq()).unwrap();
        for k in 0..59 {
            assert!(f.diag[k] > 0.0, "diag[{k}] = {}", f.diag[k]);
        }
        assert_eq!(f.diag[59], 0.0, "last pivot of a connected Laplacian is empty");
    }

    #[test]
    fn fill_stays_near_linear() {
        // AC samples ≤ m−1 edges per pivot: nnz(G) ≤ nnz(L)/2 + fills,
        // and fills should stay O(M log N) — sanity: below 4× edges.
        let l = generators::grid2d(30, 30, generators::Coeff::Uniform, 0);
        let edges = l.num_edges();
        let f = factorize(&l, &opts_seq()).unwrap();
        assert!(
            (f.stats.fills as f64) < 4.0 * edges as f64,
            "fills {} vs edges {edges}",
            f.stats.fills
        );
    }

    #[test]
    fn disconnected_graph_zero_pivots_per_component() {
        let l = crate::graph::Laplacian::from_edges(6, &[(0, 1, 1.0), (2, 3, 2.0)], "f");
        let f = factorize(&l, &opts_seq()).unwrap();
        // Components {0,1}, {2,3}, {4}, {5}: one zero pivot each (the
        // component's last-eliminated vertex) → 4 zero pivots.
        let zeros = f.diag.iter().filter(|&&d| d == 0.0).count();
        assert_eq!(zeros, 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let l = generators::random_connected(80, 120, 9);
        let f1 = factorize(&l, &opts_seq()).unwrap();
        let f2 = factorize(&l, &opts_seq()).unwrap();
        assert_eq!(f1.g, f2.g);
        assert_eq!(f1.diag, f2.diag);
    }
}
