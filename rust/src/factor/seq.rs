//! Sequential randomized Cholesky — Algorithms 1–2 verbatim.
//!
//! The reference implementation every parallel engine is tested against
//! (factors are bit-identical by construction — see [`super::sample`]).
//! Uses a simple list-of-lists working structure: live edges `(a,b)`,
//! `a < b`, are stored in `a`'s list; eliminating `k` consumes `list[k]`
//! plus `k`'s original higher neighbors and pushes sampled fills into the
//! list of each new edge's smaller endpoint.

use super::sample;
use super::stats::FactorStats;
use super::symbolic::{EngineScratch, FactorBufs};
use super::FactorError;
use crate::sparse::{Csc, Csr};
use crate::util::Timer;

/// Reusable working state of the sequential engine: the per-vertex fill
/// lists plus the elimination scratch. Capacities grow on first use and
/// persist, so refactorizing on an unchanged sparsity pattern touches
/// the allocator not at all.
pub struct SeqWorkspace {
    /// Fill lists: `fills[v]` = sampled edges `(u, w)` with `v < u`.
    fills: Vec<Vec<(u32, f64)>>,
    scratch: EngineScratch,
}

impl SeqWorkspace {
    /// Workspace for an `n`-vertex factorization.
    pub fn new(n: usize) -> SeqWorkspace {
        SeqWorkspace { fills: vec![Vec::new(); n], scratch: EngineScratch::new() }
    }
}

/// Factor a (permuted) Laplacian CSR matrix sequentially.
/// Returns `(G strictly-lower CSC, D, stats)`.
pub fn factorize_csr(
    a: &Csr,
    seed: u64,
    sort_by_weight: bool,
) -> Result<(Csc, Vec<f64>, FactorStats), FactorError> {
    let mut ws = SeqWorkspace::new(a.nrows);
    let mut out = FactorBufs::new();
    let stats = factorize_into(a, seed, sort_by_weight, &mut ws, &mut out)?;
    let (g, diag) = out.take_factor(a.nrows);
    Ok((g, diag, stats))
}

/// [`factorize_csr`] writing into caller-owned output buffers through a
/// reusable workspace — the numeric phase of the symbolic/numeric split.
/// Allocation-free when `ws`/`out` capacities already fit the run.
pub fn factorize_into(
    a: &Csr,
    seed: u64,
    sort_by_weight: bool,
    ws: &mut SeqWorkspace,
    out: &mut FactorBufs,
) -> Result<FactorStats, FactorError> {
    let timer = Timer::start();
    let n = a.nrows;
    debug_assert_eq!(ws.fills.len(), n, "workspace sized for a different matrix");
    out.clear();
    out.colptr.push(0usize);

    let EngineScratch { raw, merged, mult, bysort, cum } = &mut ws.scratch;
    let mut n_fills = 0u64;

    for k in 0..n {
        // ---- Stage 1: gather + merge the live column of k. ----
        raw.clear();
        for (&c, &v) in a.row_indices(k).iter().zip(a.row_data(k)) {
            if (c as usize) > k && v < 0.0 {
                raw.push((c, -v));
            }
        }
        raw.append(&mut ws.fills[k]);
        if raw.is_empty() {
            out.diag.push(0.0);
            out.colptr.push(out.rowidx.len());
            continue;
        }
        sample::merge_neighbors(raw, merged, mult);
        let lkk: f64 = merged.iter().map(|x| x.1).sum();
        out.diag.push(lkk);
        // G(:,k) = L(:,k)/ℓ_kk — off-diagonals are −w/ℓ_kk, rows sorted.
        for &(r, w) in merged.iter() {
            out.rowidx.push(r);
            out.data.push(-w / lkk);
        }
        out.colptr.push(out.rowidx.len());

        // ---- Stage 2: order by weight, sample the spanning structure. ----
        bysort.clear();
        bysort.extend_from_slice(merged);
        if sort_by_weight {
            sample::sort_by_weight(bysort);
        }
        let mut rng = sample::pivot_rng(seed, k as u32);
        // ---- Stage 3: push fills to the smaller endpoint's list. ----
        sample::sample_clique(bysort, cum, &mut rng, |i, j, w| {
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            ws.fills[lo as usize].push((hi, w));
            n_fills += 1;
        });
    }

    Ok(FactorStats {
        fills: n_fills,
        out_entries: out.rowidx.len() as u64,
        workers: 1,
        wall_secs: timer.secs(),
        ..FactorStats::default()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, Engine, ParacOptions};
    use crate::graph::generators;
    use crate::ordering::Ordering;
    use crate::testing::prop::forall_seeds;

    fn opts_seq() -> ParacOptions {
        ParacOptions { engine: Engine::Seq, ordering: Ordering::Natural, ..Default::default() }
    }

    #[test]
    fn path_graph_factors_exactly() {
        // A path has no clique bigger than an edge: AC is *exact* on
        // trees — G D Gᵀ must equal L precisely.
        let l = generators::path(20);
        let f = factorize(&l, &opts_seq()).unwrap();
        f.validate().unwrap();
        let got = f.product_dense();
        let want = l.matrix.to_dense();
        for i in 0..20 {
            for j in 0..20 {
                assert!((got[i][j] - want[i][j]).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn tree_factorization_is_exact_leaf_first() {
        // AC is exact whenever every pivot has ≤ 2 live neighbors — on a
        // tree, any leaf-pruning order (which minimum degree produces)
        // guarantees exactly one live neighbor per elimination.
        forall_seeds(10, |seed| {
            let l = generators::random_tree(40, seed);
            let mut o = opts_seq();
            o.ordering = Ordering::Amd;
            let f = factorize(&l, &o).unwrap();
            f.validate().map_err(|e| e.to_string())?;
            let got = f.product_dense();
            let want = l.matrix.to_dense();
            for i in 0..40 {
                for j in 0..40 {
                    if (got[i][j] - want[i][j]).abs() > 1e-9 * want[i][i].max(1.0) {
                        return Err(format!("mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn expectation_over_seeds_approaches_l() {
        // E[G D Gᵀ] = L (Kyng–Sachdeva). Average many seeds on a small
        // graph with real cliques and check convergence.
        let l = generators::complete(8);
        let n = l.n();
        let trials = 3000;
        let mut acc = vec![vec![0.0; n]; n];
        for t in 0..trials {
            let mut o = opts_seq();
            o.seed = 5000 + t;
            let f = factorize(&l, &o).unwrap();
            let p = f.product_dense();
            for i in 0..n {
                for j in 0..n {
                    acc[i][j] += p[i][j] / trials as f64;
                }
            }
        }
        let want = l.matrix.to_dense();
        for i in 0..n {
            for j in 0..n {
                assert!(
                    (acc[i][j] - want[i][j]).abs() < 0.25,
                    "E[GDGᵀ]({i},{j}) = {} vs {}",
                    acc[i][j],
                    want[i][j]
                );
            }
        }
    }

    #[test]
    fn diag_positive_and_last_zero_for_connected() {
        let l = generators::random_connected(60, 60, 3);
        let f = factorize(&l, &opts_seq()).unwrap();
        for k in 0..59 {
            assert!(f.diag[k] > 0.0, "diag[{k}] = {}", f.diag[k]);
        }
        assert_eq!(f.diag[59], 0.0, "last pivot of a connected Laplacian is empty");
    }

    #[test]
    fn fill_stays_near_linear() {
        // AC samples ≤ m−1 edges per pivot: nnz(G) ≤ nnz(L)/2 + fills,
        // and fills should stay O(M log N) — sanity: below 4× edges.
        let l = generators::grid2d(30, 30, generators::Coeff::Uniform, 0);
        let edges = l.num_edges();
        let f = factorize(&l, &opts_seq()).unwrap();
        assert!(
            (f.stats.fills as f64) < 4.0 * edges as f64,
            "fills {} vs edges {edges}",
            f.stats.fills
        );
    }

    #[test]
    fn disconnected_graph_zero_pivots_per_component() {
        let l = crate::graph::Laplacian::from_edges(6, &[(0, 1, 1.0), (2, 3, 2.0)], "f");
        let f = factorize(&l, &opts_seq()).unwrap();
        // Components {0,1}, {2,3}, {4}, {5}: one zero pivot each (the
        // component's last-eliminated vertex) → 4 zero pivots.
        let zeros = f.diag.iter().filter(|&&d| d == 0.0).count();
        assert_eq!(zeros, 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let l = generators::random_connected(80, 120, 9);
        let f1 = factorize(&l, &opts_seq()).unwrap();
        let f2 = factorize(&l, &opts_seq()).unwrap();
        assert_eq!(f1.g, f2.g);
        assert_eq!(f1.diag, f2.diag);
    }
}
