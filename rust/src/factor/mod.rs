//! The randomized approximate Cholesky factorization — the paper's core.
//!
//! Produces `L ≈ G D Gᵀ` with `G` unit-lower-triangular and `D` diagonal
//! (Algorithm 1), replacing each elimination's clique update with a
//! sampled spanning tree (Algorithm 2, [`sample`]). Three engines share
//! identical sampling logic and produce **bit-identical factors** for a
//! given `(matrix, ordering, seed)` — sampling uses a per-vertex RNG
//! stream and deterministic merge order, so parallel schedules cannot
//! perturb the output (a stronger guarantee than the paper needs, and the
//! backbone of the engine-equivalence tests):
//!
//! * [`seq`] — the sequential reference (Algorithms 1–2 verbatim).
//! * [`cpu`] — parallel left-looking engine (Algorithm 3).
//! * [`gpusim`] — parallel right-looking engine modeling the paper's
//!   persistent-kernel GPU design (Algorithm 4).

pub mod chunk;
pub mod cpu;
pub mod depend;
pub mod gpusim;
pub mod ldl;
pub mod queue;
pub mod sample;
pub mod seq;
pub mod stats;
pub mod symbolic;

pub use ldl::LdlFactor;
pub use stats::FactorStats;
pub use symbolic::SymbolicFactor;

use crate::graph::{LapKind, Laplacian};
use crate::ordering::Ordering;
use crate::sparse::{Csr, Precision};

/// Which factorization engine to run.
///
/// The parallel engines run on the persistent [`crate::par`] worker
/// pool, so `threads`/`blocks` counts above the pool size are clamped
/// to it (the pool is sized at first use — `PARAC_THREADS` or auto);
/// [`FactorStats`] records the count that actually ran. The factor
/// itself is bit-identical for any worker count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Sequential reference implementation.
    Seq,
    /// Parallel left-looking CPU engine; `0` threads = auto.
    Cpu { threads: usize },
    /// Right-looking GPU-model engine; `0` blocks = auto.
    GpuSim { blocks: usize },
}

impl Engine {
    /// Parse a CLI name (`seq`, `cpu`, `cpu:8`, `gpusim`, `gpusim:64`;
    /// `gpu`/`gpu:64` are accepted aliases for `gpusim` — [`Engine::name`]
    /// always renders the canonical `gpusim` spelling).
    pub fn parse(s: &str) -> Option<Engine> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, a.parse().ok()?),
            None => (s, 0usize),
        };
        match name {
            "seq" => Some(Engine::Seq),
            "cpu" => Some(Engine::Cpu { threads: arg }),
            "gpusim" | "gpu" => Some(Engine::GpuSim { blocks: arg }),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Seq => "seq",
            Engine::Cpu { .. } => "cpu",
            Engine::GpuSim { .. } => "gpusim",
        }
    }
}

/// Options for [`factorize`].
#[derive(Clone, Debug)]
pub struct ParacOptions {
    /// Elimination ordering (paper §6 benchmarks AMD / nnz-sort / random).
    pub ordering: Ordering,
    /// Execution engine.
    pub engine: Engine,
    /// RNG seed; per-vertex streams are derived from it.
    pub seed: u64,
    /// Fill-arena capacity multiplier over `nnz + n` (paper §5.2.1:
    /// allocate one large chunk estimated empirically; on overflow we
    /// retry doubled).
    pub arena_factor: f64,
    /// Sort neighbors by |weight| before sampling (paper: improves
    /// numerical quality; keep on unless running the ablation).
    pub sort_by_weight: bool,
    /// Collect per-stage wall times (≈5% overhead from clock reads on
    /// the hot path; enable for stage-breakdown reports).
    pub stage_timing: bool,
    /// Value-storage plane for the preconditioner built on the factor
    /// (the factorization itself always computes in f64). `None` (the
    /// default) defers to the `PARAC_PRECISION` environment variable,
    /// then to [`Precision::F64`]; `Some` pins the plane explicitly
    /// ([`crate::solver::SolverBuilder::precision`] / CLI
    /// `--precision`).
    pub precision: Option<Precision>,
}

impl Default for ParacOptions {
    fn default() -> Self {
        ParacOptions {
            ordering: Ordering::NnzSort,
            engine: Engine::Cpu { threads: 0 },
            seed: 0x9A9A,
            arena_factor: 6.0,
            sort_by_weight: true,
            stage_timing: false,
            precision: None,
        }
    }
}

/// Factorization failure modes — absorbed into the crate-wide
/// [`crate::error::ParacError`]; this alias keeps existing
/// `FactorError`-matching code compiling unchanged.
pub use crate::error::ParacError as FactorError;

/// Factor a Laplacian: compute the ordering, permute, run the engine
/// (retrying with a larger arena if the fill estimate was too small), and
/// wrap the result with its permutation.
///
/// # Example
///
/// The low-level flow underneath [`crate::solver::Solver`] (which is
/// the recommended session API — see the crate docs): generate a
/// Laplacian, factor it with the parallel CPU engine, and use the
/// factor as a PCG preconditioner.
///
/// ```
/// use parac::factor::{factorize, Engine, ParacOptions};
/// use parac::graph::generators::{self, Coeff};
/// use parac::ordering::Ordering;
/// use parac::precond::LdlPrecond;
/// use parac::solve::pcg::{self, PcgOptions};
///
/// let lap = generators::grid2d(12, 12, Coeff::Uniform, 42);
/// let opts = ParacOptions {
///     ordering: Ordering::NnzSort,
///     engine: Engine::Cpu { threads: 2 },
///     seed: 7,
///     ..Default::default()
/// };
/// let factor = factorize(&lap, &opts).expect("factorization");
/// assert_eq!(factor.n(), lap.n());
///
/// let pre = LdlPrecond::new(factor);
/// let b = pcg::random_rhs(&lap, 1);
/// let out = pcg::solve(&lap.matrix, &b, &pre, &PcgOptions::default());
/// assert!(out.converged, "rel residual {}", out.rel_residual);
/// ```
pub fn factorize(lap: &Laplacian, opts: &ParacOptions) -> Result<LdlFactor, FactorError> {
    factorize_pinned(lap, opts, None)
}

/// [`factorize`] with an optional vertex pinned to the **last**
/// elimination position — used to keep the ground vertex of an SDD
/// extension out of the preconditioner block.
pub fn factorize_pinned(
    lap: &Laplacian,
    opts: &ParacOptions,
    pin_last: Option<u32>,
) -> Result<LdlFactor, FactorError> {
    SymbolicFactor::analyze_pinned(lap, opts, pin_last)?.factorize(lap)
}

/// Factor an SPD SDD matrix `A` (e.g. a Dirichlet Poisson operator) by
/// grounding it to an `(N+1)`-vertex Laplacian (rchol construction),
/// factoring with the ground pinned last, and truncating the factor back
/// to `N×N` — the resulting `LdlFactor` preconditions `A` directly.
pub fn factorize_sdd(a: &Csr, opts: &ParacOptions) -> Result<LdlFactor, FactorError> {
    let ext = Laplacian::ground_sdd(a, "sdd").map_err(FactorError::BadInput)?;
    let ground = (ext.n() - 1) as u32;
    let f = factorize_pinned(&ext, opts, Some(ground))?;
    Ok(f.truncate_last())
}

/// Convenience: does this Laplacian type need grounding before
/// factorization? (`Grounded` operators are SPD and already reduced.)
pub fn needs_grounding(lap: &Laplacian) -> bool {
    lap.kind == LapKind::Grounded
}

