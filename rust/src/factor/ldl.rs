//! The `G D Gᵀ` factor object and its (sequential) preconditioner apply.

use crate::ordering::perm;
use crate::sparse::{Csc, Csr};

use super::stats::FactorStats;

/// An approximate `L ≈ G D Gᵀ` factorization.
///
/// `G` is unit-lower-triangular; only its strictly-lower part is stored
/// (CSC, rows sorted). `diag` is `D`. If `perm` is set, the factor is of
/// `P L Pᵀ` and solves permute in/out transparently.
#[derive(Clone, Debug)]
pub struct LdlFactor {
    /// Strictly-lower part of `G` (unit diagonal implicit), CSC.
    pub g: Csc,
    /// The diagonal `D`; `0.0` marks skipped (empty-column / last)
    /// pivots, applied pseudo-inversely.
    pub diag: Vec<f64>,
    /// Relabeling `perm[old] = new` used before factorization.
    pub perm: Option<Vec<u32>>,
    /// Engine statistics from construction.
    pub stats: FactorStats,
}

impl LdlFactor {
    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.diag.len()
    }

    /// Stored nonzeros of `G` (strictly lower).
    pub fn nnz(&self) -> usize {
        self.g.nnz()
    }

    /// Fill ratio `2·nnz(G) / nnz(L)` as reported under the paper's
    /// Fig. 4 (`nnz(G)` counting the strictly-lower entries).
    pub fn fill_ratio(&self, input_nnz: usize) -> f64 {
        2.0 * self.g.nnz() as f64 / input_nnz as f64
    }

    /// Preconditioner apply: `z = (G D Gᵀ)⁺ r` (sequential solves,
    /// zero-pivot rows skipped). Handles the stored permutation.
    pub fn solve(&self, r: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(r.len(), n);
        let mut z = vec![0.0; n];
        let mut scratch = vec![0.0; if self.perm.is_some() { n } else { 0 }];
        self.solve_into(r, &mut z, &mut scratch);
        z
    }

    /// Allocation-free [`LdlFactor::solve`]: `z = (G D Gᵀ)⁺ r` written
    /// into a caller buffer. `scratch` must have length `n` when a
    /// permutation is stored (it holds the permuted intermediate); it
    /// is untouched otherwise. Neither `z`'s nor `scratch`'s prior
    /// contents are read.
    pub fn solve_into(&self, r: &[f64], z: &mut [f64], scratch: &mut [f64]) {
        let n = self.n();
        debug_assert_eq!(r.len(), n);
        debug_assert_eq!(z.len(), n);
        match &self.perm {
            Some(p) => {
                debug_assert_eq!(scratch.len(), n);
                for (i, &ri) in r.iter().enumerate() {
                    scratch[p[i] as usize] = ri;
                }
                self.forward_inplace(scratch);
                for (yk, &d) in scratch.iter_mut().zip(&self.diag) {
                    *yk = if d > 0.0 { *yk / d } else { 0.0 };
                }
                self.backward_inplace(scratch);
                for (i, zi) in z.iter_mut().enumerate() {
                    *zi = scratch[p[i] as usize];
                }
            }
            None => {
                z.copy_from_slice(r);
                self.forward_inplace(z);
                for (yk, &d) in z.iter_mut().zip(&self.diag) {
                    *yk = if d > 0.0 { *yk / d } else { 0.0 };
                }
                self.backward_inplace(z);
            }
        }
    }

    /// Forward solve `G y = r` in place (unit diagonal; permuted index
    /// space).
    pub fn forward_inplace(&self, y: &mut [f64]) {
        for k in 0..self.n() {
            let yk = y[k];
            if yk == 0.0 {
                continue;
            }
            let rows = self.g.col_rows(k);
            let vals = self.g.col_data(k);
            for (&r, &v) in rows.iter().zip(vals) {
                y[r as usize] -= v * yk;
            }
        }
    }

    /// Backward solve `Gᵀ z = y` in place (permuted index space).
    pub fn backward_inplace(&self, y: &mut [f64]) {
        for k in (0..self.n()).rev() {
            let rows = self.g.col_rows(k);
            let vals = self.g.col_data(k);
            let mut acc = y[k];
            for (&r, &v) in rows.iter().zip(vals) {
                acc -= v * y[r as usize];
            }
            y[k] = acc;
        }
    }

    /// Apply the operator `G D Gᵀ` to a vector (testing: `E[G D Gᵀ] = L`).
    /// Operates in the *permuted* space if a permutation is stored,
    /// mapping in/out like [`LdlFactor::solve`].
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n();
        let mut t = match &self.perm {
            Some(p) => perm::apply_vec(p, x),
            None => x.to_vec(),
        };
        // t = Gᵀ x  (unit diagonal + strictly-lower columns)
        let mut gt = t.clone();
        for k in 0..n {
            let rows = self.g.col_rows(k);
            let vals = self.g.col_data(k);
            let mut acc = t[k];
            for (&r, &v) in rows.iter().zip(vals) {
                acc += v * t[r as usize];
            }
            gt[k] = acc;
        }
        // gt = D Gᵀ x
        for k in 0..n {
            gt[k] *= self.diag[k];
        }
        // t = G gt
        t.copy_from_slice(&gt);
        for k in (0..n).rev() {
            let tk = gt[k];
            if tk == 0.0 {
                continue;
            }
            let rows = self.g.col_rows(k);
            let vals = self.g.col_data(k);
            for (&r, &v) in rows.iter().zip(vals) {
                t[r as usize] += v * tk;
            }
        }
        match &self.perm {
            Some(p) => perm::unapply_vec(p, &t),
            None => t,
        }
    }

    /// Materialize `G D Gᵀ` as dense (tiny matrices; expectation tests).
    pub fn product_dense(&self) -> Vec<Vec<f64>> {
        let n = self.n();
        assert!(n <= 2048, "product_dense is a testing helper");
        let mut out = vec![vec![0.0; n]; n];
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = self.apply(&e);
            for i in 0..n {
                out[i][j] = col[i];
            }
        }
        out
    }

    /// Drop the last row/column (the ground vertex of an SDD extension):
    /// the truncated factor preconditions the original `N×N` SPD matrix.
    pub fn truncate_last(&self) -> LdlFactor {
        let n = self.n() - 1;
        let ground = n as u32;
        let mut colptr = Vec::with_capacity(n + 1);
        let mut rowidx = Vec::with_capacity(self.g.nnz());
        let mut data = Vec::with_capacity(self.g.nnz());
        colptr.push(0usize);
        for c in 0..n {
            for (&r, &v) in self.g.col_rows(c).iter().zip(self.g.col_data(c)) {
                if r != ground {
                    rowidx.push(r);
                    data.push(v);
                }
            }
            colptr.push(rowidx.len());
        }
        let g = Csc { nrows: n, ncols: n, colptr, rowidx, data };
        let perm = self.perm.as_ref().map(|p| {
            // Ground was pinned to label n (the last); dropping it keeps
            // all other labels < n unchanged. Remove the ground's entry.
            let mut q = Vec::with_capacity(n);
            for (old, &new) in p.iter().enumerate() {
                if new != ground {
                    debug_assert!(old < n + 1);
                    q.push(new);
                }
            }
            q
        });
        LdlFactor { g, diag: self.diag[..n].to_vec(), perm, stats: self.stats.clone() }
    }

    /// Export `G` (including the unit diagonal) as CSR — for etree /
    /// level-schedule analytics and MatrixMarket dumps.
    pub fn g_with_diag_csr(&self) -> Csr {
        let n = self.n();
        let mut coo = crate::sparse::Coo::with_capacity(n, n, self.g.nnz() + n);
        for c in 0..n {
            coo.push(c as u32, c as u32, 1.0);
            for (&r, &v) in self.g.col_rows(c).iter().zip(self.g.col_data(c)) {
                coo.push(r, c as u32, v);
            }
        }
        coo.to_csr()
    }

    /// Structural sanity: strictly-lower, sorted, finite, diag ≥ 0.
    pub fn validate(&self) -> Result<(), String> {
        self.g.validate()?;
        if !self.g.is_strictly_lower() {
            return Err("G not strictly lower".into());
        }
        if self.g.ncols != self.diag.len() {
            return Err("diag length mismatch".into());
        }
        if let Some(p) = &self.perm {
            perm::validate(p)?;
        }
        for (k, &d) in self.diag.iter().enumerate() {
            if !(d >= 0.0) || !d.is_finite() {
                return Err(format!("diag[{k}] = {d}"));
            }
        }
        if self.g.data.iter().any(|v| !v.is_finite()) {
            return Err("non-finite entry in G".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    /// Tiny hand-built factor: n=3, G = [[1,0,0],[-.5,1,0],[0,-1,1]],
    /// D = diag(2, 1.5, 0).
    fn tiny() -> LdlFactor {
        let mut coo = Coo::new(3, 3);
        coo.push(1, 0, -0.5);
        coo.push(2, 1, -1.0);
        LdlFactor {
            g: Csc::from_csr(&coo.to_csr()),
            diag: vec![2.0, 1.5, 0.0],
            perm: None,
            stats: FactorStats::default(),
        }
    }

    #[test]
    fn validates() {
        tiny().validate().unwrap();
    }

    #[test]
    fn apply_matches_manual_product() {
        let f = tiny();
        // G D Gᵀ computed by hand:
        // G = [[1,0,0],[-1/2,1,0],[0,-1,1]], D = diag(2,1.5,0)
        // GD = [[2,0,0],[-1,1.5,0],[0,-1.5,0]]
        // GDGᵀ = [[2,-1,0],[-1,2,-1.5],[0,-1.5,1.5]]
        let want = [[2.0, -1.0, 0.0], [-1.0, 2.0, -1.5], [0.0, -1.5, 1.5]];
        let got = f.product_dense();
        for i in 0..3 {
            for j in 0..3 {
                assert!((got[i][j] - want[i][j]).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn solve_is_pseudo_inverse_of_apply() {
        let f = tiny();
        // For x ⊥ nullspace of GDGᵀ: solve(apply(x)) == x. The nullspace
        // here is spanned by the vector with Gᵀ v = e_2-ish; easier:
        // check apply(solve(r)) == apply(solve(apply(solve(r)))) — the
        // projector property — plus exactness on a range vector.
        let x = vec![1.0, -2.0, 0.5];
        let r = f.apply(&x);
        let z = f.solve(&r);
        let r2 = f.apply(&z);
        for (a, b) in r.iter().zip(&r2) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn permutation_roundtrip() {
        let mut f = tiny();
        // Relabel with p = [2,0,1]: factor is of P L Pᵀ; solve/apply on
        // the original index space must still be a consistent pair.
        f.perm = Some(vec![2, 0, 1]);
        let x = vec![0.3, 0.7, -0.2];
        let r = f.apply(&x);
        let z = f.solve(&r);
        let r2 = f.apply(&z);
        for (a, b) in r.iter().zip(&r2) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn truncate_drops_ground_rows() {
        let mut coo = Coo::new(3, 3);
        coo.push(1, 0, -0.5);
        coo.push(2, 0, -0.25); // row that must disappear
        coo.push(2, 1, -1.0);
        let f = LdlFactor {
            g: Csc::from_csr(&coo.to_csr()),
            diag: vec![2.0, 1.5, 1.0],
            perm: Some(vec![0, 1, 2]),
            stats: FactorStats::default(),
        };
        let t = f.truncate_last();
        assert_eq!(t.n(), 2);
        assert_eq!(t.nnz(), 1);
        t.validate().unwrap();
    }

    #[test]
    fn g_with_diag_has_unit_diagonal() {
        let g = tiny().g_with_diag_csr();
        for i in 0..3 {
            assert_eq!(g.get(i, i), 1.0);
        }
        assert_eq!(g.nnz(), 5);
    }
}
