//! Shared bump-allocated arenas — the paper's "one large chunk `O`"
//! (§5.2.1): instead of per-column allocation (malloc scalability
//! ceiling, lock contention — the Rchol bottleneck the paper calls out),
//! every worker reserves space with a single atomic fetch-add and writes
//! into its disjoint slice through raw pointers.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Null index for arena-linked lists.
pub const NIL: usize = usize::MAX;

/// A fixed-capacity buffer shared across threads. Safety contract:
/// writers only touch indices inside a region they reserved from a bump
/// counter; readers only read after synchronizing with the writer
/// (release/acquire through an atomic the engines already maintain).
pub struct SharedBuf<T> {
    buf: Box<[UnsafeCell<T>]>,
}

// SAFETY: access discipline is enforced by the engines (disjoint bump
// regions + release/acquire publication); T is plain data.
unsafe impl<T: Send> Sync for SharedBuf<T> {}
unsafe impl<T: Send> Send for SharedBuf<T> {}

impl<T: Copy + Default> SharedBuf<T> {
    /// Allocate with `cap` default-initialized slots.
    pub fn new(cap: usize) -> Self {
        let mut v = Vec::with_capacity(cap);
        v.resize_with(cap, || UnsafeCell::new(T::default()));
        SharedBuf { buf: v.into_boxed_slice() }
    }

    /// Capacity.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether capacity is zero.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Write slot `i`.
    ///
    /// # Safety
    /// `i` must be inside a region reserved by this thread, or otherwise
    /// free of concurrent access.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        *self.buf[i].get() = v;
    }

    /// Read slot `i`.
    ///
    /// # Safety
    /// The write to `i` must happen-before this read (engine-level
    /// synchronization).
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T {
        *self.buf[i].get()
    }
}

/// Bump allocator over an abstract capacity.
pub struct Bump {
    head: AtomicUsize,
    cap: usize,
}

impl Bump {
    /// New allocator of `cap` slots.
    pub fn new(cap: usize) -> Self {
        Bump { head: AtomicUsize::new(0), cap }
    }

    /// Reserve `count` contiguous slots; `None` when exhausted.
    #[inline]
    pub fn alloc(&self, count: usize) -> Option<usize> {
        let start = self.head.fetch_add(count, Ordering::Relaxed);
        if start + count > self.cap {
            None
        } else {
            Some(start)
        }
    }

    /// High-water mark (may exceed cap after a failed alloc).
    pub fn used(&self) -> usize {
        self.head.load(Ordering::Relaxed).min(self.cap)
    }

    /// Capacity.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Rewind to empty so the arena can be reused for another run.
    /// Caller must guarantee no concurrent allocations are in flight.
    pub fn reset(&self) {
        self.head.store(0, Ordering::Relaxed);
    }
}

/// The fill arena: nodes `(row, val, next)` forming per-vertex
/// linked lists of pending fill-in edges (CPU engine stage 3 → stage 1).
pub struct FillArena {
    /// Target vertex of the fill edge (the larger endpoint).
    pub rows: SharedBuf<u32>,
    /// Edge weight.
    pub vals: SharedBuf<f64>,
    /// Next node in the owner's list (`NIL` terminates). Atomic because
    /// it is written during lock-free pushes.
    pub next: Box<[AtomicUsize]>,
    /// Slot allocator.
    pub bump: Bump,
}

impl FillArena {
    /// Allocate an arena of `cap` nodes.
    pub fn new(cap: usize) -> Self {
        let mut next = Vec::with_capacity(cap);
        next.resize_with(cap, || AtomicUsize::new(NIL));
        FillArena {
            rows: SharedBuf::new(cap),
            vals: SharedBuf::new(cap),
            next: next.into_boxed_slice(),
            bump: Bump::new(cap),
        }
    }

    /// Reuse the arena for another factorization: every node slot is
    /// rewritten before it is published, so rewinding the bump counter
    /// is all it takes (list heads live in the engine workspace and are
    /// re-set to `NIL` there).
    pub fn reset(&self) {
        self.bump.reset();
    }

    /// Lock-free push of node `idx` (fields already written) onto the
    /// list headed by `head` — the paper's "atomic exchange to preserve
    /// the integrity of the linked-list".
    #[inline]
    pub fn push(&self, head: &AtomicUsize, idx: usize) {
        loop {
            let old = head.load(Ordering::Relaxed);
            self.next[idx].store(old, Ordering::Relaxed);
            if head
                .compare_exchange_weak(old, idx, Ordering::Release, Ordering::Relaxed)
                .is_ok()
            {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn bump_respects_capacity() {
        let b = Bump::new(10);
        assert_eq!(b.alloc(4), Some(0));
        assert_eq!(b.alloc(6), Some(4));
        assert_eq!(b.alloc(1), None);
        assert_eq!(b.used(), 10);
    }

    #[test]
    fn shared_buf_roundtrip() {
        let s: SharedBuf<u64> = SharedBuf::new(8);
        unsafe {
            s.write(3, 42);
            assert_eq!(s.read(3), 42);
            assert_eq!(s.read(0), 0);
        }
    }

    #[test]
    fn concurrent_push_preserves_all_nodes() {
        // 8 threads × 1000 pushes onto one list: all nodes must be
        // reachable exactly once.
        let threads = 8;
        let per = 1000;
        let arena = FillArena::new(threads * per);
        let head = AtomicUsize::new(NIL);
        std::thread::scope(|s| {
            for t in 0..threads {
                let arena = &arena;
                let head = &head;
                s.spawn(move || {
                    for i in 0..per {
                        let idx = arena.bump.alloc(1).unwrap();
                        unsafe {
                            arena.rows.write(idx, (t * per + i) as u32);
                            arena.vals.write(idx, 1.0);
                        }
                        arena.push(head, idx);
                    }
                });
            }
        });
        let mut seen = vec![false; threads * per];
        let mut cur = head.load(Ordering::Acquire);
        let mut count = 0;
        while cur != NIL {
            let r = unsafe { arena.rows.read(cur) } as usize;
            assert!(!seen[r], "node {r} seen twice");
            seen[r] = true;
            count += 1;
            cur = arena.next[cur].load(Ordering::Relaxed);
        }
        assert_eq!(count, threads * per);
    }

    #[test]
    fn concurrent_bump_alloc_disjoint() {
        let b = Bump::new(100_000);
        let ranges: Vec<(usize, usize)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        let mut local = Vec::new();
                        for sz in 1..50 {
                            if let Some(start) = b.alloc(sz) {
                                local.push((start, sz));
                            }
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let mut sorted = ranges.clone();
        sorted.sort();
        for w in sorted.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "overlapping allocations");
        }
    }
}
