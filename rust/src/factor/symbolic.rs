//! The symbolic/numeric split of the randomized factorization.
//!
//! RCHOL-style reuse: ordering, permutation layout, and every engine
//! workspace depend only on the input **sparsity pattern** (and the
//! seed), while the randomized elimination sweep depends on the edge
//! weights. [`SymbolicFactor`] freezes the former — computed once by
//! [`SymbolicFactor::analyze`] — so re-solving the same graph with new
//! weights ([`SymbolicFactor::refactorize_into`], surfaced as
//! `Solver::refactorize`) re-runs only the numeric phase: a value
//! gather through the recorded permutation map plus one engine sweep
//! into recycled buffers, with zero heap allocations in steady state.
//!
//! Note the asymmetry with classical Cholesky: the randomized factor's
//! *output* structure is still weight-dependent (the sampling CDF uses
//! weights), so downstream consumers compare the refreshed pattern
//! against the previous one before reusing their own layouts — see
//! `LdlPrecond::refactorize_numeric`.

use super::ldl::LdlFactor;
use super::stats::FactorStats;
use super::{cpu, gpusim, seq, Engine, FactorError, ParacOptions};
use crate::gpusim::hashmap::HashKind;
use crate::graph::Laplacian;
use crate::sparse::{Csc, Csr};
use crate::util::Timer;

/// Recyclable factor output buffers: a strictly-lower CSC plus the
/// diagonal, stored as plain `Vec`s so the numeric phase can refill
/// them with `clear` + `push` (allocation-free within capacity) and
/// swap them with a live [`LdlFactor`]'s storage.
pub struct FactorBufs {
    /// Column pointer (`n + 1` entries once filled).
    pub colptr: Vec<usize>,
    /// Row indices, sorted within each column.
    pub rowidx: Vec<u32>,
    /// Values, parallel to `rowidx`.
    pub data: Vec<f64>,
    /// The diagonal `D` (`n` entries once filled).
    pub diag: Vec<f64>,
}

impl FactorBufs {
    /// Empty buffers (capacities grow on first use).
    pub fn new() -> FactorBufs {
        FactorBufs { colptr: Vec::new(), rowidx: Vec::new(), data: Vec::new(), diag: Vec::new() }
    }

    /// Clear contents, keeping capacity.
    pub fn clear(&mut self) {
        self.colptr.clear();
        self.rowidx.clear();
        self.data.clear();
        self.diag.clear();
    }

    /// Reserve so a factor of the given shape fits without reallocating.
    fn reserve(&mut self, cols: usize, nnz: usize, n: usize) {
        self.colptr.reserve(cols);
        self.rowidx.reserve(nnz);
        self.data.reserve(nnz);
        self.diag.reserve(n);
    }

    /// Move the contents out as an `n × n` factor, leaving the buffers
    /// empty (capacity not preserved — used by the one-shot wrappers).
    pub fn take_factor(&mut self, n: usize) -> (Csc, Vec<f64>) {
        let g = Csc {
            nrows: n,
            ncols: n,
            colptr: std::mem::take(&mut self.colptr),
            rowidx: std::mem::take(&mut self.rowidx),
            data: std::mem::take(&mut self.data),
        };
        (g, std::mem::take(&mut self.diag))
    }
}

impl Default for FactorBufs {
    fn default() -> Self {
        FactorBufs::new()
    }
}

/// Per-worker elimination scratch shared by all three engines: the
/// gather/merge/sort vectors of one elimination. Persisting these across
/// factorizations is what makes the numeric phase allocation-free.
pub struct EngineScratch {
    /// Gathered live neighbors (pre-merge).
    pub raw: Vec<(u32, f64)>,
    /// Merged neighbors, row-sorted.
    pub merged: Vec<(u32, f64)>,
    /// Multiplicities parallel to `merged`.
    pub mult: Vec<u32>,
    /// Weight-sorted copy for sampling.
    pub bysort: Vec<(u32, f64)>,
    /// Inclusive prefix sums for the sampling CDF.
    pub cum: Vec<f64>,
}

impl EngineScratch {
    /// Empty scratch (capacities grow on first use).
    pub fn new() -> EngineScratch {
        EngineScratch {
            raw: Vec::new(),
            merged: Vec::new(),
            mult: Vec::new(),
            bysort: Vec::new(),
            cum: Vec::new(),
        }
    }
}

impl Default for EngineScratch {
    fn default() -> Self {
        EngineScratch::new()
    }
}

/// The frozen engine workspace of one symbolic factorization.
enum EngineWs {
    Seq(seq::SeqWorkspace),
    Cpu(cpu::CpuWorkspace),
    Gpu(gpusim::GpuWorkspace),
}

impl EngineWs {
    fn new(a: &Csr, opts: &ParacOptions, arena_factor: f64) -> EngineWs {
        match opts.engine {
            Engine::Seq => EngineWs::Seq(seq::SeqWorkspace::new(a.nrows)),
            Engine::Cpu { threads } => {
                EngineWs::Cpu(cpu::CpuWorkspace::new(a, threads, arena_factor))
            }
            Engine::GpuSim { blocks } => EngineWs::Gpu(gpusim::GpuWorkspace::new(
                a,
                blocks,
                arena_factor,
                HashKind::RandomPerm,
                opts.seed,
            )),
        }
    }
}

/// The frozen symbolic phase of a factorization: ordering, permuted
/// pattern, value-gather map, and the engine workspace — everything
/// that depends only on the sparsity pattern and the options.
///
/// Lifecycle: [`analyze`](SymbolicFactor::analyze) once, then
/// [`factorize`](SymbolicFactor::factorize) for the first factor and
/// [`refactorize_into`](SymbolicFactor::refactorize_into) for every
/// reweighting. Numeric runs are bit-identical to a from-scratch
/// [`super::factorize`] with the same options (they share this code
/// path), and steady-state refactorization performs no heap
/// allocations when the reweighting preserves the factor structure.
pub struct SymbolicFactor {
    opts: ParacOptions,
    n: usize,
    perm: Vec<u32>,
    /// `P L Pᵀ` — values refreshed in place on refactorize.
    permuted: Csr,
    /// `permuted.data[i] == source.data[val_map[i]]`.
    val_map: Vec<usize>,
    /// Source pattern copy for the exact-reuse check.
    src_indptr: Vec<usize>,
    src_indices: Vec<u32>,
    /// Current arena multiplier (persists overflow-retry growth, so a
    /// refactorization that once outgrew the arena never retries again).
    arena_factor: f64,
    ws: EngineWs,
    /// Double buffer the numeric phase writes into; swapped with the
    /// live factor's storage on refactorize.
    spare: FactorBufs,
    symbolic_secs: f64,
}

impl SymbolicFactor {
    /// Run the symbolic phase for `lap` under `opts`: compute the
    /// ordering, the permuted pattern with its value-gather map, and
    /// size the engine workspace. No numeric work is done.
    pub fn analyze(lap: &Laplacian, opts: &ParacOptions) -> Result<SymbolicFactor, FactorError> {
        SymbolicFactor::analyze_pinned(lap, opts, None)
    }

    /// [`SymbolicFactor::analyze`] with an optional vertex pinned to the
    /// **last** elimination position (SDD ground handling).
    pub fn analyze_pinned(
        lap: &Laplacian,
        opts: &ParacOptions,
        pin_last: Option<u32>,
    ) -> Result<SymbolicFactor, FactorError> {
        let n = lap.n();
        if n == 0 {
            return Err(FactorError::BadInput("empty matrix".into()));
        }
        let timer = Timer::start();
        let mut p = opts.ordering.compute(lap, opts.seed);
        if let Some(pin) = pin_last {
            // Swap labels so `pin` gets label n-1.
            let cur = p[pin as usize];
            if cur != (n - 1) as u32 {
                let holder = p.iter().position(|&x| x == (n - 1) as u32).unwrap();
                p[holder] = cur;
                p[pin as usize] = (n - 1) as u32;
            }
        }
        let (permuted, val_map) = lap.matrix.permute_sym_map(&p);
        let arena_factor = opts.arena_factor;
        let ws = EngineWs::new(&permuted, opts, arena_factor);
        Ok(SymbolicFactor {
            opts: opts.clone(),
            n,
            perm: p,
            src_indptr: lap.matrix.indptr.clone(),
            src_indices: lap.matrix.indices.clone(),
            permuted,
            val_map,
            arena_factor,
            ws,
            spare: FactorBufs::new(),
            symbolic_secs: timer.secs(),
        })
    }

    /// Dimension of the analyzed operator.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The frozen elimination ordering (`perm[old] = new`).
    pub fn perm(&self) -> &[u32] {
        &self.perm
    }

    /// Wall-clock seconds the symbolic phase took.
    pub fn symbolic_secs(&self) -> f64 {
        self.symbolic_secs
    }

    /// Options the analysis was performed under.
    pub fn options(&self) -> &ParacOptions {
        &self.opts
    }

    /// First numeric run: factor `lap` (which must share the analyzed
    /// pattern) into a fresh [`LdlFactor`]. The spare buffers are
    /// re-reserved at the produced capacities afterwards, so even the
    /// *first* [`SymbolicFactor::refactorize_into`] is allocation-free
    /// when the reweighting preserves the factor structure.
    pub fn factorize(&mut self, lap: &Laplacian) -> Result<LdlFactor, FactorError> {
        self.check_pattern(lap)?;
        let timer = Timer::start();
        self.refresh_values(lap);
        let mut stats = self.run_numeric_checked()?;
        stats.symbolic_secs = self.symbolic_secs;
        stats.numeric_secs = timer.secs();
        let (g, diag) = self.spare.take_factor(self.n);
        self.spare.reserve(g.colptr.len(), g.rowidx.len(), diag.len());
        Ok(LdlFactor { g, diag, perm: Some(self.perm.clone()), stats })
    }

    /// Re-run only the numeric phase on new weights and swap the result
    /// into `f` (which must come from this symbolic factorization).
    /// Returns `true` when the refreshed factor has the same sparsity
    /// structure as the one it replaced — the signal downstream layouts
    /// (packed sweeps) can be refilled instead of re-analyzed. The
    /// ordering, permutation map, and workspaces are all reused; no
    /// heap allocation happens unless the new weights grow the factor
    /// past previous capacities.
    pub fn refactorize_into(
        &mut self,
        lap: &Laplacian,
        f: &mut LdlFactor,
    ) -> Result<bool, FactorError> {
        self.check_pattern(lap)?;
        let timer = Timer::start();
        self.refresh_values(lap);
        let mut stats = self.run_numeric_checked()?;
        stats.symbolic_secs = 0.0;
        stats.symbolic_reused = true;
        stats.numeric_secs = timer.secs();
        let preserved =
            self.spare.colptr == f.g.colptr && self.spare.rowidx == f.g.rowidx;
        std::mem::swap(&mut f.g.colptr, &mut self.spare.colptr);
        std::mem::swap(&mut f.g.rowidx, &mut self.spare.rowidx);
        std::mem::swap(&mut f.g.data, &mut self.spare.data);
        std::mem::swap(&mut f.diag, &mut self.spare.diag);
        f.stats = stats;
        Ok(preserved)
    }

    /// Reject operators whose sparsity pattern differs from the one the
    /// analysis froze (values are free to change, structure is not).
    fn check_pattern(&self, lap: &Laplacian) -> Result<(), FactorError> {
        if lap.n() != self.n
            || lap.matrix.indptr != self.src_indptr
            || lap.matrix.indices != self.src_indices
        {
            return Err(FactorError::BadInput(
                "sparsity pattern differs from the symbolic analysis; \
                 run a full build for structural changes"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Gather the (possibly new) values into the permuted matrix —
    /// the entire per-reweighting cost of the permutation step.
    fn refresh_values(&mut self, lap: &Laplacian) {
        for (dst, &src) in self.permuted.data.iter_mut().zip(&self.val_map) {
            *dst = lap.matrix.data[src];
        }
    }

    /// [`SymbolicFactor::run_numeric`] wrapped in the fault probes and
    /// the always-on output audit. The overflow probes model an
    /// overflow that **escaped** the doubling retry (they surface the
    /// typed error without touching the real arena), the NaN probe
    /// poisons one packed value after a successful sweep, and the audit
    /// turns any non-finite produced value — injected or real — into a
    /// typed [`FactorError::Internal`] instead of letting it poison
    /// every downstream solve. With no fault plan installed the probes
    /// are three relaxed atomic loads and the audit one predictable
    /// O(nnz) pass (noise next to the sweep itself).
    fn run_numeric_checked(&mut self) -> Result<FactorStats, FactorError> {
        use crate::faults::{self, Site};
        let est_cap = (self.arena_factor * (self.permuted.nnz() + self.n) as f64) as usize;
        if faults::should_fire(Site::ArenaOverflow) {
            return Err(FactorError::ArenaFull { capacity: est_cap });
        }
        if faults::should_fire(Site::WorkspaceOverflow) {
            return Err(FactorError::WorkspaceFull { capacity: est_cap });
        }
        let stats = self.run_numeric()?;
        if faults::should_fire(Site::NanPackedValues) {
            if let Some(v) = self.spare.data.first_mut() {
                *v = f64::NAN;
            }
        }
        if self.spare.data.iter().chain(self.spare.diag.iter()).any(|v| !v.is_finite()) {
            return Err(FactorError::Internal(
                "factorization produced non-finite values".into(),
            ));
        }
        Ok(stats)
    }

    /// One engine sweep into the spare buffers, with the same
    /// arena-overflow retry policy as the one-shot path (the grown
    /// multiplier then persists for future runs).
    fn run_numeric(&mut self) -> Result<FactorStats, FactorError> {
        let o = &self.opts;
        loop {
            let r = match &mut self.ws {
                EngineWs::Seq(ws) => seq::factorize_into(
                    &self.permuted,
                    o.seed,
                    o.sort_by_weight,
                    ws,
                    &mut self.spare,
                ),
                EngineWs::Cpu(ws) => cpu::factorize_into(
                    &self.permuted,
                    o.seed,
                    o.sort_by_weight,
                    o.stage_timing,
                    ws,
                    &mut self.spare,
                ),
                EngineWs::Gpu(ws) => gpusim::factorize_into(
                    &self.permuted,
                    o.seed,
                    o.sort_by_weight,
                    o.stage_timing,
                    ws,
                    &mut self.spare,
                ),
            };
            match r {
                Err(FactorError::ArenaFull { .. }) | Err(FactorError::WorkspaceFull { .. }) => {
                    // Double until a generous hard ceiling (a dense
                    // 2^9×(nnz+n) arena means the input is far outside
                    // AC's regime).
                    let next = self.arena_factor * 2.0;
                    if next > 512.0 {
                        let cap =
                            (next * (self.permuted.nnz() + self.n) as f64) as usize;
                        return Err(FactorError::ArenaFull { capacity: cap });
                    }
                    self.arena_factor = next;
                    self.ws = EngineWs::new(&self.permuted, o, next);
                    continue;
                }
                other => return other,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, Engine, ParacOptions};
    use crate::graph::generators;
    use crate::ordering::Ordering;

    fn opts(engine: Engine) -> ParacOptions {
        ParacOptions { engine, ordering: Ordering::NnzSort, seed: 17, ..Default::default() }
    }

    fn reweight(lap: &Laplacian, scale: impl Fn(usize) -> f64) -> Laplacian {
        let edges: Vec<(u32, u32, f64)> = lap
            .edges()
            .into_iter()
            .enumerate()
            .map(|(i, (a, b, w))| (a, b, w * scale(i)))
            .collect();
        Laplacian::from_edges(lap.n(), &edges, "reweighted")
    }

    #[test]
    fn two_phase_build_matches_one_shot() {
        let lap = generators::random_connected(120, 200, 5);
        for engine in [Engine::Seq, Engine::Cpu { threads: 2 }, Engine::GpuSim { blocks: 2 }] {
            let o = opts(engine);
            let one = factorize(&lap, &o).unwrap();
            let mut sym = SymbolicFactor::analyze(&lap, &o).unwrap();
            let two = sym.factorize(&lap).unwrap();
            assert_eq!(one.g, two.g, "{engine:?}");
            assert_eq!(one.diag, two.diag);
            assert_eq!(one.perm, two.perm);
            assert!(two.stats.symbolic_secs > 0.0);
            assert!(!two.stats.symbolic_reused);
        }
    }

    #[test]
    fn refactorize_same_weights_is_bit_identical() {
        let lap = generators::grid2d(16, 16, generators::Coeff::Uniform, 3);
        let o = opts(Engine::Cpu { threads: 2 });
        let mut sym = SymbolicFactor::analyze(&lap, &o).unwrap();
        let mut f = sym.factorize(&lap).unwrap();
        let g0 = f.g.clone();
        let d0 = f.diag.clone();
        let preserved = sym.refactorize_into(&lap, &mut f).unwrap();
        assert!(preserved, "identical weights must preserve the structure");
        assert_eq!(f.g, g0);
        assert_eq!(f.diag, d0);
        assert!(f.stats.symbolic_reused);
        assert_eq!(f.stats.symbolic_secs, 0.0);
    }

    #[test]
    fn refactorize_new_weights_matches_fresh_build() {
        let lap = generators::random_connected(90, 140, 8);
        let lap2 = reweight(&lap, |i| 1.0 + (i % 7) as f64 * 0.35);
        for engine in [Engine::Seq, Engine::Cpu { threads: 2 }, Engine::GpuSim { blocks: 2 }] {
            let o = opts(engine);
            let mut sym = SymbolicFactor::analyze(&lap, &o).unwrap();
            let mut f = sym.factorize(&lap).unwrap();
            sym.refactorize_into(&lap2, &mut f).unwrap();
            let fresh = factorize(&lap2, &o).unwrap();
            assert_eq!(f.g, fresh.g, "{engine:?}");
            assert_eq!(f.diag, fresh.diag);
        }
    }

    #[test]
    fn pattern_change_is_rejected() {
        let lap = generators::random_connected(40, 60, 1);
        let other = generators::random_connected(40, 70, 2);
        let o = opts(Engine::Seq);
        let mut sym = SymbolicFactor::analyze(&lap, &o).unwrap();
        let mut f = sym.factorize(&lap).unwrap();
        assert!(sym.refactorize_into(&other, &mut f).is_err());
    }

    #[test]
    fn uniform_scaling_preserves_structure_exactly() {
        // ×2 is an exact power of two: every CDF comparison scales
        // exactly, so sampling makes identical choices and the factor
        // structure (and G values) are bitwise unchanged, diag doubled.
        let lap = generators::grid2d(14, 14, generators::Coeff::Uniform, 2);
        let lap2 = reweight(&lap, |_| 2.0);
        let o = opts(Engine::Seq);
        let mut sym = SymbolicFactor::analyze(&lap, &o).unwrap();
        let mut f = sym.factorize(&lap).unwrap();
        let g0 = f.g.clone();
        let d0 = f.diag.clone();
        let preserved = sym.refactorize_into(&lap2, &mut f).unwrap();
        assert!(preserved);
        assert_eq!(f.g, g0, "G is scale-invariant");
        for (a, b) in f.diag.iter().zip(&d0) {
            assert_eq!(*a, 2.0 * b);
        }
    }

    #[test]
    fn arena_retry_persists_across_refactorizations() {
        let lap = generators::complete(40);
        let mut o = opts(Engine::Cpu { threads: 2 });
        o.arena_factor = 0.05; // force at least one overflow-retry
        let mut sym = SymbolicFactor::analyze(&lap, &o).unwrap();
        let mut f = sym.factorize(&lap).unwrap();
        assert!(sym.arena_factor > 0.05, "retry must have grown the arena");
        let grown = sym.arena_factor;
        sym.refactorize_into(&lap, &mut f).unwrap();
        assert_eq!(sym.arena_factor, grown, "no re-growth on the second run");
        f.validate().unwrap();
    }
}
