//! Iterative solvers for the factored systems.
//!
//! * [`linop`] — the [`linop::LinearOperator`] abstraction PCG iterates
//!   with: `Csr` implements it, and matrix-free operators plug in by
//!   implementing `n()` + `apply_to()`.
//! * [`pcg`] — preconditioned conjugate gradients with optional
//!   mean-zero nullspace projection (singular graph Laplacians) and a
//!   recomputed true-residual check on exit. The vector passes are
//!   fused ([`crate::sparse::ops`]): the α-update of `x` and `r` shares
//!   one pass with the residual norm, and the projection folds into the
//!   search-direction update — roughly half the full-vector memory
//!   traffic per iteration, bit-identical to the unfused kernels.
//!   [`pcg::solve_into`] + [`pcg::PcgWorkspace`] is the allocation-free
//!   session primitive that [`crate::solver::Solver`] drives;
//!   [`pcg::random_rhs`] builds the reproducible unit-norm right-hand
//!   sides every experiment uses.
//! * [`packed`] — the **packed sweep executor**: triangular sweeps over
//!   a contiguous level-major copy of the factor, one persistent-pool
//!   dispatch per sweep with resident workers barrier-syncing at level
//!   boundaries (paper §6.2 / §5.1 persistent-kernel analogue). This is
//!   what the ParAC preconditioner applies in level-scheduled mode.
//! * [`trisolve`] — the level-schedule analysis and the reference
//!   per-level executor ([`trisolve::LevelSchedule`]): one pool
//!   dispatch per sufficiently wide level, kept bit-identical to the
//!   packed path for comparison benches and property tests. The
//!   sequential alternative lives on [`crate::factor::LdlFactor`]
//!   itself (`forward_inplace` / `backward_inplace` / `solve` /
//!   `solve_into`).

pub mod linop;
pub mod packed;
pub mod pcg;
pub mod trisolve;

pub use linop::LinearOperator;
