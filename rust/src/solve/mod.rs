//! Iterative solvers: preconditioned conjugate gradients and (level-
//! scheduled) sparse triangular solves.

pub mod pcg;
pub mod trisolve;
