//! Iterative solvers for the factored systems.
//!
//! * [`pcg`] — preconditioned conjugate gradients with optional
//!   mean-zero nullspace projection (singular graph Laplacians) and a
//!   recomputed true-residual check on exit; [`pcg::random_rhs`] builds
//!   the reproducible unit-norm right-hand sides every experiment uses.
//! * [`trisolve`] — level-scheduled parallel triangular solves with the
//!   unit-lower factor `G`: [`trisolve::LevelSchedule`] groups columns
//!   by depth in the solve DAG once per factor ("analysis"), then
//!   forward/backward sweeps run each level in parallel — mirroring
//!   cuSPARSE's SPSV analysis/solve split (paper §6.2). The sequential
//!   alternative lives on [`crate::factor::LdlFactor`] itself
//!   (`forward_inplace` / `backward_inplace` / `solve`).

pub mod pcg;
pub mod trisolve;
