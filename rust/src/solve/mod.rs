//! Iterative solvers for the factored systems.
//!
//! * [`linop`] — the [`linop::LinearOperator`] abstraction PCG iterates
//!   with: `Csr` implements it, and matrix-free operators plug in by
//!   implementing `n()` + `apply_to()`.
//! * [`pcg`] — preconditioned conjugate gradients with optional
//!   mean-zero nullspace projection (singular graph Laplacians) and a
//!   recomputed true-residual check on exit. [`pcg::solve_into`] +
//!   [`pcg::PcgWorkspace`] is the allocation-free session primitive
//!   that [`crate::solver::Solver`] drives; [`pcg::random_rhs`] builds
//!   the reproducible unit-norm right-hand sides every experiment uses.
//! * [`trisolve`] — level-scheduled parallel triangular solves with the
//!   unit-lower factor `G`: [`trisolve::LevelSchedule`] groups columns
//!   by depth in the solve DAG once per factor ("analysis"), then
//!   forward/backward sweeps dispatch each sufficiently wide level onto
//!   the persistent [`crate::par`] worker pool — mirroring cuSPARSE's
//!   SPSV analysis/solve split (paper §6.2), with no thread spawns and
//!   no allocation per sweep. Both sweeps operate in place on caller
//!   buffers. The sequential alternative lives on
//!   [`crate::factor::LdlFactor`] itself (`forward_inplace` /
//!   `backward_inplace` / `solve` / `solve_into`).

pub mod linop;
pub mod pcg;
pub mod trisolve;

pub use linop::LinearOperator;
