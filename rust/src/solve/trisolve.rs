//! Sparse triangular solves with the unit-lower factor `G`.
//!
//! Two schedules:
//! * sequential CSC forward/backward (the LdlFactor built-ins), and
//! * **level-scheduled parallel** solves: vertices grouped by their
//!   depth in the triangular-solve DAG (paper §6.2 — GPU triangular
//!   solve performance is governed by the DAG's critical path, which is
//!   why nnz-sort/random beat AMD on the GPU).
//!
//! The level schedule is computed once per factor and reused across PCG
//! iterations, mirroring cuSPARSE's analysis + solve split.

use crate::etree;
use crate::factor::LdlFactor;
use crate::sparse::Csr;

/// Precomputed level schedule for both sweeps of `G D Gᵀ` solves.
pub struct LevelSchedule {
    /// Rows of `G` (strictly lower), CSR — forward sweep reads rows.
    g_rows: Csr,
    /// Columns of `G` (strictly lower), CSC — backward sweep reads cols.
    g_cols: crate::sparse::Csc,
    /// Vertices grouped by forward level, concatenated.
    fwd_order: Vec<u32>,
    /// Level boundaries into `fwd_order`.
    fwd_ptr: Vec<usize>,
    /// Vertices grouped by backward level.
    bwd_order: Vec<u32>,
    /// Level boundaries into `bwd_order`.
    bwd_ptr: Vec<usize>,
    /// Critical path length (number of forward levels).
    pub critical_path: usize,
}

impl LevelSchedule {
    /// Analyze a factor (the "analysis phase").
    pub fn analyze(f: &LdlFactor) -> LevelSchedule {
        let n = f.n();
        let (fwd_levels, maxl) = etree::trisolve_levels(&f.g);
        // Backward sweep dependencies are the transpose DAG: level from
        // the other end. bwd_level[k] = 1 + max over rows r in col k of
        // bwd_level[r].
        let mut bwd_levels = vec![1u32; n];
        let mut bmax = 1u32;
        for k in (0..n).rev() {
            let mut l = 1u32;
            for &r in f.g.col_rows(k) {
                let lr = bwd_levels[r as usize];
                if lr + 1 > l {
                    l = lr + 1;
                }
            }
            bwd_levels[k] = l;
            bmax = bmax.max(l);
        }
        let bucket = |levels: &[u32], maxl: usize| {
            // ptr[t] = start offset of level t+1 (levels are 1-based).
            let mut ptr = vec![0usize; maxl + 1];
            for &l in levels {
                ptr[(l - 1) as usize] += 1;
            }
            let mut acc = 0;
            for p in ptr.iter_mut() {
                let c = *p;
                *p = acc;
                acc += c;
            }
            let mut order = vec![0u32; levels.len()];
            let mut cursor = ptr.clone();
            for (v, &l) in levels.iter().enumerate() {
                order[cursor[(l - 1) as usize]] = v as u32;
                cursor[(l - 1) as usize] += 1;
            }
            (order, ptr)
        };
        let (fwd_order, fwd_ptr) = bucket(&fwd_levels, maxl);
        let (bwd_order, bwd_ptr) = bucket(&bwd_levels, bmax as usize);
        LevelSchedule {
            g_rows: f.g.clone().transpose_view_csr().transpose(),
            g_cols: f.g.clone(),
            fwd_order,
            fwd_ptr,
            bwd_order,
            bwd_ptr,
            critical_path: maxl,
        }
    }

    /// Forward solve `G y = r` in place using the level schedule with
    /// `threads` workers.
    pub fn forward(&self, y: &mut [f64], threads: usize) {
        // y[k] = r[k] − Σ_{j<k} G[k,j]·y[j]; all k in a level are
        // independent.
        let yptr = SendPtr(y.as_mut_ptr());
        for lev in 0..self.fwd_ptr.len() - 1 {
            let verts = &self.fwd_order[self.fwd_ptr[lev]..self.fwd_ptr[lev + 1]];
            parallel_chunks(verts, threads, |v| {
                let k = v as usize;
                // SAFETY: level discipline — all reads are from earlier
                // levels, the single write is to this vertex's slot.
                unsafe {
                    let mut acc = yptr.get(k);
                    for (&j, &g) in
                        self.g_rows.row_indices(k).iter().zip(self.g_rows.row_data(k))
                    {
                        acc -= g * yptr.get(j as usize);
                    }
                    yptr.set(k, acc);
                }
            });
        }
    }

    /// Backward solve `Gᵀ z = y` in place using the level schedule.
    pub fn backward(&self, y: &mut [f64], threads: usize) {
        // z[k] = y[k] − Σ_{r>k} G[r,k]·z[r]; read column k of G.
        let yptr = SendPtr(y.as_mut_ptr());
        let g = &self.g_cols;
        for lev in 0..self.bwd_ptr.len() - 1 {
            let verts = &self.bwd_order[self.bwd_ptr[lev]..self.bwd_ptr[lev + 1]];
            parallel_chunks(verts, threads, |v| {
                let k = v as usize;
                // SAFETY: level discipline (transpose DAG).
                unsafe {
                    let mut acc = yptr.get(k);
                    for (&r, &gv) in g.col_rows(k).iter().zip(g.col_data(k)) {
                        acc -= gv * yptr.get(r as usize);
                    }
                    yptr.set(k, acc);
                }
            });
        }
    }

}

/// Pointer wrapper so level workers can write disjoint entries.
#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// Read entry `i`.
    ///
    /// # Safety
    /// Caller guarantees no concurrent write to `i`.
    #[inline]
    unsafe fn get(&self, i: usize) -> f64 {
        *self.0.add(i)
    }

    /// Write entry `i`.
    ///
    /// # Safety
    /// Caller guarantees exclusive access to `i` (level discipline).
    #[inline]
    unsafe fn set(&self, i: usize, v: f64) {
        *self.0.add(i) = v;
    }
}

/// Run `f(v)` for every vertex in `verts`, split across `threads`.
fn parallel_chunks(verts: &[u32], threads: usize, f: impl Fn(u32) + Sync) {
    let threads = threads.max(1);
    if threads == 1 || verts.len() < 256 {
        for &v in verts {
            f(v);
        }
        return;
    }
    let chunk = verts.len().div_ceil(threads);
    std::thread::scope(|s| {
        for part in verts.chunks(chunk) {
            let f = &f;
            s.spawn(move || {
                for &v in part {
                    f(v);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, Engine, ParacOptions};
    use crate::graph::generators;

    #[test]
    fn level_solve_matches_sequential_solve() {
        let l = generators::grid2d(16, 16, generators::Coeff::Uniform, 0);
        let f = factorize(
            &l,
            &ParacOptions { engine: Engine::Seq, ..Default::default() },
        )
        .unwrap();
        let sched = LevelSchedule::analyze(&f);
        let n = f.n();
        let r: Vec<f64> = (0..n).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();

        // Sequential reference (operate in permuted space directly).
        let mut want = crate::ordering::perm::apply_vec(f.perm.as_ref().unwrap(), &r);
        f.forward_inplace(&mut want);
        let mut lvl = crate::ordering::perm::apply_vec(f.perm.as_ref().unwrap(), &r);
        sched.forward(&mut lvl, 4);
        for (a, b) in want.iter().zip(&lvl) {
            assert!((a - b).abs() < 1e-12);
        }

        f.backward_inplace(&mut want);
        sched.backward(&mut lvl, 4);
        for (a, b) in want.iter().zip(&lvl) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn critical_path_matches_etree_levels() {
        let l = generators::random_connected(200, 260, 7);
        let f = factorize(
            &l,
            &ParacOptions { engine: Engine::Seq, ..Default::default() },
        )
        .unwrap();
        let sched = LevelSchedule::analyze(&f);
        let (_, cp) = crate::etree::trisolve_levels(&f.g);
        assert_eq!(sched.critical_path, cp);
    }
}
