//! Level-scheduled triangular solves — the analysis phase and the
//! **reference** per-level executor behind the packed production path.
//!
//! The solve phase of the paper (§6.2, Table 3's SPSV analysis/solve
//! split) is governed by the triangular-solve DAG: vertices grouped by
//! depth can be eliminated concurrently, and the critical path bounds
//! any parallel sweep (which is why nnz-sort/random orderings beat AMD
//! on the GPU). Two executors share that analysis:
//!
//! * [`crate::solve::packed::PackedSweeps`] — the **production**
//!   executor. At analysis time it renumbers vertices into level order
//!   and copies the factor into contiguous level-major `ptr/idx/val`
//!   arrays per sweep direction, then executes each whole sweep as
//!   **one** persistent-pool dispatch, barrier-syncing the resident
//!   workers at level boundaries ([`crate::par::SweepBarrier`]). O(1)
//!   dispatches per sweep, streaming memory access, `D⁻¹` and the
//!   fill-reducing permutation fused into the boundary/scatter passes.
//! * [`LevelSchedule`] (this module) — the pre-packing executor, kept
//!   as the bit-identical reference: the factor stays in elimination
//!   order, each sufficiently wide level is its own pool dispatch, and
//!   rows are gathered through `order[]` indirection. Comparison
//!   benches (`benches/bench_precond_apply.rs`) and property tests
//!   drive both paths against each other; production code should reach
//!   for the packed executor.
//!
//! Both executors compute results bit-identical to the sequential
//! sweeps on [`crate::factor::LdlFactor`]: level scheduling and packing
//! permute *storage and execution*, never the per-entry accumulation
//! order. The schedule is computed once per factor and reused across
//! PCG iterations, mirroring cuSPARSE's analysis + solve split.

use crate::etree;
use crate::factor::LdlFactor;
use crate::par::{self, SendPtr};
use crate::sparse::{Csc, Csr};

/// Default minimum level width dispatched in parallel — below this many
/// vertices a level runs sequentially on the calling (or resident-0)
/// thread, where dispatch/barrier latency would dominate the
/// arithmetic. Tunable per solver session via
/// [`crate::solver::SolverBuilder::level_cutoff`] or the
/// `PARAC_LEVEL_CUTOFF` environment variable (see
/// [`crate::solve::packed::default_cutoff`]).
pub const LEVEL_PAR_CUTOFF: usize = 256;

/// Precomputed level schedule for both sweeps of `G D Gᵀ` solves (the
/// reference per-level executor; see the module docs).
///
/// Stores `G` row-wise (CSR) for the forward sweep; the backward sweep
/// reads columns and borrows the factor's CSC storage per call, so the
/// schedule holds exactly one extra copy of the factor structure.
pub struct LevelSchedule {
    /// Rows of `G` (strictly lower), CSR — forward sweep reads rows.
    g_rows: Csr,
    /// Vertices grouped by forward level, concatenated.
    fwd_order: Vec<u32>,
    /// Level boundaries into `fwd_order`.
    fwd_ptr: Vec<usize>,
    /// Vertices grouped by backward level.
    bwd_order: Vec<u32>,
    /// Level boundaries into `bwd_order`.
    bwd_ptr: Vec<usize>,
    /// Critical path length (number of forward levels).
    pub critical_path: usize,
}

impl LevelSchedule {
    /// Analyze a factor (the "analysis phase"): forward levels from the
    /// solve DAG, backward levels from its transpose, vertices bucketed
    /// level-major.
    pub fn analyze(f: &LdlFactor) -> LevelSchedule {
        let (fwd_levels, maxl) = etree::trisolve_levels(&f.g);
        let (bwd_levels, bmax) = etree::trisolve_levels_bwd(&f.g);
        let (fwd_order, fwd_ptr) = etree::bucket_by_level(&fwd_levels, maxl);
        let (bwd_order, bwd_ptr) = etree::bucket_by_level(&bwd_levels, bmax);
        LevelSchedule {
            // Single direct CSC→CSR transpose of the borrowed factor —
            // no intermediate clones of `G` are materialized.
            g_rows: f.g.to_csr(),
            fwd_order,
            fwd_ptr,
            bwd_order,
            bwd_ptr,
            critical_path: maxl,
        }
    }

    /// Forward solve `G y = r` in place using the level schedule with
    /// up to `threads` pool workers (one dispatch per wide level — the
    /// pre-packed cost model).
    pub fn forward(&self, y: &mut [f64], threads: usize) {
        // y[k] = r[k] − Σ_{j<k} G[k,j]·y[j]; all k in a level are
        // independent.
        let yptr = SendPtr::new(y.as_mut_ptr());
        for lev in 0..self.fwd_ptr.len() - 1 {
            let verts = &self.fwd_order[self.fwd_ptr[lev]..self.fwd_ptr[lev + 1]];
            parallel_chunks(verts, threads, |v| {
                let k = v as usize;
                // SAFETY: level discipline — all reads are from earlier
                // levels, the single write is to this vertex's slot.
                unsafe {
                    let mut acc = yptr.read(k);
                    for (&j, &g) in
                        self.g_rows.row_indices(k).iter().zip(self.g_rows.row_data(k))
                    {
                        acc -= g * yptr.read(j as usize);
                    }
                    yptr.write(k, acc);
                }
            });
        }
    }

    /// Backward solve `Gᵀ z = y` in place using the level schedule;
    /// `g` is the factor's own CSC storage (strictly lower), borrowed
    /// rather than copied into the schedule.
    pub fn backward(&self, g: &Csc, y: &mut [f64], threads: usize) {
        // z[k] = y[k] − Σ_{r>k} G[r,k]·z[r]; read column k of G.
        debug_assert_eq!(g.ncols, self.g_rows.nrows);
        let yptr = SendPtr::new(y.as_mut_ptr());
        for lev in 0..self.bwd_ptr.len() - 1 {
            let verts = &self.bwd_order[self.bwd_ptr[lev]..self.bwd_ptr[lev + 1]];
            parallel_chunks(verts, threads, |v| {
                let k = v as usize;
                // SAFETY: level discipline (transpose DAG).
                unsafe {
                    let mut acc = yptr.read(k);
                    for (&r, &gv) in g.col_rows(k).iter().zip(g.col_data(k)) {
                        acc -= gv * yptr.read(r as usize);
                    }
                    yptr.write(k, acc);
                }
            });
        }
    }
}

/// Run `f(v)` for every vertex in `verts`, split across up to
/// `threads` persistent pool workers (sequential below the
/// [`LEVEL_PAR_CUTOFF`]). Allocation-free: the pool dispatch borrows
/// the closure from this stack frame.
fn parallel_chunks(verts: &[u32], threads: usize, f: impl Fn(u32) + Sync) {
    let threads = threads.max(1);
    if threads == 1 || verts.len() < LEVEL_PAR_CUTOFF {
        for &v in verts {
            f(v);
        }
        return;
    }
    par::global().run(threads, |part, parts| {
        let (lo, hi) = par::chunk_range(verts.len(), part, parts);
        for &v in &verts[lo..hi] {
            f(v);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, Engine, ParacOptions};
    use crate::graph::generators;

    #[test]
    fn level_solve_matches_sequential_solve() {
        let l = generators::grid2d(16, 16, generators::Coeff::Uniform, 0);
        let f = factorize(
            &l,
            &ParacOptions { engine: Engine::Seq, ..Default::default() },
        )
        .unwrap();
        let sched = LevelSchedule::analyze(&f);
        let n = f.n();
        let r: Vec<f64> = (0..n).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();

        // Sequential reference (operate in permuted space directly).
        let mut want = crate::ordering::perm::apply_vec(f.perm.as_ref().unwrap(), &r);
        f.forward_inplace(&mut want);
        let mut lvl = crate::ordering::perm::apply_vec(f.perm.as_ref().unwrap(), &r);
        sched.forward(&mut lvl, 4);
        for (a, b) in want.iter().zip(&lvl) {
            assert!((a - b).abs() < 1e-12);
        }

        f.backward_inplace(&mut want);
        sched.backward(&f.g, &mut lvl, 4);
        for (a, b) in want.iter().zip(&lvl) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn wide_levels_dispatch_through_the_pool() {
        // A star with the hub eliminated last has one level of width
        // n − 1, guaranteed past the parallel cutoff — so this
        // exercises the pool dispatch path, not just the sequential
        // fallback.
        let n = 6 * LEVEL_PAR_CUTOFF + 1;
        let hub = (n - 1) as u32;
        let edges: Vec<(u32, u32, f64)> =
            (0..hub).map(|i| (i, hub, 1.0 + (i % 5) as f64)).collect();
        let l = crate::graph::Laplacian::from_edges(n, &edges, "star");
        let f = factorize(
            &l,
            &ParacOptions {
                engine: Engine::Seq,
                ordering: crate::ordering::Ordering::Natural,
                ..Default::default()
            },
        )
        .unwrap();
        let sched = LevelSchedule::analyze(&f);
        let widest = (0..sched.fwd_ptr.len() - 1)
            .map(|lev| sched.fwd_ptr[lev + 1] - sched.fwd_ptr[lev])
            .max()
            .unwrap();
        assert!(widest >= LEVEL_PAR_CUTOFF, "widest level {widest}");
        let r: Vec<f64> = (0..n).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
        let mut want = crate::ordering::perm::apply_vec(f.perm.as_ref().unwrap(), &r);
        let mut got = want.clone();
        f.forward_inplace(&mut want);
        sched.forward(&mut got, 4);
        assert_eq!(want, got, "pool-dispatched forward sweep must be bit-identical");
        f.backward_inplace(&mut want);
        sched.backward(&f.g, &mut got, 4);
        assert_eq!(want, got, "pool-dispatched backward sweep must be bit-identical");
    }

    #[test]
    fn critical_path_matches_etree_levels() {
        let l = generators::random_connected(200, 260, 7);
        let f = factorize(
            &l,
            &ParacOptions { engine: Engine::Seq, ..Default::default() },
        )
        .unwrap();
        let sched = LevelSchedule::analyze(&f);
        let (_, cp) = crate::etree::trisolve_levels(&f.g);
        assert_eq!(sched.critical_path, cp);
    }
}
