//! The abstract operator PCG iterates with.
//!
//! [`LinearOperator`] is the minimal contract the Krylov loop needs: a
//! dimension and an allocation-free `y = A x`. [`crate::sparse::Csr`]
//! implements it (so every existing call site keeps working), and any
//! matrix-free operator — a stencil, a composed product, an operator
//! living on an accelerator — can plug into [`crate::solve::pcg`] and
//! [`crate::solver::Solver`] by implementing these two methods.

use crate::sparse::Csr;

/// A square linear operator `x ↦ A x`, applied into a caller buffer.
pub trait LinearOperator: Sync {
    /// Dimension of the (square) operator.
    fn n(&self) -> usize;

    /// `y = A x`. Implementations must overwrite every element of `y`
    /// and must not allocate — this runs once per PCG iteration.
    fn apply_to(&self, x: &[f64], y: &mut [f64]);
}

impl LinearOperator for Csr {
    fn n(&self) -> usize {
        self.nrows
    }

    fn apply_to(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    /// A matrix-free 1D Laplacian stencil (path graph).
    struct PathStencil(usize);

    impl LinearOperator for PathStencil {
        fn n(&self) -> usize {
            self.0
        }
        fn apply_to(&self, x: &[f64], y: &mut [f64]) {
            let n = self.0;
            for i in 0..n {
                let left = if i > 0 { x[i - 1] } else { 0.0 };
                let right = if i + 1 < n { x[i + 1] } else { 0.0 };
                let deg = (i > 0) as u32 as f64 + (i + 1 < n) as u32 as f64;
                y[i] = deg * x[i] - left - right;
            }
        }
    }

    #[test]
    fn csr_apply_matches_mul_vec() {
        let l = generators::grid2d(5, 5, generators::Coeff::Uniform, 0);
        let x: Vec<f64> = (0..l.n()).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut y = vec![0.0; l.n()];
        l.matrix.apply_to(&x, &mut y);
        assert_eq!(y, l.matrix.mul_vec(&x));
    }

    #[test]
    fn matrix_free_stencil_matches_assembled_path() {
        let lap = generators::path(16);
        let st = PathStencil(16);
        let x: Vec<f64> = (0..16).map(|i| i as f64 - 8.0).collect();
        let mut y = vec![0.0; 16];
        st.apply_to(&x, &mut y);
        assert_eq!(y, lap.matrix.mul_vec(&x));
    }
}
