//! The packed sweep executor: whole triangular sweeps as **one** pool
//! dispatch over a contiguous, level-major copy of the factor.
//!
//! This is the production preconditioner-apply path (paper §6.2, the
//! SPSV analysis/solve split of Table 3). The pre-packed executor
//! ([`crate::solve::trisolve::LevelSchedule`], kept as the reference)
//! leaves the factor in elimination order and pays two costs per PCG
//! iteration that this module removes:
//!
//! * **O(levels) pool dispatches per sweep.** Each level used to be its
//!   own [`crate::par`] job; deep DAGs (AMD orderings, 3-D grids) have
//!   hundreds of levels, so dispatch latency — not arithmetic — bounded
//!   the sweep. Here a sweep is **one** dispatch: the participants stay
//!   resident across every level and synchronize at level boundaries on
//!   a [`SweepBarrier`], the CPU analogue of the paper's persistent GPU
//!   kernel grid-syncing between dependency levels. Runs of levels
//!   narrower than the [cutoff](PackedSweeps::cutoff) execute
//!   sequentially on participant 0 behind the barrier instead of
//!   costing anything extra, and a factor whose levels are *all* narrow
//!   skips the pool entirely (zero dispatches).
//! * **Scattered memory traffic.** The level schedule used to gather
//!   rows through `order[]` indirection, hopping over the factor in
//!   elimination order. At analysis time this module *renumbers the
//!   vertices into level order* and copies rows/columns into contiguous
//!   `ptr/idx/val` arrays per sweep direction, so a sweep streams both
//!   the factor and the solution vector front to back. The input/output
//!   scatter of [`PackedSweeps::apply_into`] composes the fill-reducing
//!   permutation with the level renumbering into a single index map
//!   (one gather in, one scatter out — not two), and the `D⁻¹` scaling
//!   is fused into the forward→backward boundary pass.
//!
//! Every result is **bit-identical** to the sequential reference
//! ([`crate::factor::LdlFactor::forward_inplace`] /
//! [`backward_inplace`](crate::factor::LdlFactor::backward_inplace)):
//! packing permutes *storage*, never the per-entry accumulation order
//! (row/column entries keep their original ascending-neighbor order).
//! Property-tested across engines, orderings, and thread counts in
//! `rust/tests/properties.rs`. One pedantic caveat, shared with the
//! reference executor: `forward_inplace` skips source columns whose
//! value is exactly `0.0`, while the gather formulations subtract
//! `v·0.0`; for an accumulator holding `-0.0` that turns `-0.0` into
//! `+0.0`, so equality is `==`-exact (what the tests pin) but the sign
//! of a zero can differ. No downstream arithmetic observes it.
//!
//! The executor is allocation-free after construction — sweeps borrow
//! caller buffers and the barrier is two atomics — so it lives inside
//! the solve path's zero-allocation contract
//! (`rust/tests/alloc_free.rs`). Dispatch and barrier counts are
//! recorded per executor ([`PackedSweeps::counters`]) and surfaced
//! through the solver stats, making the O(1)-dispatch claim observable.
//!
//! **Value storage is generic** over the sealed
//! [`Scalar`](crate::sparse::Scalar) layer: `PackedSweeps<f64>` (the
//! default) stores 8-byte values and keeps every bit-identity claim
//! above verbatim (`f64`'s conversions are the identity), while
//! `PackedSweeps<f32>` halves the bytes of the packed `val`/`diag`
//! arrays — the dominant traffic of this bandwidth-bound kernel —
//! and *accumulates in f64* (each loaded value widens before the
//! multiply-subtract). The f32 plane trades bit-identity for the
//! residual contract documented in [`crate::sparse::scalar`]; the
//! sweep structure, schedules, and dispatch economics are identical
//! in both planes.

use crate::etree;
use crate::factor::LdlFactor;
use crate::par::{self, SendPtr, SweepBarrier};
use crate::solve::trisolve::LEVEL_PAR_CUTOFF;
use crate::sparse::scalar::{Precision, Scalar};
use std::sync::atomic::{AtomicU64, Ordering};

/// Cumulative dispatch/barrier counts of one [`PackedSweeps`] executor
/// (snapshot of relaxed counters; subtract two snapshots for a
/// per-apply delta). One preconditioner apply with at least one level
/// past the cutoff costs exactly **2 dispatches** (one per sweep
/// direction) regardless of level count; an all-narrow factor costs 0.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepCounters {
    /// Pool jobs published (one per pooled sweep).
    pub dispatches: u64,
    /// In-sweep level-boundary barrier episodes.
    pub barriers: u64,
}

impl SweepCounters {
    /// Counts accumulated since an `earlier` snapshot.
    pub fn since(self, earlier: SweepCounters) -> SweepCounters {
        SweepCounters {
            dispatches: self.dispatches - earlier.dispatches,
            barriers: self.barriers - earlier.barriers,
        }
    }
}

/// The default level-width cutoff: the `PARAC_LEVEL_CUTOFF` environment
/// variable when set to a positive integer, otherwise
/// [`LEVEL_PAR_CUTOFF`]. Builders resolve this once at analysis time;
/// an explicit [`crate::solver::SolverBuilder::level_cutoff`] wins over
/// the environment.
pub fn default_cutoff() -> usize {
    cutoff_from(std::env::var("PARAC_LEVEL_CUTOFF").ok().as_deref())
}

/// Parse an optional `PARAC_LEVEL_CUTOFF` value (pure helper behind
/// [`default_cutoff`]; non-numeric values fall back). `0` means "fully
/// parallel" — it clamps to a cutoff of 1, so every non-empty level
/// clears the threshold and the whole sweep runs on the pool.
fn cutoff_from(var: Option<&str>) -> usize {
    match var.and_then(|s| s.parse::<usize>().ok()) {
        Some(0) => 1,
        Some(c) => c,
        None => LEVEL_PAR_CUTOFF,
    }
}

/// One sweep direction of the packed factor: vertices renumbered into
/// level-major order, rows (forward) or columns (backward) copied into
/// contiguous CSR-style arrays whose indices are packed positions.
/// Levels are contiguous position ranges, so the schedule needs no
/// `order[]` indirection at solve time.
struct PackedTri<S: Scalar> {
    /// Entry pointer per packed position (`len = n + 1`).
    ptr: Vec<usize>,
    /// Dependency packed positions (always < the consuming position).
    idx: Vec<u32>,
    /// Factor values in storage precision, parallel to `idx`, in the
    /// original ascending neighbor order (f64-identical accumulation
    /// order; the values themselves round only for `S = f32`).
    val: Vec<S>,
    /// Level boundaries in packed positions (`lev_ptr[t]..lev_ptr[t+1]`
    /// is level `t`).
    lev_ptr: Vec<usize>,
    /// Any level at least as wide as the cutoff? If not, the sweep
    /// never pays a pool dispatch.
    any_wide: bool,
}

impl<S: Scalar> PackedTri<S> {
    /// Pack one direction: position `i` holds vertex `order[i]`, whose
    /// dependency list is supplied by `entries(vertex)` (row of the CSR
    /// forward view, column of the CSC backward view) and remapped
    /// through `pos`; values narrow into storage precision on copy.
    /// With `threads > 1` and a large enough factor the level-major
    /// copy runs on the worker pool — two passes (exact per-position
    /// sizing, then a disjoint parallel fill), so the result is
    /// **bit-identical** to the sequential pass at every thread count.
    fn build<'a>(
        order: &[u32],
        lev_ptr: Vec<usize>,
        pos: &[u32],
        entries: impl Fn(usize) -> (&'a [u32], &'a [f64]) + Sync,
        cutoff: usize,
        threads: usize,
    ) -> PackedTri<S> {
        let n = order.len();
        let pool = par::global();
        let parts = threads.max(1).min(pool.size()).min(n.max(1));
        // Pass 1: exact entry pointer — dependency-list lengths come
        // straight from the factor's index pointers.
        let mut ptr = vec![0usize; n + 1];
        for (i, &v) in order.iter().enumerate() {
            ptr[i + 1] = entries(v as usize).0.len();
        }
        for i in 0..n {
            ptr[i + 1] += ptr[i];
        }
        let total = ptr[n];
        let mut idx = vec![0u32; total];
        let mut val = vec![S::from_f64(0.0); total];
        if parts <= 1 || n < 2048 {
            for (i, &v) in order.iter().enumerate() {
                let (deps, vals) = entries(v as usize);
                let base = ptr[i];
                for (j, (&d, &w)) in deps.iter().zip(vals).enumerate() {
                    idx[base + j] = pos[d as usize];
                    val[base + j] = S::from_f64(w);
                }
            }
        } else {
            // Pass 2: each packed position owns the disjoint slice
            // `ptr[i]..ptr[i+1]` of `idx`/`val`, so contiguous position
            // chunks write without overlap.
            let ip = SendPtr::new(idx.as_mut_ptr());
            let vp = SendPtr::new(val.as_mut_ptr());
            let ptr_ref = &ptr;
            let entries_ref = &entries;
            pool.run(parts, |part, parts| {
                let (lo, hi) = par::chunk_range(n, part, parts);
                for i in lo..hi {
                    let (deps, vals) = entries_ref(order[i] as usize);
                    let base = ptr_ref[i];
                    for (j, (&d, &w)) in deps.iter().zip(vals).enumerate() {
                        unsafe {
                            ip.write(base + j, pos[d as usize]);
                            vp.write(base + j, S::from_f64(w));
                        }
                    }
                }
            });
        }
        let any_wide = lev_ptr.windows(2).any(|w| w[1] - w[0] >= cutoff);
        PackedTri { ptr, idx, val, lev_ptr, any_wide }
    }

    /// Number of packed positions.
    fn n(&self) -> usize {
        self.ptr.len() - 1
    }
}

/// Invert a packed order into a position map (`pos[order[i]] = i`),
/// pooled for large factors — `order` is a permutation, so the scatter
/// targets are disjoint and the result is order-independent.
fn invert_order(order: &[u32], threads: usize) -> Vec<u32> {
    let n = order.len();
    let pool = par::global();
    let parts = threads.max(1).min(pool.size()).min(n.max(1));
    let mut pos = vec![0u32; n];
    if parts <= 1 || n < 2048 {
        for (i, &v) in order.iter().enumerate() {
            pos[v as usize] = i as u32;
        }
    } else {
        let p = SendPtr::new(pos.as_mut_ptr());
        pool.run(parts, |part, parts| {
            let (lo, hi) = par::chunk_range(n, part, parts);
            for (i, &v) in order[lo..hi].iter().enumerate() {
                unsafe { p.write(v as usize, (lo + i) as u32) };
            }
        });
    }
    pos
}

/// The packed analysis product for both sweeps of `G D Gᵀ` solves (see
/// the module docs). Analyze once per factor, apply every PCG
/// iteration; `Sync`, allocation-free after construction. The type
/// parameter selects the **value storage plane** — `f64` (default,
/// bit-identical to the sequential reference) or `f32` (half the
/// value bytes, f64 accumulation, residual contract).
pub struct PackedSweeps<S: Scalar = f64> {
    /// Forward sweep (`G y = r`), level-major packed rows of `G`.
    fwd: PackedTri<S>,
    /// Backward sweep (`Gᵀ z = y`), level-major packed columns of `G`.
    bwd: PackedTri<S>,
    /// `fwd_pos[vertex] = forward packed position` (permuted space).
    fwd_pos: Vec<u32>,
    /// `bwd_pos[vertex] = backward packed position` (permuted space).
    bwd_pos: Vec<u32>,
    /// Composed input scatter: `y_fwd[fwd_in[i]] = r[i]` folds the
    /// fill-reducing permutation into the forward renumbering. `None`
    /// when the factor stores no permutation — the composition would
    /// equal `fwd_pos`, so it is not duplicated.
    fwd_in: Option<Vec<u32>>,
    /// Boundary gather: backward position `i` reads forward position
    /// `mid[i]` (same vertex, both renumberings).
    mid: Vec<u32>,
    /// `D` arranged in backward packed order, in storage precision
    /// (scaling fused into the boundary pass; zero pivots apply
    /// pseudo-inversely).
    diag_bwd: Vec<S>,
    /// Composed output gather: `z[i] = y_bwd[bwd_out[i]]`; `None` ≡
    /// `bwd_pos` (same rationale as `fwd_in`).
    bwd_out: Option<Vec<u32>>,
    /// Value provenance of the forward packing: `fwd.val[e] ==
    /// f.g.data[fwd_src[e]]` — lets [`PackedSweeps::refill`] refresh the
    /// forward copy from a refactorized column factor without redoing
    /// the transpose.
    fwd_src: Vec<usize>,
    /// The backward level-major vertex order (backward values are the
    /// factor's own columns, so refill copies column slices directly).
    bwd_order: Vec<u32>,
    /// Level-width threshold below which a level (run) executes
    /// sequentially on participant 0.
    cutoff: usize,
    /// Critical path of the forward solve DAG (number of levels).
    pub critical_path: usize,
    /// Level-boundary synchronization for the resident participants.
    barrier: SweepBarrier,
    /// See [`PackedSweeps::counters`].
    dispatches: AtomicU64,
    /// See [`PackedSweeps::counters`].
    barriers: AtomicU64,
}

impl<S: Scalar> PackedSweeps<S> {
    /// Analyze a factor with the [`default_cutoff`].
    pub fn analyze(f: &LdlFactor) -> PackedSweeps<S> {
        PackedSweeps::analyze_with_cutoff(f, default_cutoff())
    }

    /// Analyze a factor (the "analysis phase"): compute both level
    /// schedules, renumber into level order, and pack rows/columns
    /// contiguously. `cutoff` is the minimum level width dispatched in
    /// parallel (clamped to at least 1). Sequential reference —
    /// equivalent to [`PackedSweeps::analyze_with_opts`] at one thread.
    pub fn analyze_with_cutoff(f: &LdlFactor, cutoff: usize) -> PackedSweeps<S> {
        PackedSweeps::analyze_with_opts(f, cutoff, 1)
    }

    /// [`PackedSweeps::analyze_with_cutoff`] with up to `threads` pool
    /// workers cooperating on the analysis itself: the level schedules
    /// run as Kahn wavefronts ([`etree::trisolve_levels_par`]), and the
    /// level bucketing and level-major packing copies run as pooled
    /// two-pass scatters with exact per-part offsets — so the product
    /// is **bit-identical** for every thread count (asserted across the
    /// generator suite in `rust/tests/properties.rs`).
    pub fn analyze_with_opts(f: &LdlFactor, cutoff: usize, threads: usize) -> PackedSweeps<S> {
        let cutoff = cutoff.max(1);
        let threads = threads.max(1);
        // Forward packing reads rows of `G`; one transient CSR
        // transpose (with value provenance for `refill`) is
        // materialized here and dropped after packing, so the resident
        // footprint is two packed copies (one per sweep) plus the
        // entry-sized provenance map. The transpose is taken first so
        // the pooled level schedules can walk both DAG directions.
        let (g_rows, g_src) = f.g.to_csr_with_src();
        let (fwd_levels, fwd_max) = etree::trisolve_levels_par(&f.g, &g_rows, threads);
        let (bwd_levels, bwd_max) = etree::trisolve_levels_bwd_par(&f.g, &g_rows, threads);
        let (fwd_order, fwd_lev) = etree::bucket_by_level_par(&fwd_levels, fwd_max, threads);
        let (bwd_order, bwd_lev) = etree::bucket_by_level_par(&bwd_levels, bwd_max, threads);
        let fwd_pos = invert_order(&fwd_order, threads);
        let bwd_pos = invert_order(&bwd_order, threads);
        let fwd = PackedTri::build(
            &fwd_order,
            fwd_lev,
            &fwd_pos,
            |k| (g_rows.row_indices(k), g_rows.row_data(k)),
            cutoff,
            threads,
        );
        let bwd = PackedTri::build(
            &bwd_order,
            bwd_lev,
            &bwd_pos,
            |k| (f.g.col_rows(k), f.g.col_data(k)),
            cutoff,
            threads,
        );
        // Compose the CSR-transpose provenance with the forward packing
        // so refill gathers straight from the factor's column storage.
        let mut fwd_src = Vec::with_capacity(fwd.idx.len());
        for &v in &fwd_order {
            let (s, e) = (g_rows.indptr[v as usize], g_rows.indptr[v as usize + 1]);
            fwd_src.extend_from_slice(&g_src[s..e]);
        }
        let (fwd_in, bwd_out) = match &f.perm {
            Some(p) => (
                Some(p.iter().map(|&pi| fwd_pos[pi as usize]).collect()),
                Some(p.iter().map(|&pi| bwd_pos[pi as usize]).collect()),
            ),
            // No permutation: the compositions degenerate to the
            // renumberings themselves — don't duplicate them.
            None => (None, None),
        };
        let mid = bwd_order.iter().map(|&v| fwd_pos[v as usize]).collect();
        let diag_bwd = bwd_order.iter().map(|&v| S::from_f64(f.diag[v as usize])).collect();
        PackedSweeps {
            fwd,
            bwd,
            fwd_pos,
            bwd_pos,
            fwd_in,
            mid,
            diag_bwd,
            bwd_out,
            fwd_src,
            bwd_order,
            cutoff,
            critical_path: fwd_max,
            barrier: SweepBarrier::new(),
            dispatches: AtomicU64::new(0),
            barriers: AtomicU64::new(0),
        }
    }

    /// Refresh the packed **values** from a refactorized factor whose
    /// sparsity structure matches the analyzed one (same `g.colptr`/
    /// `g.rowidx` and permutation) — the "near-free" half of the
    /// symbolic/numeric split. Copies values through the recorded
    /// provenance maps, narrowing into storage precision exactly like
    /// the original packing; every schedule array, counter, and the
    /// barrier stay untouched, and no heap allocation happens.
    pub fn refill(&mut self, f: &LdlFactor) {
        debug_assert_eq!(self.n(), f.n());
        debug_assert_eq!(self.fwd.idx.len(), f.g.nnz(), "structure changed; re-analyze");
        for (dst, &s) in self.fwd.val.iter_mut().zip(&self.fwd_src) {
            *dst = S::from_f64(f.g.data[s]);
        }
        for (i, &v) in self.bwd_order.iter().enumerate() {
            let vals = f.g.col_data(v as usize);
            let base = self.bwd.ptr[i];
            for (dst, &w) in self.bwd.val[base..base + vals.len()].iter_mut().zip(vals) {
                *dst = S::from_f64(w);
            }
            self.diag_bwd[i] = S::from_f64(f.diag[v as usize]);
        }
    }

    /// Bitwise equality of the full analysis product — every schedule,
    /// packing, provenance, and value array (float compare is by bits).
    /// Counters and the barrier are runtime state and excluded. Used by
    /// the pooled-analysis determinism tests.
    pub fn bitwise_eq(&self, other: &PackedSweeps<S>) -> bool {
        // `to_f64` is injective for both storage planes, so comparing
        // widened bits is exact value-bit equality.
        fn bits_eq<S: Scalar>(a: &[S], b: &[S]) -> bool {
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| x.to_f64().to_bits() == y.to_f64().to_bits())
        }
        fn tri_eq<S: Scalar>(a: &PackedTri<S>, b: &PackedTri<S>) -> bool {
            a.ptr == b.ptr
                && a.idx == b.idx
                && bits_eq(&a.val, &b.val)
                && a.lev_ptr == b.lev_ptr
                && a.any_wide == b.any_wide
        }
        tri_eq(&self.fwd, &other.fwd)
            && tri_eq(&self.bwd, &other.bwd)
            && self.fwd_pos == other.fwd_pos
            && self.bwd_pos == other.bwd_pos
            && self.fwd_in == other.fwd_in
            && self.bwd_out == other.bwd_out
            && self.mid == other.mid
            && bits_eq(&self.diag_bwd, &other.diag_bwd)
            && self.fwd_src == other.fwd_src
            && self.bwd_order == other.bwd_order
            && self.cutoff == other.cutoff
            && self.critical_path == other.critical_path
    }

    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.fwd.n()
    }

    /// The effective level-width cutoff (builder knob or
    /// `PARAC_LEVEL_CUTOFF` or [`LEVEL_PAR_CUTOFF`]).
    pub fn cutoff(&self) -> usize {
        self.cutoff
    }

    /// The storage plane of this executor's value arrays.
    pub fn precision(&self) -> Precision {
        S::PRECISION
    }

    /// Bytes of packed **value** storage streamed per full apply (both
    /// sweeps' `val` arrays plus the fused diagonal) — the traffic a
    /// narrower storage plane halves. Index/pointer bytes are excluded:
    /// they are precision-invariant.
    pub fn value_bytes(&self) -> usize {
        (self.fwd.val.len() + self.bwd.val.len() + self.diag_bwd.len()) * S::BYTES
    }

    /// Snapshot of the cumulative dispatch/barrier counters.
    pub fn counters(&self) -> SweepCounters {
        SweepCounters {
            dispatches: self.dispatches.load(Ordering::Relaxed),
            barriers: self.barriers.load(Ordering::Relaxed),
        }
    }

    /// Full preconditioner apply `z = (G D Gᵀ)⁺ r` with up to `threads`
    /// pool workers: composed scatter-in, forward sweep, fused `D⁻¹`
    /// boundary, backward sweep, composed scatter-out. `y_fwd`/`y_bwd`
    /// are caller scratch of length `n` (prior contents ignored).
    /// Bit-identical to [`LdlFactor::solve_into`].
    pub fn apply_into(
        &self,
        r: &[f64],
        z: &mut [f64],
        threads: usize,
        y_fwd: &mut [f64],
        y_bwd: &mut [f64],
    ) {
        let n = self.n();
        debug_assert_eq!(r.len(), n);
        debug_assert_eq!(z.len(), n);
        debug_assert_eq!(y_fwd.len(), n);
        debug_assert_eq!(y_bwd.len(), n);
        let fwd_in = self.fwd_in.as_deref().unwrap_or(&self.fwd_pos);
        let bwd_out = self.bwd_out.as_deref().unwrap_or(&self.bwd_pos);
        for (&slot, &ri) in fwd_in.iter().zip(r) {
            y_fwd[slot as usize] = ri;
        }
        self.sweep(&self.fwd, y_fwd, threads);
        for i in 0..n {
            let d = self.diag_bwd[i].to_f64();
            y_bwd[i] = if d > 0.0 { y_fwd[self.mid[i] as usize] / d } else { 0.0 };
        }
        self.sweep(&self.bwd, y_bwd, threads);
        for (zi, &slot) in z.iter_mut().zip(bwd_out) {
            *zi = y_bwd[slot as usize];
        }
    }

    /// Forward solve `G y = r` in place on a vector in **permuted
    /// vertex space** (the space of
    /// [`LdlFactor::forward_inplace`], which it matches bit for bit).
    /// `scratch` (length `n`) holds the packed intermediate. Mainly for
    /// parity tests and benches; the production path is
    /// [`PackedSweeps::apply_into`], whose scatters are composed.
    pub fn forward(&self, y: &mut [f64], scratch: &mut [f64], threads: usize) {
        debug_assert_eq!(y.len(), self.n());
        debug_assert_eq!(scratch.len(), self.n());
        for (&p, &yi) in self.fwd_pos.iter().zip(y.iter()) {
            scratch[p as usize] = yi;
        }
        self.sweep(&self.fwd, scratch, threads);
        for (&p, yi) in self.fwd_pos.iter().zip(y.iter_mut()) {
            *yi = scratch[p as usize];
        }
    }

    /// Backward solve `Gᵀ z = y` in place on a vector in permuted
    /// vertex space (bit-identical to
    /// [`LdlFactor::backward_inplace`]); see [`PackedSweeps::forward`].
    pub fn backward(&self, y: &mut [f64], scratch: &mut [f64], threads: usize) {
        debug_assert_eq!(y.len(), self.n());
        debug_assert_eq!(scratch.len(), self.n());
        for (&p, &yi) in self.bwd_pos.iter().zip(y.iter()) {
            scratch[p as usize] = yi;
        }
        self.sweep(&self.bwd, scratch, threads);
        for (&p, yi) in self.bwd_pos.iter().zip(y.iter_mut()) {
            *yi = scratch[p as usize];
        }
    }

    /// Run one packed sweep over `y` (packed order). Sequential inline
    /// when `threads <= 1` or no level clears the cutoff; otherwise one
    /// pool dispatch for the whole sweep, with resident participants
    /// barrier-syncing at level boundaries.
    fn sweep(&self, tri: &PackedTri<S>, y: &mut [f64], threads: usize) {
        let n = tri.n();
        if threads.max(1) == 1 || !tri.any_wide {
            // Dependencies always sit at smaller packed positions, so
            // one ascending pass is the whole solve. Values widen to
            // f64 before the multiply-subtract (identity for S = f64).
            for i in 0..n {
                let mut acc = y[i];
                for e in tri.ptr[i]..tri.ptr[i + 1] {
                    acc -= tri.val[e].to_f64() * y[tri.idx[e] as usize];
                }
                y[i] = acc;
            }
            return;
        }
        let yptr = SendPtr::new(y.as_mut_ptr());
        let nlev = tri.lev_ptr.len() - 1;
        par::global().run(threads, |part, parts| {
            // SAFETY (whole job): level discipline — position `i` reads
            // only positions from earlier levels (published by the
            // previous barrier episode or the dispatch itself) and is
            // the sole writer of its own slot within its level.
            let eliminate = |i: usize| unsafe {
                let mut acc = yptr.read(i);
                for e in tri.ptr[i]..tri.ptr[i + 1] {
                    acc -= tri.val[e].to_f64() * yptr.read(tri.idx[e] as usize);
                }
                yptr.write(i, acc);
            };
            if part == 0 && parts > 1 {
                self.dispatches.fetch_add(1, Ordering::Relaxed);
            }
            let mut lev = 0usize;
            while lev < nlev {
                let (lo, hi) = (tri.lev_ptr[lev], tri.lev_ptr[lev + 1]);
                if parts > 1 && hi - lo >= self.cutoff {
                    // Wide level: split across the resident parts.
                    let (a, b) = par::chunk_range(hi - lo, part, parts);
                    for i in lo + a..lo + b {
                        eliminate(i);
                    }
                    lev += 1;
                } else {
                    // Run of narrow levels (or the whole sweep when the
                    // dispatch degraded to one part): participant 0
                    // walks it sequentially, the rest go straight to
                    // the barrier. In-level order is ascending packed
                    // position — identical to the sequential reference.
                    let start = lev;
                    while lev < nlev
                        && (parts == 1
                            || tri.lev_ptr[lev + 1] - tri.lev_ptr[lev] < self.cutoff)
                    {
                        lev += 1;
                    }
                    if part == 0 {
                        for i in tri.lev_ptr[start]..tri.lev_ptr[lev] {
                            eliminate(i);
                        }
                    }
                }
                // Publish this level (run) to every participant before
                // anyone consumes it. The final run needs no in-sweep
                // barrier: the pool's own completion barrier publishes
                // the sweep to the dispatcher.
                if lev < nlev {
                    self.barrier.wait(parts);
                    if part == 0 {
                        self.barriers.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, Engine, ParacOptions};
    use crate::graph::generators;
    use crate::ordering::perm;

    fn seq_factor(l: &crate::graph::Laplacian) -> LdlFactor {
        factorize(l, &ParacOptions { engine: Engine::Seq, ..Default::default() }).unwrap()
    }

    #[test]
    fn packed_apply_matches_factor_solve() {
        let l = generators::grid3d(6, 6, 6, generators::Coeff::Uniform, 0);
        let f = seq_factor(&l);
        // Cutoff of 4 forces real pool dispatches + barriers even on
        // this small grid.
        let packed = PackedSweeps::<f64>::analyze_with_cutoff(&f, 4);
        let n = f.n();
        let r: Vec<f64> = (0..n).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let want = f.solve(&r);
        let (mut z, mut a, mut b) = (vec![f64::NAN; n], vec![0.0; n], vec![0.0; n]);
        for threads in [1usize, 4] {
            packed.apply_into(&r, &mut z, threads, &mut a, &mut b);
            assert_eq!(z, want, "threads={threads}");
        }
    }

    #[test]
    fn packed_sweeps_match_inplace_reference() {
        let l = generators::random_connected(300, 460, 5);
        let f = seq_factor(&l);
        let packed = PackedSweeps::<f64>::analyze_with_cutoff(&f, 8);
        let p = f.perm.as_ref().unwrap();
        let r: Vec<f64> = (0..f.n()).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
        let mut want = perm::apply_vec(p, &r);
        let mut got = want.clone();
        let mut scratch = vec![0.0; f.n()];
        f.forward_inplace(&mut want);
        packed.forward(&mut got, &mut scratch, 4);
        assert_eq!(want, got, "forward sweep must be bit-identical");
        f.backward_inplace(&mut want);
        packed.backward(&mut got, &mut scratch, 4);
        assert_eq!(want, got, "backward sweep must be bit-identical");
    }

    #[test]
    fn one_dispatch_per_sweep_regardless_of_level_count() {
        // Deep-and-wide graph: a 3-D grid factor has many levels, and a
        // cutoff of 2 makes essentially all of them "wide" — the old
        // executor would pay one dispatch per level, the packed one
        // must pay exactly one per sweep.
        let l = generators::grid3d(7, 7, 7, generators::Coeff::Uniform, 1);
        let f = seq_factor(&l);
        let packed = PackedSweeps::<f64>::analyze_with_cutoff(&f, 2);
        assert!(packed.critical_path > 3, "need a multi-level DAG");
        let n = f.n();
        let r = vec![1.0; n];
        let (mut z, mut a, mut b) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let before = packed.counters();
        packed.apply_into(&r, &mut z, 4, &mut a, &mut b);
        let delta = packed.counters().since(before);
        assert_eq!(
            delta.dispatches, 2,
            "one pool dispatch per sweep direction, independent of the {} levels",
            packed.critical_path
        );
        assert!(delta.barriers >= 1, "multi-level sweeps must barrier between levels");
        // A second apply costs the same again.
        packed.apply_into(&r, &mut z, 4, &mut a, &mut b);
        assert_eq!(packed.counters().since(before).dispatches, 4);
    }

    #[test]
    fn all_narrow_factor_never_dispatches() {
        // A path graph's factor is one long chain: every level has
        // width 1, so even a threaded apply stays inline. (Cutoff
        // pinned to the built-in default rather than `analyze`'s
        // env-sensitive one so the CI reruns under `PARAC_LEVEL_CUTOFF`
        // extremes don't flip the expectation.)
        let l = generators::path(200);
        let f = seq_factor(&l);
        let packed = PackedSweeps::<f64>::analyze_with_cutoff(&f, LEVEL_PAR_CUTOFF);
        let n = f.n();
        let r: Vec<f64> = (0..n).map(|i| i as f64 * 0.25 - 8.0).collect();
        let want = f.solve(&r);
        let (mut z, mut a, mut b) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        packed.apply_into(&r, &mut z, 4, &mut a, &mut b);
        assert_eq!(z, want);
        assert_eq!(packed.counters(), SweepCounters::default());
    }

    #[test]
    fn zero_pivots_apply_pseudo_inversely() {
        // Two disconnected components → two zero pivots; the fused
        // boundary must zero them exactly like the sequential solve.
        let mut edges: Vec<(u32, u32, f64)> = (0..40u32).map(|i| (i, i + 1, 1.0)).collect();
        edges.extend((41..90u32).map(|i| (i, i + 1, 2.0)));
        let l = crate::graph::Laplacian::from_edges(91, &edges, "two-comp");
        let f = seq_factor(&l);
        assert_eq!(f.diag.iter().filter(|&&d| d == 0.0).count(), 2);
        let packed = PackedSweeps::<f64>::analyze_with_cutoff(&f, 4);
        let r: Vec<f64> = (0..f.n()).map(|i| ((i * 29) % 13) as f64 - 6.0).collect();
        let want = f.solve(&r);
        let n = f.n();
        let (mut z, mut a, mut b) = (vec![f64::NAN; n], vec![0.0; n], vec![0.0; n]);
        packed.apply_into(&r, &mut z, 4, &mut a, &mut b);
        assert_eq!(z, want);
    }

    #[test]
    fn pooled_analysis_bit_identical_and_refill_is_identity() {
        // 2500 vertices: big enough to take the pooled bucketing /
        // packing / inversion paths rather than their fallbacks.
        let l = generators::grid2d(50, 50, generators::Coeff::HighContrast(3.0), 3);
        let f = seq_factor(&l);
        let reference = PackedSweeps::<f64>::analyze_with_opts(&f, 4, 1);
        for threads in [2usize, 4] {
            let pooled = PackedSweeps::<f64>::analyze_with_opts(&f, 4, threads);
            assert!(pooled.bitwise_eq(&reference), "threads={threads}");
        }
        // Refilling from the same factor must be a bitwise no-op.
        let mut refilled = PackedSweeps::<f64>::analyze_with_opts(&f, 4, 2);
        refilled.refill(&f);
        assert!(refilled.bitwise_eq(&reference));
        // And the refilled executor still solves correctly.
        let n = f.n();
        let r: Vec<f64> = (0..n).map(|i| ((i * 31) % 17) as f64 - 8.0).collect();
        let want = f.solve(&r);
        let (mut z, mut a, mut b) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        refilled.apply_into(&r, &mut z, 4, &mut a, &mut b);
        assert_eq!(z, want);
    }

    #[test]
    fn f32_plane_halves_value_bytes_and_stays_close() {
        let l = generators::grid2d(30, 30, generators::Coeff::HighContrast(3.0), 9);
        let f = seq_factor(&l);
        let p64 = PackedSweeps::<f64>::analyze_with_cutoff(&f, 4);
        let p32 = PackedSweeps::<f32>::analyze_with_cutoff(&f, 4);
        assert_eq!(p64.precision(), crate::sparse::Precision::F64);
        assert_eq!(p32.precision(), crate::sparse::Precision::F32);
        // The value traffic is exactly halved — same entry counts,
        // half the bytes per entry.
        assert_eq!(p32.value_bytes() * 2, p64.value_bytes());
        // The f32 apply is not bit-identical, but must stay close to
        // the f64 plane (f32 rounding on a well-conditioned factor):
        // the residual contract the solver layer builds on.
        let n = f.n();
        let r: Vec<f64> = (0..n).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let (mut z64, mut z32) = (vec![0.0; n], vec![0.0; n]);
        let (mut a, mut b) = (vec![0.0; n], vec![0.0; n]);
        p64.apply_into(&r, &mut z64, 1, &mut a, &mut b);
        p32.apply_into(&r, &mut z32, 1, &mut a, &mut b);
        let scale = z64.iter().fold(0.0f64, |m, v| m.max(v.abs())).max(1.0);
        for (i, (x, y)) in z64.iter().zip(&z32).enumerate() {
            assert!(
                (x - y).abs() <= 1e-4 * scale,
                "f32 plane drifted at {i}: {x} vs {y}"
            );
        }
        // And thread count still changes nothing within the f32 plane:
        // the sweep structure is precision-independent.
        let mut z32t = vec![0.0; n];
        p32.apply_into(&r, &mut z32t, 4, &mut a, &mut b);
        assert_eq!(z32, z32t, "f32 plane must stay thread-invariant");
    }

    #[test]
    fn cutoff_parsing_and_default() {
        assert_eq!(cutoff_from(None), LEVEL_PAR_CUTOFF);
        assert_eq!(cutoff_from(Some("64")), 64);
        assert_eq!(cutoff_from(Some("0")), 1, "0 means fully parallel");
        assert_eq!(cutoff_from(Some("not-a-number")), LEVEL_PAR_CUTOFF);
    }
}
