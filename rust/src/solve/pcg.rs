//! Preconditioned conjugate gradients with nullspace projection.
//!
//! Solves `A x = b` for symmetric positive (semi-)definite `A`. For a
//! singular graph Laplacian the right-hand side and iterates are kept in
//! the mean-zero subspace (orthogonal complement of the constant
//! nullspace), matching how the paper's experiments solve `Lx = b`.
//! Convergence is declared at relative residual `‖r‖/‖b‖ ≤ tol`
//! (paper's tables use ~1e-6..1e-7).
//!
//! Two entry points share one implementation:
//! * [`solve`] — the classic allocating call, returning a [`PcgResult`].
//! * [`solve_into`] — the session primitive: all five Krylov vectors
//!   live in a caller-owned [`PcgWorkspace`], the solution is written
//!   into a caller buffer, and after the workspace is warm **no heap
//!   allocation happens per iteration** (the preconditioner applies via
//!   [`Preconditioner::apply_scratch`] with scratch slices from the
//!   same workspace, the operator via [`LinearOperator::apply_to`]).
//!   Nothing here mutates the operator or the preconditioner, so any
//!   number of `solve_into` calls can run concurrently against the same
//!   `A` and `M` as long as each brings its own workspace — the
//!   foundation of the `&self` solve path in [`crate::solver::Solver`]
//!   and [`crate::serve`]. This is what [`crate::solver::Solver`]
//!   drives for repeated right-hand sides.
//!
//! The operator is any [`LinearOperator`] — [`crate::sparse::Csr`] or a
//! matrix-free implementation. Non-convergence is reported as data
//! (`converged == false`), never as an error or panic.
//!
//! The iteration body runs on the fused vector kernels of
//! [`crate::sparse::ops`]: one pass updates `x` and `r` and (when not
//! projecting) accumulates the residual norm; the mean-zero projection
//! of `z` is folded into the `β`-dot and the `p = z + βp` pass instead
//! of being materialized. Per iteration that cuts the full-vector
//! passes outside the SpMV and the preconditioner apply roughly in half
//! while staying **bit-identical** to the unfused formulation (pinned
//! by `fused_pcg_matches_unfused_reference` below).
//!
//! ## The f32 refinement guard
//!
//! When the preconditioner reports
//! [`Precision::F32`](crate::sparse::Precision) storage
//! ([`Preconditioner::precision`]), its apply obeys a residual contract
//! instead of the bit-identity contract, and the driver arms a guard:
//! the true (f64) relative residual is tracked every iteration, and on
//! a non-finite value, a `pᵀAp` breakdown, or
//! [`F32_STAGNATION_WINDOW`] iterations without improvement, the driver
//! asks the preconditioner to
//! [`promote_to_f64`](Preconditioner::promote_to_f64), rebuilds the
//! Krylov state from the current iterate, and continues — counting the
//! event in [`SolveStats::fallbacks`]. F64-plane solves never take any
//! of these branches, so the bit-identity pins are unaffected.

use crate::precond::Preconditioner;
use crate::solve::linop::LinearOperator;
use crate::sparse::ops::{
    dot, fused_axpy2, fused_axpy2_nrm2sq, fused_init_dir, fused_project_dot,
    fused_project_nrm2sq, fused_search_dir, mean, nrm2, project_mean_zero,
};
use crate::sparse::Precision;
use std::time::{Duration, Instant};

/// Iterations without a new best true residual before the f32
/// refinement guard declares stagnation and promotes the preconditioner
/// to its f64 plane. Generous on purpose: PCG residuals are not
/// monotone, and a premature promotion wastes the cheap plane.
pub const F32_STAGNATION_WINDOW: usize = 40;

/// How often (in iterations) [`solve_into_deadline`] consults the
/// deadline token. A clock read per iteration would be pure overhead on
/// the hot path; every 16th iteration bounds the overshoot to one
/// sub-millisecond stretch of iterations while keeping the check
/// essentially free. The first check happens on iteration 1, so a
/// budget that lapsed before the loop even started (e.g. a long queue
/// wait) is caught immediately.
pub const DEADLINE_CHECK_INTERVAL: usize = 16;

/// A wall-clock budget token for a solve: an absolute instant after
/// which the PCG loop abandons the request. Cheap to copy and thread
/// through the serving layers; the same token is shared by every
/// request of a coalesced wave.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        Deadline { at: Instant::now() + budget }
    }

    /// A deadline at an absolute instant.
    pub fn at(at: Instant) -> Deadline {
        Deadline { at }
    }

    /// Has the deadline passed?
    pub fn lapsed(&self) -> bool {
        Instant::now() >= self.at
    }
}

/// PCG options.
#[derive(Clone, Debug)]
pub struct PcgOptions {
    /// Relative residual tolerance.
    pub tol: f64,
    /// Iteration cap (paper tables cap at 1000 / 10000).
    pub max_iter: usize,
    /// Project onto the mean-zero subspace each iteration (singular
    /// Laplacians). Off for SPD (grounded) systems.
    pub project: bool,
    /// Record `‖r‖/‖b‖` each iteration.
    pub keep_history: bool,
}

impl Default for PcgOptions {
    fn default() -> Self {
        PcgOptions { tol: 1e-8, max_iter: 1000, project: true, keep_history: false }
    }
}

/// PCG outcome (allocating API).
#[derive(Clone, Debug)]
pub struct PcgResult {
    /// The (approximate) solution.
    pub x: Vec<f64>,
    /// Iterations used.
    pub iters: usize,
    /// Final relative residual (recomputed from scratch, not recurred).
    pub rel_residual: f64,
    /// Hit the tolerance before `max_iter`?
    pub converged: bool,
    /// Per-iteration relative residuals (if requested).
    pub history: Vec<f64>,
}

/// Allocation-free PCG outcome: everything except the solution vector
/// (which the caller owns) and the history (which stays in the
/// workspace, see [`PcgWorkspace::history`]).
#[derive(Clone, Copy, Debug)]
pub struct SolveStats {
    /// Iterations used.
    pub iters: usize,
    /// Final relative residual (recomputed from scratch, not recurred).
    pub rel_residual: f64,
    /// Hit the tolerance before `max_iter`?
    pub converged: bool,
    /// Preconditioner sweep pool dispatches during this solve (ParAC in
    /// level-scheduled mode performs at most **2 per apply** — one per
    /// sweep direction, independent of level count; 0 for sequential
    /// applies and for preconditioners that report no counters).
    pub precond_dispatches: u64,
    /// In-sweep level-boundary barrier episodes during this solve.
    pub precond_barriers: u64,
    /// The value plane the preconditioner **ended** the solve in:
    /// `F64` for every baseline and for an f32 session that the
    /// refinement guard promoted mid-solve; `F32` only when the whole
    /// solve ran on the f32 plane.
    pub precision: Precision,
    /// f32 → f64 refinement-guard promotions during this solve (0 or
    /// 1: a session promotes at most once, and f64 sessions never do).
    pub fallbacks: u32,
    /// The solve abandoned the iteration loop because its
    /// [`Deadline`] lapsed (only ever `true` for
    /// [`solve_into_deadline`] calls that carried a deadline; when set,
    /// `converged` is `false` and `x` holds the best iterate so far).
    pub timed_out: bool,
}

/// Reusable buffers for [`solve_into`]: the five Krylov-loop vectors
/// plus the residual history. Size once (or let `solve_into` grow them
/// on first use) and reuse across solves — repeated solves on the same
/// dimension perform zero heap allocation.
#[derive(Clone, Debug, Default)]
pub struct PcgWorkspace {
    /// Projected copy of the right-hand side.
    bwork: Vec<f64>,
    /// Residual.
    r: Vec<f64>,
    /// Preconditioned residual.
    z: Vec<f64>,
    /// Search direction.
    p: Vec<f64>,
    /// Operator-applied direction `A p`.
    ap: Vec<f64>,
    /// Preconditioner scratch (first sweep direction / permuted copy).
    pre_a: Vec<f64>,
    /// Preconditioner scratch (second sweep direction).
    pre_b: Vec<f64>,
    /// Per-iteration relative residuals of the most recent solve (only
    /// filled when `keep_history` is on; capacity is retained across
    /// solves, so steady-state pushes don't allocate).
    history: Vec<f64>,
}

impl PcgWorkspace {
    /// Pre-size every buffer for dimension `n`.
    pub fn new(n: usize) -> PcgWorkspace {
        let mut w = PcgWorkspace::default();
        w.ensure(n);
        w
    }

    /// Grow (never shrink) the buffers to dimension `n`. No-op — and no
    /// allocation — when already sized.
    pub fn ensure(&mut self, n: usize) {
        if self.bwork.len() < n {
            self.bwork.resize(n, 0.0);
            self.r.resize(n, 0.0);
            self.z.resize(n, 0.0);
            self.p.resize(n, 0.0);
            self.ap.resize(n, 0.0);
            self.pre_a.resize(n, 0.0);
            self.pre_b.resize(n, 0.0);
        }
    }

    /// Residual history of the most recent [`solve_into`] call (empty
    /// unless `keep_history` was set).
    pub fn history(&self) -> &[f64] {
        &self.history
    }

    /// Exchange the history buffer with `buf` (O(1), no allocation):
    /// the most recent solve's history moves out to the caller and the
    /// caller's buffer — typically last round's, with its capacity —
    /// moves in for reuse. This is how [`crate::solver::Solver`] hands
    /// workspace-pool histories to its session-level store.
    pub fn swap_history(&mut self, buf: &mut Vec<f64>) {
        std::mem::swap(&mut self.history, buf);
    }
}

/// Solve `A x = b` with preconditioner `m` (allocating convenience over
/// [`solve_into`]).
pub fn solve<A: LinearOperator + ?Sized>(
    a: &A,
    b: &[f64],
    m: &dyn Preconditioner,
    opts: &PcgOptions,
) -> PcgResult {
    let n = a.n();
    assert_eq!(b.len(), n);
    let mut ws = PcgWorkspace::new(n);
    let mut x = vec![0.0; n];
    let stats = solve_into(a, b, m, opts, &mut ws, &mut x);
    PcgResult {
        x,
        iters: stats.iters,
        rel_residual: stats.rel_residual,
        converged: stats.converged,
        history: ws.history,
    }
}

/// Solve `A x = b` with preconditioner `m`, writing the solution into
/// `x` (overwritten; the initial guess is zero) and keeping every
/// intermediate in `ws`. With a warm workspace this performs **zero
/// heap allocations per iteration** — by construction: the Krylov
/// vectors are reused, the operator and preconditioner write into
/// caller buffers, and the only amortized growth is the optional
/// history vector, whose capacity persists across solves.
///
/// Lengths of `b` and `x` must equal `a.n()` — checked by the callers
/// that expose this publicly ([`crate::solver::Solver::solve_into`]
/// returns a typed error); here they are debug assertions.
pub fn solve_into<A: LinearOperator + ?Sized>(
    a: &A,
    b: &[f64],
    m: &dyn Preconditioner,
    opts: &PcgOptions,
    ws: &mut PcgWorkspace,
    x: &mut [f64],
) -> SolveStats {
    solve_into_deadline(a, b, m, opts, ws, x, None)
}

/// [`solve_into`] with an optional wall-clock budget. When `deadline`
/// is `Some`, the iteration loop consults it every
/// [`DEADLINE_CHECK_INTERVAL`] iterations (first check on iteration 1)
/// and abandons the solve once it lapses, reporting
/// [`SolveStats::timed_out`]. With `deadline == None` the check branch
/// reads one `Option` discriminant per checked iteration and the
/// result is **bit-identical** to [`solve_into`] — no clock is ever
/// read, so the bit-identity and alloc-free contracts are unaffected.
pub fn solve_into_deadline<A: LinearOperator + ?Sized>(
    a: &A,
    b: &[f64],
    m: &dyn Preconditioner,
    opts: &PcgOptions,
    ws: &mut PcgWorkspace,
    x: &mut [f64],
    deadline: Option<Deadline>,
) -> SolveStats {
    // Fault site `solve-latency` (chaos testing): a fired probe sleeps
    // here, blowing the request's deadline. One relaxed atomic load
    // when no fault plan is installed.
    if let Some(d) = crate::faults::latency_fault() {
        std::thread::sleep(d);
    }
    let n = a.n();
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(x.len(), n);
    ws.ensure(n);
    ws.history.clear();
    let sweeps_before = m.sweep_counters().unwrap_or_default();
    let (bwork, r, z, p, ap, pre_a, pre_b) = (
        &mut ws.bwork[..n],
        &mut ws.r[..n],
        &mut ws.z[..n],
        &mut ws.p[..n],
        &mut ws.ap[..n],
        &mut ws.pre_a[..n],
        &mut ws.pre_b[..n],
    );
    bwork.copy_from_slice(b);
    if opts.project {
        project_mean_zero(bwork);
    }
    let bnorm = nrm2(bwork).max(f64::MIN_POSITIVE);

    x.fill(0.0);
    r.copy_from_slice(bwork);
    m.apply_scratch(r, z, pre_a, pre_b);
    // The projection of `z` is never materialized: its mean is folded
    // into the dot and the search-direction write (`mz = 0.0` when not
    // projecting — IEEE `x − 0.0 ≡ x`, so one code path serves both).
    let mz = if opts.project { mean(z) } else { 0.0 };
    let mut rz = fused_init_dir(z, mz, r, p);
    let mut iters = 0;
    let mut converged = false;
    // F32 refinement guard (module docs): armed only when the
    // preconditioner stores its factor in f32. Every guard branch below
    // is dead on the f64 plane, keeping the bit-identity pins intact.
    let mut guard = m.precision() == Precision::F32;
    let mut fallbacks: u32 = 0;
    let mut best_rel = f64::INFINITY;
    let mut since_best = 0usize;

    // Promote to the f64 plane and rebuild the Krylov state from the
    // current iterate (true residual, fresh z and p). A non-finite
    // iterate cannot seed a restart, so it drops back to x = 0.
    macro_rules! restart_on_f64_plane {
        () => {{
            if x.iter().any(|v| !v.is_finite()) || x.iter().all(|v| *v == 0.0) {
                // Also taken when the guard fired on the very first
                // apply (x still zero): the restart is then exactly a
                // clean f64-plane solve, not an A·0 detour.
                x.fill(0.0);
                r.copy_from_slice(bwork);
            } else {
                a.apply_to(x, ap);
                for i in 0..n {
                    r[i] = bwork[i] - ap[i];
                }
                if opts.project {
                    project_mean_zero(r);
                }
            }
            m.apply_scratch(r, z, pre_a, pre_b);
            let mz = if opts.project { mean(z) } else { 0.0 };
            rz = fused_init_dir(z, mz, r, p);
            best_rel = f64::INFINITY;
            since_best = 0;
        }};
    }

    // A non-finite initial rz means the f32 plane overflowed (or
    // NaN-ed) on the very first apply — promote before iterating.
    if guard && !rz.is_finite() {
        guard = false;
        if m.promote_to_f64() {
            fallbacks += 1;
            restart_on_f64_plane!();
        }
    }

    let mut timed_out = false;
    while iters < opts.max_iter {
        iters += 1;
        // Deadline token (armed only when the caller supplied one; a
        // `None` deadline makes this branch side-effect-free, keeping
        // deadline-less solves bit-identical to `solve_into`).
        if iters % DEADLINE_CHECK_INTERVAL == 1 {
            if let Some(d) = deadline {
                if d.lapsed() {
                    timed_out = true;
                    iters -= 1;
                    break;
                }
            }
        }
        a.apply_to(p, ap);
        let pap = dot(p, ap);
        if pap <= 0.0 || !pap.is_finite() {
            if guard {
                guard = false;
                if m.promote_to_f64() {
                    fallbacks += 1;
                    restart_on_f64_plane!();
                    continue;
                }
            }
            // Breakdown (semi-definite direction) — stop with best x.
            iters -= 1;
            break;
        }
        let alpha = rz / pap;
        // One fused pass updates x and r; the residual norm shares it
        // when not projecting, or shares the projection pass when it is.
        let rel = if opts.project {
            fused_axpy2(alpha, p, ap, x, r);
            fused_project_nrm2sq(r).sqrt() / bnorm
        } else {
            fused_axpy2_nrm2sq(alpha, p, ap, x, r).sqrt() / bnorm
        };
        if opts.keep_history {
            ws.history.push(rel);
        }
        if rel <= opts.tol {
            converged = true;
            break;
        }
        if guard {
            if rel.is_finite() && rel < best_rel {
                best_rel = rel;
                since_best = 0;
            } else {
                since_best += 1;
            }
            if !rel.is_finite() || since_best >= F32_STAGNATION_WINDOW {
                guard = false;
                if m.promote_to_f64() {
                    fallbacks += 1;
                    restart_on_f64_plane!();
                    continue;
                }
            }
        }
        m.apply_scratch(r, z, pre_a, pre_b);
        let mz = if opts.project { mean(z) } else { 0.0 };
        let rz_new = fused_project_dot(r, z, mz);
        if guard && !rz_new.is_finite() {
            guard = false;
            if m.promote_to_f64() {
                fallbacks += 1;
                restart_on_f64_plane!();
                continue;
            }
        }
        let beta = rz_new / rz;
        rz = rz_new;
        fused_search_dir(z, mz, beta, p);
    }

    // True residual check (reuses ap for A·x and r for b − A·x, with
    // the copy and subtraction fused into one pass).
    a.apply_to(x, ap);
    for i in 0..n {
        r[i] = bwork[i] - ap[i];
    }
    let rel_residual = if opts.project {
        fused_project_nrm2sq(r).sqrt() / bnorm
    } else {
        nrm2(r) / bnorm
    };
    let sweeps = m.sweep_counters().unwrap_or_default().since(sweeps_before);
    SolveStats {
        iters,
        rel_residual,
        converged,
        precond_dispatches: sweeps.dispatches,
        precond_barriers: sweeps.barriers,
        // Sampled after the solve: a mid-solve promotion reports F64.
        precision: m.precision(),
        fallbacks,
        timed_out,
    }
}

/// A reproducible random right-hand side in the range of the Laplacian
/// (mean-zero), unit norm.
pub fn random_rhs(lap: &crate::graph::Laplacian, seed: u64) -> Vec<f64> {
    let mut rng = crate::rng::Rng::new(seed ^ 0xB_0000);
    let mut b: Vec<f64> = (0..lap.n()).map(|_| rng.next_normal()).collect();
    project_mean_zero(&mut b);
    let nrm = nrm2(&b).max(f64::MIN_POSITIVE);
    for v in b.iter_mut() {
        *v /= nrm;
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::precond::{IdentityPrecond, JacobiPrecond};

    #[test]
    fn cg_solves_small_laplacian_unpreconditioned() {
        let l = generators::grid2d(8, 8, generators::Coeff::Uniform, 0);
        let b = random_rhs(&l, 1);
        let out = solve(&l.matrix, &b, &IdentityPrecond, &PcgOptions::default());
        assert!(out.converged, "rel={}", out.rel_residual);
        assert!(out.rel_residual <= 1e-8);
        // Verify: L x ≈ b on the mean-zero subspace.
        let ax = l.matrix.mul_vec(&out.x);
        for (got, want) in ax.iter().zip(&b) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    fn jacobi_reduces_iterations_on_contrast() {
        let l = generators::grid2d(16, 16, generators::Coeff::HighContrast(4.0), 3);
        let b = random_rhs(&l, 2);
        let o = PcgOptions { max_iter: 5000, ..Default::default() };
        let plain = solve(&l.matrix, &b, &IdentityPrecond, &o);
        let jac = solve(&l.matrix, &b, &JacobiPrecond::new(&l.matrix), &o);
        assert!(jac.converged);
        assert!(
            jac.iters < plain.iters,
            "jacobi {} vs identity {}",
            jac.iters,
            plain.iters
        );
    }

    #[test]
    fn history_is_recorded_and_monotonic_enough() {
        let l = generators::grid2d(10, 10, generators::Coeff::Uniform, 0);
        let b = random_rhs(&l, 5);
        let o = PcgOptions { keep_history: true, ..Default::default() };
        let out = solve(&l.matrix, &b, &IdentityPrecond, &o);
        assert_eq!(out.history.len(), out.iters);
        assert!(out.history.last().unwrap() <= &1e-8);
    }

    #[test]
    fn spd_grounded_system_without_projection() {
        // Grounded grid → SPD; exact solve check.
        let l = generators::grid2d(6, 6, generators::Coeff::Uniform, 0);
        let ext = crate::graph::Laplacian::ground_sdd(
            &{
                // Build SPD by adding 1.0 to one diagonal entry.
                let mut coo = crate::sparse::Coo::new(l.n(), l.n());
                for r in 0..l.n() {
                    for (&c, &v) in l.matrix.row_indices(r).iter().zip(l.matrix.row_data(r)) {
                        coo.push(r as u32, c, v);
                    }
                }
                coo.push(0, 0, 1.0);
                coo.to_csr()
            },
            "spd",
        )
        .unwrap();
        let a = ext.drop_ground().matrix;
        let mut rng = crate::rng::Rng::new(4);
        let xs: Vec<f64> = (0..a.nrows).map(|_| rng.next_normal()).collect();
        let b = a.mul_vec(&xs);
        let o = PcgOptions { project: false, max_iter: 2000, ..Default::default() };
        let out = solve(&a, &b, &IdentityPrecond, &o);
        assert!(out.converged);
        for (got, want) in out.x.iter().zip(&xs) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn solve_into_matches_allocating_solve_across_reuse() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let pre = JacobiPrecond::new(&l.matrix);
        let o = PcgOptions::default();
        let mut ws = PcgWorkspace::new(l.n());
        let mut x = vec![0.0; l.n()];
        for seed in [1u64, 2, 3] {
            let b = random_rhs(&l, seed);
            let stats = solve_into(&l.matrix, &b, &pre, &o, &mut ws, &mut x);
            let fresh = solve(&l.matrix, &b, &pre, &o);
            assert_eq!(stats.iters, fresh.iters);
            assert_eq!(x, fresh.x, "workspace reuse must be bit-identical");
            assert_eq!(stats.converged, fresh.converged);
        }
    }

    /// The pre-fusion PCG loop, verbatim, on the unfused BLAS-1 kernels
    /// — the reference the fused production loop must match bit for
    /// bit.
    fn solve_unfused_reference<A: crate::solve::linop::LinearOperator + ?Sized>(
        a: &A,
        b: &[f64],
        m: &dyn crate::precond::Preconditioner,
        opts: &PcgOptions,
    ) -> PcgResult {
        use crate::sparse::ops::{axpy, dot, nrm2, project_mean_zero};
        let n = a.n();
        let mut bwork = b.to_vec();
        if opts.project {
            project_mean_zero(&mut bwork);
        }
        let bnorm = nrm2(&bwork).max(f64::MIN_POSITIVE);
        let mut x = vec![0.0; n];
        let mut r = bwork.clone();
        let mut z = vec![0.0; n];
        m.apply_into(&r, &mut z);
        if opts.project {
            project_mean_zero(&mut z);
        }
        let mut p = z.clone();
        let mut ap = vec![0.0; n];
        let mut rz = dot(&r, &z);
        let mut iters = 0;
        let mut converged = false;
        let mut history = Vec::new();
        for it in 1..=opts.max_iter {
            iters = it;
            a.apply_to(&p, &mut ap);
            let pap = dot(&p, &ap);
            if pap <= 0.0 || !pap.is_finite() {
                iters = it - 1;
                break;
            }
            let alpha = rz / pap;
            axpy(alpha, &p, &mut x);
            axpy(-alpha, &ap, &mut r);
            if opts.project {
                project_mean_zero(&mut r);
            }
            let rel = nrm2(&r) / bnorm;
            if opts.keep_history {
                history.push(rel);
            }
            if rel <= opts.tol {
                converged = true;
                break;
            }
            m.apply_into(&r, &mut z);
            if opts.project {
                project_mean_zero(&mut z);
            }
            let rz_new = dot(&r, &z);
            let beta = rz_new / rz;
            rz = rz_new;
            for (pi, zi) in p.iter_mut().zip(z.iter()) {
                *pi = zi + beta * *pi;
            }
        }
        a.apply_to(&x, &mut ap);
        r.copy_from_slice(&bwork);
        for (ri, ai) in r.iter_mut().zip(ap.iter()) {
            *ri -= ai;
        }
        if opts.project {
            project_mean_zero(&mut r);
        }
        let rel_residual = nrm2(&r) / bnorm;
        PcgResult { x, iters, rel_residual, converged, history }
    }

    #[test]
    fn fused_pcg_matches_unfused_reference() {
        // Fusing the vector passes must change memory traffic only —
        // every iterate, the history, and the final residual stay
        // bit-identical, with the projection on (singular Laplacian)
        // and off (SPD system), across preconditioners.
        let l = generators::grid2d(14, 14, generators::Coeff::HighContrast(3.0), 2);
        let pres: Vec<Box<dyn crate::precond::Preconditioner>> = vec![
            Box::new(IdentityPrecond),
            Box::new(JacobiPrecond::new(&l.matrix)),
            Box::new(crate::precond::LdlPrecond::new(
                crate::factor::factorize(&l, &Default::default()).unwrap(),
            )),
        ];
        for pre in &pres {
            for seed in [1u64, 5] {
                let b = random_rhs(&l, seed);
                let o = PcgOptions { keep_history: true, max_iter: 600, ..Default::default() };
                let got = solve(&l.matrix, &b, pre.as_ref(), &o);
                let want = solve_unfused_reference(&l.matrix, &b, pre.as_ref(), &o);
                assert_eq!(got.x, want.x, "{}: projected solve deviates", pre.name());
                assert_eq!(got.iters, want.iters);
                assert_eq!(got.history, want.history);
                assert_eq!(got.rel_residual.to_bits(), want.rel_residual.to_bits());
            }
        }

        // SPD (no projection): Laplacian plus a boundary mass term.
        let n = l.n();
        let mut coo = crate::sparse::Coo::new(n, n);
        for row in 0..n {
            for (&c, &v) in l.matrix.row_indices(row).iter().zip(l.matrix.row_data(row)) {
                coo.push(row as u32, c, v);
            }
        }
        coo.push(0, 0, 1.0);
        let a = coo.to_csr();
        let pre = JacobiPrecond::new(&a);
        let b = random_rhs(&l, 9);
        let o = PcgOptions {
            project: false,
            keep_history: true,
            max_iter: 2000,
            ..Default::default()
        };
        let got = solve(&a, &b, &pre, &o);
        let want = solve_unfused_reference(&a, &b, &pre, &o);
        assert_eq!(got.x, want.x, "unprojected solve deviates");
        assert_eq!(got.iters, want.iters);
        assert_eq!(got.history, want.history);
        assert_eq!(got.rel_residual.to_bits(), want.rel_residual.to_bits());
    }

    /// Test double for the refinement guard: reports f32 storage and
    /// poisons every apply with NaN until promoted, then delegates to a
    /// real Jacobi preconditioner — the same observable shape as an
    /// overflowed f32 packed plane backed by an f64 fallback.
    struct FlakyF32 {
        inner: JacobiPrecond,
        promoted: std::sync::atomic::AtomicBool,
    }

    impl crate::precond::Preconditioner for FlakyF32 {
        fn apply_into(&self, r: &[f64], z: &mut [f64]) {
            if self.promoted.load(std::sync::atomic::Ordering::Acquire) {
                self.inner.apply_into(r, z);
            } else {
                z[..r.len()].fill(f64::NAN);
            }
        }
        fn name(&self) -> &'static str {
            "flaky-f32"
        }
        fn precision(&self) -> crate::sparse::Precision {
            if self.promoted.load(std::sync::atomic::Ordering::Acquire) {
                crate::sparse::Precision::F64
            } else {
                crate::sparse::Precision::F32
            }
        }
        fn promote_to_f64(&self) -> bool {
            !self.promoted.swap(true, std::sync::atomic::Ordering::AcqRel)
        }
    }

    #[test]
    fn refinement_guard_promotes_a_poisoned_f32_plane_and_converges() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let b = random_rhs(&l, 7);
        let pre = FlakyF32 {
            inner: JacobiPrecond::new(&l.matrix),
            promoted: std::sync::atomic::AtomicBool::new(false),
        };
        let o = PcgOptions { max_iter: 5000, ..Default::default() };
        let mut ws = PcgWorkspace::new(l.n());
        let mut x = vec![0.0; l.n()];
        let stats = solve_into(&l.matrix, &b, &pre, &o, &mut ws, &mut x);
        assert!(stats.converged, "rel={}", stats.rel_residual);
        assert_eq!(stats.fallbacks, 1, "exactly one guard promotion");
        assert_eq!(stats.precision, crate::sparse::Precision::F64);
        // The guard fired before the first iteration (non-finite rz at
        // init), so the restarted solve is exactly a clean Jacobi run.
        let plain = solve(&l.matrix, &b, &pre.inner, &o);
        assert_eq!(x, plain.x, "restart from x = 0 must match a clean solve");
        assert_eq!(stats.iters, plain.iters);
    }

    #[test]
    fn f64_solves_report_no_fallbacks() {
        let l = generators::grid2d(8, 8, generators::Coeff::Uniform, 0);
        let b = random_rhs(&l, 3);
        let pre = JacobiPrecond::new(&l.matrix);
        let mut ws = PcgWorkspace::new(l.n());
        let mut x = vec![0.0; l.n()];
        let stats = solve_into(&l.matrix, &b, &pre, &PcgOptions::default(), &mut ws, &mut x);
        assert!(stats.converged);
        assert_eq!(stats.fallbacks, 0);
        assert_eq!(stats.precision, crate::sparse::Precision::F64);
    }

    #[test]
    fn lapsed_deadline_abandons_the_solve_immediately() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let b = random_rhs(&l, 4);
        let pre = JacobiPrecond::new(&l.matrix);
        let mut ws = PcgWorkspace::new(l.n());
        let mut x = vec![0.0; l.n()];
        // An already-lapsed budget: the first checked iteration (1)
        // bails out before any Krylov work.
        let d = Deadline::after(Duration::ZERO);
        let stats = solve_into_deadline(
            &l.matrix,
            &b,
            &pre,
            &PcgOptions::default(),
            &mut ws,
            &mut x,
            Some(d),
        );
        assert!(stats.timed_out);
        assert!(!stats.converged);
        assert_eq!(stats.iters, 0);
    }

    #[test]
    fn none_deadline_is_bit_identical_to_solve_into() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let b = random_rhs(&l, 8);
        let pre = JacobiPrecond::new(&l.matrix);
        let o = PcgOptions::default();
        let mut ws = PcgWorkspace::new(l.n());
        let mut x1 = vec![0.0; l.n()];
        let mut x2 = vec![0.0; l.n()];
        let s1 = solve_into(&l.matrix, &b, &pre, &o, &mut ws, &mut x1);
        let s2 = solve_into_deadline(&l.matrix, &b, &pre, &o, &mut ws, &mut x2, None);
        assert_eq!(x1, x2);
        assert_eq!(s1.iters, s2.iters);
        assert!(!s2.timed_out);
        // A generous (far-future) deadline must not perturb the answer
        // either — only the lapse changes behavior, not the token.
        let far = Deadline::after(Duration::from_secs(3600));
        let mut x3 = vec![0.0; l.n()];
        let s3 = solve_into_deadline(&l.matrix, &b, &pre, &o, &mut ws, &mut x3, Some(far));
        assert_eq!(x1, x3);
        assert_eq!(s1.iters, s3.iters);
        assert!(!s3.timed_out);
    }

    #[test]
    fn matrix_free_operator_solves() {
        // PCG over a LinearOperator that is not a Csr.
        struct Shifted<'a>(&'a crate::sparse::Csr);
        impl crate::solve::linop::LinearOperator for Shifted<'_> {
            fn n(&self) -> usize {
                self.0.nrows
            }
            fn apply_to(&self, x: &[f64], y: &mut [f64]) {
                self.0.spmv(x, y);
                for (yi, xi) in y.iter_mut().zip(x) {
                    *yi += 0.1 * xi; // A + 0.1 I — SPD, no projection
                }
            }
        }
        let l = generators::grid2d(8, 8, generators::Coeff::Uniform, 0);
        let op = Shifted(&l.matrix);
        let b = random_rhs(&l, 6);
        let o = PcgOptions { project: false, ..Default::default() };
        let out = solve(&op, &b, &IdentityPrecond, &o);
        assert!(out.converged, "rel={}", out.rel_residual);
    }
}
