//! Preconditioned conjugate gradients with nullspace projection.
//!
//! Solves `A x = b` for symmetric positive (semi-)definite `A`. For a
//! singular graph Laplacian the right-hand side and iterates are kept in
//! the mean-zero subspace (orthogonal complement of the constant
//! nullspace), matching how the paper's experiments solve `Lx = b`.
//! Convergence is declared at relative residual `‖r‖/‖b‖ ≤ tol`
//! (paper's tables use ~1e-6..1e-7).

use crate::precond::Preconditioner;
use crate::sparse::ops::{axpy, dot, nrm2, project_mean_zero};
use crate::sparse::Csr;

/// PCG options.
#[derive(Clone, Debug)]
pub struct PcgOptions {
    /// Relative residual tolerance.
    pub tol: f64,
    /// Iteration cap (paper tables cap at 1000 / 10000).
    pub max_iter: usize,
    /// Project onto the mean-zero subspace each iteration (singular
    /// Laplacians). Off for SPD (grounded) systems.
    pub project: bool,
    /// Record `‖r‖/‖b‖` each iteration.
    pub keep_history: bool,
}

impl Default for PcgOptions {
    fn default() -> Self {
        PcgOptions { tol: 1e-8, max_iter: 1000, project: true, keep_history: false }
    }
}

/// PCG outcome.
#[derive(Clone, Debug)]
pub struct PcgResult {
    /// The (approximate) solution.
    pub x: Vec<f64>,
    /// Iterations used.
    pub iters: usize,
    /// Final relative residual (recomputed from scratch, not recurred).
    pub rel_residual: f64,
    /// Hit the tolerance before `max_iter`?
    pub converged: bool,
    /// Per-iteration relative residuals (if requested).
    pub history: Vec<f64>,
}

/// Solve `A x = b` with preconditioner `m`.
pub fn solve(a: &Csr, b: &[f64], m: &dyn Preconditioner, opts: &PcgOptions) -> PcgResult {
    let n = a.nrows;
    assert_eq!(b.len(), n);
    let mut bwork = b.to_vec();
    if opts.project {
        project_mean_zero(&mut bwork);
    }
    let bnorm = nrm2(&bwork).max(f64::MIN_POSITIVE);

    let mut x = vec![0.0; n];
    let mut r = bwork.clone();
    let mut z = m.apply(&r);
    if opts.project {
        project_mean_zero(&mut z);
    }
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut history = Vec::new();
    let mut iters = 0;
    let mut converged = false;

    for it in 1..=opts.max_iter {
        iters = it;
        let ap = a.mul_vec(&p);
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // Breakdown (semi-definite direction) — stop with best x.
            iters = it - 1;
            break;
        }
        let alpha = rz / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        if opts.project {
            project_mean_zero(&mut r);
        }
        let rel = nrm2(&r) / bnorm;
        if opts.keep_history {
            history.push(rel);
        }
        if rel <= opts.tol {
            converged = true;
            break;
        }
        z = m.apply(&r);
        if opts.project {
            project_mean_zero(&mut z);
        }
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for (pi, zi) in p.iter_mut().zip(&z) {
            *pi = zi + beta * *pi;
        }
    }

    // True residual check.
    let mut rr = bwork.clone();
    let ax = a.mul_vec(&x);
    for (ri, ai) in rr.iter_mut().zip(&ax) {
        *ri -= ai;
    }
    if opts.project {
        project_mean_zero(&mut rr);
    }
    let rel_residual = nrm2(&rr) / bnorm;
    PcgResult { x, iters, rel_residual, converged, history }
}

/// A reproducible random right-hand side in the range of the Laplacian
/// (mean-zero), unit norm.
pub fn random_rhs(lap: &crate::graph::Laplacian, seed: u64) -> Vec<f64> {
    let mut rng = crate::rng::Rng::new(seed ^ 0xB_0000);
    let mut b: Vec<f64> = (0..lap.n()).map(|_| rng.next_normal()).collect();
    project_mean_zero(&mut b);
    let nrm = nrm2(&b).max(f64::MIN_POSITIVE);
    for v in b.iter_mut() {
        *v /= nrm;
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::precond::{IdentityPrecond, JacobiPrecond};

    #[test]
    fn cg_solves_small_laplacian_unpreconditioned() {
        let l = generators::grid2d(8, 8, generators::Coeff::Uniform, 0);
        let b = random_rhs(&l, 1);
        let out = solve(&l.matrix, &b, &IdentityPrecond, &PcgOptions::default());
        assert!(out.converged, "rel={}", out.rel_residual);
        assert!(out.rel_residual <= 1e-8);
        // Verify: L x ≈ b on the mean-zero subspace.
        let ax = l.matrix.mul_vec(&out.x);
        for (got, want) in ax.iter().zip(&b) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    fn jacobi_reduces_iterations_on_contrast() {
        let l = generators::grid2d(16, 16, generators::Coeff::HighContrast(4.0), 3);
        let b = random_rhs(&l, 2);
        let o = PcgOptions { max_iter: 5000, ..Default::default() };
        let plain = solve(&l.matrix, &b, &IdentityPrecond, &o);
        let jac = solve(&l.matrix, &b, &JacobiPrecond::new(&l.matrix), &o);
        assert!(jac.converged);
        assert!(
            jac.iters < plain.iters,
            "jacobi {} vs identity {}",
            jac.iters,
            plain.iters
        );
    }

    #[test]
    fn history_is_recorded_and_monotonic_enough() {
        let l = generators::grid2d(10, 10, generators::Coeff::Uniform, 0);
        let b = random_rhs(&l, 5);
        let o = PcgOptions { keep_history: true, ..Default::default() };
        let out = solve(&l.matrix, &b, &IdentityPrecond, &o);
        assert_eq!(out.history.len(), out.iters);
        assert!(out.history.last().unwrap() <= &1e-8);
    }

    #[test]
    fn spd_grounded_system_without_projection() {
        // Grounded grid → SPD; exact solve check.
        let l = generators::grid2d(6, 6, generators::Coeff::Uniform, 0);
        let ext = crate::graph::Laplacian::ground_sdd(
            &{
                // Build SPD by adding 1.0 to one diagonal entry.
                let mut coo = crate::sparse::Coo::new(l.n(), l.n());
                for r in 0..l.n() {
                    for (&c, &v) in l.matrix.row_indices(r).iter().zip(l.matrix.row_data(r)) {
                        coo.push(r as u32, c, v);
                    }
                }
                coo.push(0, 0, 1.0);
                coo.to_csr()
            },
            "spd",
        )
        .unwrap();
        let a = ext.drop_ground().matrix;
        let mut rng = crate::rng::Rng::new(4);
        let xs: Vec<f64> = (0..a.nrows).map(|_| rng.next_normal()).collect();
        let b = a.mul_vec(&xs);
        let o = PcgOptions { project: false, max_iter: 2000, ..Default::default() };
        let out = solve(&a, &b, &IdentityPrecond, &o);
        assert!(out.converged);
        for (got, want) in out.x.iter().zip(&xs) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }
}
