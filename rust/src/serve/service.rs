//! Request admission and wave coalescing: many client threads, few
//! `solve_batch` waves.
//!
//! [`SolveService`] fronts a [`FactorCache`] with one [`BatchGate`] per
//! resident operator. A gate implements **leader-based group commit**
//! (the classic WAL trick, applied to solves): the first request to
//! arrive for an operator becomes the wave *leader* and waits — up to
//! [`ServeOptions::max_wait`] — for followers targeting the same
//! factor; the wave seals early the moment it reaches
//! [`ServeOptions::max_wave`] requests. The leader then runs the whole
//! wave through [`Solver::solve_batch_shared`] on the shared session
//! and hands each follower its solution through the gate. Requests for
//! *different* operators never wait on each other (separate gates), and
//! waves for the same operator may overlap (a new leader can start
//! collecting while the previous wave is still solving) — the solver is
//! `Sync`, so overlap is safe and bit-identity is preserved: every
//! right-hand side is solved from a zero initial guess by the same
//! arithmetic as a lone [`Solver::solve_shared`] call.
//!
//! Admission is **bounded**: a request that finds the collecting wave
//! already holding [`ServeOptions::max_queue`] right-hand sides is shed
//! with a typed [`ParacError::Overloaded`] before its buffer is copied,
//! and counted in [`ServiceStats::shed`] — overload surfaces as
//! back-pressure instead of an unbounded queue.
//!
//! ## Fault tolerance
//!
//! The service is the recovery boundary for everything below it:
//!
//! * **Deadlines** — [`ServeOptions::deadline`] stamps every request
//!   with a wall-clock budget ([`crate::solve::pcg::Deadline`]). A
//!   request whose budget lapses while queued is shed without solving;
//!   one that lapses mid-PCG is abandoned at the next deadline check.
//!   Both surface as [`ParacError::DeadlineExceeded`] (retryable) and
//!   count in [`ServiceStats::deadline_shed`].
//! * **Panic quarantine** — the wave leader runs the batched solve
//!   under `catch_unwind`; if it panics (a worker-pool job blew up, or
//!   the session is corrupt), every request of the wave fails with a
//!   typed [`ParacError::Internal`], the cached session is
//!   [quarantined](FactorCache::quarantine), and the next request
//!   rebuilds fresh ([`ServiceStats::quarantined`]).
//! * **Degrade-and-retry** — a build that fails with an escaped
//!   [`ParacError::ArenaFull`] / [`ParacError::WorkspaceFull`], a
//!   non-finite factor ([`ParacError::Internal`]), or a build panic is
//!   retried up to [`MAX_BUILD_ATTEMPTS`] times with progressively
//!   degraded settings — grown arena, pinned f64 plane, sequential
//!   engine last — each retry counted in [`ServiceStats::retries`].
//!
//! No background threads anywhere: the service borrows its clients'
//! threads, so a binary that drops the service leaks nothing.

use crate::error::ParacError;
use crate::factor::Engine;
use crate::graph::Laplacian;
use crate::serve::cache::FactorCache;
use crate::solve::pcg::{Deadline, SolveStats};
use crate::solver::{Solver, SolverBuilder};
use crate::sparse::Precision;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Build attempts the degrade-and-retry policy makes beyond the first:
/// one per rung of the degradation ladder (grown arena → f64 plane →
/// sequential engine).
pub const MAX_BUILD_ATTEMPTS: usize = 3;

/// Coalescing knobs for a [`SolveService`].
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Seal a wave as soon as it holds this many requests (1 =
    /// never coalesce; every request solves immediately).
    pub max_wave: usize,
    /// Seal a wave after the leader has waited this long, full or not.
    pub max_wait: Duration,
    /// Admission bound: a request arriving while the collecting wave
    /// already holds this many right-hand sides is **shed** with a
    /// typed [`ParacError::Overloaded`] instead of queueing without
    /// bound — back-pressure the caller can retry on. `0` disables the
    /// bound (the pre-admission-control behaviour).
    pub max_queue: usize,
    /// Per-request wall-clock budget: each request is stamped with
    /// `Deadline::after(budget)` at admission. `None` (the default)
    /// disables deadlines entirely — no clock is read on the solve
    /// path and results stay bit-identical to the deadline-less
    /// service.
    pub deadline: Option<Duration>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_wave: 8,
            max_wait: Duration::from_micros(200),
            max_queue: 1024,
            deadline: None,
        }
    }
}

/// Outcome of one request: the solution and its solve stats.
type WaveItem = Result<(Vec<f64>, SolveStats), ParacError>;

/// What the leader reports back to the service after running a wave.
struct WaveOutcome {
    /// Requests in the wave.
    size: usize,
    /// The batched solve panicked (caught at the leader boundary): the
    /// service quarantines the session this wave ran on.
    panicked: bool,
}

/// One queued request: its right-hand side and its admission-stamped
/// deadline.
struct Pending {
    b: Vec<f64>,
    deadline: Option<Deadline>,
}

/// State behind one gate's lock.
struct GateState {
    /// Requests of the wave currently collecting.
    pending: Vec<Pending>,
    /// Generation number of the collecting wave (bumped at seal, so a
    /// late arrival starts the next wave instead of joining a sealed
    /// one).
    generation: u64,
    /// Finished results, keyed by (generation, index-within-wave);
    /// each follower removes exactly its own.
    results: HashMap<(u64, usize), WaveItem>,
}

/// One operator's group-commit gate.
struct BatchGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

impl BatchGate {
    fn new() -> BatchGate {
        BatchGate {
            state: Mutex::new(GateState {
                pending: Vec::new(),
                generation: 0,
                results: HashMap::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Admit one request; returns its solution when the wave it joined
    /// has been solved, plus `Some(WaveOutcome)` when this thread led
    /// the wave (for the caller's traffic accounting and quarantine
    /// decision). The calling thread either leads the wave (collect,
    /// seal, solve, distribute) or follows (wait for the leader's
    /// hand-off). Two shed points at admission — before the right-hand
    /// side is buffered: a collecting wave already at
    /// [`ServeOptions::max_queue`] sheds with
    /// [`ParacError::Overloaded`], and a request whose deadline has
    /// already lapsed sheds with [`ParacError::DeadlineExceeded`].
    fn solve(
        &self,
        solver: &Solver<'static>,
        b: &[f64],
        deadline: Option<Deadline>,
        opts: &ServeOptions,
    ) -> (WaveItem, Option<WaveOutcome>) {
        let (my_gen, my_idx) = {
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            if opts.max_queue > 0 && st.pending.len() >= opts.max_queue {
                return (Err(ParacError::Overloaded { capacity: opts.max_queue }), None);
            }
            if deadline.is_some_and(|d| d.lapsed()) {
                return (Err(ParacError::DeadlineExceeded), None);
            }
            let slot = (st.generation, st.pending.len());
            st.pending.push(Pending { b: b.to_vec(), deadline });
            if st.pending.len() >= opts.max_wave.max(1) {
                // Wave full — wake the leader immediately.
                self.cv.notify_all();
            }
            slot
        };

        if my_idx == 0 {
            self.lead(solver, my_gen, opts)
        } else {
            (self.follow(my_gen, my_idx), None)
        }
    }

    /// Leader: wait out the coalescing window, seal, solve the wave
    /// under `catch_unwind`, distribute results, return our own plus
    /// the wave outcome.
    fn lead(
        &self,
        solver: &Solver<'static>,
        my_gen: u64,
        opts: &ServeOptions,
    ) -> (WaveItem, Option<WaveOutcome>) {
        let window_end = Instant::now() + opts.max_wait;
        let batch = {
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                if st.pending.len() >= opts.max_wave.max(1) {
                    break;
                }
                let now = Instant::now();
                if now >= window_end {
                    break;
                }
                let (next, timeout) = self
                    .cv
                    .wait_timeout(st, window_end - now)
                    .unwrap_or_else(|p| p.into_inner());
                st = next;
                if timeout.timed_out() {
                    break;
                }
            }
            // Seal: take the wave, open the next generation.
            st.generation += 1;
            std::mem::take(&mut st.pending)
        };

        let wave = batch.len();
        let bs: Vec<&[f64]> = batch.iter().map(|p| p.b.as_slice()).collect();
        let deadlines: Vec<Option<Deadline>> = batch.iter().map(|p| p.deadline).collect();
        let mut xs = vec![Vec::new(); wave];
        let mut results = Vec::new();
        // The quarantine boundary: a panic anywhere below (a worker-
        // pool job, a corrupt factor hit mid-sweep) is caught *here*,
        // converted into a typed error for every request of the wave,
        // and reported upward so the service can quarantine the
        // session. The solver holds no locks across a wave, so
        // unwinding cannot poison shared state.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            solver.solve_wave_shared(&bs, &deadlines, &mut xs, &mut results)
        }));
        let panicked = outcome.is_err();

        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let mine = match outcome {
            Ok(Ok(())) => {
                // Hand each follower its result (reverse order so the
                // index-0 pop below is ours).
                let mut items: Vec<WaveItem> = xs
                    .into_iter()
                    .zip(results)
                    .map(|(x, r)| r.map(|stats| (x, stats)))
                    .collect();
                for idx in (1..wave).rev() {
                    let item = items.pop().expect("one result per request");
                    st.results.insert((my_gen, idx), item);
                }
                items.pop().expect("leader's own result")
            }
            Ok(Err(e)) => {
                // Whole-wave shape error: every request gets the same
                // typed failure.
                for idx in 1..wave {
                    st.results.insert((my_gen, idx), Err(e.clone()));
                }
                Err(e)
            }
            Err(_panic) => {
                let e = ParacError::Internal("solve wave panicked".into());
                for idx in 1..wave {
                    st.results.insert((my_gen, idx), Err(e.clone()));
                }
                Err(e)
            }
        };
        drop(st);
        self.cv.notify_all();
        (mine, Some(WaveOutcome { size: wave, panicked }))
    }

    /// Follower: wait until the leader posts our result.
    fn follow(&self, my_gen: u64, my_idx: usize) -> WaveItem {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(item) = st.results.remove(&(my_gen, my_idx)) {
                return item;
            }
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// Aggregate service traffic counters (monotonic, lock-free reads).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Requests received (shed requests included).
    pub requests: u64,
    /// `solve_batch` waves executed.
    pub waves: u64,
    /// Requests beyond the first in each wave — the solves that rode
    /// another request's admission instead of paying their own.
    pub coalesced: u64,
    /// Requests shed at admission ([`ParacError::Overloaded`]) because
    /// the collecting wave was already at
    /// [`ServeOptions::max_queue`].
    pub shed: u64,
    /// Degraded build attempts made by the degrade-and-retry policy
    /// (one per rung climbed, across all sessions ever built).
    pub retries: u64,
    /// Sessions quarantined after a wave panicked on them; each
    /// quarantine also shows up as a cache eviction.
    pub quarantined: u64,
    /// Requests that failed with [`ParacError::DeadlineExceeded`] —
    /// shed while queued or abandoned mid-PCG.
    pub deadline_shed: u64,
}

/// A concurrent solve front end: factor cache + per-operator
/// group-commit gates.
pub struct SolveService {
    cache: FactorCache,
    opts: ServeOptions,
    gates: Mutex<HashMap<u64, Arc<BatchGate>>>,
    requests: AtomicU64,
    waves: AtomicU64,
    coalesced: AtomicU64,
    shed: AtomicU64,
    retries: AtomicU64,
    quarantined: AtomicU64,
    deadline_shed: AtomicU64,
}

impl SolveService {
    /// A service over `cache` with the given coalescing options.
    pub fn new(cache: FactorCache, opts: ServeOptions) -> SolveService {
        SolveService {
            cache,
            opts,
            gates: Mutex::new(HashMap::new()),
            requests: AtomicU64::new(0),
            waves: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            deadline_shed: AtomicU64::new(0),
        }
    }

    /// The factor cache behind this service.
    pub fn cache(&self) -> &FactorCache {
        &self.cache
    }

    /// The coalescing options this service admits requests under.
    pub fn options(&self) -> &ServeOptions {
        &self.opts
    }

    /// Traffic counters so far.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            requests: self.requests.load(Ordering::Relaxed),
            waves: self.waves.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            deadline_shed: self.deadline_shed.load(Ordering::Relaxed),
        }
    }

    /// Solve `L x = b` for `lap`, sharing factors through the cache and
    /// riding a coalesced wave when other clients target the same
    /// operator inside the window. Blocks the calling thread until the
    /// wave completes; returns the owned solution plus its stats.
    /// Bit-identical to [`Solver::solve_shared`] on the cached session.
    pub fn solve(
        &self,
        lap: &Arc<Laplacian>,
        b: &[f64],
    ) -> Result<(Vec<f64>, SolveStats), ParacError> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let deadline = self.opts.deadline.map(Deadline::after);
        let solver = match self.session(lap) {
            Ok(s) => s,
            Err(e) => return Err(self.count_err(e)),
        };
        let fp = lap.fingerprint();
        let gate = self.gate_for(fp.full);
        let (out, led) = gate.solve(&solver, b, deadline, &self.opts);
        if let Some(wave) = led {
            self.waves.fetch_add(1, Ordering::Relaxed);
            self.coalesced
                .fetch_add(wave.size.saturating_sub(1) as u64, Ordering::Relaxed);
            if wave.panicked {
                // The session this wave ran on may be corrupt; drop it
                // so the next request rebuilds fresh. Followers of the
                // panicked wave already hold their typed errors.
                self.cache.quarantine(fp.full);
                self.quarantined.fetch_add(1, Ordering::Relaxed);
            }
        }
        out.map_err(|e| self.count_err(e))
    }

    /// Count a terminal per-request failure in the matching stat.
    fn count_err(&self, e: ParacError) -> ParacError {
        match e {
            ParacError::Overloaded { .. } => {
                self.shed.fetch_add(1, Ordering::Relaxed);
            }
            ParacError::DeadlineExceeded => {
                self.deadline_shed.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
        e
    }

    /// A usable session for `lap`: the cached one when healthy,
    /// otherwise degrade-and-retry. A build that fails with an escaped
    /// overflow ([`ParacError::ArenaFull`] / [`ParacError::WorkspaceFull`]),
    /// a non-finite factor ([`ParacError::Internal`]), or a panic is
    /// retried up to [`MAX_BUILD_ATTEMPTS`] more times, each rung of
    /// the ladder trading speed for headroom
    /// (see [`Self::degraded_builder`]). Other build errors —
    /// [`ParacError::BadInput`], dimension mismatches — are not
    /// retryable and propagate immediately.
    fn session(&self, lap: &Arc<Laplacian>) -> Result<Arc<Solver<'static>>, ParacError> {
        let mut last = match catch_unwind(AssertUnwindSafe(|| self.cache.get_or_build(lap))) {
            Ok(Ok(solver)) => return Ok(solver),
            Ok(Err(e)) => e,
            Err(_panic) => ParacError::Internal("factor build panicked".into()),
        };
        for attempt in 1..=MAX_BUILD_ATTEMPTS {
            let degradable = matches!(
                last,
                ParacError::ArenaFull { .. }
                    | ParacError::WorkspaceFull { .. }
                    | ParacError::Internal(_)
            );
            if !degradable {
                break;
            }
            self.retries.fetch_add(1, Ordering::Relaxed);
            let builder = self.degraded_builder(attempt);
            last = match catch_unwind(AssertUnwindSafe(|| {
                self.cache.rebuild_with(lap, &builder)
            })) {
                Ok(Ok(solver)) => return Ok(solver),
                Ok(Err(e)) => e,
                Err(_panic) => ParacError::Internal("factor build panicked".into()),
            };
        }
        Err(last)
    }

    /// The degradation ladder: each rung keeps the previous rungs'
    /// concessions and adds one more.
    ///
    /// 1. grow the arena headroom 8× (outruns estimator misses),
    /// 2. pin the value plane to f64 (rules out f32 range/rounding),
    /// 3. fall back to the sequential engine (rules out the parallel
    ///    path entirely — slow but maximally conservative).
    fn degraded_builder(&self, attempt: usize) -> SolverBuilder {
        let base = self.cache.builder().clone();
        let grown = base.parac_opts().arena_factor * 8.0;
        let mut builder = base.arena_factor(grown);
        if attempt >= 2 {
            builder = builder.precision(Precision::F64);
        }
        if attempt >= 3 {
            builder = builder.engine(Engine::Seq);
        }
        builder
    }

    /// The gate for one resident operator, created on first use. A
    /// refactorized or rebuilt operator has a new full-fingerprint and
    /// therefore a fresh gate; stale gates are retained (bounded by the
    /// number of distinct operators ever served — same order as the
    /// cache's own key history).
    fn gate_for(&self, full: u64) -> Arc<BatchGate> {
        let mut gates = self.gates.lock().unwrap_or_else(|p| p.into_inner());
        gates.entry(full).or_insert_with(|| Arc::new(BatchGate::new())).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::solve::pcg;

    fn service(max_wave: usize, max_wait: Duration) -> SolveService {
        let cache = FactorCache::new(Solver::builder().seed(7), 4);
        SolveService::new(cache, ServeOptions { max_wave, max_wait, ..Default::default() })
    }

    #[test]
    fn zero_max_queue_disables_admission_control() {
        let cache = FactorCache::new(Solver::builder().seed(7), 4);
        let svc = SolveService::new(
            cache,
            ServeOptions { max_wave: 1, max_queue: 0, ..Default::default() },
        );
        let lap = Arc::new(generators::grid2d(8, 8, generators::Coeff::Uniform, 0));
        let b = pcg::random_rhs(&lap, 2);
        assert!(svc.solve(&lap, &b).unwrap().1.converged);
        assert_eq!(svc.stats().shed, 0);
    }

    #[test]
    fn single_request_solves_immediately_with_wave_of_one() {
        // max_wave = 1: the leader seals without waiting.
        let svc = service(1, Duration::from_secs(10));
        let lap = Arc::new(generators::grid2d(10, 10, generators::Coeff::Uniform, 0));
        let b = pcg::random_rhs(&lap, 1);
        let (x, stats) = svc.solve(&lap, &b).unwrap();
        assert!(stats.converged);
        // Bit-identical to the shared-session primitive.
        let solver = svc.cache().get_or_build(&lap).unwrap();
        let mut want = vec![0.0; lap.n()];
        solver.solve_shared(&b, &mut want).unwrap();
        assert_eq!(x, want);
        assert_eq!(svc.stats().requests, 1);
        assert_eq!(svc.stats().waves, 1);
    }

    #[test]
    fn full_wave_coalesces_and_stays_bit_identical() {
        // N clients + max_wave = N + a generous window: exactly one
        // wave, every result bit-identical to serial solves.
        const CLIENTS: usize = 8;
        let svc = service(CLIENTS, Duration::from_secs(30));
        let lap = Arc::new(generators::grid2d(12, 12, generators::Coeff::Uniform, 0));
        let rhs: Vec<Vec<f64>> =
            (0..CLIENTS).map(|i| pcg::random_rhs(&lap, 100 + i as u64)).collect();

        let got: Vec<Vec<f64>> = std::thread::scope(|scope| {
            let handles: Vec<_> = rhs
                .iter()
                .map(|b| {
                    let svc = &svc;
                    let lap = &lap;
                    scope.spawn(move || svc.solve(lap, b).unwrap().0)
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        let solver = svc.cache().get_or_build(&lap).unwrap();
        let mut want = vec![0.0; lap.n()];
        for (b, x) in rhs.iter().zip(&got) {
            solver.solve_shared(b, &mut want).unwrap();
            assert_eq!(x, &want, "coalesced result deviates from serial reference");
        }
        let st = svc.stats();
        assert_eq!(st.requests as usize, CLIENTS);
        assert_eq!(st.waves, 1, "all {CLIENTS} requests must ride one wave");
        assert_eq!(st.coalesced as usize, CLIENTS - 1);
    }

    #[test]
    fn bounded_wait_seals_partial_waves() {
        // A lone request against a huge max_wave must still return,
        // after ~max_wait.
        let svc = service(64, Duration::from_millis(5));
        let lap = Arc::new(generators::grid2d(8, 8, generators::Coeff::Uniform, 0));
        let b = pcg::random_rhs(&lap, 3);
        let t0 = Instant::now();
        let (_, stats) = svc.solve(&lap, &b).unwrap();
        assert!(stats.converged);
        assert!(t0.elapsed() >= Duration::from_millis(5), "window must be honored");
    }

    #[test]
    fn lapsed_deadlines_are_shed_and_counted() {
        // A zero budget has lapsed by the time the session is built, so
        // the request is shed at admission without solving anything.
        let cache = FactorCache::new(Solver::builder().seed(7), 4);
        let svc = SolveService::new(
            cache,
            ServeOptions {
                max_wave: 1,
                deadline: Some(Duration::ZERO),
                ..Default::default()
            },
        );
        let lap = Arc::new(generators::grid2d(8, 8, generators::Coeff::Uniform, 0));
        let b = pcg::random_rhs(&lap, 2);
        let err = svc.solve(&lap, &b).unwrap_err();
        assert!(matches!(err, ParacError::DeadlineExceeded));
        assert!(err.is_retryable(), "deadline errors invite a client retry");
        let st = svc.stats();
        assert_eq!(st.requests, 1);
        assert_eq!(st.deadline_shed, 1);
        assert_eq!(st.waves, 0, "a shed request must not run a wave");
    }

    #[test]
    fn distinct_graphs_use_distinct_gates_and_cache_entries() {
        let svc = service(4, Duration::from_millis(1));
        let a = Arc::new(generators::grid2d(8, 8, generators::Coeff::Uniform, 0));
        let bgraph = Arc::new(generators::grid2d(9, 9, generators::Coeff::Uniform, 0));
        for lap in [&a, &bgraph] {
            let b = pcg::random_rhs(lap, 4);
            assert!(svc.solve(lap, &b).unwrap().1.converged);
        }
        assert_eq!(svc.cache().len(), 2);
        assert_eq!(svc.cache().stats().misses, 2);
    }
}
