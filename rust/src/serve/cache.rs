//! The factor cache: build once, serve every later request for the
//! same graph from the shared factor.
//!
//! A [`FactorCache`] maps [`Laplacian::fingerprint`] hashes to
//! `Arc<Solver<'static>>` sessions built by one stored
//! [`SolverBuilder`] configuration. Three outcomes per request, in
//! decreasing order of luck:
//!
//! 1. **Hit** — the full fingerprint (structure + weights) is resident:
//!    the `Arc` is cloned and returned. No ordering, no analysis, no
//!    numeric work — the whole build is skipped.
//! 2. **Refactorize** — the *pattern* is known but the weights are new
//!    (a reweighted graph): the resident session is routed through
//!    [`Solver::refactorize_shared`], rerunning only the numeric phase
//!    on the frozen symbolic analysis (observable:
//!    `factor_stats().symbolic_reused == true`). Falls back to a fresh
//!    build when the resident session is still shared by in-flight
//!    clients (mutating it under them would be unsound) or when the
//!    pattern hash collided (the refactorize path's own structural
//!    check rejects impostors with a typed error).
//! 3. **Miss** — an unseen graph: a full build.
//!
//! Capacity is bounded: past `capacity` resident sessions the
//! least-recently-used entry is evicted (clients already holding its
//! `Arc` keep solving; the memory is reclaimed when the last clone
//! drops). Builds happen **while holding the cache lock** — deliberate
//! single-flight semantics: N clients racing for the same cold graph
//! produce one build and N−1 hits, which is the right trade for a
//! cache whose misses cost seconds while its hits cost nanoseconds.

use crate::error::ParacError;
use crate::graph::Laplacian;
use crate::solver::{Solver, SolverBuilder};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Monotonic counters describing a cache's traffic so far. Cheap to
/// copy out; read via [`FactorCache::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Requests answered by a resident factor (no work at all).
    pub hits: u64,
    /// Requests answered by a fresh full build.
    pub misses: u64,
    /// Requests answered by the numeric-only refactorize path
    /// (known pattern, new weights).
    pub refactorizes: u64,
    /// Resident sessions evicted to respect the capacity bound.
    pub evictions: u64,
}

/// One resident factor.
struct Entry {
    solver: Arc<Solver<'static>>,
    /// Pattern hash of the graph this session was built on, for
    /// reverse-indexing on eviction.
    pattern: u64,
    /// Logical timestamp of the last touch (for LRU eviction).
    last_used: u64,
}

struct Inner {
    /// Resident sessions keyed by the **full** fingerprint hash.
    entries: HashMap<u64, Entry>,
    /// Pattern hash → full hash of the most recent resident session
    /// with that structure (the refactorize-routing index).
    patterns: HashMap<u64, u64>,
    /// Logical clock; bumped per request.
    tick: u64,
    stats: CacheStats,
}

/// A bounded cache of built solver sessions keyed by graph fingerprint.
pub struct FactorCache {
    builder: SolverBuilder,
    capacity: usize,
    inner: Mutex<Inner>,
}

impl FactorCache {
    /// A cache that builds with `builder` and keeps at most `capacity`
    /// resident sessions (clamped to at least 1).
    pub fn new(builder: SolverBuilder, capacity: usize) -> FactorCache {
        FactorCache {
            builder,
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                patterns: HashMap::new(),
                tick: 0,
                stats: CacheStats::default(),
            }),
        }
    }

    /// The builder configuration every cached session is built with.
    pub fn builder(&self) -> &SolverBuilder {
        &self.builder
    }

    /// Resident session count.
    pub fn len(&self) -> usize {
        self.lock().entries.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Traffic counters so far.
    pub fn stats(&self) -> CacheStats {
        self.lock().stats
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // Poisoning can only come from a panic inside a build; the maps
        // themselves are always consistent (mutated between builds).
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Return the shared session for `lap`, building / refactorizing /
    /// cloning as the fingerprint dictates (see the module docs for the
    /// three outcomes).
    pub fn get_or_build(&self, lap: &Arc<Laplacian>) -> Result<Arc<Solver<'static>>, ParacError> {
        let fp = lap.fingerprint();
        let mut guard = self.lock();
        let inner = &mut *guard; // split-borrow the fields
        inner.tick += 1;
        let now = inner.tick;

        if let Some(e) = inner.entries.get_mut(&fp.full) {
            e.last_used = now;
            inner.stats.hits += 1;
            return Ok(e.solver.clone());
        }

        // Known structure, new weights → try the numeric-only path on
        // the resident session, if no client still holds it.
        if let Some(&resident_full) = inner.patterns.get(&fp.pattern) {
            if let Some(mut entry) = inner.entries.remove(&resident_full) {
                match Arc::get_mut(&mut entry.solver) {
                    Some(solver) => match solver.refactorize_shared(lap.clone()) {
                        Ok(()) => {
                            inner.stats.refactorizes += 1;
                            let shared = entry.solver.clone();
                            entry.last_used = now;
                            inner.entries.insert(fp.full, entry);
                            inner.patterns.insert(fp.pattern, fp.full);
                            return Ok(shared);
                        }
                        Err(ParacError::BadInput(_)) => {
                            // Pattern-hash collision: the structural
                            // check inside refactorize caught it. Put
                            // the untouched session back and fall
                            // through to a fresh build.
                            inner.entries.insert(resident_full, entry);
                        }
                        Err(ParacError::Internal(_)) => {
                            // The numeric rerun produced non-finite
                            // values: the resident session can no
                            // longer be trusted. Drop it (auto-heal)
                            // and fall through to a fresh build for
                            // this request.
                            if inner.patterns.get(&fp.pattern) == Some(&resident_full) {
                                inner.patterns.remove(&fp.pattern);
                            }
                        }
                        Err(other) => {
                            inner.entries.insert(resident_full, entry);
                            return Err(other);
                        }
                    },
                    None => {
                        // Still shared by in-flight clients — leave it
                        // resident for them and build fresh.
                        inner.entries.insert(resident_full, entry);
                    }
                }
            }
        }

        inner.stats.misses += 1;
        let solver = Arc::new(self.builder.build_shared(lap.clone())?);
        inner.entries.insert(
            fp.full,
            Entry { solver: solver.clone(), pattern: fp.pattern, last_used: now },
        );
        inner.patterns.insert(fp.pattern, fp.full);
        self.evict_past_capacity(inner, fp.full);
        Ok(solver)
    }

    /// Quarantine the resident session keyed by the **full**
    /// fingerprint hash `full`: remove it from the cache so no future
    /// request is served from it (clients already holding its `Arc`
    /// keep their clone; the memory is reclaimed when the last drops).
    /// The next request for that graph takes the miss path and builds
    /// fresh. Returns whether a session was actually resident. Counted
    /// in [`CacheStats::evictions`]; the serve layer calls this when a
    /// solve wave over the session panicked
    /// (see `ServiceStats::quarantined`).
    pub fn quarantine(&self, full: u64) -> bool {
        let mut guard = self.lock();
        let inner = &mut *guard;
        match inner.entries.remove(&full) {
            Some(entry) => {
                inner.stats.evictions += 1;
                if inner.patterns.get(&entry.pattern) == Some(&full) {
                    inner.patterns.remove(&entry.pattern);
                }
                true
            }
            None => false,
        }
    }

    /// Build a **fresh** session for `lap` with an explicit (typically
    /// degraded) builder, replacing whatever is resident for that
    /// fingerprint — the serve layer's degrade-and-retry path after an
    /// escaped overflow or a non-finite factor. Same single-flight
    /// semantics as [`FactorCache::get_or_build`] (the build runs under
    /// the cache lock); the replaced entry's in-flight clients keep
    /// solving on their `Arc`.
    pub fn rebuild_with(
        &self,
        lap: &Arc<Laplacian>,
        builder: &SolverBuilder,
    ) -> Result<Arc<Solver<'static>>, ParacError> {
        let fp = lap.fingerprint();
        let mut guard = self.lock();
        let inner = &mut *guard;
        inner.tick += 1;
        let now = inner.tick;
        inner.entries.remove(&fp.full);
        inner.stats.misses += 1;
        let solver = Arc::new(builder.build_shared(lap.clone())?);
        inner.entries.insert(
            fp.full,
            Entry { solver: solver.clone(), pattern: fp.pattern, last_used: now },
        );
        inner.patterns.insert(fp.pattern, fp.full);
        self.evict_past_capacity(inner, fp.full);
        Ok(solver)
    }

    /// Evict least-recently-used entries until the capacity bound
    /// holds, never evicting `keep` (the entry serving the current
    /// request).
    fn evict_past_capacity(&self, inner: &mut Inner, keep: u64) {
        while inner.entries.len() > self.capacity {
            let victim = inner
                .entries
                .iter()
                .filter(|(full, _)| **full != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(full, e)| (*full, e.pattern));
            let Some((full, pattern)) = victim else { break };
            inner.entries.remove(&full);
            inner.stats.evictions += 1;
            // Drop the routing index only if it still points at the
            // victim (a newer same-pattern entry may have re-aimed it).
            if inner.patterns.get(&pattern) == Some(&full) {
                inner.patterns.remove(&pattern);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn builder() -> SolverBuilder {
        Solver::builder()
    }

    #[test]
    fn repeated_requests_share_one_session() {
        let cache = FactorCache::new(builder().seed(3), 4);
        let lap = Arc::new(generators::grid2d(10, 10, generators::Coeff::Uniform, 0));
        let a = cache.get_or_build(&lap).unwrap();
        let b = cache.get_or_build(&lap).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second request must reuse the cached session");
        // A structurally identical rebuild of the same graph (new
        // allocation, same content) also hits.
        let rebuilt = Arc::new(generators::grid2d(10, 10, generators::Coeff::Uniform, 0));
        let c = cache.get_or_build(&rebuilt).unwrap();
        assert!(Arc::ptr_eq(&a, &c));
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.refactorizes), (2, 1, 0));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn reweighted_pattern_routes_through_refactorize() {
        let cache = FactorCache::new(builder().seed(5), 4);
        let lap = Arc::new(generators::grid2d(12, 12, generators::Coeff::Uniform, 0));
        {
            let first = cache.get_or_build(&lap).unwrap();
            assert!(!first.factor_stats().unwrap().symbolic_reused);
        } // drop the clone so the cache holds the only reference

        let edges: Vec<(u32, u32, f64)> =
            lap.edges().into_iter().map(|(a, b, w)| (a, b, w * 2.0)).collect();
        let heavy = Arc::new(Laplacian::from_edges(lap.n(), &edges, "heavy"));
        let second = cache.get_or_build(&heavy).unwrap();
        assert!(
            second.factor_stats().unwrap().symbolic_reused,
            "reweighted build must skip the symbolic phase"
        );
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.refactorizes), (0, 1, 1));

        // Bit-identical to a fresh build on the new weights.
        let fresh = builder().seed(5).build(&heavy).unwrap();
        assert_eq!(second.factor().unwrap().g, fresh.factor().unwrap().g);
        assert_eq!(second.factor().unwrap().diag, fresh.factor().unwrap().diag);
    }

    #[test]
    fn shared_resident_session_is_not_mutated_under_clients() {
        let cache = FactorCache::new(builder().seed(1), 4);
        let lap = Arc::new(generators::grid2d(8, 8, generators::Coeff::Uniform, 0));
        let held = cache.get_or_build(&lap).unwrap(); // client keeps this alive

        let edges: Vec<(u32, u32, f64)> =
            lap.edges().into_iter().map(|(a, b, w)| (a, b, w * 3.0)).collect();
        let heavy = Arc::new(Laplacian::from_edges(lap.n(), &edges, "heavy"));
        let other = cache.get_or_build(&heavy).unwrap();
        assert!(!Arc::ptr_eq(&held, &other), "a held session must never be refactorized");
        // The held session still solves its original system.
        let b = crate::solve::pcg::random_rhs(&lap, 2);
        let mut x = vec![0.0; lap.n()];
        assert!(held.solve_shared(&b, &mut x).unwrap().converged);
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.refactorizes), (0, 2, 0));
    }

    #[test]
    fn quarantine_forces_a_rebuild_on_the_next_request() {
        let cache = FactorCache::new(builder().seed(7), 4);
        let lap = Arc::new(generators::grid2d(9, 9, generators::Coeff::Uniform, 0));
        let fp = lap.fingerprint();
        let held = cache.get_or_build(&lap).unwrap();
        assert!(cache.quarantine(fp.full), "the session was resident");
        assert!(!cache.quarantine(fp.full), "already gone");
        assert_eq!(cache.len(), 0);
        // The quarantined clone keeps working for its holder…
        let b = crate::solve::pcg::random_rhs(&lap, 1);
        let mut x = vec![0.0; lap.n()];
        assert!(held.solve_shared(&b, &mut x).unwrap().converged);
        // …while the next request takes the miss path into a new
        // session with identical answers.
        let rebuilt = cache.get_or_build(&lap).unwrap();
        assert!(!Arc::ptr_eq(&held, &rebuilt));
        let mut x2 = vec![0.0; lap.n()];
        assert!(rebuilt.solve_shared(&b, &mut x2).unwrap().converged);
        assert_eq!(x, x2, "a rebuilt session answers bit-identically");
        let st = cache.stats();
        assert_eq!((st.misses, st.evictions), (2, 1));
    }

    #[test]
    fn rebuild_with_replaces_the_resident_session() {
        let cache = FactorCache::new(builder().seed(4), 4);
        let lap = Arc::new(generators::grid2d(8, 8, generators::Coeff::Uniform, 0));
        let first = cache.get_or_build(&lap).unwrap();
        // Degraded rebuild (bigger arena, sequential engine): replaces
        // the resident entry in place.
        let degraded = builder().seed(4).arena_factor(48.0).engine(crate::factor::Engine::Seq);
        let second = cache.rebuild_with(&lap, &degraded).unwrap();
        assert!(!Arc::ptr_eq(&first, &second));
        assert_eq!(cache.len(), 1);
        // The replacement is what later requests get.
        let third = cache.get_or_build(&lap).unwrap();
        assert!(Arc::ptr_eq(&second, &third));
        let b = crate::solve::pcg::random_rhs(&lap, 3);
        let mut x = vec![0.0; lap.n()];
        assert!(third.solve_shared(&b, &mut x).unwrap().converged);
    }

    #[test]
    fn capacity_bound_evicts_least_recently_used() {
        let cache = FactorCache::new(builder().seed(2), 2);
        let laps: Vec<Arc<Laplacian>> = (0..3)
            .map(|i| {
                Arc::new(generators::grid2d(6 + i, 6, generators::Coeff::Uniform, 0))
            })
            .collect();
        cache.get_or_build(&laps[0]).unwrap();
        cache.get_or_build(&laps[1]).unwrap();
        cache.get_or_build(&laps[0]).unwrap(); // touch 0 → 1 is LRU
        cache.get_or_build(&laps[2]).unwrap(); // evicts 1
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        // 0 is still resident (hit), 1 must rebuild (miss).
        let before = cache.stats().misses;
        cache.get_or_build(&laps[0]).unwrap();
        assert_eq!(cache.stats().misses, before);
        cache.get_or_build(&laps[1]).unwrap();
        assert_eq!(cache.stats().misses, before + 1);
    }
}
