//! The serving subsystem: one factor, many concurrent clients.
//!
//! The paper's economics — a randomized Cholesky factor is cheap to
//! build and amortized over many PCG solves — only pay off at scale if
//! *many callers* can ride one factor at once. This module supplies the
//! three layers that make that true:
//!
//! * [`workspace`] — [`WorkspacePool`]: per-call [`crate::solve::pcg::PcgWorkspace`]
//!   checkout, the mechanism behind the `&self` solve path
//!   ([`crate::solver::Solver::solve_shared`] /
//!   [`crate::solver::Solver::solve_batch_shared`]). The session's
//!   factor, ordering maps, and packed sweep arrays are immutable
//!   shared state; everything mutable is checked out per call.
//! * [`cache`] — [`FactorCache`]: a bounded
//!   [`Laplacian::fingerprint`](crate::graph::Laplacian::fingerprint)-keyed
//!   cache of built sessions. Repeated builds of the same graph return
//!   one `Arc`-shared solver; reweighted builds of a known pattern
//!   rerun only the numeric phase
//!   ([`crate::solver::Solver::refactorize_shared`]).
//! * [`service`] — [`SolveService`]: request admission from N client
//!   threads, coalescing compatible requests for the same factor into
//!   [`crate::solver::Solver::solve_batch_shared`] waves under
//!   bounded-wait / max-wave knobs ([`ServeOptions`]).
//!
//! Every layer preserves **bit-identity**: a request served through the
//! pool, the cache, and a coalesced wave returns exactly the bits a
//! lone sequential [`crate::solver::Solver::solve_into`] call would
//! (asserted in `rust/tests/serve.rs` and `rust/tests/alloc_free.rs`).
//! The `parac serve` CLI subcommand and `benches/bench_serve.rs` drive
//! this stack under open-loop load via
//! [`crate::coordinator::serve_driver`].
//!
//! The service is also the stack's **recovery boundary**: per-request
//! deadlines, panic quarantine of corrupt sessions, and
//! degrade-and-retry builds (see the [`service`] module docs and the
//! deterministic fault plane in [`crate::faults`]; soak-tested in
//! `rust/tests/chaos.rs`).

pub mod cache;
pub mod service;
pub mod workspace;

pub use cache::{CacheStats, FactorCache};
pub use service::{ServeOptions, ServiceStats, SolveService};
pub use workspace::WorkspacePool;

// The load-bearing property of the whole subsystem, checked at compile
// time: a built session is immutable shared state, safe to hand to any
// number of threads. If a future change smuggles non-Sync interior
// state into the solve path, this fails to compile.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<crate::solver::Solver<'static>>();
    assert_send_sync::<WorkspacePool>();
    assert_send_sync::<FactorCache>();
    assert_send_sync::<SolveService>();
};
