//! Per-call workspace checkout — the mechanism that makes the solve
//! path `&self`.
//!
//! A [`WorkspacePool`] owns every [`PcgWorkspace`] a
//! [`crate::solver::Solver`] session will ever use. A solve checks one
//! out on entry and returns it on exit; concurrent solves each get
//! their own, so the session itself carries **no per-solve mutable
//! state**. The pool grows lazily to the peak concurrency ever seen
//! (each growth step allocates one workspace) and then recycles
//! forever: the steady state is pop/push on a `Mutex<Vec<_>>` — no
//! heap allocation, a few nanoseconds of uncontended lock — which is
//! what keeps the zero-allocations-per-solve contract of
//! `rust/tests/alloc_free.rs` intact under concurrency.

use crate::solve::pcg::PcgWorkspace;
use std::sync::Mutex;

/// How many returned-workspace slots the free list pre-reserves, so
/// restores never reallocate the list until concurrency exceeds this.
const FREE_LIST_RESERVE: usize = 32;

/// A checkout pool of [`PcgWorkspace`]s, all sized for one operator
/// dimension.
pub struct WorkspacePool {
    /// Operator dimension every checked-out workspace is sized for.
    n: usize,
    /// Idle workspaces, warm from previous solves.
    free: Mutex<Vec<PcgWorkspace>>,
}

impl WorkspacePool {
    /// A pool for dimension `n`, pre-warmed with one workspace (the
    /// single-caller steady state never allocates).
    pub fn new(n: usize) -> WorkspacePool {
        let mut free = Vec::with_capacity(FREE_LIST_RESERVE);
        free.push(PcgWorkspace::new(n));
        WorkspacePool { n, free: Mutex::new(free) }
    }

    /// Dimension the pool's workspaces are sized for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Idle workspaces currently in the pool (diagnostic; racy under
    /// concurrency by nature).
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// Pre-create workspaces until at least `count` are resident, so a
    /// known client fleet can warm the pool before a measured or
    /// allocation-audited window.
    pub fn warm(&self, count: usize) {
        let mut free = self.free.lock().unwrap_or_else(|p| p.into_inner());
        while free.len() < count {
            free.push(PcgWorkspace::new(self.n));
        }
    }

    /// Take a workspace out of the pool (allocating a fresh one only
    /// when every resident workspace is already checked out — i.e. when
    /// this call raises the peak concurrency).
    pub fn checkout(&self) -> PcgWorkspace {
        let recycled = {
            // A poisoned lock only means a solve panicked while
            // checking out or restoring; the list is still valid.
            let mut free = self.free.lock().unwrap_or_else(|p| p.into_inner());
            free.pop()
        };
        recycled.unwrap_or_else(|| PcgWorkspace::new(self.n))
    }

    /// Return a workspace after a solve. Its buffers (and the free
    /// list's capacity) are retained, so the next checkout is
    /// allocation-free.
    pub fn restore(&self, ws: PcgWorkspace) {
        let mut free = self.free.lock().unwrap_or_else(|p| p.into_inner());
        free.push(ws);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_recycles_and_grows_on_demand() {
        let pool = WorkspacePool::new(64);
        assert_eq!(pool.n(), 64);
        assert_eq!(pool.idle(), 1);
        let a = pool.checkout();
        assert_eq!(pool.idle(), 0);
        // Pool empty: a second checkout mints a new workspace.
        let b = pool.checkout();
        pool.restore(a);
        pool.restore(b);
        assert_eq!(pool.idle(), 2);
        // Warm to a fleet size.
        pool.warm(8);
        assert_eq!(pool.idle(), 8);
        pool.warm(4); // never shrinks
        assert_eq!(pool.idle(), 8);
    }
}
