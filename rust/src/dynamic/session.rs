//! [`DynamicSession`]: a live solver session over a mutating graph.
//!
//! Each [`DynamicSession::step`] applies one [`UpdateBatch`] and picks
//! the cheapest repair path the delta admits (see the module docs in
//! [`crate::dynamic`]): pattern-preserving reweights rerun only the
//! numeric phase, contained structural edits take the cone-localized
//! repair from [`super::cone`], and everything else rebuilds through a
//! [`FactorCache`] so returning to a known graph is a cache hit. The
//! chosen path, cone size, update/solve timings, and the post-update
//! graph fingerprint come back in a [`StepReport`].

use crate::dynamic::{cone, UpdateBatch};
use crate::error::ParacError;
use crate::factor::LdlFactor;
use crate::graph::{Fingerprint, Laplacian};
use crate::serve::{CacheStats, FactorCache};
use crate::solve::pcg::SolveStats;
use crate::solver::{Solver, SolverBuilder};
use crate::util::Timer;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Which repair path a step took.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateClass {
    /// The sparsity pattern survived (or nothing changed): numeric-only
    /// refactorization, bit-identical to a fresh build.
    WeightOnly,
    /// Structural delta below the damage threshold: the elimination
    /// cone was re-eliminated and spliced into the factor.
    Localized,
    /// Full rebuild through the session's [`FactorCache`].
    Rebuild,
}

impl UpdateClass {
    /// Stable lower-case name (report/JSON field labels).
    pub fn name(&self) -> &'static str {
        match self {
            UpdateClass::WeightOnly => "weight-only",
            UpdateClass::Localized => "localized",
            UpdateClass::Rebuild => "rebuild",
        }
    }
}

/// How many steps each repair path has served.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassCounts {
    /// Steps classified [`UpdateClass::WeightOnly`].
    pub weight_only: u64,
    /// Steps classified [`UpdateClass::Localized`].
    pub localized: u64,
    /// Steps classified [`UpdateClass::Rebuild`] (escalations included).
    pub rebuild: u64,
}

impl ClassCounts {
    /// Total steps counted.
    pub fn total(&self) -> u64 {
        self.weight_only + self.localized + self.rebuild
    }
}

/// Knobs for the classification policy.
#[derive(Clone, Debug)]
pub struct DynamicOptions {
    /// Maximum dependency-cone size for the localized path, as a
    /// fraction of `n` (default 0.25). `0.0` disables the localized
    /// path entirely — every structural update rebuilds.
    pub damage_threshold: f64,
    /// Capacity of the rebuild-path [`FactorCache`] (default 4).
    pub cache_capacity: usize,
    /// When a localized repair's solve fails to converge, escalate to a
    /// full rebuild and re-solve instead of returning the stalled
    /// result (default `true`). The step is then counted as a rebuild
    /// and flagged [`StepReport::escalated`].
    pub escalate_on_stall: bool,
}

impl Default for DynamicOptions {
    fn default() -> DynamicOptions {
        DynamicOptions {
            damage_threshold: 0.25,
            cache_capacity: 4,
            escalate_on_stall: true,
        }
    }
}

/// What one [`DynamicSession::step`] did and what it cost.
#[derive(Clone, Debug)]
pub struct StepReport {
    /// 0-based step index.
    pub round: usize,
    /// Repair path the update took.
    pub class: UpdateClass,
    /// True when a stalled localized repair was escalated to a rebuild
    /// (`class` is then [`UpdateClass::Rebuild`]).
    pub escalated: bool,
    /// Dependency-cone size when the localized path ran.
    pub cone: Option<usize>,
    /// Seconds spent repairing the factor (classification included;
    /// escalation rebuild time included).
    pub update_secs: f64,
    /// Seconds spent in the PCG solve that produced `x`.
    pub solve_secs: f64,
    /// PCG iterations of that solve.
    pub iters: usize,
    /// Relative residual the solve reached.
    pub rel_residual: f64,
    /// Whether the solve converged to the session tolerance.
    pub converged: bool,
    /// Live edges after the batch.
    pub edges: usize,
    /// Fingerprint of the post-update graph (deterministic: the
    /// session's edge store iterates in sorted order).
    pub fingerprint: Fingerprint,
}

/// A solver session that follows a mutating graph; see
/// [`crate::dynamic`] for the path taxonomy.
pub struct DynamicSession {
    n: usize,
    /// Canonical edge store: key `(min(u,v), max(u,v))`, sorted
    /// iteration — round graphs are deterministic by construction.
    edges: BTreeMap<(u32, u32), f64>,
    lap: Arc<Laplacian>,
    fp: Fingerprint,
    solver: Arc<Solver<'static>>,
    cache: FactorCache,
    opts: DynamicOptions,
    round: usize,
    counts: ClassCounts,
    escalations: u64,
    /// True while the live factor matches the frozen symbolic analysis
    /// (fresh build / numeric refactorize). A splice invalidates it, so
    /// subsequent pattern-preserving batches must also go through the
    /// localized path until the next rebuild re-freezes the analysis.
    symbolic_fresh: bool,
}

impl DynamicSession {
    /// Open a session on `initial`, building the first factor with
    /// `builder` (which also parameterizes every later repair and the
    /// rebuild cache).
    pub fn new(
        initial: &Laplacian,
        builder: SolverBuilder,
        opts: DynamicOptions,
    ) -> Result<DynamicSession, ParacError> {
        let n = initial.n();
        let mut edges = BTreeMap::new();
        for (u, v, w) in initial.edges() {
            let key = (u.min(v), u.max(v));
            if key.0 != key.1 {
                *edges.entry(key).or_insert(0.0) += w;
            }
        }
        let lap = Arc::new(Self::assemble(n, &edges, 0));
        let fp = lap.fingerprint();
        let solver = Arc::new(builder.build_shared(lap.clone())?);
        let cache = FactorCache::new(builder, opts.cache_capacity.max(1));
        Ok(DynamicSession {
            n,
            edges,
            lap,
            fp,
            solver,
            cache,
            opts,
            round: 0,
            counts: ClassCounts::default(),
            escalations: 0,
            symbolic_fresh: true,
        })
    }

    fn assemble(n: usize, edges: &BTreeMap<(u32, u32), f64>, round: usize) -> Laplacian {
        let list: Vec<(u32, u32, f64)> =
            edges.iter().map(|(&(u, v), &w)| (u, v, w)).collect();
        Laplacian::from_edges(n, &list, &format!("dyn{round}"))
    }

    /// Apply `batch`, repair the factor along the cheapest admissible
    /// path, and solve `L x = b` on the updated graph. A batch that
    /// fails [`UpdateBatch::validate`] is rejected with a typed error
    /// before the graph is touched.
    pub fn step(
        &mut self,
        batch: &UpdateBatch,
        b: &[f64],
    ) -> Result<(StepReport, Vec<f64>), ParacError> {
        if b.len() != self.n {
            return Err(ParacError::DimensionMismatch {
                what: "rhs",
                expected: self.n,
                got: b.len(),
            });
        }
        batch.validate(self.n)?;

        // Adds before removes: add-then-remove of one edge in a single
        // batch nets to a removal.
        for &(u, v, w) in &batch.add {
            let key = (u.min(v), u.max(v));
            if key.0 != key.1 {
                *self.edges.entry(key).or_insert(0.0) += w;
            }
        }
        for &(u, v) in &batch.remove {
            self.edges.remove(&(u.min(v), u.max(v)));
        }
        let new_lap = Arc::new(Self::assemble(self.n, &self.edges, self.round + 1));
        let new_fp = new_lap.fingerprint();

        let timer = Timer::start();
        let mut class;
        let mut cone_size = None;
        if new_fp.full == self.fp.full {
            // The batch netted to nothing — the factor already matches.
            class = UpdateClass::WeightOnly;
        } else if new_fp.pattern == self.fp.pattern && self.symbolic_fresh {
            match self.try_weight_only(&new_lap) {
                Ok(()) => class = UpdateClass::WeightOnly,
                // A refused refactorize (shared session, stale symbolic,
                // numeric breakdown) degrades to a rebuild, not an error.
                Err(ParacError::BadInput(_)) | Err(ParacError::Internal(_)) => {
                    self.rebuild(&new_lap)?;
                    class = UpdateClass::Rebuild;
                }
                Err(e) => return Err(e),
            }
        } else {
            match self.try_localized(&new_lap, batch)? {
                Some(m) => {
                    class = UpdateClass::Localized;
                    cone_size = Some(m);
                }
                None => {
                    self.rebuild(&new_lap)?;
                    class = UpdateClass::Rebuild;
                }
            }
        }
        let mut update_secs = timer.secs();

        let mut x = vec![0.0; self.n];
        let solve_timer = Timer::start();
        let mut stats = self.solver.solve_shared(b, &mut x)?;
        let mut solve_secs = solve_timer.secs();
        let mut escalated = false;
        if !stats.converged && class == UpdateClass::Localized && self.opts.escalate_on_stall {
            // The spliced factor was not a good enough preconditioner:
            // escalate to a full rebuild and serve from that instead.
            let esc_timer = Timer::start();
            self.rebuild(&new_lap)?;
            update_secs += esc_timer.secs();
            let solve_timer = Timer::start();
            stats = self.solver.solve_shared(b, &mut x)?;
            solve_secs = solve_timer.secs();
            class = UpdateClass::Rebuild;
            cone_size = None;
            escalated = true;
            self.escalations += 1;
        }
        match class {
            UpdateClass::WeightOnly => self.counts.weight_only += 1,
            UpdateClass::Localized => self.counts.localized += 1,
            UpdateClass::Rebuild => self.counts.rebuild += 1,
        }

        self.lap = new_lap;
        self.fp = new_fp;
        let report = StepReport {
            round: self.round,
            class,
            escalated,
            cone: cone_size,
            update_secs,
            solve_secs,
            iters: stats.iters,
            rel_residual: stats.rel_residual,
            converged: stats.converged,
            edges: self.edges.len(),
            fingerprint: new_fp,
        };
        self.round += 1;
        Ok((report, x))
    }

    /// Numeric-only refactorize on the session's solver. Needs `&mut`
    /// access to the `Arc`'d solver; when the rebuild cache still holds
    /// a clone of it (the session's solver IS the cached one after a
    /// rebuild), quarantine that entry first to regain sole ownership.
    fn try_weight_only(&mut self, lap: &Arc<Laplacian>) -> Result<(), ParacError> {
        match self.exclusive_solver() {
            Some(s) => s.refactorize_shared(lap.clone()),
            None => Err(ParacError::BadInput(
                "session solver is shared; falling back to rebuild".into(),
            )),
        }
    }

    fn exclusive_solver(&mut self) -> Option<&mut Solver<'static>> {
        if Arc::get_mut(&mut self.solver).is_none() {
            self.cache.quarantine(self.fp.full);
        }
        Arc::get_mut(&mut self.solver)
    }

    /// Cone-localized repair; `Ok(None)` means "fall back to rebuild".
    fn try_localized(
        &mut self,
        lap: &Arc<Laplacian>,
        batch: &UpdateBatch,
    ) -> Result<Option<usize>, ParacError> {
        let max_cone = (self.opts.damage_threshold * self.n as f64) as usize;
        if max_cone == 0 {
            return Ok(None);
        }
        let touched = batch.touched();
        if touched.is_empty() {
            return Ok(None);
        }
        let spliced = {
            let Some(old) = self.solver.factor() else {
                return Ok(None);
            };
            let opts = self.cache.builder().parac_opts().clone();
            cone::localized_factor(old, lap, &touched, &opts, max_cone)
        };
        let Some((f, m)) = spliced else {
            return Ok(None);
        };
        let Some(s) = self.exclusive_solver() else {
            return Ok(None);
        };
        match s.splice_factor(lap.clone(), f) {
            Ok(()) => {
                self.symbolic_fresh = false;
                Ok(Some(m))
            }
            // Any splice refusal falls back to the rebuild path.
            Err(_) => Ok(None),
        }
    }

    fn rebuild(&mut self, lap: &Arc<Laplacian>) -> Result<(), ParacError> {
        self.solver = self.cache.get_or_build(lap)?;
        self.symbolic_fresh = true;
        Ok(())
    }

    /// Solve on the current graph without applying an update (read-only:
    /// usable between steps, e.g. by the scenario drivers).
    pub fn solve(&self, b: &[f64], x: &mut [f64]) -> Result<SolveStats, ParacError> {
        self.solver.solve_shared(b, x)
    }

    /// Vertex count (fixed for the session's lifetime).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Live edge count.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Steps applied so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// The current graph (rebuilt canonically after every step).
    pub fn laplacian(&self) -> &Arc<Laplacian> {
        &self.lap
    }

    /// Fingerprint of the current graph.
    pub fn fingerprint(&self) -> Fingerprint {
        self.fp
    }

    /// Per-path classification counts.
    pub fn counts(&self) -> ClassCounts {
        self.counts
    }

    /// Localized repairs that stalled and were escalated to rebuilds.
    pub fn escalations(&self) -> u64 {
        self.escalations
    }

    /// Hit/miss/refactorize counters of the rebuild cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The live factor, when the active preconditioner exposes one.
    pub fn factor(&self) -> Option<&LdlFactor> {
        self.solver.factor()
    }
}
