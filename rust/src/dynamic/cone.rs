//! Cone-localized refactorization: repair a factor after a small
//! structural edit without re-eliminating the whole graph.
//!
//! In an LDL-style elimination, the values of factor column `j` depend
//! only on columns that are *descendants* of `j` in the elimination
//! tree. Turned around: when an edit touches a set of vertices `T`, the
//! only columns whose values can change are `T` plus their etree
//! **ancestors** — the *dependency cone* `cone(T)`. Because the cone is
//! ancestor-closed, every column outside it has all of its descendants
//! outside the edit's influence too, so those columns are byte-for-byte
//! reusable.
//!
//! [`localized_factor`] exploits this: it extracts the cone's induced
//! subproblem from the **new** graph, collapses every edge leaving the
//! cone onto a ground vertex (exactly how
//! [`crate::factor::factorize_sdd`] grounds an SDD system), re-runs the
//! randomized elimination on that small grounded problem pinned so the
//! ground is eliminated last, truncates the ground away, and splices
//! the re-eliminated columns back into the old factor. The result is a
//! *bona fide* approximate factor of the new graph — the cone columns
//! see the exact boundary coupling (Schur complements onto the ground
//! are what elimination does anyway), and the rest is unchanged by the
//! ancestor-closure argument.
//!
//! The splice is approximate in the same sense the base factor is
//! (randomized sampling inside the cone uses fresh clique samples), so
//! correctness is pinned behaviorally in `rust/tests/dynamic.rs`: the
//! spliced factor's PCG solve must converge to the same tolerance as a
//! full rebuild for every suite graph. Any structural doubt —
//! oversized cone, non-natural local ordering, a failed
//! [`crate::factor::LdlFactor::validate`] — returns `None` and the
//! caller falls back to a full rebuild.

use crate::etree;
use crate::factor::{self, LdlFactor, ParacOptions};
use crate::graph::Laplacian;
use crate::ordering::Ordering;
use crate::sparse::Csc;

/// Union of elimination-tree root-paths from the `touched` columns
/// (indices in the factor's permuted space): every factor column whose
/// values can depend on a touched column. Returned sorted ascending.
/// Returns `None` as soon as the cone exceeds `max_cone` — the signal
/// that a localized repair would not pay for itself.
pub fn dependency_cone(parent: &[i64], touched: &[u32], max_cone: usize) -> Option<Vec<u32>> {
    let mut seen = vec![false; parent.len()];
    let mut cone = Vec::new();
    for &t in touched {
        let mut j = t as usize;
        loop {
            if j >= seen.len() || seen[j] {
                break;
            }
            seen[j] = true;
            cone.push(j as u32);
            if cone.len() > max_cone {
                return None;
            }
            match parent[j] {
                p if p >= 0 && p as usize > j => j = p as usize,
                _ => break,
            }
        }
    }
    cone.sort_unstable();
    Some(cone)
}

/// Re-eliminate the dependency cone of `touched` (original vertex ids)
/// against `new_lap` and splice the result into `old`, producing a
/// factor for the new graph. Returns the spliced factor and the cone
/// size, or `None` when the repair is not worthwhile / not safe (cone
/// larger than `max_cone`, cone covers the whole graph, local
/// elimination failed, or the spliced factor fails validation) — the
/// caller should fall back to a full rebuild.
pub fn localized_factor(
    old: &LdlFactor,
    new_lap: &Laplacian,
    touched: &[u32],
    opts: &ParacOptions,
    max_cone: usize,
) -> Option<(LdlFactor, usize)> {
    let n = old.n();
    if n == 0 || new_lap.n() != n || touched.is_empty() || max_cone == 0 {
        return None;
    }
    // The cone lives in the factor's elimination (permuted) space.
    let touched_perm: Vec<u32> = match &old.perm {
        Some(p) => touched
            .iter()
            .map(|&v| p.get(v as usize).copied())
            .collect::<Option<Vec<u32>>>()?,
        None => touched.to_vec(),
    };
    let parent = etree::etree_from_factor(&old.g);
    let cone = dependency_cone(&parent, &touched_perm, max_cone)?;
    let m = cone.len();
    if m == 0 || m >= n {
        return None;
    }

    // Original vertex id of each cone member; cone order (ascending
    // permuted index) is the elimination order the splice must keep.
    let orig: Vec<u32> = match &old.perm {
        Some(p) => {
            let mut iperm = vec![0u32; n];
            for (o, &np) in p.iter().enumerate() {
                iperm[np as usize] = o as u32;
            }
            cone.iter().map(|&c| iperm[c as usize]).collect()
        }
        None => cone.clone(),
    };
    let mut local_of = vec![u32::MAX; n]; // keyed by original vertex id
    for (l, &o) in orig.iter().enumerate() {
        local_of[o as usize] = l as u32;
    }

    // Grounded cone subproblem of the NEW graph: intra-cone edges keep
    // their weights; all coupling that leaves the cone collapses onto a
    // ground vertex (index m), eliminated last and truncated away.
    let mut ledges: Vec<(u32, u32, f64)> = Vec::new();
    let mut ground = vec![0.0f64; m];
    for (l, &o) in orig.iter().enumerate() {
        let row = o as usize;
        let idx = new_lap.matrix.row_indices(row);
        let val = new_lap.matrix.row_data(row);
        for (&c, &v) in idx.iter().zip(val) {
            let c = c as usize;
            if c == row {
                continue;
            }
            let w = -v; // off-diagonal of a Laplacian is -weight
            if !w.is_finite() || w <= 0.0 {
                continue;
            }
            let lc = local_of[c];
            if lc == u32::MAX {
                ground[l] += w;
            } else if (lc as usize) > l {
                ledges.push((l as u32, lc, w));
            }
        }
    }
    for (l, &g) in ground.iter().enumerate() {
        if g > 0.0 {
            ledges.push((l as u32, m as u32, g));
        }
    }
    if ledges.is_empty() {
        return None;
    }
    let ext = Laplacian::from_edges(m + 1, &ledges, "cone");
    // Natural ordering + pin-last keeps local labels in place, so local
    // column l IS cone position l — the property the splice relies on.
    let lopts = ParacOptions {
        ordering: Ordering::Natural,
        ..opts.clone()
    };
    let f = factor::factorize_pinned(&ext, &lopts, Some(m as u32)).ok()?;
    let local = f.truncate_last();
    if local.n() != m {
        return None;
    }
    if let Some(p) = &local.perm {
        // Anything but the identity would mis-splice; bail rather than
        // assume (defensive — Natural + pin-last is identity today).
        if p.iter().enumerate().any(|(i, &q)| q as usize != i) {
            return None;
        }
    }

    // Splice: cone columns come from the local factor (rows mapped back
    // through `cone` — monotone, so sortedness and strict lowerness are
    // preserved), every other column is carried over verbatim.
    let mut in_cone = vec![false; n];
    for &c in &cone {
        in_cone[c as usize] = true;
    }
    let mut colptr = Vec::with_capacity(n + 1);
    colptr.push(0usize);
    let mut rowidx: Vec<u32> = Vec::new();
    let mut data: Vec<f64> = Vec::new();
    let mut diag = old.diag.clone();
    let mut next_local = 0usize;
    for j in 0..n {
        if in_cone[j] {
            let l = next_local;
            next_local += 1;
            for (&r, &v) in local.g.col_rows(l).iter().zip(local.g.col_data(l)) {
                rowidx.push(cone[r as usize]);
                data.push(v);
            }
            diag[j] = local.diag[l];
        } else {
            for (&r, &v) in old.g.col_rows(j).iter().zip(old.g.col_data(j)) {
                rowidx.push(r);
                data.push(v);
            }
        }
        colptr.push(rowidx.len());
    }
    let g = Csc {
        nrows: n,
        ncols: n,
        colptr,
        rowidx,
        data,
    };
    let spliced = LdlFactor {
        g,
        diag,
        perm: old.perm.clone(),
        stats: old.stats.clone(),
    };
    spliced.validate().ok()?;
    Some((spliced, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Coeff};
    use crate::precond::LdlPrecond;
    use crate::solve::pcg::{self, PcgOptions, PcgWorkspace};

    #[test]
    fn dependency_cone_climbs_root_paths() {
        // A path etree: 0 → 1 → 2 → 3 → root.
        let parent = vec![1i64, 2, 3, -1];
        assert_eq!(dependency_cone(&parent, &[0], 10), Some(vec![0, 1, 2, 3]));
        assert_eq!(dependency_cone(&parent, &[2], 10), Some(vec![2, 3]));
        // Shared ancestors are visited once.
        assert_eq!(dependency_cone(&parent, &[0, 2], 10), Some(vec![0, 1, 2, 3]));
        // Budget exceeded → None.
        assert_eq!(dependency_cone(&parent, &[0], 3), None);
    }

    #[test]
    fn localized_factor_splices_a_working_preconditioner() {
        let lap = generators::grid2d(12, 12, Coeff::Uniform, 0);
        let opts = ParacOptions::default();
        let old = factor::factorize(&lap, &opts).unwrap();

        // Structural edit: one fresh long-range edge.
        let mut edges = lap.edges();
        edges.push((3, 100, 1.25));
        let new_lap = Laplacian::from_edges(lap.n(), &edges, "edited");

        let (spliced, m) =
            localized_factor(&old, &new_lap, &[3, 100], &opts, lap.n()).expect("cone repair");
        assert!(m >= 2 && m < lap.n(), "cone size {m} out of range");
        spliced.validate().unwrap();

        // Non-cone columns are byte-identical to the old factor.
        let parent = etree::etree_from_factor(&old.g);
        let perm = old.perm.as_ref().unwrap();
        let cone = dependency_cone(&parent, &[perm[3], perm[100]], lap.n()).unwrap();
        let mut in_cone = vec![false; lap.n()];
        for &c in &cone {
            in_cone[c as usize] = true;
        }
        for j in 0..lap.n() {
            if !in_cone[j] {
                assert_eq!(spliced.g.col_rows(j), old.g.col_rows(j));
                assert_eq!(spliced.g.col_data(j), old.g.col_data(j));
                assert_eq!(spliced.diag[j], old.diag[j]);
            }
        }

        // And the spliced factor preconditions the NEW system to
        // convergence.
        let pre = LdlPrecond::new(spliced);
        let b = pcg::random_rhs(&new_lap, 7);
        let mut ws = PcgWorkspace::new(new_lap.n());
        let mut x = vec![0.0; new_lap.n()];
        let popts = PcgOptions {
            tol: 1e-8,
            max_iter: 600,
            ..Default::default()
        };
        let stats = pcg::solve_into(&new_lap.matrix, &b, &pre, &popts, &mut ws, &mut x);
        assert!(
            stats.converged,
            "spliced preconditioner failed: {} iters, rel {}",
            stats.iters, stats.rel_residual
        );
    }

    #[test]
    fn oversized_cone_is_refused() {
        let lap = generators::grid2d(10, 10, Coeff::Uniform, 1);
        let opts = ParacOptions::default();
        let old = factor::factorize(&lap, &opts).unwrap();
        let mut edges = lap.edges();
        edges.push((0, 99, 1.0));
        let new_lap = Laplacian::from_edges(lap.n(), &edges, "edited");
        // A one-column budget cannot hold any real cone.
        assert!(localized_factor(&old, &new_lap, &[0, 99], &opts, 1).is_none());
    }
}
