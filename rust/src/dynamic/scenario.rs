//! The scenario zoo: dynamic workloads that drive a [`DynamicSession`]
//! the way the paper's §1 imagines — "the input changes every round".
//!
//! Three scenarios, each exercising a different mix of repair paths:
//!
//! * [`edge_churn`] — rotating reweight / insert / delete rounds, the
//!   generic stream: reweights land on the weight-only path, small
//!   inserts and deletes on the localized path.
//! * [`spectral_partition`] — inverse-power iteration **on the session's
//!   own solver** approximates the Fiedler vector, the induced median
//!   cut is weakened by deleting its lightest edges each round (never
//!   disconnecting the graph). This is the classic
//!   partition-refine-repartition loop, and every round's deletions are
//!   structural.
//! * [`resistance_sparsify`] — Spielman–Srivastava-style: sample edges,
//!   estimate leverage `w·R_eff` with one projected solve per edge
//!   (`R_eff(u,v) = (e_u - e_v)ᵀ L⁺ (e_u - e_v)`), and drop the
//!   lowest-leverage edges, again keeping the graph connected. The
//!   incremental-sparsification use-case verbatim.
//!
//! Every scenario returns a [`ScenarioReport`] with classification
//! counts, mean per-path update latency, and (optionally) a from-scratch
//! rebuild baseline timed on the same round graphs — the numbers
//! `BENCH_dynamic.json` and `parac dynamic` publish.

use crate::dynamic::{ClassCounts, DynamicOptions, DynamicSession, StepReport, UpdateBatch, UpdateClass};
use crate::error::ParacError;
use crate::graph::Laplacian;
use crate::rng::Rng;
use crate::solve::pcg;
use crate::solver::SolverBuilder;
use crate::util::Timer;

/// Names accepted by [`run`], in display order.
pub const SCENARIOS: &[&str] = &["churn", "spectral", "resist"];

/// Shared scenario knobs.
#[derive(Clone, Debug)]
pub struct ScenarioOptions {
    /// Update rounds to drive (default 8).
    pub rounds: usize,
    /// Stream seed (default `0xD11A`).
    pub seed: u64,
    /// Also time a from-scratch `build_shared` on every round graph as
    /// the latency yardstick (default `true`; benches keep it on, tests
    /// turn it off).
    pub measure_full_rebuild: bool,
    /// Session policy knobs.
    pub dynamic: DynamicOptions,
}

impl Default for ScenarioOptions {
    fn default() -> ScenarioOptions {
        ScenarioOptions {
            rounds: 8,
            seed: 0xD11A,
            measure_full_rebuild: true,
            dynamic: DynamicOptions::default(),
        }
    }
}

/// What one scenario run did and what each path cost.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// Scenario name (one of [`SCENARIOS`]).
    pub name: &'static str,
    /// Graph the stream ran on.
    pub graph: String,
    /// Rounds driven.
    pub rounds: usize,
    /// How the rounds classified.
    pub counts: ClassCounts,
    /// Stalled localized repairs escalated to rebuilds.
    pub escalations: u64,
    /// Mean update seconds on the weight-only path (0 when unused).
    pub weight_only_secs: f64,
    /// Mean update seconds on the localized path (0 when unused).
    pub localized_secs: f64,
    /// Mean update seconds on the rebuild path (0 when unused).
    pub rebuild_secs: f64,
    /// Mean from-scratch build seconds on the same round graphs (0 when
    /// [`ScenarioOptions::measure_full_rebuild`] was off).
    pub full_rebuild_secs: f64,
    /// Mean per-round solve seconds.
    pub solve_secs: f64,
    /// Mean per-round PCG iterations.
    pub mean_iters: f64,
    /// Whether every round's solve converged.
    pub all_converged: bool,
    /// Live edges after the last round.
    pub final_edges: usize,
    /// Scenario-specific scalar: edges churned (churn), final cut
    /// weight (spectral), edges removed (resist).
    pub metric: f64,
}

impl ScenarioReport {
    /// Flatten into [`crate::coordinator::pipeline::BenchRow`] fields.
    pub fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("rounds", self.rounds as f64),
            ("weight_only", self.counts.weight_only as f64),
            ("localized", self.counts.localized as f64),
            ("rebuild", self.counts.rebuild as f64),
            ("escalations", self.escalations as f64),
            ("weight_only_secs", self.weight_only_secs),
            ("localized_secs", self.localized_secs),
            ("rebuild_secs", self.rebuild_secs),
            ("full_rebuild_secs", self.full_rebuild_secs),
            ("solve_secs", self.solve_secs),
            ("mean_iters", self.mean_iters),
            ("converged", if self.all_converged { 1.0 } else { 0.0 }),
            ("final_edges", self.final_edges as f64),
            ("metric", self.metric),
        ]
    }
}

/// Run a named scenario (see [`SCENARIOS`]).
pub fn run(
    name: &str,
    lap: &Laplacian,
    builder: SolverBuilder,
    opts: &ScenarioOptions,
) -> Result<ScenarioReport, ParacError> {
    match name {
        "churn" => edge_churn(lap, builder, opts),
        "spectral" => spectral_partition(lap, builder, opts),
        "resist" => resistance_sparsify(lap, builder, opts),
        other => Err(ParacError::InvalidOption {
            what: "scenario (churn|spectral|resist)",
            got: other.into(),
        }),
    }
}

/// Per-path accumulator shared by the scenario drivers.
struct Acc {
    wo: (f64, u64),
    loc: (f64, u64),
    rb: (f64, u64),
    solve: f64,
    iters: f64,
    rounds: usize,
    converged: bool,
    baseline: f64,
    baseline_n: u64,
}

impl Acc {
    fn new() -> Acc {
        Acc {
            wo: (0.0, 0),
            loc: (0.0, 0),
            rb: (0.0, 0),
            solve: 0.0,
            iters: 0.0,
            rounds: 0,
            converged: true,
            baseline: 0.0,
            baseline_n: 0,
        }
    }

    fn absorb(&mut self, rep: &StepReport) {
        let slot = match rep.class {
            UpdateClass::WeightOnly => &mut self.wo,
            UpdateClass::Localized => &mut self.loc,
            UpdateClass::Rebuild => &mut self.rb,
        };
        slot.0 += rep.update_secs;
        slot.1 += 1;
        self.solve += rep.solve_secs;
        self.iters += rep.iters as f64;
        self.rounds += 1;
        self.converged &= rep.converged;
    }

    /// Time a from-scratch build on the session's current graph — the
    /// "what a rebuild-every-round loop would pay" yardstick.
    fn baseline_round(
        &mut self,
        session: &DynamicSession,
        builder: &SolverBuilder,
    ) -> Result<(), ParacError> {
        let t = Timer::start();
        let s = builder.build_shared(session.laplacian().clone())?;
        self.baseline += t.secs();
        self.baseline_n += 1;
        drop(s);
        Ok(())
    }

    fn report(
        self,
        name: &'static str,
        session: &DynamicSession,
        metric: f64,
    ) -> ScenarioReport {
        let mean = |(secs, n): (f64, u64)| if n > 0 { secs / n as f64 } else { 0.0 };
        let rounds = self.rounds.max(1) as f64;
        ScenarioReport {
            name,
            graph: session.laplacian().name.clone(),
            rounds: self.rounds,
            counts: session.counts(),
            escalations: session.escalations(),
            weight_only_secs: mean(self.wo),
            localized_secs: mean(self.loc),
            rebuild_secs: mean(self.rb),
            full_rebuild_secs: mean((self.baseline, self.baseline_n)),
            solve_secs: self.solve / rounds,
            mean_iters: self.iters / rounds,
            all_converged: self.converged,
            final_edges: session.num_edges(),
            metric,
        }
    }
}

/// Candidate removals keep the graph connected? Checked on a probe
/// Laplacian of the surviving edges — the projected solve needs one
/// component.
fn stays_connected(session: &DynamicSession, removals: &[(u32, u32)]) -> bool {
    let edges: Vec<(u32, u32, f64)> = session
        .laplacian()
        .edges()
        .into_iter()
        .filter(|&(u, v, _)| !removals.contains(&(u.min(v), u.max(v))))
        .collect();
    if edges.is_empty() {
        return false;
    }
    let probe = Laplacian::from_edges(session.n(), &edges, "probe");
    probe.components().1 == 1
}

/// Rotating reweight / insert / delete stream: round `3k` reweights
/// existing edges (weight-only path), round `3k+1` inserts fresh random
/// edges, round `3k+2` deletes some of the previously inserted extras
/// (both structural). The base graph is never deleted from, so the
/// stream stays connected by construction. `metric` = edges churned.
pub fn edge_churn(
    lap: &Laplacian,
    builder: SolverBuilder,
    opts: &ScenarioOptions,
) -> Result<ScenarioReport, ParacError> {
    let n = lap.n();
    let mut session = DynamicSession::new(lap, builder.clone(), opts.dynamic.clone())?;
    let mut rng = Rng::new(opts.seed ^ 0xC0FF_EE00);
    let b = pcg::random_rhs(lap, opts.seed);
    let churn = (n / 50).clamp(2, 64);
    let mut acc = Acc::new();
    let mut extras: Vec<(u32, u32)> = Vec::new();
    let mut churned = 0u64;
    for round in 0..opts.rounds {
        let mut batch = UpdateBatch::default();
        match round % 3 {
            0 => {
                // Reweight existing edges: pattern-preserving.
                let edges = session.laplacian().edges();
                for _ in 0..churn {
                    let (u, v, _) = edges[rng.below(edges.len())];
                    batch.add.push((u, v, rng.range_f64(0.1, 1.0)));
                }
            }
            1 => {
                // Insert fresh random edges; only record as removable
                // extras the ones that did not already exist, so the
                // delete round never touches the base graph.
                for _ in 0..churn {
                    let u = rng.below(n) as u32;
                    let v = rng.below(n) as u32;
                    let key = (u.min(v), u.max(v));
                    if u == v || extras.contains(&key) {
                        continue;
                    }
                    let existed =
                        session.laplacian().matrix.get(u as usize, v as usize) != 0.0;
                    batch.add.push((u, v, rng.range_f64(0.5, 2.0)));
                    if !existed {
                        extras.push(key);
                    }
                }
            }
            _ => {
                // Delete previously inserted extras.
                for _ in 0..churn.min(extras.len()) {
                    let i = rng.below(extras.len());
                    batch.remove.push(extras.swap_remove(i));
                }
                if batch.remove.is_empty() {
                    // Nothing insert-round gave us yet: reweight instead.
                    let edges = session.laplacian().edges();
                    let (u, v, _) = edges[rng.below(edges.len())];
                    batch.add.push((u, v, 0.5));
                }
            }
        }
        churned += (batch.add.len() + batch.remove.len()) as u64;
        let (rep, _x) = session.step(&batch, &b)?;
        acc.absorb(&rep);
        if opts.measure_full_rebuild {
            acc.baseline_round(&session, &builder)?;
        }
    }
    Ok(acc.report("churn", &session, churned as f64))
}

/// One projected-and-normalized vector (mean removed, unit 2-norm).
fn project_and_normalize(x: &mut [f64]) {
    let mean = x.iter().sum::<f64>() / x.len() as f64;
    for v in x.iter_mut() {
        *v -= mean;
    }
    let nrm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    if nrm > 0.0 {
        for v in x.iter_mut() {
            *v /= nrm;
        }
    }
}

/// Approximate Fiedler vector by inverse-power iteration on the
/// session's solver: repeatedly apply `L⁺` (one PCG solve per step) to
/// a mean-zero vector — low Laplacian modes are amplified most.
fn inverse_power(
    session: &DynamicSession,
    steps: usize,
    rng: &mut Rng,
) -> Result<Vec<f64>, ParacError> {
    let n = session.n();
    let mut x: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
    project_and_normalize(&mut x);
    let mut y = vec![0.0; n];
    for _ in 0..steps {
        session.solve(&x, &mut y)?;
        std::mem::swap(&mut x, &mut y);
        project_and_normalize(&mut x);
    }
    Ok(x)
}

/// Spectral partition-and-refine loop: per round, estimate the Fiedler
/// vector (inverse-power on the session), split at its median, and
/// delete up to 3 of the cut's lightest edges — skipping any deletion
/// that would disconnect the graph; rounds with nothing removable
/// strengthen an uncut edge instead (weight-only). `metric` = final cut
/// weight.
pub fn spectral_partition(
    lap: &Laplacian,
    builder: SolverBuilder,
    opts: &ScenarioOptions,
) -> Result<ScenarioReport, ParacError> {
    let n = lap.n();
    let mut session = DynamicSession::new(lap, builder.clone(), opts.dynamic.clone())?;
    let mut rng = Rng::new(opts.seed ^ 0x5EC7_0000);
    let b = pcg::random_rhs(lap, opts.seed);
    let mut acc = Acc::new();
    let mut cut_weight = 0.0;
    for _round in 0..opts.rounds {
        let fiedler = inverse_power(&session, 4, &mut rng)?;
        let mut sorted = fiedler.clone();
        sorted.sort_by(f64::total_cmp);
        let median = sorted[n / 2];
        let side: Vec<bool> = fiedler.iter().map(|&v| v > median).collect();

        let mut cut: Vec<(u32, u32, f64)> = session
            .laplacian()
            .edges()
            .into_iter()
            .filter(|&(u, v, _)| side[u as usize] != side[v as usize])
            .collect();
        cut_weight = cut.iter().map(|e| e.2).sum();
        cut.sort_by(|a, c| a.2.total_cmp(&c.2));

        let mut batch = UpdateBatch::default();
        let mut removals: Vec<(u32, u32)> = Vec::new();
        for &(u, v, _) in cut.iter().take(6) {
            if batch.remove.len() == 3 {
                break;
            }
            removals.push((u.min(v), u.max(v)));
            if stays_connected(&session, &removals) {
                batch.remove.push((u, v));
            } else {
                removals.pop();
            }
        }
        if batch.remove.is_empty() {
            // Cut is all bridges (or empty): strengthen the heaviest
            // uncut edge instead so the round still does work.
            let uncut = session
                .laplacian()
                .edges()
                .into_iter()
                .filter(|&(u, v, _)| side[u as usize] == side[v as usize])
                .max_by(|a, c| a.2.total_cmp(&c.2));
            if let Some((u, v, w)) = uncut {
                batch.add.push((u, v, 0.5 * w.max(1e-12)));
            }
        }
        let (rep, _x) = session.step(&batch, &b)?;
        acc.absorb(&rep);
        if opts.measure_full_rebuild {
            acc.baseline_round(&session, &builder)?;
        }
    }
    Ok(acc.report("spectral", &session, cut_weight))
}

/// Effective-resistance sparsification: per round, sample up to 8
/// edges, estimate each one's leverage `w·R_eff` with one projected
/// solve (`R_eff(u,v) = x[u] - x[v]` for `L x = e_u - e_v`), and drop
/// the lowest-leverage half — skipping near-bridges (leverage ≈ 1) and
/// anything that would disconnect the graph; incompressible rounds
/// reweight instead. `metric` = total edges removed.
pub fn resistance_sparsify(
    lap: &Laplacian,
    builder: SolverBuilder,
    opts: &ScenarioOptions,
) -> Result<ScenarioReport, ParacError> {
    let n = lap.n();
    let mut session = DynamicSession::new(lap, builder.clone(), opts.dynamic.clone())?;
    let mut rng = Rng::new(opts.seed ^ 0x2E55_0000);
    let b = pcg::random_rhs(lap, opts.seed);
    let mut acc = Acc::new();
    let mut removed_total = 0u64;
    let mut rhs = vec![0.0; n];
    let mut x = vec![0.0; n];
    for _round in 0..opts.rounds {
        let edges = session.laplacian().edges();
        let sample = edges.len().min(8);
        // Sample `sample` distinct edge indices (partial Fisher–Yates).
        let mut idx: Vec<usize> = (0..edges.len()).collect();
        let mut scored: Vec<((u32, u32), f64)> = Vec::with_capacity(sample);
        for k in 0..sample {
            let j = k + rng.below(idx.len() - k);
            idx.swap(k, j);
            let (u, v, w) = edges[idx[k]];
            rhs.fill(0.0);
            rhs[u as usize] = 1.0;
            rhs[v as usize] = -1.0;
            session.solve(&rhs, &mut x)?;
            let r_eff = (x[u as usize] - x[v as usize]).max(0.0);
            scored.push(((u, v), w * r_eff));
        }
        scored.sort_by(|a, c| a.1.total_cmp(&c.1));

        let mut batch = UpdateBatch::default();
        let mut removals: Vec<(u32, u32)> = Vec::new();
        for &((u, v), leverage) in scored.iter().take(sample / 2) {
            if leverage >= 0.99 {
                // Bridge-like: R_eff ≈ 1/w ⇒ leverage ≈ 1; removal
                // would disconnect (or nearly so). Keep it.
                continue;
            }
            removals.push((u.min(v), u.max(v)));
            if stays_connected(&session, &removals) {
                batch.remove.push((u, v));
            } else {
                removals.pop();
            }
        }
        if batch.remove.is_empty() {
            // Fully incompressible round: compensating reweight.
            let ((u, v), _) = scored[scored.len() - 1];
            batch.add.push((u, v, 0.25));
        }
        removed_total += batch.remove.len() as u64;
        let (rep, _sol) = session.step(&batch, &b)?;
        acc.absorb(&rep);
        if opts.measure_full_rebuild {
            acc.baseline_round(&session, &builder)?;
        }
    }
    Ok(acc.report("resist", &session, removed_total as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Coeff};
    use crate::solver::Solver;

    #[test]
    fn every_scenario_runs_and_converges_on_a_grid() {
        let lap = generators::grid2d(12, 12, Coeff::Uniform, 1);
        let opts = ScenarioOptions {
            rounds: 3,
            seed: 11,
            measure_full_rebuild: false,
            dynamic: DynamicOptions::default(),
        };
        for name in SCENARIOS {
            let rep = run(
                name,
                &lap,
                Solver::builder().seed(2).tol(1e-7).max_iter(1200),
                &opts,
            )
            .unwrap();
            assert_eq!(rep.rounds, 3, "{name}");
            assert_eq!(rep.counts.total(), 3, "{name}");
            assert!(rep.all_converged, "{name} had a non-converged round");
            assert!(rep.mean_iters > 0.0, "{name}");
            assert_eq!(rep.fields().len(), 14);
        }
    }

    #[test]
    fn unknown_scenario_is_a_typed_error() {
        let lap = generators::grid2d(6, 6, Coeff::Uniform, 0);
        assert!(matches!(
            run("nope", &lap, Solver::builder(), &ScenarioOptions::default()),
            Err(ParacError::InvalidOption { .. })
        ));
    }
}
