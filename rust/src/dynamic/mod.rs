//! Dynamic graphs: delta-classified updates over a live solver session.
//!
//! The paper's §1 motivation names workloads "where the input changes
//! every round, such as incremental sparsification". This subsystem
//! makes that first-class: a [`DynamicSession`] keeps a
//! [`crate::solver::Solver`] session alive while the graph mutates, and
//! classifies each [`UpdateBatch`] onto the cheapest of three
//! escalating repair paths:
//!
//! 1. **Weight-only** — the batch reweights existing edges without
//!    changing the sparsity pattern. The frozen symbolic analysis from
//!    the PR 5 split still describes the graph, so the session reruns
//!    only the numeric phase
//!    ([`crate::solver::Solver::refactorize_shared`]) — bit-identical
//!    to a fresh build at a fraction of the cost.
//! 2. **Cone-localized** — the pattern changed, but the damage is
//!    contained. The columns whose factor values can depend on the
//!    touched vertices form a *dependency cone* in the elimination
//!    tree (the touched columns plus their etree ancestors,
//!    [`cone::dependency_cone`]); [`cone::localized_factor`]
//!    re-eliminates just that cone against the new graph (grounding the
//!    boundary exactly like [`crate::factor::factorize_sdd`] grounds an
//!    SDD system) and splices the result into the previous factor via
//!    [`crate::solver::Solver::splice_factor`].
//! 3. **Full rebuild** — the cone exceeds the damage threshold
//!    ([`DynamicOptions::damage_threshold`]) or a splice fails
//!    validation. Rebuilds route through a [`crate::serve::FactorCache`]
//!    so returning to a previously seen graph (or pattern) hits the
//!    cache instead of refactorizing from scratch.
//!
//! The [`scenario`] zoo drives the session with the workloads the paper
//! gestures at: edge-churn streams, spectral partitioning via
//! inverse-power iteration on the solver itself, and an
//! effective-resistance sparsification loop. The `parac dynamic` CLI
//! subcommand and `benches/bench_dynamic.rs` (`BENCH_dynamic.json`)
//! report per-path update latency against a from-scratch rebuild
//! baseline plus classification counts.
//!
//! [`crate::coordinator::incremental`] remains as the minimal
//! rebuild-every-round reference loop; its [`UpdateBatch`] now lives
//! here and is shared by both.

pub mod cone;
pub mod scenario;
pub mod session;

pub use session::{
    ClassCounts, DynamicOptions, DynamicSession, StepReport, UpdateClass,
};

use crate::error::ParacError;

/// One batch of edge updates applied between solves.
///
/// Semantics (pinned in `rust/tests/dynamic.rs`):
/// * `add` edges **accumulate**: adding an existing edge increases its
///   weight; repeated adds of the same endpoints sum.
/// * `remove` deletes the edge outright regardless of weight; removing
///   a nonexistent edge is a no-op.
/// * Adds apply before removes, so add-then-remove of the same edge in
///   one batch nets to the edge being absent.
/// * Endpoints are unordered (`(u, v)` ≡ `(v, u)`); self-loops are
///   ignored.
#[derive(Clone, Debug, Default)]
pub struct UpdateBatch {
    /// Edges to insert or reweight: `(u, v, added_weight)`.
    pub add: Vec<(u32, u32, f64)>,
    /// Edges to delete: `(u, v)`.
    pub remove: Vec<(u32, u32)>,
}

impl UpdateBatch {
    /// An empty batch (identical to `Default::default()`).
    pub fn new() -> UpdateBatch {
        UpdateBatch::default()
    }

    /// True when the batch carries no adds and no removes.
    pub fn is_empty(&self) -> bool {
        self.add.is_empty() && self.remove.is_empty()
    }

    /// Reject malformed updates with typed errors **before** anything
    /// is applied: non-finite or nonpositive add-weights and
    /// out-of-range endpoints are [`ParacError::BadInput`], matching
    /// the finiteness gates on the serving path. Sessions call this at
    /// the top of `step`, so a rejected batch leaves the graph
    /// untouched.
    pub fn validate(&self, n: usize) -> Result<(), ParacError> {
        for &(u, v, w) in &self.add {
            if !w.is_finite() {
                return Err(ParacError::BadInput(format!(
                    "update weight for edge ({u}, {v}) is not finite ({w})"
                )));
            }
            if w <= 0.0 {
                return Err(ParacError::BadInput(format!(
                    "update weight for edge ({u}, {v}) must be positive, got {w}"
                )));
            }
            if u as usize >= n || v as usize >= n {
                return Err(ParacError::BadInput(format!(
                    "update edge ({u}, {v}) out of range for {n} vertices"
                )));
            }
        }
        for &(u, v) in &self.remove {
            if u as usize >= n || v as usize >= n {
                return Err(ParacError::BadInput(format!(
                    "removal edge ({u}, {v}) out of range for {n} vertices"
                )));
            }
        }
        Ok(())
    }

    /// Sorted, deduplicated list of every vertex the batch touches
    /// (self-loop endpoints excluded — they never enter the graph).
    /// This is the seed set for the dependency cone.
    pub fn touched(&self) -> Vec<u32> {
        let mut t = Vec::with_capacity(2 * (self.add.len() + self.remove.len()));
        for &(u, v, _) in &self.add {
            if u != v {
                t.push(u);
                t.push(v);
            }
        }
        for &(u, v) in &self.remove {
            if u != v {
                t.push(u);
                t.push(v);
            }
        }
        t.sort_unstable();
        t.dedup();
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_rejects_bad_weights_and_bounds() {
        let ok = UpdateBatch {
            add: vec![(0, 1, 0.5)],
            remove: vec![(2, 3)],
        };
        ok.validate(4).unwrap();
        for w in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -1.0] {
            let bad = UpdateBatch {
                add: vec![(0, 1, w)],
                remove: vec![],
            };
            assert!(
                matches!(bad.validate(4), Err(ParacError::BadInput(_))),
                "weight {w} must be rejected"
            );
        }
        let oob = UpdateBatch {
            add: vec![(0, 4, 1.0)],
            remove: vec![],
        };
        assert!(matches!(oob.validate(4), Err(ParacError::BadInput(_))));
        let oob = UpdateBatch {
            add: vec![],
            remove: vec![(4, 0)],
        };
        assert!(matches!(oob.validate(4), Err(ParacError::BadInput(_))));
    }

    #[test]
    fn touched_is_sorted_unique_and_skips_self_loops() {
        let b = UpdateBatch {
            add: vec![(5, 2, 1.0), (2, 5, 1.0), (7, 7, 1.0)],
            remove: vec![(0, 2)],
        };
        assert_eq!(b.touched(), vec![0, 2, 5]);
        assert!(UpdateBatch::new().is_empty());
        assert!(UpdateBatch::new().touched().is_empty());
    }
}
