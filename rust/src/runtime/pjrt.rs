//! HLO-text artifact loading and execution via a PJRT CPU client.
//!
//! Interchange format is HLO **text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! DESIGN.md). All artifacts are lowered with `return_tuple=True`, so
//! outputs unwrap as tuples.
//!
//! # Availability
//!
//! The real backend depends on the `xla` crate (PJRT CPU client
//! bindings), which is **not** part of the offline build. It is gated
//! behind the off-by-default `xla` cargo feature; enabling that feature
//! additionally requires adding the `xla` crate as a dependency. Without
//! it this module keeps the full API surface — [`Artifacts`],
//! [`LoadedExec`], [`Input`] — and reports unavailability through
//! `Result`s, so every caller (the CLI `info` command, the HLO sampler,
//! `bench_sample_kernel`, and the integration tests) degrades
//! gracefully instead of failing to build.

// The `xla` feature flags in the real PJRT client below, which needs the
// `xla` crate. That crate is not declared in Cargo.toml (it is not part
// of the offline build), so fail early with an actionable message
// instead of a cryptic `unresolved crate` error. To actually enable the
// backend: add the `xla` crate to [dependencies] and delete this guard.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` cargo feature additionally requires the `xla` crate (PJRT bindings): \
     add it to [dependencies] in Cargo.toml and remove this guard in rust/src/runtime/pjrt.rs"
);

#[cfg(feature = "xla")]
use anyhow::Context;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A typed input buffer for [`LoadedExec::run_mixed`].
pub enum Input<'a> {
    /// f32 tensor with shape.
    F32(&'a [f32], &'a [usize]),
    /// i32 tensor with shape.
    I32(&'a [i32], &'a [usize]),
}

/// A compiled executable plus its artifact name.
pub struct LoadedExec {
    /// Artifact stem (e.g. `sample_b64_k16`).
    pub name: String,
    #[cfg(feature = "xla")]
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedExec {
    /// Execute with f32 buffers; returns the flat f32 contents of each
    /// tuple element.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let wrapped: Vec<Input> = inputs.iter().map(|&(d, s)| Input::F32(d, s)).collect();
        self.run_mixed(&wrapped)
    }

    /// Execute with mixed f32/i32 inputs; returns each tuple element's
    /// flat contents as f32 (i32 outputs are converted).
    #[cfg(feature = "xla")]
    pub fn run_mixed(&self, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| {
                let (lit, shape): (xla::Literal, &[usize]) = match inp {
                    Input::F32(d, s) => (xla::Literal::vec1(d), s),
                    Input::I32(d, s) => (xla::Literal::vec1(d), s),
                };
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(|e| anyhow!("reshape {dims:?}: {e}"))
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        let mut out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e}", self.name))?;
        let elems = out.decompose_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        elems
            .into_iter()
            .map(|l| {
                // Outputs may be f32 or i32; surface both as f32 for the
                // caller (indices round-trip exactly below 2^24).
                match l.ty().map_err(|e| anyhow!("{e}"))? {
                    xla::ElementType::F32 => l.to_vec::<f32>().map_err(|e| anyhow!("{e}")),
                    xla::ElementType::S32 => Ok(l
                        .to_vec::<i32>()
                        .map_err(|e| anyhow!("{e}"))?
                        .into_iter()
                        .map(|v| v as f32)
                        .collect()),
                    other => Err(anyhow!("unsupported output type {other:?}")),
                }
            })
            .collect()
    }

    /// Execute with mixed f32/i32 inputs; returns each tuple element's
    /// flat contents as f32 (i32 outputs are converted).
    ///
    /// Built without the `xla` feature: always fails.
    #[cfg(not(feature = "xla"))]
    pub fn run_mixed(&self, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
        let _ = inputs;
        Err(anyhow!(
            "cannot execute `{}`: parac was built without the `xla` feature",
            self.name
        ))
    }
}

/// A directory of compiled artifacts, keyed by file stem.
pub struct Artifacts {
    #[cfg(feature = "xla")]
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, LoadedExec>,
}

impl Artifacts {
    /// Create a CPU PJRT client rooted at the artifact directory. Fails
    /// when the crate was built without the `xla` feature.
    #[cfg(feature = "xla")]
    pub fn open(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Artifacts { client, dir: dir.as_ref().to_path_buf(), cache: HashMap::new() })
    }

    /// Create a CPU PJRT client rooted at the artifact directory. Fails
    /// when the crate was built without the `xla` feature.
    #[cfg(not(feature = "xla"))]
    pub fn open(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let _ = dir;
        Err(anyhow!(
            "PJRT runtime unavailable: parac was built without the `xla` feature"
        ))
    }

    /// Default artifact directory: `$PARAC_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Artifacts> {
        let dir = std::env::var("PARAC_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    /// Platform string of the PJRT client.
    pub fn platform(&self) -> String {
        #[cfg(feature = "xla")]
        return self.client.platform_name();
        #[cfg(not(feature = "xla"))]
        return "unavailable (built without the `xla` feature)".to_string();
    }

    /// Artifact stems available on disk.
    pub fn available(&self) -> Vec<String> {
        scan_artifact_stems(&self.dir)
    }

    /// Load (compile + cache) an artifact by stem.
    pub fn load(&mut self, name: &str) -> Result<&LoadedExec> {
        if !self.cache.contains_key(name) {
            #[cfg(feature = "xla")]
            {
                let path = self.dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("bad path"))?,
                )
                .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {name}: {e}"))?;
                self.cache
                    .insert(name.to_string(), LoadedExec { name: name.to_string(), exe });
            }
            #[cfg(not(feature = "xla"))]
            {
                return Err(anyhow!(
                    "cannot load `{name}` from {:?}: parac was built without the `xla` feature",
                    self.dir
                ));
            }
        }
        Ok(&self.cache[name])
    }
}

/// List the `*.hlo.txt` stems in an artifact directory (shared between
/// the real and stubbed [`Artifacts::available`]).
fn scan_artifact_stems(dir: &Path) -> Vec<String> {
    let mut v = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            if let Some(name) = e.path().file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    v.push(stem.to_string());
                }
            }
        }
    }
    v.sort();
    v
}

#[cfg(test)]
mod tests {
    // The PJRT round-trip is exercised by the `hlo_pcg` example and the
    // `hlo_sampler_matches_native_reference` integration test (both
    // require `make artifacts` and the `xla` feature; they skip
    // gracefully otherwise). Unit scope here is limited to path logic.
    use super::*;

    #[test]
    fn available_lists_hlo_stems() {
        let dir = std::env::temp_dir().join("parac_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("foo.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("bar.json"), "{}").unwrap();
        std::fs::write(dir.join("notes.txt"), "not an artifact").unwrap();
        let names = scan_artifact_stems(&dir);
        assert!(names.contains(&"foo".to_string()));
        assert!(!names.iter().any(|n| n.contains("bar")));
        assert!(!names.iter().any(|n| n.contains("notes")), "plain .txt is not an artifact");
    }

    #[test]
    fn open_reports_feature_state() {
        // Without the `xla` feature, open() must fail with a clear
        // message rather than panic — callers rely on this to skip.
        if cfg!(not(feature = "xla")) {
            let err = Artifacts::open(std::env::temp_dir()).err().expect("stub must fail");
            assert!(err.to_string().contains("xla"), "unhelpful error: {err}");
        }
    }
}
