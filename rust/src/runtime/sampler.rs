//! Bucketed batch executor for the AOT-compiled Pallas clique-sampling
//! kernel (`sample_b{B}_k{K}` artifacts).
//!
//! PJRT executables have static shapes, so ready vertices are grouped
//! into padded buckets: a vertex with `m ≤ K` merged neighbors goes to
//! the width-`K` bucket, weights **front-padded** with zeros (keeping
//! the ascending sort valid). The kernel returns, per slot `i`, the
//! sampled partner index and new edge weight; this module scatters the
//! results back into `(u, v, w)` fill edges.
//!
//! The uniform draws are generated host-side from the same per-pivot
//! RNG stream as the native engines, so the offloaded samples are
//! bit-compatible in distribution (identical draws feed an identical
//! inverse-CDF; tiny f32-vs-f64 CDF rounding can pick a different
//! partner only when two cumulative weights collide at f32 precision).
//!
//! Without the `xla` cargo feature the [`Artifacts`] store never opens,
//! so [`HloSampler`] is unreachable in default builds; callers fall back
//! to [`native_reference`] / the native engines.

use super::pjrt::Artifacts;
use crate::factor::sample;
use anyhow::{anyhow, Result};

/// Supported bucket widths (must match `python/compile/aot.py`).
pub const BUCKET_WIDTHS: [usize; 3] = [16, 64, 256];
/// Batch size per kernel launch (must match aot.py).
pub const BATCH: usize = 64;

/// One vertex's sampling task: merged neighbors sorted ascending by
/// weight.
#[derive(Clone, Debug)]
pub struct SampleTask {
    /// Pivot vertex id (for RNG stream derivation).
    pub pivot: u32,
    /// Merged neighbors `(vertex, weight)` sorted ascending by weight.
    pub nbrs: Vec<(u32, f64)>,
}

/// A sampled fill edge.
#[derive(Clone, Debug, PartialEq)]
pub struct FillEdge {
    /// Smaller-position endpoint's vertex id.
    pub u: u32,
    /// Partner vertex id.
    pub v: u32,
    /// New edge weight.
    pub w: f64,
}

/// Batched sampler over the PJRT artifacts.
pub struct HloSampler<'a> {
    arts: &'a mut Artifacts,
    seed: u64,
}

impl<'a> HloSampler<'a> {
    /// Wrap an artifact store.
    pub fn new(arts: &'a mut Artifacts, seed: u64) -> Self {
        HloSampler { arts, seed }
    }

    /// Pick the smallest bucket width ≥ `m` (None: too wide, caller
    /// falls back to the native path).
    pub fn bucket_for(m: usize) -> Option<usize> {
        BUCKET_WIDTHS.iter().copied().find(|&k| m <= k)
    }

    /// Run one bucket batch: all tasks must fit width `k`. Emits fill
    /// edges for every task. Tasks beyond [`BATCH`] are chunked.
    pub fn run_bucket(&mut self, k: usize, tasks: &[SampleTask]) -> Result<Vec<FillEdge>> {
        if !BUCKET_WIDTHS.contains(&k) {
            return Err(anyhow!("unknown bucket width {k}"));
        }
        let name = format!("sample_b{BATCH}_k{k}");
        let mut out = Vec::new();
        for chunk in tasks.chunks(BATCH) {
            // Front-padded weights + host-generated uniforms.
            let mut w = vec![0f32; BATCH * k];
            let mut u = vec![0f32; BATCH * k];
            for (b, t) in chunk.iter().enumerate() {
                let m = t.nbrs.len();
                assert!(m <= k, "task too wide for bucket");
                let off = k - m;
                for (i, &(_, wt)) in t.nbrs.iter().enumerate() {
                    w[b * k + off + i] = wt as f32;
                }
                let mut rng = sample::pivot_rng(self.seed, t.pivot);
                for i in 0..m.saturating_sub(1) {
                    u[b * k + off + i] = rng.next_f64() as f32;
                }
            }
            let exe = self.arts.load(&name)?;
            let res = exe.run_f32(&[(&w, &[BATCH, k]), (&u, &[BATCH, k])])?;
            let (j_idx, w_new) = (&res[0], &res[1]);
            for (b, t) in chunk.iter().enumerate() {
                let m = t.nbrs.len();
                let off = k - m;
                for i in 0..m.saturating_sub(1) {
                    let j = j_idx[b * k + off + i] as i64 as usize;
                    let wn = w_new[b * k + off + i] as f64;
                    if j < k && wn > 0.0 {
                        let jj = j - off;
                        out.push(FillEdge { u: t.nbrs[i].0, v: t.nbrs[jj].0, w: wn });
                    }
                }
            }
        }
        Ok(out)
    }
}

/// Pure-rust reference of the batched kernel semantics (used by tests
/// and by the `bench_sample_kernel` comparison): identical to
/// [`sample::sample_clique`] driven by the same RNG stream.
pub fn native_reference(seed: u64, task: &SampleTask) -> Vec<FillEdge> {
    let mut rng = sample::pivot_rng(seed, task.pivot);
    let mut cum = Vec::new();
    let mut out = Vec::new();
    sample::sample_clique(&task.nbrs, &mut cum, &mut rng, |a, b, w| {
        out.push(FillEdge { u: a, v: b, w });
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        assert_eq!(HloSampler::bucket_for(3), Some(16));
        assert_eq!(HloSampler::bucket_for(16), Some(16));
        assert_eq!(HloSampler::bucket_for(17), Some(64));
        assert_eq!(HloSampler::bucket_for(300), None);
    }

    #[test]
    fn native_reference_emits_m_minus_one() {
        let t = SampleTask {
            pivot: 5,
            nbrs: vec![(1, 0.5), (2, 1.0), (3, 2.0)],
        };
        let edges = native_reference(42, &t);
        assert_eq!(edges.len(), 2);
        assert!(edges.iter().all(|e| e.w > 0.0));
    }
}
