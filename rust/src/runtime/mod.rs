//! PJRT runtime — loads the HLO-text artifacts produced by the python
//! compile path (`python/compile/aot.py`) and executes them on the CPU
//! PJRT client. Python never runs at solve time; the rust binary is
//! self-contained once `make artifacts` has produced `artifacts/`.
//!
//! The PJRT backend needs the `xla` crate and is gated behind the
//! off-by-default `xla` cargo feature (see [`pjrt`] for details). The
//! default build keeps the whole API and fails soft at runtime, so the
//! rest of the crate — including [`sampler`], the CLI, and the benches —
//! builds and runs without it.

pub mod pjrt;
pub mod sampler;

pub use pjrt::{Artifacts, LoadedExec};
