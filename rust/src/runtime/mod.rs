//! PJRT runtime — loads the HLO-text artifacts produced by the python
//! compile path (`python/compile/aot.py`) and executes them on the CPU
//! PJRT client. Python never runs at solve time; the rust binary is
//! self-contained once `make artifacts` has produced `artifacts/`.

pub mod pjrt;
pub mod sampler;

pub use pjrt::{Artifacts, LoadedExec};
