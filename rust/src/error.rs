//! The crate-wide typed error: everything a `parac` entry point can
//! fail with, in one enum.
//!
//! Design rules (the `Solver` session-API contract):
//!
//! * **Bad input is an error, not a panic.** Every failure reachable
//!   from the public [`crate::solver::Solver`] / pipeline surface comes
//!   back as a [`ParacError`]; panics are reserved for internal
//!   invariant violations (engine bugs), never for caller mistakes.
//! * **Non-convergence is data, not an error.** PCG exhausting its
//!   iteration budget is a legitimate outcome the caller inspects via
//!   `converged` / `rel_residual` on the solve result — it does *not*
//!   produce an `Err`.
//! * **Library code propagates, binaries decide.** `coordinator` and
//!   `solver` return `Result`; only `main.rs` and the bench/example
//!   binaries are allowed to `?`-and-exit (or unwrap).
//!
//! [`ParacError`] absorbs the former `factor::FactorError` (which is
//! now a deprecated alias) so factorization, preconditioner setup, and
//! solving share one error channel.

/// Everything that can go wrong inside the `parac` library surface.
#[derive(Debug, Clone, PartialEq)]
pub enum ParacError {
    /// The shared fill arena filled up (estimate too small). `factorize`
    /// retries internally with a doubled arena; this escapes only after
    /// repeated doubling hit the hard ceiling.
    ArenaFull {
        /// Node capacity of the arena that overflowed.
        capacity: usize,
    },
    /// The workspace hash map of the gpusim engine overflowed.
    WorkspaceFull {
        /// Slot capacity of the workspace that overflowed.
        capacity: usize,
    },
    /// Input is not a valid operator for the requested action (empty or
    /// non-square matrix, non-Laplacian structure, unrecoverable
    /// incomplete-factorization breakdown, …).
    BadInput(String),
    /// A vector argument's length does not match the solver dimension.
    DimensionMismatch {
        /// Which argument mismatched (`"rhs"`, `"solution"`, …).
        what: &'static str,
        /// The solver/operator dimension.
        expected: usize,
        /// The length actually supplied.
        got: usize,
    },
    /// A configuration knob was given an unparseable / out-of-range
    /// value (engine name, ordering name, SSOR relaxation factor, …).
    InvalidOption {
        /// Which knob was rejected.
        what: &'static str,
        /// The offending value, rendered for the message.
        got: String,
    },
    /// A serving request was shed at admission because the wave gate's
    /// queue already held `capacity` pending right-hand sides
    /// (`ServeOptions::max_queue`). Back-pressure, not failure: the
    /// caller should retry after a backoff. Counted in
    /// `ServiceStats::shed`.
    Overloaded {
        /// The configured queue bound that was hit.
        capacity: usize,
    },
    /// The request's deadline (`ServeOptions::deadline` or an explicit
    /// per-request budget) lapsed before a solution converged — either
    /// while queued (shed without solving) or mid-PCG (the iteration
    /// loop checks the deadline every few iterations and abandons the
    /// solve). Like [`ParacError::Overloaded`] this is load, not
    /// corruption: the request is safe to retry. Counted in
    /// `ServiceStats::deadline_shed`.
    DeadlineExceeded,
    /// An internal invariant broke while serving this request: a solve
    /// wave or factor build panicked (caught at the serve leader
    /// boundary), or a factorization produced non-finite values. The
    /// offending cached session is quarantined and rebuilt; *this*
    /// request failed, but the next one gets a fresh session.
    Internal(String),
}

impl ParacError {
    /// Whether the failure is transient load shedding that a client
    /// should simply retry (after backoff): [`ParacError::Overloaded`]
    /// and [`ParacError::DeadlineExceeded`]. Everything else reports a
    /// property of the input or the system that retrying the identical
    /// request will not fix.
    pub fn is_retryable(&self) -> bool {
        matches!(self, ParacError::Overloaded { .. } | ParacError::DeadlineExceeded)
    }
}

impl std::fmt::Display for ParacError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParacError::ArenaFull { capacity } => {
                write!(f, "fill arena full ({capacity} nodes)")
            }
            ParacError::WorkspaceFull { capacity } => {
                write!(f, "gpusim workspace full ({capacity} slots)")
            }
            ParacError::BadInput(m) => write!(f, "bad input: {m}"),
            ParacError::DimensionMismatch { what, expected, got } => {
                write!(f, "{what} has length {got}, expected {expected}")
            }
            ParacError::InvalidOption { what, got } => {
                write!(f, "invalid {what}: {got:?}")
            }
            ParacError::Overloaded { capacity } => {
                write!(f, "service overloaded: {capacity} requests already queued")
            }
            ParacError::DeadlineExceeded => {
                write!(f, "deadline exceeded before the solve completed")
            }
            ParacError::Internal(m) => write!(f, "internal failure: {m}"),
        }
    }
}

impl std::error::Error for ParacError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_name_the_failure() {
        assert!(ParacError::ArenaFull { capacity: 7 }.to_string().contains("7"));
        assert!(ParacError::BadInput("empty matrix".into()).to_string().contains("empty"));
        let e = ParacError::DimensionMismatch { what: "rhs", expected: 10, got: 3 };
        assert!(e.to_string().contains("rhs") && e.to_string().contains("10"));
        let e = ParacError::InvalidOption { what: "engine", got: "tpu".into() };
        assert!(e.to_string().contains("engine") && e.to_string().contains("tpu"));
        let e = ParacError::Overloaded { capacity: 64 };
        assert!(e.to_string().contains("overloaded") && e.to_string().contains("64"));
        assert!(ParacError::DeadlineExceeded.to_string().contains("deadline"));
        let e = ParacError::Internal("solve wave panicked".into());
        assert!(e.to_string().contains("internal") && e.to_string().contains("panicked"));
    }

    #[test]
    fn retryable_covers_exactly_the_load_errors() {
        assert!(ParacError::Overloaded { capacity: 1 }.is_retryable());
        assert!(ParacError::DeadlineExceeded.is_retryable());
        assert!(!ParacError::ArenaFull { capacity: 1 }.is_retryable());
        assert!(!ParacError::WorkspaceFull { capacity: 1 }.is_retryable());
        assert!(!ParacError::BadInput("x".into()).is_retryable());
        assert!(!ParacError::Internal("x".into()).is_retryable());
        assert!(!ParacError::InvalidOption { what: "engine", got: "tpu".into() }.is_retryable());
        assert!(
            !ParacError::DimensionMismatch { what: "rhs", expected: 1, got: 2 }.is_retryable()
        );
    }

    #[test]
    fn is_std_error() {
        fn takes_err(_: &dyn std::error::Error) {}
        takes_err(&ParacError::WorkspaceFull { capacity: 1 });
    }
}
