//! `parac` CLI — factor, solve, and reproduce the paper's experiments.
//!
//! Library calls return typed [`ParacError`]s; this binary is the layer
//! that prints them and exits.

use parac::cli::args::Args;
use parac::coordinator::pipeline::{self, Method};
use parac::coordinator::report::{sci, secs, Table};
use parac::error::ParacError;
use parac::factor::{Engine, ParacOptions};
use parac::graph::suite::{self, Scale};
use parac::ordering::Ordering;
use parac::solve::pcg::PcgOptions;
use parac::solver::PrecondKind;
use parac::util::fmt_count;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let out = match cmd {
        "info" => {
            info(&args);
            Ok(())
        }
        "factor" => factor_cmd(&args),
        "solve" => solve_cmd(&args),
        "suite" => {
            suite_cmd(&args);
            Ok(())
        }
        "repro" => repro_cmd(&args),
        "serve" => serve_cmd(&args),
        "dynamic" => dynamic_cmd(&args),
        _ => {
            help();
            Ok(())
        }
    };
    if let Err(e) = out {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn help() {
    println!(
        "parac — parallel randomized approximate Cholesky preconditioners

USAGE:
  parac info                               PJRT platform + artifact inventory
  parac suite [--scale tiny|small|medium]  list the benchmark suite
  parac factor --matrix NAME [--engine seq|cpu[:T]|gpusim[:B]]
               [--ordering amd|nnz|random|natural|rcm] [--seed S]
               (\"gpu\" is an accepted alias for gpusim: gpu, gpu:8)
  parac solve  --matrix NAME
               [--method parac[:T]|ichol0|icholt[:DROPTOL]|amg|jacobi|ssor[:OMEGA]|identity]
               [--tol 1e-8] [--max-iter 1000] [--level-threads T] [--omega 1.5]
               [--droptol 1e-3] [--precision f64|f32] [engine/ordering flags]
               (--precision f32 stores the ParAC factor sweeps in f32 —
               half the apply traffic — with automatic f64 fallback;
               PARAC_PRECISION sets the default)
  parac repro table2|table3|fig3|fig4|hash [--scale tiny|small|medium] [--threads T]
  parac serve  --matrix NAME [--clients N[,N...]] [--requests R] [--interval-us U]
               [--max-wave W] [--max-wait-us U] [--max-queue Q] [--cache-cap C]
               [--deadline-us D] [--retries K]
               [--threads T] [--precision f64|f32] [--json PATH]
               [engine/ordering flags]
               (--max-queue bounds admission: requests beyond Q pending
               are shed with a typed overload error; 0 = unbounded.
               --deadline-us stamps each request with a wall-clock
               budget — lapsed requests are shed typed; 0 = off.
               --retries bounds client retry-with-backoff on retryable
               errors)
               open-loop serving benchmark: N client threads share one
               cached factor through coalesced solve waves
  parac dynamic --matrix NAME [--scenario churn|spectral|resist|all]
               [--rounds R] [--threshold F] [--cache-cap C] [--seed S]
               [--no-baseline] [--threads T] [--tol 1e-8] [--max-iter N]
               [--json PATH] [engine/ordering flags]
               dynamic-graph update streams: each round's batch is
               classified weight-only / cone-localized / rebuild.
               --threshold caps the dependency-cone fraction of n before
               a structural update escalates to a full rebuild;
               --no-baseline skips the per-round from-scratch build
               timed as the latency yardstick
"
    );
}

fn scale(args: &Args) -> Scale {
    Scale::parse(args.get("scale", "small")).unwrap_or(Scale::Small)
}

fn build_matrix(args: &Args) -> Result<parac::graph::Laplacian, ParacError> {
    let name = args.get("matrix", "uniform_3d_poisson");
    match suite::by_name(name) {
        Some(e) => Ok((e.build)(scale(args))),
        None => Err(ParacError::BadInput(format!(
            "unknown matrix {name}; use `parac suite` to list"
        ))),
    }
}

fn parac_opts(args: &Args) -> Result<ParacOptions, ParacError> {
    let ordering = args.get("ordering", "nnz");
    let engine = args.get("engine", "cpu");
    Ok(ParacOptions {
        ordering: Ordering::parse(ordering).ok_or_else(|| ParacError::InvalidOption {
            what: "ordering",
            got: ordering.into(),
        })?,
        engine: Engine::parse(engine).ok_or_else(|| ParacError::InvalidOption {
            what: "engine",
            got: engine.into(),
        })?,
        seed: args.get_parse("seed", 0x9A9Au64),
        precision: match args.get("precision", "") {
            "" => None, // defer to PARAC_PRECISION, then f64
            s => Some(parac::sparse::Precision::parse(s)?),
        },
        ..Default::default()
    })
}

fn info(_args: &Args) {
    match parac::runtime::Artifacts::open_default() {
        Ok(arts) => {
            println!("PJRT platform: {}", arts.platform());
            println!("artifacts: {:?}", arts.available());
        }
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    println!("default threads: {}", parac::util::default_threads());
}

fn suite_cmd(args: &Args) {
    let sc = scale(args);
    let mut t = Table::new(&["matrix", "class", "columns", "nonzeros"]);
    for e in suite::SUITE {
        let l = (e.build)(sc);
        t.row(vec![
            e.name.into(),
            e.class.into(),
            fmt_count(l.n()),
            fmt_count(l.matrix.nnz()),
        ]);
    }
    print!("{}", t.render());
}

fn factor_cmd(args: &Args) -> Result<(), ParacError> {
    let lap = build_matrix(args)?;
    let opts = parac_opts(args)?;
    let (f, dt) = {
        let timer = parac::util::Timer::start();
        let f = parac::factor::factorize(&lap, &opts)?;
        (f, timer.secs())
    };
    println!(
        "{}: n={} nnz={} engine={} ordering={}",
        lap.name,
        fmt_count(lap.n()),
        fmt_count(lap.matrix.nnz()),
        opts.engine.name(),
        opts.ordering.name()
    );
    println!(
        "factor: {:.3}s  nnz(G)={}  fill-ratio={:.2}  {}",
        dt,
        fmt_count(f.nnz()),
        f.fill_ratio(lap.matrix.nnz()),
        f.stats.summary()
    );
    let rep = parac::etree::report(&lap.matrix, &f.g);
    println!(
        "etree: classical={} actual={} critical-path={}",
        rep.classical_height, rep.actual_height, rep.critical_path
    );
    Ok(())
}

fn solve_cmd(args: &Args) -> Result<(), ParacError> {
    let lap = build_matrix(args)?;
    let pcg_opts = PcgOptions {
        tol: args.get_parse("tol", 1e-8f64),
        max_iter: args.get_parse("max-iter", 1000usize),
        ..Default::default()
    };
    // `--method` accepts the same parameterized spellings as
    // `PrecondKind::parse` (`parac:8`, `icholt:1e-4`, `ssor:1.2`);
    // explicit flags (`--level-threads`, `--droptol`, `--omega`) win
    // over the inline parameter when both are given.
    let method = match PrecondKind::parse(args.get("method", "parac"))? {
        PrecondKind::Parac { level_threads } => Method::Parac {
            opts: parac_opts(args)?,
            level_threads: args.get_parse("level-threads", level_threads),
        },
        PrecondKind::Ichol0 => Method::Ichol0,
        PrecondKind::IcholT { droptol, fill_target } => Method::IcholT {
            droptol: Some(args.get_parse("droptol", droptol.unwrap_or(1e-3))),
            fill_target,
        },
        PrecondKind::Amg => Method::Amg,
        PrecondKind::Jacobi => Method::Jacobi,
        PrecondKind::Ssor { omega } => Method::Ssor { omega: args.get_parse("omega", omega) },
        PrecondKind::Identity => Method::Identity,
    };
    let r = pipeline::run(&lap, &method, &pcg_opts, args.get_parse("rhs-seed", 7u64))?;
    let mut t = Table::new(&["method", "setup (s)", "solve (s)", "iters", "rel residual"]);
    t.row(vec![
        r.method.into(),
        secs(r.setup_secs),
        secs(r.solve_secs),
        r.iters.to_string(),
        sci(r.rel_residual),
    ]);
    print!("{}", t.render());
    if !r.converged {
        println!("(did not converge)");
    }
    Ok(())
}

fn serve_cmd(args: &Args) -> Result<(), ParacError> {
    use parac::coordinator::serve_driver::{run_open_loop, LoadSpec};
    use parac::serve::{FactorCache, ServeOptions, SolveService};
    use std::sync::Arc;
    use std::time::Duration;

    let lap = Arc::new(build_matrix(args)?);
    let clients: Vec<usize> = args
        .get("clients", "1,8")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .filter(|&c| c > 0)
        .collect();
    if clients.is_empty() {
        return Err(ParacError::BadInput("--clients needs at least one count".into()));
    }
    let builder = parac::solver::Solver::builder()
        .parac_options(parac_opts(args)?)
        .threads(args.get_parse("threads", 0usize))
        .tol(args.get_parse("tol", 1e-8f64))
        .max_iter(args.get_parse("max-iter", 1000usize));
    let deadline_us = args.get_parse("deadline-us", 0u64);
    let opts = ServeOptions {
        max_wave: args.get_parse("max-wave", ServeOptions::default().max_wave),
        max_wait: Duration::from_micros(args.get_parse("max-wait-us", 200u64)),
        max_queue: args.get_parse("max-queue", ServeOptions::default().max_queue),
        deadline: (deadline_us > 0).then(|| Duration::from_micros(deadline_us)),
    };
    println!(
        "{}: n={} nnz={}  max_wave={} max_wait={:?} max_queue={} deadline={}",
        lap.name,
        fmt_count(lap.n()),
        fmt_count(lap.matrix.nnz()),
        opts.max_wave,
        opts.max_wait,
        opts.max_queue,
        match opts.deadline {
            Some(d) => format!("{d:?}"),
            None => "off".into(),
        }
    );
    let mut t = Table::new(&[
        "clients",
        "solves",
        "solves/s",
        "p50 (ms)",
        "p99 (ms)",
        "waves",
        "coalesced",
    ]);
    let mut rows = Vec::new();
    for &c in &clients {
        // A fresh service per client count: each row measures a cold
        // cache warmed by exactly one untimed build.
        let cache = FactorCache::new(builder.clone(), args.get_parse("cache-cap", 4usize));
        let svc = SolveService::new(cache, opts);
        let spec = LoadSpec {
            clients: c,
            requests_per_client: args.get_parse("requests", 32usize),
            interval: Duration::from_micros(args.get_parse("interval-us", 500u64)),
            seed: args.get_parse("rhs-seed", 7u64),
            max_retries: args.get_parse("retries", LoadSpec::default().max_retries),
        };
        let rep = run_open_loop(&svc, &lap, &spec)?;
        t.row(vec![
            c.to_string(),
            rep.solves.to_string(),
            format!("{:.1}", rep.throughput),
            format!("{:.3}", rep.p50_ms),
            format!("{:.3}", rep.p99_ms),
            rep.service.waves.to_string(),
            rep.service.coalesced.to_string(),
        ]);
        rows.push(pipeline::BenchRow {
            name: format!("{} clients={c}", lap.name),
            fields: rep.fields(),
        });
    }
    print!("{}", t.render());
    let json = args.get("json", "");
    if !json.is_empty() {
        let path = std::path::Path::new(json);
        pipeline::write_bench_rows_json(path, "serve", &rows)
            .map_err(|e| ParacError::BadInput(format!("writing {json}: {e}")))?;
        println!("wrote {json}");
    }
    Ok(())
}

fn dynamic_cmd(args: &Args) -> Result<(), ParacError> {
    use parac::dynamic::scenario::{self, ScenarioOptions};
    use parac::dynamic::DynamicOptions;

    let lap = build_matrix(args)?;
    let builder = parac::solver::Solver::builder()
        .parac_options(parac_opts(args)?)
        .threads(args.get_parse("threads", 0usize))
        .tol(args.get_parse("tol", 1e-8f64))
        .max_iter(args.get_parse("max-iter", 1000usize));
    let sopts = ScenarioOptions {
        rounds: args.get_parse("rounds", 8usize),
        seed: args.get_parse("seed", 0xD11Au64),
        measure_full_rebuild: !args.flag("no-baseline"),
        dynamic: DynamicOptions {
            damage_threshold: args.get_parse("threshold", 0.25f64),
            cache_capacity: args.get_parse("cache-cap", 4usize),
            ..Default::default()
        },
    };
    let which = args.get("scenario", "all");
    let names: Vec<&str> = if which == "all" {
        scenario::SCENARIOS.to_vec()
    } else {
        vec![which]
    };
    println!(
        "{}: n={} nnz={}  rounds={} threshold={} baseline={}",
        lap.name,
        fmt_count(lap.n()),
        fmt_count(lap.matrix.nnz()),
        sopts.rounds,
        sopts.dynamic.damage_threshold,
        if sopts.measure_full_rebuild { "on" } else { "off" },
    );
    let ms = |s: f64| {
        if s > 0.0 {
            format!("{:.3}", s * 1e3)
        } else {
            "-".into()
        }
    };
    let mut t = Table::new(&[
        "scenario",
        "weight-only",
        "localized",
        "rebuild",
        "wo (ms)",
        "loc (ms)",
        "rb (ms)",
        "full rb (ms)",
        "iters",
    ]);
    let mut rows = Vec::new();
    for name in names {
        let rep = scenario::run(name, &lap, builder.clone(), &sopts)?;
        t.row(vec![
            rep.name.into(),
            rep.counts.weight_only.to_string(),
            rep.counts.localized.to_string(),
            rep.counts.rebuild.to_string(),
            ms(rep.weight_only_secs),
            ms(rep.localized_secs),
            ms(rep.rebuild_secs),
            ms(rep.full_rebuild_secs),
            format!("{:.1}", rep.mean_iters),
        ]);
        rows.push(pipeline::BenchRow {
            name: format!("{} {}", lap.name, rep.name),
            fields: rep.fields(),
        });
    }
    print!("{}", t.render());
    let json = args.get("json", "");
    if !json.is_empty() {
        let path = std::path::Path::new(json);
        pipeline::write_bench_rows_json(path, "dynamic", &rows)
            .map_err(|e| ParacError::BadInput(format!("writing {json}: {e}")))?;
        println!("wrote {json}");
    }
    Ok(())
}

fn repro_cmd(args: &Args) -> Result<(), ParacError> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    let sc = scale(args);
    let threads = args.get_parse("threads", 0usize);
    match which {
        "table2" => parac::coordinator::repro::table2(sc, threads),
        "table3" => parac::coordinator::repro::table3(sc, threads),
        "fig3" => parac::coordinator::repro::fig3(sc, threads),
        "fig4" => parac::coordinator::repro::fig4(sc, threads),
        "hash" => parac::coordinator::repro::hash_ablation(sc, threads),
        other => Err(ParacError::InvalidOption {
            what: "repro target (table2|table3|fig3|fig4|hash)",
            got: other.into(),
        }),
    }
}
