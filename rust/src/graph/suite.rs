//! The named benchmark suite — scaled analogues of the paper's Table 1.
//!
//! Every experiment driver (Tables 2–3, Figures 3–4) iterates this suite
//! so rows line up with the paper's. `Scale` trades fidelity for runtime;
//! `Medium` is the default for benches, `Tiny` for unit tests.

use super::generators::{self, Coeff};
use super::laplacian::Laplacian;

/// Problem size multiplier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// ~1–3k vertices — unit tests.
    Tiny,
    /// ~10–30k vertices — integration tests / quick repro.
    Small,
    /// ~60–260k vertices — the bench default.
    Medium,
}

impl Scale {
    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "tiny" => Some(Scale::Tiny),
            "small" => Some(Scale::Small),
            "medium" => Some(Scale::Medium),
            _ => None,
        }
    }
}

/// One suite entry: the paper's matrix it stands in for, and a generator.
pub struct SuiteEntry {
    /// Identifier used in reports (matches the paper's matrix name).
    pub name: &'static str,
    /// What class of problem this is (mesh / road / social / …).
    pub class: &'static str,
    /// Build the scaled instance.
    pub build: fn(Scale) -> Laplacian,
}

fn dims(scale: Scale, tiny: usize, small: usize, medium: usize) -> usize {
    match scale {
        Scale::Tiny => tiny,
        Scale::Small => small,
        Scale::Medium => medium,
    }
}

/// The full suite, in the paper's Table 1 order.
pub const SUITE: &[SuiteEntry] = &[
    SuiteEntry {
        name: "parabolic_fem",
        class: "2D mesh",
        build: |s| {
            let d = dims(s, 40, 130, 360);
            generators::grid2d(d, d, Coeff::Uniform, 101)
        },
    },
    SuiteEntry {
        name: "ecology1",
        class: "2D mesh",
        build: |s| {
            let d = dims(s, 45, 160, 420);
            generators::grid2d(d, d, Coeff::Uniform, 102)
        },
    },
    SuiteEntry {
        name: "apache2",
        class: "3D mesh",
        build: |s| {
            let d = dims(s, 12, 28, 62);
            generators::grid3d(d, d, d, Coeff::Uniform, 103)
        },
    },
    SuiteEntry {
        name: "G3_circuit",
        class: "circuit",
        build: |s| {
            let d = dims(s, 45, 170, 450);
            generators::grid2d(d, d, Coeff::HighContrast(3.0), 104)
        },
    },
    SuiteEntry {
        name: "GAP-road",
        class: "road",
        build: |s| {
            let d = dims(s, 50, 180, 510);
            generators::road_like(d, d, 0.15, 105)
        },
    },
    SuiteEntry {
        name: "com-LiveJournal",
        class: "social",
        build: |s| {
            let n = dims(s, 1200, 9000, 36000);
            generators::pref_attach(n, 8, 106)
        },
    },
    SuiteEntry {
        name: "delaunay_n24",
        class: "triangulation",
        build: |s| {
            let d = dims(s, 40, 150, 400);
            generators::delaunay_like(d, d, 107)
        },
    },
    SuiteEntry {
        name: "venturiLevel3",
        class: "2D mesh",
        build: |s| {
            let d = dims(s, 40, 140, 380);
            generators::grid2d(d, d, Coeff::Anisotropic(1.0, 4.0, 1.0), 108)
        },
    },
    SuiteEntry {
        name: "europe_osm",
        class: "road",
        build: |s| {
            let d = dims(s, 55, 190, 520);
            generators::road_like(d, d, 0.08, 109)
        },
    },
    SuiteEntry {
        name: "belgium_osm",
        class: "road",
        build: |s| {
            let d = dims(s, 35, 110, 300);
            generators::road_like(d, d, 0.10, 110)
        },
    },
    SuiteEntry {
        name: "uniform_3d_poisson",
        class: "3D poisson",
        build: |s| {
            let d = dims(s, 12, 30, 64);
            generators::grid3d(d, d, d, Coeff::Uniform, 111)
        },
    },
    SuiteEntry {
        name: "aniso_3d_poisson",
        class: "3D poisson",
        build: |s| {
            let d = dims(s, 12, 30, 64);
            generators::grid3d(d, d, d, Coeff::Anisotropic(1.0, 1.0, 25.0), 112)
        },
    },
    SuiteEntry {
        name: "contrast_3d_poisson",
        class: "3D poisson",
        build: |s| {
            let d = dims(s, 12, 30, 64);
            generators::grid3d(d, d, d, Coeff::HighContrast(4.0), 113)
        },
    },
    SuiteEntry {
        name: "com-Orkut",
        class: "social",
        build: |s| {
            // Denser power-law tail than com-LiveJournal (higher m →
            // fatter hubs) — the serving benchmark's cache-miss case.
            let n = dims(s, 900, 7000, 28000);
            generators::pref_attach(n, 16, 115)
        },
    },
    SuiteEntry {
        name: "rand_expander",
        class: "expander",
        build: |s| {
            // Union of 3 random Hamiltonian cycles: constant degree,
            // no locality, logarithmic diameter — the adversarial case
            // for fill-reducing orderings (and connected by
            // construction, see [`generators::expander`]).
            let n = dims(s, 1500, 12000, 48000);
            generators::expander(n, 3, 116)
        },
    },
    SuiteEntry {
        name: "xcontrast_2d",
        class: "2D mesh",
        build: |s| {
            let d = dims(s, 40, 130, 360);
            let base = generators::grid2d(d, d, Coeff::Uniform, 117);
            // Two-scale medium at an extreme absolute level: the left
            // half of the grid carries weights ~1e39, the right half
            // ~1e27 — a 1e12 weight ratio. f64 factors it exactly like
            // the unit-scale grid (conditioning is scale-invariant),
            // but an f32 value-storage plane overflows on the heavy
            // half (f32::MAX ≈ 3.4e38), which makes this the
            // deterministic trigger for the f32→f64 refinement guard
            // in `solve::pcg`.
            let edges: Vec<(u32, u32, f64)> = base
                .edges()
                .into_iter()
                .map(|(a, b, w)| {
                    let col = a as usize % d;
                    let scale = if col * 2 < d { 1e39 } else { 1e27 };
                    (a, b, w * scale)
                })
                .collect();
            Laplacian::from_edges(base.n(), &edges, "xcontrast_2d")
        },
    },
    SuiteEntry {
        name: "spe16m",
        class: "reservoir",
        build: |s| {
            let d = dims(s, 12, 30, 60);
            // SPE10-like: strong vertical anisotropy + extreme contrast is
            // approximated by layering contrast over anisotropy: generate
            // contrast field, then scale z-edges down.
            generators::grid3d(d, d, d / 2 + 1, Coeff::HighContrast(5.0), 114)
        },
    },
    SuiteEntry {
        name: "clique_ladder",
        class: "high-diameter",
        build: |s| {
            // Path-of-cliques caterpillar: the suite's high-diameter
            // adversary (ROADMAP item 5) — diameter ~ clique count, so
            // level-scheduled sweeps face maximal dependency chains
            // while each clique stresses the sampler locally.
            let cliques = dims(s, 140, 1100, 4500);
            generators::clique_path(cliques, 4, 118)
        },
    },
];

/// Look up a suite entry by name.
pub fn by_name(name: &str) -> Option<&'static SuiteEntry> {
    SUITE.iter().find(|e| e.name == name)
}

/// Names of all suite entries.
pub fn names() -> Vec<&'static str> {
    SUITE.iter().map(|e| e.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_entries_build_tiny_and_validate() {
        for e in SUITE {
            let l = (e.build)(Scale::Tiny);
            l.validate().unwrap_or_else(|err| panic!("{}: {err}", e.name));
            assert!(l.n() > 500, "{} too small: {}", e.name, l.n());
            let (_, ncomp) = l.components();
            assert_eq!(ncomp, 1, "{} must be connected", e.name);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("GAP-road").is_some());
        assert!(by_name("nonexistent").is_none());
        assert_eq!(names().len(), SUITE.len());
    }

    #[test]
    fn scales_are_monotone() {
        let e = by_name("apache2").unwrap();
        let t = (e.build)(Scale::Tiny).n();
        let s = (e.build)(Scale::Small).n();
        assert!(t < s);
    }
}
