//! Graph / Laplacian substrate: Laplacian construction and validation,
//! SDD→Laplacian grounding, synthetic workload generators mirroring the
//! paper's matrix suite (Table 1), and the named benchmark suite.

pub mod doubling;
pub mod generators;
pub mod laplacian;
pub mod suite;

pub use laplacian::{Laplacian, LapKind};
