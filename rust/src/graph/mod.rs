//! Graph / Laplacian substrate: Laplacian construction and validation,
//! SDD→Laplacian grounding, synthetic workload generators mirroring the
//! paper's matrix suite (Table 1), and the named benchmark suite.
//!
//! * [`laplacian`] — the [`Laplacian`] operator type ([`LapKind::Graph`]
//!   singular vs [`LapKind::Grounded`] SPD), edge-list construction,
//!   invariant validation, and the rchol ground-vertex extension for SPD
//!   SDD M-matrices.
//! * [`doubling`] — Gremban's bipartite double cover, reducing SDD
//!   matrices with positive off-diagonals to Laplacians.
//! * [`generators`] — scaled synthetic analogues of each matrix class
//!   the paper evaluates (meshes, roads, social networks, Poisson
//!   variants) plus stress-test graphs (path, star, complete, trees).
//! * [`suite`] — the named benchmark suite in Table 1 order, used by
//!   every repro driver so report rows line up with the paper's.

pub mod doubling;
pub mod generators;
pub mod laplacian;
pub mod suite;

pub use laplacian::{Fingerprint, Laplacian, LapKind};
