//! Synthetic workload generators.
//!
//! The paper evaluates on SuiteSparse matrices, SPE reservoir benchmarks,
//! OSM road networks, and Laplacians.jl 3D Poisson problems (Table 1).
//! None of those datasets ship with this environment, so each *class* is
//! reproduced by a generator that matches the structural properties ParAC
//! is sensitive to — degree distribution, locality, conditioning —
//! per the substitution policy in DESIGN.md:
//!
//! | paper matrix              | generator here                           |
//! |---------------------------|------------------------------------------|
//! | parabolic_fem / ecology*  | [`grid2d`] (5-point mesh)                |
//! | apache2 / venturiLevel3   | [`grid3d`] (7-point mesh)                |
//! | G3_circuit                | [`grid2d`] + high-contrast weights       |
//! | GAP-road / *_osm          | [`road_like`] (tree + sparse shortcuts)  |
//! | com-LiveJournal           | [`pref_attach`] (heavy-tail social net)  |
//! | delaunay_n24              | [`delaunay_like`] (triangulated grid)    |
//! | 3D poisson variants       | [`grid3d`] with [`Coeff`] variants       |
//! | spe16m                    | [`grid3d`] aniso + extreme contrast      |

use super::laplacian::Laplacian;
use crate::rng::Rng;

/// Coefficient field for mesh generators — selects the paper's uniform /
/// anisotropic / high-contrast Poisson variants.
#[derive(Clone, Copy, Debug)]
pub enum Coeff {
    /// Unit weight on every edge.
    Uniform,
    /// Direction-scaled weights `(ax, ay, az)` (az ignored in 2D).
    Anisotropic(f64, f64, f64),
    /// Per-cell coefficient `10^U(0, log10_ratio)`; edge weight is the
    /// harmonic mean of its two cells — the classic high-contrast medium.
    HighContrast(f64),
}

impl Coeff {
    fn tag(&self) -> String {
        match self {
            Coeff::Uniform => "uniform".into(),
            Coeff::Anisotropic(x, y, z) => format!("aniso({x},{y},{z})"),
            Coeff::HighContrast(r) => format!("contrast(1e{r})"),
        }
    }
}

#[inline]
fn harmonic(a: f64, b: f64) -> f64 {
    2.0 * a * b / (a + b)
}

/// 5-point 2D grid Laplacian (`nx·ny` vertices).
pub fn grid2d(nx: usize, ny: usize, coeff: Coeff, seed: u64) -> Laplacian {
    let mut rng = Rng::new(seed);
    let cell: Vec<f64> = match coeff {
        Coeff::HighContrast(r) => (0..nx * ny).map(|_| 10f64.powf(rng.range_f64(0.0, r))).collect(),
        _ => Vec::new(),
    };
    let (ax, ay) = match coeff {
        Coeff::Anisotropic(x, y, _) => (x, y),
        _ => (1.0, 1.0),
    };
    let id = |x: usize, y: usize| (y * nx + x) as u32;
    let w = |a: u32, b: u32, dirw: f64| -> f64 {
        if cell.is_empty() {
            dirw
        } else {
            harmonic(cell[a as usize], cell[b as usize])
        }
    };
    let mut edges = Vec::with_capacity(2 * nx * ny);
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                let (a, b) = (id(x, y), id(x + 1, y));
                edges.push((a, b, w(a, b, ax)));
            }
            if y + 1 < ny {
                let (a, b) = (id(x, y), id(x, y + 1));
                edges.push((a, b, w(a, b, ay)));
            }
        }
    }
    Laplacian::from_edges(nx * ny, &edges, &format!("grid2d({nx}x{ny},{})", coeff.tag()))
}

/// 7-point 3D grid Laplacian (`nx·ny·nz` vertices) — the paper's "3D
/// poisson" family.
pub fn grid3d(nx: usize, ny: usize, nz: usize, coeff: Coeff, seed: u64) -> Laplacian {
    let mut rng = Rng::new(seed);
    let n = nx * ny * nz;
    let cell: Vec<f64> = match coeff {
        Coeff::HighContrast(r) => (0..n).map(|_| 10f64.powf(rng.range_f64(0.0, r))).collect(),
        _ => Vec::new(),
    };
    let (ax, ay, az) = match coeff {
        Coeff::Anisotropic(x, y, z) => (x, y, z),
        _ => (1.0, 1.0, 1.0),
    };
    let id = |x: usize, y: usize, z: usize| (z * ny * nx + y * nx + x) as u32;
    let w = |a: u32, b: u32, dirw: f64| -> f64 {
        if cell.is_empty() {
            dirw
        } else {
            harmonic(cell[a as usize], cell[b as usize])
        }
    };
    let mut edges = Vec::with_capacity(3 * n);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                if x + 1 < nx {
                    let (a, b) = (id(x, y, z), id(x + 1, y, z));
                    edges.push((a, b, w(a, b, ax)));
                }
                if y + 1 < ny {
                    let (a, b) = (id(x, y, z), id(x, y + 1, z));
                    edges.push((a, b, w(a, b, ay)));
                }
                if z + 1 < nz {
                    let (a, b) = (id(x, y, z), id(x, y, z + 1));
                    edges.push((a, b, w(a, b, az)));
                }
            }
        }
    }
    Laplacian::from_edges(n, &edges, &format!("grid3d({nx}x{ny}x{nz},{})", coeff.tag()))
}

/// Road-network analogue: a random spanning tree over a 2D grid plus a
/// small fraction of local "shortcut" edges. Average degree ≈ 2.2–2.6,
/// huge diameter — the properties that make GAP-road / europe_osm behave
/// the way they do in Tables 2–3.
pub fn road_like(nx: usize, ny: usize, extra_frac: f64, seed: u64) -> Laplacian {
    let n = nx * ny;
    let mut rng = Rng::new(seed);
    let id = |x: usize, y: usize| y * nx + x;
    // Random spanning tree via randomized DFS over the grid.
    let mut visited = vec![false; n];
    let mut edges: Vec<(u32, u32, f64)> = Vec::with_capacity(n + (extra_frac * n as f64) as usize);
    let mut stack = vec![id(rng.below(nx), rng.below(ny))];
    visited[stack[0]] = true;
    let mut nbrs = Vec::with_capacity(4);
    while let Some(&u) = stack.last() {
        let (x, y) = (u % nx, u / nx);
        nbrs.clear();
        if x > 0 && !visited[id(x - 1, y)] {
            nbrs.push(id(x - 1, y));
        }
        if x + 1 < nx && !visited[id(x + 1, y)] {
            nbrs.push(id(x + 1, y));
        }
        if y > 0 && !visited[id(x, y - 1)] {
            nbrs.push(id(x, y - 1));
        }
        if y + 1 < ny && !visited[id(x, y + 1)] {
            nbrs.push(id(x, y + 1));
        }
        if nbrs.is_empty() {
            stack.pop();
            continue;
        }
        let v = nbrs[rng.below(nbrs.len())];
        visited[v] = true;
        edges.push((u as u32, v as u32, rng.range_f64(0.5, 2.0)));
        stack.push(v);
    }
    // Shortcuts: re-add a fraction of unused grid edges.
    let n_extra = (extra_frac * n as f64) as usize;
    for _ in 0..n_extra {
        let x = rng.below(nx - 1);
        let y = rng.below(ny - 1);
        let (a, b) = if rng.below(2) == 0 {
            (id(x, y), id(x + 1, y))
        } else {
            (id(x, y), id(x, y + 1))
        };
        edges.push((a as u32, b as u32, rng.range_f64(0.5, 2.0)));
    }
    Laplacian::from_edges(n, &edges, &format!("road_like({nx}x{ny},+{extra_frac})"))
}

/// Barabási–Albert preferential attachment: heavy-tailed degree
/// distribution, high density — the com-LiveJournal analogue.
pub fn pref_attach(n: usize, m: usize, seed: u64) -> Laplacian {
    assert!(n > m && m >= 1);
    let mut rng = Rng::new(seed);
    // Target list: each edge endpoint appears once → sampling ∝ degree.
    let mut targets: Vec<u32> = Vec::with_capacity(2 * n * m);
    let mut edges: Vec<(u32, u32, f64)> = Vec::with_capacity(n * m);
    // Seed clique on m+1 vertices.
    for a in 0..=(m as u32) {
        for b in 0..a {
            edges.push((b, a, 1.0));
            targets.push(a);
            targets.push(b);
        }
    }
    for v in (m as u32 + 1)..(n as u32) {
        let mut chosen = Vec::with_capacity(m);
        while chosen.len() < m {
            let t = targets[rng.below(targets.len())];
            if t != v && !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            edges.push((t, v, 1.0));
            targets.push(v);
            targets.push(t);
        }
    }
    Laplacian::from_edges(n, &edges, &format!("pref_attach({n},m={m})"))
}

/// Triangulated grid: each unit cell gets one of its two diagonals at
/// random — a planar triangulation with delaunay_n24-like structure.
pub fn delaunay_like(nx: usize, ny: usize, seed: u64) -> Laplacian {
    let mut rng = Rng::new(seed);
    let id = |x: usize, y: usize| (y * nx + x) as u32;
    let mut edges = Vec::with_capacity(3 * nx * ny);
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                edges.push((id(x, y), id(x + 1, y), 1.0));
            }
            if y + 1 < ny {
                edges.push((id(x, y), id(x, y + 1), 1.0));
            }
            if x + 1 < nx && y + 1 < ny {
                if rng.below(2) == 0 {
                    edges.push((id(x, y), id(x + 1, y + 1), 1.0));
                } else {
                    edges.push((id(x + 1, y), id(x, y + 1), 1.0));
                }
            }
        }
    }
    Laplacian::from_edges(nx * ny, &edges, &format!("delaunay_like({nx}x{ny})"))
}

/// Erdős–Rényi `G(n, p)` with `p = avg_deg / (n−1)` (irregular sparsity,
/// no locality at all — a stress test for the orderings).
pub fn erdos_renyi(n: usize, avg_deg: f64, seed: u64) -> Laplacian {
    let mut rng = Rng::new(seed);
    let p = avg_deg / (n as f64 - 1.0);
    let mut edges = Vec::with_capacity((n as f64 * avg_deg / 2.0) as usize);
    // Geometric skipping over the upper-triangular pair sequence.
    let ln_q = (1.0 - p).ln();
    let mut a = 0usize;
    let mut b = 0usize;
    loop {
        let u = 1.0 - rng.next_f64();
        let skip = (u.ln() / ln_q).floor() as usize + 1;
        b += skip;
        while b >= n {
            a += 1;
            b = a + 1 + (b - n);
            if a >= n - 1 {
                return Laplacian::from_edges(
                    n,
                    &edges,
                    &format!("erdos_renyi({n},deg={avg_deg})"),
                );
            }
        }
        edges.push((a as u32, b as u32, 1.0));
    }
}

/// Random regular-ish expander: the union of `rounds` independent
/// random Hamiltonian cycles (each a shuffled permutation of the
/// vertices, closed into a ring). Every round is connected on its own,
/// so the union is connected by construction; for `rounds ≥ 2` the
/// result is an expander with high probability — constant degree
/// `≈ 2·rounds`, no locality, and logarithmic diameter: the opposite
/// corner of the suite from the meshes, and the adversarial case for
/// every fill-reducing ordering. Parallel edges across rounds collapse
/// by weight accumulation in the Laplacian assembly.
pub fn expander(n: usize, rounds: usize, seed: u64) -> Laplacian {
    assert!(n >= 3 && rounds >= 1);
    let mut rng = Rng::new(seed);
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut edges = Vec::with_capacity(rounds * n);
    for _ in 0..rounds {
        rng.shuffle(&mut perm);
        for i in 0..n {
            let a = perm[i];
            let b = perm[(i + 1) % n];
            // A permutation ring never yields a self-loop.
            edges.push((a, b, 1.0));
        }
    }
    Laplacian::from_edges(n, &edges, &format!("expander({n},r={rounds})"))
}

/// Path graph (worst-case sequential chain — critical-path stress test).
pub fn path(n: usize) -> Laplacian {
    let edges: Vec<_> = (0..n as u32 - 1).map(|i| (i, i + 1, 1.0)).collect();
    Laplacian::from_edges(n, &edges, &format!("path({n})"))
}

/// Star graph (single high-degree hub — clique-sampling stress test).
pub fn star(n: usize) -> Laplacian {
    let edges: Vec<_> = (1..n as u32).map(|i| (0, i, 1.0)).collect();
    Laplacian::from_edges(n, &edges, &format!("star({n})"))
}

/// Complete graph on `n` vertices (dense limit, tiny `n` only).
pub fn complete(n: usize) -> Laplacian {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for a in 0..n as u32 {
        for b in 0..a {
            edges.push((b, a, 1.0));
        }
    }
    Laplacian::from_edges(n, &edges, &format!("complete({n})"))
}

/// Uniform random tree on `n` vertices (random attachment).
pub fn random_tree(n: usize, seed: u64) -> Laplacian {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(n - 1);
    for v in 1..n as u32 {
        let parent = rng.below(v as usize) as u32;
        edges.push((parent, v, rng.range_f64(0.5, 2.0)));
    }
    Laplacian::from_edges(n, &edges, &format!("random_tree({n})"))
}

/// Path of cliques ("caterpillar ladder"): `cliques` cliques of `k`
/// vertices each, consecutive cliques joined by a single light bridge
/// edge. The high-diameter adversary ROADMAP item 5 asks for: diameter
/// grows linearly in `cliques` (every cross-graph route threads all the
/// bridges), which is worst-case for level-scheduled sweeps, while each
/// clique locally stresses the sampler. Random weights, deterministic
/// per seed. `n = cliques·k`, `m = cliques·k(k-1)/2 + cliques - 1`.
pub fn clique_path(cliques: usize, k: usize, seed: u64) -> Laplacian {
    assert!(cliques >= 1 && k >= 2, "need at least one clique of size 2");
    let mut rng = Rng::new(seed);
    let n = cliques * k;
    let mut edges = Vec::with_capacity(cliques * k * (k - 1) / 2 + cliques - 1);
    for c in 0..cliques {
        let base = (c * k) as u32;
        for a in 0..k as u32 {
            for b in 0..a {
                edges.push((base + b, base + a, rng.range_f64(0.5, 2.0)));
            }
        }
        if c + 1 < cliques {
            // One light bridge, last vertex of this clique to the first
            // of the next: the only route across.
            edges.push((base + k as u32 - 1, base + k as u32, rng.range_f64(0.25, 1.0)));
        }
    }
    Laplacian::from_edges(n, &edges, &format!("clique_path({cliques}x{k})"))
}

/// A small connected random graph with random weights — the property-test
/// workhorse (connected by construction: random tree + extra edges).
pub fn random_connected(n: usize, extra_edges: usize, seed: u64) -> Laplacian {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(n - 1 + extra_edges);
    for v in 1..n as u32 {
        let parent = rng.below(v as usize) as u32;
        edges.push((parent, v, rng.range_f64(0.1, 10.0)));
    }
    for _ in 0..extra_edges {
        let a = rng.below(n) as u32;
        let b = rng.below(n) as u32;
        if a != b {
            edges.push((a.min(b), a.max(b), rng.range_f64(0.1, 10.0)));
        }
    }
    Laplacian::from_edges(n, &edges, &format!("random_connected({n},+{extra_edges})"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid2d_structure() {
        let l = grid2d(4, 3, Coeff::Uniform, 0);
        l.validate().unwrap();
        assert_eq!(l.n(), 12);
        assert_eq!(l.num_edges(), 3 * 3 + 4 * 2); // nx-1 per row * ny + ny-1 per col * nx
        let (_, ncomp) = l.components();
        assert_eq!(ncomp, 1);
    }

    #[test]
    fn grid3d_structure() {
        let l = grid3d(3, 3, 3, Coeff::Uniform, 0);
        l.validate().unwrap();
        assert_eq!(l.n(), 27);
        assert_eq!(l.num_edges(), 3 * (2 * 3 * 3)); // 3 directions × 2·3·3 edges
        // Interior vertex degree 6.
        assert_eq!(l.matrix.get(13, 13), 6.0);
    }

    #[test]
    fn anisotropic_weights() {
        let l = grid2d(3, 3, Coeff::Anisotropic(10.0, 0.1, 1.0), 0);
        l.validate().unwrap();
        assert_eq!(l.matrix.get(0, 1), -10.0); // x-edge
        assert_eq!(l.matrix.get(0, 3), -0.1); // y-edge
    }

    #[test]
    fn high_contrast_range() {
        let l = grid3d(4, 4, 4, Coeff::HighContrast(4.0), 7);
        l.validate().unwrap();
        let ws: Vec<f64> = l.edges().iter().map(|e| e.2).collect();
        let lo = ws.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = ws.iter().cloned().fold(0.0, f64::max);
        assert!(hi / lo > 10.0, "expected contrast, got {lo}..{hi}");
    }

    #[test]
    fn road_like_is_connected_and_sparse() {
        let l = road_like(20, 20, 0.15, 3);
        l.validate().unwrap();
        let (_, ncomp) = l.components();
        assert_eq!(ncomp, 1);
        let avg_deg = 2.0 * l.num_edges() as f64 / l.n() as f64;
        assert!(avg_deg < 3.0, "road networks must stay sparse, got {avg_deg}");
    }

    #[test]
    fn pref_attach_heavy_tail() {
        let l = pref_attach(500, 4, 1);
        l.validate().unwrap();
        let (_, ncomp) = l.components();
        assert_eq!(ncomp, 1);
        let max_deg = (0..l.n())
            .map(|r| l.matrix.row_indices(r).len() - 1)
            .max()
            .unwrap();
        assert!(max_deg > 20, "hub degree {max_deg} too small for BA graph");
    }

    #[test]
    fn delaunay_has_diagonals() {
        let l = delaunay_like(5, 5, 2);
        l.validate().unwrap();
        assert_eq!(l.num_edges(), 4 * 5 * 2 + 16);
    }

    #[test]
    fn erdos_renyi_degree() {
        let l = erdos_renyi(2000, 6.0, 5);
        l.validate().unwrap();
        let avg = 2.0 * l.num_edges() as f64 / l.n() as f64;
        assert!((avg - 6.0).abs() < 0.6, "avg degree {avg}");
    }

    #[test]
    fn expander_is_connected_and_near_regular() {
        let l = expander(600, 3, 7);
        l.validate().unwrap();
        let (_, ncomp) = l.components();
        assert_eq!(ncomp, 1, "each Hamiltonian round is connected on its own");
        // Every round gives each vertex exactly degree 2; merged
        // parallel edges can only lower the count.
        let degs: Vec<usize> =
            (0..l.n()).map(|r| l.matrix.row_indices(r).len() - 1).collect();
        assert!(degs.iter().all(|&d| (2..=6).contains(&d)), "degree outside [2, 2*rounds]");
        // Deterministic per seed, distinct across seeds.
        assert_eq!(l.matrix, expander(600, 3, 7).matrix);
        assert_ne!(l.matrix, expander(600, 3, 8).matrix);
    }

    #[test]
    fn special_graphs() {
        path(10).validate().unwrap();
        star(10).validate().unwrap();
        complete(8).validate().unwrap();
        assert_eq!(complete(8).num_edges(), 28);
        let t = random_tree(64, 9);
        t.validate().unwrap();
        assert_eq!(t.num_edges(), 63);
        let (_, nc) = t.components();
        assert_eq!(nc, 1);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = random_connected(100, 50, 42);
        let b = random_connected(100, 50, 42);
        assert_eq!(a.matrix, b.matrix);
        let c = random_connected(100, 50, 43);
        assert_ne!(a.matrix, c.matrix);
    }

    #[test]
    fn clique_path_structure() {
        let l = clique_path(30, 4, 11);
        l.validate().unwrap();
        assert_eq!(l.n(), 120);
        // 30 cliques of C(4,2)=6 edges plus 29 bridges.
        assert_eq!(l.num_edges(), 30 * 6 + 29);
        let (_, ncomp) = l.components();
        assert_eq!(ncomp, 1, "bridges must connect the ladder");
        // Degrees: k-1 inside a clique, +1 for a bridge endpoint (the
        // first and last vertex of interior cliques carry one each).
        let degs: Vec<usize> =
            (0..l.n()).map(|r| l.matrix.row_indices(r).len() - 1).collect();
        assert!(degs.iter().all(|&d| (3..=4).contains(&d)), "degree outside [k-1, k]");
        // Deterministic per seed, distinct across seeds.
        assert_eq!(l.matrix, clique_path(30, 4, 11).matrix);
        assert_ne!(l.matrix, clique_path(30, 4, 12).matrix);
    }
}
