//! The doubling reduction: SDD matrices with **positive** off-diagonals
//! (not M-matrices, so [`super::laplacian::Laplacian::ground_sdd`] alone
//! does not apply) reduce to a graph Laplacian of twice the size via the
//! bipartite double cover (Gremban's construction, used by rchol):
//!
//! For `A = D + A_n + A_p` (diagonal, negative off-diag, positive
//! off-diag), the `2N × 2N` matrix
//!
//! ```text
//!   Â = [ D + A_n      -A_p    ]   acting on (x⁺, x⁻)
//!       [ -A_p       D + A_n   ]
//! ```
//!
//! is SDD with non-positive off-diagonals; grounding it yields a
//! Laplacian. A solve of `A x = b` maps to `Â (x, −x) = (b, −b)`, so the
//! preconditioner apply averages the two halves:
//! `z = (ẑ⁺ − ẑ⁻) / 2`.

use super::laplacian::Laplacian;
use crate::sparse::{Coo, Csr};

/// Build the `2N` double-cover SDD M-matrix of `a` (entries mirrored per
/// the Gremban construction). Fails if `a` is not SDD.
pub fn double_cover(a: &Csr) -> Result<Csr, String> {
    let n = a.nrows;
    let mut coo = Coo::with_capacity(2 * n, 2 * n, 2 * a.nnz());
    for r in 0..n {
        let mut offsum = 0.0;
        let mut diag = 0.0;
        for (&c, &v) in a.row_indices(r).iter().zip(a.row_data(r)) {
            let c = c as usize;
            if c == r {
                diag = v;
                continue;
            }
            offsum += v.abs();
            if v < 0.0 {
                // Negative edge stays within each copy.
                coo.push(r as u32, c as u32, v);
                coo.push((r + n) as u32, (c + n) as u32, v);
            } else {
                // Positive edge crosses between the copies, negated.
                coo.push(r as u32, (c + n) as u32, -v);
                coo.push((r + n) as u32, c as u32, -v);
            }
        }
        if diag + 1e-9 * diag.abs() < offsum {
            return Err(format!("row {r} not diagonally dominant"));
        }
        coo.push(r as u32, r as u32, diag);
        coo.push((r + n) as u32, (r + n) as u32, diag);
    }
    Ok(coo.to_csr())
}

/// A preconditioner for a general SDD matrix built by factoring the
/// grounded double cover with ParAC. The three `2N` intermediates are
/// preallocated at construction (behind an uncontended `Mutex`, like
/// [`crate::precond::LdlPrecond`]) so applies stay allocation-free.
pub struct DoubledSddPrecond {
    factor: crate::factor::LdlFactor,
    n: usize,
    scratch: std::sync::Mutex<DoubledScratch>,
}

/// Cover-space buffers: rhs lift, solution, and permutation scratch.
struct DoubledScratch {
    rhat: Vec<f64>,
    zhat: Vec<f64>,
    perm: Vec<f64>,
}

impl DoubledSddPrecond {
    /// Ground + factor the double cover of `a`.
    pub fn new(a: &Csr, opts: &crate::factor::ParacOptions) -> Result<Self, String> {
        let doubled = double_cover(a)?;
        let factor =
            crate::factor::factorize_sdd(&doubled, opts).map_err(|e| e.to_string())?;
        let n = a.nrows;
        let scratch = std::sync::Mutex::new(DoubledScratch {
            rhat: vec![0.0; 2 * n],
            zhat: vec![0.0; 2 * n],
            perm: vec![0.0; 2 * n],
        });
        Ok(DoubledSddPrecond { factor, n, scratch })
    }

    /// The underlying `2N` factor.
    pub fn factor(&self) -> &crate::factor::LdlFactor {
        &self.factor
    }
}

impl crate::precond::Preconditioner for DoubledSddPrecond {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        // Â (x, −x) = (r, −r): solve on the cover, fold back.
        let mut s = self.scratch.lock().unwrap_or_else(|p| p.into_inner());
        let DoubledScratch { rhat, zhat, perm } = &mut *s;
        rhat[..self.n].copy_from_slice(r);
        for i in 0..self.n {
            rhat[self.n + i] = -r[i];
        }
        self.factor.solve_into(rhat, zhat, perm);
        for (i, zi) in z.iter_mut().enumerate() {
            *zi = 0.5 * (zhat[i] - zhat[self.n + i]);
        }
    }

    fn name(&self) -> &'static str {
        "parac-doubled"
    }

    fn nnz(&self) -> usize {
        self.factor.nnz() + 2 * self.n
    }
}

/// Kept for parity with the Laplacian module: whether `a` needs the
/// doubling reduction (any positive off-diagonal).
pub fn needs_doubling(a: &Csr) -> bool {
    (0..a.nrows).any(|r| {
        a.row_indices(r)
            .iter()
            .zip(a.row_data(r))
            .any(|(&c, &v)| c as usize != r && v > 1e-14)
    })
}

/// Convenience: `Laplacian`-typed view of the grounded double cover
/// (diagnostics / tests).
pub fn doubled_laplacian(a: &Csr, name: &str) -> Result<Laplacian, String> {
    Laplacian::ground_sdd(&double_cover(a)?, name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ParacOptions;
    use crate::solve::pcg::{self, PcgOptions};

    /// SDD test matrix with mixed-sign off-diagonals: a ring where every
    /// third edge has a positive coupling.
    fn mixed_sign_sdd(n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            let j = (i + 1) % n;
            let v = if i % 3 == 0 { 0.8 } else { -1.0 };
            coo.push_sym(i as u32, j as u32, v);
        }
        for i in 0..n {
            coo.push(i as u32, i as u32, 2.2); // strictly dominant
        }
        coo.to_csr()
    }

    #[test]
    fn double_cover_is_m_matrix_sdd() {
        let a = mixed_sign_sdd(24);
        assert!(needs_doubling(&a));
        let d = double_cover(&a).unwrap();
        assert_eq!(d.nrows, 48);
        assert!(d.is_symmetric(1e-12));
        // All off-diagonals non-positive, rows dominant.
        for r in 0..48 {
            for (&c, &v) in d.row_indices(r).iter().zip(d.row_data(r)) {
                if c as usize != r {
                    assert!(v <= 0.0, "positive off-diag survived at ({r},{c})");
                }
            }
        }
        let lap = doubled_laplacian(&a, "cover").unwrap();
        lap.validate().unwrap();
    }

    #[test]
    fn doubled_precond_solves_mixed_sign_system() {
        let a = mixed_sign_sdd(60);
        let pre = DoubledSddPrecond::new(&a, &ParacOptions::default()).unwrap();
        let mut rng = crate::rng::Rng::new(4);
        let xs: Vec<f64> = (0..60).map(|_| rng.next_normal()).collect();
        let b = a.mul_vec(&xs);
        let o = PcgOptions { project: false, tol: 1e-10, max_iter: 300, ..Default::default() };
        let out = pcg::solve(&a, &b, &pre, &o);
        assert!(out.converged, "rel={}", out.rel_residual);
        for (got, want) in out.x.iter().zip(&xs) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    fn doubled_precond_beats_jacobi() {
        let a = mixed_sign_sdd(120);
        let pre = DoubledSddPrecond::new(&a, &ParacOptions::default()).unwrap();
        let jac = crate::precond::JacobiPrecond::new(&a);
        let b: Vec<f64> = (0..120).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
        let o = PcgOptions { project: false, tol: 1e-9, max_iter: 500, ..Default::default() };
        let with = pcg::solve(&a, &b, &pre, &o);
        let without = pcg::solve(&a, &b, &jac, &o);
        assert!(with.converged);
        assert!(with.iters <= without.iters, "{} vs {}", with.iters, without.iters);
    }

    #[test]
    fn rejects_non_sdd() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 0.5);
        coo.push(1, 1, 0.5);
        coo.push_sym(0, 1, 1.0);
        assert!(double_cover(&coo.to_csr()).is_err());
    }

    #[test]
    fn pure_m_matrix_needs_no_doubling() {
        let lap = crate::graph::generators::grid2d(5, 5, crate::graph::generators::Coeff::Uniform, 0);
        assert!(!needs_doubling(&lap.matrix));
    }
}
