//! Graph Laplacian construction and SDD grounding.
//!
//! A weighted undirected graph `G = (V, E, w)` has Laplacian
//! `L = Σ_{(i,j)∈E} w_ij (e_i−e_j)(e_i−e_j)ᵀ` (paper Def. 2.1): diagonal
//! `ℓ_ii = Σ_j w_ij`, off-diagonal `ℓ_ij = −w_ij`. `L` is singular with
//! nullspace `span{1}` per connected component.
//!
//! SPD SDD M-matrices (e.g. Poisson with Dirichlet boundary) are handled
//! by the rchol grounding construction: extend to an `(N+1)`-vertex
//! Laplacian whose extra "ground" vertex absorbs each row's diagonal
//! excess; factor that, and use the leading `N×N` block as the
//! preconditioner.

use crate::sparse::{Coo, Csr};

/// What kind of operator this Laplacian-like matrix is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LapKind {
    /// A true graph Laplacian: zero row sums, singular (nullspace = 1 per
    /// component).
    Graph,
    /// A grounded Laplacian: the leading block of a graph Laplacian with
    /// its ground vertex row/column removed — SPD.
    Grounded,
}

/// A Laplacian (or grounded-Laplacian) operator plus metadata.
#[derive(Clone, Debug)]
pub struct Laplacian {
    /// The `N×N` matrix, both triangles stored.
    pub matrix: Csr,
    /// Singular graph Laplacian or SPD grounded block.
    pub kind: LapKind,
    /// Human-readable provenance (generator name + parameters).
    pub name: String,
}

/// Content hash of a Laplacian, split into the two granularities the
/// serving layer routes on (see [`crate::serve::FactorCache`]):
///
/// * `pattern` — dimension, kind, and sparsity structure only. Two
///   Laplacians with equal `pattern` are candidates for the numeric
///   [`refactorize`](crate::solver::Solver::refactorize_shared) path
///   (same edges, possibly different weights).
/// * `full` — `pattern` plus every weight, bit-exact
///   (`f64::to_bits`). Two Laplacians with equal `full` describe the
///   same operator and can share one cached factor outright.
///
/// Equal hashes are necessary but not sufficient (64-bit FNV-1a can
/// collide); every consumer that acts on a match re-validates —
/// the refactorize path's own pattern check rejects impostors with a
/// typed error. The provenance `name` is deliberately excluded: the
/// same graph built under two names is still the same operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fingerprint {
    /// Structure-only hash (dimension + kind + CSR layout).
    pub pattern: u64,
    /// Structure-and-weights hash.
    pub full: u64,
}

/// FNV-1a over the 8 bytes of `v` (little-endian).
#[inline]
fn fnv1a_u64(h: u64, v: u64) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = h;
    for byte in v.to_le_bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

impl Laplacian {
    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.matrix.nrows
    }

    /// Number of undirected edges (off-diagonal nnz / 2).
    pub fn num_edges(&self) -> usize {
        (self.matrix.nnz() - self.matrix.diag().iter().filter(|d| **d != 0.0).count()) / 2
    }

    /// Content [`Fingerprint`] of this operator: one pass of FNV-1a
    /// over the CSR structure (for [`Fingerprint::pattern`]) and a
    /// second accumulation folding in the bit patterns of the weights
    /// (for [`Fingerprint::full`]). O(nnz) — cheap next to a
    /// factorization or a PCG solve, but callers issuing many requests
    /// against one graph should compute it once and reuse it.
    pub fn fingerprint(&self) -> Fingerprint {
        let mut h = FNV_OFFSET;
        h = fnv1a_u64(h, self.matrix.nrows as u64);
        h = fnv1a_u64(h, self.kind as u64);
        for &p in &self.matrix.indptr {
            h = fnv1a_u64(h, p as u64);
        }
        for &c in &self.matrix.indices {
            h = fnv1a_u64(h, c as u64);
        }
        let pattern = h;
        for &v in &self.matrix.data {
            h = fnv1a_u64(h, v.to_bits());
        }
        Fingerprint { pattern, full: h }
    }

    /// Build a Laplacian from an undirected weighted edge list.
    /// Duplicate edges are merged (weights summed); self-loops ignored.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f64)], name: &str) -> Laplacian {
        let mut coo = Coo::with_capacity(n, n, edges.len() * 4);
        let mut deg = vec![0.0f64; n];
        for &(a, b, w) in edges {
            if a == b {
                continue;
            }
            debug_assert!(w > 0.0, "edge weights must be positive");
            coo.push(a, b, -w);
            coo.push(b, a, -w);
            deg[a as usize] += w;
            deg[b as usize] += w;
        }
        for (i, &d) in deg.iter().enumerate() {
            if d != 0.0 {
                coo.push(i as u32, i as u32, d);
            }
        }
        Laplacian { matrix: coo.to_csr(), kind: LapKind::Graph, name: name.to_string() }
    }

    /// Check the Laplacian invariants: finite values, symmetry,
    /// non-positive off-diagonals, and (for `Graph` kind) zero row
    /// sums. Non-finite weights are caught *first* — NaN compares
    /// false against every threshold below, so without this check a
    /// poisoned matrix would sail through the sign and row-sum tests.
    pub fn validate(&self) -> Result<(), String> {
        self.matrix.validate()?;
        if let Some(i) = self.matrix.data.iter().position(|v| !v.is_finite()) {
            return Err(format!(
                "non-finite value {} at nnz index {i}",
                self.matrix.data[i]
            ));
        }
        if !self.matrix.is_symmetric(1e-12) {
            return Err("not symmetric".into());
        }
        for r in 0..self.n() {
            let mut sum = 0.0;
            for (&c, &v) in self.matrix.row_indices(r).iter().zip(self.matrix.row_data(r)) {
                if (c as usize) != r && v > 1e-14 {
                    return Err(format!("positive off-diagonal at ({r},{c})"));
                }
                sum += v;
            }
            match self.kind {
                LapKind::Graph => {
                    let scale = self.matrix.get(r, r).max(1.0);
                    if sum.abs() > 1e-9 * scale {
                        return Err(format!("row {r} sum {sum} not zero"));
                    }
                }
                LapKind::Grounded => {
                    if sum < -1e-9 {
                        return Err(format!("row {r} sum {sum} negative (not SDD)"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Extract the weighted edge list (lower triangle, `a < b` pairs).
    pub fn edges(&self) -> Vec<(u32, u32, f64)> {
        let mut out = Vec::with_capacity(self.matrix.nnz() / 2);
        for r in 0..self.n() {
            for (&c, &v) in self.matrix.row_indices(r).iter().zip(self.matrix.row_data(r)) {
                if (c as usize) > r && v < 0.0 {
                    out.push((r as u32, c, -v));
                }
            }
        }
        out
    }

    /// Ground vertex extension (rchol): turn an SDD M-matrix `A` into the
    /// `(N+1)`-vertex graph Laplacian whose last vertex absorbs each
    /// row's excess `a_ii − Σ_{j≠i}|a_ij|`. Returns an exact `Graph`
    /// Laplacian; factoring it and truncating to `N×N` preconditions `A`.
    pub fn ground_sdd(a: &Csr, name: &str) -> Result<Laplacian, String> {
        let n = a.nrows;
        let g = n as u32; // ground vertex index
        let mut edges: Vec<(u32, u32, f64)> = Vec::with_capacity(a.nnz() / 2 + n);
        for r in 0..n {
            let mut excess = 0.0;
            for (&c, &v) in a.row_indices(r).iter().zip(a.row_data(r)) {
                let c = c as usize;
                if c == r {
                    excess += v;
                } else {
                    if v > 1e-14 {
                        return Err(format!(
                            "positive off-diagonal at ({r},{c}); doubling reduction not applied"
                        ));
                    }
                    excess += v; // v negative
                    if c > r {
                        edges.push((r as u32, c as u32, -v));
                    }
                }
            }
            if excess < -1e-9 {
                return Err(format!("row {r} not diagonally dominant (excess {excess})"));
            }
            if excess > 1e-14 {
                edges.push((r as u32, g, excess));
            }
        }
        Ok(Laplacian::from_edges(n + 1, &edges, name))
    }

    /// The grounded SPD block: remove the **last** vertex's row/column.
    /// Inverse of [`Laplacian::ground_sdd`] when the ground is vertex `N`.
    pub fn drop_ground(&self) -> Laplacian {
        let n = self.n() - 1;
        let mut coo = Coo::with_capacity(n, n, self.matrix.nnz());
        for r in 0..n {
            for (&c, &v) in self.matrix.row_indices(r).iter().zip(self.matrix.row_data(r)) {
                if (c as usize) < n {
                    coo.push(r as u32, c, v);
                }
            }
        }
        Laplacian {
            matrix: coo.to_csr(),
            kind: LapKind::Grounded,
            name: format!("{}/grounded", self.name),
        }
    }

    /// Connected components (BFS); returns the component id of each
    /// vertex and the number of components.
    pub fn components(&self) -> (Vec<u32>, usize) {
        let n = self.n();
        let mut comp = vec![u32::MAX; n];
        let mut ncomp = 0u32;
        let mut stack = Vec::new();
        for s in 0..n {
            if comp[s] != u32::MAX {
                continue;
            }
            comp[s] = ncomp;
            stack.push(s);
            while let Some(u) = stack.pop() {
                for (&c, &v) in self.matrix.row_indices(u).iter().zip(self.matrix.row_data(u)) {
                    let c = c as usize;
                    if c != u && v < 0.0 && comp[c] == u32::MAX {
                        comp[c] = ncomp;
                        stack.push(c);
                    }
                }
            }
            ncomp += 1;
        }
        (comp, ncomp as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Laplacian {
        Laplacian::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (0, 2, 3.0)], "tri")
    }

    #[test]
    fn laplacian_row_sums_zero() {
        let l = triangle();
        l.validate().unwrap();
        assert_eq!(l.matrix.get(0, 0), 4.0);
        assert_eq!(l.matrix.get(1, 1), 3.0);
        assert_eq!(l.matrix.get(2, 2), 5.0);
        assert_eq!(l.matrix.get(0, 1), -1.0);
    }

    #[test]
    fn edges_roundtrip() {
        let l = triangle();
        let mut e = l.edges();
        e.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.total_cmp(&b.2)));
        assert_eq!(e, vec![(0, 1, 1.0), (0, 2, 3.0), (1, 2, 2.0)]);
    }

    #[test]
    fn non_finite_weights_fail_validation() {
        // NaN compares false against every sign/row-sum threshold, so
        // the finiteness check must catch it explicitly — and name the
        // offending entry.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut l = triangle();
            l.matrix.data[1] = bad;
            let msg = l.validate().unwrap_err();
            assert!(
                msg.contains("non-finite value") && msg.contains("nnz index 1"),
                "unexpected validation message for {bad}: {msg}"
            );
        }
    }

    #[test]
    fn duplicate_edges_merge() {
        let l = Laplacian::from_edges(2, &[(0, 1, 1.0), (1, 0, 2.5)], "dup");
        assert_eq!(l.matrix.get(0, 1), -3.5);
        l.validate().unwrap();
    }

    #[test]
    fn ground_and_drop_roundtrip() {
        // SPD tridiagonal SDD matrix: diag 2.5, offdiag -1.
        let mut coo = Coo::new(4, 4);
        for i in 0..4u32 {
            coo.push(i, i, 2.5);
        }
        for i in 0..3u32 {
            coo.push_sym(i, i + 1, -1.0);
        }
        let a = coo.to_csr();
        let lap = Laplacian::ground_sdd(&a, "sdd").unwrap();
        assert_eq!(lap.n(), 5);
        lap.validate().unwrap();
        let back = lap.drop_ground();
        assert_eq!(back.matrix.to_dense(), a.to_dense());
    }

    #[test]
    fn ground_rejects_non_sdd() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 0.5);
        coo.push(1, 1, 0.5);
        coo.push_sym(0, 1, -1.0);
        assert!(Laplacian::ground_sdd(&coo.to_csr(), "bad").is_err());
    }

    #[test]
    fn fingerprint_distinguishes_weights_but_not_names() {
        let a = Laplacian::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)], "a");
        let same = Laplacian::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)], "other-name");
        let reweighted = Laplacian::from_edges(3, &[(0, 1, 1.0), (1, 2, 3.0)], "a");
        let other_pattern = Laplacian::from_edges(3, &[(0, 1, 1.0), (0, 2, 2.0)], "a");

        // Same operator under a different name: identical fingerprint.
        assert_eq!(a.fingerprint(), same.fingerprint());
        // Same edges, new weights: pattern matches, full differs.
        assert_eq!(a.fingerprint().pattern, reweighted.fingerprint().pattern);
        assert_ne!(a.fingerprint().full, reweighted.fingerprint().full);
        // Different edges: both differ.
        assert_ne!(a.fingerprint().pattern, other_pattern.fingerprint().pattern);
        assert_ne!(a.fingerprint().full, other_pattern.fingerprint().full);
        // Deterministic across calls.
        assert_eq!(a.fingerprint(), a.fingerprint());
    }

    #[test]
    fn fingerprint_separates_kinds() {
        // A grounded block vs a graph Laplacian that happen to share
        // dimensions must not collide via structure alone.
        let lap = Laplacian::from_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)], "g");
        let mut grounded = lap.clone();
        grounded.kind = LapKind::Grounded;
        assert_ne!(lap.fingerprint().pattern, grounded.fingerprint().pattern);
    }

    #[test]
    fn components_counts() {
        let l = Laplacian::from_edges(5, &[(0, 1, 1.0), (2, 3, 1.0)], "forest");
        let (comp, n) = l.components();
        assert_eq!(n, 3); // {0,1}, {2,3}, {4}
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[2], comp[3]);
        assert_ne!(comp[0], comp[2]);
    }
}
