//! Padded ELLPACK format — the fixed-shape layout consumed by the
//! AOT-compiled JAX/Pallas SpMV kernel (Layer 1/2).
//!
//! PJRT executables are compiled for static shapes, so the rust side pads
//! a CSR operator to `(n_pad, width)`: every row gets exactly `width`
//! slots; unused slots carry column `row` (a self-reference) and value
//! `0.0` so gathers stay in-bounds and contribute nothing.

use super::csr::Csr;

/// A padded ELL matrix with fixed row width.
#[derive(Clone, Debug)]
pub struct Ell {
    /// Logical number of rows (≤ `n_pad`).
    pub nrows: usize,
    /// Padded number of rows (the compiled kernel's static dimension).
    pub n_pad: usize,
    /// Fixed entries-per-row.
    pub width: usize,
    /// Column indices, row-major `(n_pad, width)`.
    pub cols: Vec<i32>,
    /// Values, row-major `(n_pad, width)`.
    pub vals: Vec<f32>,
}

impl Ell {
    /// Pad `a` to `(n_pad, width)`. Fails if any row has more than
    /// `width` entries or `a.nrows > n_pad`.
    pub fn from_csr(a: &Csr, n_pad: usize, width: usize) -> Result<Ell, String> {
        if a.nrows > n_pad {
            return Err(format!("nrows {} exceeds n_pad {}", a.nrows, n_pad));
        }
        let max_row = (0..a.nrows).map(|r| a.indptr[r + 1] - a.indptr[r]).max().unwrap_or(0);
        if max_row > width {
            return Err(format!("row width {max_row} exceeds ELL width {width}"));
        }
        let mut cols = vec![0i32; n_pad * width];
        let mut vals = vec![0f32; n_pad * width];
        for r in 0..n_pad {
            for k in 0..width {
                cols[r * width + k] = r.min(n_pad - 1) as i32; // safe self-reference
            }
        }
        for r in 0..a.nrows {
            let idx = a.row_indices(r);
            let dat = a.row_data(r);
            for (k, (&c, &v)) in idx.iter().zip(dat).enumerate() {
                cols[r * width + k] = c as i32;
                vals[r * width + k] = v as f32;
            }
        }
        Ok(Ell { nrows: a.nrows, n_pad, width, cols, vals })
    }

    /// Reference SpMV in f64 accumulation (oracle for the Pallas kernel
    /// and for tests). `x` has length `n_pad`.
    pub fn spmv_ref(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n_pad);
        let mut y = vec![0f32; self.n_pad];
        for r in 0..self.n_pad {
            let mut acc = 0f64;
            for k in 0..self.width {
                let c = self.cols[r * self.width + k] as usize;
                acc += self.vals[r * self.width + k] as f64 * x[c] as f64;
            }
            y[r] = acc as f32;
        }
        y
    }

    /// Pad a length-`nrows` vector to `n_pad` with zeros.
    pub fn pad_vec(&self, x: &[f64]) -> Vec<f32> {
        assert_eq!(x.len(), self.nrows);
        let mut out = vec![0f32; self.n_pad];
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            *o = v as f32;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn ell_matches_csr_spmv() {
        let lap = generators::grid2d(8, 8, generators::Coeff::Uniform, 3);
        let a = &lap.matrix;
        let ell = Ell::from_csr(a, 80, 8).unwrap();
        let x: Vec<f64> = (0..a.nrows).map(|i| (i as f64 * 0.37).sin()).collect();
        let y_csr = a.mul_vec(&x);
        let xp = ell.pad_vec(&x);
        let y_ell = ell.spmv_ref(&xp);
        for i in 0..a.nrows {
            assert!((y_csr[i] as f32 - y_ell[i]).abs() < 1e-3, "row {i}");
        }
        for i in a.nrows..80 {
            assert_eq!(y_ell[i], 0.0);
        }
    }

    #[test]
    fn width_overflow_rejected() {
        let lap = generators::grid2d(4, 4, generators::Coeff::Uniform, 3);
        assert!(Ell::from_csr(&lap.matrix, 16, 2).is_err());
    }
}
