//! Padded ELLPACK format — the fixed-shape layout consumed by the
//! AOT-compiled JAX/Pallas SpMV kernel (Layer 1/2).
//!
//! PJRT executables are compiled for static shapes, so the rust side pads
//! a CSR operator to `(n_pad, width)`: every row gets exactly `width`
//! slots; unused slots carry column `row` (a self-reference) and value
//! `0.0` so gathers stay in-bounds and contribute nothing.
//!
//! Value storage rides the crate-wide [`Scalar`] layer. The default
//! plane is `f32` — what the compiled Pallas `spmv_ell` kernel and the
//! PJRT `run_f32` path consume — but `Ell<f64>` is available for
//! oracles and for backends that execute in double; [`Ell::spmv_ref`]
//! accumulates in f64 in every plane.

use super::csr::Csr;
use super::scalar::Scalar;

/// A padded ELL matrix with fixed row width. `S` selects the value
/// (and vector) storage plane; the default `f32` matches the GPU
/// kernels' element type.
#[derive(Clone, Debug)]
pub struct Ell<S: Scalar = f32> {
    /// Logical number of rows (≤ `n_pad`).
    pub nrows: usize,
    /// Padded number of rows (the compiled kernel's static dimension).
    pub n_pad: usize,
    /// Fixed entries-per-row.
    pub width: usize,
    /// Column indices, row-major `(n_pad, width)`.
    pub cols: Vec<i32>,
    /// Values in storage precision, row-major `(n_pad, width)`.
    pub vals: Vec<S>,
}

impl<S: Scalar> Ell<S> {
    /// Pad `a` to `(n_pad, width)`, narrowing values into the storage
    /// plane. Fails if any row has more than `width` entries or
    /// `a.nrows > n_pad`.
    pub fn from_csr(a: &Csr, n_pad: usize, width: usize) -> Result<Ell<S>, String> {
        if a.nrows > n_pad {
            return Err(format!("nrows {} exceeds n_pad {}", a.nrows, n_pad));
        }
        let max_row = (0..a.nrows).map(|r| a.indptr[r + 1] - a.indptr[r]).max().unwrap_or(0);
        if max_row > width {
            return Err(format!("row width {max_row} exceeds ELL width {width}"));
        }
        let mut cols = vec![0i32; n_pad * width];
        let mut vals = vec![S::from_f64(0.0); n_pad * width];
        for r in 0..n_pad {
            for k in 0..width {
                cols[r * width + k] = r.min(n_pad - 1) as i32; // safe self-reference
            }
        }
        for r in 0..a.nrows {
            let idx = a.row_indices(r);
            let dat = a.row_data(r);
            for (k, (&c, &v)) in idx.iter().zip(dat).enumerate() {
                cols[r * width + k] = c as i32;
                vals[r * width + k] = S::from_f64(v);
            }
        }
        Ok(Ell { nrows: a.nrows, n_pad, width, cols, vals })
    }

    /// Reference SpMV in f64 accumulation (oracle for the Pallas kernel
    /// and for tests). `x` has length `n_pad`.
    pub fn spmv_ref(&self, x: &[S]) -> Vec<S> {
        assert_eq!(x.len(), self.n_pad);
        let mut y = vec![S::from_f64(0.0); self.n_pad];
        for r in 0..self.n_pad {
            let mut acc = 0f64;
            for k in 0..self.width {
                let c = self.cols[r * self.width + k] as usize;
                acc += self.vals[r * self.width + k].to_f64() * x[c].to_f64();
            }
            y[r] = S::from_f64(acc);
        }
        y
    }

    /// Pad a length-`nrows` vector to `n_pad` with zeros, narrowing
    /// into the storage plane.
    pub fn pad_vec(&self, x: &[f64]) -> Vec<S> {
        assert_eq!(x.len(), self.nrows);
        let mut out = vec![S::from_f64(0.0); self.n_pad];
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            *o = S::from_f64(v);
        }
        out
    }

    /// Bytes of value storage (`vals` only — `cols` is
    /// precision-invariant).
    pub fn value_bytes(&self) -> usize {
        self.vals.len() * S::BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn ell_matches_csr_spmv() {
        let lap = generators::grid2d(8, 8, generators::Coeff::Uniform, 3);
        let a = &lap.matrix;
        let ell = Ell::<f32>::from_csr(a, 80, 8).unwrap();
        let x: Vec<f64> = (0..a.nrows).map(|i| (i as f64 * 0.37).sin()).collect();
        let y_csr = a.mul_vec(&x);
        let xp = ell.pad_vec(&x);
        let y_ell = ell.spmv_ref(&xp);
        for i in 0..a.nrows {
            assert!((y_csr[i] as f32 - y_ell[i]).abs() < 1e-3, "row {i}");
        }
        for i in a.nrows..80 {
            assert_eq!(y_ell[i], 0.0);
        }
    }

    #[test]
    fn f64_plane_matches_csr_exactly_and_doubles_bytes() {
        let lap = generators::grid2d(8, 8, generators::Coeff::Uniform, 3);
        let a = &lap.matrix;
        let e32 = Ell::<f32>::from_csr(a, 80, 8).unwrap();
        let e64 = Ell::<f64>::from_csr(a, 80, 8).unwrap();
        assert_eq!(e64.value_bytes(), 2 * e32.value_bytes());
        // In the f64 plane the padded SpMV reproduces CSR bit for bit
        // on the logical rows: same values, f64 accumulation, and the
        // padding slots contribute v·0 with in-bounds self-references.
        let x: Vec<f64> = (0..a.nrows).map(|i| (i as f64 * 0.29).cos()).collect();
        let y_csr = a.mul_vec(&x);
        let y_ell = e64.spmv_ref(&e64.pad_vec(&x));
        for i in 0..a.nrows {
            assert_eq!(y_csr[i], y_ell[i], "row {i}");
        }
    }

    #[test]
    fn width_overflow_rejected() {
        let lap = generators::grid2d(4, 4, generators::Coeff::Uniform, 3);
        assert!(Ell::<f32>::from_csr(&lap.matrix, 16, 2).is_err());
    }
}
