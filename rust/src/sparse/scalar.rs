//! The scalar-storage layer: which float type *stores* matrix values.
//!
//! Every kernel in this crate that streams matrix values — the packed
//! triangular sweeps (`solve::packed`), row-split SpMV
//! (`sparse::csr`), the ELL plane (`sparse::ell`) — is
//! bandwidth-bound: the bytes of the `val` arrays are the cost. This
//! module makes that byte width a type parameter. [`Scalar`] is a
//! **sealed** trait implemented for exactly `f64` and `f32`;
//! generic kernels store `Vec<S>` but always *accumulate in f64*
//! (`S::to_f64` per loaded value), so `f32` halves the traffic of the
//! memory-bound inner loops while the arithmetic stays double.
//!
//! The contract is two-tier:
//!
//! - **`f64` plane** — `from_f64`/`to_f64` are the identity, so every
//!   generic kernel is bit-identical to the pre-generic code. All
//!   bit-identity pins (engines × orderings × threads) keep holding.
//! - **`f32` plane** — values round on store. Bit-identity is
//!   deliberately traded for a *residual contract*: PCG still
//!   converges to the same f64 tolerance (the preconditioner only
//!   needs to be spectrally close, not exact), with iteration counts
//!   within a budgeted factor of the f64 plane, and a fallback guard
//!   in `solve::pcg` for systems too ill-conditioned for f32 storage.
//!
//! [`Precision`] is the user-facing name for the choice, parsed from
//! the CLI (`--precision`), the `PARAC_PRECISION` environment
//! variable, or set via `SolverBuilder::precision`.

use crate::error::ParacError;

mod sealed {
    /// Seals [`super::Scalar`]: only `f64` and `f32` ever implement
    /// it, so generic kernels may rely on the exact conversion
    /// semantics documented there.
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for f32 {}
}

/// A float type usable as *storage* for matrix values.
///
/// Sealed: implemented for `f64` (identity conversions — generic code
/// is bit-identical to hand-written f64 code) and `f32` (values round
/// on store; kernels convert back with [`Scalar::to_f64`] and
/// accumulate in f64).
pub trait Scalar:
    sealed::Sealed + Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static
{
    /// Human-readable name of this storage plane (`"f64"` / `"f32"`).
    const NAME: &'static str;
    /// Bytes per stored value (8 / 4).
    const BYTES: usize;
    /// The [`Precision`] tag naming this storage type.
    const PRECISION: Precision;
    /// Narrow an f64 value into this storage type (identity for f64).
    fn from_f64(v: f64) -> Self;
    /// Widen a stored value back to f64 for accumulation (identity
    /// for f64).
    fn to_f64(self) -> f64;
}

impl Scalar for f64 {
    const NAME: &'static str = "f64";
    const BYTES: usize = 8;
    const PRECISION: Precision = Precision::F64;
    #[inline(always)]
    fn from_f64(v: f64) -> f64 {
        v
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
}

impl Scalar for f32 {
    const NAME: &'static str = "f32";
    const BYTES: usize = 4;
    const PRECISION: Precision = Precision::F32;
    #[inline(always)]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

/// Which storage plane the preconditioner's value arrays use.
///
/// `F64` (the default) keeps every bit-identity guarantee. `F32`
/// halves the bytes streamed per preconditioner apply — the win on a
/// bandwidth-bound kernel — at the cost of bit-identity: results obey
/// a residual contract instead (converged to the same tolerance,
/// iteration counts within a budgeted factor of f64, automatic f64
/// fallback on stagnation or non-finite arithmetic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// 8-byte value storage; bit-identical to the sequential
    /// reference (the crate's historical behavior).
    #[default]
    F64,
    /// 4-byte value storage with f64 accumulation; residual contract
    /// instead of bit-identity.
    F32,
}

impl Precision {
    /// Canonical lowercase name (`"f64"` / `"f32"`), round-tripping
    /// through [`Precision::parse`].
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    /// Parse a precision name from the CLI / environment.
    ///
    /// Accepts `f64`/`f32` (any ASCII case) and the common synonyms
    /// `double`/`single`. Anything else is a typed
    /// [`ParacError::InvalidOption`].
    pub fn parse(s: &str) -> Result<Precision, ParacError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f64" | "fp64" | "double" => Ok(Precision::F64),
            "f32" | "fp32" | "single" => Ok(Precision::F32),
            _ => Err(ParacError::InvalidOption {
                what: "precision",
                got: s.to_string(),
            }),
        }
    }

    /// The `PARAC_PRECISION` environment override, if set and
    /// parsable. Unset or unparsable values yield `None` (mirroring
    /// how `PARAC_LEVEL_CUTOFF` ignores garbage rather than failing a
    /// run at solve time).
    pub fn from_env() -> Option<Precision> {
        std::env::var("PARAC_PRECISION").ok().and_then(|s| Precision::parse(&s).ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_conversions_are_the_identity() {
        for v in [0.0, -0.0, 1.5, f64::MIN_POSITIVE, 1e308, -3.25e-200] {
            assert_eq!(f64::from_f64(v).to_bits(), v.to_bits());
            assert_eq!(v.to_f64().to_bits(), v.to_bits());
        }
        assert_eq!(f64::BYTES, 8);
        assert_eq!(f64::NAME, "f64");
        assert_eq!(f64::PRECISION, Precision::F64);
    }

    #[test]
    fn f32_round_trips_through_f64_accumulation() {
        // Exactly representable values survive the round trip...
        for v in [0.0, 1.5, -2.0, 1024.25] {
            assert_eq!(f32::from_f64(v).to_f64(), v);
        }
        // ...inexact ones round, and overflow saturates to infinity
        // (the trigger the pcg fallback guard detects).
        assert!((f32::from_f64(0.1).to_f64() - 0.1).abs() > 0.0);
        assert!(f32::from_f64(1e300).to_f64().is_infinite());
        assert_eq!(f32::BYTES, 4);
        assert_eq!(f32::PRECISION, Precision::F32);
    }

    #[test]
    fn precision_parses_names_and_rejects_garbage() {
        assert_eq!(Precision::parse("f64").unwrap(), Precision::F64);
        assert_eq!(Precision::parse("F32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("double").unwrap(), Precision::F64);
        assert_eq!(Precision::parse("single").unwrap(), Precision::F32);
        assert_eq!(Precision::parse(" f32 ").unwrap(), Precision::F32);
        for p in [Precision::F64, Precision::F32] {
            assert_eq!(Precision::parse(p.name()).unwrap(), p);
        }
        let err = Precision::parse("f16").unwrap_err();
        match err {
            ParacError::InvalidOption { what, got } => {
                assert_eq!(what, "precision");
                assert_eq!(got, "f16");
            }
            other => panic!("wrong error: {other:?}"),
        }
        assert_eq!(Precision::default(), Precision::F64);
    }
}
