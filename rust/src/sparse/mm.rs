//! MatrixMarket coordinate-format IO (`%%MatrixMarket matrix coordinate
//! real general|symmetric`) — interop with SuiteSparse-style inputs.

use super::coo::Coo;
use super::csr::Csr;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Read a MatrixMarket file into CSR. Symmetric files are expanded to
/// both triangles.
pub fn read_matrix_market(path: &Path) -> Result<Csr, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("open {path:?}: {e}"))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = lines
        .next()
        .ok_or("empty file")?
        .map_err(|e| e.to_string())?;
    let h = header.to_ascii_lowercase();
    if !h.starts_with("%%matrixmarket matrix coordinate") {
        return Err(format!("unsupported header: {header}"));
    }
    let symmetric = h.contains("symmetric");
    if h.contains("complex") || h.contains("pattern") {
        return Err("complex/pattern matrices unsupported".into());
    }
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line.map_err(|e| e.to_string())?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or("missing size line")?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|s| s.parse().map_err(|e| format!("size parse: {e}")))
        .collect::<Result<_, _>>()?;
    if dims.len() != 3 {
        return Err("size line must be 'rows cols nnz'".into());
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);
    let mut coo = Coo::with_capacity(nrows, ncols, if symmetric { 2 * nnz } else { nnz });
    let mut read = 0usize;
    for line in lines {
        let line = line.map_err(|e| e.to_string())?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it.next().ok_or("row")?.parse().map_err(|e| format!("{e}"))?;
        let c: usize = it.next().ok_or("col")?.parse().map_err(|e| format!("{e}"))?;
        let v: f64 = it.next().map_or(Ok(1.0), |s| s.parse()).map_err(|e| format!("{e}"))?;
        if r == 0 || c == 0 || r > nrows || c > ncols {
            return Err(format!("index out of range: {r} {c}"));
        }
        let (r, c) = (r as u32 - 1, c as u32 - 1);
        if symmetric {
            coo.push_sym(r, c, v);
        } else {
            coo.push(r, c, v);
        }
        read += 1;
    }
    if read != nnz {
        return Err(format!("expected {nnz} entries, read {read}"));
    }
    Ok(coo.to_csr())
}

/// Write a CSR matrix in MatrixMarket format. If `symmetric`, only the
/// lower triangle is emitted (the matrix must actually be symmetric).
pub fn write_matrix_market(a: &Csr, path: &Path, symmetric: bool) -> Result<(), String> {
    let f = std::fs::File::create(path).map_err(|e| format!("create {path:?}: {e}"))?;
    let mut w = BufWriter::new(f);
    let kind = if symmetric { "symmetric" } else { "general" };
    let entries: Vec<(usize, u32, f64)> = (0..a.nrows)
        .flat_map(|r| {
            a.row_indices(r)
                .iter()
                .zip(a.row_data(r))
                .filter(move |(c, _)| !symmetric || (**c as usize) <= r)
                .map(move |(&c, &v)| (r, c, v))
        })
        .collect();
    writeln!(w, "%%MatrixMarket matrix coordinate real {kind}").map_err(|e| e.to_string())?;
    writeln!(w, "{} {} {}", a.nrows, a.ncols, entries.len()).map_err(|e| e.to_string())?;
    for (r, c, v) in entries {
        writeln!(w, "{} {} {:.17e}", r + 1, c + 1, v).map_err(|e| e.to_string())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn roundtrip_symmetric() {
        let lap = generators::grid2d(6, 5, generators::Coeff::Uniform, 1);
        let dir = std::env::temp_dir().join("parac_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("lap.mtx");
        write_matrix_market(&lap.matrix, &p, true).unwrap();
        let back = read_matrix_market(&p).unwrap();
        assert_eq!(back.nrows, lap.matrix.nrows);
        assert_eq!(back.nnz(), lap.matrix.nnz());
        for r in 0..back.nrows {
            assert_eq!(back.row_indices(r), lap.matrix.row_indices(r));
        }
    }

    #[test]
    fn roundtrip_general() {
        let mut coo = crate::sparse::Coo::new(3, 2);
        coo.push(0, 1, 2.0);
        coo.push(2, 0, -3.5);
        let a = coo.to_csr();
        let dir = std::env::temp_dir().join("parac_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("gen.mtx");
        write_matrix_market(&a, &p, false).unwrap();
        let back = read_matrix_market(&p).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("parac_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.mtx");
        std::fs::write(&p, "not a matrix\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
    }
}
