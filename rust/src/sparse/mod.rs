//! Sparse matrix substrate: COO / CSR / CSC / padded-ELL formats,
//! MatrixMarket IO, and the kernels (SpMV, SpGEMM, permutation,
//! transpose) the rest of the crate is built on.
//!
//! * [`Coo`] is the mutable builder format (generators assemble here,
//!   [`Coo::to_csr`] sorts/dedups/compresses).
//! * [`Csr`] is the primary operator format (SpMV, symmetric
//!   permutation, validation).
//! * [`Csc`] stores triangular-factor columns (strictly lower).
//! * [`Ell`] is the fixed-shape padded layout consumed by the
//!   AOT-compiled Pallas SpMV kernel.
//! * [`mm`] reads/writes MatrixMarket coordinate files; [`ops`] holds
//!   BLAS-1 helpers, Gustavson SpGEMM, and the small dense Cholesky used
//!   at the AMG coarsest level.
//!
//! Conventions:
//! * Row/column indices are `u32` (matrices up to 4·10⁹ rows — far beyond
//!   the paper's largest testcase). Values are `f64` in the assembly and
//!   operator formats; kernels that *stream* values (packed sweeps, SpMV,
//!   ELL) are generic over the [`scalar::Scalar`] storage layer (`f64` or
//!   `f32` storage, always f64 accumulation).
//! * Symmetric matrices are stored with **both** triangles unless a type
//!   says otherwise (`Csc` factor columns store strictly-lower entries).

pub mod coo;
pub mod csc;
pub mod csr;
pub mod ell;
pub mod mm;
pub mod ops;
pub mod scalar;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use ell::Ell;
pub use scalar::{Precision, Scalar};
