//! Sparse matrix substrate: COO / CSR / CSC / padded-ELL formats,
//! MatrixMarket IO, and the kernels (SpMV, SpGEMM, permutation,
//! transpose) the rest of the crate is built on.
//!
//! Conventions:
//! * Row/column indices are `u32` (matrices up to 4·10⁹ rows — far beyond
//!   the paper's largest testcase), values are `f64`.
//! * Symmetric matrices are stored with **both** triangles unless a type
//!   says otherwise (`Csc` factor columns store strictly-lower entries).

pub mod coo;
pub mod csc;
pub mod csr;
pub mod ell;
pub mod mm;
pub mod ops;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use ell::Ell;
