//! Triplet (COO) format — the mutable builder format.
//!
//! All generators assemble matrices as triplets; [`Coo::to_csr`] sorts,
//! deduplicates (summing duplicates) and compresses.

use super::csr::Csr;

/// A coordinate-format sparse matrix builder.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Row indices of entries.
    pub rows: Vec<u32>,
    /// Column indices of entries.
    pub cols: Vec<u32>,
    /// Entry values; duplicates are summed on conversion.
    pub vals: Vec<f64>,
}

impl Coo {
    /// An empty `nrows × ncols` builder.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    /// With pre-reserved capacity for `nnz` entries.
    pub fn with_capacity(nrows: usize, ncols: usize, nnz: usize) -> Self {
        Self {
            nrows,
            ncols,
            rows: Vec::with_capacity(nnz),
            cols: Vec::with_capacity(nnz),
            vals: Vec::with_capacity(nnz),
        }
    }

    /// Number of stored (pre-dedup) entries.
    pub fn nnz(&self) -> usize {
        self.rows.len()
    }

    /// Append one entry.
    #[inline]
    pub fn push(&mut self, r: u32, c: u32, v: f64) {
        debug_assert!((r as usize) < self.nrows && (c as usize) < self.ncols);
        self.rows.push(r);
        self.cols.push(c);
        self.vals.push(v);
    }

    /// Append both `(r,c,v)` and `(c,r,v)` — convenience for symmetric
    /// assembly from an edge list.
    #[inline]
    pub fn push_sym(&mut self, r: u32, c: u32, v: f64) {
        self.push(r, c, v);
        if r != c {
            self.push(c, r, v);
        }
    }

    /// Convert to CSR, summing duplicate entries and dropping exact zeros
    /// produced by cancellation only if `drop_zeros` is requested by the
    /// caller via [`Csr::drop_zeros`] afterwards (kept here for clarity).
    pub fn to_csr(&self) -> Csr {
        let n = self.nrows;
        // Counting sort by row.
        let mut row_counts = vec![0usize; n + 1];
        for &r in &self.rows {
            row_counts[r as usize + 1] += 1;
        }
        for i in 0..n {
            row_counts[i + 1] += row_counts[i];
        }
        let mut order: Vec<u32> = vec![0; self.nnz()];
        {
            let mut next = row_counts.clone();
            for (k, &r) in self.rows.iter().enumerate() {
                let slot = next[r as usize];
                order[slot] = k as u32;
                next[r as usize] += 1;
            }
        }
        // Per-row: sort by column, merge duplicates.
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices: Vec<u32> = Vec::with_capacity(self.nnz());
        let mut data: Vec<f64> = Vec::with_capacity(self.nnz());
        indptr.push(0usize);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for r in 0..n {
            scratch.clear();
            for &k in &order[row_counts[r]..row_counts[r + 1]] {
                scratch.push((self.cols[k as usize], self.vals[k as usize]));
            }
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < scratch.len() {
                let c = scratch[i].0;
                let mut v = scratch[i].1;
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                indices.push(c);
                data.push(v);
                i = j;
            }
            indptr.push(indices.len());
        }
        Csr { nrows: self.nrows, ncols: self.ncols, indptr, indices, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_sums_duplicates() {
        let mut c = Coo::new(3, 3);
        c.push(0, 1, 1.0);
        c.push(0, 1, 2.5);
        c.push(2, 0, -1.0);
        c.push(1, 1, 4.0);
        let m = c.to_csr();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.get(0, 1), 3.5);
        assert_eq!(m.get(2, 0), -1.0);
        assert_eq!(m.get(1, 1), 4.0);
        assert_eq!(m.get(0, 0), 0.0);
    }

    #[test]
    fn symmetric_push() {
        let mut c = Coo::new(4, 4);
        c.push_sym(0, 3, 2.0);
        c.push_sym(1, 1, 5.0); // diagonal: inserted once
        let m = c.to_csr();
        assert_eq!(m.get(0, 3), 2.0);
        assert_eq!(m.get(3, 0), 2.0);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn rows_sorted_by_column() {
        let mut c = Coo::new(2, 5);
        for &col in &[4u32, 0, 2, 3, 1] {
            c.push(0, col, col as f64);
        }
        let m = c.to_csr();
        let row: Vec<u32> = m.row_indices(0).to_vec();
        assert_eq!(row, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_matrix() {
        let c = Coo::new(5, 5);
        let m = c.to_csr();
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.indptr.len(), 6);
    }
}
