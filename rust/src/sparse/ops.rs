//! Vector/matrix kernels: BLAS-1 helpers, sparse matrix–matrix product
//! (Gustavson SpGEMM), and small dense Cholesky (AMG coarsest level).

use super::csr::Csr;

/// `y ← y + a·x`.
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Dot product.
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// Euclidean norm.
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Subtract the mean in place — projects onto the range of a connected
/// graph Laplacian (orthogonal complement of the constant nullspace).
pub fn project_mean_zero(x: &mut [f64]) {
    let m = x.iter().sum::<f64>() / x.len() as f64;
    for v in x.iter_mut() {
        *v -= m;
    }
}

/// Sparse × sparse (Gustavson row-wise SpGEMM): `C = A·B`.
pub fn spgemm(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.ncols, b.nrows);
    let n = a.nrows;
    let m = b.ncols;
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f64> = Vec::new();
    indptr.push(0usize);
    // Dense accumulator with a generation marker (SPA).
    let mut acc = vec![0.0f64; m];
    let mut mark = vec![u32::MAX; m];
    let mut cols_here: Vec<u32> = Vec::new();
    for r in 0..n {
        cols_here.clear();
        let gen = r as u32;
        for ka in a.indptr[r]..a.indptr[r + 1] {
            let av = a.data[ka];
            let arow = a.indices[ka] as usize;
            for kb in b.indptr[arow]..b.indptr[arow + 1] {
                let c = b.indices[kb] as usize;
                if mark[c] != gen {
                    mark[c] = gen;
                    acc[c] = 0.0;
                    cols_here.push(c as u32);
                }
                acc[c] += av * b.data[kb];
            }
        }
        cols_here.sort_unstable();
        for &c in &cols_here {
            indices.push(c);
            data.push(acc[c as usize]);
        }
        indptr.push(indices.len());
    }
    Csr { nrows: n, ncols: m, indptr, indices, data }
}

/// Galerkin triple product `Pᵀ A P` (AMG coarse operator).
pub fn rap(p: &Csr, a: &Csr) -> Csr {
    let pt = p.transpose();
    spgemm(&spgemm(&pt, a), p)
}

/// Dense Cholesky factorization in place: `A = L·Lᵀ`, lower triangle of
/// `a` (row-major `n×n`) is overwritten with `L`. Zero/negative pivots
/// (singular Laplacian coarse grids) are tolerated by pinning the pivot
/// row to identity — i.e. a pseudo-inverse-style solve.
pub fn dense_cholesky(a: &mut [f64], n: usize) {
    assert_eq!(a.len(), n * n);
    for k in 0..n {
        let mut d = a[k * n + k];
        for j in 0..k {
            d -= a[k * n + j] * a[k * n + j];
        }
        if d <= 1e-12 {
            // Singular pivot: pin (acts on the orthogonal complement).
            a[k * n + k] = 0.0;
            for i in (k + 1)..n {
                a[i * n + k] = 0.0;
            }
            continue;
        }
        let d = d.sqrt();
        a[k * n + k] = d;
        for i in (k + 1)..n {
            let mut v = a[i * n + k];
            for j in 0..k {
                v -= a[i * n + j] * a[k * n + j];
            }
            a[i * n + k] = v / d;
        }
    }
}

/// Solve `L·Lᵀ x = b` with `L` from [`dense_cholesky`] (zero pivots skip).
pub fn dense_cholesky_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let d = l[i * n + i];
        if d == 0.0 {
            y[i] = 0.0;
            continue;
        }
        let mut v = b[i];
        for j in 0..i {
            v -= l[i * n + j] * y[j];
        }
        y[i] = v / d;
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let d = l[i * n + i];
        if d == 0.0 {
            x[i] = 0.0;
            continue;
        }
        let mut v = y[i];
        for j in (i + 1)..n {
            v -= l[j * n + i] * x[j];
        }
        x[i] = v / d;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    #[test]
    fn blas1() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((nrm2(&x) - 14f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn projection_removes_mean() {
        let mut x = vec![1.0, 2.0, 3.0, 6.0];
        project_mean_zero(&mut x);
        assert!(x.iter().sum::<f64>().abs() < 1e-12);
    }

    #[test]
    fn spgemm_against_dense() {
        let mut ca = Coo::new(3, 4);
        ca.push(0, 0, 1.0);
        ca.push(0, 2, 2.0);
        ca.push(1, 1, 3.0);
        ca.push(2, 3, -1.0);
        let mut cb = Coo::new(4, 2);
        cb.push(0, 0, 1.0);
        cb.push(1, 1, 2.0);
        cb.push(2, 0, -1.0);
        cb.push(3, 1, 4.0);
        let a = ca.to_csr();
        let b = cb.to_csr();
        let c = spgemm(&a, &b);
        let ad = a.to_dense();
        let bd = b.to_dense();
        let cd = c.to_dense();
        for i in 0..3 {
            for j in 0..2 {
                let want: f64 = (0..4).map(|k| ad[i][k] * bd[k][j]).sum();
                assert!((cd[i][j] - want).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn spgemm_identity() {
        let mut c = Coo::new(3, 3);
        c.push_sym(0, 1, -1.0);
        c.push(0, 0, 1.0);
        c.push(1, 1, 2.0);
        c.push(2, 2, 3.0);
        let a = c.to_csr();
        let i = Csr::eye(3);
        assert_eq!(spgemm(&a, &i).to_dense(), a.to_dense());
        assert_eq!(spgemm(&i, &a).to_dense(), a.to_dense());
    }

    #[test]
    fn dense_chol_solves_spd() {
        // SPD 3x3.
        let mut a = vec![4.0, 1.0, 0.0, 1.0, 3.0, 1.0, 0.0, 1.0, 2.0];
        let orig = a.clone();
        dense_cholesky(&mut a, 3);
        let b = vec![1.0, 2.0, 3.0];
        let x = dense_cholesky_solve(&a, 3, &b);
        // Check A x = b.
        for i in 0..3 {
            let got: f64 = (0..3).map(|j| orig[i * 3 + j] * x[j]).sum();
            assert!((got - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn dense_chol_singular_laplacian() {
        // 2x2 Laplacian [[1,-1],[-1,1]] — singular; solve must not NaN.
        let mut a = vec![1.0, -1.0, -1.0, 1.0];
        dense_cholesky(&mut a, 2);
        let x = dense_cholesky_solve(&a, 2, &[1.0, -1.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
