//! Vector/matrix kernels: BLAS-1 helpers, the fused PCG vector passes,
//! sparse matrix–matrix product (Gustavson SpGEMM), and small dense
//! Cholesky (AMG coarsest level).
//!
//! The `fused_*` kernels exist because PCG's per-iteration cost on a
//! well-preconditioned system is dominated by streaming full-length
//! vectors through memory, not by flops: fusing the α-update of `x` and
//! `r` with the residual norm, and folding the mean-zero projection
//! into the dot/search-direction passes, roughly halves the number of
//! full-vector passes per iteration. Every fusion preserves the
//! element-wise operation sequence of the unfused kernels exactly —
//! same operands, same order — so results are **bit-identical** (IEEE
//! 754 has no reassociation here; pinned by the parity test in
//! `crate::solve::pcg`).

use super::csr::Csr;

/// `y ← y + a·x`.
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Dot product.
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// Euclidean norm.
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Subtract the mean in place — projects onto the range of a connected
/// graph Laplacian (orthogonal complement of the constant nullspace).
pub fn project_mean_zero(x: &mut [f64]) {
    let m = mean(x);
    for v in x.iter_mut() {
        *v -= m;
    }
}

/// Arithmetic mean (the exact expression [`project_mean_zero`]
/// subtracts — callers that fold the projection into a later pass must
/// use this so the fused and unfused paths stay bit-identical).
pub fn mean(x: &[f64]) -> f64 {
    x.iter().sum::<f64>() / x.len() as f64
}

/// Fused PCG α-update: `x ← x + α·p` and `r ← r − α·ap` in one pass
/// (bit-identical to `axpy(α, p, x); axpy(−α, ap, r)` — IEEE 754
/// negation commutes with multiplication exactly).
pub fn fused_axpy2(alpha: f64, p: &[f64], ap: &[f64], x: &mut [f64], r: &mut [f64]) {
    debug_assert_eq!(p.len(), x.len());
    debug_assert_eq!(ap.len(), r.len());
    debug_assert_eq!(x.len(), r.len());
    for i in 0..x.len() {
        x[i] += alpha * p[i];
        r[i] -= alpha * ap[i];
    }
}

/// [`fused_axpy2`] plus the squared residual norm `Σ rᵢ²` accumulated
/// in the same pass (each `rᵢ` is final before it is squared, in
/// ascending order — bit-identical to a separate [`dot`]`(r, r)`).
/// For the unprojected PCG iteration: three passes become one.
pub fn fused_axpy2_nrm2sq(alpha: f64, p: &[f64], ap: &[f64], x: &mut [f64], r: &mut [f64]) -> f64 {
    debug_assert_eq!(p.len(), x.len());
    debug_assert_eq!(ap.len(), r.len());
    debug_assert_eq!(x.len(), r.len());
    let mut acc = 0.0;
    for i in 0..x.len() {
        x[i] += alpha * p[i];
        let ri = r[i] - alpha * ap[i];
        r[i] = ri;
        acc += ri * ri;
    }
    acc
}

/// Fused projection + squared norm: `r ← r − mean(r)` and `Σ rᵢ²` in
/// one subtract-and-square pass (bit-identical to
/// [`project_mean_zero`]`(r)` followed by [`dot`]`(r, r)`).
pub fn fused_project_nrm2sq(r: &mut [f64]) -> f64 {
    let m = mean(r);
    let mut acc = 0.0;
    for v in r.iter_mut() {
        *v -= m;
        acc += *v * *v;
    }
    acc
}

/// Dot product against a *virtually projected* vector:
/// `Σ rᵢ·(zᵢ − mz)` without materializing the projection — `z` is left
/// untouched. With `mz = mean(z)` this is bit-identical to
/// `project_mean_zero(z); dot(r, z)`; with `mz = 0.0` it is exactly
/// [`dot`] (IEEE: `x − 0.0 ≡ x`).
pub fn fused_project_dot(r: &[f64], z: &[f64], mz: f64) -> f64 {
    debug_assert_eq!(r.len(), z.len());
    let mut acc = 0.0;
    for (&ri, &zi) in r.iter().zip(z) {
        acc += ri * (zi - mz);
    }
    acc
}

/// Fused search-direction update: `pᵢ ← (zᵢ − mz) + β·pᵢ` — the
/// mean-zero projection of `z` folded into the `p = z + βp` pass, `z`
/// untouched (it is dead after this point in the PCG iteration, so the
/// projection is never materialized at all).
pub fn fused_search_dir(z: &[f64], mz: f64, beta: f64, p: &mut [f64]) {
    debug_assert_eq!(z.len(), p.len());
    for (pi, &zi) in p.iter_mut().zip(z) {
        *pi = (zi - mz) + beta * *pi;
    }
}

/// Fused initial search direction: `pᵢ ← zᵢ − mz` and `Σ rᵢ·pᵢ` in one
/// pass (bit-identical to `project_mean_zero(z); p.copy_from_slice(z);
/// dot(r, z)`).
pub fn fused_init_dir(z: &[f64], mz: f64, r: &[f64], p: &mut [f64]) -> f64 {
    debug_assert_eq!(z.len(), p.len());
    debug_assert_eq!(z.len(), r.len());
    let mut acc = 0.0;
    for i in 0..z.len() {
        let zi = z[i] - mz;
        p[i] = zi;
        acc += r[i] * zi;
    }
    acc
}

/// Sparse × sparse (Gustavson row-wise SpGEMM): `C = A·B`.
pub fn spgemm(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.ncols, b.nrows);
    let n = a.nrows;
    let m = b.ncols;
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f64> = Vec::new();
    indptr.push(0usize);
    // Dense accumulator with a generation marker (SPA).
    let mut acc = vec![0.0f64; m];
    let mut mark = vec![u32::MAX; m];
    let mut cols_here: Vec<u32> = Vec::new();
    for r in 0..n {
        cols_here.clear();
        let gen = r as u32;
        for ka in a.indptr[r]..a.indptr[r + 1] {
            let av = a.data[ka];
            let arow = a.indices[ka] as usize;
            for kb in b.indptr[arow]..b.indptr[arow + 1] {
                let c = b.indices[kb] as usize;
                if mark[c] != gen {
                    mark[c] = gen;
                    acc[c] = 0.0;
                    cols_here.push(c as u32);
                }
                acc[c] += av * b.data[kb];
            }
        }
        cols_here.sort_unstable();
        for &c in &cols_here {
            indices.push(c);
            data.push(acc[c as usize]);
        }
        indptr.push(indices.len());
    }
    Csr { nrows: n, ncols: m, indptr, indices, data }
}

/// Galerkin triple product `Pᵀ A P` (AMG coarse operator).
pub fn rap(p: &Csr, a: &Csr) -> Csr {
    let pt = p.transpose();
    spgemm(&spgemm(&pt, a), p)
}

/// Dense Cholesky factorization in place: `A = L·Lᵀ`, lower triangle of
/// `a` (row-major `n×n`) is overwritten with `L`. Zero/negative pivots
/// (singular Laplacian coarse grids) are tolerated by pinning the pivot
/// row to identity — i.e. a pseudo-inverse-style solve.
pub fn dense_cholesky(a: &mut [f64], n: usize) {
    assert_eq!(a.len(), n * n);
    for k in 0..n {
        let mut d = a[k * n + k];
        for j in 0..k {
            d -= a[k * n + j] * a[k * n + j];
        }
        if d <= 1e-12 {
            // Singular pivot: pin (acts on the orthogonal complement).
            a[k * n + k] = 0.0;
            for i in (k + 1)..n {
                a[i * n + k] = 0.0;
            }
            continue;
        }
        let d = d.sqrt();
        a[k * n + k] = d;
        for i in (k + 1)..n {
            let mut v = a[i * n + k];
            for j in 0..k {
                v -= a[i * n + j] * a[k * n + j];
            }
            a[i * n + k] = v / d;
        }
    }
}

/// Solve `L·Lᵀ x = b` with `L` from [`dense_cholesky`] (zero pivots skip).
pub fn dense_cholesky_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let d = l[i * n + i];
        if d == 0.0 {
            y[i] = 0.0;
            continue;
        }
        let mut v = b[i];
        for j in 0..i {
            v -= l[i * n + j] * y[j];
        }
        y[i] = v / d;
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let d = l[i * n + i];
        if d == 0.0 {
            x[i] = 0.0;
            continue;
        }
        let mut v = y[i];
        for j in (i + 1)..n {
            v -= l[j * n + i] * x[j];
        }
        x[i] = v / d;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    #[test]
    fn blas1() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((nrm2(&x) - 14f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn projection_removes_mean() {
        let mut x = vec![1.0, 2.0, 3.0, 6.0];
        project_mean_zero(&mut x);
        assert!(x.iter().sum::<f64>().abs() < 1e-12);
    }

    /// Awkward values (denormals-adjacent magnitudes, negative zeros,
    /// near-cancellations) for the bit-identity checks below.
    fn gnarly(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = crate::rng::Rng::new(seed);
        (0..n)
            .map(|i| {
                let v = rng.next_normal() * 10f64.powi((i % 7) as i32 - 3);
                if i % 11 == 0 {
                    -0.0
                } else {
                    v
                }
            })
            .collect()
    }

    #[test]
    fn fused_alpha_update_is_bit_identical() {
        let (p, ap) = (gnarly(1, 257), gnarly(2, 257));
        let alpha = 0.731_532_9;
        for project in [true, false] {
            let mut x0 = gnarly(3, 257);
            let mut r0 = gnarly(4, 257);
            let mut x1 = x0.clone();
            let mut r1 = r0.clone();
            // Unfused reference: two axpys, then project/norm.
            axpy(alpha, &p, &mut x0);
            axpy(-alpha, &ap, &mut r0);
            let want = if project {
                project_mean_zero(&mut r0);
                nrm2(&r0)
            } else {
                nrm2(&r0)
            };
            let got = if project {
                fused_axpy2(alpha, &p, &ap, &mut x1, &mut r1);
                fused_project_nrm2sq(&mut r1).sqrt()
            } else {
                fused_axpy2_nrm2sq(alpha, &p, &ap, &mut x1, &mut r1).sqrt()
            };
            assert_eq!(x0, x1, "project={project}");
            assert_eq!(r0, r1, "project={project}");
            assert!(want.to_bits() == got.to_bits(), "project={project}: {want} vs {got}");
        }
    }

    #[test]
    fn fused_projection_folding_is_bit_identical() {
        let r = gnarly(5, 193);
        let z = gnarly(6, 193);
        let beta = -0.234_567;
        for project in [true, false] {
            // Unfused reference materializes the projected z.
            let mut zp = z.clone();
            let mz = if project {
                let m = mean(&zp);
                project_mean_zero(&mut zp);
                m
            } else {
                0.0
            };
            let want_dot = dot(&r, &zp);
            assert_eq!(want_dot.to_bits(), fused_project_dot(&r, &z, mz).to_bits());

            let mut p0 = gnarly(7, 193);
            let mut p1 = p0.clone();
            for (pi, zi) in p0.iter_mut().zip(zp.iter()) {
                *pi = zi + beta * *pi;
            }
            fused_search_dir(&z, mz, beta, &mut p1);
            assert_eq!(p0, p1, "project={project}");

            let mut d0 = vec![0.0; r.len()];
            let mut d1 = vec![f64::NAN; r.len()];
            d0.copy_from_slice(&zp);
            let want_rz = dot(&r, &d0);
            let got_rz = fused_init_dir(&z, mz, &r, &mut d1);
            assert_eq!(d0, d1, "project={project}");
            assert_eq!(want_rz.to_bits(), got_rz.to_bits(), "project={project}");
        }
    }

    #[test]
    fn spgemm_against_dense() {
        let mut ca = Coo::new(3, 4);
        ca.push(0, 0, 1.0);
        ca.push(0, 2, 2.0);
        ca.push(1, 1, 3.0);
        ca.push(2, 3, -1.0);
        let mut cb = Coo::new(4, 2);
        cb.push(0, 0, 1.0);
        cb.push(1, 1, 2.0);
        cb.push(2, 0, -1.0);
        cb.push(3, 1, 4.0);
        let a = ca.to_csr();
        let b = cb.to_csr();
        let c = spgemm(&a, &b);
        let ad = a.to_dense();
        let bd = b.to_dense();
        let cd = c.to_dense();
        for i in 0..3 {
            for j in 0..2 {
                let want: f64 = (0..4).map(|k| ad[i][k] * bd[k][j]).sum();
                assert!((cd[i][j] - want).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn spgemm_identity() {
        let mut c = Coo::new(3, 3);
        c.push_sym(0, 1, -1.0);
        c.push(0, 0, 1.0);
        c.push(1, 1, 2.0);
        c.push(2, 2, 3.0);
        let a = c.to_csr();
        let i = Csr::eye(3);
        assert_eq!(spgemm(&a, &i).to_dense(), a.to_dense());
        assert_eq!(spgemm(&i, &a).to_dense(), a.to_dense());
    }

    #[test]
    fn dense_chol_solves_spd() {
        // SPD 3x3.
        let mut a = vec![4.0, 1.0, 0.0, 1.0, 3.0, 1.0, 0.0, 1.0, 2.0];
        let orig = a.clone();
        dense_cholesky(&mut a, 3);
        let b = vec![1.0, 2.0, 3.0];
        let x = dense_cholesky_solve(&a, 3, &b);
        // Check A x = b.
        for i in 0..3 {
            let got: f64 = (0..3).map(|j| orig[i * 3 + j] * x[j]).sum();
            assert!((got - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn dense_chol_singular_laplacian() {
        // 2x2 Laplacian [[1,-1],[-1,1]] — singular; solve must not NaN.
        let mut a = vec![1.0, -1.0, -1.0, 1.0];
        dense_cholesky(&mut a, 2);
        let x = dense_cholesky_solve(&a, 2, &[1.0, -1.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
