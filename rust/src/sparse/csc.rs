//! Compressed sparse column format — used for triangular factors.
//!
//! The randomized factorization produces columns of `G` one at a time, so
//! CSC is the natural output layout. `Csc` here stores the **strictly
//! lower** part of a unit-lower-triangular factor (the implicit unit
//! diagonal is not stored), matching how [`crate::factor::LdlFactor`]
//! consumes it.

use super::csr::Csr;

/// A CSC sparse matrix (column-major compressed).
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Column pointer, length `ncols + 1`.
    pub colptr: Vec<usize>,
    /// Row indices, sorted within each column.
    pub rowidx: Vec<u32>,
    /// Values, parallel to `rowidx`.
    pub data: Vec<f64>,
}

impl Csc {
    /// An `n × n` zero matrix.
    pub fn zero(n: usize) -> Self {
        Self { nrows: n, ncols: n, colptr: vec![0; n + 1], rowidx: Vec::new(), data: Vec::new() }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.rowidx.len()
    }

    /// Row indices of column `c`.
    #[inline]
    pub fn col_rows(&self, c: usize) -> &[u32] {
        &self.rowidx[self.colptr[c]..self.colptr[c + 1]]
    }

    /// Values of column `c`.
    #[inline]
    pub fn col_data(&self, c: usize) -> &[f64] {
        &self.data[self.colptr[c]..self.colptr[c + 1]]
    }

    /// Reinterpret as CSR of the transpose (zero-copy: CSC of A is CSR of
    /// Aᵀ).
    pub fn transpose_view_csr(self) -> Csr {
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr: self.colptr,
            indices: self.rowidx,
            data: self.data,
        }
    }

    /// Materialize as CSR of the same matrix: a direct counting
    /// transpose from the borrowed CSC arrays — no intermediate copy of
    /// the input is made, so the peak footprint is the input plus the
    /// output. Column indices come out sorted within each row because
    /// columns are scattered in ascending order.
    pub fn to_csr(&self) -> Csr {
        let mut counts = vec![0usize; self.nrows + 1];
        for &r in &self.rowidx {
            counts[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            counts[i + 1] += counts[i];
        }
        let mut cursor = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        for c in 0..self.ncols {
            for k in self.colptr[c]..self.colptr[c + 1] {
                let slot = cursor[self.rowidx[k] as usize];
                indices[slot] = c as u32;
                data[slot] = self.data[k];
                cursor[self.rowidx[k] as usize] += 1;
            }
        }
        Csr { nrows: self.nrows, ncols: self.ncols, indptr: counts, indices, data }
    }

    /// [`Csc::to_csr`] that also records provenance: returns `(a, src)`
    /// with `a.data[i] == self.data[src[i]]`. Used by the packed sweep
    /// executor to refill row-major copies of a refactorized column
    /// factor without re-running the transpose.
    pub fn to_csr_with_src(&self) -> (Csr, Vec<usize>) {
        let mut counts = vec![0usize; self.nrows + 1];
        for &r in &self.rowidx {
            counts[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            counts[i + 1] += counts[i];
        }
        let mut cursor = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        let mut src = vec![0usize; self.nnz()];
        for c in 0..self.ncols {
            for k in self.colptr[c]..self.colptr[c + 1] {
                let slot = cursor[self.rowidx[k] as usize];
                indices[slot] = c as u32;
                data[slot] = self.data[k];
                src[slot] = k;
                cursor[self.rowidx[k] as usize] += 1;
            }
        }
        (Csr { nrows: self.nrows, ncols: self.ncols, indptr: counts, indices, data }, src)
    }

    /// Build from CSR.
    pub fn from_csr(a: &Csr) -> Csc {
        let t = a.transpose();
        Csc { nrows: a.nrows, ncols: a.ncols, colptr: t.indptr, rowidx: t.indices, data: t.data }
    }

    /// Structural validation (sorted rows per column, bounds, monotone
    /// colptr).
    pub fn validate(&self) -> Result<(), String> {
        if self.colptr.len() != self.ncols + 1 {
            return Err("colptr length".into());
        }
        if *self.colptr.last().unwrap() != self.rowidx.len() || self.colptr[0] != 0 {
            return Err("colptr ends".into());
        }
        for c in 0..self.ncols {
            if self.colptr[c] > self.colptr[c + 1] {
                return Err(format!("colptr not monotone at {c}"));
            }
            let rows = self.col_rows(c);
            for w in rows.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("col {c} not strictly sorted"));
                }
            }
            if let Some(&r) = rows.last() {
                if r as usize >= self.nrows {
                    return Err(format!("row out of range in col {c}"));
                }
            }
        }
        Ok(())
    }

    /// Check strict lower-triangularity (all row indices > column index) —
    /// the invariant of factor storage.
    pub fn is_strictly_lower(&self) -> bool {
        (0..self.ncols).all(|c| self.col_rows(c).iter().all(|&r| (r as usize) > c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn sample_csr() -> Csr {
        let mut c = Coo::new(3, 4);
        c.push(0, 0, 1.0);
        c.push(0, 3, 2.0);
        c.push(1, 1, 3.0);
        c.push(2, 0, 4.0);
        c.push(2, 2, 5.0);
        c.to_csr()
    }

    #[test]
    fn csr_csc_roundtrip() {
        let a = sample_csr();
        let csc = Csc::from_csr(&a);
        assert_eq!(csc.to_csr(), a);
        csc.validate().unwrap();
    }

    #[test]
    fn column_access() {
        let a = sample_csr();
        let csc = Csc::from_csr(&a);
        assert_eq!(csc.col_rows(0), &[0, 2]);
        assert_eq!(csc.col_data(0), &[1.0, 4.0]);
        assert_eq!(csc.col_rows(3), &[0]);
    }

    #[test]
    fn strictly_lower_check() {
        let mut c = Coo::new(3, 3);
        c.push(1, 0, 1.0);
        c.push(2, 1, 1.0);
        let l = Csc::from_csr(&c.to_csr());
        assert!(l.is_strictly_lower());
        let mut c2 = Coo::new(3, 3);
        c2.push(0, 0, 1.0);
        assert!(!Csc::from_csr(&c2.to_csr()).is_strictly_lower());
    }
}
