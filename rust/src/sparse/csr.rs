//! Compressed sparse row format — the crate's primary operator format.

use crate::sparse::scalar::Scalar;

/// Rows below which [`Csr::spmv_par`] runs the sequential kernel —
/// pool-dispatch latency would dominate the arithmetic.
pub const PAR_SPMV_CUTOFF: usize = 1024;

/// A CSR sparse matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Row pointer, length `nrows + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    pub indices: Vec<u32>,
    /// Values, parallel to `indices`.
    pub data: Vec<f64>,
}

impl Csr {
    /// An `n × n` zero matrix.
    pub fn zero(n: usize) -> Self {
        Self { nrows: n, ncols: n, indptr: vec![0; n + 1], indices: Vec::new(), data: Vec::new() }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self {
            nrows: n,
            ncols: n,
            indptr: (0..=n).collect(),
            indices: (0..n as u32).collect(),
            data: vec![1.0; n],
        }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_data(&self, r: usize) -> &[f64] {
        &self.data[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Entry lookup by binary search (O(log nnz-per-row)).
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let cols = self.row_indices(r);
        match cols.binary_search(&(c as u32)) {
            Ok(k) => self.row_data(r)[k],
            Err(_) => 0.0,
        }
    }

    /// `y = A·x`.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.spmv_with(&self.data, x, y);
    }

    /// `y = A·x` with the matrix **values** supplied externally in any
    /// [`Scalar`] storage plane (`vals` parallel to `self.indices`,
    /// e.g. from [`Csr::values_as`]). Accumulation is f64 regardless
    /// of storage — with `vals = &self.data` this *is* [`Csr::spmv`]
    /// bit for bit; with f32 values the streamed matrix bytes halve.
    pub fn spmv_with<S: Scalar>(&self, vals: &[S], x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(vals.len(), self.nnz());
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        for r in 0..self.nrows {
            let mut acc = 0.0;
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            for k in lo..hi {
                acc += vals[k].to_f64() * x[self.indices[k] as usize];
            }
            y[r] = acc;
        }
    }

    /// `y = A·x` split by contiguous row ranges across up to `threads`
    /// workers of the persistent [`crate::par`] pool. Bit-identical to
    /// [`Csr::spmv`]: every row's dot product is computed by exactly
    /// one part with the same accumulation order, only the row ranges
    /// are distributed. Falls back to the sequential kernel below
    /// [`PAR_SPMV_CUTOFF`] rows or with `threads <= 1`. Allocation-free
    /// (the dispatch borrows the closure from this stack frame).
    pub fn spmv_par(&self, x: &[f64], y: &mut [f64], threads: usize) {
        self.spmv_with_par(&self.data, x, y, threads);
    }

    /// [`Csr::spmv_par`] over externally supplied values in any
    /// [`Scalar`] storage plane — the same row-split pool dispatch,
    /// same per-row f64 accumulation order, so within one plane the
    /// result is bit-identical to [`Csr::spmv_with`] at any thread
    /// count.
    pub fn spmv_with_par<S: Scalar>(&self, vals: &[S], x: &[f64], y: &mut [f64], threads: usize) {
        debug_assert_eq!(vals.len(), self.nnz());
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        if threads <= 1 || self.nrows < PAR_SPMV_CUTOFF {
            return self.spmv_with(vals, x, y);
        }
        let yptr = crate::par::SendPtr::new(y.as_mut_ptr());
        crate::par::global().run(threads, |part, parts| {
            let (lo, hi) = crate::par::chunk_range(self.nrows, part, parts);
            for r in lo..hi {
                let mut acc = 0.0;
                for k in self.indptr[r]..self.indptr[r + 1] {
                    acc += vals[k].to_f64() * x[self.indices[k] as usize];
                }
                // SAFETY: row ranges are disjoint across parts and `y`
                // outlives the (blocking) dispatch.
                unsafe { yptr.write(r, acc) };
            }
        });
    }

    /// The value array narrowed into storage plane `S` (parallel to
    /// `self.indices`), for use with [`Csr::spmv_with`] /
    /// [`Csr::spmv_with_par`]. For `S = f64` this is a plain copy.
    pub fn values_as<S: Scalar>(&self) -> Vec<S> {
        self.data.iter().map(|&v| S::from_f64(v)).collect()
    }

    /// Allocating SpMV convenience.
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.nrows];
        self.spmv(x, &mut y);
        y
    }

    /// Transpose (also converts CSR↔CSC interpretation).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.ncols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            counts[i + 1] += counts[i];
        }
        let mut indptr = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        for r in 0..self.nrows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                let c = self.indices[k] as usize;
                let slot = indptr[c];
                indices[slot] = r as u32;
                data[slot] = self.data[k];
                indptr[c] += 1;
            }
        }
        // indptr has been advanced by one row's worth; rebuild from counts.
        Csr { nrows: self.ncols, ncols: self.nrows, indptr: counts, indices, data }
    }

    /// Extract the diagonal.
    pub fn diag(&self) -> Vec<f64> {
        let n = self.nrows.min(self.ncols);
        (0..n).map(|i| self.get(i, i)).collect()
    }

    /// Symmetric permutation `P A Pᵀ`: entry `(i,j)` moves to
    /// `(perm[i], perm[j])` where `perm` maps old index → new index.
    /// Direct CSR construction (no triplet materialization): row counts
    /// are a permutation of the input's, entries scatter then sort
    /// within rows.
    pub fn permute_sym(&self, perm: &[u32]) -> Csr {
        assert_eq!(self.nrows, self.ncols);
        assert_eq!(perm.len(), self.nrows);
        let n = self.nrows;
        let mut indptr = vec![0usize; n + 1];
        for r in 0..n {
            indptr[perm[r] as usize + 1] = self.indptr[r + 1] - self.indptr[r];
        }
        for i in 0..n {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0f64; self.nnz()];
        for r in 0..n {
            let dst = indptr[perm[r] as usize];
            let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
            for (off, k) in (lo..hi).enumerate() {
                indices[dst + off] = perm[self.indices[k] as usize];
                data[dst + off] = self.data[k];
            }
        }
        // Per-row sort by column (rows are permutations of sorted rows).
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for i in 0..n {
            let (lo, hi) = (indptr[i], indptr[i + 1]);
            if hi - lo > 1 {
                scratch.clear();
                scratch.extend(indices[lo..hi].iter().copied().zip(data[lo..hi].iter().copied()));
                scratch.sort_unstable_by_key(|&(c, _)| c);
                for (off, &(c, v)) in scratch.iter().enumerate() {
                    indices[lo + off] = c;
                    data[lo + off] = v;
                }
            }
        }
        Csr { nrows: n, ncols: n, indptr, indices, data }
    }

    /// [`Csr::permute_sym`] that also records where each permuted entry
    /// came from: returns `(p, map)` with `p.data[i] ==
    /// self.data[map[i]]`. A later value refresh on the same pattern is
    /// then a plain gather (`p.data[i] = new_data[map[i]]`) with no
    /// re-permutation — the symbolic/numeric split's value path.
    pub fn permute_sym_map(&self, perm: &[u32]) -> (Csr, Vec<usize>) {
        assert_eq!(self.nrows, self.ncols);
        assert_eq!(perm.len(), self.nrows);
        let n = self.nrows;
        let mut indptr = vec![0usize; n + 1];
        for r in 0..n {
            indptr[perm[r] as usize + 1] = self.indptr[r + 1] - self.indptr[r];
        }
        for i in 0..n {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0f64; self.nnz()];
        let mut map = vec![0usize; self.nnz()];
        for r in 0..n {
            let dst = indptr[perm[r] as usize];
            let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
            for (off, k) in (lo..hi).enumerate() {
                indices[dst + off] = perm[self.indices[k] as usize];
                data[dst + off] = self.data[k];
                map[dst + off] = k;
            }
        }
        let mut scratch: Vec<(u32, f64, usize)> = Vec::new();
        for i in 0..n {
            let (lo, hi) = (indptr[i], indptr[i + 1]);
            if hi - lo > 1 {
                scratch.clear();
                for off in lo..hi {
                    scratch.push((indices[off], data[off], map[off]));
                }
                scratch.sort_unstable_by_key(|&(c, _, _)| c);
                for (off, &(c, v, k)) in scratch.iter().enumerate() {
                    indices[lo + off] = c;
                    data[lo + off] = v;
                    map[lo + off] = k;
                }
            }
        }
        (Csr { nrows: n, ncols: n, indptr, indices, data }, map)
    }

    /// Structural + numerical symmetry check (tolerance `tol`).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        let t = self.transpose();
        if t.indptr != self.indptr || t.indices != self.indices {
            return false;
        }
        self.data.iter().zip(&t.data).all(|(a, b)| (a - b).abs() <= tol)
    }

    /// Drop entries with `|v| <= tol` (pruning exact-zero cancellations).
    pub fn drop_zeros(&self, tol: f64) -> Csr {
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut data = Vec::with_capacity(self.nnz());
        indptr.push(0);
        for r in 0..self.nrows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                if self.data[k].abs() > tol {
                    indices.push(self.indices[k]);
                    data.push(self.data[k]);
                }
            }
            indptr.push(indices.len());
        }
        Csr { nrows: self.nrows, ncols: self.ncols, indptr, indices, data }
    }

    /// Lower triangle (strict if `strict`), as CSR.
    pub fn tril(&self, strict: bool) -> Csr {
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for r in 0..self.nrows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                let c = self.indices[k] as usize;
                if c < r || (!strict && c == r) {
                    indices.push(self.indices[k]);
                    data.push(self.data[k]);
                }
            }
            indptr.push(indices.len());
        }
        Csr { nrows: self.nrows, ncols: self.ncols, indptr, indices, data }
    }

    /// Dense conversion (testing helper; panics on big matrices).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        assert!(self.nrows * self.ncols <= 1 << 22, "to_dense is a testing helper");
        let mut d = vec![vec![0.0; self.ncols]; self.nrows];
        for r in 0..self.nrows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                d[r][self.indices[k] as usize] += self.data[k];
            }
        }
        d
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Validate structural invariants (sorted unique columns per row,
    /// in-range indices, monotone indptr). Used by property tests.
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.nrows + 1 {
            return Err("indptr length".into());
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() != self.indices.len() {
            return Err("indptr ends".into());
        }
        if self.indices.len() != self.data.len() {
            return Err("indices/data length mismatch".into());
        }
        // Bounds/monotonicity first — row access below must be safe.
        for r in 0..self.nrows {
            if self.indptr[r] > self.indptr[r + 1] || self.indptr[r + 1] > self.indices.len() {
                return Err(format!("indptr not monotone/bounded at {r}"));
            }
        }
        for r in 0..self.nrows {
            let cols = self.row_indices(r);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} not strictly sorted"));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.ncols {
                    return Err(format!("column out of range in row {r}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn small() -> Csr {
        // [2 -1 0; -1 2 -1; 0 -1 2]
        let mut c = Coo::new(3, 3);
        for i in 0..3 {
            c.push(i, i, 2.0);
        }
        c.push_sym(0, 1, -1.0);
        c.push_sym(1, 2, -1.0);
        c.to_csr()
    }

    #[test]
    fn spmv_tridiag() {
        let a = small();
        let y = a.mul_vec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![0.0, 0.0, 4.0]);
    }

    #[test]
    fn spmv_par_matches_sequential_bitwise() {
        // Path Laplacian big enough to clear the parallel cutoff.
        let n = 2 * PAR_SPMV_CUTOFF;
        let mut c = Coo::new(n, n);
        for i in 0..n {
            c.push(i as u32, i as u32, 2.0);
        }
        for i in 0..n - 1 {
            c.push_sym(i as u32, (i + 1) as u32, -(1.0 + (i % 3) as f64));
        }
        let a = c.to_csr();
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut seq = vec![0.0; n];
        a.spmv(&x, &mut seq);
        let mut par = vec![f64::NAN; n];
        a.spmv_par(&x, &mut par, 4);
        assert_eq!(seq, par, "row-split SpMV must be bit-identical");
        // Sequential fallback (threads = 1) also overwrites fully.
        let mut one = vec![f64::NAN; n];
        a.spmv_par(&x, &mut one, 1);
        assert_eq!(seq, one);
    }

    #[test]
    fn spmv_with_planes_share_the_row_split_kernel() {
        // Same matrix as the bitwise test above, exercised through the
        // scalar-storage layer: the f64 plane is bit-identical to the
        // classic kernel, and the f32 plane is thread-invariant within
        // itself (same accumulation order, only the values rounded).
        let n = 2 * PAR_SPMV_CUTOFF;
        let mut c = Coo::new(n, n);
        for i in 0..n {
            c.push(i as u32, i as u32, 2.0);
        }
        for i in 0..n - 1 {
            c.push_sym(i as u32, (i + 1) as u32, -(1.0 + (i % 3) as f64 * 0.1));
        }
        let a = c.to_csr();
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut want = vec![0.0; n];
        a.spmv(&x, &mut want);

        let v64 = a.values_as::<f64>();
        let mut y64 = vec![f64::NAN; n];
        a.spmv_with_par(&v64, &x, &mut y64, 4);
        assert_eq!(want, y64, "f64 plane must match spmv bit for bit");

        let v32 = a.values_as::<f32>();
        let mut y32 = vec![f64::NAN; n];
        a.spmv_with(&v32, &x, &mut y32);
        let mut y32p = vec![f64::NAN; n];
        a.spmv_with_par(&v32, &x, &mut y32p, 4);
        assert_eq!(y32, y32p, "f32 plane must be thread-invariant");
        for (w, y) in want.iter().zip(&y32) {
            assert!((w - y).abs() <= 1e-4 * (1.0 + w.abs()), "f32 plane drifted: {w} vs {y}");
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = small();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn symmetric_detection() {
        let a = small();
        assert!(a.is_symmetric(0.0));
        let mut c = Coo::new(2, 2);
        c.push(0, 1, 1.0);
        assert!(!c.to_csr().is_symmetric(0.0));
    }

    #[test]
    fn permute_identity_is_noop() {
        let a = small();
        let p: Vec<u32> = (0..3).collect();
        assert_eq!(a.permute_sym(&p), a);
    }

    #[test]
    fn permute_reversal() {
        let a = small();
        let p = vec![2u32, 1, 0];
        let b = a.permute_sym(&p);
        assert_eq!(b.get(0, 0), a.get(2, 2));
        assert_eq!(b.get(0, 1), a.get(2, 1));
        assert!(b.is_symmetric(0.0));
    }

    #[test]
    fn tril_shapes() {
        let a = small();
        let l = a.tril(false);
        assert_eq!(l.nnz(), 5);
        let ls = a.tril(true);
        assert_eq!(ls.nnz(), 2);
    }

    #[test]
    fn validate_catches_bad_indptr() {
        let mut a = small();
        a.indptr[1] = 10;
        assert!(a.validate().is_err());
    }

    #[test]
    fn diag_extraction() {
        let a = small();
        assert_eq!(a.diag(), vec![2.0, 2.0, 2.0]);
    }
}
