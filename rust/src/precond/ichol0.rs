//! Zero fill-in incomplete Cholesky — IC(0), the cuSPARSE `csric02`
//! stand-in of Table 3: cheapest construction, weakest preconditioning.
//!
//! Computes `L` with exactly the sparsity of the lower triangle of `A`
//! (including the diagonal). For singular Laplacians a tiny diagonal
//! shift is applied automatically on pivot breakdown, mirroring the
//! usual shifted-IC practice.

use super::Preconditioner;
use crate::error::ParacError;
use crate::sparse::Csr;

/// IC(0) factor `A ≈ L Lᵀ` with `pattern(L) = pattern(tril(A))`.
pub struct Ichol0 {
    /// Lower-triangular factor rows (CSR, diagonal last entry per row).
    l: Csr,
    /// Diagonal shift applied (0.0 if none was needed).
    pub shift: f64,
}

impl Ichol0 {
    /// Build IC(0); retries with growing diagonal shifts on breakdown.
    /// Panics on unrecoverable breakdown — use [`Ichol0::try_new`] for
    /// the error-propagating path.
    pub fn new(a: &Csr) -> Ichol0 {
        match Self::try_new(a) {
            Ok(f) => f,
            Err(e) => panic!("{e}"),
        }
    }

    /// Build IC(0); retries with growing diagonal shifts, and reports
    /// unrecoverable breakdown (e.g. an indefinite input) as
    /// [`ParacError::BadInput`] instead of panicking.
    pub fn try_new(a: &Csr) -> Result<Ichol0, ParacError> {
        let base: f64 = {
            let d = a.diag();
            d.iter().cloned().fold(0.0, f64::max)
        };
        let mut shift = 0.0;
        loop {
            match Self::attempt(a, shift) {
                Some(l) => return Ok(Ichol0 { l, shift }),
                None => {
                    shift = if shift == 0.0 { 1e-8 * base.max(1.0) } else { shift * 10.0 };
                    if shift >= base.max(1.0) {
                        return Err(ParacError::BadInput(format!(
                            "IC(0) breakdown not recoverable (shift {shift})"
                        )));
                    }
                }
            }
        }
    }

    /// One construction attempt with `A + shift·I`.
    fn attempt(a: &Csr, shift: f64) -> Option<Csr> {
        let n = a.nrows;
        let lower = a.tril(false);
        let mut l = lower.clone();
        // Row-by-row up-looking IC(0) on the fixed pattern:
        // l_ij = (a_ij − Σ_{k<j} l_ik l_jk) / l_jj  for j < i in pattern,
        // l_ii = sqrt(a_ii + shift − Σ_{k<i} l_ik²).
        for i in 0..n {
            let (lo, hi) = (l.indptr[i], l.indptr[i + 1]);
            for idx in lo..hi {
                let j = l.indices[idx] as usize;
                let mut sum = l.data[idx] + if i == j { shift } else { 0.0 };
                // Sparse dot of rows i and j over columns < j.
                let (ilo, jlo) = (l.indptr[i], l.indptr[j]);
                let (mut p, mut q) = (ilo, jlo);
                let iend = idx; // entries of row i with col < j
                let jend = l.indptr[j + 1] - 1; // skip diag of row j
                while p < iend && q < jend {
                    let cp = l.indices[p];
                    let cq = l.indices[q];
                    match cp.cmp(&cq) {
                        std::cmp::Ordering::Less => p += 1,
                        std::cmp::Ordering::Greater => q += 1,
                        std::cmp::Ordering::Equal => {
                            sum -= l.data[p] * l.data[q];
                            p += 1;
                            q += 1;
                        }
                    }
                }
                if i == j {
                    if sum <= 0.0 {
                        // Singular tail pivot (last vertex of a connected
                        // Laplacian): pin if negligible, else fail.
                        let scale = l.data[idx].abs().max(1.0);
                        if sum.abs() <= 1e-10 * scale {
                            l.data[idx] = 0.0;
                            continue;
                        }
                        return None;
                    }
                    l.data[idx] = sum.sqrt();
                } else {
                    let djj = l.data[l.indptr[j + 1] - 1];
                    l.data[idx] = if djj > 0.0 { sum / djj } else { 0.0 };
                }
            }
        }
        Some(l)
    }

    /// Access the factor (testing).
    pub fn factor(&self) -> &Csr {
        &self.l
    }
}

impl Preconditioner for Ichol0 {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        let n = self.l.nrows;
        let l = &self.l;
        // Forward solve L y = r into z (rows; diagonal is last entry per
        // row). Row i reads only z[j] for j < i, which this sweep has
        // already written — z's prior contents are never read.
        for i in 0..n {
            let (lo, hi) = (l.indptr[i], l.indptr[i + 1]);
            let d = l.data[hi - 1];
            if d == 0.0 {
                z[i] = 0.0;
                continue;
            }
            let mut acc = r[i];
            for idx in lo..hi - 1 {
                acc -= l.data[idx] * z[l.indices[idx] as usize];
            }
            z[i] = acc / d;
        }
        // Backward solve Lᵀ z = y in place (column sweep over rows).
        for i in (0..n).rev() {
            let (lo, hi) = (l.indptr[i], l.indptr[i + 1]);
            let d = l.data[hi - 1];
            if d == 0.0 {
                z[i] = 0.0;
                continue;
            }
            z[i] /= d;
            let zi = z[i];
            for idx in lo..hi - 1 {
                z[l.indices[idx] as usize] -= l.data[idx] * zi;
            }
        }
    }

    fn name(&self) -> &'static str {
        "ichol0"
    }

    fn nnz(&self) -> usize {
        self.l.nnz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::precond::IdentityPrecond;
    use crate::solve::pcg;

    #[test]
    fn exact_on_tridiagonal_spd() {
        // Grounded path → tridiagonal SPD with no fill: IC(0) is the
        // exact Cholesky, so PCG converges in one iteration.
        let l = generators::path(32);
        let mut coo = crate::sparse::Coo::new(32, 32);
        for r in 0..32 {
            for (&c, &v) in l.matrix.row_indices(r).iter().zip(l.matrix.row_data(r)) {
                coo.push(r as u32, c, v);
            }
            coo.push(r as u32, r as u32, 0.01); // ground every vertex a bit
        }
        let a = coo.to_csr();
        let ic = Ichol0::new(&a);
        let b: Vec<f64> = (0..32).map(|i| (i as f64 * 0.3).sin()).collect();
        let o = pcg::PcgOptions { project: false, ..Default::default() };
        let out = pcg::solve(&a, &b, &ic, &o);
        assert!(out.iters <= 2, "IC(0) must be exact on tridiagonal, took {}", out.iters);
    }

    #[test]
    fn preconditioners_laplacian_with_projection() {
        let l = generators::grid2d(16, 16, generators::Coeff::Uniform, 0);
        let ic = Ichol0::new(&l.matrix);
        let b = pcg::random_rhs(&l, 7);
        let o = pcg::PcgOptions { max_iter: 2000, ..Default::default() };
        let out = pcg::solve(&l.matrix, &b, &ic, &o);
        assert!(out.converged, "rel={}", out.rel_residual);
        let plain = pcg::solve(&l.matrix, &b, &IdentityPrecond, &o);
        assert!(out.iters < plain.iters, "ic0 {} vs plain {}", out.iters, plain.iters);
    }

    #[test]
    fn pattern_matches_lower_triangle() {
        let l = generators::grid2d(6, 6, generators::Coeff::Uniform, 0);
        let ic = Ichol0::new(&l.matrix);
        assert_eq!(ic.factor().nnz(), l.matrix.tril(false).nnz());
        assert_eq!(ic.shift, 0.0);
    }
}
