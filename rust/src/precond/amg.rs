//! Smoothed-aggregation algebraic multigrid — the HyPre / AmgX stand-in
//! of Tables 2–3 (see DESIGN.md substitutions). Strong multilevel
//! baseline: wins on PDE meshes, degrades on irregular graph Laplacians,
//! which is exactly the behaviour the paper's comparison turns on.
//!
//! Setup: strength filtering → greedy aggregation → piecewise-constant
//! tentative prolongator → Jacobi smoothing of `P` → Galerkin coarse
//! operator `Pᵀ A P`, recursively until the coarse grid is tiny. Apply:
//! one V-cycle (weighted-Jacobi pre/post smoothing, dense pseudo-inverse
//! Cholesky on the coarsest level).

use super::Preconditioner;
use crate::sparse::ops::{dense_cholesky, dense_cholesky_solve, rap, spgemm};
use crate::sparse::{Coo, Csr};

/// One multigrid level.
struct Level {
    a: Csr,
    p: Csr,
    inv_diag: Vec<f64>,
    /// Weighted-Jacobi relaxation factor.
    omega: f64,
}

/// AMG setup options.
#[derive(Clone, Debug)]
pub struct AmgOptions {
    /// Strength threshold θ: keep `|a_ij| ≥ θ·√(a_ii·a_jj)`.
    pub theta: f64,
    /// Stop coarsening below this size.
    pub coarse_size: usize,
    /// Maximum number of levels.
    pub max_levels: usize,
    /// Pre/post smoothing sweeps.
    pub sweeps: usize,
}

impl Default for AmgOptions {
    fn default() -> Self {
        AmgOptions { theta: 0.08, coarse_size: 64, max_levels: 12, sweeps: 1 }
    }
}

/// Smoothed-aggregation AMG V-cycle preconditioner.
pub struct AmgPrecond {
    levels: Vec<Level>,
    coarse_chol: Vec<f64>,
    coarse_n: usize,
    sweeps: usize,
    /// Total operator complexity Σ nnz(A_l) / nnz(A_0).
    pub operator_complexity: f64,
    /// Setup wall-clock seconds.
    pub setup_secs: f64,
}

impl AmgPrecond {
    /// Run the setup phase.
    pub fn new(a: &Csr, opts: &AmgOptions) -> AmgPrecond {
        let timer = crate::util::Timer::start();
        let mut levels: Vec<Level> = Vec::new();
        let mut cur = a.clone();
        let nnz0 = a.nnz() as f64;
        let mut nnz_total = a.nnz() as f64;
        while cur.nrows > opts.coarse_size && levels.len() + 1 < opts.max_levels {
            let agg = aggregate(&cur, opts.theta);
            let ncoarse = agg.iter().copied().max().map_or(0, |m| m as usize + 1);
            if ncoarse == 0 || ncoarse as f64 > 0.9 * cur.nrows as f64 {
                break; // coarsening stalled
            }
            let t = tentative_prolongator(&agg, ncoarse);
            let (p, omega, inv_diag) = smooth_prolongator(&cur, &t);
            let coarse = rap(&p, &cur).drop_zeros(1e-14);
            nnz_total += coarse.nnz() as f64;
            levels.push(Level { a: cur, p, inv_diag, omega });
            cur = coarse;
        }
        // Coarsest: dense Cholesky with zero-pivot pinning.
        let n = cur.nrows;
        let mut dense = vec![0.0f64; n * n];
        for r in 0..n {
            for (&c, &v) in cur.row_indices(r).iter().zip(cur.row_data(r)) {
                dense[r * n + c as usize] += v;
            }
        }
        dense_cholesky(&mut dense, n);
        AmgPrecond {
            levels,
            coarse_chol: dense,
            coarse_n: n,
            sweeps: opts.sweeps,
            operator_complexity: nnz_total / nnz0,
            setup_secs: timer.secs(),
        }
    }

    /// Number of levels (including the coarsest).
    pub fn num_levels(&self) -> usize {
        self.levels.len() + 1
    }

    fn vcycle(&self, lvl: usize, b: &[f64]) -> Vec<f64> {
        if lvl == self.levels.len() {
            return dense_cholesky_solve(&self.coarse_chol, self.coarse_n, b);
        }
        let l = &self.levels[lvl];
        let n = l.a.nrows;
        // Pre-smooth (weighted Jacobi from zero initial guess).
        let mut x = vec![0.0f64; n];
        for s in 0..self.sweeps {
            if s == 0 {
                for i in 0..n {
                    x[i] = l.omega * l.inv_diag[i] * b[i];
                }
            } else {
                let ax = l.a.mul_vec(&x);
                for i in 0..n {
                    x[i] += l.omega * l.inv_diag[i] * (b[i] - ax[i]);
                }
            }
        }
        // Coarse correction.
        let ax = l.a.mul_vec(&x);
        let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        let rc = mul_transpose_vec(&l.p, &r);
        let xc = self.vcycle(lvl + 1, &rc);
        let corr = l.p.mul_vec(&xc);
        for (xi, ci) in x.iter_mut().zip(&corr) {
            *xi += ci;
        }
        // Post-smooth.
        for _ in 0..self.sweeps {
            let ax = l.a.mul_vec(&x);
            for i in 0..n {
                x[i] += l.omega * l.inv_diag[i] * (b[i] - ax[i]);
            }
        }
        x
    }
}

impl Preconditioner for AmgPrecond {
    // The V-cycle allocates per-level temporaries internally — AMG is a
    // setup-heavy baseline, not the hot path; see the module note on
    // `precond::Preconditioner`.
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(&self.vcycle(0, r));
    }
    fn name(&self) -> &'static str {
        "amg"
    }
    fn nnz(&self) -> usize {
        self.levels.iter().map(|l| l.a.nnz() + l.p.nnz()).sum::<usize>()
            + self.coarse_n * self.coarse_n
    }
}

/// `y = Pᵀ x` without materializing the transpose.
fn mul_transpose_vec(p: &Csr, x: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0f64; p.ncols];
    for r in 0..p.nrows {
        let xr = x[r];
        if xr == 0.0 {
            continue;
        }
        for (&c, &v) in p.row_indices(r).iter().zip(p.row_data(r)) {
            y[c as usize] += v * xr;
        }
    }
    y
}

/// Greedy strength-based aggregation. Returns `agg[i]` = aggregate id
/// (every vertex assigned).
fn aggregate(a: &Csr, theta: f64) -> Vec<u32> {
    let n = a.nrows;
    let diag = a.diag();
    let strong = |i: usize, j: usize, v: f64| -> bool {
        i != j && v.abs() >= theta * (diag[i].abs() * diag[j].abs()).sqrt()
    };
    let mut agg = vec![u32::MAX; n];
    let mut next_id = 0u32;
    // Pass 1: seed aggregates around untouched vertices.
    for i in 0..n {
        if agg[i] != u32::MAX {
            continue;
        }
        let nbrs: Vec<usize> = a
            .row_indices(i)
            .iter()
            .zip(a.row_data(i))
            .filter(|(&c, &v)| strong(i, c as usize, v))
            .map(|(&c, _)| c as usize)
            .collect();
        if nbrs.iter().all(|&j| agg[j] == u32::MAX) {
            agg[i] = next_id;
            for &j in &nbrs {
                agg[j] = next_id;
            }
            next_id += 1;
        }
    }
    // Pass 2: attach stragglers to their most strongly connected
    // aggregate.
    for i in 0..n {
        if agg[i] != u32::MAX {
            continue;
        }
        let mut best = (0.0f64, u32::MAX);
        for (&c, &v) in a.row_indices(i).iter().zip(a.row_data(i)) {
            let j = c as usize;
            if j != i && agg[j] != u32::MAX && v.abs() > best.0 {
                best = (v.abs(), agg[j]);
            }
        }
        if best.1 != u32::MAX {
            agg[i] = best.1;
        } else {
            agg[i] = next_id; // isolated singleton
            next_id += 1;
        }
    }
    agg
}

/// Piecewise-constant tentative prolongator, columns normalized.
fn tentative_prolongator(agg: &[u32], ncoarse: usize) -> Csr {
    let n = agg.len();
    let mut sizes = vec![0usize; ncoarse];
    for &a in agg {
        sizes[a as usize] += 1;
    }
    let mut coo = Coo::with_capacity(n, ncoarse, n);
    for (i, &a) in agg.iter().enumerate() {
        coo.push(i as u32, a, 1.0 / (sizes[a as usize] as f64).sqrt());
    }
    coo.to_csr()
}

/// Jacobi-smoothed prolongator `P = (I − ω D⁻¹ A) T`; also returns the
/// level's `ω` and `D⁻¹` for the V-cycle smoother.
fn smooth_prolongator(a: &Csr, t: &Csr) -> (Csr, f64, Vec<f64>) {
    let n = a.nrows;
    let inv_diag: Vec<f64> =
        a.diag().into_iter().map(|d| if d > 0.0 { 1.0 / d } else { 0.0 }).collect();
    // Spectral radius of D⁻¹A by power iteration.
    let mut v: Vec<f64> = (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0 + 0.1).collect();
    let mut rho = 1.0f64;
    for _ in 0..10 {
        let av = a.mul_vec(&v);
        let mut w: Vec<f64> = av.iter().zip(&inv_diag).map(|(x, d)| x * d).collect();
        let nrm = crate::sparse::ops::nrm2(&w).max(1e-30);
        rho = nrm / crate::sparse::ops::nrm2(&v).max(1e-30);
        for wi in w.iter_mut() {
            *wi /= nrm;
        }
        v = w;
    }
    let omega_p = 4.0 / (3.0 * rho.max(1e-12));
    // P = T − ω D⁻¹ A T.
    let at = spgemm(a, t);
    let mut scaled = at;
    for r in 0..n {
        let d = inv_diag[r] * omega_p;
        for idx in scaled.indptr[r]..scaled.indptr[r + 1] {
            scaled.data[idx] *= -d;
        }
    }
    let p = add_csr(t, &scaled).drop_zeros(1e-14);
    // Jacobi relaxation weight for the V-cycle.
    let omega = 2.0 / (3.0 * rho.max(1e-12)) * 2.0; // ≈ 4/(3ρ) conservative
    (p, omega.min(1.0), inv_diag)
}

/// Sparse matrix addition.
fn add_csr(a: &Csr, b: &Csr) -> Csr {
    assert_eq!((a.nrows, a.ncols), (b.nrows, b.ncols));
    let mut coo = Coo::with_capacity(a.nrows, a.ncols, a.nnz() + b.nnz());
    for r in 0..a.nrows {
        for (&c, &v) in a.row_indices(r).iter().zip(a.row_data(r)) {
            coo.push(r as u32, c, v);
        }
        for (&c, &v) in b.row_indices(r).iter().zip(b.row_data(r)) {
            coo.push(r as u32, c, v);
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::precond::IdentityPrecond;
    use crate::solve::pcg;

    #[test]
    fn builds_hierarchy_on_grid() {
        let l = generators::grid2d(32, 32, generators::Coeff::Uniform, 0);
        let amg = AmgPrecond::new(&l.matrix, &AmgOptions::default());
        assert!(amg.num_levels() >= 2, "expected real coarsening");
        assert!(amg.operator_complexity < 3.0, "complexity {}", amg.operator_complexity);
    }

    #[test]
    fn amg_crushes_iteration_count_on_mesh() {
        let l = generators::grid2d(32, 32, generators::Coeff::Uniform, 0);
        let amg = AmgPrecond::new(&l.matrix, &AmgOptions::default());
        let b = pcg::random_rhs(&l, 1);
        let o = pcg::PcgOptions { max_iter: 2000, ..Default::default() };
        let with = pcg::solve(&l.matrix, &b, &amg, &o);
        let without = pcg::solve(&l.matrix, &b, &IdentityPrecond, &o);
        assert!(with.converged, "rel={}", with.rel_residual);
        assert!(
            with.iters * 3 < without.iters.max(3),
            "amg {} vs plain {}",
            with.iters,
            without.iters
        );
    }

    #[test]
    fn handles_3d_anisotropy() {
        let l = generators::grid3d(10, 10, 10, generators::Coeff::Anisotropic(1.0, 1.0, 20.0), 0);
        let amg = AmgPrecond::new(&l.matrix, &AmgOptions::default());
        let b = pcg::random_rhs(&l, 2);
        let o = pcg::PcgOptions { max_iter: 2000, ..Default::default() };
        let out = pcg::solve(&l.matrix, &b, &amg, &o);
        assert!(out.converged);
    }

    #[test]
    fn aggregation_covers_all_vertices() {
        let l = generators::road_like(15, 15, 0.1, 3);
        let agg = aggregate(&l.matrix, 0.08);
        assert!(agg.iter().all(|&a| a != u32::MAX));
    }

    #[test]
    fn tentative_prolongator_partition_of_unity() {
        let agg = vec![0u32, 0, 1, 1, 1];
        let t = tentative_prolongator(&agg, 2);
        // Columns have unit 2-norm.
        for c in 0..2 {
            let mut s = 0.0;
            for r in 0..5 {
                let v = t.get(r, c);
                s += v * v;
            }
            assert!((s - 1.0).abs() < 1e-12);
        }
    }
}
