//! Preconditioners: the ParAC factor plus every baseline the paper
//! compares against (Tables 2–3).
//!
//! | paper baseline            | here                                  |
//! |---------------------------|---------------------------------------|
//! | ParAC `G D Gᵀ`            | [`LdlPrecond`]                        |
//! | MATLAB `ichol('ict')`     | [`icholt::IcholT`] (threshold drop)   |
//! | cuSPARSE `csric02` (IC0)  | [`ichol0::Ichol0`] (zero fill-in)     |
//! | HyPre / AmgX (AMG)        | [`amg::AmgPrecond`] (smoothed aggr.)  |
//! | –                         | [`Ssor`], [`JacobiPrecond`], [`IdentityPrecond`] |
//!
//! Everything implements [`Preconditioner`], the symmetric-apply trait
//! [`crate::solve::pcg::solve`] consumes; [`LdlPrecond`] wraps the ParAC
//! [`crate::factor::LdlFactor`] with sequential or level-scheduled
//! parallel triangular solves.

pub mod amg;
pub mod ichol0;
pub mod ssor;
pub mod icholt;
pub mod ldl_precond;

pub use amg::AmgPrecond;
pub use ichol0::Ichol0;
pub use icholt::IcholT;
pub use ldl_precond::LdlPrecond;
pub use ssor::Ssor;

use crate::sparse::Csr;

/// A symmetric preconditioner application `z = M⁻¹ r`.
pub trait Preconditioner: Sync {
    /// Apply the preconditioner to a residual.
    fn apply(&self, r: &[f64]) -> Vec<f64>;

    /// Short display name for reports.
    fn name(&self) -> &'static str;

    /// Stored nonzeros (for fill comparisons); 0 if not applicable.
    fn nnz(&self) -> usize {
        0
    }
}

/// No preconditioning (plain CG).
pub struct IdentityPrecond;

impl Preconditioner for IdentityPrecond {
    fn apply(&self, r: &[f64]) -> Vec<f64> {
        r.to_vec()
    }
    fn name(&self) -> &'static str {
        "identity"
    }
}

/// Diagonal (Jacobi) preconditioner.
pub struct JacobiPrecond {
    inv_diag: Vec<f64>,
}

impl JacobiPrecond {
    /// Extract `diag(A)⁻¹` (zero diagonals pass through unchanged).
    pub fn new(a: &Csr) -> JacobiPrecond {
        let inv_diag = a
            .diag()
            .into_iter()
            .map(|d| if d > 0.0 { 1.0 / d } else { 1.0 })
            .collect();
        JacobiPrecond { inv_diag }
    }
}

impl Preconditioner for JacobiPrecond {
    fn apply(&self, r: &[f64]) -> Vec<f64> {
        r.iter().zip(&self.inv_diag).map(|(x, d)| x * d).collect()
    }
    fn name(&self) -> &'static str {
        "jacobi"
    }
    fn nnz(&self) -> usize {
        self.inv_diag.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn identity_is_identity() {
        let r = vec![1.0, -2.0, 3.0];
        assert_eq!(IdentityPrecond.apply(&r), r);
    }

    #[test]
    fn jacobi_scales_by_diag() {
        let l = generators::path(4); // diag [1,2,2,1]
        let p = JacobiPrecond::new(&l.matrix);
        let z = p.apply(&[2.0, 2.0, 4.0, 3.0]);
        assert_eq!(z, vec![2.0, 1.0, 2.0, 3.0]);
    }
}
