//! Preconditioners: the ParAC factor plus every baseline the paper
//! compares against (Tables 2–3).
//!
//! | paper baseline            | here                                  |
//! |---------------------------|---------------------------------------|
//! | ParAC `G D Gᵀ`            | [`LdlPrecond`]                        |
//! | MATLAB `ichol('ict')`     | [`icholt::IcholT`] (threshold drop)   |
//! | cuSPARSE `csric02` (IC0)  | [`ichol0::Ichol0`] (zero fill-in)     |
//! | HyPre / AmgX (AMG)        | [`amg::AmgPrecond`] (smoothed aggr.)  |
//! | –                         | [`Ssor`], [`JacobiPrecond`], [`IdentityPrecond`] |
//!
//! Everything implements [`Preconditioner`], the symmetric-apply trait
//! [`crate::solve::pcg`] consumes. The primitive is the allocation-free
//! [`Preconditioner::apply_scratch`] — PCG calls it once per iteration
//! with reused buffers from its workspace, and every intermediate lives
//! in those caller buffers, so a built preconditioner is immutable
//! shared state (`Send + Sync`, no interior mutability) that any number
//! of concurrent solves can apply through `&self`. The `Vec`-returning
//! [`Preconditioner::apply`] and the buffer-only
//! [`Preconditioner::apply_into`] are convenience shims on top. One
//! documented exception to allocation-freedom: [`AmgPrecond`] (its
//! V-cycle allocates per-level temporaries; a setup-heavy baseline, not
//! the hot path). [`LdlPrecond`] in level-scheduled mode runs the
//! packed sweep executor ([`crate::solve::packed`]) on the persistent
//! worker pool — one dispatch per sweep, zero allocation after pool
//! warm-up.

pub mod amg;
pub mod ichol0;
pub mod ssor;
pub mod icholt;
pub mod ldl_precond;

pub use amg::AmgPrecond;
pub use ichol0::Ichol0;
pub use icholt::IcholT;
pub use ldl_precond::LdlPrecond;
pub use ssor::Ssor;

use crate::sparse::Csr;

/// A symmetric preconditioner application `z = M⁻¹ r`.
///
/// The `Send + Sync` supertrait is load-bearing: a built preconditioner
/// is immutable shared state, applied concurrently through `&self` from
/// any number of solve calls (see [`crate::serve`]). All per-apply
/// mutable state must come in through the caller via
/// [`Preconditioner::apply_scratch`].
pub trait Preconditioner: Send + Sync {
    /// Apply the preconditioner into a caller buffer: `z = M⁻¹ r`.
    ///
    /// `z.len()` must equal `r.len()`; every element of `z` is
    /// overwritten (no prior contents are read). Implementations whose
    /// apply needs intermediates may allocate here — the allocation-free
    /// hot-loop primitive is
    /// [`apply_scratch`](Preconditioner::apply_scratch), which PCG calls
    /// with reused caller buffers.
    fn apply_into(&self, r: &[f64], z: &mut [f64]);

    /// Apply with caller-owned scratch: `z = M⁻¹ r`, using `a`/`b`
    /// (each of length `r.len()`) for any intermediates.
    ///
    /// This is the hot-loop primitive: PCG calls it once per iteration
    /// with buffers from its reused workspace, and implementations must
    /// not allocate unless documented otherwise (only [`AmgPrecond`]
    /// does). Preconditioners with no intermediates ignore the scratch;
    /// the default forwards to [`apply_into`](Preconditioner::apply_into).
    fn apply_scratch(&self, r: &[f64], z: &mut [f64], a: &mut [f64], b: &mut [f64]) {
        let _ = (a, b);
        self.apply_into(r, z);
    }

    /// Allocating convenience shim over
    /// [`apply_into`](Preconditioner::apply_into).
    fn apply(&self, r: &[f64]) -> Vec<f64> {
        let mut z = vec![0.0; r.len()];
        self.apply_into(r, &mut z);
        z
    }

    /// Short display name for reports.
    fn name(&self) -> &'static str;

    /// Stored nonzeros (for fill comparisons); 0 if not applicable.
    fn nnz(&self) -> usize {
        0
    }

    /// Cumulative sweep dispatch/barrier counters, for preconditioners
    /// whose apply runs level-scheduled sweeps on the worker pool
    /// ([`LdlPrecond`] via [`crate::solve::packed::PackedSweeps`]).
    /// `None` for everything else. [`crate::solve::pcg::solve_into`]
    /// snapshots this around each solve so the O(1)-dispatch behaviour
    /// is visible in the solve stats.
    fn sweep_counters(&self) -> Option<crate::solve::packed::SweepCounters> {
        None
    }

    /// The storage plane this preconditioner currently applies in.
    /// `F64` (the default — every baseline stores doubles) keeps the
    /// bit-identity contract; `F32` signals the PCG driver that the
    /// apply obeys a residual contract instead, arming the
    /// stagnation/NaN fallback guard in [`crate::solve::pcg`].
    fn precision(&self) -> crate::sparse::Precision {
        crate::sparse::Precision::F64
    }

    /// Ask an f32-plane preconditioner to switch itself to an f64
    /// plane (the iterative-refinement fallback). Returns `true` the
    /// first time the promotion actually happens — subsequent calls,
    /// and every preconditioner already in f64, return `false`. The
    /// default is a no-op: only [`LdlPrecond`] in f32 packed mode can
    /// promote. Must be callable through `&self` from inside a solve
    /// (interior one-shot state, still `Sync`).
    fn promote_to_f64(&self) -> bool {
        false
    }

    /// Downcast to the ParAC factor preconditioner, for callers that
    /// hold a `dyn Preconditioner` and need factor-specific operations
    /// (stats, refactorization). `None` for everything else.
    fn as_ldl(&self) -> Option<&LdlPrecond> {
        None
    }

    /// Mutable variant of [`Preconditioner::as_ldl`].
    fn as_ldl_mut(&mut self) -> Option<&mut LdlPrecond> {
        None
    }
}

/// No preconditioning (plain CG).
pub struct IdentityPrecond;

impl Preconditioner for IdentityPrecond {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }
    fn name(&self) -> &'static str {
        "identity"
    }
}

/// Diagonal (Jacobi) preconditioner.
pub struct JacobiPrecond {
    inv_diag: Vec<f64>,
}

impl JacobiPrecond {
    /// Extract `diag(A)⁻¹` (zero diagonals pass through unchanged).
    pub fn new(a: &Csr) -> JacobiPrecond {
        let inv_diag = a
            .diag()
            .into_iter()
            .map(|d| if d > 0.0 { 1.0 / d } else { 1.0 })
            .collect();
        JacobiPrecond { inv_diag }
    }

    /// [`JacobiPrecond::new`] with the diagonal extraction chunked over
    /// the persistent worker pool. The map is element-wise, so the
    /// result is bit-identical to the sequential constructor; small
    /// matrices and single-thread requests take the sequential path.
    pub fn new_par(a: &Csr, threads: usize) -> JacobiPrecond {
        let n = a.nrows.min(a.ncols);
        let pool = crate::par::global();
        let parts = threads.max(1).min(pool.size()).min(n.max(1));
        if parts <= 1 || n < crate::sparse::csr::PAR_SPMV_CUTOFF {
            return JacobiPrecond::new(a);
        }
        let mut inv_diag = vec![0.0f64; n];
        let out = crate::par::SendPtr::new(inv_diag.as_mut_ptr());
        pool.run(parts, |part, parts| {
            let (lo, hi) = crate::par::chunk_range(n, part, parts);
            for i in lo..hi {
                let d = a.get(i, i);
                let v = if d > 0.0 { 1.0 / d } else { 1.0 };
                // Disjoint row chunks: safe.
                unsafe { out.write(i, v) };
            }
        });
        JacobiPrecond { inv_diag }
    }
}

impl Preconditioner for JacobiPrecond {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        for ((zi, ri), di) in z.iter_mut().zip(r).zip(&self.inv_diag) {
            *zi = ri * di;
        }
    }
    fn name(&self) -> &'static str {
        "jacobi"
    }
    fn nnz(&self) -> usize {
        self.inv_diag.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn identity_is_identity() {
        let r = vec![1.0, -2.0, 3.0];
        assert_eq!(IdentityPrecond.apply(&r), r);
        let mut z = vec![9.0; 3];
        IdentityPrecond.apply_into(&r, &mut z);
        assert_eq!(z, r);
    }

    #[test]
    fn jacobi_scales_by_diag() {
        let l = generators::path(4); // diag [1,2,2,1]
        let p = JacobiPrecond::new(&l.matrix);
        let z = p.apply(&[2.0, 2.0, 4.0, 3.0]);
        assert_eq!(z, vec![2.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn jacobi_pooled_extraction_matches_sequential() {
        // 2304 rows ≥ PAR_SPMV_CUTOFF: takes the pooled path.
        let l = generators::grid2d(48, 48, generators::Coeff::HighContrast(2.0), 1);
        let seq = JacobiPrecond::new(&l.matrix);
        for threads in [1usize, 2, 4] {
            let par = JacobiPrecond::new_par(&l.matrix, threads);
            assert_eq!(seq.inv_diag, par.inv_diag, "threads={threads}");
        }
    }

    #[test]
    fn shim_matches_apply_into() {
        let l = generators::grid2d(6, 6, generators::Coeff::Uniform, 1);
        let p = JacobiPrecond::new(&l.matrix);
        let r: Vec<f64> = (0..l.n()).map(|i| (i as f64).cos()).collect();
        let mut z = vec![0.0; l.n()];
        p.apply_into(&r, &mut z);
        assert_eq!(z, p.apply(&r));
    }
}
