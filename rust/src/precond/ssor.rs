//! Symmetric SOR preconditioner — an extra matrix-free baseline
//! (`M = (D/ω + L) (D/ω)⁻¹ (D/ω + L)ᵀ · ω/(2−ω)`), useful as a
//! middle ground between Jacobi and incomplete factorizations in the
//! ablation sweeps.

use super::Preconditioner;
use crate::error::ParacError;
use crate::sparse::Csr;

/// SSOR with relaxation factor `ω ∈ (0, 2)`.
pub struct Ssor {
    lower: Csr, // strictly lower triangle of A (rows)
    diag: Vec<f64>,
    omega: f64,
}

impl Ssor {
    /// Build from a symmetric matrix. Panics on an out-of-range `ω` —
    /// use [`Ssor::try_new`] for the error-propagating path.
    pub fn new(a: &Csr, omega: f64) -> Ssor {
        match Self::try_new(a, omega) {
            Ok(s) => s,
            Err(e) => panic!("{e}"),
        }
    }

    /// Build, rejecting an out-of-range relaxation factor (`ω` must be
    /// in `(0, 2)`) as [`ParacError::InvalidOption`] instead of
    /// panicking.
    pub fn try_new(a: &Csr, omega: f64) -> Result<Ssor, ParacError> {
        if !(omega > 0.0 && omega < 2.0) {
            return Err(ParacError::InvalidOption { what: "ssor omega", got: omega.to_string() });
        }
        Ok(Ssor { lower: a.tril(true), diag: a.diag(), omega })
    }
}

impl Preconditioner for Ssor {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        // M⁻¹ = ω(2−ω) · (D + ωLᵀ)⁻¹ D (D + ωL)⁻¹.
        let n = self.diag.len();
        let w = self.omega;
        // Forward: (D + ωL) y = r, written into z. Row i reads only
        // z[c] for c < i (strictly lower), already written this sweep —
        // z's prior contents are never read.
        for i in 0..n {
            let mut acc = r[i];
            for (&c, &v) in self.lower.row_indices(i).iter().zip(self.lower.row_data(i)) {
                acc -= w * v * z[c as usize];
            }
            let d = self.diag[i];
            z[i] = if d > 0.0 { acc / d } else { 0.0 };
        }
        // Middle: z ← ω(2−ω) · D z.
        for (zi, &d) in z.iter_mut().zip(&self.diag) {
            *zi *= w * (2.0 - w) * d;
        }
        // Backward: (D + ωLᵀ) z = y, scatter over rows of L.
        for i in (0..n).rev() {
            let d = self.diag[i];
            z[i] = if d > 0.0 { z[i] / d } else { 0.0 };
            let zi = z[i];
            for (&c, &v) in self.lower.row_indices(i).iter().zip(self.lower.row_data(i)) {
                z[c as usize] -= w * v * zi;
            }
        }
    }

    fn name(&self) -> &'static str {
        "ssor"
    }

    fn nnz(&self) -> usize {
        self.lower.nnz() + self.diag.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::precond::{JacobiPrecond, Preconditioner};
    use crate::solve::pcg::{self, PcgOptions};

    #[test]
    fn ssor_is_symmetric_operator() {
        // ⟨M⁻¹u, v⟩ == ⟨u, M⁻¹v⟩ — required for PCG.
        let l = generators::grid2d(8, 8, generators::Coeff::Uniform, 0);
        let s = Ssor::new(&l.matrix, 1.2);
        let mut rng = crate::rng::Rng::new(5);
        for _ in 0..10 {
            let u: Vec<f64> = (0..64).map(|_| rng.next_normal()).collect();
            let v: Vec<f64> = (0..64).map(|_| rng.next_normal()).collect();
            let left = crate::sparse::ops::dot(&s.apply(&u), &v);
            let right = crate::sparse::ops::dot(&u, &s.apply(&v));
            assert!((left - right).abs() < 1e-9 * left.abs().max(1.0));
        }
    }

    #[test]
    fn ssor_beats_jacobi_on_mesh() {
        let l = generators::grid2d(24, 24, generators::Coeff::Uniform, 0);
        let b = pcg::random_rhs(&l, 2);
        let o = PcgOptions { max_iter: 3000, ..Default::default() };
        let ss = pcg::solve(&l.matrix, &b, &Ssor::new(&l.matrix, 1.5), &o);
        let jc = pcg::solve(&l.matrix, &b, &JacobiPrecond::new(&l.matrix), &o);
        assert!(ss.converged);
        assert!(ss.iters < jc.iters, "ssor {} vs jacobi {}", ss.iters, jc.iters);
    }
}
