//! The ParAC factor as a PCG preconditioner, with an optional
//! level-scheduled parallel triangular solve (the paper's GPU solve
//! path; cf. Table 3's SPSV analysis stage).
//!
//! Level-scheduled mode runs the **packed sweep executor**
//! ([`crate::solve::packed::PackedSweeps`]): at construction the factor
//! is renumbered into level-major order and copied contiguously per
//! sweep direction, and each apply then costs at most one persistent
//! worker-pool dispatch per sweep — two total, independent of the DAG
//! depth — with the `D⁻¹` scaling and the fill-reducing permutation
//! fused into the boundary/scatter passes.
//!
//! The apply is allocation-free in **both** modes when driven through
//! [`Preconditioner::apply_scratch`]: every intermediate lives in the
//! caller's scratch buffers (PCG hands in two reused workspace vectors
//! per iteration), so the preconditioner itself holds **no mutable
//! state at all** — factor, schedules and packed arrays are immutable
//! after construction, and any number of concurrent solves can apply it
//! through `&self`. Pool dispatch allocates nothing after warm-up (see
//! the assertion in `rust/tests/alloc_free.rs`); concurrent dispatchers
//! serialize on the pool's dispatch lock, preserving the one-dispatch-
//! per-sweep contract per caller.

use super::Preconditioner;
use crate::factor::LdlFactor;
use crate::solve::packed::{PackedSweeps, SweepCounters};

/// `z = (G D Gᵀ)⁺ r`, sequential or level-parallel (packed executor).
pub struct LdlPrecond {
    factor: LdlFactor,
    packed: Option<PackedSweeps>,
    threads: usize,
    /// Level-width cutoff the packed analysis ran with — kept so a
    /// structure-changing refactorization can re-analyze identically.
    cutoff: usize,
}

impl LdlPrecond {
    /// Sequential-solve preconditioner.
    pub fn new(factor: LdlFactor) -> LdlPrecond {
        LdlPrecond {
            factor,
            packed: None,
            threads: 1,
            cutoff: crate::solve::packed::default_cutoff(),
        }
    }

    /// Level-scheduled parallel solves with `threads` workers and the
    /// [default cutoff](crate::solve::packed::default_cutoff) (the
    /// "analysis" — level schedules plus the packed level-major copy —
    /// runs here, once, mirroring cuSPARSE SPSV analysis).
    pub fn with_level_schedule(factor: LdlFactor, threads: usize) -> LdlPrecond {
        Self::with_level_schedule_cutoff(factor, threads, crate::solve::packed::default_cutoff())
    }

    /// [`LdlPrecond::with_level_schedule`] with an explicit level-width
    /// cutoff (the [`crate::solver::SolverBuilder::level_cutoff`]
    /// knob): levels narrower than `cutoff` run sequentially on the
    /// resident participant 0 instead of being split. The analysis
    /// itself runs pooled with the same `threads` budget.
    pub fn with_level_schedule_cutoff(
        factor: LdlFactor,
        threads: usize,
        cutoff: usize,
    ) -> LdlPrecond {
        let packed = PackedSweeps::analyze_with_opts(&factor, cutoff, threads);
        LdlPrecond { factor, packed: Some(packed), threads, cutoff }
    }

    /// Access the wrapped factor.
    pub fn factor(&self) -> &LdlFactor {
        &self.factor
    }

    /// Critical path of the solve DAG (None if sequential mode).
    pub fn critical_path(&self) -> Option<usize> {
        self.packed.as_ref().map(|p| p.critical_path)
    }

    /// Swap a renumbered factor in under the preconditioner: `rebuild`
    /// mutates the wrapped factor in place (typically
    /// [`crate::factor::SymbolicFactor::refactorize_into`]) and returns
    /// whether the factor's sparsity structure was preserved. If so,
    /// the packed executor is [refilled](PackedSweeps::refill) in place
    /// — no allocation, schedules and counters untouched; otherwise the
    /// packed analysis is redone at the original cutoff and thread
    /// budget. Returns the closure's verdict.
    pub fn refactorize_numeric<E>(
        &mut self,
        rebuild: impl FnOnce(&mut LdlFactor) -> Result<bool, E>,
    ) -> Result<bool, E> {
        let preserved = rebuild(&mut self.factor)?;
        if let Some(packed) = &mut self.packed {
            if preserved {
                packed.refill(&self.factor);
            } else {
                *packed = PackedSweeps::analyze_with_opts(&self.factor, self.cutoff, self.threads);
            }
        }
        Ok(preserved)
    }
}

impl Preconditioner for LdlPrecond {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        // Convenience shim: allocates the scratch per call. The hot
        // path is `apply_scratch` with reused caller buffers.
        let mut a = vec![0.0; r.len()];
        let mut b = vec![0.0; r.len()];
        self.apply_scratch(r, z, &mut a, &mut b);
    }

    fn apply_scratch(&self, r: &[f64], z: &mut [f64], a: &mut [f64], b: &mut [f64]) {
        match &self.packed {
            None => self.factor.solve_into(r, z, a),
            Some(packed) => packed.apply_into(r, z, self.threads, a, b),
        }
    }

    fn name(&self) -> &'static str {
        "parac"
    }

    fn nnz(&self) -> usize {
        self.factor.nnz() + self.factor.n()
    }

    fn sweep_counters(&self) -> Option<SweepCounters> {
        self.packed.as_ref().map(|p| p.counters())
    }

    fn as_ldl(&self) -> Option<&LdlPrecond> {
        Some(self)
    }

    fn as_ldl_mut(&mut self) -> Option<&mut LdlPrecond> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, ParacOptions};
    use crate::graph::generators;
    use crate::solve::pcg;

    #[test]
    fn parac_preconditioned_cg_converges_fast() {
        let l = generators::grid2d(24, 24, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let pre = LdlPrecond::new(f);
        let b = pcg::random_rhs(&l, 3);
        let o = pcg::PcgOptions { max_iter: 300, ..Default::default() };
        let out = pcg::solve(&l.matrix, &b, &pre, &o);
        assert!(out.converged, "rel={} iters={}", out.rel_residual, out.iters);
        // Must beat unpreconditioned CG decisively.
        let plain = pcg::solve(&l.matrix, &b, &super::super::IdentityPrecond, &o);
        assert!(
            out.iters * 2 < plain.iters.max(1) || plain.iters == o.max_iter,
            "parac {} vs plain {}",
            out.iters,
            plain.iters
        );
    }

    #[test]
    fn level_parallel_apply_matches_sequential() {
        let l = generators::grid3d(6, 6, 6, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let seq = LdlPrecond::new(f.clone());
        // A small cutoff so the packed executor genuinely dispatches
        // and barriers on this grid.
        let par = LdlPrecond::with_level_schedule_cutoff(f, 4, 8);
        let b = pcg::random_rhs(&l, 9);
        let a = seq.apply(&b);
        let c = par.apply(&b);
        assert_eq!(a, c, "packed parallel apply must be bit-identical to sequential");
        assert!(par.critical_path().unwrap() >= 1);
        let counters = par.sweep_counters().unwrap();
        assert_eq!(counters.dispatches, 2, "one pool dispatch per sweep direction");
        assert!(seq.sweep_counters().is_none());
    }

    #[test]
    fn apply_into_matches_factor_solve() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 2);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let want = f.solve(&pcg::random_rhs(&l, 4));
        let pre = LdlPrecond::new(f);
        let mut z = vec![0.0; l.n()];
        pre.apply_into(&pcg::random_rhs(&l, 4), &mut z);
        assert_eq!(z, want);
    }
}
