//! The ParAC factor as a PCG preconditioner, with an optional
//! level-scheduled parallel triangular solve (the paper's GPU solve
//! path; cf. Table 3's SPSV analysis stage).
//!
//! The apply is allocation-free in **both** modes: the permuted
//! intermediate lives in a scratch buffer sized once at construction
//! (behind an uncontended `Mutex` so the preconditioner stays `Sync`;
//! PCG applies it sequentially, so the lock never blocks and never
//! allocates), and level-scheduled mode with `threads > 1` dispatches
//! wide levels onto the persistent [`crate::par`] worker pool — no
//! thread spawns, no heap allocation after the pool is warm (see
//! `solve::trisolve` and the assertion in `rust/tests/alloc_free.rs`).

use super::Preconditioner;
use crate::factor::LdlFactor;
use crate::solve::trisolve::LevelSchedule;
use std::sync::Mutex;

/// `z = (G D Gᵀ)⁺ r`, sequential or level-parallel.
pub struct LdlPrecond {
    factor: LdlFactor,
    schedule: Option<LevelSchedule>,
    threads: usize,
    /// Pre-sized scratch for the permuted intermediate (empty when the
    /// factor stores no permutation and the sequential path is used).
    scratch: Mutex<Vec<f64>>,
}

impl LdlPrecond {
    /// Sequential-solve preconditioner.
    pub fn new(factor: LdlFactor) -> LdlPrecond {
        let scratch = vec![0.0; if factor.perm.is_some() { factor.n() } else { 0 }];
        LdlPrecond { factor, schedule: None, threads: 1, scratch: Mutex::new(scratch) }
    }

    /// Level-scheduled parallel solves with `threads` workers (the
    /// "analysis" runs here, once — mirroring cuSPARSE SPSV analysis).
    pub fn with_level_schedule(factor: LdlFactor, threads: usize) -> LdlPrecond {
        let schedule = LevelSchedule::analyze(&factor);
        let scratch = vec![0.0; factor.n()];
        LdlPrecond { factor, schedule: Some(schedule), threads, scratch: Mutex::new(scratch) }
    }

    /// Access the wrapped factor.
    pub fn factor(&self) -> &LdlFactor {
        &self.factor
    }

    /// Critical path of the solve DAG (None if sequential mode).
    pub fn critical_path(&self) -> Option<usize> {
        self.schedule.as_ref().map(|s| s.critical_path)
    }
}

impl Preconditioner for LdlPrecond {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        // A poisoned lock only means another apply panicked mid-solve;
        // the buffer contents are overwritten anyway, so recover.
        let mut scratch = self.scratch.lock().unwrap_or_else(|p| p.into_inner());
        match &self.schedule {
            None => self.factor.solve_into(r, z, &mut scratch[..]),
            Some(sched) => {
                let f = &self.factor;
                // Work in the permuted space in `scratch` (or directly
                // in `z` when no permutation is stored).
                let y: &mut [f64] = match &f.perm {
                    Some(p) => {
                        for (i, &ri) in r.iter().enumerate() {
                            scratch[p[i] as usize] = ri;
                        }
                        &mut scratch[..]
                    }
                    None => {
                        z.copy_from_slice(r);
                        &mut *z
                    }
                };
                sched.forward(y, self.threads);
                for (yk, &d) in y.iter_mut().zip(&f.diag) {
                    *yk = if d > 0.0 { *yk / d } else { 0.0 };
                }
                sched.backward(&f.g, y, self.threads);
                if let Some(p) = &f.perm {
                    for (i, zi) in z.iter_mut().enumerate() {
                        *zi = scratch[p[i] as usize];
                    }
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "parac"
    }

    fn nnz(&self) -> usize {
        self.factor.nnz() + self.factor.n()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, ParacOptions};
    use crate::graph::generators;
    use crate::solve::pcg;

    #[test]
    fn parac_preconditioned_cg_converges_fast() {
        let l = generators::grid2d(24, 24, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let pre = LdlPrecond::new(f);
        let b = pcg::random_rhs(&l, 3);
        let o = pcg::PcgOptions { max_iter: 300, ..Default::default() };
        let out = pcg::solve(&l.matrix, &b, &pre, &o);
        assert!(out.converged, "rel={} iters={}", out.rel_residual, out.iters);
        // Must beat unpreconditioned CG decisively.
        let plain = pcg::solve(&l.matrix, &b, &super::super::IdentityPrecond, &o);
        assert!(
            out.iters * 2 < plain.iters.max(1) || plain.iters == o.max_iter,
            "parac {} vs plain {}",
            out.iters,
            plain.iters
        );
    }

    #[test]
    fn level_parallel_apply_matches_sequential() {
        let l = generators::grid3d(6, 6, 6, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let seq = LdlPrecond::new(f.clone());
        let par = LdlPrecond::with_level_schedule(f, 4);
        let b = pcg::random_rhs(&l, 9);
        let a = seq.apply(&b);
        let c = par.apply(&b);
        for (x, y) in a.iter().zip(&c) {
            assert!((x - y).abs() < 1e-12);
        }
        assert!(par.critical_path().unwrap() >= 1);
    }

    #[test]
    fn apply_into_matches_factor_solve() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 2);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let want = f.solve(&pcg::random_rhs(&l, 4));
        let pre = LdlPrecond::new(f);
        let mut z = vec![0.0; l.n()];
        pre.apply_into(&pcg::random_rhs(&l, 4), &mut z);
        assert_eq!(z, want);
    }
}
