//! The ParAC factor as a PCG preconditioner, with an optional
//! level-scheduled parallel triangular solve (the paper's GPU solve
//! path; cf. Table 3's SPSV analysis stage).

use super::Preconditioner;
use crate::factor::LdlFactor;
use crate::ordering::perm;
use crate::solve::trisolve::LevelSchedule;

/// `z = (G D Gᵀ)⁺ r`, sequential or level-parallel.
pub struct LdlPrecond {
    factor: LdlFactor,
    schedule: Option<LevelSchedule>,
    threads: usize,
}

impl LdlPrecond {
    /// Sequential-solve preconditioner.
    pub fn new(factor: LdlFactor) -> LdlPrecond {
        LdlPrecond { factor, schedule: None, threads: 1 }
    }

    /// Level-scheduled parallel solves with `threads` workers (the
    /// "analysis" runs here, once — mirroring cuSPARSE SPSV analysis).
    pub fn with_level_schedule(factor: LdlFactor, threads: usize) -> LdlPrecond {
        let schedule = LevelSchedule::analyze(&factor);
        LdlPrecond { factor, schedule: Some(schedule), threads }
    }

    /// Access the wrapped factor.
    pub fn factor(&self) -> &LdlFactor {
        &self.factor
    }

    /// Critical path of the solve DAG (None if sequential mode).
    pub fn critical_path(&self) -> Option<usize> {
        self.schedule.as_ref().map(|s| s.critical_path)
    }
}

impl Preconditioner for LdlPrecond {
    fn apply(&self, r: &[f64]) -> Vec<f64> {
        match &self.schedule {
            None => self.factor.solve(r),
            Some(sched) => {
                let f = &self.factor;
                let mut y = match &f.perm {
                    Some(p) => perm::apply_vec(p, r),
                    None => r.to_vec(),
                };
                sched.forward(&mut y, self.threads);
                for k in 0..f.n() {
                    let d = f.diag[k];
                    y[k] = if d > 0.0 { y[k] / d } else { 0.0 };
                }
                sched.backward(&mut y, self.threads);
                match &f.perm {
                    Some(p) => perm::unapply_vec(p, &y),
                    None => y,
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "parac"
    }

    fn nnz(&self) -> usize {
        self.factor.nnz() + self.factor.n()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, ParacOptions};
    use crate::graph::generators;
    use crate::solve::pcg;

    #[test]
    fn parac_preconditioned_cg_converges_fast() {
        let l = generators::grid2d(24, 24, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let pre = LdlPrecond::new(f);
        let b = pcg::random_rhs(&l, 3);
        let o = pcg::PcgOptions { max_iter: 300, ..Default::default() };
        let out = pcg::solve(&l.matrix, &b, &pre, &o);
        assert!(out.converged, "rel={} iters={}", out.rel_residual, out.iters);
        // Must beat unpreconditioned CG decisively.
        let plain = pcg::solve(&l.matrix, &b, &super::super::IdentityPrecond, &o);
        assert!(
            out.iters * 2 < plain.iters.max(1) || plain.iters == o.max_iter,
            "parac {} vs plain {}",
            out.iters,
            plain.iters
        );
    }

    #[test]
    fn level_parallel_apply_matches_sequential() {
        let l = generators::grid3d(6, 6, 6, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let seq = LdlPrecond::new(f.clone());
        let par = LdlPrecond::with_level_schedule(f, 4);
        let b = pcg::random_rhs(&l, 9);
        let a = seq.apply(&b);
        let c = par.apply(&b);
        for (x, y) in a.iter().zip(&c) {
            assert!((x - y).abs() < 1e-12);
        }
        assert!(par.critical_path().unwrap() >= 1);
    }
}
