//! The ParAC factor as a PCG preconditioner, with an optional
//! level-scheduled parallel triangular solve (the paper's GPU solve
//! path; cf. Table 3's SPSV analysis stage).
//!
//! Level-scheduled mode runs the **packed sweep executor**
//! ([`crate::solve::packed::PackedSweeps`]): at construction the factor
//! is renumbered into level-major order and copied contiguously per
//! sweep direction, and each apply then costs at most one persistent
//! worker-pool dispatch per sweep — two total, independent of the DAG
//! depth — with the `D⁻¹` scaling and the fill-reducing permutation
//! fused into the boundary/scatter passes.
//!
//! The apply is allocation-free in **both** modes when driven through
//! [`Preconditioner::apply_scratch`]: every intermediate lives in the
//! caller's scratch buffers (PCG hands in two reused workspace vectors
//! per iteration), so the preconditioner itself holds **no mutable
//! state at all** — factor, schedules and packed arrays are immutable
//! after construction, and any number of concurrent solves can apply it
//! through `&self`. Pool dispatch allocates nothing after warm-up (see
//! the assertion in `rust/tests/alloc_free.rs`); concurrent dispatchers
//! serialize on the pool's dispatch lock, preserving the one-dispatch-
//! per-sweep contract per caller.

use super::Preconditioner;
use crate::factor::LdlFactor;
use crate::solve::packed::{PackedSweeps, SweepCounters};
use crate::sparse::Precision;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Which executor (and storage plane) an apply routes through.
enum Plane {
    /// Sequential factor solve (always f64).
    Seq,
    /// Packed executor, 8-byte values — bit-identical to `Seq`.
    F64(PackedSweeps<f64>),
    /// Packed executor, 4-byte values (half the apply traffic), with a
    /// lazily built f64 fallback plane for the iterative-refinement
    /// guard. `promoted` flips once — through `&self`, mid-solve — and
    /// every later apply routes through the fallback.
    F32 {
        packed: PackedSweeps<f32>,
        fallback: OnceLock<PackedSweeps<f64>>,
        promoted: AtomicBool,
    },
}

/// `z = (G D Gᵀ)⁺ r`, sequential or level-parallel (packed executor),
/// in an f64 or f32 value-storage plane.
pub struct LdlPrecond {
    factor: LdlFactor,
    plane: Plane,
    threads: usize,
    /// Level-width cutoff the packed analysis ran with — kept so a
    /// structure-changing refactorization (and the f32→f64 fallback)
    /// can re-analyze identically.
    cutoff: usize,
}

impl LdlPrecond {
    /// Sequential-solve preconditioner (always f64 — the sequential
    /// factor solve has no narrowed storage plane).
    pub fn new(mut factor: LdlFactor) -> LdlPrecond {
        factor.stats.precision = Precision::F64;
        LdlPrecond {
            factor,
            plane: Plane::Seq,
            threads: 1,
            cutoff: crate::solve::packed::default_cutoff(),
        }
    }

    /// Level-scheduled parallel solves with `threads` workers and the
    /// [default cutoff](crate::solve::packed::default_cutoff) (the
    /// "analysis" — level schedules plus the packed level-major copy —
    /// runs here, once, mirroring cuSPARSE SPSV analysis).
    pub fn with_level_schedule(factor: LdlFactor, threads: usize) -> LdlPrecond {
        Self::with_level_schedule_cutoff(factor, threads, crate::solve::packed::default_cutoff())
    }

    /// [`LdlPrecond::with_level_schedule`] with an explicit level-width
    /// cutoff (the [`crate::solver::SolverBuilder::level_cutoff`]
    /// knob): levels narrower than `cutoff` run sequentially on the
    /// resident participant 0 instead of being split. The analysis
    /// itself runs pooled with the same `threads` budget.
    pub fn with_level_schedule_cutoff(
        factor: LdlFactor,
        threads: usize,
        cutoff: usize,
    ) -> LdlPrecond {
        Self::with_level_schedule_precision(factor, threads, cutoff, Precision::F64)
    }

    /// [`LdlPrecond::with_level_schedule_cutoff`] with an explicit
    /// value-storage plane, selected **at analyze time**: `F64` packs
    /// 8-byte values (bit-identical to the sequential reference),
    /// `F32` packs 4-byte values — half the bytes streamed per apply
    /// on this bandwidth-bound kernel — with f64 accumulation and the
    /// automatic f64 fallback documented on
    /// [`Preconditioner::promote_to_f64`].
    pub fn with_level_schedule_precision(
        mut factor: LdlFactor,
        threads: usize,
        cutoff: usize,
        precision: Precision,
    ) -> LdlPrecond {
        factor.stats.precision = precision;
        let plane = match precision {
            Precision::F64 => {
                Plane::F64(PackedSweeps::<f64>::analyze_with_opts(&factor, cutoff, threads))
            }
            Precision::F32 => Plane::F32 {
                packed: PackedSweeps::<f32>::analyze_with_opts(&factor, cutoff, threads),
                fallback: OnceLock::new(),
                promoted: AtomicBool::new(false),
            },
        };
        LdlPrecond { factor, plane, threads, cutoff }
    }

    /// Access the wrapped factor.
    pub fn factor(&self) -> &LdlFactor {
        &self.factor
    }

    /// Critical path of the solve DAG (None if sequential mode).
    pub fn critical_path(&self) -> Option<usize> {
        match &self.plane {
            Plane::Seq => None,
            Plane::F64(p) => Some(p.critical_path),
            Plane::F32 { packed, .. } => Some(packed.critical_path),
        }
    }

    /// The storage plane selected at analyze time (what
    /// `FactorStats::precision` records). Unlike
    /// [`Preconditioner::precision`], this does **not** change when
    /// the fallback guard promotes an f32 plane mid-solve.
    pub fn selected_precision(&self) -> Precision {
        match &self.plane {
            Plane::F32 { .. } => Precision::F32,
            _ => Precision::F64,
        }
    }

    /// Swap a renumbered factor in under the preconditioner: `rebuild`
    /// mutates the wrapped factor in place (typically
    /// [`crate::factor::SymbolicFactor::refactorize_into`]) and returns
    /// whether the factor's sparsity structure was preserved. If so,
    /// the packed executor — and, in f32 mode, any materialized f64
    /// fallback plane — is [refilled](PackedSweeps::refill) in place
    /// (no allocation, schedules and counters untouched); otherwise
    /// the packed analysis is redone at the original cutoff, thread
    /// budget, and precision. Returns the closure's verdict.
    pub fn refactorize_numeric<E>(
        &mut self,
        rebuild: impl FnOnce(&mut LdlFactor) -> Result<bool, E>,
    ) -> Result<bool, E> {
        let preserved = rebuild(&mut self.factor)?;
        // Rebuilds reset the factor's stats snapshot; restamp the plane.
        self.factor.stats.precision = match &self.plane {
            Plane::F32 { .. } => Precision::F32,
            _ => Precision::F64,
        };
        match &mut self.plane {
            Plane::Seq => {}
            Plane::F64(packed) => {
                if preserved {
                    packed.refill(&self.factor);
                } else {
                    *packed = PackedSweeps::<f64>::analyze_with_opts(
                        &self.factor,
                        self.cutoff,
                        self.threads,
                    );
                }
            }
            Plane::F32 { packed, fallback, .. } => {
                if preserved {
                    packed.refill(&self.factor);
                    if let Some(fb) = fallback.get_mut() {
                        fb.refill(&self.factor);
                    }
                } else {
                    *packed = PackedSweeps::<f32>::analyze_with_opts(
                        &self.factor,
                        self.cutoff,
                        self.threads,
                    );
                    if fallback.get().is_some() {
                        let fresh = OnceLock::new();
                        let _ = fresh.set(PackedSweeps::<f64>::analyze_with_opts(
                            &self.factor,
                            self.cutoff,
                            self.threads,
                        ));
                        *fallback = fresh;
                    }
                }
            }
        }
        Ok(preserved)
    }
}

impl Preconditioner for LdlPrecond {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        // Convenience shim: allocates the scratch per call. The hot
        // path is `apply_scratch` with reused caller buffers.
        let mut a = vec![0.0; r.len()];
        let mut b = vec![0.0; r.len()];
        self.apply_scratch(r, z, &mut a, &mut b);
    }

    fn apply_scratch(&self, r: &[f64], z: &mut [f64], a: &mut [f64], b: &mut [f64]) {
        match &self.plane {
            Plane::Seq => self.factor.solve_into(r, z, a),
            Plane::F64(packed) => packed.apply_into(r, z, self.threads, a, b),
            Plane::F32 { packed, fallback, promoted } => {
                if promoted.load(Ordering::Acquire) {
                    // Promotion publishes the fallback before the flag
                    // (see `promote_to_f64`), so `get()` cannot miss.
                    fallback
                        .get()
                        .expect("promoted flag implies fallback plane")
                        .apply_into(r, z, self.threads, a, b)
                } else {
                    packed.apply_into(r, z, self.threads, a, b)
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "parac"
    }

    fn nnz(&self) -> usize {
        self.factor.nnz() + self.factor.n()
    }

    fn sweep_counters(&self) -> Option<SweepCounters> {
        match &self.plane {
            Plane::Seq => None,
            Plane::F64(p) => Some(p.counters()),
            Plane::F32 { packed, fallback, .. } => {
                let a = packed.counters();
                let b = fallback.get().map(|p| p.counters()).unwrap_or_default();
                Some(SweepCounters {
                    dispatches: a.dispatches + b.dispatches,
                    barriers: a.barriers + b.barriers,
                })
            }
        }
    }

    fn precision(&self) -> Precision {
        match &self.plane {
            Plane::Seq | Plane::F64(_) => Precision::F64,
            Plane::F32 { promoted, .. } => {
                if promoted.load(Ordering::Acquire) {
                    Precision::F64
                } else {
                    Precision::F32
                }
            }
        }
    }

    fn promote_to_f64(&self) -> bool {
        match &self.plane {
            Plane::Seq | Plane::F64(_) => false,
            Plane::F32 { fallback, promoted, .. } => {
                // Build (or reuse) the f64 plane, then publish the
                // flag. The one-time analysis here is the documented
                // allocation exception to the zero-alloc solve
                // contract — it happens at most once per executor.
                fallback.get_or_init(|| {
                    PackedSweeps::<f64>::analyze_with_opts(&self.factor, self.cutoff, self.threads)
                });
                !promoted.swap(true, Ordering::AcqRel)
            }
        }
    }

    fn as_ldl(&self) -> Option<&LdlPrecond> {
        Some(self)
    }

    fn as_ldl_mut(&mut self) -> Option<&mut LdlPrecond> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, ParacOptions};
    use crate::graph::generators;
    use crate::solve::pcg;

    #[test]
    fn parac_preconditioned_cg_converges_fast() {
        let l = generators::grid2d(24, 24, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let pre = LdlPrecond::new(f);
        let b = pcg::random_rhs(&l, 3);
        let o = pcg::PcgOptions { max_iter: 300, ..Default::default() };
        let out = pcg::solve(&l.matrix, &b, &pre, &o);
        assert!(out.converged, "rel={} iters={}", out.rel_residual, out.iters);
        // Must beat unpreconditioned CG decisively.
        let plain = pcg::solve(&l.matrix, &b, &super::super::IdentityPrecond, &o);
        assert!(
            out.iters * 2 < plain.iters.max(1) || plain.iters == o.max_iter,
            "parac {} vs plain {}",
            out.iters,
            plain.iters
        );
    }

    #[test]
    fn level_parallel_apply_matches_sequential() {
        let l = generators::grid3d(6, 6, 6, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let seq = LdlPrecond::new(f.clone());
        // A small cutoff so the packed executor genuinely dispatches
        // and barriers on this grid.
        let par = LdlPrecond::with_level_schedule_cutoff(f, 4, 8);
        let b = pcg::random_rhs(&l, 9);
        let a = seq.apply(&b);
        let c = par.apply(&b);
        assert_eq!(a, c, "packed parallel apply must be bit-identical to sequential");
        assert!(par.critical_path().unwrap() >= 1);
        let counters = par.sweep_counters().unwrap();
        assert_eq!(counters.dispatches, 2, "one pool dispatch per sweep direction");
        assert!(seq.sweep_counters().is_none());
    }

    #[test]
    fn apply_into_matches_factor_solve() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 2);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let want = f.solve(&pcg::random_rhs(&l, 4));
        let pre = LdlPrecond::new(f);
        let mut z = vec![0.0; l.n()];
        pre.apply_into(&pcg::random_rhs(&l, 4), &mut z);
        assert_eq!(z, want);
    }

    #[test]
    fn f32_plane_applies_close_and_reports_its_precision() {
        let l = generators::grid2d(20, 20, generators::Coeff::Uniform, 5);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let p64 = LdlPrecond::with_level_schedule_cutoff(f.clone(), 2, 4);
        let p32 = LdlPrecond::with_level_schedule_precision(f, 2, 4, Precision::F32);
        assert_eq!(p64.precision(), Precision::F64);
        assert_eq!(p32.precision(), Precision::F32);
        assert_eq!(p32.selected_precision(), Precision::F32);
        assert_eq!(p32.factor().stats.precision, Precision::F32);
        let b = pcg::random_rhs(&l, 11);
        let z64 = p64.apply(&b);
        let z32 = p32.apply(&b);
        let scale = z64.iter().fold(0.0f64, |m, v| m.max(v.abs())).max(1.0);
        for (i, (x, y)) in z64.iter().zip(&z32).enumerate() {
            assert!((x - y).abs() <= 1e-4 * scale, "f32 apply drifted at {i}: {x} vs {y}");
        }
    }

    #[test]
    fn promotion_switches_the_apply_to_the_f64_plane_once() {
        let l = generators::grid2d(16, 16, generators::Coeff::Uniform, 6);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let p64 = LdlPrecond::with_level_schedule_cutoff(f.clone(), 2, 4);
        let p32 = LdlPrecond::with_level_schedule_precision(f, 2, 4, Precision::F32);
        let b = pcg::random_rhs(&l, 13);
        // Before promotion: f32 plane, not bit-identical to f64.
        assert_eq!(p32.precision(), Precision::F32);
        // First promotion reports the transition, repeats don't.
        assert!(p32.promote_to_f64());
        assert!(!p32.promote_to_f64());
        assert_eq!(p32.precision(), Precision::F64);
        // Selected precision (the analyze-time choice) is unchanged.
        assert_eq!(p32.selected_precision(), Precision::F32);
        // After promotion the apply routes through the f64 plane —
        // bit-identical to a preconditioner built in f64 directly.
        assert_eq!(p32.apply(&b), p64.apply(&b));
        // Non-f32 preconditioners never promote.
        assert!(!p64.promote_to_f64());
    }
}
