//! The ParAC factor as a PCG preconditioner, with an optional
//! level-scheduled parallel triangular solve (the paper's GPU solve
//! path; cf. Table 3's SPSV analysis stage).
//!
//! Level-scheduled mode runs the **packed sweep executor**
//! ([`crate::solve::packed::PackedSweeps`]): at construction the factor
//! is renumbered into level-major order and copied contiguously per
//! sweep direction, and each apply then costs at most one persistent
//! worker-pool dispatch per sweep — two total, independent of the DAG
//! depth — with the `D⁻¹` scaling and the fill-reducing permutation
//! fused into the boundary/scatter passes.
//!
//! The apply is allocation-free in **both** modes: the intermediates
//! live in scratch buffers sized once at construction (behind an
//! uncontended `Mutex` so the preconditioner stays `Sync`; PCG applies
//! it sequentially, so the lock never blocks and never allocates), and
//! pool dispatch allocates nothing after warm-up (see the assertion in
//! `rust/tests/alloc_free.rs`).

use super::Preconditioner;
use crate::factor::LdlFactor;
use crate::solve::packed::{PackedSweeps, SweepCounters};
use std::sync::Mutex;

/// Reusable apply intermediates (one buffer per sweep direction; the
/// sequential mode uses only the first).
struct Scratch {
    a: Vec<f64>,
    b: Vec<f64>,
}

/// `z = (G D Gᵀ)⁺ r`, sequential or level-parallel (packed executor).
pub struct LdlPrecond {
    factor: LdlFactor,
    packed: Option<PackedSweeps>,
    threads: usize,
    /// Level-width cutoff the packed analysis ran with — kept so a
    /// structure-changing refactorization can re-analyze identically.
    cutoff: usize,
    scratch: Mutex<Scratch>,
}

impl LdlPrecond {
    /// Sequential-solve preconditioner.
    pub fn new(factor: LdlFactor) -> LdlPrecond {
        let scratch = Scratch {
            a: vec![0.0; if factor.perm.is_some() { factor.n() } else { 0 }],
            b: Vec::new(),
        };
        LdlPrecond {
            factor,
            packed: None,
            threads: 1,
            cutoff: crate::solve::packed::default_cutoff(),
            scratch: Mutex::new(scratch),
        }
    }

    /// Level-scheduled parallel solves with `threads` workers and the
    /// [default cutoff](crate::solve::packed::default_cutoff) (the
    /// "analysis" — level schedules plus the packed level-major copy —
    /// runs here, once, mirroring cuSPARSE SPSV analysis).
    pub fn with_level_schedule(factor: LdlFactor, threads: usize) -> LdlPrecond {
        Self::with_level_schedule_cutoff(factor, threads, crate::solve::packed::default_cutoff())
    }

    /// [`LdlPrecond::with_level_schedule`] with an explicit level-width
    /// cutoff (the [`crate::solver::SolverBuilder::level_cutoff`]
    /// knob): levels narrower than `cutoff` run sequentially on the
    /// resident participant 0 instead of being split. The analysis
    /// itself runs pooled with the same `threads` budget.
    pub fn with_level_schedule_cutoff(
        factor: LdlFactor,
        threads: usize,
        cutoff: usize,
    ) -> LdlPrecond {
        let packed = PackedSweeps::analyze_with_opts(&factor, cutoff, threads);
        let scratch = Scratch { a: vec![0.0; factor.n()], b: vec![0.0; factor.n()] };
        LdlPrecond { factor, packed: Some(packed), threads, cutoff, scratch: Mutex::new(scratch) }
    }

    /// Access the wrapped factor.
    pub fn factor(&self) -> &LdlFactor {
        &self.factor
    }

    /// Critical path of the solve DAG (None if sequential mode).
    pub fn critical_path(&self) -> Option<usize> {
        self.packed.as_ref().map(|p| p.critical_path)
    }

    /// Swap a renumbered factor in under the preconditioner: `rebuild`
    /// mutates the wrapped factor in place (typically
    /// [`crate::factor::SymbolicFactor::refactorize_into`]) and returns
    /// whether the factor's sparsity structure was preserved. If so,
    /// the packed executor is [refilled](PackedSweeps::refill) in place
    /// — no allocation, schedules and counters untouched; otherwise the
    /// packed analysis is redone at the original cutoff and thread
    /// budget. Returns the closure's verdict.
    pub fn refactorize_numeric<E>(
        &mut self,
        rebuild: impl FnOnce(&mut LdlFactor) -> Result<bool, E>,
    ) -> Result<bool, E> {
        let preserved = rebuild(&mut self.factor)?;
        if let Some(packed) = &mut self.packed {
            if preserved {
                packed.refill(&self.factor);
            } else {
                *packed = PackedSweeps::analyze_with_opts(&self.factor, self.cutoff, self.threads);
            }
        }
        Ok(preserved)
    }
}

impl Preconditioner for LdlPrecond {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        // A poisoned lock only means another apply panicked mid-solve;
        // the buffer contents are overwritten anyway, so recover.
        let mut scratch = self.scratch.lock().unwrap_or_else(|p| p.into_inner());
        match &self.packed {
            None => self.factor.solve_into(r, z, &mut scratch.a[..]),
            Some(packed) => {
                let Scratch { a, b } = &mut *scratch;
                packed.apply_into(r, z, self.threads, &mut a[..], &mut b[..]);
            }
        }
    }

    fn name(&self) -> &'static str {
        "parac"
    }

    fn nnz(&self) -> usize {
        self.factor.nnz() + self.factor.n()
    }

    fn sweep_counters(&self) -> Option<SweepCounters> {
        self.packed.as_ref().map(|p| p.counters())
    }

    fn as_ldl(&self) -> Option<&LdlPrecond> {
        Some(self)
    }

    fn as_ldl_mut(&mut self) -> Option<&mut LdlPrecond> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{factorize, ParacOptions};
    use crate::graph::generators;
    use crate::solve::pcg;

    #[test]
    fn parac_preconditioned_cg_converges_fast() {
        let l = generators::grid2d(24, 24, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let pre = LdlPrecond::new(f);
        let b = pcg::random_rhs(&l, 3);
        let o = pcg::PcgOptions { max_iter: 300, ..Default::default() };
        let out = pcg::solve(&l.matrix, &b, &pre, &o);
        assert!(out.converged, "rel={} iters={}", out.rel_residual, out.iters);
        // Must beat unpreconditioned CG decisively.
        let plain = pcg::solve(&l.matrix, &b, &super::super::IdentityPrecond, &o);
        assert!(
            out.iters * 2 < plain.iters.max(1) || plain.iters == o.max_iter,
            "parac {} vs plain {}",
            out.iters,
            plain.iters
        );
    }

    #[test]
    fn level_parallel_apply_matches_sequential() {
        let l = generators::grid3d(6, 6, 6, generators::Coeff::Uniform, 0);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let seq = LdlPrecond::new(f.clone());
        // A small cutoff so the packed executor genuinely dispatches
        // and barriers on this grid.
        let par = LdlPrecond::with_level_schedule_cutoff(f, 4, 8);
        let b = pcg::random_rhs(&l, 9);
        let a = seq.apply(&b);
        let c = par.apply(&b);
        assert_eq!(a, c, "packed parallel apply must be bit-identical to sequential");
        assert!(par.critical_path().unwrap() >= 1);
        let counters = par.sweep_counters().unwrap();
        assert_eq!(counters.dispatches, 2, "one pool dispatch per sweep direction");
        assert!(seq.sweep_counters().is_none());
    }

    #[test]
    fn apply_into_matches_factor_solve() {
        let l = generators::grid2d(12, 12, generators::Coeff::Uniform, 2);
        let f = factorize(&l, &ParacOptions::default()).unwrap();
        let want = f.solve(&pcg::random_rhs(&l, 4));
        let pre = LdlPrecond::new(f);
        let mut z = vec![0.0; l.n()];
        pre.apply_into(&pcg::random_rhs(&l, 4), &mut z);
        assert_eq!(z, want);
    }
}
