//! Threshold-drop incomplete Cholesky — ICT, the MATLAB `ichol(...,
//! 'ict')` stand-in of Table 2. The paper tunes its drop tolerance so
//! the fill is on-par with ParAC's; [`IcholT::with_fill_target`]
//! automates exactly that calibration.
//!
//! Left-looking column algorithm with the classic
//! column-lists-by-next-row structure; entries below
//! `droptol · ‖A(:,j)‖₁` are discarded immediately.

use super::Preconditioner;
use crate::error::ParacError;
use crate::sparse::Csr;

const NIL: u32 = u32::MAX;

/// ICT factor `A ≈ L Lᵀ`.
pub struct IcholT {
    /// Strictly-lower columns of `L` (CSC-like growing arrays).
    colptr: Vec<usize>,
    rowidx: Vec<u32>,
    data: Vec<f64>,
    /// Diagonal of `L`.
    diag: Vec<f64>,
    /// Diagonal shift used (0.0 when clean).
    pub shift: f64,
    /// Drop tolerance used.
    pub droptol: f64,
}

impl IcholT {
    /// Build with an explicit drop tolerance. Panics on unrecoverable
    /// breakdown — use [`IcholT::try_new`] for the error-propagating
    /// path.
    pub fn new(a: &Csr, droptol: f64) -> IcholT {
        match Self::try_new(a, droptol) {
            Ok(f) => f,
            Err(e) => panic!("{e}"),
        }
    }

    /// Build with an explicit drop tolerance; unrecoverable breakdown
    /// (e.g. an indefinite input) comes back as
    /// [`ParacError::BadInput`] instead of panicking.
    pub fn try_new(a: &Csr, droptol: f64) -> Result<IcholT, ParacError> {
        let base = a.diag().iter().cloned().fold(0.0, f64::max);
        let mut shift = 0.0;
        loop {
            if let Some(f) = Self::attempt(a, droptol, shift) {
                return Ok(f);
            }
            shift = if shift == 0.0 { 1e-8 * base.max(1.0) } else { shift * 10.0 };
            if shift >= base.max(1.0) {
                return Err(ParacError::BadInput(format!(
                    "ICT breakdown not recoverable (shift {shift})"
                )));
            }
        }
    }

    /// Calibrate the drop tolerance so `nnz(L)` lands within ~25% of
    /// `target_nnz` (the paper's "fill on-par with ParAC" protocol).
    /// Returns the calibrated factor.
    pub fn with_fill_target(a: &Csr, target_nnz: usize) -> IcholT {
        match Self::try_with_fill_target(a, target_nnz) {
            Ok(f) => f,
            Err(e) => panic!("{e}"),
        }
    }

    /// Error-propagating [`IcholT::with_fill_target`].
    pub fn try_with_fill_target(a: &Csr, target_nnz: usize) -> Result<IcholT, ParacError> {
        let mut tol = 1e-2;
        let mut best = Self::try_new(a, tol)?;
        for _ in 0..8 {
            let got = best.nnz();
            let ratio = got as f64 / target_nnz.max(1) as f64;
            if (0.75..=1.25).contains(&ratio) {
                break;
            }
            // More fill ⇒ need a larger tolerance.
            tol *= ratio.clamp(0.2, 5.0).powf(1.2);
            best = Self::try_new(a, tol)?;
        }
        Ok(best)
    }

    fn attempt(a: &Csr, droptol: f64, shift: f64) -> Option<IcholT> {
        let n = a.nrows;
        let mut colptr = vec![0usize];
        let mut rowidx: Vec<u32> = Vec::with_capacity(a.nnz());
        let mut data: Vec<f64> = Vec::with_capacity(a.nnz());
        let mut diag = vec![0.0f64; n];
        // Column lists: head[i] = first column whose next nonzero row is
        // i; next[k] links columns; pos[k] = cursor into column k.
        let mut head = vec![NIL; n];
        let mut next = vec![NIL; n];
        let mut pos = vec![0usize; n];
        // Sparse accumulator.
        let mut acc = vec![0.0f64; n];
        let mut marked = vec![false; n];
        let mut rows_here: Vec<u32> = Vec::new();
        // Column 1-norms of A (drop reference).
        let colnorm: Vec<f64> = (0..n)
            .map(|j| a.row_data(j).iter().map(|v| v.abs()).sum::<f64>())
            .collect();

        for j in 0..n {
            rows_here.clear();
            let mut dval = shift;
            for (&c, &v) in a.row_indices(j).iter().zip(a.row_data(j)) {
                let c = c as usize;
                if c == j {
                    dval += v;
                } else if c > j {
                    acc[c] = v;
                    marked[c] = true;
                    rows_here.push(c as u32);
                }
            }
            // Left-looking updates from all columns k with L[j,k] ≠ 0.
            let mut k = head[j];
            while k != NIL {
                let k_next = next[k as usize];
                let kc = k as usize;
                let ljk = data[pos[kc]];
                dval -= ljk * ljk;
                for idx in (pos[kc] + 1)..colptr[kc + 1] {
                    let i = rowidx[idx] as usize;
                    if !marked[i] {
                        marked[i] = true;
                        acc[i] = 0.0;
                        rows_here.push(i as u32);
                    }
                    acc[i] -= ljk * data[idx];
                }
                // Advance k's cursor and relink under its next row.
                pos[kc] += 1;
                if pos[kc] < colptr[kc + 1] {
                    let nr = rowidx[pos[kc]] as usize;
                    next[kc] = head[nr];
                    head[nr] = k;
                }
                k = k_next;
            }
            // Pivot.
            if dval <= 0.0 {
                let scale = a.get(j, j).abs().max(1.0);
                if dval.abs() <= 1e-10 * scale {
                    diag[j] = 0.0;
                    for &i in &rows_here {
                        marked[i as usize] = false;
                    }
                    colptr.push(rowidx.len());
                    continue;
                }
                return None;
            }
            let d = dval.sqrt();
            diag[j] = d;
            // Scale, drop, store (rows sorted).
            rows_here.sort_unstable();
            let tau = droptol * colnorm[j];
            let start = rowidx.len();
            for &i in &rows_here {
                let v = acc[i as usize] / d;
                marked[i as usize] = false;
                if v.abs() * d >= tau {
                    rowidx.push(i);
                    data.push(v);
                }
            }
            colptr.push(rowidx.len());
            // Link column j under its first off-diagonal row.
            pos[j] = start;
            if start < rowidx.len() {
                let nr = rowidx[start] as usize;
                next[j] = head[nr];
                head[nr] = j as u32;
            }
        }
        Some(IcholT { colptr, rowidx, data, diag, shift, droptol })
    }

    /// Stored entries (off-diagonal + diagonal).
    pub fn nnz(&self) -> usize {
        self.rowidx.len() + self.diag.iter().filter(|&&d| d != 0.0).count()
    }
}

impl Preconditioner for IcholT {
    fn apply_into(&self, r: &[f64], z: &mut [f64]) {
        let n = self.diag.len();
        // Forward L y = r (CSC scatter), in place in z.
        z.copy_from_slice(r);
        for j in 0..n {
            let d = self.diag[j];
            if d == 0.0 {
                z[j] = 0.0;
                continue;
            }
            z[j] /= d;
            let yj = z[j];
            for idx in self.colptr[j]..self.colptr[j + 1] {
                z[self.rowidx[idx] as usize] -= self.data[idx] * yj;
            }
        }
        // Backward Lᵀ z = y (CSC gather).
        for j in (0..n).rev() {
            let d = self.diag[j];
            if d == 0.0 {
                z[j] = 0.0;
                continue;
            }
            let mut accv = z[j];
            for idx in self.colptr[j]..self.colptr[j + 1] {
                accv -= self.data[idx] * z[self.rowidx[idx] as usize];
            }
            z[j] = accv / d;
        }
    }

    fn name(&self) -> &'static str {
        "icholt"
    }

    fn nnz(&self) -> usize {
        // Explicitly the inherent method (same name as this trait method).
        IcholT::nnz(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::solve::pcg;

    #[test]
    fn zero_droptol_is_exact_cholesky() {
        // With droptol = 0 (keep everything), ICT == complete Cholesky:
        // PCG converges immediately on an SPD system.
        let l = generators::grid2d(7, 7, generators::Coeff::Uniform, 0);
        let mut coo = crate::sparse::Coo::new(l.n(), l.n());
        for r in 0..l.n() {
            for (&c, &v) in l.matrix.row_indices(r).iter().zip(l.matrix.row_data(r)) {
                coo.push(r as u32, c, v);
            }
            coo.push(r as u32, r as u32, 0.05);
        }
        let a = coo.to_csr();
        let f = IcholT::new(&a, 0.0);
        let b: Vec<f64> = (0..a.nrows).map(|i| ((i * 7) % 5) as f64 - 2.0).collect();
        let o = pcg::PcgOptions { project: false, ..Default::default() };
        let out = pcg::solve(&a, &b, &f, &o);
        assert!(out.iters <= 2, "exact Cholesky must converge instantly, took {}", out.iters);
    }

    #[test]
    fn larger_droptol_less_fill_more_iterations() {
        let l = generators::grid2d(20, 20, generators::Coeff::Uniform, 0);
        let tight = IcholT::new(&l.matrix, 1e-4);
        let loose = IcholT::new(&l.matrix, 5e-2);
        assert!(tight.nnz() > loose.nnz());
        let b = pcg::random_rhs(&l, 1);
        let o = pcg::PcgOptions { max_iter: 3000, ..Default::default() };
        let it_t = pcg::solve(&l.matrix, &b, &tight, &o).iters;
        let it_l = pcg::solve(&l.matrix, &b, &loose, &o).iters;
        assert!(it_t <= it_l, "tight {it_t} vs loose {it_l}");
    }

    #[test]
    fn fill_target_calibration() {
        let l = generators::grid2d(24, 24, generators::Coeff::Uniform, 0);
        let target = l.matrix.nnz(); // aim for ~input fill
        let f = IcholT::with_fill_target(&l.matrix, target);
        let ratio = f.nnz() as f64 / target as f64;
        assert!(
            (0.4..=2.5).contains(&ratio),
            "calibrated fill ratio {ratio} too far from 1"
        );
    }

    #[test]
    fn solves_laplacian_system() {
        let l = generators::grid2d(16, 16, generators::Coeff::HighContrast(3.0), 2);
        let f = IcholT::new(&l.matrix, 1e-3);
        let b = pcg::random_rhs(&l, 4);
        let o = pcg::PcgOptions { max_iter: 2000, ..Default::default() };
        let out = pcg::solve(&l.matrix, &b, &f, &o);
        assert!(out.converged, "rel={}", out.rel_residual);
    }
}
