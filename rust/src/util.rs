//! Small utilities: timers, formatting, summary statistics.

use std::time::Instant;

/// Wall-clock timer with millisecond reporting.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

/// Human-readable duration (`1.23 s`, `45.6 ms`, `789 µs`).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.0} µs", secs * 1e6)
    }
}

/// Human-readable count (`1.5M`, `23.4k`).
pub fn fmt_count(n: usize) -> String {
    let n = n as f64;
    if n >= 1e6 {
        format!("{:.1}M", n / 1e6)
    } else if n >= 1e3 {
        format!("{:.1}k", n / 1e3)
    } else {
        format!("{n:.0}")
    }
}

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
    (mean, var.sqrt())
}

/// Median (copies and sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let m = v.len() / 2;
    if v.len() % 2 == 1 {
        v[m]
    } else {
        0.5 * (v[m - 1] + v[m])
    }
}

/// Number of worker threads to use by default (respects
/// `PARAC_THREADS`, falls back to available parallelism).
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("PARAC_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(2.5), "2.50 s");
        assert_eq!(fmt_duration(0.0456), "45.60 ms");
        assert_eq!(fmt_duration(0.000789), "789 µs");
        assert_eq!(fmt_count(1_500_000), "1.5M");
        assert_eq!(fmt_count(23_400), "23.4k");
        assert_eq!(fmt_count(42), "42");
    }

    #[test]
    fn stats() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.ms() >= 1.0);
    }
}
