//! Elimination-tree analytics — everything Figure 4 measures.
//!
//! * [`etree_classical`] — Liu's union-find e-tree of the *classical*
//!   (no-dropping) Cholesky factorization, computed symbolically from the
//!   input matrix. Its height is the paper's "classical e-tree height":
//!   the dependency depth a conventional parallel factorization would be
//!   limited by.
//! * [`etree_from_factor`] — the *actual* e-tree of a computed randomized
//!   factor (parent = first sub-diagonal nonzero per column). Sampling
//!   cuts edges, so this tree is much shallower — the source of ParAC's
//!   extra parallelism (paper §4.1).
//! * [`trisolve_levels`] — level schedule / critical path of the
//!   triangular-solve DAG of the factor ("longest path" in Fig. 4),
//!   which bounds parallel triangular-solve performance.
//! * [`trisolve_levels_bwd`] / [`bucket_by_level`] — the transpose-DAG
//!   levels of the backward sweep and the level-major vertex grouping;
//!   together with [`trisolve_levels`] these are the full "analysis
//!   phase" consumed by [`crate::solve::trisolve::LevelSchedule`] and
//!   the packed executor [`crate::solve::packed::PackedSweeps`].
//! * [`trisolve_levels_par`] / [`trisolve_levels_bwd_par`] /
//!   [`bucket_by_level_par`] — the same analysis on the persistent
//!   worker pool (a Kahn wavefront for the level schedules), each
//!   bit-identical to its sequential reference with a small-input
//!   fallback, so the symbolic phase itself scales with the solve
//!   threads.

use crate::sparse::{Csc, Csr};
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

/// Liu's elimination tree of the complete Cholesky factor of a symmetric
/// matrix, without forming the factor. Returns `parent[v]` (`-1` = root).
pub fn etree_classical(a: &Csr) -> Vec<i64> {
    let n = a.nrows;
    let mut parent = vec![-1i64; n];
    let mut ancestor = vec![-1i64; n];
    for i in 0..n {
        for &kc in a.row_indices(i) {
            let mut k = kc as i64;
            if k >= i as i64 {
                continue;
            }
            // Walk from k to the root of its current subtree, compressing
            // the path onto i.
            while ancestor[k as usize] != -1 && ancestor[k as usize] != i as i64 {
                let next = ancestor[k as usize];
                ancestor[k as usize] = i as i64;
                k = next;
            }
            if ancestor[k as usize] == -1 {
                ancestor[k as usize] = i as i64;
                parent[k as usize] = i as i64;
            }
        }
    }
    parent
}

/// E-tree of a computed (possibly incomplete/randomized) factor: the
/// parent of column `k` is the first sub-diagonal nonzero row in `G(:,k)`.
/// `g` stores the strictly-lower part of the unit-lower factor in CSC.
pub fn etree_from_factor(g: &Csc) -> Vec<i64> {
    let n = g.ncols;
    let mut parent = vec![-1i64; n];
    for k in 0..n {
        let rows = g.col_rows(k);
        if let Some(&r) = rows.first() {
            parent[k] = r as i64;
        }
    }
    parent
}

/// Height of a forest given `parent` pointers (levels counted in
/// vertices: an isolated vertex has height 1). Requires the e-tree
/// property `parent[v] > v`.
pub fn tree_height(parent: &[i64]) -> usize {
    let n = parent.len();
    let mut depth = vec![1u32; n];
    let mut best = if n == 0 { 0 } else { 1 };
    // parent > child, so a single ascending pass computes depths.
    for v in 0..n {
        let p = parent[v];
        if p >= 0 {
            debug_assert!(p as usize > v, "e-tree parents must have larger labels");
            let d = depth[v] + 1;
            if d > depth[p as usize] {
                depth[p as usize] = d;
                if d as usize > best {
                    best = d as usize;
                }
            }
        }
    }
    best
}

/// Level schedule of the forward-triangular-solve DAG: `level[k] = 1 +
/// max level over columns j with G[k,j] ≠ 0`. Returns `(levels,
/// critical_path_len)`. `g` in CSC (strictly lower).
pub fn trisolve_levels(g: &Csc) -> (Vec<u32>, usize) {
    let n = g.ncols;
    let mut level = vec![1u32; n];
    let mut maxl = if n == 0 { 0 } else { 1 };
    // Column k finalizes level[k] before any row below it is visited —
    // ascending order works because dependencies point downward.
    for k in 0..n {
        let lk = level[k];
        if lk as usize > maxl {
            maxl = lk as usize;
        }
        for &r in g.col_rows(k) {
            let r = r as usize;
            if level[r] <= lk {
                level[r] = lk + 1;
            }
        }
    }
    (level, maxl)
}

/// Level schedule of the **backward** (transpose) triangular-solve DAG:
/// `level[k] = 1 + max level over rows r in column k of G` — dependencies
/// run from the far end of the elimination order, so the pass walks the
/// columns descending. Returns `(levels, critical_path_len)`. `g` in CSC
/// (strictly lower). The backward critical path can differ from the
/// forward one level-by-level, but both sweeps share the same DAG depth
/// bound.
pub fn trisolve_levels_bwd(g: &Csc) -> (Vec<u32>, usize) {
    let n = g.ncols;
    let mut level = vec![1u32; n];
    let mut maxl = if n == 0 { 0 } else { 1 };
    // Column k depends on every row below it; descending order
    // finalizes all of those rows' levels first.
    for k in (0..n).rev() {
        let mut l = 1u32;
        for &r in g.col_rows(k) {
            let lr = level[r as usize];
            if lr + 1 > l {
                l = lr + 1;
            }
        }
        level[k] = l;
        if l as usize > maxl {
            maxl = l as usize;
        }
    }
    (level, maxl)
}

/// [`trisolve_levels`] on the persistent worker pool: a Kahn wavefront
/// over the solve DAG. In-degrees (row counts of the factor's CSR view)
/// drop atomically as predecessors complete; the part whose decrement
/// hits zero owns the vertex — it writes the level and appends the
/// vertex to the shared frontier, so every slot is written exactly once.
/// Levels are a deterministic function of the DAG (1 + longest incoming
/// path), so the result is **bit-identical** to the sequential scan no
/// matter how the waves interleave. `rows` must be the CSR view of `g`
/// (same nonzeros, row-major — [`Csc::to_csr_with_src`]). Falls back to
/// the sequential pass for one part or small inputs.
pub fn trisolve_levels_par(g: &Csc, rows: &Csr, threads: usize) -> (Vec<u32>, usize) {
    let n = g.ncols;
    let pool = crate::par::global();
    let parts = threads.min(pool.size()).min(n.max(1));
    if parts <= 1 || n < 2048 {
        return trisolve_levels(g);
    }
    debug_assert_eq!(rows.nrows, n, "rows must be the CSR view of g");
    // Forward DAG: vertex r waits on every column k with G[r,k] != 0
    // (its row entries); completing k releases g.col_rows(k).
    wavefront_levels(n, parts, &rows.indptr, |k| g.col_rows(k))
}

/// [`trisolve_levels_bwd`] on the persistent worker pool — the same
/// Kahn wavefront as [`trisolve_levels_par`] run over the transpose
/// DAG: column k waits on its own rows (`g.col_rows(k)`, in-degrees are
/// column counts), and completing r releases every column whose row r
/// appears in (`rows.row_indices(r)`). Bit-identical to the sequential
/// pass; same small-input fallback. `rows` must be the CSR view of `g`.
pub fn trisolve_levels_bwd_par(g: &Csc, rows: &Csr, threads: usize) -> (Vec<u32>, usize) {
    let n = g.ncols;
    let pool = crate::par::global();
    let parts = threads.min(pool.size()).min(n.max(1));
    if parts <= 1 || n < 2048 {
        return trisolve_levels_bwd(g);
    }
    debug_assert_eq!(rows.nrows, n, "rows must be the CSR view of g");
    wavefront_levels(n, parts, &g.colptr, |r| rows.row_indices(r))
}

/// Shared engine of the two `_par` level schedules: one pool dispatch
/// running Kahn's algorithm by waves. `ptr` is the in-degree pointer
/// array of the dependency DAG (`indeg[v] = ptr[v+1] - ptr[v]`) and
/// `succ(v)` lists the vertices released when `v` completes.
///
/// All participants stay resident for the whole computation and meet at
/// a [`crate::par::SweepBarrier`] twice per wave: once after processing
/// their chunk of the current frontier window (during which zero-degree
/// discoveries are appended past the shared tail cursor), and once
/// after part 0 advances the window over the freshly appended run. The
/// append *order* within a wave is scheduling-dependent, but the
/// `(level, critical_path)` output never observes it.
fn wavefront_levels<'a, F>(n: usize, parts: usize, ptr: &[usize], succ: F) -> (Vec<u32>, usize)
where
    F: Fn(usize) -> &'a [u32] + Sync,
{
    let pool = crate::par::global();
    let indeg: Vec<AtomicU32> =
        (0..n).map(|v| AtomicU32::new((ptr[v + 1] - ptr[v]) as u32)).collect();
    let mut level = vec![0u32; n];
    let mut frontier = vec![0u32; n];
    let tail = AtomicUsize::new(0);
    let wave_lo = AtomicUsize::new(0);
    let wave_hi = AtomicUsize::new(0);
    let critical = AtomicUsize::new(if n == 0 { 0 } else { 1 });
    let barrier = crate::par::SweepBarrier::new();
    let level_ptr = crate::par::SendPtr::new(level.as_mut_ptr());
    let front_ptr = crate::par::SendPtr::new(frontier.as_mut_ptr());
    pool.run(parts, |part, parts| {
        // Seed wave: sources (in-degree zero) sit at level 1.
        let (lo, hi) = crate::par::chunk_range(n, part, parts);
        for v in lo..hi {
            if indeg[v].load(Ordering::Relaxed) == 0 {
                // SAFETY: v is in this part's disjoint chunk; the
                // frontier slot comes from the monotone tail cursor, so
                // both writes are exclusive. Readers are fenced by the
                // barrier below.
                unsafe { level_ptr.write(v, 1) };
                let slot = tail.fetch_add(1, Ordering::Relaxed);
                unsafe { front_ptr.write(slot, v as u32) };
            }
        }
        barrier.wait(parts);
        if part == 0 {
            wave_hi.store(tail.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        barrier.wait(parts);
        let mut cur = 1u32;
        loop {
            let wlo = wave_lo.load(Ordering::Relaxed);
            let whi = wave_hi.load(Ordering::Relaxed);
            if wlo == whi {
                break;
            }
            let (clo, chi) = crate::par::chunk_range(whi - wlo, part, parts);
            for i in (wlo + clo)..(wlo + chi) {
                // SAFETY: the window [wlo, whi) was fully written and
                // published (barrier) in the previous wave; parts read
                // disjoint chunks of it.
                let v = unsafe { front_ptr.read(i) } as usize;
                for &s in succ(v) {
                    let s = s as usize;
                    if indeg[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                        // SAFETY: exactly one decrement observes 1, so
                        // this part exclusively owns vertex s; the
                        // frontier slot is exclusive as in the seed.
                        unsafe { level_ptr.write(s, cur + 1) };
                        let slot = tail.fetch_add(1, Ordering::Relaxed);
                        unsafe { front_ptr.write(slot, s as u32) };
                    }
                }
            }
            barrier.wait(parts);
            if part == 0 {
                let t = tail.load(Ordering::Relaxed);
                wave_lo.store(whi, Ordering::Relaxed);
                wave_hi.store(t, Ordering::Relaxed);
                if t > whi {
                    critical.store(cur as usize + 1, Ordering::Relaxed);
                }
            }
            barrier.wait(parts);
            cur += 1;
        }
    });
    (level, critical.load(Ordering::Relaxed))
}

/// Group vertices by level into one concatenated, level-major order:
/// returns `(order, ptr)` where `order[ptr[t]..ptr[t + 1]]` lists the
/// vertices of level `t + 1` (levels are 1-based) in ascending vertex
/// id. This is the renumbering both sweep executors schedule by; the
/// packed executor additionally *stores* the factor in this order so a
/// sweep streams memory contiguously.
pub fn bucket_by_level(levels: &[u32], maxl: usize) -> (Vec<u32>, Vec<usize>) {
    let mut ptr = vec![0usize; maxl + 1];
    for &l in levels {
        ptr[(l - 1) as usize] += 1;
    }
    let mut acc = 0;
    for p in ptr.iter_mut() {
        let c = *p;
        *p = acc;
        acc += c;
    }
    let mut order = vec![0u32; levels.len()];
    let mut cursor = ptr.clone();
    for (v, &l) in levels.iter().enumerate() {
        order[cursor[(l - 1) as usize]] = v as u32;
        cursor[(l - 1) as usize] += 1;
    }
    (order, ptr)
}

/// [`bucket_by_level`] on the persistent worker pool: per-part level
/// histograms over contiguous vertex chunks, an exact per-(part, level)
/// offset table, then a disjoint parallel scatter. Chunks are ascending
/// vertex ranges, so within each level the concatenation of the parts'
/// contributions is globally ascending — the result is **bit-identical**
/// to the sequential [`bucket_by_level`] for every input. Falls back to
/// the sequential pass for one part or small inputs.
pub fn bucket_by_level_par(levels: &[u32], maxl: usize, threads: usize) -> (Vec<u32>, Vec<usize>) {
    let n = levels.len();
    let pool = crate::par::global();
    let parts = threads.min(pool.size()).min(n.max(1));
    if parts <= 1 || n < 2048 {
        return bucket_by_level(levels, maxl);
    }

    // Pass 1: per-part histograms over contiguous chunks.
    let mut hist = vec![0usize; parts * maxl];
    {
        let hist_ptr = crate::par::SendPtr::new(hist.as_mut_ptr());
        pool.run(parts, |part, parts| {
            let (lo, hi) = crate::par::chunk_range(n, part, parts);
            let base = part * maxl;
            for &l in &levels[lo..hi] {
                let at = base + (l - 1) as usize;
                // Disjoint rows of the histogram matrix: safe.
                unsafe { hist_ptr.write(at, hist_ptr.read(at) + 1) };
            }
        });
    }

    // Exact offsets: ptr[l] = total count below level l;
    // offset(part, l) = ptr[l] + Σ_{q < part} hist[q][l].
    let mut ptr = vec![0usize; maxl + 1];
    for l in 0..maxl {
        let mut c = 0;
        for p in 0..parts {
            c += hist[p * maxl + l];
        }
        ptr[l + 1] = ptr[l] + c;
    }
    let mut offsets = vec![0usize; parts * maxl];
    for l in 0..maxl {
        let mut acc = ptr[l];
        for p in 0..parts {
            offsets[p * maxl + l] = acc;
            acc += hist[p * maxl + l];
        }
    }

    // Pass 2: disjoint scatter — each (part, level) owns its own slice.
    let mut order = vec![0u32; n];
    {
        let order_ptr = crate::par::SendPtr::new(order.as_mut_ptr());
        let off_ptr = crate::par::SendPtr::new(offsets.as_mut_ptr());
        pool.run(parts, |part, parts| {
            let (lo, hi) = crate::par::chunk_range(n, part, parts);
            let base = part * maxl;
            for v in lo..hi {
                let l = (levels[v] - 1) as usize;
                // Each (part, level) pair owns a disjoint slice of
                // `order` starting at its offset: safe.
                unsafe {
                    let slot = off_ptr.read(base + l);
                    order_ptr.write(slot, v as u32);
                    off_ptr.write(base + l, slot + 1);
                }
            }
        });
    }
    (order, ptr)
}

/// Histogram of level widths — the parallelism profile (how many columns
/// can be processed concurrently at each step of a level-scheduled
/// solve).
pub fn level_histogram(levels: &[u32]) -> Vec<usize> {
    let maxl = levels.iter().copied().max().unwrap_or(0) as usize;
    let mut h = vec![0usize; maxl];
    for &l in levels {
        h[(l - 1) as usize] += 1;
    }
    h
}

/// Summary statistics for one factor — a Fig. 4 row.
#[derive(Clone, Debug)]
pub struct EtreeReport {
    /// Height of the classical (symbolic, no-drop) e-tree of the input.
    pub classical_height: usize,
    /// Height of the actual e-tree of the computed factor.
    pub actual_height: usize,
    /// Critical path of the factor's triangular-solve DAG.
    pub critical_path: usize,
    /// Fill ratio `2·nnz(G) / nnz(L)` as defined under Fig. 4.
    pub fill_ratio: f64,
}

/// Compute the full Fig. 4 metric set for `(input, factor)`.
pub fn report(input: &Csr, g: &Csc) -> EtreeReport {
    let classical = etree_classical(input);
    let actual = etree_from_factor(g);
    let (_, cp) = trisolve_levels(g);
    EtreeReport {
        classical_height: tree_height(&classical),
        actual_height: tree_height(&actual),
        critical_path: cp,
        fill_ratio: 2.0 * g.nnz() as f64 / input.nnz() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::sparse::Coo;

    #[test]
    fn path_etree_is_a_chain() {
        let l = generators::path(10);
        let parent = etree_classical(&l.matrix);
        for v in 0..9 {
            assert_eq!(parent[v], v as i64 + 1);
        }
        assert_eq!(parent[9], -1);
        assert_eq!(tree_height(&parent), 10);
    }

    #[test]
    fn star_etree_is_flat_when_hub_last() {
        // Star with hub at the end: no fill, all leaves point at hub.
        let n = 8u32;
        let edges: Vec<_> = (0..n - 1).map(|i| (i, n - 1, 1.0)).collect();
        let l = crate::graph::Laplacian::from_edges(n as usize, &edges, "star-last");
        let parent = etree_classical(&l.matrix);
        for v in 0..(n - 1) as usize {
            assert_eq!(parent[v], (n - 1) as i64);
        }
        assert_eq!(tree_height(&parent), 2);
    }

    #[test]
    fn grid_etree_height_between_bounds() {
        let l = generators::grid2d(8, 8, generators::Coeff::Uniform, 0);
        let parent = etree_classical(&l.matrix);
        let h = tree_height(&parent);
        assert!(h >= 8, "height {h} too small for an 8x8 grid");
        assert!(h <= 64);
    }

    #[test]
    fn factor_etree_and_levels() {
        // Hand-built strictly-lower factor on 4 columns:
        // col0 -> rows {1,3}, col1 -> {2}, col2 -> {}, col3 -> {}.
        let mut coo = Coo::new(4, 4);
        coo.push(1, 0, -0.5);
        coo.push(3, 0, -0.5);
        coo.push(2, 1, -1.0);
        let g = crate::sparse::Csc::from_csr(&coo.to_csr());
        let parent = etree_from_factor(&g);
        assert_eq!(parent, vec![1, 2, -1, -1]);
        assert_eq!(tree_height(&parent), 3);
        let (levels, cp) = trisolve_levels(&g);
        assert_eq!(levels, vec![1, 2, 3, 2]);
        assert_eq!(cp, 3);
        assert_eq!(level_histogram(&levels), vec![1, 2, 1]);
    }

    #[test]
    fn backward_levels_mirror_the_transpose_dag() {
        // Same hand-built factor as `factor_etree_and_levels`:
        // col0 -> rows {1,3}, col1 -> {2}. Backward dependencies point
        // from each column to its rows, so col0 waits on col1 (via row
        // 1) which waits on col2.
        let mut coo = Coo::new(4, 4);
        coo.push(1, 0, -0.5);
        coo.push(3, 0, -0.5);
        coo.push(2, 1, -1.0);
        let g = crate::sparse::Csc::from_csr(&coo.to_csr());
        let (levels, cp) = trisolve_levels_bwd(&g);
        assert_eq!(levels, vec![3, 2, 1, 1]);
        assert_eq!(cp, 3);
    }

    /// Deterministic strictly-lower pattern big enough for the pooled
    /// wavefront: each column scatters into a few rows below it at
    /// varied strides, giving a DAG with wide and narrow levels.
    fn synthetic_lower_factor(n: usize) -> Csc {
        let mut coo = Coo::new(n, n);
        for k in 0..n {
            let mut rows = std::collections::BTreeSet::new();
            if k + 1 < n {
                rows.insert(k + 1);
            }
            let far = k + 2 + (k % 37);
            if far < n {
                rows.insert(far);
            }
            let farther = k + 5 + (k % 101);
            if farther < n && k % 3 != 0 {
                rows.insert(farther);
            }
            for r in rows {
                coo.push(r as u32, k as u32, -1.0);
            }
        }
        Csc::from_csr(&coo.to_csr())
    }

    #[test]
    fn trisolve_levels_par_matches_sequential() {
        let g = synthetic_lower_factor(4096);
        let (rows, _src) = g.to_csr_with_src();
        let want_fwd = trisolve_levels(&g);
        let want_bwd = trisolve_levels_bwd(&g);
        assert!(want_fwd.1 > 3, "test DAG should have real depth");
        for threads in [1, 2, 3, 4, 7] {
            assert_eq!(trisolve_levels_par(&g, &rows, threads), want_fwd, "fwd threads={threads}");
            assert_eq!(
                trisolve_levels_bwd_par(&g, &rows, threads),
                want_bwd,
                "bwd threads={threads}"
            );
        }
    }

    #[test]
    fn trisolve_levels_par_small_input_falls_back() {
        // The hand-built 4-column factor from `factor_etree_and_levels`
        // takes the sequential fallback but must agree exactly.
        let mut coo = Coo::new(4, 4);
        coo.push(1, 0, -0.5);
        coo.push(3, 0, -0.5);
        coo.push(2, 1, -1.0);
        let g = crate::sparse::Csc::from_csr(&coo.to_csr());
        let (rows, _src) = g.to_csr_with_src();
        assert_eq!(trisolve_levels_par(&g, &rows, 4), (vec![1, 2, 3, 2], 3));
        assert_eq!(trisolve_levels_bwd_par(&g, &rows, 4), (vec![3, 2, 1, 1], 3));
        // Empty factor: everything level 1 on both sweeps.
        let z = crate::sparse::Csc::zero(5);
        let (zrows, _) = z.to_csr_with_src();
        assert_eq!(trisolve_levels_par(&z, &zrows, 4), trisolve_levels(&z));
        assert_eq!(trisolve_levels_bwd_par(&z, &zrows, 4), trisolve_levels_bwd(&z));
    }

    #[test]
    fn bucket_by_level_is_level_major_and_stable() {
        let levels = vec![2u32, 1, 2, 1, 3];
        let (order, ptr) = bucket_by_level(&levels, 3);
        assert_eq!(ptr, vec![0, 2, 4, 5]);
        // Within a level, vertices stay in ascending id order.
        assert_eq!(order, vec![1, 3, 0, 2, 4]);
        // Every vertex appears exactly once.
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn bucket_by_level_par_matches_sequential() {
        // Big enough to take the pooled path (n ≥ 2048), with a skewed
        // level distribution including empty interior levels.
        let n = 5000usize;
        let maxl = 9;
        let levels: Vec<u32> =
            (0..n).map(|v| 1 + ((v * v + 3 * v) % 11).min(maxl - 1) as u32).collect();
        let want = bucket_by_level(&levels, maxl);
        for threads in [1, 2, 3, 4, 7] {
            let got = bucket_by_level_par(&levels, maxl, threads);
            assert_eq!(got, want, "threads={threads}");
        }
        // Tiny input takes the sequential fallback but must agree too.
        let small = vec![2u32, 1, 2, 1, 3];
        assert_eq!(bucket_by_level_par(&small, 3, 4), bucket_by_level(&small, 3));
    }

    #[test]
    fn empty_factor_levels() {
        let g = crate::sparse::Csc::zero(5);
        let (levels, cp) = trisolve_levels(&g);
        assert!(levels.iter().all(|&l| l == 1));
        assert_eq!(cp, 1);
        assert_eq!(tree_height(&etree_from_factor(&g)), 1);
    }
}
