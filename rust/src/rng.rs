//! Deterministic, seedable pseudo-random number generation.
//!
//! The crate uses its own small RNGs (SplitMix64 for stream derivation,
//! Xoshiro256++ for bulk generation) so that every factorization,
//! generator, and benchmark is reproducible from a single `u64` seed and
//! independent per-thread streams can be derived without coordination —
//! mirroring how the paper's GPU implementation gives each block its own
//! RNG state.

/// SplitMix64 — tiny, fast generator used to seed other generators and to
/// derive independent streams (`seed ⊕ stream-id` avalanche).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the crate's workhorse generator.
///
/// Period 2^256−1, passes BigCrush; `jump`-free stream separation is done
/// by seeding each stream through SplitMix64 with a distinct stream id.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single `u64` (expanded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // Avoid the all-zero state (probability 2^-256, but be safe).
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Derive an independent stream for `(seed, stream)` — used to give
    /// each worker thread / simulated GPU block its own generator.
    pub fn stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let base = sm.next_u64();
        Self::new(base ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's debiased multiply-shift).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Standard normal via Box–Muller (one value; the pair's twin is
    /// discarded — simplicity over throughput, not on any hot path).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let av: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        let cv: Vec<u64> = (0..32).map(|_| c.next_u64()).collect();
        assert_eq!(av, bv);
        assert_ne!(av, cv);
    }

    #[test]
    fn streams_are_distinct() {
        let mut s0 = Rng::stream(7, 0);
        let mut s1 = Rng::stream(7, 1);
        let v0: Vec<u64> = (0..16).map(|_| s0.next_u64()).collect();
        let v1: Vec<u64> = (0..16).map(|_| s1.next_u64()).collect();
        assert_ne!(v0, v1);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_bounds_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(5);
        let p = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &v in &p {
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_has_plausible_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
