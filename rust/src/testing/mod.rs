//! Test support: a small seeded property-testing driver.
//!
//! The offline environment has no `proptest`/`quickcheck`, so this module
//! provides the same discipline with less machinery: run an invariant
//! check over many seeded random cases and report the failing seed so the
//! case can be replayed deterministically.

pub mod prop;
