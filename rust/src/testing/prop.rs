//! Seeded property-test driver (stand-in for `proptest`, which is not
//! available offline).
//!
//! ```no_run
//! use parac::testing::prop::forall_seeds;
//! forall_seeds(64, |seed| {
//!     let x = seed as i64;
//!     if x + 1 <= x { return Err("overflow".into()); }
//!     Ok(())
//! });
//! ```

use crate::rng::Rng;

/// Run `check(seed)` for `cases` derived seeds; panic with the failing
/// seed (replayable) on the first `Err`.
pub fn forall_seeds(cases: u64, check: impl Fn(u64) -> Result<(), String>) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i).wrapping_mul(0x9E3779B97F4A7C15) ^ i;
        if let Err(msg) = check(seed) {
            panic!("property failed for seed {seed:#x} (case {i}/{cases}): {msg}");
        }
    }
}

/// Run `check(rng)` for `cases` independent RNG streams.
pub fn forall_rngs(cases: u64, check: impl Fn(&mut Rng) -> Result<(), String>) {
    forall_seeds(cases, |seed| {
        let mut rng = Rng::new(seed);
        check(&mut rng)
    })
}

/// Base seed: fixed by default for reproducible CI; override with
/// `PARAC_PROP_SEED` for fuzzing sessions.
fn base_seed() -> u64 {
    std::env::var("PARAC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Assert two f64 slices are element-wise close.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, ctx: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{ctx}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        if (x - y).abs() > tol * scale {
            return Err(format!("{ctx}: index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall_seeds(16, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall_seeds(16, |seed| {
            if seed % 3 == 0 {
                Err("multiple of three".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_checks() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9, "t").is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-9, "t").is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-9, "t").is_err());
    }
}
