//! Markdown table rendering for experiment reports.

/// A simple markdown table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, &w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        let _ = ncol;
        out
    }
}

/// Format seconds as the paper's tables do (seconds with 2 decimals).
pub fn secs(x: f64) -> String {
    format!("{x:.2}")
}

/// Format milliseconds.
pub fn ms(x: f64) -> String {
    format!("{:.2}", x * 1e3)
}

/// Format a residual in scientific notation (`4.61e-7` style).
pub fn sci(x: f64) -> String {
    format!("{x:.2e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new(&["name", "iters"]);
        t.row(vec!["grid".into(), "42".into()]);
        let s = t.render();
        assert!(s.contains("| name |"));
        assert!(s.contains("| grid | 42    |") || s.contains("| grid | 42"));
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    fn formatters() {
        assert_eq!(secs(1.234), "1.23");
        assert_eq!(ms(0.0456), "45.60");
        assert!(sci(4.61e-7).contains("e-7"));
    }
}
