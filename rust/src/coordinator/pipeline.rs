//! The experiment pipeline: matrix → ordering → factor → PCG → report.
//!
//! Every repro driver (Tables 2–3, Figures 3–4) and example goes through
//! [`run`] so timings are measured uniformly: `setup_secs` is
//! preconditioner construction (ParAC factor time / ichol factor time /
//! AMG setup time — the paper's "Factorize/Setup/Analysis" columns),
//! `solve_secs` is the PCG loop.

use crate::factor::{self, ParacOptions};
use crate::graph::Laplacian;
use crate::precond::amg::AmgOptions;
use crate::precond::{AmgPrecond, Ichol0, IcholT, JacobiPrecond, LdlPrecond, Preconditioner};
use crate::solve::pcg::{self, PcgOptions};
use crate::util::Timer;

/// Which solver configuration to run.
#[derive(Clone, Debug)]
pub enum Method {
    /// ParAC with the given options; `level_threads > 0` uses the
    /// level-scheduled parallel triangular solve.
    Parac { opts: ParacOptions, level_threads: usize },
    /// Zero fill-in incomplete Cholesky (cuSPARSE `csric02` proxy).
    Ichol0,
    /// Threshold ICT; `droptol = None` calibrates fill to `fill_target`.
    IcholT { droptol: Option<f64>, fill_target: Option<usize> },
    /// Smoothed-aggregation AMG (HyPre / AmgX proxy).
    Amg,
    /// Jacobi diagonal scaling.
    Jacobi,
}

impl Method {
    /// Display name for report rows.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Parac { .. } => "ParAC",
            Method::Ichol0 => "ichol(0)",
            Method::IcholT { .. } => "ichol-t",
            Method::Amg => "AMG",
            Method::Jacobi => "Jacobi",
        }
    }
}

/// One pipeline run's outcome — a table row.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Method display name.
    pub method: &'static str,
    /// Preconditioner construction seconds.
    pub setup_secs: f64,
    /// PCG solve seconds.
    pub solve_secs: f64,
    /// PCG iterations.
    pub iters: usize,
    /// Final (true) relative residual.
    pub rel_residual: f64,
    /// Converged within the budget?
    pub converged: bool,
    /// Preconditioner nonzeros.
    pub nnz: usize,
    /// Factor statistics (ParAC only).
    pub factor_stats: Option<crate::factor::FactorStats>,
}

/// Run one method on one Laplacian with a seeded right-hand side.
pub fn run(lap: &Laplacian, method: &Method, pcg_opts: &PcgOptions, rhs_seed: u64) -> RunResult {
    let b = pcg::random_rhs(lap, rhs_seed);
    run_with_rhs(lap, method, pcg_opts, &b)
}

/// [`run`] with an explicit right-hand side.
pub fn run_with_rhs(
    lap: &Laplacian,
    method: &Method,
    pcg_opts: &PcgOptions,
    b: &[f64],
) -> RunResult {
    let timer = Timer::start();
    let (pre, factor_stats): (Box<dyn Preconditioner>, _) = match method {
        Method::Parac { opts, level_threads } => {
            let f = factor::factorize(lap, opts).expect("ParAC factorization failed");
            let stats = f.stats.clone();
            let pre: Box<dyn Preconditioner> = if *level_threads > 0 {
                Box::new(LdlPrecond::with_level_schedule(f, *level_threads))
            } else {
                Box::new(LdlPrecond::new(f))
            };
            (pre, Some(stats))
        }
        Method::Ichol0 => (Box::new(Ichol0::new(&lap.matrix)), None),
        Method::IcholT { droptol, fill_target } => {
            let f = match (droptol, fill_target) {
                (Some(t), _) => IcholT::new(&lap.matrix, *t),
                (None, Some(nnz)) => IcholT::with_fill_target(&lap.matrix, *nnz),
                (None, None) => IcholT::new(&lap.matrix, 1e-3),
            };
            (Box::new(f), None)
        }
        Method::Amg => (Box::new(AmgPrecond::new(&lap.matrix, &AmgOptions::default())), None),
        Method::Jacobi => (Box::new(JacobiPrecond::new(&lap.matrix)), None),
    };
    let setup_secs = timer.secs();
    let nnz = pre.nnz();

    let t2 = Timer::start();
    let out = pcg::solve(&lap.matrix, b, pre.as_ref(), pcg_opts);
    let solve_secs = t2.secs();
    RunResult {
        method: method.name(),
        setup_secs,
        solve_secs,
        iters: out.iters,
        rel_residual: out.rel_residual,
        converged: out.converged,
        nnz,
        factor_stats,
    }
}

/// The paper's default ParAC method for CPU tables (AMD ordering).
pub fn parac_cpu_method(threads: usize, seed: u64) -> Method {
    Method::Parac {
        opts: ParacOptions {
            ordering: crate::ordering::Ordering::Amd,
            engine: factor::Engine::Cpu { threads },
            seed,
            ..Default::default()
        },
        level_threads: 0,
    }
}

/// The paper's default ParAC method for GPU tables (nnz-sort ordering,
/// gpusim engine). The level schedule is analyzed (modeling the
/// cuSPARSE SPSV analysis stage of Table 3) but executed serially —
/// this testbed has one core, so a parallel sweep would only add
/// scheduling overhead; `benches/bench_trisolve.rs` quantifies that
/// trade-off explicitly.
pub fn parac_gpu_method(blocks: usize, seed: u64) -> Method {
    Method::Parac {
        opts: ParacOptions {
            ordering: crate::ordering::Ordering::NnzSort,
            engine: factor::Engine::GpuSim { blocks },
            seed,
            ..Default::default()
        },
        level_threads: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn parac_pipeline_end_to_end() {
        let lap = generators::grid2d(20, 20, generators::Coeff::Uniform, 0);
        let o = PcgOptions { max_iter: 500, tol: 1e-8, ..Default::default() };
        let r = run(&lap, &parac_cpu_method(2, 1), &o, 7);
        assert!(r.converged, "rel={}", r.rel_residual);
        assert!(r.iters < 200);
        assert!(r.factor_stats.is_some());
        assert!(r.nnz > 0);
    }

    #[test]
    fn all_methods_converge_on_small_mesh() {
        let lap = generators::grid2d(14, 14, generators::Coeff::Uniform, 0);
        let o = PcgOptions { max_iter: 3000, tol: 1e-7, ..Default::default() };
        for m in [
            parac_gpu_method(2, 3),
            Method::Ichol0,
            Method::IcholT { droptol: Some(1e-3), fill_target: None },
            Method::Amg,
            Method::Jacobi,
        ] {
            let r = run(&lap, &m, &o, 11);
            assert!(r.converged, "{} rel={}", r.method, r.rel_residual);
        }
    }
}
