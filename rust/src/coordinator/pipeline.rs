//! The experiment pipeline: matrix → ordering → factor → PCG → report.
//!
//! Every repro driver (Tables 2–3, Figures 3–4) and example goes through
//! [`run`] so timings are measured uniformly: `setup_secs` is
//! preconditioner construction (ParAC factor time / ichol factor time /
//! AMG setup time — the paper's "Factorize/Setup/Analysis" columns),
//! `solve_secs` is the PCG loop. Underneath, [`run_with_rhs`] is a thin
//! veneer over the [`Solver`] session API: it translates a [`Method`]
//! into a [`crate::solver::SolverBuilder`], builds, solves, and folds
//! the outcome into a [`RunResult`] row. All failures come back as
//! typed [`ParacError`]s — binaries decide whether to `?`-and-exit.
//!
//! [`write_bench_json`] serializes rows as hand-rolled JSON
//! (`BENCH_pipeline.json`) so successive PRs can track the performance
//! trajectory mechanically.

use crate::error::ParacError;
use crate::factor::{self, ParacOptions};
use crate::graph::Laplacian;
use crate::solve::pcg::{self, PcgOptions};
use crate::solver::{PrecondKind, Solver, SolverBuilder};
use crate::util::Timer;

/// Which solver configuration to run.
#[derive(Clone, Debug)]
pub enum Method {
    /// ParAC with the given options; `level_threads > 0` uses the
    /// level-scheduled parallel triangular solve.
    Parac {
        /// Factorization options.
        opts: ParacOptions,
        /// Workers for the level-scheduled solve (0 = sequential).
        level_threads: usize,
    },
    /// Zero fill-in incomplete Cholesky (cuSPARSE `csric02` proxy).
    Ichol0,
    /// Threshold ICT; `droptol = None` calibrates fill to `fill_target`.
    IcholT {
        /// Explicit drop tolerance (wins over `fill_target`).
        droptol: Option<f64>,
        /// Calibrate fill to this nonzero count when `droptol` is None.
        fill_target: Option<usize>,
    },
    /// Smoothed-aggregation AMG (HyPre / AmgX proxy).
    Amg,
    /// Jacobi diagonal scaling.
    Jacobi,
    /// Symmetric SOR with the given relaxation factor.
    Ssor {
        /// Relaxation factor `ω ∈ (0, 2)`.
        omega: f64,
    },
    /// No preconditioning (plain CG).
    Identity,
}

impl Method {
    /// Display name for report rows.
    pub fn name(&self) -> &'static str {
        self.precond_kind().name()
    }

    /// The preconditioner choice this method maps to.
    pub fn precond_kind(&self) -> PrecondKind {
        match self {
            Method::Parac { level_threads, .. } => {
                PrecondKind::Parac { level_threads: *level_threads }
            }
            Method::Ichol0 => PrecondKind::Ichol0,
            Method::IcholT { droptol, fill_target } => {
                PrecondKind::IcholT { droptol: *droptol, fill_target: *fill_target }
            }
            Method::Amg => PrecondKind::Amg,
            Method::Jacobi => PrecondKind::Jacobi,
            Method::Ssor { omega } => PrecondKind::Ssor { omega: *omega },
            Method::Identity => PrecondKind::Identity,
        }
    }

    /// Translate into a [`SolverBuilder`] carrying these PCG options.
    /// The caller's `project` flag is forwarded explicitly (pipeline
    /// callers configure it verbatim; the builder's kind-based
    /// auto-detection is for users who leave it unset).
    pub fn solver_builder(&self, pcg_opts: &PcgOptions) -> SolverBuilder {
        let mut b = Solver::builder()
            .pcg_options(pcg_opts.clone())
            .project(pcg_opts.project)
            .preconditioner(self.precond_kind());
        if let Method::Parac { opts, .. } = self {
            b = b.parac_options(opts.clone());
        }
        b
    }
}

/// One pipeline run's outcome — a table row.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Method display name.
    pub method: &'static str,
    /// Preconditioner construction seconds.
    pub setup_secs: f64,
    /// PCG solve seconds.
    pub solve_secs: f64,
    /// PCG iterations.
    pub iters: usize,
    /// Final (true) relative residual.
    pub rel_residual: f64,
    /// Converged within the budget?
    pub converged: bool,
    /// Preconditioner nonzeros.
    pub nnz: usize,
    /// Factor statistics (ParAC only).
    pub factor_stats: Option<crate::factor::FactorStats>,
}

impl RunResult {
    /// Serialize as one JSON object (hand-rolled; no dependencies).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"method\":{},\"setup_secs\":{},\"solve_secs\":{},\"iters\":{},\
             \"rel_residual\":{},\"converged\":{},\"nnz\":{}}}",
            json_string(self.method),
            json_f64(self.setup_secs),
            json_f64(self.solve_secs),
            self.iters,
            json_f64(self.rel_residual),
            self.converged,
            self.nnz,
        )
    }
}

/// Render a string as a JSON string literal (quotes included), escaping
/// backslashes, quotes, and control characters.
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render an f64 as a JSON number (`null` for non-finite values, which
/// JSON cannot represent).
fn json_f64(x: f64) -> String {
    // `{}` on f64 prints integers without a decimal point; that is
    // still a valid JSON number, so no fixup needed.
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

/// Write pipeline rows as a machine-readable JSON file (one `runs`
/// array), e.g. `BENCH_pipeline.json` at the repo root — the perf
/// trajectory artifact successive PRs diff against.
pub fn write_bench_json(
    path: &std::path::Path,
    label: &str,
    rows: &[RunResult],
) -> std::io::Result<()> {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"bench\": {},\n", json_string(label)));
    out.push_str("  \"runs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str("    ");
        out.push_str(&r.to_json());
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// A free-form bench row for [`write_bench_rows_json`]: a label plus
/// named numeric fields. Used by benches whose rows are not pipeline
/// [`RunResult`]s (e.g. `benches/bench_batch_solve.rs`).
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// Row label (e.g. `"uniform_3d_poisson n=4096 rhs=8 threads=4"`).
    pub name: String,
    /// Named numeric fields, serialized in order.
    pub fields: Vec<(&'static str, f64)>,
}

/// Write free-form bench rows as a machine-readable JSON file with the
/// same shape (`bench` label + one `runs` array) and the same
/// hand-rolled serialization helpers as [`write_bench_json`] — e.g.
/// `BENCH_batch_solve.json` at the repo root.
pub fn write_bench_rows_json(
    path: &std::path::Path,
    label: &str,
    rows: &[BenchRow],
) -> std::io::Result<()> {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"bench\": {},\n", json_string(label)));
    out.push_str("  \"runs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str("    {");
        out.push_str(&format!("\"name\":{}", json_string(&r.name)));
        for (k, v) in &r.fields {
            out.push_str(&format!(",{}:{}", json_string(k), json_f64(*v)));
        }
        out.push('}');
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// Run one method on one Laplacian with a seeded right-hand side.
pub fn run(
    lap: &Laplacian,
    method: &Method,
    pcg_opts: &PcgOptions,
    rhs_seed: u64,
) -> Result<RunResult, ParacError> {
    let b = pcg::random_rhs(lap, rhs_seed);
    run_with_rhs(lap, method, pcg_opts, &b)
}

/// [`run`] with an explicit right-hand side.
pub fn run_with_rhs(
    lap: &Laplacian,
    method: &Method,
    pcg_opts: &PcgOptions,
    b: &[f64],
) -> Result<RunResult, ParacError> {
    let timer = Timer::start();
    let mut solver = method.solver_builder(pcg_opts).build(lap)?;
    let setup_secs = timer.secs();
    let nnz = solver.preconditioner().nnz();
    let factor_stats = solver.factor_stats().cloned();

    let mut x = vec![0.0; lap.n()];
    let t2 = Timer::start();
    let out = solver.solve_into(b, &mut x)?;
    let solve_secs = t2.secs();
    Ok(RunResult {
        method: method.name(),
        setup_secs,
        solve_secs,
        iters: out.iters,
        rel_residual: out.rel_residual,
        converged: out.converged,
        nnz,
        factor_stats,
    })
}

/// The paper's default ParAC method for CPU tables (AMD ordering).
pub fn parac_cpu_method(threads: usize, seed: u64) -> Method {
    Method::Parac {
        opts: ParacOptions {
            ordering: crate::ordering::Ordering::Amd,
            engine: factor::Engine::Cpu { threads },
            seed,
            ..Default::default()
        },
        level_threads: 0,
    }
}

/// The paper's default ParAC method for GPU tables (nnz-sort ordering,
/// gpusim engine). The level schedule is analyzed (modeling the
/// cuSPARSE SPSV analysis stage of Table 3) but executed serially —
/// this testbed has one core, so a parallel sweep would only add
/// scheduling overhead; `benches/bench_trisolve.rs` quantifies that
/// trade-off explicitly.
pub fn parac_gpu_method(blocks: usize, seed: u64) -> Method {
    Method::Parac {
        opts: ParacOptions {
            ordering: crate::ordering::Ordering::NnzSort,
            engine: factor::Engine::GpuSim { blocks },
            seed,
            ..Default::default()
        },
        level_threads: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn parac_pipeline_end_to_end() {
        let lap = generators::grid2d(20, 20, generators::Coeff::Uniform, 0);
        let o = PcgOptions { max_iter: 500, tol: 1e-8, ..Default::default() };
        let r = run(&lap, &parac_cpu_method(2, 1), &o, 7).unwrap();
        assert!(r.converged, "rel={}", r.rel_residual);
        assert!(r.iters < 200);
        assert!(r.factor_stats.is_some());
        assert!(r.nnz > 0);
    }

    #[test]
    fn all_methods_converge_on_small_mesh() {
        let lap = generators::grid2d(14, 14, generators::Coeff::Uniform, 0);
        let o = PcgOptions { max_iter: 3000, tol: 1e-7, ..Default::default() };
        for m in [
            parac_gpu_method(2, 3),
            Method::Ichol0,
            Method::IcholT { droptol: Some(1e-3), fill_target: None },
            Method::Amg,
            Method::Jacobi,
            Method::Ssor { omega: 1.5 },
        ] {
            let r = run(&lap, &m, &o, 11).unwrap();
            assert!(r.converged, "{} rel={}", r.method, r.rel_residual);
        }
    }

    #[test]
    fn bad_input_propagates_as_error() {
        let empty = Laplacian::from_edges(0, &[], "empty");
        let o = PcgOptions::default();
        assert!(run(&empty, &Method::Jacobi, &o, 1).is_err());
    }

    #[test]
    fn bench_json_is_wellformed() {
        let r = RunResult {
            method: "ParAC",
            setup_secs: 0.25,
            solve_secs: 1.5,
            iters: 42,
            rel_residual: 4.2e-8,
            converged: true,
            nnz: 1000,
            factor_stats: None,
        };
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"method\":\"ParAC\""));
        assert!(j.contains("\"iters\":42"));
        assert!(j.contains("\"converged\":true"));
        // Non-finite residuals must serialize as null, not `NaN`.
        let bad = RunResult { rel_residual: f64::NAN, ..r.clone() };
        assert!(bad.to_json().contains("\"rel_residual\":null"));

        let dir = std::env::temp_dir().join("parac_pipeline_json");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_pipeline.json");
        write_bench_json(&path, "unit", &[r]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"bench\": \"unit\""));
        assert!(body.contains("\"runs\": ["));
    }

    #[test]
    fn bench_rows_json_is_wellformed() {
        let rows = vec![
            BenchRow {
                name: "grid rhs=8 threads=4".into(),
                fields: vec![("rhs", 8.0), ("threads", 4.0), ("wall_secs", 0.125)],
            },
            BenchRow { name: "empty-fields".into(), fields: vec![("nan", f64::NAN)] },
        ];
        let dir = std::env::temp_dir().join("parac_pipeline_json");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_rows_unit.json");
        write_bench_rows_json(&path, "batch_solve unit", &rows).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"bench\": \"batch_solve unit\""));
        assert!(body.contains("\"name\":\"grid rhs=8 threads=4\""));
        assert!(body.contains("\"rhs\":8"));
        assert!(body.contains("\"wall_secs\":0.125"));
        // Non-finite fields serialize as null, same as RunResult.
        assert!(body.contains("\"nan\":null"));
    }

    #[test]
    fn json_strings_escape_specials() {
        assert_eq!(json_string("plain"), "\"plain\"");
        assert_eq!(json_string("a\\b"), "\"a\\\\b\"");
        assert_eq!(json_string("say \"hi\""), "\"say \\\"hi\\\"\"");
        assert_eq!(json_string("line\nbreak\ttab"), "\"line\\nbreak\\ttab\"");
        assert_eq!(json_string("\u{1}"), "\"\\u0001\"");
    }
}
