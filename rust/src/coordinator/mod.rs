//! Pipeline coordinator and experiment drivers.
//!
//! [`pipeline`] is a thin veneer over the [`crate::solver::Solver`]
//! session API that measures setup/solve phases uniformly and renders
//! [`pipeline::RunResult`] rows (including the machine-readable
//! `BENCH_pipeline.json` via [`pipeline::write_bench_json`]);
//! [`repro`] regenerates the paper's tables/figures; [`incremental`]
//! runs the rebuild-every-round resparsification reference loop (the
//! delta-classified version lives in [`crate::dynamic`]);
//! [`serve_driver`]
//! measures the serving subsystem ([`crate::serve`]) under open-loop
//! multi-client load. Everything returns typed
//! [`crate::error::ParacError`]s — only binaries exit.

pub mod incremental;
pub mod pipeline;
pub mod report;
pub mod repro;
pub mod serve_driver;
