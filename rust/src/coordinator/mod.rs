//! Pipeline coordinator and experiment drivers (filled in alongside the
//! runtime; see `pipeline` / `report` / repro drivers).

pub mod incremental;
pub mod pipeline;
pub mod report;
pub mod repro;
