//! Experiment regenerators — one driver per table / figure in the
//! paper's evaluation (§6). Each prints a markdown table with the same
//! rows and columns as the paper (matrix suite in Table 1 order) and is
//! reachable both from `parac repro …` and from the bench harness.
//!
//! Every driver returns `Result<(), ParacError>` — failures propagate
//! to the calling binary, which decides how to exit; nothing in here
//! unwraps or panics on bad input.

use super::pipeline::{self, Method};
use super::report::{sci, secs, Table};
use crate::error::ParacError;
use crate::etree;
use crate::factor::{self, Engine, ParacOptions};
use crate::graph::suite::{Scale, SUITE};
use crate::ordering::Ordering;
use crate::solve::pcg::PcgOptions;
use crate::util::{default_threads, fmt_count, Timer};

fn pcg_opts() -> PcgOptions {
    // Paper tables converge to ~1e-6..1e-7 relative residual.
    PcgOptions { tol: 1e-7, max_iter: 1000, ..Default::default() }
}

fn workers(threads: usize) -> usize {
    // Clamp to the persistent pool exactly like the engines do, so the
    // table headers report the worker count that actually runs.
    if threads == 0 { default_threads() } else { threads }.min(crate::par::global().size())
}

/// Table 2 — CPU convergence: ParAC (AMD) vs fill-matched ICT vs AMG
/// (HyPre proxy).
pub fn table2(scale: Scale, threads: usize) -> Result<(), ParacError> {
    let t = workers(threads);
    println!("## Table 2 (CPU): ParAC vs ichol-t vs AMG  [scale {scale:?}, {t} threads]\n");
    let mut tab = Table::new(&[
        "problem", "ParAC fact(s)", "ParAC solve(s)", "ParAC it", "ParAC res", "ICT fact(s)",
        "ICT solve(s)", "ICT it", "ICT res", "AMG setup(s)", "AMG solve(s)", "AMG it", "AMG res",
    ]);
    for e in SUITE {
        let lap = (e.build)(scale);
        let o = pcg_opts();
        let rp = pipeline::run(&lap, &pipeline::parac_cpu_method(t, 1), &o, 7)?;
        let target = rp.nnz;
        let ri = pipeline::run(
            &lap,
            &Method::IcholT { droptol: None, fill_target: Some(target) },
            &o,
            7,
        )?;
        let ra = pipeline::run(&lap, &Method::Amg, &o, 7)?;
        tab.row(vec![
            e.name.into(),
            secs(rp.setup_secs),
            secs(rp.solve_secs),
            rp.iters.to_string(),
            sci(rp.rel_residual),
            secs(ri.setup_secs),
            secs(ri.solve_secs),
            ri.iters.to_string(),
            sci(ri.rel_residual),
            secs(ra.setup_secs),
            secs(ra.solve_secs),
            ra.iters.to_string(),
            sci(ra.rel_residual),
        ]);
    }
    print!("{}", tab.render());
    Ok(())
}

/// Table 3 — GPU-model results: ParAC (gpusim, nnz-sort, level-parallel
/// SPSV) vs AMG (AmgX proxy) vs IC(0)+CG (cuSPARSE proxy). Times in ms.
pub fn table3(scale: Scale, blocks: usize) -> Result<(), ParacError> {
    let b = workers(blocks);
    println!(
        "## Table 3 (GPU model): ParAC(nnz-sort) vs AMG vs ichol(0)  [scale {scale:?}, {b} blocks]\n"
    );
    let mut tab = Table::new(&[
        "problem", "ParAC factor(ms)", "ParAC solve(ms)", "ParAC total(ms)", "ParAC it",
        "ParAC res", "AMG total(ms)", "AMG it", "AMG res", "IC0 factor(ms)", "IC0 solve(ms)",
        "IC0 it", "IC0 res",
    ]);
    for e in SUITE {
        let lap = (e.build)(scale);
        let o = PcgOptions { tol: 1e-7, max_iter: 10_000, ..Default::default() };
        let rp = pipeline::run(&lap, &pipeline::parac_gpu_method(b, 1), &o, 7)?;
        let ra = pipeline::run(&lap, &Method::Amg, &pcg_opts(), 7)?;
        let r0 = pipeline::run(&lap, &Method::Ichol0, &o, 7)?;
        tab.row(vec![
            e.name.into(),
            format!("{:.1}", rp.setup_secs * 1e3),
            format!("{:.1}", rp.solve_secs * 1e3),
            format!("{:.1}", (rp.setup_secs + rp.solve_secs) * 1e3),
            rp.iters.to_string(),
            sci(rp.rel_residual),
            format!("{:.1}", (ra.setup_secs + ra.solve_secs) * 1e3),
            ra.iters.to_string(),
            sci(ra.rel_residual),
            format!("{:.1}", r0.setup_secs * 1e3),
            format!("{:.1}", r0.solve_secs * 1e3),
            r0.iters.to_string(),
            sci(r0.rel_residual),
        ]);
    }
    print!("{}", tab.render());
    Ok(())
}

/// Figure 3 — CPU factor-time scaling over threads for the three
/// orderings.
pub fn fig3(scale: Scale, max_threads: usize) -> Result<(), ParacError> {
    let maxt = workers(max_threads);
    let mut counts = vec![1usize];
    let mut c = 1usize;
    while c * 2 <= maxt {
        c *= 2;
        counts.push(c);
    }
    println!("## Figure 3: CPU factor time (s) vs threads  [scale {scale:?}]\n");
    let mut headers: Vec<String> = vec!["problem".into(), "ordering".into()];
    headers.extend(counts.iter().map(|c| format!("T={c}")));
    headers.push("speedup".into());
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut tab = Table::new(&hrefs);
    for e in SUITE {
        let lap = (e.build)(scale);
        for ord in Ordering::paper_set() {
            let mut times = Vec::new();
            for &t in &counts {
                let opts = ParacOptions {
                    ordering: ord,
                    engine: Engine::Cpu { threads: t },
                    seed: 1,
                    ..Default::default()
                };
                let timer = Timer::start();
                factor::factorize(&lap, &opts)?;
                times.push(timer.secs());
            }
            let mut row = vec![e.name.to_string(), ord.name().to_string()];
            row.extend(times.iter().map(|t| format!("{t:.3}")));
            let last = times.last().copied().unwrap_or(times[0]);
            row.push(format!("{:.1}x", times[0] / last.max(1e-9)));
            tab.row(row);
        }
    }
    print!("{}", tab.render());
    Ok(())
}

/// Hash-ablation (§5.3.4 / §7.1): random-permutation vs identity hash
/// codes in the gpusim workspace — probe-length and wall-time impact.
/// The factor itself is hash-independent (pinned by tests); only the
/// probing behaviour changes.
pub fn hash_ablation(scale: Scale, blocks: usize) -> Result<(), ParacError> {
    use crate::factor::gpusim::factorize_csr_hash;
    use crate::gpusim::hashmap::HashKind;
    let b = workers(blocks);
    println!("## Hash ablation (gpusim workspace): random-permutation vs identity\n");
    let mut tab = Table::new(&[
        "problem", "hash", "factor(ms)", "max probe", "probe steps / fill",
    ]);
    for name in ["uniform_3d_poisson", "com-LiveJournal", "GAP-road", "G3_circuit"] {
        let e = crate::graph::suite::by_name(name)
            .ok_or_else(|| ParacError::BadInput(format!("unknown suite matrix {name}")))?;
        let lap = (e.build)(scale);
        let perm = Ordering::NnzSort.compute(&lap, 1);
        let permuted = lap.matrix.permute_sym(&perm);
        for (kind, label) in [(HashKind::RandomPerm, "random-perm"), (HashKind::Identity, "identity")] {
            let timer = Timer::start();
            let (_, _, stats) = factorize_csr_hash(&permuted, 1, true, b, 6.0, kind, false)?;
            let dt = timer.secs();
            tab.row(vec![
                e.name.into(),
                label.into(),
                format!("{:.1}", dt * 1e3),
                stats.max_probe.to_string(),
                format!("{:.2}", stats.probe_steps as f64 / stats.fills.max(1) as f64),
            ]);
        }
    }
    print!("{}", tab.render());
    Ok(())
}

/// Figure 4 — e-tree heights, triangular-solve critical path, gpusim
/// factor time, and fill ratio per ordering.
pub fn fig4(scale: Scale, blocks: usize) -> Result<(), ParacError> {
    let b = workers(blocks);
    println!("## Figure 4: e-tree depth / critical path / GPU-model time / fill  [scale {scale:?}]\n");
    let mut tab = Table::new(&[
        "problem", "ordering", "classical e-tree", "actual e-tree", "critical path",
        "gpusim factor(ms)", "fill ratio",
    ]);
    for e in SUITE {
        let lap = (e.build)(scale);
        for ord in Ordering::paper_set() {
            let opts = ParacOptions {
                ordering: ord,
                engine: Engine::GpuSim { blocks: b },
                seed: 1,
                ..Default::default()
            };
            let timer = Timer::start();
            let f = factor::factorize(&lap, &opts)?;
            let dt = timer.secs();
            // Heights are measured on the *permuted* matrix (the one the
            // elimination actually ran on).
            let perm = f.perm.clone().ok_or_else(|| {
                ParacError::BadInput("factorize returned no permutation".into())
            })?;
            let permuted = lap.matrix.permute_sym(&perm);
            let rep = etree::report(&permuted, &f.g);
            tab.row(vec![
                e.name.into(),
                ord.name().into(),
                rep.classical_height.to_string(),
                rep.actual_height.to_string(),
                rep.critical_path.to_string(),
                format!("{:.1}", dt * 1e3),
                format!("{:.2}", rep.fill_ratio),
            ]);
        }
    }
    print!("{}", tab.render());
    println!(
        "\n(n per problem at this scale: {})",
        SUITE
            .iter()
            .map(|e| format!("{}={}", e.name, fmt_count((e.build)(scale).n())))
            .collect::<Vec<_>>()
            .join(", ")
    );
    Ok(())
}
