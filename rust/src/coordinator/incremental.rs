//! Incremental (re-)sparsification — the paper's §1 motivation for fast
//! preconditioner construction: *"this is especially useful … if we are
//! dealing with situations where the input changes every round, such as
//! incremental sparsification."*
//!
//! The session holds a dynamic weighted graph; each round applies a batch
//! of edge insertions/deletions, re-runs ParAC **from scratch** (the
//! whole point of the paper: construction is cheap enough to redo per
//! round — no incremental symbolic state to maintain), and solves the
//! round's system. The per-round cost is the paper's headline
//! "construction ≪ solve" economics in a loop.
//!
//! This is the **reference loop**: deliberately the dumbest correct
//! thing. The first-class dynamic subsystem lives in [`crate::dynamic`]
//! — [`crate::dynamic::DynamicSession`] classifies each batch onto
//! weight-only / cone-localized / rebuild repair paths instead of
//! rebuilding every round, and shares this module's [`UpdateBatch`].

use crate::error::ParacError;
use crate::factor::{self, ParacOptions};
use crate::graph::{Fingerprint, Laplacian};
use crate::precond::LdlPrecond;
use crate::solve::pcg::{self, PcgOptions, PcgWorkspace};
use crate::util::Timer;
use std::collections::HashMap;

/// Batch type shared with the delta-classified session — see
/// [`crate::dynamic::UpdateBatch`] for the pinned semantics.
pub use crate::dynamic::UpdateBatch;

/// Per-round report.
#[derive(Clone, Debug)]
pub struct RoundReport {
    /// Round number (0-based).
    pub round: usize,
    /// Live edges after the batch.
    pub edges: usize,
    /// Fingerprint of the round's graph (deterministic: the edge list
    /// is sorted before the Laplacian is built).
    pub fingerprint: Fingerprint,
    /// ParAC factorization seconds.
    pub factor_secs: f64,
    /// PCG solve seconds.
    pub solve_secs: f64,
    /// PCG iterations.
    pub iters: usize,
    /// Converged?
    pub converged: bool,
}

/// A dynamic-graph solving session.
pub struct IncrementalSession {
    n: usize,
    edges: HashMap<(u32, u32), f64>,
    opts: ParacOptions,
    pcg: PcgOptions,
    round: usize,
    /// Krylov buffers reused across rounds (the graph changes, the
    /// dimension doesn't).
    ws: PcgWorkspace,
}

impl IncrementalSession {
    /// Start from an initial Laplacian.
    pub fn new(initial: &Laplacian, opts: ParacOptions, pcg: PcgOptions) -> Self {
        let mut edges = HashMap::new();
        for (u, v, w) in initial.edges() {
            edges.insert((u.min(v), u.max(v)), w);
        }
        let n = initial.n();
        IncrementalSession { n, edges, opts, pcg, round: 0, ws: PcgWorkspace::new(n) }
    }

    /// Number of live edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Apply a batch, refactor, solve `L x = b`. Returns the report and
    /// the solution; factorization failures propagate as typed errors
    /// (the batch is still applied — the session graph has moved on).
    pub fn step(
        &mut self,
        batch: &UpdateBatch,
        b: &[f64],
    ) -> Result<(RoundReport, Vec<f64>), ParacError> {
        if b.len() != self.n {
            return Err(ParacError::DimensionMismatch {
                what: "rhs",
                expected: self.n,
                got: b.len(),
            });
        }
        batch.validate(self.n)?;
        for &(u, v, w) in &batch.add {
            let key = (u.min(v), u.max(v));
            if key.0 != key.1 {
                *self.edges.entry(key).or_insert(0.0) += w;
            }
        }
        for &(u, v) in &batch.remove {
            self.edges.remove(&(u.min(v), u.max(v)));
        }
        let mut list: Vec<(u32, u32, f64)> =
            self.edges.iter().map(|(&(u, v), &w)| (u, v, w)).collect();
        // HashMap iteration order is randomized per process; sort so the
        // round graph (edge order, fingerprint, ordering heuristics) is
        // identical for identical session histories.
        list.sort_unstable_by_key(|&(u, v, _)| (u, v));
        let lap = Laplacian::from_edges(self.n, &list, &format!("round{}", self.round));
        let fingerprint = lap.fingerprint();

        let t = Timer::start();
        // Fresh seed per round — resparsification wants independent
        // samples (Kyng–Pachocki–Peng–Sachdeva framework).
        let mut opts = self.opts.clone();
        opts.seed = self.opts.seed.wrapping_add(self.round as u64 * 0x9E37);
        let f = factor::factorize(&lap, &opts)?;
        let factor_secs = t.secs();

        let t = Timer::start();
        let pre = LdlPrecond::new(f);
        let mut x = vec![0.0; self.n];
        let out = pcg::solve_into(&lap.matrix, b, &pre, &self.pcg, &mut self.ws, &mut x);
        let solve_secs = t.secs();

        let report = RoundReport {
            round: self.round,
            edges: self.edges.len(),
            fingerprint,
            factor_secs,
            solve_secs,
            iters: out.iters,
            converged: out.converged,
        };
        self.round += 1;
        Ok((report, x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::rng::Rng;

    #[test]
    fn session_survives_edge_churn() {
        let lap = generators::grid2d(16, 16, generators::Coeff::Uniform, 0);
        let n = lap.n();
        let mut sess = IncrementalSession::new(
            &lap,
            ParacOptions::default(),
            PcgOptions { tol: 1e-7, max_iter: 600, ..Default::default() },
        );
        let mut rng = Rng::new(8);
        let b = pcg::random_rhs(&lap, 3);
        let e0 = sess.num_edges();
        for round in 0..5 {
            // Random churn: add 20 random edges, drop 10 existing ones
            // (never disconnect badly: grid core stays).
            let mut batch = UpdateBatch::default();
            for _ in 0..20 {
                let u = rng.below(n) as u32;
                let v = rng.below(n) as u32;
                if u != v {
                    batch.add.push((u, v, rng.range_f64(0.5, 2.0)));
                }
            }
            let (rep, x) = sess.step(&batch, &b).unwrap();
            assert!(rep.converged, "round {round}: rel residual too high");
            assert!(rep.iters < 200);
            assert!(x.iter().all(|v| v.is_finite()));
        }
        assert!(sess.num_edges() > e0, "edges should have accumulated");
    }

    #[test]
    fn removals_are_respected() {
        let lap = generators::complete(8);
        let mut sess = IncrementalSession::new(
            &lap,
            ParacOptions::default(),
            PcgOptions { tol: 1e-8, max_iter: 100, ..Default::default() },
        );
        assert_eq!(sess.num_edges(), 28);
        let batch = UpdateBatch {
            add: vec![],
            remove: (1..8).map(|v| (0u32, v as u32)).collect(),
        };
        let b = pcg::random_rhs(&lap, 1);
        // Vertex 0 is now isolated: the projected system on the rest
        // still solves; vertex 0's component is handled by zero pivots.
        let (rep, _) = sess.step(&batch, &b).unwrap();
        assert_eq!(sess.num_edges(), 21);
        assert!(rep.factor_secs >= 0.0);
    }

    #[test]
    fn per_round_seeds_differ() {
        let lap = generators::grid2d(8, 8, generators::Coeff::Uniform, 0);
        let mut sess = IncrementalSession::new(
            &lap,
            ParacOptions::default(),
            PcgOptions { tol: 1e-6, max_iter: 300, ..Default::default() },
        );
        let b = pcg::random_rhs(&lap, 2);
        let (r0, x0) = sess.step(&UpdateBatch::default(), &b).unwrap();
        let (r1, x1) = sess.step(&UpdateBatch::default(), &b).unwrap();
        assert!(r0.converged && r1.converged);
        // Same graph, same rhs — but different sampled preconditioners:
        // iterates differ while both converge to the same solution.
        let close = x0
            .iter()
            .zip(&x1)
            .all(|(a, b)| (a - b).abs() < 1e-4 * a.abs().max(1.0));
        assert!(close, "solutions should agree to solver tolerance");
    }
}
