//! # ParAC — Parallel Randomized Approximate Cholesky Preconditioners
//!
//! Reproduction of *"Parallel GPU-Accelerated Randomized Construction of
//! Approximate Cholesky Preconditioners"* (Liang et al., CS.DC 2025).
//!
//! The library constructs an incomplete `G D Gᵀ` factorization of a graph
//! Laplacian (or SDD matrix) by randomized clique sub-sampling during
//! Gaussian elimination (the AC algorithm of Kyng–Sachdeva /
//! Gao–Kyng–Spielman), parallelized with **dynamic dependency tracking**:
//! no nested dissection, no symbolic factorization — ready vertices are
//! discovered on the fly from per-vertex dependency counters over the
//! evolving multigraph.
//!
//! Two parallel engines are provided, mirroring the paper:
//! * [`factor::cpu`] — left-looking CPU engine (linked-list fill-in
//!   aggregation, atomic-exchange insertion, bump-allocated arena).
//! * [`factor::gpusim`] — right-looking engine modeling the paper's
//!   persistent-kernel GPU design (linear-probing slot-state workspace,
//!   `hash(v) + fill_count(v)` insertion, random-permutation hashing,
//!   block-level sort/scan primitives).
//!
//! The documented entry point is [`factor::factorize`]: ordering →
//! permutation → engine dispatch (with arena-overflow retry) → an
//! [`factor::LdlFactor`] that plugs into PCG as
//! [`precond::LdlPrecond`]. See `examples/quickstart.rs` for the
//! minimal end-to-end flow.
//!
//! Alongside the core contribution the crate ships every substrate the
//! paper's evaluation depends on: sparse kernels ([`sparse`]), graph
//! generators mirroring the paper's matrix suite ([`graph`]), orderings
//! (AMD, nnz-sort, random, RCM — [`ordering`]), elimination-tree
//! analytics ([`etree`]), PCG with level-scheduled triangular solves
//! ([`solve`]), and baseline preconditioners (IC(0), ICT,
//! smoothed-aggregation AMG, Jacobi — [`precond`]). A PJRT runtime
//! ([`runtime`], gated behind the off-by-default `xla` cargo feature)
//! loads AOT-compiled JAX/Pallas artifacts for the L1/L2 layers (see
//! `python/compile/`).

#![warn(missing_docs)]

pub mod cli;
pub mod coordinator;
pub mod etree;
pub mod factor;
pub mod gpusim;
pub mod graph;
pub mod ordering;
pub mod precond;
pub mod rng;
pub mod runtime;
pub mod solve;
pub mod sparse;
pub mod testing;
pub mod util;
