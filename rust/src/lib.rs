//! # ParAC — Parallel Randomized Approximate Cholesky Preconditioners
//!
//! Reproduction of *"Parallel GPU-Accelerated Randomized Construction of
//! Approximate Cholesky Preconditioners"* (Liang et al., CS.DC 2025).
//!
//! The library constructs an incomplete `G D Gᵀ` factorization of a graph
//! Laplacian (or SDD matrix) by randomized clique sub-sampling during
//! Gaussian elimination (the AC algorithm of Kyng–Sachdeva /
//! Gao–Kyng–Spielman), parallelized with **dynamic dependency tracking**:
//! no nested dissection, no symbolic factorization — ready vertices are
//! discovered on the fly from per-vertex dependency counters over the
//! evolving multigraph.
//!
//! Two parallel engines are provided, mirroring the paper:
//! * [`factor::cpu`] — left-looking CPU engine (linked-list fill-in
//!   aggregation, atomic-exchange insertion, bump-allocated arena).
//! * [`factor::gpusim`] — right-looking engine modeling the paper's
//!   persistent-kernel GPU design (linear-probing slot-state workspace,
//!   `hash(v) + fill_count(v)` insertion, random-permutation hashing,
//!   block-level sort/scan primitives).
//!
//! ## Quickstart: the `Solver` session
//!
//! The documented entry point is [`solver::Solver`]: a builder collects
//! the ordering / engine / seed / preconditioner / PCG knobs plus the
//! solve-phase parallelism ([`solver::SolverBuilder::threads`] — SpMV
//! row splits and level-scheduled triangular solves served by the
//! persistent [`par`] worker pool), `build` factors once, and the
//! session then solves any number of right-hand sides with **zero heap
//! allocations per PCG iteration** (every error is a typed
//! [`error::ParacError`], never a panic).
//!
//! The whole solve path runs through `&self` — a built session is
//! immutable shared state (`Solver: Sync`, asserted at compile time in
//! [`serve`]), and each call checks a Krylov workspace out of the
//! session's pool. Any number of threads may call
//! [`solver::Solver::solve_shared`] /
//! [`solver::Solver::solve_batch_shared`] concurrently on one solver,
//! bit-identically to a serial loop; [`solver::Solver::solve_into`] and
//! [`solver::Solver::solve_batch`] remain as thin `&mut self` wrappers
//! for single-owner code:
//!
//! ```
//! use parac::factor::Engine;
//! use parac::graph::generators::{self, Coeff};
//! use parac::ordering::Ordering;
//! use parac::solve::pcg;
//! use parac::solver::Solver;
//!
//! let lap = generators::grid2d(12, 12, Coeff::Uniform, 42);
//! let mut solver = Solver::builder()
//!     .ordering(Ordering::NnzSort)
//!     .engine(Engine::Cpu { threads: 2 }) // factorization parallelism
//!     .threads(2)                         // solve-phase parallelism
//!     .seed(7)
//!     .build(&lap)
//!     .expect("solver setup");
//!
//! // A batch of right-hand sides rides one factor, one pool, and one
//! // workspace; results are bit-identical to looping `solve_into`.
//! let b1 = pcg::random_rhs(&lap, 1);
//! let b2 = pcg::random_rhs(&lap, 2);
//! let mut xs = vec![Vec::new(); 2];
//! let stats = solver.solve_batch(&[&b1, &b2], &mut xs).expect("dimensions match");
//! assert!(stats.iter().all(|s| s.converged));
//!
//! // The session stays reusable for single right-hand sides too.
//! let b3 = pcg::random_rhs(&lap, 3);
//! let mut x = vec![0.0; lap.n()];
//! assert!(solver.solve_into(&b3, &mut x).unwrap().converged);
//!
//! // New edge weights on the same sparsity pattern? `refactorize`
//! // reruns only the numeric phase on the frozen symbolic analysis
//! // (ordering, elimination tree, level schedules, workspaces) — no
//! // re-analysis, no allocation, bit-identical to a fresh `build`
//! // with the same seed.
//! let heavy = generators::grid2d(12, 12, Coeff::HighContrast(10.0), 42);
//! solver.refactorize(&heavy).expect("same pattern");
//! assert!(solver.factor_stats().unwrap().symbolic_reused);
//! assert!(solver.solve_into(&b3, &mut x).unwrap().converged);
//!
//! // The same session is safe to share: `solve_shared` takes `&self`,
//! // so threads can solve concurrently with bit-identical results.
//! let shared = &solver;
//! std::thread::scope(|scope| {
//!     scope.spawn(move || {
//!         let mut x = vec![0.0; shared.n()];
//!         assert!(shared.solve_shared(&b3, &mut x).unwrap().converged);
//!     });
//! });
//! ```
//!
//! ## Serving: one factor, many clients
//!
//! The [`serve`] subsystem builds on the `&self` contract:
//! [`serve::FactorCache`] keys built sessions by
//! [`graph::Laplacian::fingerprint`] (repeat builds return the shared
//! `Arc`; reweighted builds of a known pattern rerun only the numeric
//! phase), and [`serve::SolveService`] admits requests from N client
//! threads, coalescing compatible ones into batched solve waves. The
//! `parac serve` subcommand and `benches/bench_serve.rs` measure the
//! stack under open-loop load via [`coordinator::serve_driver`].
//!
//! ## Dynamic graphs: updates without full rebuilds
//!
//! The [`dynamic`] subsystem keeps a session live while the graph
//! changes — the paper's §1 "input changes every round" workloads.
//! [`dynamic::DynamicSession::step`] applies an
//! [`dynamic::UpdateBatch`] and classifies it onto the cheapest repair
//! path: pattern-preserving reweights rerun only the numeric phase
//! ([`solver::Solver::refactorize_shared`]); small structural deltas
//! take a **cone-localized refactorization** (re-eliminate just the
//! touched columns and their elimination-tree ancestors and splice the
//! result into the factor — [`dynamic::cone`],
//! [`solver::Solver::splice_factor`]); heavy damage rebuilds through a
//! [`serve::FactorCache`] so known graphs hit the cache. The
//! [`dynamic::scenario`] zoo (edge churn, spectral partitioning via
//! inverse-power iteration, effective-resistance sparsification)
//! drives it from the `parac dynamic` subcommand and
//! `benches/bench_dynamic.rs` (`BENCH_dynamic.json`).
//!
//! ## Precision: the f32 value plane
//!
//! Numeric *storage* is a pluggable plane under the same kernels: the
//! sealed [`sparse::Scalar`] trait (f64 / f32, always accumulating in
//! f64) generalizes the packed triangular sweeps ([`solve::packed`]),
//! CSR/ELL SpMV ([`sparse`]), and the preconditioner value arrays.
//! [`solver::SolverBuilder::precision`] (or the `PARAC_PRECISION` env
//! var, or `--precision` on the CLI) selects the plane per session:
//! [`sparse::Precision::F64`] keeps every result bit-identical to the
//! sequential reference, while [`sparse::Precision::F32`] halves the
//! preconditioner-apply value traffic and is protected by an iterative-
//! refinement guard in [`solve::pcg`] — if the f32 plane stagnates or
//! produces non-finite values, the solve transparently rebuilds the f64
//! plane mid-flight and continues (counted in
//! [`solve::pcg::SolveStats::fallbacks`]).
//!
//! The lower-level pieces remain public: [`factor::factorize`] produces
//! the [`factor::LdlFactor`], [`precond`] wraps it (and every baseline
//! the paper compares against) behind the allocation-free
//! [`precond::Preconditioner`] trait, and [`solve::pcg`] iterates over
//! any [`solve::LinearOperator`] — assembled or matrix-free.
//!
//! Alongside the core contribution the crate ships every substrate the
//! paper's evaluation depends on: sparse kernels ([`sparse`]), graph
//! generators mirroring the paper's matrix suite ([`graph`]), orderings
//! (AMD, nnz-sort, random, RCM — [`ordering`]), elimination-tree
//! analytics ([`etree`]), PCG with fused vector kernels and packed
//! level-scheduled triangular solves — one pool dispatch per sweep
//! over a contiguous level-major factor ([`solve`],
//! [`solve::packed`]), the persistent worker pool behind every
//! parallel section ([`par`] — the CPU stand-in for the paper's
//! resident kernel), and baseline preconditioners (IC(0), ICT,
//! smoothed-aggregation AMG, Jacobi — [`precond`]). A PJRT runtime
//! ([`runtime`], gated behind the off-by-default `xla` cargo feature)
//! loads AOT-compiled JAX/Pallas artifacts for the L1/L2 layers (see
//! `python/compile/`).

#![warn(missing_docs)]
// Clippy, tuned for this crate's numeric-kernel style: indexed loops
// are kept where the index *is* the mathematical object (sweep order
// matters and neighbors are gathered by position), engine entry points
// mirror the paper's parameter lists, and the engine-dispatch return
// type is one shared tuple.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod cli;
pub mod coordinator;
pub mod dynamic;
pub mod error;
pub mod etree;
pub mod factor;
pub mod faults;
pub mod gpusim;
pub mod graph;
pub mod ordering;
pub mod par;
pub mod precond;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod solve;
pub mod solver;
pub mod sparse;
pub mod testing;
pub mod util;

pub use error::ParacError;
pub use solver::{PrecondKind, Solver, SolverBuilder};
pub use sparse::Precision;
