//! The unified solver session: configure once, factor once, solve many
//! right-hand sides — the paper's "cheap construction, drop into PCG"
//! economics as a single object.
//!
//! [`Solver::builder`] collects every knob that used to be scattered
//! across `ParacOptions`, `pipeline::Method`, and `PcgOptions`:
//! elimination ordering, engine, seed, arena/sort/timing options, the
//! preconditioner choice ([`PrecondKind`] spans ParAC and every paper
//! baseline), and the PCG tolerances. [`SolverBuilder::build`] does all
//! the setup work (ordering, factorization, level-schedule analysis,
//! workspace sizing) and returns a typed
//! [`ParacError`](crate::error::ParacError) on bad input — nothing on
//! this surface panics.
//!
//! ## The `&self` solve contract
//!
//! A built session is **immutable shared state**: the operator, the
//! factor, the ordering maps, and the packed sweep arrays are frozen at
//! build time, and every per-solve intermediate lives in a
//! [`PcgWorkspace`] checked out from an internal
//! [`WorkspacePool`](crate::serve::WorkspacePool) for the duration of
//! one call. The primitives are therefore `&self`:
//! [`Solver::solve_shared`] and [`Solver::solve_batch_shared`] can be
//! called **concurrently from any number of threads** on one shared
//! `Solver` (it is `Sync`, asserted statically in [`crate::serve`]),
//! each call bit-identical to the same call made alone. The historical
//! `&mut self` entry points — [`Solver::solve`],
//! [`Solver::solve_into`], [`Solver::solve_batch`] — remain as thin
//! wrappers over the shared primitives for single-owner code.
//!
//! [`Solver::solve_into`] performs **zero heap allocations per PCG
//! iteration** (asserted by the tracking-allocator test in
//! `rust/tests/alloc_free.rs`): workspaces are recycled through the
//! pool, and every preconditioner applies via
//! [`Preconditioner::apply_scratch`](crate::precond::Preconditioner::apply_scratch)
//! into workspace scratch. One configuration allocates by design and is
//! exempt from that contract: AMG (V-cycle temporaries). Everything
//! else — including multi-threaded sessions, whose SpMV and
//! level-scheduled triangular solves dispatch onto the persistent
//! [`crate::par`] worker pool — allocates nothing after the pool is
//! warm (concurrent callers that deepen the workspace pool allocate
//! only while it grows to the peak concurrency).
//!
//! Parallelism and batching are session knobs:
//! * [`SolverBuilder::threads`] sets how many pool workers the solve
//!   phase uses (row-split SpMV via
//!   [`Csr::spmv_par`](crate::sparse::Csr::spmv_par), and — for the
//!   ParAC preconditioner — level-scheduled triangular solves through
//!   the packed executor ([`crate::solve::packed`]): one pool dispatch
//!   per sweep over a contiguous level-major factor copy, observable
//!   via [`Solver::sweep_counters`] and the per-solve
//!   `precond_dispatches`/`precond_barriers` fields of [`SolveStats`];
//!   [`SolverBuilder::level_cutoff`] tunes the width below which a
//!   level stays sequential). The default of 1 keeps the solve fully
//!   sequential. Concurrent callers' sweep dispatches serialize on the
//!   worker pool's dispatch lock — they block briefly, never error.
//! * [`Solver::solve_batch`] runs many right-hand sides through one
//!   session: one factor, one pool, one workspace, results
//!   **bit-identical** to looping [`Solver::solve_into`] per RHS.
//! * [`SolverBuilder::precision`] picks the value-storage plane of the
//!   ParAC preconditioner ([`Precision::F64`], the default, keeps every
//!   bit-identity guarantee; [`Precision::F32`] halves the bytes each
//!   apply streams, with an automatic mid-solve fallback to f64 for
//!   systems too ill-conditioned for narrow storage — see
//!   [`crate::sparse::scalar`] and the crate-level "Precision"
//!   section). Unset, the `PARAC_PRECISION` environment variable is
//!   consulted, then f64. The resolved plane is reported in
//!   [`FactorStats::precision`] and per-solve in
//!   [`SolveStats::precision`] /
//!   [`SolveStats::fallbacks`](crate::solve::pcg::SolveStats::fallbacks).
//! * [`SolverBuilder::build_shared`] returns a `Solver<'static>` that
//!   **owns** its Laplacian through an [`Arc`] — the form the
//!   [`crate::serve`] factor cache stores and shares across clients.
//!
//! Three entry points cover the workload spectrum:
//! * [`SolverBuilder::build`] — a graph [`Laplacian`] (possibly
//!   singular; mean-zero projection is selected automatically from
//!   [`LapKind`]).
//! * [`SolverBuilder::build_sdd`] — a raw SPD/SDD [`Csr`] (Dirichlet
//!   operators); ParAC goes through the rchol grounding construction.
//! * [`SolverBuilder::build_operator`] — any matrix-free
//!   [`LinearOperator`] with a caller-supplied preconditioner.
//!
//! ```
//! use parac::graph::generators::{self, Coeff};
//! use parac::solve::pcg;
//! use parac::solver::Solver;
//!
//! let lap = generators::grid2d(12, 12, Coeff::Uniform, 42);
//! let mut solver = Solver::builder()
//!     .seed(7)
//!     .tol(1e-8)
//!     .threads(2)
//!     .build(&lap)
//!     .expect("solver setup");
//!
//! // Solve a batch of right-hand sides with one reused workspace —
//! // bit-identical to looping `solve_into` per RHS.
//! let b1 = pcg::random_rhs(&lap, 1);
//! let b2 = pcg::random_rhs(&lap, 2);
//! let mut xs = vec![Vec::new(); 2];
//! let stats = solver.solve_batch(&[&b1, &b2], &mut xs).expect("dimensions match");
//! assert!(stats.iter().all(|s| s.converged));
//! ```

use crate::error::ParacError;
use crate::factor::{self, Engine, FactorStats, ParacOptions, SymbolicFactor};
use crate::graph::{LapKind, Laplacian};
use crate::ordering::Ordering;
use crate::precond::{
    AmgPrecond, Ichol0, IcholT, IdentityPrecond, JacobiPrecond, LdlPrecond, Preconditioner, Ssor,
};
use crate::precond::amg::AmgOptions;
use crate::serve::WorkspacePool;
use crate::solve::linop::LinearOperator;
use crate::solve::pcg::{self, PcgOptions, PcgResult, PcgWorkspace, SolveStats};
use crate::sparse::{Csr, Precision};
use crate::util::Timer;
use std::sync::{Arc, Mutex};

/// Which preconditioner a [`Solver`] builds — ParAC plus every baseline
/// the paper compares against, and the extra ablation baselines.
#[derive(Clone, Debug, PartialEq)]
pub enum PrecondKind {
    /// The ParAC `G D Gᵀ` factor; `level_threads > 0` uses the
    /// level-scheduled parallel triangular solve with that many pool
    /// workers.
    Parac {
        /// Workers for the level-scheduled solve. 0 = inherit the
        /// session-wide [`SolverBuilder::threads`] knob (sequential
        /// when that is 1, its default).
        level_threads: usize,
    },
    /// Zero fill-in incomplete Cholesky (cuSPARSE `csric02` proxy).
    Ichol0,
    /// Threshold ICT; `droptol = None` calibrates fill to `fill_target`.
    IcholT {
        /// Explicit drop tolerance (wins over `fill_target`).
        droptol: Option<f64>,
        /// Calibrate fill to this nonzero count when `droptol` is None.
        fill_target: Option<usize>,
    },
    /// Smoothed-aggregation AMG (HyPre / AmgX proxy).
    Amg,
    /// Diagonal (Jacobi) scaling.
    Jacobi,
    /// Symmetric SOR with relaxation factor `omega ∈ (0, 2)`.
    Ssor {
        /// Relaxation factor.
        omega: f64,
    },
    /// No preconditioning (plain CG).
    Identity,
}

impl PrecondKind {
    /// Display name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            PrecondKind::Parac { .. } => "ParAC",
            PrecondKind::Ichol0 => "ichol(0)",
            PrecondKind::IcholT { .. } => "ichol-t",
            PrecondKind::Amg => "AMG",
            PrecondKind::Jacobi => "Jacobi",
            PrecondKind::Ssor { .. } => "SSOR",
            PrecondKind::Identity => "identity",
        }
    }

    /// Parse a CLI name, with optional `name:value` parameters the same
    /// way [`Engine::parse`] accepts `cpu:8`:
    ///
    /// * `parac`, `parac:8` — level-scheduled solve threads;
    /// * `ichol0`;
    /// * `icholt` / `ichol-t`, `icholt:1e-4` — drop tolerance;
    /// * `amg`, `jacobi`;
    /// * `ssor`, `ssor:1.2` — relaxation factor;
    /// * `identity` / `none`.
    ///
    /// Unknown names, malformed parameters, and parameters on kinds
    /// that take none are all
    /// [`ParacError::InvalidOption`] — never a silent fallback.
    /// (Out-of-range values such as `ssor:7.0` parse here and are
    /// rejected with a typed error at build time.)
    pub fn parse(s: &str) -> Result<PrecondKind, ParacError> {
        let invalid = || ParacError::InvalidOption { what: "preconditioner", got: s.to_string() };
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (s, None),
        };
        let no_param = |kind: PrecondKind| if param.is_none() { Ok(kind) } else { Err(invalid()) };
        match name {
            "parac" => Ok(PrecondKind::Parac {
                level_threads: match param {
                    None => 0,
                    Some(p) => p.parse().map_err(|_| invalid())?,
                },
            }),
            "ichol0" => no_param(PrecondKind::Ichol0),
            "icholt" | "ichol-t" => {
                let droptol = match param {
                    None => 1e-3,
                    Some(p) => p.parse().map_err(|_| invalid())?,
                };
                Ok(PrecondKind::IcholT { droptol: Some(droptol), fill_target: None })
            }
            "amg" => no_param(PrecondKind::Amg),
            "jacobi" => no_param(PrecondKind::Jacobi),
            "ssor" => Ok(PrecondKind::Ssor {
                omega: match param {
                    None => 1.5,
                    Some(p) => p.parse().map_err(|_| invalid())?,
                },
            }),
            "identity" | "none" => no_param(PrecondKind::Identity),
            _ => Err(invalid()),
        }
    }
}

/// Configuration collector for [`Solver`]; create via
/// [`Solver::builder`], finish with one of the `build*` methods.
#[derive(Clone, Debug)]
pub struct SolverBuilder {
    parac: ParacOptions,
    precond: PrecondKind,
    pcg: PcgOptions,
    /// Mean-zero projection override; `None` = decide from the input
    /// (`LapKind::Graph` projects, SPD inputs don't).
    project: Option<bool>,
    /// Pool workers for the solve phase (SpMV + ParAC triangular
    /// solves); 1 = sequential, 0 = every pool worker.
    threads: usize,
    /// Level-width cutoff for the packed sweep executor; `None` =
    /// `PARAC_LEVEL_CUTOFF` env override or the built-in default.
    level_cutoff: Option<usize>,
    /// Explicit fault-injection spec (see [`crate::faults`]); `None` =
    /// consult `PARAC_FAULTS` once per process.
    faults: Option<String>,
}

impl Default for SolverBuilder {
    fn default() -> Self {
        SolverBuilder {
            parac: ParacOptions::default(),
            precond: PrecondKind::Parac { level_threads: 0 },
            pcg: PcgOptions::default(),
            project: None,
            threads: 1,
            level_cutoff: None,
            faults: None,
        }
    }
}

impl SolverBuilder {
    /// Elimination ordering for the ParAC factorization.
    pub fn ordering(mut self, ordering: Ordering) -> Self {
        self.parac.ordering = ordering;
        self
    }

    /// Factorization engine (`seq` / `cpu` / `gpusim`).
    pub fn engine(mut self, engine: Engine) -> Self {
        self.parac.engine = engine;
        self
    }

    /// RNG seed for the randomized sampling.
    pub fn seed(mut self, seed: u64) -> Self {
        self.parac.seed = seed;
        self
    }

    /// Fill-arena capacity multiplier over `nnz + n`.
    pub fn arena_factor(mut self, factor: f64) -> Self {
        self.parac.arena_factor = factor;
        self
    }

    /// Sort neighbors by |weight| before sampling (quality knob).
    pub fn sort_by_weight(mut self, sort: bool) -> Self {
        self.parac.sort_by_weight = sort;
        self
    }

    /// Collect per-stage wall times during factorization.
    pub fn stage_timing(mut self, timing: bool) -> Self {
        self.parac.stage_timing = timing;
        self
    }

    /// Replace the whole ParAC option block at once.
    pub fn parac_options(mut self, opts: ParacOptions) -> Self {
        self.parac = opts;
        self
    }

    /// Choose the preconditioner (default: sequential ParAC).
    pub fn preconditioner(mut self, kind: PrecondKind) -> Self {
        self.precond = kind;
        self
    }

    /// Worker threads for the **solve phase**, served by the persistent
    /// [`crate::par`] pool: `threads > 1` row-splits the operator SpMV
    /// ([`Csr::spmv_par`](crate::sparse::Csr::spmv_par)) and — when the
    /// preconditioner is ParAC and `level_threads` was left at 0 —
    /// switches the triangular solves to the level-scheduled parallel
    /// path with this many workers. `1` (the default) keeps the solve
    /// sequential; `0` means "all pool workers". Dispatch allocates
    /// nothing after the pool is warm.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Minimum level width the packed sweep executor splits across the
    /// pool (levels narrower than this run sequentially on the resident
    /// participant 0, behind the in-sweep barrier). Default: the
    /// `PARAC_LEVEL_CUTOFF` environment variable when set, otherwise
    /// [`crate::solve::trisolve::LEVEL_PAR_CUTOFF`]; an explicit call
    /// here wins over both. Only affects the ParAC preconditioner in
    /// level-scheduled mode. Clamped to at least 1.
    pub fn level_cutoff(mut self, cutoff: usize) -> Self {
        self.level_cutoff = Some(cutoff.max(1));
        self
    }

    /// Value-storage plane for the ParAC preconditioner's packed
    /// triangular sweeps (the factorization itself always computes in
    /// f64). [`Precision::F64`] — the default — keeps the bit-identity
    /// contract; [`Precision::F32`] halves the bytes streamed per
    /// apply, obeys a residual contract instead, and arms the
    /// [refinement guard](crate::solve::pcg) that transparently
    /// promotes back to f64 if the narrowed plane stagnates or
    /// overflows. Unset, the `PARAC_PRECISION` environment variable
    /// (then f64) decides. Ignored by the baseline preconditioners,
    /// which all store doubles.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.parac.precision = Some(precision);
        self
    }

    /// PCG relative-residual tolerance.
    pub fn tol(mut self, tol: f64) -> Self {
        self.pcg.tol = tol;
        self
    }

    /// PCG iteration cap.
    pub fn max_iter(mut self, max_iter: usize) -> Self {
        self.pcg.max_iter = max_iter;
        self
    }

    /// Record per-iteration relative residuals (read back via
    /// [`Solver::history`]).
    pub fn keep_history(mut self, keep: bool) -> Self {
        self.pcg.keep_history = keep;
        self
    }

    /// Force mean-zero projection on or off (default: automatic from
    /// the input kind).
    pub fn project(mut self, project: bool) -> Self {
        self.project = Some(project);
        self
    }

    /// Install a fault-injection plan for robustness testing (see
    /// [`crate::faults`] for the grammar; `"off"` clears). The plan is
    /// process-wide and armed when the session builds; unset, the
    /// `PARAC_FAULTS` environment variable is consulted once per
    /// process. A malformed spec is a typed
    /// [`ParacError::InvalidOption`] at build time.
    pub fn faults(mut self, spec: &str) -> Self {
        self.faults = Some(spec.to_string());
        self
    }

    /// The ParAC option block this builder currently carries (the
    /// serving layer's degrade-and-retry policy reads the active
    /// `arena_factor` from here before growing it).
    pub fn parac_opts(&self) -> &ParacOptions {
        &self.parac
    }

    /// Arm the fault plane: an explicit [`SolverBuilder::faults`] spec
    /// wins; otherwise `PARAC_FAULTS` is read once per process. Called
    /// on every `build*` (cold path — one lock when a spec is present,
    /// one `OnceLock` read otherwise).
    fn arm_faults(&self) -> Result<(), ParacError> {
        match &self.faults {
            Some(spec) => crate::faults::install_spec(spec)
                .map_err(|got| ParacError::InvalidOption { what: "faults", got }),
            None => crate::faults::init_from_env()
                .map_err(|got| ParacError::InvalidOption { what: "PARAC_FAULTS", got }),
        }
    }

    /// Replace the whole PCG option block at once (its `project` field
    /// is overridden by the automatic/explicit projection choice).
    pub fn pcg_options(mut self, opts: PcgOptions) -> Self {
        self.pcg = opts;
        self
    }

    /// Build a solver session for a graph Laplacian: validate, build
    /// the chosen preconditioner (factoring for ParAC), and pre-size
    /// the PCG workspace. All failures are typed; nothing panics on bad
    /// input.
    pub fn build<'a>(&self, lap: &'a Laplacian) -> Result<Solver<'a>, ParacError> {
        if lap.n() == 0 {
            return Err(ParacError::BadInput("empty matrix".into()));
        }
        check_finite_values(&lap.matrix.data)?;
        self.arm_faults()?;
        let timer = Timer::start();
        let (pre, stats, symbolic) = self.build_precond(lap)?;
        let project = self.project.unwrap_or(lap.kind == LapKind::Graph);
        let op = SessionOp::Matrix { a: &lap.matrix, threads: self.solve_threads() };
        Ok(self.assemble(op, pre, stats, symbolic, project, timer.secs()))
    }

    /// [`SolverBuilder::build`] for a **shared** (reference-counted)
    /// Laplacian: the session keeps the [`Arc`] instead of a borrow, so
    /// the returned `Solver<'static>` has no lifetime tie to the caller
    /// and can itself be put behind an `Arc` and handed to any number
    /// of threads — the form [`crate::serve::FactorCache`] stores.
    /// Reweighting goes through [`Solver::refactorize_shared`].
    pub fn build_shared(&self, lap: Arc<Laplacian>) -> Result<Solver<'static>, ParacError> {
        if lap.n() == 0 {
            return Err(ParacError::BadInput("empty matrix".into()));
        }
        check_finite_values(&lap.matrix.data)?;
        self.arm_faults()?;
        let timer = Timer::start();
        let (pre, stats, symbolic) = self.build_precond(&lap)?;
        let project = self.project.unwrap_or(lap.kind == LapKind::Graph);
        let op = SessionOp::OwnedLap { lap, threads: self.solve_threads() };
        Ok(self.assemble(op, pre, stats, symbolic, project, timer.secs()))
    }

    /// Run only the **symbolic phase** of the ParAC factorization for
    /// `lap` under this builder's options: ordering, permutation layout,
    /// and engine workspace sizing — no numeric work. The returned
    /// [`SymbolicFactor`] can then
    /// [`factorize`](SymbolicFactor::factorize) and
    /// [`refactorize_into`](SymbolicFactor::refactorize_into) any
    /// reweighting of the same sparsity pattern. [`SolverBuilder::build`]
    /// with a ParAC preconditioner performs exactly this analysis
    /// internally and keeps it for [`Solver::refactorize`].
    pub fn build_symbolic(&self, lap: &Laplacian) -> Result<SymbolicFactor, ParacError> {
        SymbolicFactor::analyze(lap, &self.parac)
    }

    /// Build a solver session for a raw SPD/SDD matrix (e.g. a
    /// Dirichlet Poisson operator). ParAC preconditioning goes through
    /// the rchol grounding construction
    /// ([`factor::factorize_sdd`]); projection defaults to off.
    pub fn build_sdd<'a>(&self, a: &'a Csr) -> Result<Solver<'a>, ParacError> {
        if a.nrows == 0 || a.nrows != a.ncols {
            return Err(ParacError::BadInput(format!(
                "expected a non-empty square matrix, got {}×{}",
                a.nrows, a.ncols
            )));
        }
        check_finite_values(&a.data)?;
        self.arm_faults()?;
        let timer = Timer::start();
        let (pre, stats): (Box<dyn Preconditioner>, _) = match &self.precond {
            PrecondKind::Parac { level_threads } => {
                let f = factor::factorize_sdd(a, &self.parac)?;
                let precision = self.resolved_precision();
                let mut stats = f.stats.clone();
                stats.precision = precision;
                (
                    wrap_ldl(f, self.level_threads(*level_threads), self.level_cutoff, precision),
                    Some(stats),
                )
            }
            other => (build_baseline(a, other, self.solve_threads())?, None),
        };
        let project = self.project.unwrap_or(false);
        let op = SessionOp::Matrix { a, threads: self.solve_threads() };
        // SDD sessions factor a grounded (N+1)-vertex extension and
        // truncate, so the symbolic product doesn't map back onto the
        // session operator — no refactorize support here.
        Ok(self.assemble(op, pre, stats, None, project, timer.secs()))
    }

    /// Build a solver session for a matrix-free operator with a
    /// caller-supplied preconditioner (use
    /// [`IdentityPrecond`] for plain CG); the
    /// builder's `precond` choice is ignored because matrix-dependent
    /// preconditioners cannot be constructed from an abstract operator.
    /// Projection defaults to off.
    pub fn build_operator<'a>(
        &self,
        op: &'a dyn LinearOperator,
        pre: Box<dyn Preconditioner>,
    ) -> Result<Solver<'a>, ParacError> {
        if op.n() == 0 {
            return Err(ParacError::BadInput("empty operator".into()));
        }
        let project = self.project.unwrap_or(false);
        let mut pcg = self.pcg.clone();
        pcg.project = project;
        let n = op.n();
        Ok(Solver {
            op: SessionOp::Dyn(op),
            pre,
            pcg,
            workspaces: WorkspacePool::new(n),
            history: Mutex::new(Vec::new()),
            n,
            setup_secs: 0.0,
            factor_stats: None,
            symbolic: None,
        })
    }

    fn assemble<'a>(
        &self,
        op: SessionOp<'a>,
        pre: Box<dyn Preconditioner>,
        factor_stats: Option<FactorStats>,
        symbolic: Option<SymbolicFactor>,
        project: bool,
        setup_secs: f64,
    ) -> Solver<'a> {
        let mut pcg = self.pcg.clone();
        pcg.project = project;
        let n = op.n();
        Solver {
            op,
            pre,
            pcg,
            workspaces: WorkspacePool::new(n),
            history: Mutex::new(Vec::new()),
            n,
            setup_secs,
            factor_stats,
            symbolic,
        }
    }

    fn build_precond(
        &self,
        lap: &Laplacian,
    ) -> Result<(Box<dyn Preconditioner>, Option<FactorStats>, Option<SymbolicFactor>), ParacError>
    {
        match &self.precond {
            PrecondKind::Parac { level_threads } => {
                let mut sym = self.build_symbolic(lap)?;
                let f = sym.factorize(lap)?;
                let precision = self.resolved_precision();
                let mut stats = f.stats.clone();
                stats.precision = precision;
                Ok((
                    wrap_ldl(f, self.level_threads(*level_threads), self.level_cutoff, precision),
                    Some(stats),
                    Some(sym),
                ))
            }
            other => Ok((build_baseline(&lap.matrix, other, self.solve_threads())?, None, None)),
        }
    }

    /// Resolve the value-storage plane: an explicit
    /// [`SolverBuilder::precision`] wins, then the `PARAC_PRECISION`
    /// environment variable, then [`Precision::F64`].
    fn resolved_precision(&self) -> Precision {
        self.parac.precision.or_else(Precision::from_env).unwrap_or_default()
    }

    /// Resolve the `threads` knob (0 = every worker of the global pool).
    fn solve_threads(&self) -> usize {
        match self.threads {
            0 => crate::par::global().size(),
            n => n,
        }
    }

    /// Effective level-scheduled solve width for a ParAC
    /// preconditioner: an explicit `level_threads` wins; otherwise the
    /// session-wide `threads` knob (sequential when that is 1).
    fn level_threads(&self, configured: usize) -> usize {
        if configured > 0 {
            configured
        } else {
            match self.solve_threads() {
                0 | 1 => 0,
                st => st,
            }
        }
    }
}

/// Wrap a ParAC factor as a preconditioner, with or without the
/// level-scheduled (packed-executor) parallel solve; `cutoff = None`
/// resolves to the environment/default cutoff. An `F32` plane always
/// routes through the packed executor — the sequential factor solve
/// has no narrowed storage — so `level_threads = 0` degrades to a
/// single-worker packed analysis there.
fn wrap_ldl(
    f: crate::factor::LdlFactor,
    level_threads: usize,
    cutoff: Option<usize>,
    precision: Precision,
) -> Box<dyn Preconditioner> {
    match precision {
        Precision::F64 => {
            if level_threads > 0 {
                Box::new(match cutoff {
                    Some(c) => LdlPrecond::with_level_schedule_cutoff(f, level_threads, c),
                    None => LdlPrecond::with_level_schedule(f, level_threads),
                })
            } else {
                Box::new(LdlPrecond::new(f))
            }
        }
        Precision::F32 => Box::new(LdlPrecond::with_level_schedule_precision(
            f,
            level_threads.max(1),
            cutoff.unwrap_or_else(crate::solve::packed::default_cutoff),
            Precision::F32,
        )),
    }
}

/// Reject NaN/±inf matrix values at build time with a typed error: a
/// single non-finite weight silently poisons the whole factorization
/// (NaN propagates through every elimination it touches), so the
/// session surface refuses it up front. One predictable pass over the
/// value array — noise next to a factorization.
fn check_finite_values(data: &[f64]) -> Result<(), ParacError> {
    match data.iter().position(|v| !v.is_finite()) {
        None => Ok(()),
        Some(i) => Err(ParacError::BadInput(format!(
            "matrix value at nnz index {i} is non-finite ({})",
            data[i]
        ))),
    }
}

/// Build a non-ParAC preconditioner from an assembled matrix. Setup
/// passes that chunk cleanly run on the persistent pool with the
/// session's `threads` budget (currently the Jacobi diagonal
/// extraction; results are bit-identical to the sequential setup).
fn build_baseline(
    a: &Csr,
    kind: &PrecondKind,
    threads: usize,
) -> Result<Box<dyn Preconditioner>, ParacError> {
    Ok(match kind {
        PrecondKind::Parac { .. } => unreachable!("handled by the callers"),
        PrecondKind::Ichol0 => Box::new(Ichol0::try_new(a)?),
        PrecondKind::IcholT { droptol, fill_target } => Box::new(match (droptol, fill_target) {
            (Some(t), _) => IcholT::try_new(a, *t)?,
            (None, Some(nnz)) => IcholT::try_with_fill_target(a, *nnz)?,
            (None, None) => IcholT::try_new(a, 1e-3)?,
        }),
        PrecondKind::Amg => Box::new(AmgPrecond::new(a, &AmgOptions::default())),
        PrecondKind::Jacobi => Box::new(JacobiPrecond::new_par(a, threads)),
        PrecondKind::Ssor { omega } => Box::new(Ssor::try_new(a, *omega)?),
        PrecondKind::Identity => Box::new(IdentityPrecond),
    })
}

/// The operator a session applies each PCG iteration: either a
/// caller-supplied matrix-free operator, or an assembled CSR matrix
/// whose SpMV is row-split across the persistent pool when the session
/// was built with `threads > 1`.
enum SessionOp<'a> {
    /// Abstract operator from [`SolverBuilder::build_operator`].
    Dyn(&'a dyn LinearOperator),
    /// Assembled matrix; `threads > 1` dispatches [`Csr::spmv_par`].
    Matrix {
        /// The borrowed operator matrix.
        a: &'a Csr,
        /// Row-split width (1 = sequential SpMV).
        threads: usize,
    },
    /// Reference-counted Laplacian from [`SolverBuilder::build_shared`]
    /// — no borrow, so the session is `'static` and cacheable.
    OwnedLap {
        /// The shared operator graph.
        lap: Arc<Laplacian>,
        /// Row-split width (1 = sequential SpMV).
        threads: usize,
    },
}

impl LinearOperator for SessionOp<'_> {
    fn n(&self) -> usize {
        match self {
            SessionOp::Dyn(op) => op.n(),
            SessionOp::Matrix { a, .. } => a.nrows,
            SessionOp::OwnedLap { lap, .. } => lap.n(),
        }
    }

    fn apply_to(&self, x: &[f64], y: &mut [f64]) {
        match self {
            SessionOp::Dyn(op) => op.apply_to(x, y),
            SessionOp::Matrix { a, threads } => a.spmv_par(x, y, *threads),
            SessionOp::OwnedLap { lap, threads } => lap.matrix.spmv_par(x, y, *threads),
        }
    }
}

/// A configured, factored solver session: the operator, the owned
/// preconditioner, PCG options, and a pool of reusable workspaces.
/// Create via [`Solver::builder`]; call [`Solver::solve_shared`] /
/// [`Solver::solve_batch_shared`] (through `&self`, from any number of
/// threads) or the single-owner `&mut self` wrappers [`Solver::solve`]
/// / [`Solver::solve_into`] / [`Solver::solve_batch`] as many times as
/// there are right-hand sides.
///
/// Everything reachable from a solve is immutable after construction —
/// the only mutable state is the workspace pool (checked out per call)
/// and the history store (swapped under a lock after a solve) — which
/// is why the session is `Sync` (asserted statically in
/// [`crate::serve`]) and concurrent solves are bit-identical to the
/// same solves run alone.
pub struct Solver<'a> {
    op: SessionOp<'a>,
    pre: Box<dyn Preconditioner>,
    pcg: PcgOptions,
    /// Per-call Krylov workspaces: checked out on entry to a solve,
    /// returned on exit; grows to the peak concurrency, then recycles.
    workspaces: WorkspacePool,
    /// Residual history of the most recently *completed* solve (only
    /// written when the builder set `keep_history`; under concurrency
    /// the last finisher wins).
    history: Mutex<Vec<f64>>,
    n: usize,
    setup_secs: f64,
    factor_stats: Option<FactorStats>,
    /// The frozen symbolic phase of a ParAC graph session — powers
    /// [`Solver::refactorize`]. `None` for baselines, SDD, and
    /// operator sessions.
    symbolic: Option<SymbolicFactor>,
}

impl<'a> Solver<'a> {
    /// Start configuring a solver session.
    pub fn builder() -> SolverBuilder {
        SolverBuilder::default()
    }

    /// Operator dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Wall-clock seconds spent in `build*` (preconditioner
    /// construction — the paper's "Factorize/Setup/Analysis" columns).
    pub fn setup_secs(&self) -> f64 {
        self.setup_secs
    }

    /// The preconditioner (for `name()` / `nnz()` reporting).
    pub fn preconditioner(&self) -> &dyn Preconditioner {
        self.pre.as_ref()
    }

    /// ParAC factor statistics (None for baseline preconditioners).
    /// After [`Solver::refactorize`] these describe the most recent
    /// numeric run — `symbolic_reused` is set and `symbolic_secs` is 0.
    pub fn factor_stats(&self) -> Option<&FactorStats> {
        self.factor_stats.as_ref()
    }

    /// The ParAC factor backing the preconditioner (None for baseline
    /// preconditioners and operator sessions).
    pub fn factor(&self) -> Option<&crate::factor::LdlFactor> {
        self.pre.as_ldl().map(|p| p.factor())
    }

    /// Re-run only the **numeric phase** on new edge weights: `lap`
    /// must have exactly the sparsity pattern this session was built
    /// on (same vertices, same edges — only weights may differ; a
    /// structural change is a typed [`ParacError::BadInput`], rebuild
    /// instead). The frozen ordering, elimination layout, engine
    /// workspaces, and — when the reweighting preserves the factor's
    /// structure — the packed sweep schedules are all reused, so steady
    /// state performs no ordering, no e-tree work, no analysis, and no
    /// heap allocation. The refreshed factor is **bit-identical** to a
    /// fresh [`SolverBuilder::build`] on `lap` with the same options.
    /// Only available on ParAC graph sessions
    /// ([`SolverBuilder::build`]); the session's operator is re-pointed
    /// at `lap`, so subsequent solves target the new system.
    pub fn refactorize(&mut self, lap: &'a Laplacian) -> Result<(), ParacError> {
        if matches!(self.op, SessionOp::OwnedLap { .. }) {
            return Err(ParacError::BadInput(
                "this session owns its Laplacian (build_shared); use refactorize_shared".into(),
            ));
        }
        self.refactorize_numeric_only(lap)?;
        if let SessionOp::Matrix { a, .. } = &mut self.op {
            *a = &lap.matrix;
        }
        Ok(())
    }

    /// [`Solver::refactorize`] for sessions built with
    /// [`SolverBuilder::build_shared`]: same numeric-only contract, but
    /// the session's owned [`Arc`] is re-pointed at `lap`, so the
    /// `'static` session keeps owning its operator. This is the path
    /// [`crate::serve::FactorCache`] routes reweighted builds through.
    pub fn refactorize_shared(&mut self, lap: Arc<Laplacian>) -> Result<(), ParacError> {
        if !matches!(self.op, SessionOp::OwnedLap { .. }) {
            return Err(ParacError::BadInput(
                "refactorize_shared requires a session built with SolverBuilder::build_shared"
                    .into(),
            ));
        }
        self.refactorize_numeric_only(&lap)?;
        if let SessionOp::OwnedLap { lap: owned, .. } = &mut self.op {
            *owned = lap;
        }
        Ok(())
    }

    /// Swap a **structurally different** factor under the session in
    /// one move. This is the splice half of the dynamic subsystem's
    /// cone-localized refactorization ([`crate::dynamic::cone`]): the
    /// caller re-eliminated the damaged columns against `lap` and
    /// spliced them into the previous factor; this call installs the
    /// result and re-points the session operator at `lap`. The packed
    /// sweep schedules are re-analyzed from the new factor (the
    /// structure changed, so the refill fast path cannot apply), and
    /// the frozen symbolic analysis is dropped — it describes the old
    /// pattern — so a later [`Solver::refactorize_shared`] on this
    /// session is a typed [`ParacError::BadInput`] until a full rebuild
    /// re-freezes it. Only available on sessions built with
    /// [`SolverBuilder::build_shared`] and the ParAC preconditioner.
    pub fn splice_factor(
        &mut self,
        lap: Arc<Laplacian>,
        factor: crate::factor::LdlFactor,
    ) -> Result<(), ParacError> {
        if !matches!(self.op, SessionOp::OwnedLap { .. }) {
            return Err(ParacError::BadInput(
                "splice_factor requires a session built with SolverBuilder::build_shared".into(),
            ));
        }
        if lap.n() != self.n {
            return Err(ParacError::DimensionMismatch {
                what: "splice operator",
                expected: self.n,
                got: lap.n(),
            });
        }
        if factor.n() != self.n {
            return Err(ParacError::DimensionMismatch {
                what: "splice factor",
                expected: self.n,
                got: factor.n(),
            });
        }
        let ldl = self.pre.as_ldl_mut().ok_or_else(|| {
            ParacError::BadInput("splice_factor requires the ParAC preconditioner".into())
        })?;
        ldl.refactorize_numeric(|f| {
            *f = factor;
            // Structure not preserved: force packed-plane re-analysis.
            Ok::<bool, ParacError>(false)
        })?;
        self.factor_stats = Some(ldl.factor().stats.clone());
        self.symbolic = None;
        if let SessionOp::OwnedLap { lap: owned, .. } = &mut self.op {
            *owned = lap;
        }
        Ok(())
    }

    /// Shared numeric-refactorize core: validates, reruns the numeric
    /// phase on the frozen symbolic analysis, refreshes the factor
    /// stats. The caller re-points the session operator.
    fn refactorize_numeric_only(&mut self, lap: &Laplacian) -> Result<(), ParacError> {
        if lap.n() != self.n {
            return Err(ParacError::DimensionMismatch {
                what: "refactorize operator",
                expected: self.n,
                got: lap.n(),
            });
        }
        let sym = self.symbolic.as_mut().ok_or_else(|| {
            ParacError::BadInput(
                "refactorize requires a ParAC graph session built with SolverBuilder::build"
                    .into(),
            )
        })?;
        let ldl = self.pre.as_ldl_mut().ok_or_else(|| {
            ParacError::BadInput("refactorize requires the ParAC preconditioner".into())
        })?;
        ldl.refactorize_numeric(|f| sym.refactorize_into(lap, f))?;
        self.factor_stats = Some(ldl.factor().stats.clone());
        Ok(())
    }

    /// Cumulative sweep dispatch/barrier counters of the packed
    /// triangular-solve executor (None unless the preconditioner is
    /// ParAC in level-scheduled mode). Per-solve deltas are also
    /// recorded on every returned
    /// [`SolveStats`] (`precond_dispatches` / `precond_barriers`) —
    /// the observable behind the O(1)-dispatches-per-sweep claim.
    pub fn sweep_counters(&self) -> Option<crate::solve::packed::SweepCounters> {
        self.pre.sweep_counters()
    }

    /// Per-iteration relative residuals of the most recent completed
    /// solve (empty unless the builder set `keep_history`). Returned by
    /// value: the store is shared across concurrent `&self` solves (the
    /// last finisher wins), so callers get a stable snapshot.
    pub fn history(&self) -> Vec<f64> {
        self.history.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// The PCG options this session runs with.
    pub fn pcg_options(&self) -> &PcgOptions {
        &self.pcg
    }

    /// Grow the session's workspace pool to at least `count` idle
    /// Krylov workspaces. A serving deployment calls this once before
    /// opening the session to `count` concurrent clients, so that even
    /// the *first* wave of overlapping [`Solver::solve_shared`] calls
    /// stays allocation-free (without it, calls that raise the peak
    /// concurrency allocate their workspace on first checkout).
    pub fn warm_workspaces(&self, count: usize) {
        self.workspaces.warm(count);
    }

    /// Solve `A x = b`, allocating the solution vector. Non-convergence
    /// is data (`converged == false`), not an error. Thin wrapper over
    /// [`Solver::solve_shared`].
    pub fn solve(&mut self, b: &[f64]) -> Result<PcgResult, ParacError> {
        let mut x = vec![0.0; self.n];
        let stats = self.solve_shared(b, &mut x)?;
        Ok(PcgResult {
            x,
            iters: stats.iters,
            rel_residual: stats.rel_residual,
            converged: stats.converged,
            history: self.history(),
        })
    }

    /// Solve `A x = b` into a caller buffer: zero heap allocations per
    /// PCG iteration (AMG is the one exception — see the module docs).
    /// `x` is overwritten (the initial guess is zero). Non-convergence
    /// is data, not an error. Thin `&mut self` wrapper over
    /// [`Solver::solve_shared`] for single-owner code.
    pub fn solve_into(&mut self, b: &[f64], x: &mut [f64]) -> Result<SolveStats, ParacError> {
        self.solve_shared(b, x)
    }

    /// Solve `A x = b` into a caller buffer through `&self` — the
    /// shared-session primitive. Any number of threads may call this
    /// concurrently on one solver: each call checks a [`PcgWorkspace`]
    /// out of the session pool, runs PCG against the immutable operator
    /// and preconditioner, and returns the workspace. Results are
    /// **bit-identical** to the same call made alone (asserted in
    /// `rust/tests/serve.rs`), and after the pool has grown to the peak
    /// concurrency a call performs zero heap allocations.
    pub fn solve_shared(&self, b: &[f64], x: &mut [f64]) -> Result<SolveStats, ParacError> {
        if b.len() != self.n {
            return Err(ParacError::DimensionMismatch {
                what: "rhs",
                expected: self.n,
                got: b.len(),
            });
        }
        if x.len() != self.n {
            return Err(ParacError::DimensionMismatch {
                what: "solution",
                expected: self.n,
                got: x.len(),
            });
        }
        let mut ws = self.workspaces.checkout();
        let stats = pcg::solve_into(&self.op, b, self.pre.as_ref(), &self.pcg, &mut ws, x);
        self.store_history(&mut ws);
        self.workspaces.restore(ws);
        Ok(stats)
    }

    /// Solve the same system for a **batch** of right-hand sides,
    /// reusing one factor, one pool, and one workspace across all of
    /// them — the "amortize setup across traffic" half of the paper's
    /// cheap-construction economics. Each `xs[i]` is resized to the
    /// operator dimension once (so passing empty vectors is fine), then
    /// overwritten.
    ///
    /// Results are **bit-identical** to calling [`Solver::solve_into`]
    /// once per right-hand side in order (property-tested per engine in
    /// `rust/tests/solver.rs`): batching changes amortization, never
    /// answers. Dimension errors are reported before any solve runs.
    /// Thin `&mut self` wrapper over [`Solver::solve_batch_shared`].
    pub fn solve_batch(
        &mut self,
        bs: &[&[f64]],
        xs: &mut [Vec<f64>],
    ) -> Result<Vec<SolveStats>, ParacError> {
        let mut stats = Vec::with_capacity(bs.len());
        self.solve_batch_shared(bs, xs, &mut stats)?;
        Ok(stats)
    }

    /// [`Solver::solve_batch`] through `&self`, with caller-owned stats
    /// storage (cleared, then one entry per right-hand side) so a warm
    /// caller can stay allocation-free. One workspace is checked out
    /// for the whole wave. Safe to call concurrently with any other
    /// `*_shared` call; bit-identical to looping
    /// [`Solver::solve_shared`] per RHS.
    pub fn solve_batch_shared(
        &self,
        bs: &[&[f64]],
        xs: &mut [Vec<f64>],
        stats: &mut Vec<SolveStats>,
    ) -> Result<(), ParacError> {
        if bs.len() != xs.len() {
            return Err(ParacError::DimensionMismatch {
                what: "batch solutions",
                expected: bs.len(),
                got: xs.len(),
            });
        }
        for b in bs {
            if b.len() != self.n {
                return Err(ParacError::DimensionMismatch {
                    what: "rhs",
                    expected: self.n,
                    got: b.len(),
                });
            }
        }
        for x in xs.iter_mut() {
            x.resize(self.n, 0.0);
        }
        stats.clear();
        stats.reserve(bs.len());
        let mut ws = self.workspaces.checkout();
        for (b, x) in bs.iter().zip(xs.iter_mut()) {
            stats.push(pcg::solve_into(&self.op, b, self.pre.as_ref(), &self.pcg, &mut ws, x));
        }
        self.store_history(&mut ws);
        self.workspaces.restore(ws);
        Ok(())
    }

    /// The serving wave primitive: [`Solver::solve_batch_shared`] with
    /// **per-request deadlines and per-request outcomes**. Whole-wave
    /// shape mismatches (slice lengths, RHS dimensions) are still one
    /// `Err` before any solve runs, exactly like the batch path; per
    /// request, a deadline that lapsed while the request was queued
    /// sheds it without solving, and a deadline that lapses mid-PCG
    /// abandons that solve — both reported as
    /// [`ParacError::DeadlineExceeded`] in that request's slot of
    /// `results`. One workspace serves the whole wave, and with every
    /// deadline `None` the arithmetic — and every solution bit — is
    /// identical to [`Solver::solve_batch_shared`].
    pub fn solve_wave_shared(
        &self,
        bs: &[&[f64]],
        deadlines: &[Option<pcg::Deadline>],
        xs: &mut [Vec<f64>],
        results: &mut Vec<Result<SolveStats, ParacError>>,
    ) -> Result<(), ParacError> {
        if bs.len() != xs.len() {
            return Err(ParacError::DimensionMismatch {
                what: "batch solutions",
                expected: bs.len(),
                got: xs.len(),
            });
        }
        if bs.len() != deadlines.len() {
            return Err(ParacError::DimensionMismatch {
                what: "batch deadlines",
                expected: bs.len(),
                got: deadlines.len(),
            });
        }
        for b in bs {
            if b.len() != self.n {
                return Err(ParacError::DimensionMismatch {
                    what: "rhs",
                    expected: self.n,
                    got: b.len(),
                });
            }
        }
        for x in xs.iter_mut() {
            x.resize(self.n, 0.0);
        }
        results.clear();
        results.reserve(bs.len());
        let mut ws = self.workspaces.checkout();
        for ((b, d), x) in bs.iter().zip(deadlines).zip(xs.iter_mut()) {
            if d.is_some_and(|d| d.lapsed()) {
                // Shed while queued: the budget was gone before this
                // request's turn in the wave came up.
                results.push(Err(ParacError::DeadlineExceeded));
                continue;
            }
            let stats =
                pcg::solve_into_deadline(&self.op, b, self.pre.as_ref(), &self.pcg, &mut ws, x, *d);
            results.push(if stats.timed_out {
                Err(ParacError::DeadlineExceeded)
            } else {
                Ok(stats)
            });
        }
        self.store_history(&mut ws);
        self.workspaces.restore(ws);
        Ok(())
    }

    /// Publish a finished workspace's residual history to the session
    /// store (O(1) buffer swap; only when the session records history —
    /// otherwise both buffers are empty and the lock is skipped).
    fn store_history(&self, ws: &mut PcgWorkspace) {
        if self.pcg.keep_history {
            let mut store = self.history.lock().unwrap_or_else(|p| p.into_inner());
            ws.swap_history(&mut store);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn builder_defaults_solve_a_laplacian() {
        let lap = generators::grid2d(16, 16, generators::Coeff::Uniform, 0);
        let mut s = Solver::builder().seed(3).build(&lap).unwrap();
        assert_eq!(s.n(), lap.n());
        assert!(s.factor_stats().is_some());
        assert!(s.preconditioner().nnz() > 0);
        let b = pcg::random_rhs(&lap, 1);
        let out = s.solve(&b).unwrap();
        assert!(out.converged, "rel={}", out.rel_residual);
    }

    #[test]
    fn every_precond_kind_builds_and_converges() {
        let lap = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let b = pcg::random_rhs(&lap, 5);
        for kind in [
            PrecondKind::Parac { level_threads: 0 },
            PrecondKind::Parac { level_threads: 2 },
            PrecondKind::Ichol0,
            PrecondKind::IcholT { droptol: Some(1e-3), fill_target: None },
            PrecondKind::Amg,
            PrecondKind::Jacobi,
            PrecondKind::Ssor { omega: 1.5 },
            PrecondKind::Identity,
        ] {
            let name = kind.name();
            let mut s = Solver::builder()
                .preconditioner(kind)
                .max_iter(3000)
                .tol(1e-7)
                .build(&lap)
                .unwrap();
            let out = s.solve(&b).unwrap();
            assert!(out.converged, "{name}: rel={}", out.rel_residual);
        }
    }

    #[test]
    fn bad_input_is_typed_not_panicking() {
        let empty = Laplacian::from_edges(0, &[], "empty");
        match Solver::builder().build(&empty) {
            Err(ParacError::BadInput(_)) => {}
            Err(other) => panic!("expected BadInput, got {other:?}"),
            Ok(_) => panic!("expected BadInput, got a solver"),
        }

        let lap = generators::grid2d(4, 4, generators::Coeff::Uniform, 0);
        match Solver::builder()
            .preconditioner(PrecondKind::Ssor { omega: 7.0 })
            .build(&lap)
        {
            Err(ParacError::InvalidOption { what, .. }) => assert_eq!(what, "ssor omega"),
            Err(other) => panic!("expected InvalidOption, got {other:?}"),
            Ok(_) => panic!("expected InvalidOption, got a solver"),
        }

        let mut s = Solver::builder().build(&lap).unwrap();
        let mut x = vec![0.0; lap.n()];
        match s.solve_into(&[1.0, 2.0], &mut x) {
            Err(ParacError::DimensionMismatch { what: "rhs", expected, got }) => {
                assert_eq!((expected, got), (lap.n(), 2));
            }
            other => panic!("expected DimensionMismatch, got {other:?}"),
        }
    }

    #[test]
    fn sdd_session_solves_grounded_system() {
        // Dirichlet 2D Poisson: Laplacian + boundary mass → SPD.
        let lap = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let n = lap.n();
        let mut coo = crate::sparse::Coo::new(n, n);
        for r in 0..n {
            for (&c, &v) in lap.matrix.row_indices(r).iter().zip(lap.matrix.row_data(r)) {
                coo.push(r as u32, c, v);
            }
        }
        for r in 0..12u32 {
            coo.push(r, r, 1.0);
        }
        let a = coo.to_csr();
        let mut s = Solver::builder().tol(1e-10).max_iter(500).build_sdd(&a).unwrap();
        let mut rng = crate::rng::Rng::new(2);
        let xs: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let b = a.mul_vec(&xs);
        let out = s.solve(&b).unwrap();
        assert!(out.converged);
        for (got, want) in out.x.iter().zip(&xs) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    fn matrix_free_operator_session() {
        struct Shifted<'m>(&'m Csr);
        impl LinearOperator for Shifted<'_> {
            fn n(&self) -> usize {
                self.0.nrows
            }
            fn apply_to(&self, x: &[f64], y: &mut [f64]) {
                self.0.spmv(x, y);
                for (yi, xi) in y.iter_mut().zip(x) {
                    *yi += 0.5 * xi;
                }
            }
        }
        let lap = generators::grid2d(8, 8, generators::Coeff::Uniform, 0);
        let op = Shifted(&lap.matrix);
        let mut s = Solver::builder()
            .build_operator(&op, Box::new(IdentityPrecond))
            .unwrap();
        let b = pcg::random_rhs(&lap, 9);
        let out = s.solve(&b).unwrap();
        assert!(out.converged, "rel={}", out.rel_residual);
    }

    #[test]
    fn history_survives_in_session() {
        let lap = generators::grid2d(10, 10, generators::Coeff::Uniform, 0);
        let mut s = Solver::builder().keep_history(true).build(&lap).unwrap();
        let b = pcg::random_rhs(&lap, 4);
        let out = s.solve(&b).unwrap();
        assert_eq!(s.history().len(), out.iters);
        assert_eq!(s.history(), &out.history[..]);
    }

    #[test]
    fn precond_kind_parse_name_roundtrip() {
        for s in ["parac", "ichol0", "icholt", "amg", "jacobi", "ssor", "identity"] {
            let k = PrecondKind::parse(s).unwrap();
            assert!(!k.name().is_empty());
        }
        assert!(matches!(
            PrecondKind::parse("nonsense"),
            Err(ParacError::InvalidOption { what: "preconditioner", .. })
        ));
    }

    #[test]
    fn precond_kind_parse_accepts_parameters() {
        assert_eq!(
            PrecondKind::parse("parac:8").unwrap(),
            PrecondKind::Parac { level_threads: 8 }
        );
        assert_eq!(
            PrecondKind::parse("ssor:1.2").unwrap(),
            PrecondKind::Ssor { omega: 1.2 }
        );
        assert_eq!(
            PrecondKind::parse("icholt:1e-4").unwrap(),
            PrecondKind::IcholT { droptol: Some(1e-4), fill_target: None }
        );
        assert_eq!(
            PrecondKind::parse("ichol-t:1e-2").unwrap(),
            PrecondKind::IcholT { droptol: Some(1e-2), fill_target: None }
        );
        // Malformed or misplaced parameters are typed errors, not
        // silent fallbacks.
        for bad in ["parac:x", "ssor:", "icholt:tiny", "jacobi:2", "identity:0", "amg:3"] {
            assert!(
                matches!(
                    PrecondKind::parse(bad),
                    Err(ParacError::InvalidOption { what: "preconditioner", .. })
                ),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn f32_precision_session_converges_and_reports_the_plane() {
        let lap = generators::grid2d(20, 20, generators::Coeff::HighContrast(3.0), 1);
        let b = pcg::random_rhs(&lap, 8);
        let mut s64 = Solver::builder().seed(2).precision(Precision::F64).build(&lap).unwrap();
        let mut s32 = Solver::builder().seed(2).precision(Precision::F32).build(&lap).unwrap();
        assert_eq!(s64.factor_stats().unwrap().precision, Precision::F64);
        assert_eq!(s32.factor_stats().unwrap().precision, Precision::F32);
        let mut x64 = vec![0.0; lap.n()];
        let mut x32 = vec![0.0; lap.n()];
        let st64 = s64.solve_into(&b, &mut x64).unwrap();
        let st32 = s32.solve_into(&b, &mut x32).unwrap();
        assert!(st64.converged && st32.converged);
        assert_eq!((st64.precision, st64.fallbacks), (Precision::F64, 0));
        // This benign grid converges on the narrow plane without the
        // guard firing, and within the iteration-budget contract.
        assert_eq!((st32.precision, st32.fallbacks), (Precision::F32, 0));
        assert!(st32.rel_residual <= s32.pcg_options().tol);
        assert!(
            st32.iters as f64 <= (st64.iters as f64 * 1.3).ceil(),
            "f32 iters {} vs f64 iters {}",
            st32.iters,
            st64.iters
        );
    }

    #[test]
    fn threads_knob_changes_nothing_numerically() {
        // Hold the arithmetic fixed (level-scheduled triangular solves
        // in both sessions — the level schedule accumulates in row
        // order, unlike the sequential CSC sweep) and vary only the
        // dispatch: one pool worker vs four, sequential SpMV vs the
        // row-split parallel SpMV. Per-entry arithmetic is identical,
        // so the solutions must be bit-identical. The grid clears
        // `PAR_SPMV_CUTOFF`, so the parallel SpMV really dispatches.
        let lap = generators::grid2d(40, 40, generators::Coeff::Uniform, 0);
        assert!(lap.n() >= crate::sparse::csr::PAR_SPMV_CUTOFF);
        let b = pcg::random_rhs(&lap, 6);
        let narrow = Solver::builder()
            .seed(2)
            .engine(crate::factor::Engine::Seq)
            .preconditioner(PrecondKind::Parac { level_threads: 1 })
            .build(&lap)
            .unwrap()
            .solve(&b)
            .unwrap();
        let wide = Solver::builder()
            .seed(2)
            .engine(crate::factor::Engine::Seq)
            .preconditioner(PrecondKind::Parac { level_threads: 4 })
            .threads(4)
            .build(&lap)
            .unwrap()
            .solve(&b)
            .unwrap();
        assert_eq!(narrow.x, wide.x, "threads(4) must be bit-identical to threads(1)");
        assert_eq!(narrow.iters, wide.iters);
        assert!(wide.converged);
    }

    #[test]
    fn dispatch_counters_observe_one_dispatch_per_sweep() {
        // Two applies per iteration never happen — PCG applies the
        // preconditioner once per iteration plus once at setup — and
        // each apply must cost exactly 2 pool dispatches (one per sweep
        // direction) no matter how many levels the DAG has. A cutoff of
        // 1 makes every level "wide", so the old executor would have
        // paid O(levels × applies) dispatches here.
        let lap = generators::grid2d(20, 20, generators::Coeff::Uniform, 0);
        let mut s = Solver::builder()
            .seed(4)
            .engine(crate::factor::Engine::Seq)
            .preconditioner(PrecondKind::Parac { level_threads: 4 })
            .level_cutoff(1)
            .build(&lap)
            .unwrap();
        assert_eq!(s.sweep_counters().unwrap(), Default::default());
        let b = pcg::random_rhs(&lap, 1);
        let mut x = vec![0.0; lap.n()];
        let stats = s.solve_into(&b, &mut x).unwrap();
        assert!(stats.converged);
        // Applies = 1 at setup + one per iteration except the last
        // (which converges before the tail apply) = `iters` exactly.
        let applies = stats.iters as u64;
        assert_eq!(stats.precond_dispatches, 2 * applies);
        assert!(stats.precond_barriers >= stats.precond_dispatches);
        assert_eq!(s.sweep_counters().unwrap().dispatches, stats.precond_dispatches);

        // Baselines report no sweep counters and zeroed stats fields.
        let mut jac = Solver::builder()
            .preconditioner(PrecondKind::Jacobi)
            .max_iter(2000)
            .build(&lap)
            .unwrap();
        assert!(jac.sweep_counters().is_none());
        let jstats = jac.solve_into(&b, &mut x).unwrap();
        assert_eq!((jstats.precond_dispatches, jstats.precond_barriers), (0, 0));
    }

    #[test]
    fn refactorize_matches_fresh_build_and_solves_new_system() {
        let lap = generators::grid2d(14, 14, generators::Coeff::Uniform, 0);
        // Same pattern, new weights (declared before the sessions so
        // the borrow outlives them).
        let edges: Vec<(u32, u32, f64)> = lap
            .edges()
            .into_iter()
            .enumerate()
            .map(|(i, (a, b, w))| (a, b, w * (1.0 + (i % 5) as f64 * 0.5)))
            .collect();
        let lap2 = Laplacian::from_edges(lap.n(), &edges, "reweighted");
        let build = || Solver::builder().seed(5).threads(2).level_cutoff(4);

        let mut s = build().build(&lap).unwrap();
        let built_stats = s.factor_stats().unwrap().clone();
        assert!(!built_stats.symbolic_reused);
        assert!(built_stats.symbolic_secs > 0.0, "build must report the analysis time");
        s.refactorize(&lap2).unwrap();
        let st = s.factor_stats().unwrap();
        assert!(st.symbolic_reused, "refactorize must reuse the symbolic phase");
        assert_eq!(st.symbolic_secs, 0.0, "no analysis work on refactorize");

        // Bit-identical to a from-scratch session on the new weights.
        let mut fresh = build().build(&lap2).unwrap();
        assert_eq!(s.factor().unwrap().g, fresh.factor().unwrap().g);
        assert_eq!(s.factor().unwrap().diag, fresh.factor().unwrap().diag);

        // And the session now solves the *new* system, identically.
        let b = pcg::random_rhs(&lap2, 3);
        let got = s.solve(&b).unwrap();
        let want = fresh.solve(&b).unwrap();
        assert!(got.converged);
        assert_eq!(got.x, want.x);
        assert_eq!(got.iters, want.iters);
    }

    #[test]
    fn splice_factor_repoints_the_session_and_errors_are_typed() {
        let lap = generators::grid2d(10, 10, generators::Coeff::Uniform, 0);
        let denser = {
            let mut edges = lap.edges();
            edges.push((0, 55, 1.5));
            Laplacian::from_edges(lap.n(), &edges, "denser")
        };
        // A full factor of the new graph stands in for a spliced one
        // here — the splice construction itself is pinned in
        // `crate::dynamic::cone`.
        let f = crate::factor::factorize(&denser, &crate::factor::ParacOptions::default()).unwrap();
        let denser = Arc::new(denser);
        let mut s = Solver::builder().seed(3).build_shared(Arc::new(lap.clone())).unwrap();
        s.splice_factor(denser.clone(), f).unwrap();
        // The session now solves the *new* system.
        let b = pcg::random_rhs(&denser, 2);
        let mut x = vec![0.0; denser.n()];
        assert!(s.solve_shared(&b, &mut x).unwrap().converged);
        // The structural change drops the frozen symbolic phase.
        assert!(matches!(
            s.refactorize_shared(denser.clone()),
            Err(ParacError::BadInput(_))
        ));
        // Dimension mismatches are typed.
        let small = generators::grid2d(4, 4, generators::Coeff::Uniform, 0);
        let f_small =
            crate::factor::factorize(&small, &crate::factor::ParacOptions::default()).unwrap();
        assert!(matches!(
            s.splice_factor(denser.clone(), f_small),
            Err(ParacError::DimensionMismatch { what: "splice factor", .. })
        ));
        // Borrowed sessions cannot splice.
        let mut borrowed = Solver::builder().build(&lap).unwrap();
        let f2 = crate::factor::factorize(&lap, &crate::factor::ParacOptions::default()).unwrap();
        assert!(matches!(
            borrowed.splice_factor(denser.clone(), f2),
            Err(ParacError::BadInput(_))
        ));
    }

    #[test]
    fn refactorize_error_paths_are_typed() {
        let lap = generators::grid2d(8, 8, generators::Coeff::Uniform, 0);
        let bigger = generators::grid2d(9, 9, generators::Coeff::Uniform, 0);
        let same_n_other_pattern = generators::path(64);

        let mut s = Solver::builder().build(&lap).unwrap();
        match s.refactorize(&bigger) {
            Err(ParacError::DimensionMismatch { what: "refactorize operator", expected, got }) => {
                assert_eq!((expected, got), (64, 81));
            }
            other => panic!("expected DimensionMismatch, got {other:?}"),
        }
        match s.refactorize(&same_n_other_pattern) {
            Err(ParacError::BadInput(msg)) => assert!(msg.contains("pattern"), "{msg}"),
            other => panic!("expected BadInput, got {other:?}"),
        }
        // A failed refactorize leaves the session solvable.
        let b = pcg::random_rhs(&lap, 2);
        assert!(s.solve(&b).unwrap().converged);

        // Baseline sessions cannot refactorize.
        let mut jac = Solver::builder()
            .preconditioner(PrecondKind::Jacobi)
            .max_iter(2000)
            .build(&lap)
            .unwrap();
        assert!(matches!(jac.refactorize(&lap), Err(ParacError::BadInput(_))));
    }

    #[test]
    fn non_finite_weights_are_rejected_at_build_time() {
        // Regression (satellite of the robustness PR): a NaN or ±inf
        // edge weight used to flow straight into the factorization and
        // poison it silently; now every build surface rejects it.
        let mut lap = generators::grid2d(6, 6, generators::Coeff::Uniform, 0);
        lap.matrix.data[3] = f64::NAN;
        assert!(matches!(
            Solver::builder().build(&lap),
            Err(ParacError::BadInput(msg)) if msg.contains("non-finite")
        ));
        lap.matrix.data[3] = f64::INFINITY;
        assert!(matches!(
            Solver::builder().build_shared(Arc::new(lap.clone())),
            Err(ParacError::BadInput(msg)) if msg.contains("non-finite")
        ));
        let mut a = generators::grid2d(6, 6, generators::Coeff::Uniform, 0).matrix;
        a.data[0] = f64::NEG_INFINITY;
        assert!(matches!(
            Solver::builder().build_sdd(&a),
            Err(ParacError::BadInput(msg)) if msg.contains("non-finite")
        ));
    }

    #[test]
    fn bad_fault_spec_is_a_typed_build_error() {
        let lap = generators::grid2d(4, 4, generators::Coeff::Uniform, 0);
        assert!(matches!(
            Solver::builder().faults("no-such-site=3").build(&lap),
            Err(ParacError::InvalidOption { what: "faults", .. })
        ));
        // "off" is a valid spec and must not perturb the build.
        assert!(Solver::builder().faults("off").build(&lap).is_ok());
    }

    #[test]
    fn solve_wave_matches_batch_without_deadlines_and_sheds_lapsed_ones() {
        let lap = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let s = Solver::builder().seed(3).build(&lap).unwrap();
        let b1 = pcg::random_rhs(&lap, 1);
        let b2 = pcg::random_rhs(&lap, 2);
        let bs: Vec<&[f64]> = vec![&b1, &b2];

        let mut batch_xs = vec![Vec::new(), Vec::new()];
        let mut batch_stats = Vec::new();
        s.solve_batch_shared(&bs, &mut batch_xs, &mut batch_stats).unwrap();

        // All-None deadlines: bit-identical to the batch path.
        let mut wave_xs = vec![Vec::new(), Vec::new()];
        let mut results = Vec::new();
        s.solve_wave_shared(&bs, &[None, None], &mut wave_xs, &mut results).unwrap();
        assert_eq!(wave_xs, batch_xs, "deadline-less wave must match the batch path bit for bit");
        for (r, want) in results.iter().zip(&batch_stats) {
            let got = r.as_ref().unwrap();
            assert_eq!(got.iters, want.iters);
            assert!(!got.timed_out);
        }

        // A lapsed deadline sheds its request; the neighbor still
        // solves to the same bits.
        let lapsed = Some(pcg::Deadline::after(std::time::Duration::ZERO));
        let mut xs = vec![Vec::new(), Vec::new()];
        s.solve_wave_shared(&bs, &[lapsed, None], &mut xs, &mut results).unwrap();
        assert!(matches!(results[0], Err(ParacError::DeadlineExceeded)));
        assert!(results[1].as_ref().unwrap().converged);
        assert_eq!(xs[1], batch_xs[1]);

        // Shape errors stay whole-wave, before any solve.
        assert!(matches!(
            s.solve_wave_shared(&bs, &[None], &mut xs, &mut results),
            Err(ParacError::DimensionMismatch { what: "batch deadlines", .. })
        ));
    }

    #[test]
    fn solve_batch_smoke() {
        let lap = generators::grid2d(12, 12, generators::Coeff::Uniform, 0);
        let mut s = Solver::builder().seed(3).build(&lap).unwrap();
        let b1 = pcg::random_rhs(&lap, 1);
        let b2 = pcg::random_rhs(&lap, 2);
        let mut xs = vec![Vec::new(), vec![0.0; 3]]; // wrong sizes grow/shrink to n
        let stats = s.solve_batch(&[&b1, &b2], &mut xs).unwrap();
        assert_eq!(stats.len(), 2);
        assert!(stats.iter().all(|st| st.converged));
        assert!(xs.iter().all(|x| x.len() == lap.n()));

        // Mismatched batch shapes are typed errors.
        assert!(matches!(
            s.solve_batch(&[&b1], &mut []),
            Err(ParacError::DimensionMismatch { what: "batch solutions", .. })
        ));
        let short = vec![1.0; 3];
        let mut one = vec![Vec::new()];
        assert!(matches!(
            s.solve_batch(&[&short], &mut one),
            Err(ParacError::DimensionMismatch { what: "rhs", .. })
        ));
    }
}
