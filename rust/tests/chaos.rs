//! Chaos soak: the ISSUE 9 capstone.
//!
//! One test, four phases, all driven by the deterministic fault plane
//! (`parac::faults`):
//!
//! 1. **Poisoned-lock recovery** — a `worker-panic=1` plan makes the
//!    first pooled build panic *inside* `FactorCache::get_or_build`
//!    (poisoning the cache mutex); once faults clear, the same cache
//!    must keep serving.
//! 2. **Degrade-and-retry ladder** — a seed chosen so the very first
//!    arena and NaN probes fire walks the service through all three
//!    rungs (grown arena → f64 plane → sequential engine) before the
//!    build lands; `ServiceStats::retries` reconciles exactly.
//! 3. **Seeded soak** — 8 client threads hammer a deadline-armed
//!    service while latency (and, when the pool is real, worker-panic)
//!    faults fire on schedule. Contract: no hang, no escaped panic,
//!    every failure is a typed `ParacError`, and the service counters
//!    reconcile with what the clients observed.
//! 4. **Recovery** — with the plan cleared, the soaked service still
//!    converges, and a fresh graph served through it is bit-identical
//!    to a standalone fault-free solver with the same knobs.
//!
//! The fault plane is process-global state, so this binary holds
//! exactly one `#[test]` and CI runs it with `--test-threads=1`.
//! Sites probed from inside worker-pool jobs cannot fire when the
//! global pool degenerates to an inline call (`PARAC_THREADS=1`);
//! those phases gate on the pool size so the soak passes under both
//! CI thread counts.

use parac::error::ParacError;
use parac::faults::{self, FaultPlan, Site};
use parac::graph::generators::{self, Coeff};
use parac::serve::{FactorCache, ServeOptions, SolveService};
use parac::solve::pcg;
use parac::solver::Solver;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Client threads in the soak phase.
const SOAK_CLIENTS: usize = 8;
/// Requests each soak client issues.
const SOAK_REQUESTS: usize = 12;

#[test]
fn chaos_soak_stays_typed_and_recovery_restores_bit_identity() {
    // Consume the PARAC_FAULTS env slot first so a later builder's
    // `init_from_env` can never clobber the plans this test installs.
    faults::init_from_env().expect("PARAC_FAULTS must parse");
    // Pool-borne sites (worker-panic) only fire on a real dispatch;
    // a size-1 global pool runs every job inline past the probe.
    let pooled = parac::par::global().size() > 1;

    // ------------------------------------------------------------------
    // Phase 1: a build panic poisons the cache lock; the cache recovers.
    // ------------------------------------------------------------------
    if pooled {
        faults::install_spec("worker-panic=1").unwrap();
        let cache = FactorCache::new(Solver::builder().seed(3).threads(2), 4);
        let lap = Arc::new(generators::grid2d(16, 16, Coeff::Uniform, 1));
        let r = catch_unwind(AssertUnwindSafe(|| cache.get_or_build(&lap)));
        assert!(r.is_err(), "worker-panic=1 must panic the pooled build");
        assert!(faults::fired(Site::WorkerPanic) >= 1);

        faults::install(None);
        let solver = cache
            .get_or_build(&lap)
            .expect("a poisoned cache lock must keep serving after recovery");
        let b = pcg::random_rhs(&lap, 1);
        let mut x = vec![0.0; lap.n()];
        assert!(solver.solve_shared(&b, &mut x).unwrap().converged);
    }

    // ------------------------------------------------------------------
    // Phase 2: escaped overflow + NaN factor walk the full degrade
    // ladder deterministically.
    // ------------------------------------------------------------------
    // Pick a seed whose phase makes probe 0 fire on both sites (about a
    // quarter of seeds do); with period 2 the probe sequence is then
    // arena: fire,ok,fire,ok,…  nan: fire,ok,…  which drives exactly:
    //   attempt 0  arena(c0) fires  -> ArenaFull
    //   retry 1    arena(c1) ok, nan(c0) fires -> non-finite factor
    //   retry 2    arena(c2) fires  -> ArenaFull
    //   retry 3    arena(c3) ok, nan(c1) ok    -> built (seq engine)
    let ladder_seed = (0u64..256)
        .find(|s| {
            let spec = format!("seed={s},arena-overflow=2,nan-packed-values=2");
            let p = FaultPlan::parse(&spec).unwrap().unwrap();
            p.fires_at(Site::ArenaOverflow, 0) && p.fires_at(Site::NanPackedValues, 0)
        })
        .expect("some seed under 256 fires both sites at probe 0");
    faults::install_spec(&format!(
        "seed={ladder_seed},arena-overflow=2,nan-packed-values=2"
    ))
    .unwrap();

    let svc = SolveService::new(
        FactorCache::new(Solver::builder().seed(7), 4),
        ServeOptions { max_wave: 1, ..Default::default() },
    );
    let lap = Arc::new(generators::grid2d(14, 14, Coeff::Uniform, 2));
    let b = pcg::random_rhs(&lap, 5);
    let (x, stats) = svc.solve(&lap, &b).expect("degrade-and-retry must save this build");
    assert!(stats.converged);
    assert_eq!(x.len(), lap.n());
    assert_eq!(
        svc.stats().retries,
        3,
        "the schedule above climbs exactly three rungs"
    );
    assert!(faults::fired(Site::ArenaOverflow) >= 2);
    assert!(faults::fired(Site::NanPackedValues) >= 1);

    // ------------------------------------------------------------------
    // Phase 3: seeded soak under deadlines, latency faults, and (when
    // pooled) injected worker panics.
    // ------------------------------------------------------------------
    let soak_spec = if pooled {
        "seed=11,solve-latency=5,latency-us=20000,worker-panic=700"
    } else {
        "seed=11,solve-latency=5,latency-us=20000"
    };
    faults::install_spec(soak_spec).unwrap();

    let svc = SolveService::new(
        FactorCache::new(Solver::builder().seed(9).threads(2), 4),
        ServeOptions {
            max_wave: 4,
            max_wait: Duration::from_micros(200),
            max_queue: 4,
            deadline: Some(Duration::from_millis(5)),
        },
    );
    let laps = [
        Arc::new(generators::grid2d(12, 12, Coeff::Uniform, 3)),
        Arc::new(generators::grid2d(13, 13, Coeff::Uniform, 3)),
    ];
    let ok = AtomicU64::new(0);
    let deadline_errs = AtomicU64::new(0);
    let overload_errs = AtomicU64::new(0);
    let internal_errs = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for client in 0..SOAK_CLIENTS {
            let svc = &svc;
            let laps = &laps;
            let (ok, deadline_errs, overload_errs, internal_errs) =
                (&ok, &deadline_errs, &overload_errs, &internal_errs);
            scope.spawn(move || {
                for i in 0..SOAK_REQUESTS {
                    let lap = &laps[(client + i) % laps.len()];
                    let b = pcg::random_rhs(lap, (client * SOAK_REQUESTS + i) as u64);
                    match svc.solve(lap, &b) {
                        Ok((x, stats)) => {
                            assert_eq!(x.len(), lap.n());
                            assert!(stats.converged, "an Ok solve must have converged");
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ParacError::DeadlineExceeded) => {
                            deadline_errs.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ParacError::Overloaded { .. }) => {
                            overload_errs.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ParacError::Internal(_)) => {
                            internal_errs.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("chaos surfaced a non-contract error: {e}"),
                    }
                }
            });
        }
    });

    let issued = (SOAK_CLIENTS * SOAK_REQUESTS) as u64;
    let st = svc.stats();
    let observed = (
        ok.load(Ordering::Relaxed),
        deadline_errs.load(Ordering::Relaxed),
        overload_errs.load(Ordering::Relaxed),
        internal_errs.load(Ordering::Relaxed),
    );
    assert_eq!(
        observed.0 + observed.1 + observed.2 + observed.3,
        issued,
        "every request resolves exactly once"
    );
    assert_eq!(st.requests, issued, "the service saw every request");
    assert_eq!(st.deadline_shed, observed.1, "deadline stat reconciles with clients");
    assert_eq!(st.shed, observed.2, "overload stat reconciles with clients");
    assert!(
        st.quarantined <= observed.3,
        "every quarantined wave failed at least its leader with Internal"
    );
    assert!(observed.0 > 0, "the soak must not starve every request");
    assert!(
        faults::probed(Site::SolveLatency) > 0,
        "the latency site must have been consulted during the soak"
    );

    // ------------------------------------------------------------------
    // Phase 4: faults cleared — the soaked service recovers, and fresh
    // traffic is bit-identical to a fault-free standalone solver.
    // ------------------------------------------------------------------
    faults::install(None);
    for lap in &laps {
        let b = pcg::random_rhs(lap, 999);
        let (_, stats) = svc.solve(lap, &b).expect("soaked graphs must still serve");
        assert!(stats.converged);
    }

    let fresh = Arc::new(generators::grid2d(17, 17, Coeff::Uniform, 4));
    let bf = pcg::random_rhs(&fresh, 99);
    let (got, stats) = svc.solve(&fresh, &bf).expect("fresh graph after chaos");
    assert!(stats.converged);
    let standalone = Solver::builder().seed(9).threads(2).build(&fresh).unwrap();
    let mut want = vec![0.0; fresh.n()];
    standalone.solve_shared(&bf, &mut want).unwrap();
    assert_eq!(
        got, want,
        "with the plan cleared, served bits must match the fault-free reference"
    );
}
