//! Cross-cutting property tests on factorization invariants, run over
//! seeded random graphs (the crate's proptest stand-in, see
//! `parac::testing::prop`).

use parac::factor::{factorize, Engine, ParacOptions};
use parac::graph::generators;
use parac::ordering::Ordering;
use parac::testing::prop::forall_seeds;

fn opts(seed: u64, ordering: Ordering, engine: Engine) -> ParacOptions {
    ParacOptions { seed, ordering, engine, ..Default::default() }
}

/// Columns of `G` inherit the Laplacian's zero column sums: for every
/// non-empty pivot, `1 + Σ_i G[i,k] = 0` (the merged weights divided by
/// their own sum). This pins the normalization of Algorithm 1 line 8.
#[test]
fn g_columns_sum_to_minus_one() {
    forall_seeds(12, |seed| {
        let l = generators::random_connected(120, 200, seed);
        let f = factorize(&l, &opts(seed, Ordering::Random, Engine::Seq)).unwrap();
        for k in 0..f.n() {
            let col_sum: f64 = f.g.col_data(k).iter().sum();
            if f.diag[k] > 0.0 {
                if (1.0 + col_sum).abs() > 1e-12 {
                    return Err(format!("column {k}: 1 + Σ = {}", 1.0 + col_sum));
                }
            } else if !f.g.col_rows(k).is_empty() {
                return Err(format!("zero pivot {k} has stored entries"));
            }
        }
        Ok(())
    });
}

/// The number of zero pivots equals the number of connected components
/// (one per component — its last-eliminated vertex).
#[test]
fn zero_pivots_count_components() {
    forall_seeds(12, |seed| {
        let mut rng = parac::rng::Rng::new(seed);
        // Build a forest of 1–4 random components.
        let ncomp = 1 + rng.below(4);
        let mut edges = Vec::new();
        let mut base = 0u32;
        let mut total = 0usize;
        for _ in 0..ncomp {
            let sz = 5 + rng.below(30);
            for v in 1..sz as u32 {
                edges.push((base + rng.below(v as usize) as u32, base + v, 1.0));
            }
            base += sz as u32;
            total += sz;
        }
        let l = parac::graph::Laplacian::from_edges(total, &edges, "forest");
        let f = factorize(&l, &opts(seed, Ordering::Random, Engine::Cpu { threads: 2 }))
            .unwrap();
        let zeros = f.diag.iter().filter(|&&d| d == 0.0).count();
        if zeros != ncomp {
            return Err(format!("{zeros} zero pivots for {ncomp} components"));
        }
        Ok(())
    });
}

/// Total fill is bounded: every pivot with m merged neighbors samples
/// exactly m−1 edges, so `nnz(G) = Σ m_k` and `fills = Σ (m_k − 1)` —
/// the structural identity `fills == nnz(G) − (n − #empty)`.
#[test]
fn fill_identity_holds() {
    forall_seeds(12, |seed| {
        let l = generators::random_connected(200, 380, seed);
        let f = factorize(&l, &opts(seed, Ordering::NnzSort, Engine::Seq)).unwrap();
        let nonempty = f.diag.iter().filter(|&&d| d > 0.0).count() as u64;
        if f.stats.fills != f.nnz() as u64 - nonempty {
            return Err(format!(
                "fills {} != nnz(G) {} − nonempty {nonempty}",
                f.stats.fills,
                f.nnz()
            ));
        }
        Ok(())
    });
}

/// The factor's quadratic form is PSD: `xᵀ G D Gᵀ x ≥ 0` for all x
/// (D ≥ 0 by construction).
#[test]
fn factor_operator_is_psd() {
    forall_seeds(12, |seed| {
        let l = generators::random_connected(80, 140, seed);
        let f = factorize(&l, &opts(seed, Ordering::Amd, Engine::Seq)).unwrap();
        let mut rng = parac::rng::Rng::new(seed ^ 0xF00);
        for _ in 0..10 {
            let x: Vec<f64> = (0..80).map(|_| rng.next_normal()).collect();
            let q = parac::sparse::ops::dot(&x, &f.apply(&x));
            if q < -1e-9 {
                return Err(format!("negative quadratic form {q}"));
            }
        }
        Ok(())
    });
}

/// Arena sizing is self-healing: absurdly small initial estimates still
/// produce the *same* factor after internal retries.
#[test]
fn arena_retry_preserves_determinism() {
    forall_seeds(8, |seed| {
        let l = generators::pref_attach(300, 5, seed);
        let normal = factorize(&l, &opts(seed, Ordering::Natural, Engine::Cpu { threads: 2 }))
            .unwrap();
        let mut tight = opts(seed, Ordering::Natural, Engine::Cpu { threads: 2 });
        tight.arena_factor = 0.02;
        let retried = factorize(&l, &tight).unwrap();
        if normal.g != retried.g || normal.diag != retried.diag {
            return Err("retry changed the factor".into());
        }
        Ok(())
    });
}

/// Permuted solves are consistent: preconditioner apply must be
/// symmetric (`⟨M⁻¹u, v⟩ = ⟨u, M⁻¹v⟩`) — required by PCG — for every
/// ordering.
#[test]
fn precond_apply_is_symmetric() {
    forall_seeds(8, |seed| {
        let l = generators::random_connected(100, 170, seed);
        for ord in [Ordering::Amd, Ordering::NnzSort, Ordering::Random, Ordering::Rcm] {
            let f = factorize(&l, &opts(seed, ord, Engine::Seq)).unwrap();
            let pre = parac::precond::LdlPrecond::new(f);
            let mut rng = parac::rng::Rng::new(seed ^ 0xABC);
            let u: Vec<f64> = (0..100).map(|_| rng.next_normal()).collect();
            let v: Vec<f64> = (0..100).map(|_| rng.next_normal()).collect();
            use parac::precond::Preconditioner;
            let left = parac::sparse::ops::dot(&pre.apply(&u), &v);
            let right = parac::sparse::ops::dot(&u, &pre.apply(&v));
            if (left - right).abs() > 1e-9 * left.abs().max(1.0) {
                return Err(format!("{ord:?}: asymmetric apply {left} vs {right}"));
            }
        }
        Ok(())
    });
}
