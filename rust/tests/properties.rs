//! Cross-cutting property tests on factorization invariants, run over
//! seeded random graphs (the crate's proptest stand-in, see
//! `parac::testing::prop`).

use parac::factor::{factorize, Engine, ParacOptions};
use parac::graph::generators;
use parac::ordering::Ordering;
use parac::testing::prop::forall_seeds;

fn opts(seed: u64, ordering: Ordering, engine: Engine) -> ParacOptions {
    ParacOptions { seed, ordering, engine, ..Default::default() }
}

/// Columns of `G` inherit the Laplacian's zero column sums: for every
/// non-empty pivot, `1 + Σ_i G[i,k] = 0` (the merged weights divided by
/// their own sum). This pins the normalization of Algorithm 1 line 8.
#[test]
fn g_columns_sum_to_minus_one() {
    forall_seeds(12, |seed| {
        let l = generators::random_connected(120, 200, seed);
        let f = factorize(&l, &opts(seed, Ordering::Random, Engine::Seq)).unwrap();
        for k in 0..f.n() {
            let col_sum: f64 = f.g.col_data(k).iter().sum();
            if f.diag[k] > 0.0 {
                if (1.0 + col_sum).abs() > 1e-12 {
                    return Err(format!("column {k}: 1 + Σ = {}", 1.0 + col_sum));
                }
            } else if !f.g.col_rows(k).is_empty() {
                return Err(format!("zero pivot {k} has stored entries"));
            }
        }
        Ok(())
    });
}

/// The number of zero pivots equals the number of connected components
/// (one per component — its last-eliminated vertex).
#[test]
fn zero_pivots_count_components() {
    forall_seeds(12, |seed| {
        let mut rng = parac::rng::Rng::new(seed);
        // Build a forest of 1–4 random components.
        let ncomp = 1 + rng.below(4);
        let mut edges = Vec::new();
        let mut base = 0u32;
        let mut total = 0usize;
        for _ in 0..ncomp {
            let sz = 5 + rng.below(30);
            for v in 1..sz as u32 {
                edges.push((base + rng.below(v as usize) as u32, base + v, 1.0));
            }
            base += sz as u32;
            total += sz;
        }
        let l = parac::graph::Laplacian::from_edges(total, &edges, "forest");
        let f = factorize(&l, &opts(seed, Ordering::Random, Engine::Cpu { threads: 2 }))
            .unwrap();
        let zeros = f.diag.iter().filter(|&&d| d == 0.0).count();
        if zeros != ncomp {
            return Err(format!("{zeros} zero pivots for {ncomp} components"));
        }
        Ok(())
    });
}

/// Total fill is bounded: every pivot with m merged neighbors samples
/// exactly m−1 edges, so `nnz(G) = Σ m_k` and `fills = Σ (m_k − 1)` —
/// the structural identity `fills == nnz(G) − (n − #empty)`.
#[test]
fn fill_identity_holds() {
    forall_seeds(12, |seed| {
        let l = generators::random_connected(200, 380, seed);
        let f = factorize(&l, &opts(seed, Ordering::NnzSort, Engine::Seq)).unwrap();
        let nonempty = f.diag.iter().filter(|&&d| d > 0.0).count() as u64;
        if f.stats.fills != f.nnz() as u64 - nonempty {
            return Err(format!(
                "fills {} != nnz(G) {} − nonempty {nonempty}",
                f.stats.fills,
                f.nnz()
            ));
        }
        Ok(())
    });
}

/// The factor's quadratic form is PSD: `xᵀ G D Gᵀ x ≥ 0` for all x
/// (D ≥ 0 by construction).
#[test]
fn factor_operator_is_psd() {
    forall_seeds(12, |seed| {
        let l = generators::random_connected(80, 140, seed);
        let f = factorize(&l, &opts(seed, Ordering::Amd, Engine::Seq)).unwrap();
        let mut rng = parac::rng::Rng::new(seed ^ 0xF00);
        for _ in 0..10 {
            let x: Vec<f64> = (0..80).map(|_| rng.next_normal()).collect();
            let q = parac::sparse::ops::dot(&x, &f.apply(&x));
            if q < -1e-9 {
                return Err(format!("negative quadratic form {q}"));
            }
        }
        Ok(())
    });
}

/// Arena sizing is self-healing: absurdly small initial estimates still
/// produce the *same* factor after internal retries.
#[test]
fn arena_retry_preserves_determinism() {
    forall_seeds(8, |seed| {
        let l = generators::pref_attach(300, 5, seed);
        let normal = factorize(&l, &opts(seed, Ordering::Natural, Engine::Cpu { threads: 2 }))
            .unwrap();
        let mut tight = opts(seed, Ordering::Natural, Engine::Cpu { threads: 2 });
        tight.arena_factor = 0.02;
        let retried = factorize(&l, &tight).unwrap();
        if normal.g != retried.g || normal.diag != retried.diag {
            return Err("retry changed the factor".into());
        }
        Ok(())
    });
}

/// The allocation-free `apply_into` matches the legacy `apply` shim
/// bit-for-bit for every preconditioner — even when the output buffer
/// starts poisoned with NaN, which proves no implementation reads the
/// buffer's prior contents.
#[test]
fn apply_into_matches_apply_for_every_preconditioner() {
    use parac::precond::{
        AmgPrecond, Ichol0, IcholT, IdentityPrecond, JacobiPrecond, LdlPrecond, Preconditioner,
        Ssor,
    };
    use parac::precond::amg::AmgOptions;
    forall_seeds(6, |seed| {
        let l = generators::random_connected(90, 150, seed);
        let f = factorize(&l, &opts(seed, Ordering::Amd, Engine::Seq))
            .map_err(|e| e.to_string())?;
        let f_lvl = f.clone();
        let pres: Vec<Box<dyn Preconditioner>> = vec![
            Box::new(LdlPrecond::new(f)),
            Box::new(LdlPrecond::with_level_schedule(f_lvl, 2)),
            Box::new(Ichol0::new(&l.matrix)),
            Box::new(IcholT::new(&l.matrix, 1e-3)),
            Box::new(AmgPrecond::new(&l.matrix, &AmgOptions::default())),
            Box::new(JacobiPrecond::new(&l.matrix)),
            Box::new(Ssor::new(&l.matrix, 1.3)),
            Box::new(IdentityPrecond),
        ];
        let mut rng = parac::rng::Rng::new(seed ^ 0x5EED);
        let r: Vec<f64> = (0..l.n()).map(|_| rng.next_normal()).collect();
        for pre in &pres {
            let want = pre.apply(&r);
            let mut z = vec![f64::NAN; l.n()];
            pre.apply_into(&r, &mut z);
            if z != want {
                return Err(format!("{}: apply_into deviates from apply", pre.name()));
            }
            // A second application into the now-dirty buffer must also
            // be identical (workspace-reuse property).
            pre.apply_into(&r, &mut z);
            if z != want {
                return Err(format!("{}: dirty-buffer reuse deviates", pre.name()));
            }
        }
        Ok(())
    });
}

/// `Engine::parse` accepts every display name it produces, and
/// parameterized spellings round-trip through `name()`.
#[test]
fn engine_parse_name_roundtrip() {
    for (spec, name, want) in [
        ("seq", "seq", Engine::Seq),
        ("cpu", "cpu", Engine::Cpu { threads: 0 }),
        ("cpu:8", "cpu", Engine::Cpu { threads: 8 }),
        ("gpusim", "gpusim", Engine::GpuSim { blocks: 0 }),
        ("gpu", "gpusim", Engine::GpuSim { blocks: 0 }),
        ("gpu:8", "gpusim", Engine::GpuSim { blocks: 8 }),
        ("gpusim:64", "gpusim", Engine::GpuSim { blocks: 64 }),
    ] {
        let e = Engine::parse(spec).unwrap_or_else(|| panic!("{spec} must parse"));
        assert_eq!(e, want, "{spec}");
        assert_eq!(e.name(), name, "{spec}");
        // name() itself is always re-parseable.
        assert!(Engine::parse(e.name()).is_some(), "{name} must re-parse");
    }
    assert!(Engine::parse("tpu").is_none());
    assert!(Engine::parse("cpu:x").is_none());
}

/// Permuted solves are consistent: preconditioner apply must be
/// symmetric (`⟨M⁻¹u, v⟩ = ⟨u, M⁻¹v⟩`) — required by PCG — for every
/// ordering.
#[test]
fn precond_apply_is_symmetric() {
    forall_seeds(8, |seed| {
        let l = generators::random_connected(100, 170, seed);
        for ord in [Ordering::Amd, Ordering::NnzSort, Ordering::Random, Ordering::Rcm] {
            let f = factorize(&l, &opts(seed, ord, Engine::Seq)).unwrap();
            let pre = parac::precond::LdlPrecond::new(f);
            let mut rng = parac::rng::Rng::new(seed ^ 0xABC);
            let u: Vec<f64> = (0..100).map(|_| rng.next_normal()).collect();
            let v: Vec<f64> = (0..100).map(|_| rng.next_normal()).collect();
            use parac::precond::Preconditioner;
            let left = parac::sparse::ops::dot(&pre.apply(&u), &v);
            let right = parac::sparse::ops::dot(&u, &pre.apply(&v));
            if (left - right).abs() > 1e-9 * left.abs().max(1.0) {
                return Err(format!("{ord:?}: asymmetric apply {left} vs {right}"));
            }
        }
        Ok(())
    });
}

/// The symbolic/numeric split round-trips exactly, across every engine,
/// ordering, and thread count: `Solver::refactorize` with **unchanged**
/// weights reproduces the original factor bit for bit (and keeps the
/// packed executor — its cumulative sweep counters survive, which a
/// re-analysis would reset), and with **new** weights it matches a
/// from-scratch build with the same seed exactly.
#[test]
fn refactorize_bit_identical_across_engines_orderings_threads() {
    use parac::solver::Solver;

    let lap = generators::random_connected(150, 240, 3);
    // Same pattern, different weights (merged-edge order is preserved
    // by rebuilding from the extracted edge list).
    let edges: Vec<(u32, u32, f64)> = lap
        .edges()
        .into_iter()
        .enumerate()
        .map(|(i, (a, b, w))| (a, b, w * (1.0 + (i % 7) as f64 * 0.35)))
        .collect();
    let lap2 = parac::graph::Laplacian::from_edges(lap.n(), &edges, "reweighted");

    let engines = [Engine::Seq, Engine::Cpu { threads: 2 }, Engine::GpuSim { blocks: 2 }];
    let orderings = [Ordering::Natural, Ordering::Amd, Ordering::NnzSort, Ordering::Random];

    for engine in engines {
        for ordering in orderings {
            for threads in [1usize, 2, 4] {
                let ctx = format!("{engine:?}/{ordering:?}/t={threads}");
                let build = |l| {
                    Solver::builder()
                        .seed(11)
                        .ordering(ordering)
                        .engine(engine)
                        .threads(threads)
                        .level_cutoff(8)
                        .build(l)
                        .unwrap()
                };

                let mut s = build(&lap);
                let g0 = s.factor().unwrap().g.clone();
                let d0 = s.factor().unwrap().diag.clone();
                let p0 = s.factor().unwrap().perm.clone();
                // Advance the sweep counters so the refill-not-reanalyze
                // claim below is observable (threads > 1 sessions only).
                let b: Vec<f64> = (0..lap.n()).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
                let mut x = vec![0.0; lap.n()];
                s.solve_into(&b, &mut x).unwrap();
                let counters_before = s.sweep_counters();

                // Unchanged weights: bit-for-bit reproduction.
                s.refactorize(&lap).unwrap();
                {
                    let f = s.factor().unwrap();
                    assert_eq!(f.g, g0, "{ctx}: refactorize changed G");
                    assert_eq!(f.diag, d0, "{ctx}: refactorize changed D");
                    assert_eq!(f.perm, p0, "{ctx}: refactorize changed the permutation");
                }
                let st = s.factor_stats().unwrap();
                assert!(st.symbolic_reused, "{ctx}: numeric-only run must flag reuse");
                assert_eq!(st.symbolic_secs, 0.0, "{ctx}: no analysis on refactorize");
                // The packed executor survived (refill path): cumulative
                // counters are not reset, as a fresh analysis would do.
                assert_eq!(
                    s.sweep_counters(),
                    counters_before,
                    "{ctx}: refactorize must keep the packed executor"
                );

                // New weights: identical to a from-scratch session.
                s.refactorize(&lap2).unwrap();
                let fresh = build(&lap2);
                assert_eq!(
                    s.factor().unwrap().g,
                    fresh.factor().unwrap().g,
                    "{ctx}: refactorized G deviates from a fresh build"
                );
                assert_eq!(
                    s.factor().unwrap().diag,
                    fresh.factor().unwrap().diag,
                    "{ctx}: refactorized D deviates from a fresh build"
                );
            }
        }
    }
}

/// The pooled symbolic analysis is a pure optimization: for every
/// generator in the graph suite — plus disconnected and single-vertex
/// edge cases — the e-tree parents, the level buckets, and the complete
/// packed sweep layout are identical whether the analysis runs
/// sequentially or on 2/4 pool workers.
#[test]
fn pooled_analysis_deterministic_across_suite() {
    use parac::graph::suite::{Scale, SUITE};
    use parac::solve::packed::PackedSweeps;

    let mut graphs: Vec<parac::graph::Laplacian> =
        SUITE.iter().map(|e| (e.build)(Scale::Tiny)).collect();
    graphs.push(parac::graph::Laplacian::from_edges(
        6,
        &[(0, 1, 1.0), (2, 3, 2.0)],
        "disconnected",
    ));
    graphs.push(parac::graph::Laplacian::from_edges(1, &[], "single-vertex"));

    for l in &graphs {
        let f = factorize(l, &opts(7, Ordering::NnzSort, Engine::Seq)).unwrap();
        let parents = parac::etree::etree_from_factor(&f.g);
        assert_eq!(parents.len(), l.n());

        let (fwd_levels, fwd_max) = parac::etree::trisolve_levels(&f.g);
        let (bwd_levels, bwd_max) = parac::etree::trisolve_levels_bwd(&f.g);
        let fwd_ref = parac::etree::bucket_by_level(&fwd_levels, fwd_max);
        let bwd_ref = parac::etree::bucket_by_level(&bwd_levels, bwd_max);
        let reference = PackedSweeps::<f64>::analyze_with_opts(&f, 4, 1);

        for threads in [2usize, 4] {
            assert_eq!(
                parac::etree::bucket_by_level_par(&fwd_levels, fwd_max, threads),
                fwd_ref,
                "{} t={threads}: forward level buckets deviate",
                l.name
            );
            assert_eq!(
                parac::etree::bucket_by_level_par(&bwd_levels, bwd_max, threads),
                bwd_ref,
                "{} t={threads}: backward level buckets deviate",
                l.name
            );
            let pooled = PackedSweeps::<f64>::analyze_with_opts(&f, 4, threads);
            assert!(
                pooled.bitwise_eq(&reference),
                "{} t={threads}: pooled packed layout deviates",
                l.name
            );
        }

        // Determinism of the analysis inputs themselves: re-deriving the
        // e-tree from the same factor is exact.
        assert_eq!(parents, parac::etree::etree_from_factor(&f.g), "{}", l.name);
    }
}

/// The packed sweep executor is bit-identical to the sequential
/// in-place sweeps (`LdlFactor::{forward,backward}_inplace`) and to the
/// full sequential solve, across every engine, ordering, and thread
/// count — including a graph whose widest level exceeds the cutoff
/// (real pool dispatches + in-sweep barriers) and a disconnected graph
/// (zero-diagonal pivot columns applied pseudo-inversely).
#[test]
fn packed_sweeps_bit_identical_to_sequential_reference() {
    use parac::precond::{LdlPrecond, Preconditioner};
    use parac::solve::packed::PackedSweeps;

    // Two disconnected chains plus an isolated vertex (61): three
    // components → three zero pivots, including a fully zero diagonal
    // column in the input.
    let mut edges: Vec<(u32, u32, f64)> = (0..60u32).map(|i| (i, i + 1, 1.0)).collect();
    edges.extend((62..130u32).map(|i| (i, i + 1, 0.5 + (i % 3) as f64)));
    let disconnected = parac::graph::Laplacian::from_edges(131, &edges, "two-chains");

    // Star with the hub eliminated last (under Natural ordering): one
    // level of width n − 1 ≫ any cutoff used here.
    let star_edges: Vec<(u32, u32, f64)> =
        (0..599u32).map(|i| (i, 599, 1.0 + (i % 4) as f64)).collect();
    let graphs = [
        ("random", generators::random_connected(150, 240, 3)),
        ("wide-star", parac::graph::Laplacian::from_edges(600, &star_edges, "star-hub-last")),
        ("disconnected", disconnected),
    ];
    let engines = [
        Engine::Seq,
        Engine::Cpu { threads: 2 },
        Engine::GpuSim { blocks: 2 },
    ];
    let orderings = [Ordering::Natural, Ordering::Amd, Ordering::NnzSort, Ordering::Random];

    for (gname, l) in &graphs {
        for engine in engines {
            for ordering in orderings {
                let f = factorize(l, &opts(11, ordering, engine)).unwrap();
                // Cutoff 16: the wide graphs really dispatch pooled
                // sweeps with level-boundary barriers, narrow ones
                // exercise the worker-0 sequential runs.
                let packed = PackedSweeps::<f64>::analyze_with_cutoff(&f, 16);
                let pre = LdlPrecond::with_level_schedule_cutoff(f.clone(), 4, 16);
                let n = f.n();
                let r: Vec<f64> = (0..n).map(|i| ((i * 31) % 17) as f64 - 8.0).collect();
                let ctx = format!("{gname}/{engine:?}/{ordering:?}");

                // Sweep-level parity in permuted space.
                let rp = match &f.perm {
                    Some(p) => parac::ordering::perm::apply_vec(p, &r),
                    None => r.clone(),
                };
                let mut scratch = vec![0.0; n];
                for threads in [1usize, 2, 4] {
                    let mut want = rp.clone();
                    let mut got = rp.clone();
                    f.forward_inplace(&mut want);
                    packed.forward(&mut got, &mut scratch, threads);
                    assert_eq!(want, got, "{ctx} t={threads}: forward sweep deviates");
                    f.backward_inplace(&mut want);
                    packed.backward(&mut got, &mut scratch, threads);
                    assert_eq!(want, got, "{ctx} t={threads}: backward sweep deviates");
                }

                // Full apply parity (composed scatters + fused D⁻¹).
                let want = f.solve(&r);
                let mut z = vec![f64::NAN; n];
                pre.apply_into(&r, &mut z);
                assert_eq!(z, want, "{ctx}: packed apply deviates from solve");
            }
        }
    }

    // The wide-star really crossed the default cutoff too: its widest
    // level beats LEVEL_PAR_CUTOFF, so an executor configured at that
    // cutoff dispatches exactly once per sweep there. (Pinned
    // explicitly rather than via `analyze` so the assertion holds when
    // CI reruns the suite under `PARAC_LEVEL_CUTOFF` extremes.)
    let f = factorize(&graphs[1].1, &opts(11, Ordering::Natural, Engine::Seq)).unwrap();
    let packed =
        PackedSweeps::<f64>::analyze_with_cutoff(&f, parac::solve::trisolve::LEVEL_PAR_CUTOFF);
    let (levels, _) = parac::etree::trisolve_levels(&f.g);
    let widest = parac::etree::level_histogram(&levels).into_iter().max().unwrap();
    assert!(
        widest >= parac::solve::trisolve::LEVEL_PAR_CUTOFF,
        "star's widest level ({widest}) must clear the default cutoff"
    );
    let r: Vec<f64> = (0..f.n()).map(|i| (i % 5) as f64 - 2.0).collect();
    let want = f.solve(&r);
    let n = f.n();
    let (mut z, mut a, mut b) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
    let before = packed.counters();
    packed.apply_into(&r, &mut z, 4, &mut a, &mut b);
    assert_eq!(z, want);
    let delta = packed.counters().since(before);
    assert_eq!(delta.dispatches, 2, "one dispatch per sweep at the default cutoff");
}

/// The f32 value plane's two-tier contract, over the whole suite: every
/// matrix still converges to the same tolerance, within an iteration
/// budget of 1.3× the f64 plane — plus, per refinement-guard fallback,
/// one stagnation window of detection latency and one restarted solve.
#[test]
fn f32_plane_converges_within_iteration_budget_across_suite() {
    use parac::graph::suite::{Scale, SUITE};
    use parac::solve::pcg::{self, F32_STAGNATION_WINDOW};
    use parac::solver::Solver;
    use parac::sparse::Precision;

    for e in SUITE {
        let lap = (e.build)(Scale::Tiny);
        let b = pcg::random_rhs(&lap, 29);
        let run = |precision| {
            let mut s = Solver::builder()
                .seed(5)
                .threads(2)
                .precision(precision)
                .tol(1e-7)
                .max_iter(4000)
                .build(&lap)
                .unwrap();
            let mut x = vec![0.0; lap.n()];
            s.solve_into(&b, &mut x).unwrap()
        };
        let st64 = run(Precision::F64);
        let st32 = run(Precision::F32);
        assert!(st64.converged, "{}: f64 plane must converge", e.name);
        assert_eq!(st64.fallbacks, 0, "{}: the f64 plane never falls back", e.name);
        assert!(
            st32.converged && st32.rel_residual <= 1e-7,
            "{}: f32 plane must reach the same tolerance (rel={})",
            e.name,
            st32.rel_residual
        );
        // Clean f32 sessions are pinned at 1.3× the f64 count. Each
        // guard fallback may additionally spend a detection phase (some
        // partial progress, then one stagnation window) plus a restarted
        // solve — allow 2× (window + f64 count) per fallback for it.
        let budget = (st64.iters as f64 * 1.3).ceil()
            + st32.fallbacks as f64 * 2.0 * (F32_STAGNATION_WINDOW + st64.iters) as f64;
        assert!(
            st32.iters as f64 <= budget,
            "{}: f32 took {} iters vs f64 {} (budget {budget}, fallbacks {})",
            e.name,
            st32.iters,
            st64.iters,
            st32.fallbacks
        );
    }
}

/// The extreme-contrast suite entry overwhelms the f32 plane by
/// construction (heavy-half factor diagonal > `f32::MAX` saturates to
/// `inf`, zeroing that half of every apply): the refinement guard must
/// detect the stagnation, promote the session to the f64 plane
/// mid-solve, and still converge — and the promotion must be sticky.
#[test]
fn refinement_guard_rescues_extreme_contrast_in_f32_sessions() {
    use parac::graph::suite::{self, Scale};
    use parac::solve::pcg;
    use parac::solver::Solver;
    use parac::sparse::Precision;

    let lap = (suite::by_name("xcontrast_2d").unwrap().build)(Scale::Tiny);
    let b = pcg::random_rhs(&lap, 41);
    let mut s = Solver::builder()
        .seed(9)
        .threads(2)
        .precision(Precision::F32)
        .tol(1e-7)
        .max_iter(4000)
        .build(&lap)
        .unwrap();
    assert_eq!(s.factor_stats().unwrap().precision, Precision::F32);
    let mut x = vec![0.0; lap.n()];
    let st = s.solve_into(&b, &mut x).unwrap();
    assert!(st.converged, "guarded f32 session must converge (rel={})", st.rel_residual);
    assert_eq!(st.fallbacks, 1, "the overflowed plane must promote exactly once");
    assert_eq!(st.precision, Precision::F64, "the solve must end on the f64 plane");
    assert!(st.rel_residual <= 1e-7);

    // Follow-up solves run on the promoted plane from the start: no
    // second fallback, no renewed stagnation.
    let b2 = pcg::random_rhs(&lap, 42);
    let st2 = s.solve_into(&b2, &mut x).unwrap();
    assert!(st2.converged);
    assert_eq!(st2.fallbacks, 0, "promotion is sticky across solves");
    assert_eq!(st2.precision, Precision::F64);
}
