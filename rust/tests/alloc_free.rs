//! Proof of the session API's zero-allocation contract: a counting
//! global allocator wraps the system allocator, and repeated
//! `Solver::solve_into` calls after warm-up must not allocate at all —
//! not per iteration, not per solve. The contract covers the parallel
//! paths too: multi-threaded sessions dispatch SpMV row splits and
//! level-scheduled triangular sweeps onto the persistent `parac::par`
//! worker pool, whose steady-state dispatch is allocation-free by
//! construction (what used to be a documented exception when every
//! wide level spawned scoped OS threads is now an asserted guarantee).
//!
//! The parallel phase runs the ParAC triangular solves through the
//! packed sweep executor (`parac::solve::packed`): one pool dispatch
//! per sweep, resident workers barrier-syncing at level boundaries —
//! asserted both allocation-free *and* actually dispatching (the sweep
//! counters must move, so the test cannot silently degrade to the
//! sequential inline path).
//!
//! Phase 3 extends the contract to numeric-only refactorization:
//! `Solver::refactorize` on a frozen sparsity pattern recycles the
//! ordering, e-tree, packed schedules, engine workspaces, and the
//! double-buffered factor storage, so rebuilding the factor for new
//! edge weights — from the **first** refactorize onward, thanks to the
//! spare buffers pre-warmed at build time — allocates nothing either.
//!
//! Phase 4 extends it to the **concurrent** `&self` solve path behind
//! the serving subsystem: eight OS threads hammering one shared session
//! through `solve_shared` / `solve_batch_shared` stay allocation-free
//! once the workspace pool is warmed to the peak concurrency
//! (`Solver::warm_workspaces`) — checkout is a Mutex-guarded pop, the
//! operator and preconditioner are immutable, and the packed sweeps
//! serialize on the pool's dispatch lock without allocating.
//!
//! This lives in its own integration-test binary (one `#[test]`, four
//! phases) so no concurrently running test can touch the allocation
//! counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// System allocator with an allocation counter.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::SeqCst)
}

#[test]
fn solve_into_allocates_nothing_after_warmup() {
    use parac::factor::Engine;
    use parac::graph::generators;
    use parac::solve::pcg;
    use parac::solver::Solver;

    // ---- Phase 1: the sequential session (no pool involved). ----
    let lap = generators::grid2d(20, 20, generators::Coeff::Uniform, 0);
    let mut solver = Solver::builder()
        .engine(Engine::Seq)
        .seed(9)
        .tol(1e-8)
        .build(&lap)
        .expect("solver setup");

    let rhs: Vec<Vec<f64>> = (1..=4).map(|s| pcg::random_rhs(&lap, s)).collect();
    let mut x = vec![0.0; lap.n()];

    // Warm-up: first solve may size the (already pre-sized) workspace.
    let warm = solver.solve_into(&rhs[0], &mut x).expect("warm-up solve");
    assert!(warm.converged, "warm-up must converge (rel={})", warm.rel_residual);

    // Steady state: dozens of full PCG solves, zero allocations.
    let before = allocations();
    for b in rhs.iter().cycle().take(24) {
        let stats = solver.solve_into(b, &mut x).expect("steady-state solve");
        assert!(stats.converged);
    }
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "sequential solve_into allocated {} times across 24 warm solves — the \
         zero-allocation contract is broken",
        after - before
    );

    // ---- Phase 2: the pooled parallel session. ----
    // threads(2) row-splits every SpMV (the grid clears the parallel
    // cutoff, so the pool dispatches every iteration) and runs the
    // ParAC triangular solves through the packed sweep executor; the
    // small level cutoff guarantees the sweeps genuinely dispatch and
    // barrier rather than falling back to the inline sequential path.
    // The warm-up solve creates the global worker pool; after that,
    // dispatch is pure atomics + futex wakeups and a level boundary is
    // two atomics — steady state must stay at zero allocations,
    // exactly like the sequential path.
    let lap_wide = generators::grid2d(48, 48, generators::Coeff::Uniform, 1);
    assert!(
        lap_wide.n() >= parac::sparse::csr::PAR_SPMV_CUTOFF,
        "phase-2 grid must be large enough to exercise the parallel SpMV dispatch"
    );
    // Same pattern as `lap_wide`, every weight scaled by exactly 2.0.
    // A power-of-two scale leaves every sampling decision — and hence
    // the factor structure — bit-identical, so phase 3's refactorize
    // exercises the pure refill path. Declared before the solver so the
    // session (which borrows its operator) can refactorize onto it.
    let scaled: Vec<(u32, u32, f64)> =
        lap_wide.edges().into_iter().map(|(a, b, w)| (a, b, w * 2.0)).collect();
    let lap_scaled = parac::graph::Laplacian::from_edges(lap_wide.n(), &scaled, "scaled");
    let mut pooled = Solver::builder()
        .engine(Engine::Seq)
        .threads(2)
        .level_cutoff(8)
        .seed(9)
        .tol(1e-8)
        .build(&lap_wide)
        .expect("pooled solver setup");
    let rhs_wide: Vec<Vec<f64>> = (1..=4).map(|s| pcg::random_rhs(&lap_wide, s)).collect();
    let mut xw = vec![0.0; lap_wide.n()];

    let warm = pooled.solve_into(&rhs_wide[0], &mut xw).expect("pool warm-up solve");
    assert!(warm.converged, "pool warm-up must converge (rel={})", warm.rel_residual);
    assert!(
        warm.precond_dispatches >= 2,
        "packed sweeps must really dispatch onto the pool (got {})",
        warm.precond_dispatches
    );

    let before = allocations();
    for b in rhs_wide.iter().cycle().take(12) {
        let stats = pooled.solve_into(b, &mut xw).expect("pooled steady-state solve");
        assert!(stats.converged);
        assert_eq!(
            stats.precond_dispatches,
            2 * stats.iters as u64,
            "exactly one pool dispatch per sweep direction per apply"
        );
    }
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "packed-sweep/pooled solve_into allocated {} times across 12 warm \
         solves — one-dispatch-per-sweep execution must be allocation-free",
        after - before
    );

    // ---- Phase 3: numeric-only refactorization. ----
    // Alternate between the ×2.0-scaled weights and the originals. Each
    // refactorize reruns only the numeric phase on the frozen pattern
    // (value refresh, randomized sweep into the recycled spare buffers,
    // packed-executor refill) and each is followed by a full solve on
    // the new operator. Counted from the very first refactorize: the
    // spare factor buffers were reserved at build time, so even the
    // first numeric-only rebuild must not touch the allocator.
    let before = allocations();
    for round in 0..6usize {
        let lap_next = if round % 2 == 0 { &lap_scaled } else { &lap_wide };
        pooled.refactorize(lap_next).expect("numeric-only refactorize");
        let fs = pooled.factor_stats().expect("factor stats");
        assert!(fs.symbolic_reused, "refactorize must skip the symbolic phase");
        assert_eq!(fs.symbolic_secs, 0.0, "no analysis time on a frozen pattern");
        let stats = pooled.solve_into(&rhs_wide[round % 4], &mut xw).expect("post-refactorize solve");
        assert!(stats.converged);
    }
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "refactorize allocated {} times across 6 numeric-only rebuilds — the \
         frozen-pattern path must reuse every workspace and buffer",
        after - before
    );

    // ---- Phase 4: the concurrent `&self` solve path. ----
    // Eight OS threads hammer the same session through `solve_shared` /
    // `solve_batch_shared`. The workspace pool is pre-warmed to the
    // peak concurrency and every output buffer is pre-sized, so after
    // one concurrent warm-up round the measured window — full PCG
    // solves from eight threads at once, including the pooled packed
    // sweeps — must not touch the allocator at all. (Thread spawn/join
    // allocates, so the threads are started and barrier-synced *before*
    // the counter is read and joined after.)
    const CLIENTS: usize = 8;
    pooled.refactorize(&lap_wide).expect("reset to original weights");
    pooled.warm_workspaces(CLIENTS);
    {
        let session = &pooled;
        let barrier = std::sync::Barrier::new(CLIENTS + 1);
        let counted: AtomicU64 = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for t in 0..CLIENTS {
                let barrier = &barrier;
                let counted = &counted;
                let rhs_wide = &rhs_wide;
                scope.spawn(move || {
                    let mut x = vec![0.0; session.n()];
                    // Mixed traffic: even threads solve one RHS per
                    // call, odd threads drive two-RHS batches with
                    // pre-sized solutions and reused stats storage.
                    let mut xs = vec![vec![0.0; session.n()]; 2];
                    let mut stats_store = Vec::with_capacity(2);
                    let mut round = |n_rounds: usize| {
                        for r in 0..n_rounds {
                            if t % 2 == 0 {
                                let b = &rhs_wide[(t + r) % rhs_wide.len()];
                                let stats =
                                    session.solve_shared(b, &mut x).expect("concurrent solve");
                                assert!(stats.converged);
                            } else {
                                let bs: [&[f64]; 2] = [
                                    &rhs_wide[(t + r) % rhs_wide.len()],
                                    &rhs_wide[(t + r + 1) % rhs_wide.len()],
                                ];
                                session
                                    .solve_batch_shared(&bs, &mut xs, &mut stats_store)
                                    .expect("concurrent batch solve");
                                assert!(stats_store.iter().all(|s| s.converged));
                            }
                        }
                    };
                    // Concurrent warm-up (pool checkout order settles).
                    barrier.wait();
                    round(2);
                    // Measured window: all threads inside, zero allocs.
                    barrier.wait();
                    let before = allocations();
                    round(4);
                    counted.fetch_add(allocations() - before, Ordering::Relaxed);
                    barrier.wait();
                });
            }
            barrier.wait(); // release warm-up
            barrier.wait(); // all warmed: open the measured window
            barrier.wait(); // all counted: safe to join (joins allocate)
        });
        // Every thread measured its own window while all eight were
        // inside theirs, so any allocation anywhere in the concurrent
        // solve path lands in the sum.
        assert_eq!(
            counted.load(Ordering::Relaxed),
            0,
            "concurrent &self solves allocated — the shared-session \
             zero-allocation contract is broken"
        );
    }

    // ---- Phase 5: the f32 storage plane. ----
    // Narrowing the plane swaps the packed value arrays, not the
    // execution structure, so the whole contract above must hold
    // verbatim on an f32 session — warm single-owner solves and the
    // concurrent `&self` path. (A refinement-guard promotion builds the
    // f64 fallback plane once, which is an allocation by design; this
    // phase therefore uses the same well-conditioned operator, which
    // never promotes — asserted via `fallbacks`.)
    let mut narrow = Solver::builder()
        .engine(Engine::Seq)
        .threads(2)
        .level_cutoff(8)
        .seed(9)
        .tol(1e-8)
        .precision(parac::sparse::Precision::F32)
        .build(&lap_wide)
        .expect("f32 solver setup");
    let warm = narrow.solve_into(&rhs_wide[0], &mut xw).expect("f32 warm-up solve");
    assert!(warm.converged, "f32 warm-up must converge (rel={})", warm.rel_residual);
    assert_eq!(warm.precision, parac::sparse::Precision::F32, "must stay on the f32 plane");
    assert_eq!(warm.fallbacks, 0, "a well-conditioned operator must not promote");

    let before = allocations();
    for b in rhs_wide.iter().cycle().take(12) {
        let stats = narrow.solve_into(b, &mut xw).expect("f32 steady-state solve");
        assert!(stats.converged);
        assert_eq!(stats.fallbacks, 0);
    }
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "f32-plane solve_into allocated {} times across 12 warm solves — the \
         zero-allocation contract must be precision-independent",
        after - before
    );

    // Concurrent `&self` solves on the f32 plane.
    narrow.warm_workspaces(CLIENTS);
    {
        let session = &narrow;
        let barrier = std::sync::Barrier::new(CLIENTS + 1);
        let counted: AtomicU64 = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for t in 0..CLIENTS {
                let barrier = &barrier;
                let counted = &counted;
                let rhs_wide = &rhs_wide;
                scope.spawn(move || {
                    let mut x = vec![0.0; session.n()];
                    let mut round = |n_rounds: usize| {
                        for r in 0..n_rounds {
                            let b = &rhs_wide[(t + r) % rhs_wide.len()];
                            let stats =
                                session.solve_shared(b, &mut x).expect("concurrent f32 solve");
                            assert!(stats.converged);
                            assert_eq!(stats.fallbacks, 0);
                        }
                    };
                    barrier.wait();
                    round(2);
                    barrier.wait();
                    let before = allocations();
                    round(4);
                    counted.fetch_add(allocations() - before, Ordering::Relaxed);
                    barrier.wait();
                });
            }
            barrier.wait(); // release warm-up
            barrier.wait(); // all warmed: open the measured window
            barrier.wait(); // all counted: safe to join (joins allocate)
        });
        assert_eq!(
            counted.load(Ordering::Relaxed),
            0,
            "concurrent &self solves on the f32 plane allocated — the \
             shared-session contract must be precision-independent"
        );
    }
}
