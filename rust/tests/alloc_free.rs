//! Proof of the session API's zero-allocation contract: a counting
//! global allocator wraps the system allocator, and repeated
//! `Solver::solve_into` calls after warm-up must not allocate at all —
//! not per iteration, not per solve.
//!
//! This lives in its own integration-test binary (one `#[test]`) so no
//! concurrently running test can touch the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// System allocator with an allocation counter.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::SeqCst)
}

#[test]
fn solve_into_allocates_nothing_after_warmup() {
    use parac::factor::Engine;
    use parac::graph::generators;
    use parac::solve::pcg;
    use parac::solver::Solver;

    let lap = generators::grid2d(20, 20, generators::Coeff::Uniform, 0);
    // Sequential engine + sequential ParAC solve: the documented
    // allocation-free configuration (threads would allocate stacks).
    let mut solver = Solver::builder()
        .engine(Engine::Seq)
        .seed(9)
        .tol(1e-8)
        .build(&lap)
        .expect("solver setup");

    let rhs: Vec<Vec<f64>> = (1..=4).map(|s| pcg::random_rhs(&lap, s)).collect();
    let mut x = vec![0.0; lap.n()];

    // Warm-up: first solve may size the (already pre-sized) workspace.
    let warm = solver.solve_into(&rhs[0], &mut x).expect("warm-up solve");
    assert!(warm.converged, "warm-up must converge (rel={})", warm.rel_residual);

    // Steady state: dozens of full PCG solves, zero allocations.
    let before = allocations();
    for b in rhs.iter().cycle().take(24) {
        let stats = solver.solve_into(b, &mut x).expect("steady-state solve");
        assert!(stats.converged);
    }
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "solve_into allocated {} times across 24 warm solves — the \
         zero-allocation contract is broken",
        after - before
    );
}
