//! Serving-subsystem integration tests: the `&self` solve contract
//! under real OS-thread contention, and the cache + coalescing stack
//! end to end.
//!
//! The load-bearing claim (ISSUE acceptance): **concurrent solves
//! through a shared `&Solver` are bit-identical to a serial loop** —
//! not approximately equal, identical down to the last ULP — because
//! the factor, ordering maps, and packed sweep arrays are immutable
//! shared state and every mutable byte lives in a per-call checked-out
//! workspace. Static `Sync` is asserted at compile time in
//! `parac::serve`; these tests assert the runtime half.

use parac::graph::generators::{self, Coeff};
use parac::graph::Laplacian;
use parac::serve::{FactorCache, ServeOptions, SolveService};
use parac::solve::pcg::{self, SolveStats};
use parac::solver::Solver;
use std::sync::Arc;
use std::time::Duration;

const CLIENTS: usize = 8;

/// Serial reference: one `solve_shared` at a time, in request order.
fn serial_reference(solver: &Solver, rhs: &[Vec<f64>]) -> Vec<(Vec<f64>, SolveStats)> {
    let mut out = Vec::with_capacity(rhs.len());
    for b in rhs {
        let mut x = vec![0.0; b.len()];
        let stats = solver.solve_shared(b, &mut x).expect("serial reference solve");
        assert!(stats.converged);
        out.push((x, stats));
    }
    out
}

#[test]
fn eight_threads_on_one_shared_solver_match_the_serial_loop() {
    let lap = generators::grid2d(24, 24, Coeff::Uniform, 3);
    let solver = Solver::builder().threads(2).seed(5).build(&lap).expect("build");
    solver.warm_workspaces(CLIENTS);

    // 4 requests per client; client t solves rhs[t*4..t*4+4].
    let rhs: Vec<Vec<f64>> =
        (0..CLIENTS * 4).map(|i| pcg::random_rhs(&lap, 1000 + i as u64)).collect();
    let want = serial_reference(&solver, &rhs);

    // Mixed traffic: even clients issue single solves, odd clients run
    // their four requests as two 2-RHS batches.
    let got: Vec<Vec<(Vec<f64>, SolveStats)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|t| {
                let solver = &solver;
                let mine = &rhs[t * 4..t * 4 + 4];
                scope.spawn(move || {
                    let mut out = Vec::with_capacity(4);
                    if t % 2 == 0 {
                        for b in mine {
                            let mut x = vec![0.0; b.len()];
                            let stats =
                                solver.solve_shared(b, &mut x).expect("concurrent solve");
                            out.push((x, stats));
                        }
                    } else {
                        let mut stats = Vec::new();
                        for pair in mine.chunks(2) {
                            let bs: Vec<&[f64]> =
                                pair.iter().map(|b| b.as_slice()).collect();
                            let mut xs = vec![Vec::new(); bs.len()];
                            solver
                                .solve_batch_shared(&bs, &mut xs, &mut stats)
                                .expect("concurrent batch solve");
                            for (x, s) in xs.into_iter().zip(stats.iter()) {
                                out.push((x, *s));
                            }
                        }
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
    });

    for (t, results) in got.iter().enumerate() {
        for (i, (x, stats)) in results.iter().enumerate() {
            let (wx, wstats) = &want[t * 4 + i];
            assert_eq!(
                x, wx,
                "client {t} request {i}: concurrent solution deviates from serial"
            );
            assert_eq!(stats.iters, wstats.iters, "client {t} request {i}: iteration count");
            assert_eq!(
                stats.rel_residual.to_bits(),
                wstats.rel_residual.to_bits(),
                "client {t} request {i}: residual bits"
            );
        }
    }
}

#[test]
fn dimension_errors_are_typed_not_panics_under_sharing() {
    let lap = generators::grid2d(8, 8, Coeff::Uniform, 0);
    let solver = Solver::builder().seed(1).build(&lap).expect("build");
    let short = vec![1.0; lap.n() - 1];
    let mut x = vec![0.0; lap.n()];
    assert!(matches!(
        solver.solve_shared(&short, &mut x),
        Err(parac::ParacError::DimensionMismatch { what: "rhs", .. })
    ));
    let b = vec![1.0; lap.n()];
    let mut wrong = vec![0.0; 3];
    assert!(matches!(
        solver.solve_shared(&b, &mut wrong),
        Err(parac::ParacError::DimensionMismatch { what: "solution", .. })
    ));
}

#[test]
fn service_under_concurrent_mixed_graphs_stays_bit_identical() {
    // Two graphs + a reweighting of the first, served to 8 concurrent
    // clients through the full stack (cache admission, per-operator
    // gates, coalesced waves). Every response must equal the lone
    // shared-session solve on the same operator.
    let grid = Arc::new(generators::grid2d(16, 16, Coeff::Uniform, 2));
    let road = Arc::new(generators::road_like(14, 14, 0.1, 3));
    let heavy_edges: Vec<(u32, u32, f64)> =
        grid.edges().into_iter().map(|(a, b, w)| (a, b, w * 2.0)).collect();
    let heavy = Arc::new(Laplacian::from_edges(grid.n(), &heavy_edges, "heavy"));

    let svc = SolveService::new(
        FactorCache::new(Solver::builder().seed(9).threads(2), 4),
        ServeOptions { max_wave: 4, max_wait: Duration::from_micros(200), ..Default::default() },
    );
    // Pre-build all three operators so no client pays a cold build
    // inside the concurrent phase. `heavy` shares `grid`'s pattern, so
    // grid/heavy requests exercise the refactorize-or-rebuild decision
    // under contention — bit-identical either way.
    let graphs = [grid.clone(), road.clone(), heavy.clone()];
    for g in &graphs {
        let b = pcg::random_rhs(g, 1);
        assert!(svc.solve(g, &b).expect("pre-build").1.converged);
    }

    // References from the cached sessions themselves (lone calls).
    let rhs: Vec<(usize, Vec<f64>)> = (0..CLIENTS * 3)
        .map(|i| (i % 3, pcg::random_rhs(&graphs[i % 3], 500 + i as u64)))
        .collect();
    let want: Vec<Vec<f64>> = rhs
        .iter()
        .map(|(gi, b)| {
            let session = svc.cache().get_or_build(&graphs[*gi]).expect("cached");
            let mut x = vec![0.0; b.len()];
            assert!(session.solve_shared(b, &mut x).expect("reference").converged);
            x
        })
        .collect();

    let got: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = rhs
            .iter()
            .map(|(gi, b)| {
                let svc = &svc;
                let lap = &graphs[*gi];
                scope.spawn(move || svc.solve(lap, b).expect("served solve").0)
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
    });

    for (i, (x, wx)) in got.iter().zip(&want).enumerate() {
        assert_eq!(x, wx, "request {i}: served solution deviates from lone solve");
    }
    let st = svc.stats();
    assert_eq!(st.requests as usize, 3 + CLIENTS * 3);
    assert!(st.waves >= 3, "at least one wave per operator");
    // grid and heavy share a pattern: depending on which requests held
    // the session when the sibling weights arrived, the cache ends with
    // either one re-keyed entry for the pair (refactorize path) or both
    // resident (fresh-build fallback) — plus road. Both are correct.
    assert!((2..=3).contains(&svc.cache().len()), "resident count {}", svc.cache().len());
}

#[test]
fn bounded_admission_sheds_excess_requests_under_contention() {
    // Queue bound of one: the first request to reach the gate leads a
    // wave and holds the coalescing window open (max_wave is out of
    // reach), so a second concurrent request must be shed at admission
    // with the typed overload error — back-pressure, not an unbounded
    // queue, not a panic.
    let lap = Arc::new(generators::grid2d(12, 12, Coeff::Uniform, 6));
    let svc = SolveService::new(
        FactorCache::new(Solver::builder().seed(3), 2),
        ServeOptions {
            max_wave: 8,
            max_wait: Duration::from_secs(1),
            max_queue: 1,
            ..Default::default()
        },
    );
    // Pre-build the factor through the cache so neither contender pays
    // the build inside the timed window.
    svc.cache().get_or_build(&lap).expect("pre-build");
    let before = svc.stats();

    let b1 = pcg::random_rhs(&lap, 1);
    let b2 = pcg::random_rhs(&lap, 2);
    let (first, second) = std::thread::scope(|scope| {
        let leader = scope.spawn(|| svc.solve(&lap, &b1));
        // Give the spawned request time to enter the window; even if
        // this loses the race, exactly one of the two is shed.
        std::thread::sleep(Duration::from_millis(100));
        let contender = svc.solve(&lap, &b2);
        (leader.join().expect("leader panicked"), contender)
    });

    let served = [&first, &second].into_iter().filter(|r| r.is_ok()).count();
    assert_eq!(served, 1, "exactly one of two contending requests is served");
    for r in [&first, &second] {
        match r {
            Ok((_, stats)) => assert!(stats.converged, "served request must converge"),
            Err(e) => assert!(
                matches!(e, parac::ParacError::Overloaded { capacity: 1 }),
                "shed request must carry the typed overload error, got: {e}"
            ),
        }
    }
    let st = svc.stats();
    assert_eq!(st.requests - before.requests, 2, "shed requests still count as received");
    assert_eq!(st.shed - before.shed, 1, "exactly one request shed");
    assert_eq!(st.waves - before.waves, 1, "the survivor solves in a wave of one");
    assert_eq!(st.coalesced - before.coalesced, 0, "nothing rode the survivor's wave");
}

#[test]
fn reweighted_serving_routes_through_refactorize_and_matches_fresh_build() {
    // Serve graph A, drop every client, then serve reweighted A': the
    // cache must take the numeric-only path (symbolic_reused) and the
    // served answers must equal a from-scratch build on A'.
    let a = Arc::new(generators::grid2d(12, 12, Coeff::Uniform, 4));
    let svc = SolveService::new(
        FactorCache::new(Solver::builder().seed(13), 2),
        ServeOptions { max_wave: 2, max_wait: Duration::from_micros(50), ..Default::default() },
    );
    let b0 = pcg::random_rhs(&a, 1);
    assert!(svc.solve(&a, &b0).expect("first build").1.converged);

    let edges: Vec<(u32, u32, f64)> =
        a.edges().into_iter().map(|(u, v, w)| (u, v, w * 4.0)).collect();
    let a2 = Arc::new(Laplacian::from_edges(a.n(), &edges, "reweighted"));
    let b1 = pcg::random_rhs(&a2, 2);
    let (x, stats) = svc.solve(&a2, &b1).expect("reweighted solve");
    assert!(stats.converged);
    assert_eq!(svc.cache().stats().refactorizes, 1, "must take the numeric-only path");
    let session = svc.cache().get_or_build(&a2).expect("resident");
    assert!(session.factor_stats().expect("stats").symbolic_reused);

    let fresh = Solver::builder().seed(13).build(&a2).expect("fresh build");
    let mut wx = vec![0.0; a2.n()];
    assert!(fresh.solve_shared(&b1, &mut wx).expect("fresh solve").converged);
    assert_eq!(x, wx, "refactorized serving deviates from a fresh build");
}
