//! Dynamic-graph subsystem pins (ISSUE 10): weight-only streams are
//! bit-identical to numeric refactorize, the cone-localized path
//! converges like a full rebuild on **every** suite graph, update
//! batches have typed validation and pinned edge semantics, and both
//! session types are deterministic.

use parac::coordinator::incremental::IncrementalSession;
use parac::dynamic::scenario::{self, ScenarioOptions};
use parac::dynamic::{DynamicOptions, DynamicSession, UpdateBatch, UpdateClass};
use parac::error::ParacError;
use parac::factor::ParacOptions;
use parac::graph::generators::{self, Coeff};
use parac::graph::suite::{Scale, SUITE};
use parac::rng::Rng;
use parac::solve::pcg::{self, PcgOptions};
use parac::solver::{Solver, SolverBuilder};

fn builder() -> SolverBuilder {
    Solver::builder().seed(5).tol(1e-8).max_iter(1500)
}

/// A pattern-preserving stream reruns only the numeric phase, and the
/// resulting factor is bit-identical to a fresh build on the final
/// graph — the PR 5 refactorize contract carried through the session.
#[test]
fn weight_only_stream_is_bit_identical_to_refactorize() {
    let lap = generators::grid2d(14, 14, Coeff::Uniform, 0);
    let mut sess = DynamicSession::new(&lap, builder(), DynamicOptions::default()).unwrap();
    let b = pcg::random_rhs(&lap, 3);
    for round in 0..3 {
        let mut batch = UpdateBatch::default();
        let edges = sess.laplacian().edges();
        for (i, &(u, v, _)) in edges.iter().enumerate().take(40) {
            if i % 2 == round % 2 {
                batch.add.push((u, v, 0.25 + i as f64 * 0.01));
            }
        }
        let (rep, x) = sess.step(&batch, &b).unwrap();
        assert_eq!(rep.class, UpdateClass::WeightOnly, "round {round}");
        assert!(!rep.escalated);
        assert!(rep.converged, "round {round}: rel {}", rep.rel_residual);
        assert!(x.iter().all(|v| v.is_finite()));
    }
    assert_eq!(sess.counts().weight_only, 3);
    assert_eq!(sess.counts().localized, 0);
    assert_eq!(sess.counts().rebuild, 0);

    let fresh = builder().build_shared(sess.laplacian().clone()).unwrap();
    let ours = sess.factor().expect("session factor");
    let theirs = fresh.factor().expect("fresh factor");
    assert_eq!(ours.g, theirs.g, "weight-only stream must match a fresh build bit-for-bit");
    assert_eq!(ours.diag, theirs.diag);
}

/// The acceptance pin: for every suite graph, a structural-update
/// stream through the session converges to the same tolerance as a
/// full rebuild on the final graph — and the cone-localized path
/// actually fires across the suite.
#[test]
fn localized_stream_converges_across_suite() {
    let mut localized_seen = 0u64;
    for e in SUITE {
        let lap = (e.build)(Scale::Tiny);
        let n = lap.n();
        let b = pcg::random_rhs(&lap, 7);
        let bld = Solver::builder().seed(9).tol(1e-6).max_iter(1200);
        let mut sess = DynamicSession::new(
            &lap,
            bld.clone(),
            DynamicOptions { damage_threshold: 0.6, ..Default::default() },
        )
        .unwrap();

        // Four long-range edges that do not exist yet — guaranteed
        // structural on any suite graph.
        let mut picked: Vec<(u32, u32)> = Vec::new();
        'outer: for u in 0..n as u32 {
            for off in [n as u32 / 2, n as u32 / 3] {
                let v = (u + off) % n as u32;
                let key = (u.min(v), u.max(v));
                if u != v
                    && sess.laplacian().matrix.get(u as usize, v as usize) == 0.0
                    && !picked.contains(&key)
                {
                    picked.push(key);
                    if picked.len() == 4 {
                        break 'outer;
                    }
                }
            }
        }
        assert!(picked.len() == 4, "{}: could not find fresh edges", e.name);

        for chunk in picked.chunks(2) {
            let mut batch = UpdateBatch::default();
            for &(u, v) in chunk {
                batch.add.push((u, v, 1.0));
            }
            let (rep, _x) = sess.step(&batch, &b).unwrap();
            assert_ne!(
                rep.class,
                UpdateClass::WeightOnly,
                "{}: structural batch misclassified",
                e.name
            );
            assert!(
                rep.converged,
                "{}: {} round did not converge (rel {})",
                e.name,
                rep.class.name(),
                rep.rel_residual
            );
            if rep.class == UpdateClass::Localized {
                localized_seen += 1;
            }
        }

        // Same tolerance as a from-scratch rebuild on the final graph.
        let fresh = bld.build_shared(sess.laplacian().clone()).unwrap();
        let mut x_fresh = vec![0.0; n];
        let fresh_stats = fresh.solve_shared(&b, &mut x_fresh).unwrap();
        assert!(fresh_stats.converged, "{}: full rebuild did not converge", e.name);
        let mut x_sess = vec![0.0; n];
        let sess_stats = sess.solve(&b, &mut x_sess).unwrap();
        assert!(
            sess_stats.converged && sess_stats.rel_residual <= 1e-6,
            "{}: session solve rel {} vs rebuild rel {}",
            e.name,
            sess_stats.rel_residual,
            fresh_stats.rel_residual
        );
    }
    assert!(
        localized_seen > 0,
        "no suite graph ever took the cone-localized path"
    );
}

/// Satellite: nonpositive / non-finite weights and out-of-range
/// endpoints are typed `BadInput` at batch application — in both
/// session types — and a rejected batch leaves the graph untouched.
#[test]
fn bad_update_weights_are_typed_errors() {
    let lap = generators::grid2d(8, 8, Coeff::Uniform, 0);
    let mut dyn_sess = DynamicSession::new(&lap, builder(), DynamicOptions::default()).unwrap();
    let mut inc_sess = IncrementalSession::new(
        &lap,
        ParacOptions::default(),
        PcgOptions { tol: 1e-6, max_iter: 400, ..Default::default() },
    );
    let b = pcg::random_rhs(&lap, 1);
    let edges_before = dyn_sess.num_edges();
    let fp_before = dyn_sess.fingerprint();
    for w in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -1.0] {
        let batch = UpdateBatch { add: vec![(0, 63, w)], remove: vec![] };
        assert!(
            matches!(dyn_sess.step(&batch, &b), Err(ParacError::BadInput(_))),
            "DynamicSession accepted weight {w}"
        );
        assert!(
            matches!(inc_sess.step(&batch, &b), Err(ParacError::BadInput(_))),
            "IncrementalSession accepted weight {w}"
        );
    }
    let oob = UpdateBatch { add: vec![(0, 64, 1.0)], remove: vec![] };
    assert!(matches!(dyn_sess.step(&oob, &b), Err(ParacError::BadInput(_))));
    let oob = UpdateBatch { add: vec![], remove: vec![(64, 0)] };
    assert!(matches!(inc_sess.step(&oob, &b), Err(ParacError::BadInput(_))));
    // Rejected batches moved nothing.
    assert_eq!(dyn_sess.num_edges(), edges_before);
    assert_eq!(dyn_sess.fingerprint(), fp_before);
    // Both sessions remain usable afterwards.
    let ok = UpdateBatch { add: vec![(0, 63, 0.5)], remove: vec![] };
    assert!(dyn_sess.step(&ok, &b).unwrap().0.converged);
    assert!(inc_sess.step(&ok, &b).unwrap().0.converged);
}

/// Satellite: `UpdateBatch` edge semantics pinned through the session —
/// add-then-remove nets out, removing a nonexistent edge is a no-op,
/// repeated adds accumulate, and a disconnecting update still solves
/// (projected, per-component mean-zero rhs).
#[test]
fn update_batch_edge_semantics_are_pinned() {
    let lap = generators::grid2d(8, 8, Coeff::Uniform, 0);
    let b = pcg::random_rhs(&lap, 2);
    let mut sess = DynamicSession::new(&lap, builder(), DynamicOptions::default()).unwrap();

    // Add-then-remove of one (new) edge in one batch: adds apply first,
    // removes second — the edge nets out absent and nothing changed.
    let batch = UpdateBatch { add: vec![(0, 63, 2.0)], remove: vec![(0, 63)] };
    let before = sess.fingerprint();
    let (rep, _) = sess.step(&batch, &b).unwrap();
    assert_eq!(sess.laplacian().matrix.get(0, 63), 0.0);
    assert_eq!(sess.fingerprint(), before);
    assert_eq!(rep.class, UpdateClass::WeightOnly);

    // Removing a nonexistent edge is a no-op, not an error.
    let batch = UpdateBatch { add: vec![], remove: vec![(1, 50)] };
    let (rep, _) = sess.step(&batch, &b).unwrap();
    assert_eq!(sess.fingerprint(), before);
    assert_eq!(rep.class, UpdateClass::WeightOnly);

    // Repeated adds accumulate — within a batch and across batches
    // (endpoint order does not matter).
    let batch = UpdateBatch { add: vec![(0, 9, 0.5), (9, 0, 0.25)], remove: vec![] };
    sess.step(&batch, &b).unwrap();
    let batch = UpdateBatch { add: vec![(0, 9, 0.25)], remove: vec![] };
    sess.step(&batch, &b).unwrap();
    assert_eq!(sess.laplacian().matrix.get(0, 9), -1.0, "weights must accumulate");

    // A disconnecting removal: the projected solve on the surviving
    // component still succeeds (the isolated vertex rides a zero pivot).
    let star = generators::star(40);
    let mut sess = DynamicSession::new(&star, builder(), DynamicOptions::default()).unwrap();
    let mut b = vec![0.0f64; 40];
    for (i, bi) in b.iter_mut().enumerate() {
        if i != 7 {
            *bi = (i as f64 * 0.37).sin();
        }
    }
    let mean = b.iter().sum::<f64>() / 39.0;
    for (i, bi) in b.iter_mut().enumerate() {
        if i != 7 {
            *bi -= mean;
        }
    }
    let batch = UpdateBatch { add: vec![], remove: vec![(0, 7)] };
    let (rep, x) = sess.step(&batch, &b).unwrap();
    assert_eq!(sess.num_edges(), 38);
    assert!(rep.converged, "solve on the surviving component must converge");
    assert!(x.iter().all(|v| v.is_finite()));

    // Same semantics through the rebuild-every-round reference loop.
    let mut inc = IncrementalSession::new(
        &star,
        ParacOptions::default(),
        PcgOptions { tol: 1e-7, max_iter: 300, ..Default::default() },
    );
    let (irep, ix) = inc
        .step(&UpdateBatch { add: vec![], remove: vec![(0, 7)] }, &b)
        .unwrap();
    assert_eq!(irep.edges, 38);
    assert!(ix.iter().all(|v| v.is_finite()));
}

/// Satellite regression: identical session histories produce identical
/// round graphs — the `HashMap` iteration-order bug would make these
/// fingerprints (and the solves) differ run-to-run.
#[test]
fn incremental_rounds_are_deterministic() {
    let lap = generators::road_like(12, 12, 0.2, 5);
    let mk = || {
        IncrementalSession::new(
            &lap,
            ParacOptions::default(),
            PcgOptions { tol: 1e-6, max_iter: 600, ..Default::default() },
        )
    };
    let mut a = mk();
    let mut c = mk();
    let b = pcg::random_rhs(&lap, 4);
    let mut rng = Rng::new(17);
    for round in 0..4 {
        let mut batch = UpdateBatch::default();
        for _ in 0..12 {
            let u = rng.below(lap.n()) as u32;
            let v = rng.below(lap.n()) as u32;
            if u != v {
                batch.add.push((u, v, rng.range_f64(0.5, 2.0)));
            }
        }
        let (ra, xa) = a.step(&batch, &b).unwrap();
        let (rc, xc) = c.step(&batch, &b).unwrap();
        assert_eq!(ra.fingerprint, rc.fingerprint, "round {round} graphs diverged");
        assert_eq!(xa, xc, "round {round} solutions must be bit-identical");
    }
}

/// The delta-classified session is deterministic too: same initial
/// graph + same batches ⇒ same fingerprints, same classification, and
/// bit-identical solutions.
#[test]
fn dynamic_sessions_are_deterministic() {
    let lap = generators::grid2d(10, 10, Coeff::Uniform, 3);
    let mut a = DynamicSession::new(&lap, builder(), DynamicOptions::default()).unwrap();
    let mut c = DynamicSession::new(&lap, builder(), DynamicOptions::default()).unwrap();
    let b = pcg::random_rhs(&lap, 9);
    let batches = [
        UpdateBatch { add: vec![(0, 55, 1.0), (3, 77, 0.5)], remove: vec![] },
        UpdateBatch { add: vec![(0, 55, 0.25)], remove: vec![(3, 77)] },
        UpdateBatch { add: vec![(2, 3, 0.5)], remove: vec![] },
    ];
    for (i, batch) in batches.iter().enumerate() {
        let (ra, xa) = a.step(batch, &b).unwrap();
        let (rc, xc) = c.step(batch, &b).unwrap();
        assert_eq!(ra.fingerprint, rc.fingerprint, "batch {i}");
        assert_eq!(ra.class, rc.class, "batch {i}");
        assert_eq!(xa, xc, "batch {i} solutions must be bit-identical");
    }
}

/// Structural updates past the damage threshold rebuild through the
/// factor cache — and returning to a previously seen graph is a cache
/// hit, not a fresh factorization.
#[test]
fn rebuild_path_routes_through_the_factor_cache() {
    let lap = generators::grid2d(10, 10, Coeff::Uniform, 2);
    // Threshold 0 disables the localized path: every structural update
    // must rebuild.
    let mut sess = DynamicSession::new(
        &lap,
        builder(),
        DynamicOptions { damage_threshold: 0.0, ..Default::default() },
    )
    .unwrap();
    let b = pcg::random_rhs(&lap, 5);
    let (r1, _) = sess
        .step(&UpdateBatch { add: vec![(0, 55, 1.0)], remove: vec![] }, &b)
        .unwrap();
    assert_eq!(r1.class, UpdateClass::Rebuild);
    let (r2, _) = sess
        .step(&UpdateBatch { add: vec![], remove: vec![(0, 55)] }, &b)
        .unwrap();
    assert_eq!(r2.class, UpdateClass::Rebuild);
    // Back to the graph of round 1 (same weights): full-fingerprint hit.
    let (r3, _) = sess
        .step(&UpdateBatch { add: vec![(0, 55, 1.0)], remove: vec![] }, &b)
        .unwrap();
    assert_eq!(r3.class, UpdateClass::Rebuild);
    let st = sess.cache_stats();
    assert_eq!(st.hits, 1, "returning to a known graph must hit the cache");
    assert_eq!(st.misses, 2);
    assert_eq!(sess.counts().rebuild, 3);
    assert_eq!(sess.counts().localized, 0);
}

/// The scenario zoo runs end to end on a suite-independent grid (the
/// bench asserts convergence at scale; this is the cheap CI pin).
#[test]
fn scenario_zoo_smoke() {
    let lap = generators::grid2d(12, 12, Coeff::Uniform, 1);
    let opts = ScenarioOptions {
        rounds: 3,
        seed: 11,
        measure_full_rebuild: true,
        dynamic: DynamicOptions::default(),
    };
    for name in scenario::SCENARIOS {
        let rep = scenario::run(
            name,
            &lap,
            Solver::builder().seed(2).tol(1e-7).max_iter(1200),
            &opts,
        )
        .unwrap();
        assert_eq!(rep.rounds, 3, "{name}");
        assert_eq!(rep.counts.total(), 3, "{name}");
        assert!(rep.all_converged, "{name} had a non-converged round");
        assert!(
            rep.full_rebuild_secs > 0.0,
            "{name} must time the rebuild baseline"
        );
    }
}
