//! Session-API integration tests: `Solver` reuse semantics (one
//! workspace, many right-hand sides, results identical to fresh
//! sessions), typed-error behaviour across the public surface, and the
//! pipeline veneer staying consistent with the session it wraps.

use parac::coordinator::pipeline::{self, Method};
use parac::error::ParacError;
use parac::factor::Engine;
use parac::graph::generators;
use parac::ordering::Ordering;
use parac::solve::pcg::{self, PcgOptions};
use parac::solver::{PrecondKind, Solver};

/// Two right-hand sides through one session must produce exactly the
/// solutions two fresh single-use sessions produce: workspace reuse can
/// leak no state between solves.
#[test]
fn solver_reuse_matches_fresh_solves() {
    let lap = generators::grid2d(18, 18, generators::Coeff::Uniform, 0);
    let b1 = pcg::random_rhs(&lap, 1);
    let b2 = pcg::random_rhs(&lap, 2);

    let builder = Solver::builder().seed(11).engine(Engine::Seq).tol(1e-9);
    let mut shared = builder.build(&lap).unwrap();
    let mut x1 = vec![0.0; lap.n()];
    let mut x2 = vec![0.0; lap.n()];
    let s1 = shared.solve_into(&b1, &mut x1).unwrap();
    let s2 = shared.solve_into(&b2, &mut x2).unwrap();
    assert!(s1.converged && s2.converged);

    // Fresh session per rhs (same deterministic seed → same factor).
    let f1 = builder.build(&lap).unwrap().solve(&b1).unwrap();
    let f2 = builder.build(&lap).unwrap().solve(&b2).unwrap();
    assert_eq!(x1, f1.x, "rhs 1: reused workspace must be bit-identical");
    assert_eq!(x2, f2.x, "rhs 2: reused workspace must be bit-identical");
    assert_eq!(s1.iters, f1.iters);
    assert_eq!(s2.iters, f2.iters);
}

/// Re-solving the *same* rhs after an intervening different rhs gives
/// the same answer again (idempotent sessions).
#[test]
fn solver_resolve_is_idempotent() {
    let lap = generators::grid3d(5, 5, 5, generators::Coeff::Uniform, 3);
    let mut s = Solver::builder().seed(5).build(&lap).unwrap();
    let b = pcg::random_rhs(&lap, 7);
    let other = pcg::random_rhs(&lap, 8);
    let first = s.solve(&b).unwrap();
    s.solve(&other).unwrap();
    let again = s.solve(&b).unwrap();
    assert_eq!(first.x, again.x);
    assert_eq!(first.iters, again.iters);
}

/// The pipeline veneer and a hand-built session agree on the outcome.
#[test]
fn pipeline_matches_manual_session() {
    let lap = generators::grid2d(14, 14, generators::Coeff::Uniform, 0);
    let o = PcgOptions { tol: 1e-7, max_iter: 2000, ..Default::default() };
    let b = pcg::random_rhs(&lap, 9);
    let method = Method::IcholT { droptol: Some(1e-3), fill_target: None };
    let r = pipeline::run_with_rhs(&lap, &method, &o, &b).unwrap();

    let mut s = method.solver_builder(&o).build(&lap).unwrap();
    let out = s.solve(&b).unwrap();
    assert_eq!(r.iters, out.iters);
    assert_eq!(r.rel_residual, out.rel_residual);
    assert_eq!(r.nnz, s.preconditioner().nnz());
    assert_eq!(r.method, "ichol-t");
}

/// Every failure on the public surface is a typed error, never a panic.
#[test]
fn public_surface_returns_typed_errors() {
    // Empty input.
    let empty = parac::graph::Laplacian::from_edges(0, &[], "empty");
    assert!(matches!(
        Solver::builder().build(&empty),
        Err(ParacError::BadInput(_))
    ));
    assert!(pipeline::run(&empty, &Method::Jacobi, &PcgOptions::default(), 1).is_err());

    // Out-of-range knob.
    let lap = generators::grid2d(6, 6, generators::Coeff::Uniform, 0);
    assert!(matches!(
        Solver::builder()
            .preconditioner(PrecondKind::Ssor { omega: -1.0 })
            .build(&lap),
        Err(ParacError::InvalidOption { .. })
    ));

    // Dimension mismatches on both vector arguments.
    let mut s = Solver::builder().build(&lap).unwrap();
    let short = vec![1.0; 3];
    let mut x = vec![0.0; lap.n()];
    assert!(matches!(
        s.solve_into(&short, &mut x),
        Err(ParacError::DimensionMismatch { what: "rhs", .. })
    ));
    let b = pcg::random_rhs(&lap, 1);
    let mut short_x = vec![0.0; 3];
    assert!(matches!(
        s.solve_into(&b, &mut short_x),
        Err(ParacError::DimensionMismatch { what: "solution", .. })
    ));

    // Errors render useful messages.
    let Err(e) = Solver::builder().build(&empty) else {
        panic!("empty build must fail");
    };
    let msg = e.to_string();
    assert!(msg.contains("bad input"), "{msg}");
}

/// Non-convergence is data, not an error: an impossible tolerance with
/// a tiny budget returns Ok with `converged == false`.
#[test]
fn non_convergence_is_data() {
    let lap = generators::grid2d(16, 16, generators::Coeff::HighContrast(5.0), 1);
    let mut s = Solver::builder()
        .preconditioner(PrecondKind::Identity)
        .tol(1e-30)
        .max_iter(3)
        .build(&lap)
        .unwrap();
    let b = pcg::random_rhs(&lap, 2);
    let out = s.solve(&b).expect("budget exhaustion must not be an error");
    assert!(!out.converged);
    assert!(out.iters <= 3);
    assert!(out.rel_residual > 0.0);
}

/// `solve_batch` is sugar for looping `solve_into`: for every engine,
/// batched solutions and stats must be bit-identical to per-RHS
/// `solve_into` calls through an identically configured session
/// (factors are deterministic per `(matrix, ordering, seed)`, so a
/// fresh build reproduces the same factor).
#[test]
fn solve_batch_matches_looped_solve_into_for_every_engine() {
    let lap = generators::grid2d(16, 16, generators::Coeff::Uniform, 0);
    let bs: Vec<Vec<f64>> = (1..=5).map(|s| pcg::random_rhs(&lap, s)).collect();
    for engine in [Engine::Seq, Engine::Cpu { threads: 2 }, Engine::GpuSim { blocks: 2 }] {
        let builder = Solver::builder().engine(engine).seed(13).threads(2).tol(1e-9);
        let mut batch = builder.build(&lap).unwrap();
        let refs: Vec<&[f64]> = bs.iter().map(|b| b.as_slice()).collect();
        let mut xs = vec![Vec::new(); bs.len()];
        let stats = batch.solve_batch(&refs, &mut xs).unwrap();
        assert_eq!(stats.len(), bs.len());

        let mut single = builder.build(&lap).unwrap();
        let mut x = vec![0.0; lap.n()];
        for (i, b) in bs.iter().enumerate() {
            let st = single.solve_into(b, &mut x).unwrap();
            assert_eq!(xs[i], x, "{engine:?}: rhs {i} solution must be bit-identical");
            assert_eq!(stats[i].iters, st.iters, "{engine:?}: rhs {i} iterations");
            assert_eq!(stats[i].converged, st.converged, "{engine:?}: rhs {i}");
            assert_eq!(
                stats[i].rel_residual, st.rel_residual,
                "{engine:?}: rhs {i} residual must be bit-identical"
            );
        }
    }
}

/// The builder spans every ordering and engine combination.
#[test]
fn builder_spans_orderings_and_engines() {
    let lap = generators::grid2d(10, 10, generators::Coeff::Uniform, 0);
    let b = pcg::random_rhs(&lap, 3);
    for ord in [Ordering::Amd, Ordering::NnzSort, Ordering::Random, Ordering::Rcm] {
        for engine in [Engine::Seq, Engine::Cpu { threads: 2 }, Engine::GpuSim { blocks: 2 }] {
            let mut s = Solver::builder()
                .ordering(ord)
                .engine(engine)
                .seed(4)
                .build(&lap)
                .unwrap();
            let out = s.solve(&b).unwrap();
            assert!(out.converged, "{ord:?}/{engine:?}");
        }
    }
}
